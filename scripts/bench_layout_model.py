#!/usr/bin/env python3
"""Model harness seeding BENCH_layout.json.

Mirrors `cargo bench --bench layout_sweep` at the algorithmic level: the
streaming intersect engine under the flat memory layout (per-wedge
counter bumps along every second-hop prefix) versus the hub layout
(heavy-degree tail served by bigint-bitmap AND + popcount, whole-pass
hot-skip in the flat walk; see scripts/wedge_model.py).  Results are
asserted bit-identical before timing — the layout is a pure performance
knob.

This exists because the authoring container has no Rust toolchain; the
JSON it writes is labeled `"harness": "python-model"` and is superseded
by re-running the Rust bench (`parbutterfly bench run --filter layout`
or `cargo bench --bench layout_sweep`), which overwrites the same file
with native numbers.

Usage: python3 scripts/bench_layout_model.py
"""
import json
from pathlib import Path

import bench_model_common
import wedge_model as wm


def runners_for(stat, n, m, adj, up, side):
    if stat == "total":
        return [
            ("flat", lambda: wm.total_flat(n, adj, up)),
            ("hub", lambda: wm.total_hub(n, m, adj, up, side)),
        ]
    if stat == "vertex":
        return [
            ("flat", lambda: wm.per_vertex_intersect(n, adj, up, [0] * n)),
            ("hub", lambda: wm.per_vertex_hub(n, m, adj, up, side, [0] * n)),
        ]
    return [
        ("flat", lambda: wm.per_edge_intersect(n, m, adj, up, [0] * m)),
        ("hub", lambda: wm.per_edge_hub(n, m, adj, up, side, [0] * m)),
    ]


def butterflies(stat, result):
    if stat == "total":
        return result
    return sum(result) // 4


def main():
    rows = []
    summary = []
    for wl_id, describe, gen in wm.WORKLOADS:
        nu, nv, edges = gen()
        n, m = nu + nv, len(edges)
        adj, up, side = wm.preprocess(nu, nv, edges)
        print(f"[{wl_id}] {describe}: n={n} m={m}")
        for stat in ["total", "vertex", "edge"]:
            runners = runners_for(stat, n, m, adj, up, side)
            # Layouts must be bit-identical, not just fast.
            outs = [f() for _label, f in runners]
            assert outs[0] == outs[1], f"{wl_id}/{stat}: hub disagrees with flat"
            ms = {}
            for label, f in runners:
                ms[label] = bench_model_common.bench(f)
                rows.append({"workload": wl_id, "stat": stat, "config": label,
                             "median_ms": round(ms[label], 3)})
                print(f"  {stat}/{label:<6} {ms[label]:10.2f} ms")
            speedup = ms["flat"] / ms["hub"]
            print(f"  {stat}: hub speedup {speedup:.2f}x")
            summary.append({
                "workload": wl_id, "stat": stat,
                "flat_ms": round(ms["flat"], 3),
                "hub_ms": round(ms["hub"], 3),
                "speedup": round(speedup, 3),
                "butterflies": butterflies(stat, outs[0]),
            })
    doc = {
        "bench": "layout_sweep",
        "harness": "python-model",
        "note": ("Algorithmic model measurements (scripts/bench_layout_model.py): the "
                 "streaming intersect engine under the flat vs hub memory layouts "
                 "(hub: bigint-bitmap AND/popcount second hops into the deg > sqrt(m) "
                 "tail, whole-pass hot-skip), outputs asserted bit-identical.  "
                 "Workloads without a heavy tail (small/er/dense have no deg > "
                 "sqrt(m) vertices at model scale) measure the hub layout's overhead "
                 "floor; cl is the only workload that exercises the heavy tail "
                 "(H=36).  Python bigint popcounts do not reflect native "
                 "word-at-a-time popcount costs, so the flat/hub ratio here is "
                 "indicative only — regenerate natively with `parbutterfly bench "
                 "run --filter layout` or `cargo bench --bench layout_sweep`."),
        "env": bench_model_common.environment(threads=1),
        "threads": 1,
        "rows": rows,
        "summary": summary,
    }
    out = Path(__file__).resolve().parent.parent / "BENCH_layout.json"
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
