#!/usr/bin/env python3
"""Algorithmic model of the Rust peeling stack + golden-corpus generator.

Two jobs (the authoring container has no Rust toolchain, so this model
is how the intersect peeling path was validated before being written in
Rust — the same role scripts/bench_intersect_model.py played for the
counting engine in the previous PR):

* ``validate`` — randomized equivalence sweep: the aggregation-style
  UPDATE-V/UPDATE-E (what `peel/vertex.rs` / `peel/edge.rs` compute via
  the WedgeAgg strategies), the live-view streaming intersect
  UPDATE-V/UPDATE-E (what the new `PeelEngine::Intersect` path
  computes: incrementally-shrinking adjacency, dense counters,
  touched-list resets, no wedge records), and the literal
  recount-every-round oracle (`testutil/brute.rs`) must produce
  identical tip and wing numbers on every random graph.

* ``golden`` — regenerate ``rust/tests/golden/<name>.peel`` from the
  committed golden edge lists: pinned tip numbers for BOTH sides and
  wing numbers, computed by the literal oracle.  `golden_peel.rs`
  asserts every PeelEngine x BucketKind combination against these
  files.

Usage:
    python3 scripts/peel_model.py validate [trials]
    python3 scripts/peel_model.py golden
"""
import random
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
GOLDEN = ROOT / "rust" / "tests" / "golden"


# ---------------------------------------------------------------------------
# Graph plumbing (mirrors graph/bipartite.rs: edge id = position in the
# (u, v)-sorted deduplicated edge list).
# ---------------------------------------------------------------------------

class Graph:
    def __init__(self, nu, nv, edges):
        self.nu, self.nv = nu, nv
        self.edges = sorted(set(edges))
        self.m = len(self.edges)
        self.nbrs_u = [[] for _ in range(nu)]  # (v, eid)
        self.nbrs_v = [[] for _ in range(nv)]  # (u, eid)
        for eid, (u, v) in enumerate(self.edges):
            self.nbrs_u[u].append((v, eid))
            self.nbrs_v[v].append((u, eid))

    def wedges_centered_u(self):
        return sum(len(n) * (len(n) - 1) // 2 for n in self.nbrs_u)

    def wedges_centered_v(self):
        return sum(len(n) * (len(n) - 1) // 2 for n in self.nbrs_v)


def load_golden(path):
    nu = nv = None
    edges = []
    for line in path.read_text().splitlines():
        t = line.strip()
        if t.startswith("# bip"):
            _, _, a, b = t.split()
            nu, nv = int(a), int(b)
        elif t and not t.startswith("#"):
            u, v = t.split()
            edges.append((int(u), int(v)))
    return Graph(nu, nv, edges)


def common(a, b):
    return len(set(a) & set(b))


# ---------------------------------------------------------------------------
# Literal oracles (testutil/brute.rs): recount everything each round.
# ---------------------------------------------------------------------------

def oracle_tips(g, peel_u):
    nbrs = g.nbrs_u if peel_u else g.nbrs_v
    n = g.nu if peel_u else g.nv
    alive = [True] * n
    tip = [0] * n
    k, remaining = 0, n
    adj = [[v for (v, _) in nbrs[x]] for x in range(n)]
    while remaining:
        counts = [0] * n
        live = [x for x in range(n) if alive[x]]
        for i, x1 in enumerate(live):
            for x2 in live[i + 1:]:
                c = common(adj[x1], adj[x2])
                b = c * (c - 1) // 2
                counts[x1] += b
                counts[x2] += b
        mn = min(counts[x] for x in live)
        k = max(k, mn)
        for x in live:
            if counts[x] == mn:
                tip[x] = k
                alive[x] = False
                remaining -= 1
    return tip


def butterflies_per_edge(g, alive):
    """Per-edge butterfly counts over alive edges only."""
    be = [0] * g.m
    eid_of = {e: i for i, e in enumerate(g.edges)}
    for eid, (u1, v1) in enumerate(g.edges):
        if not alive[eid]:
            continue
        b = 0
        for (u2, e2) in g.nbrs_v[v1]:
            if u2 == u1 or not alive[e2]:
                continue
            for (v2, ea) in g.nbrs_u[u1]:
                if v2 == v1 or not alive[ea]:
                    continue
                eb = eid_of.get((u2, v2))
                if eb is not None and alive[eb]:
                    b += 1
        be[eid] = b
    return be


def oracle_wings(g):
    alive = [True] * g.m
    wing = [0] * g.m
    k, remaining = 0, g.m
    while remaining:
        counts = butterflies_per_edge(g, alive)
        mn = min(counts[e] for e in range(g.m) if alive[e])
        k = max(k, mn)
        for e in range(g.m):
            if alive[e] and counts[e] == mn:
                wing[e] = k
                alive[e] = False
                remaining -= 1
    return wing


# ---------------------------------------------------------------------------
# Bucketing model (both Rust backends produce this exact sequence).
# ---------------------------------------------------------------------------

class Buckets:
    def __init__(self, counts):
        self.cur = list(counts)
        self.final = [False] * len(counts)

    def pop_min(self):
        live = [i for i in range(len(self.cur)) if not self.final[i]]
        if not live:
            return None
        mn = min(self.cur[i] for i in live)
        batch = [i for i in live if self.cur[i] == mn]
        for i in batch:
            self.final[i] = True
        return mn, batch

    def update(self, i, nc):
        if not self.final[i]:
            self.cur[i] = nc


# ---------------------------------------------------------------------------
# PEEL-V: aggregation path vs live-view intersect path.
# ---------------------------------------------------------------------------

def peel_v_agg(g, counts, peel_u):
    """update_v semantics of peel/vertex.rs: per-pair wedge
    multiplicities over the FULL adjacency, second endpoints filtered by
    the peeled[] array (previous rounds + current batch)."""
    nbrs_peel = g.nbrs_u if peel_u else g.nbrs_v
    nbrs_other = g.nbrs_v if peel_u else g.nbrs_u
    n = g.nu if peel_u else g.nv
    buckets = Buckets(counts)
    peeled = [False] * n
    tips = [0] * n
    k = 0
    while True:
        popped = buckets.pop_min()
        if popped is None:
            break
        c, batch = popped
        k = max(k, c)
        for x in batch:
            tips[x] = k
            peeled[x] = True
        delta = {}
        for x1 in batch:
            pair = {}
            for (y, _e) in nbrs_peel[x1]:
                for (x2, _e2) in nbrs_other[y]:
                    if x2 != x1 and not peeled[x2]:
                        pair[x2] = pair.get(x2, 0) + 1
            for x2, d in pair.items():
                b = d * (d - 1) // 2
                if b:
                    delta[x2] = delta.get(x2, 0) + b
        for x2, removed in delta.items():
            if not peeled[x2]:
                buckets.update(x2, max(buckets.cur[x2] - removed, k))
    return tips


def peel_v_intersect(g, counts, peel_u):
    """Live-view streaming path (the new PeelEngine::Intersect):
    remove the batch from every center's live list FIRST, then walk
    x1 -> y -> live x2 with a dense counter + touched list."""
    nbrs_peel = g.nbrs_u if peel_u else g.nbrs_v
    nbrs_other = g.nbrs_v if peel_u else g.nbrs_u
    n = g.nu if peel_u else g.nv
    n_other = g.nv if peel_u else g.nu
    # Live CSR: per center y, live peel-side neighbors with O(1)
    # swap-removal via a per-edge position index.
    live = [[(x, e) for (x, e) in nbrs_other[y]] for y in range(n_other)]
    llen = [len(live[y]) for y in range(n_other)]
    pos = [0] * g.m
    for y in range(n_other):
        for i, (_x, e) in enumerate(live[y]):
            pos[e] = i

    def remove(y, e):
        i = pos[e]
        last = llen[y] - 1
        assert live[y][i][1] == e
        live[y][i] = live[y][last]
        pos[live[y][i][1]] = i
        llen[y] = last

    buckets = Buckets(counts)
    tips = [0] * n
    k = 0
    cnt = [0] * n
    while True:
        popped = buckets.pop_min()
        if popped is None:
            break
        c, batch = popped
        k = max(k, c)
        for x in batch:
            tips[x] = k
        for x1 in batch:
            for (y, e) in nbrs_peel[x1]:
                remove(y, e)
        delta = {}
        for x1 in batch:
            touched = []
            for (y, _e) in nbrs_peel[x1]:
                row = live[y]
                for i in range(llen[y]):
                    x2 = row[i][0]
                    if cnt[x2] == 0:
                        touched.append(x2)
                    cnt[x2] += 1
            for x2 in touched:
                b = cnt[x2] * (cnt[x2] - 1) // 2
                if b:
                    delta[x2] = delta.get(x2, 0) + b
                cnt[x2] = 0
        for x2, removed in delta.items():
            buckets.update(x2, max(buckets.cur[x2] - removed, k))
    return tips


# ---------------------------------------------------------------------------
# PEEL-E: aggregation path vs live-view intersect path.
# ---------------------------------------------------------------------------

ALIVE = -1


def alive_for(round_of, rnd, x, e):
    r = round_of[x]
    return r == ALIVE or (r == rnd and x > e)


def peel_e_agg(g, counts):
    """update_e semantics of peel/edge.rs: sorted-list intersections
    over the full adjacency, same-round tie-break via alive_for."""
    eid_of = {e: i for i, e in enumerate(g.edges)}
    buckets = Buckets(counts)
    round_of = [ALIVE] * g.m
    wings = [0] * g.m
    k, rnd = 0, 0
    while True:
        popped = buckets.pop_min()
        if popped is None:
            break
        c, batch = popped
        k = max(k, c)
        for e in batch:
            wings[e] = k
            round_of[e] = rnd
        delta = {}

        def emit(eid):
            delta[eid] = delta.get(eid, 0) + 1

        for e in batch:
            u1, v1 = g.edges[e]
            for (u2, e2) in g.nbrs_v[v1]:
                if u2 == u1 or not alive_for(round_of, rnd, e2, e):
                    continue
                for (v2, ea) in g.nbrs_u[u1]:
                    if v2 == v1:
                        continue
                    eb = eid_of.get((u2, v2))
                    if eb is None:
                        continue
                    if alive_for(round_of, rnd, ea, e) and alive_for(round_of, rnd, eb, e):
                        emit(e2)
                        emit(ea)
                        emit(eb)
        for e, removed in delta.items():
            if round_of[e] == ALIVE:
                buckets.update(e, max(buckets.cur[e] - removed, k))
        rnd += 1
    return wings


def peel_e_intersect(g, counts):
    """Live-view streaming path: adjacency pruned of PREVIOUS rounds
    (batch edges removed only after the walk, so the same-round
    alive_for tie-break still sees them), dense v2 stamps instead of
    pairwise intersections."""
    buckets = Buckets(counts)
    round_of = [ALIVE] * g.m
    wings = [0] * g.m
    k, rnd = 0, 0
    # Live incident-edge lists for both sides, O(1) removal.
    live_u = [list(g.nbrs_u[u]) for u in range(g.nu)]
    live_v = [list(g.nbrs_v[v]) for v in range(g.nv)]
    ulen = [len(r) for r in live_u]
    vlen = [len(r) for r in live_v]
    pos_u = [0] * g.m
    pos_v = [0] * g.m
    for u in range(g.nu):
        for i, (_v, e) in enumerate(live_u[u]):
            pos_u[e] = i
    for v in range(g.nv):
        for i, (_u, e) in enumerate(live_v[v]):
            pos_v[e] = i

    def remove(e):
        u, v = g.edges[e]
        i = pos_u[e]
        last = ulen[u] - 1
        live_u[u][i] = live_u[u][last]
        pos_u[live_u[u][i][1]] = i
        ulen[u] = last
        i = pos_v[e]
        last = vlen[v] - 1
        live_v[v][i] = live_v[v][last]
        pos_v[live_v[v][i][1]] = i
        vlen[v] = last

    stamp_eid = [0] * g.nv   # v2 -> ea edge id
    stamp_tag = [-1] * g.nv  # validity tag (peeled-edge id being processed)
    while True:
        popped = buckets.pop_min()
        if popped is None:
            break
        c, batch = popped
        k = max(k, c)
        for e in batch:
            wings[e] = k
            round_of[e] = rnd
        delta = {}

        def emit(eid):
            delta[eid] = delta.get(eid, 0) + 1

        for e in batch:
            u1, v1 = g.edges[e]
            # Stamp live N(u1); edge e itself fails alive_for(e, e).
            for i in range(ulen[u1]):
                v2, ea = live_u[u1][i]
                if alive_for(round_of, rnd, ea, e):
                    stamp_eid[v2] = ea
                    stamp_tag[v2] = e
            for i in range(vlen[v1]):
                u2, e2 = live_v[v1][i]
                if not alive_for(round_of, rnd, e2, e):
                    continue
                for j in range(ulen[u2]):
                    v2, eb = live_u[u2][j]
                    if stamp_tag[v2] == e and alive_for(round_of, rnd, eb, e):
                        emit(e2)
                        emit(stamp_eid[v2])
                        emit(eb)
        for e in batch:
            remove(e)
        for e, removed in delta.items():
            if round_of[e] == ALIVE:
                buckets.update(e, max(buckets.cur[e] - removed, k))
        rnd += 1
    return wings


# ---------------------------------------------------------------------------
# Initial counts (the counting framework's per-vertex / per-edge output).
# ---------------------------------------------------------------------------

def initial_vertex_counts(g, peel_u):
    nbrs = g.nbrs_u if peel_u else g.nbrs_v
    n = g.nu if peel_u else g.nv
    adj = [[v for (v, _) in nbrs[x]] for x in range(n)]
    counts = [0] * n
    for x1 in range(n):
        for x2 in range(x1 + 1, n):
            c = common(adj[x1], adj[x2])
            b = c * (c - 1) // 2
            counts[x1] += b
            counts[x2] += b
    return counts


def initial_edge_counts(g):
    return butterflies_per_edge(g, [True] * g.m)


# ---------------------------------------------------------------------------
# Entrypoints.
# ---------------------------------------------------------------------------

def random_graph(rng):
    nu = rng.randrange(2, 13)
    nv = rng.randrange(2, 13)
    m = rng.randrange(0, min(nu * nv, 70))
    edges = {(rng.randrange(nu), rng.randrange(nv)) for _ in range(m)}
    return Graph(nu, nv, edges)


def validate(trials):
    rng = random.Random(20260729)
    for t in range(trials):
        g = random_graph(rng)
        for peel_u in (True, False):
            counts = initial_vertex_counts(g, peel_u)
            expect = oracle_tips(g, peel_u)
            agg = peel_v_agg(g, counts, peel_u)
            isect = peel_v_intersect(g, counts, peel_u)
            assert agg == expect, f"trial {t} peel_u={peel_u}: agg {agg} != {expect}"
            assert isect == expect, f"trial {t} peel_u={peel_u}: intersect {isect} != {expect}"
        be = initial_edge_counts(g)
        expect = oracle_wings(g)
        agg = peel_e_agg(g, be)
        isect = peel_e_intersect(g, be)
        assert agg == expect, f"trial {t}: edge agg {agg} != {expect}"
        assert isect == expect, f"trial {t}: edge intersect {isect} != {expect}"
        if (t + 1) % 50 == 0:
            print(f"  {t + 1}/{trials} trials ok")
    print(f"validate: {trials} randomized graphs, all four peeling paths == oracle")


CORPUS = ["davis", "k6x7", "er20x25", "er16x16", "cl30x20", "blocks12"]


def golden():
    for name in CORPUS:
        g = load_golden(GOLDEN / f"{name}.txt")
        tips_u = oracle_tips(g, True)
        tips_v = oracle_tips(g, False)
        wings = oracle_wings(g)
        # Cross-check the pinned values against the incremental models
        # before writing anything.
        assert peel_v_intersect(g, initial_vertex_counts(g, True), True) == tips_u, name
        assert peel_v_intersect(g, initial_vertex_counts(g, False), False) == tips_v, name
        assert peel_e_intersect(g, initial_edge_counts(g)) == wings, name
        out = GOLDEN / f"{name}.peel"
        lines = [
            f"# golden peeling decomposition for {name}.txt",
            "# regenerate: python3 scripts/peel_model.py golden "
            "(literal recount-every-round oracle, = testutil/brute.rs)",
            f"# rows: tips_u ({g.nu} values), tips_v ({g.nv} values), wings ({g.m} values)",
            "tips_u " + " ".join(map(str, tips_u)),
            "tips_v " + " ".join(map(str, tips_v)),
            "wings " + " ".join(map(str, wings)),
        ]
        out.write_text("\n".join(lines) + "\n")
        print(f"wrote {out} (max tip_u {max(tips_u)}, max tip_v {max(tips_v)}, "
              f"max wing {max(wings) if wings else 0})")


if __name__ == "__main__":
    cmd = sys.argv[1] if len(sys.argv) > 1 else "validate"
    if cmd == "validate":
        validate(int(sys.argv[2]) if len(sys.argv) > 2 else 300)
    elif cmd == "golden":
        golden()
    else:
        sys.exit(f"unknown command {cmd!r}")
