#!/usr/bin/env python3
"""Algorithmic model of the Rust peeling stack + golden-corpus generator.

Two jobs (the authoring container has no Rust toolchain, so this model
is how the intersect peeling path was validated before being written in
Rust — the same role scripts/bench_intersect_model.py played for the
counting engine in the previous PR):

* ``validate`` — randomized equivalence sweep: the aggregation-style
  UPDATE-V/UPDATE-E (what `peel/vertex.rs` / `peel/edge.rs` compute via
  the WedgeAgg strategies), the live-view streaming intersect
  UPDATE-V/UPDATE-E (what the new `PeelEngine::Intersect` path
  computes: incrementally-shrinking adjacency, dense counters,
  touched-list resets, no wedge records), and the literal
  recount-every-round oracle (`testutil/brute.rs`) must produce
  identical tip and wing numbers on every random graph.

* ``golden`` — regenerate ``rust/tests/golden/<name>.peel`` from the
  committed golden edge lists: pinned tip numbers for BOTH sides and
  wing numbers, computed by the literal oracle.  `golden_peel.rs`
  asserts every PeelEngine x BucketKind combination against these
  files.

Usage:
    python3 scripts/peel_model.py validate [trials]
    python3 scripts/peel_model.py golden
    python3 scripts/peel_model.py corpus
    python3 scripts/peel_model.py --two-phase <edge-list.txt>

``validate`` also covers the two-phase coarse->fine models
(`PeelEngine::TwoPhase`), ``corpus`` regenerates the six PR-8 stress
graphs (`NEW_CORPUS`), and ``--two-phase`` prints the two-phase model's
full decomposition of one golden-format edge list for differential use.
"""
import random
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
GOLDEN = ROOT / "rust" / "tests" / "golden"


# ---------------------------------------------------------------------------
# Graph plumbing (mirrors graph/bipartite.rs: edge id = position in the
# (u, v)-sorted deduplicated edge list).
# ---------------------------------------------------------------------------

class Graph:
    def __init__(self, nu, nv, edges):
        self.nu, self.nv = nu, nv
        self.edges = sorted(set(edges))
        self.m = len(self.edges)
        self.nbrs_u = [[] for _ in range(nu)]  # (v, eid)
        self.nbrs_v = [[] for _ in range(nv)]  # (u, eid)
        for eid, (u, v) in enumerate(self.edges):
            self.nbrs_u[u].append((v, eid))
            self.nbrs_v[v].append((u, eid))

    def wedges_centered_u(self):
        return sum(len(n) * (len(n) - 1) // 2 for n in self.nbrs_u)

    def wedges_centered_v(self):
        return sum(len(n) * (len(n) - 1) // 2 for n in self.nbrs_v)


def load_golden(path):
    nu = nv = None
    edges = []
    for line in path.read_text().splitlines():
        t = line.strip()
        if t.startswith("# bip"):
            _, _, a, b = t.split()
            nu, nv = int(a), int(b)
        elif t and not t.startswith("#"):
            u, v = t.split()
            edges.append((int(u), int(v)))
    return Graph(nu, nv, edges)


def common(a, b):
    return len(set(a) & set(b))


# ---------------------------------------------------------------------------
# Literal oracles (testutil/brute.rs): recount everything each round.
# ---------------------------------------------------------------------------

def oracle_tips(g, peel_u):
    nbrs = g.nbrs_u if peel_u else g.nbrs_v
    n = g.nu if peel_u else g.nv
    alive = [True] * n
    tip = [0] * n
    k, remaining = 0, n
    adj = [[v for (v, _) in nbrs[x]] for x in range(n)]
    while remaining:
        counts = [0] * n
        live = [x for x in range(n) if alive[x]]
        for i, x1 in enumerate(live):
            for x2 in live[i + 1:]:
                c = common(adj[x1], adj[x2])
                b = c * (c - 1) // 2
                counts[x1] += b
                counts[x2] += b
        mn = min(counts[x] for x in live)
        k = max(k, mn)
        for x in live:
            if counts[x] == mn:
                tip[x] = k
                alive[x] = False
                remaining -= 1
    return tip


def butterflies_per_edge(g, alive):
    """Per-edge butterfly counts over alive edges only."""
    be = [0] * g.m
    eid_of = {e: i for i, e in enumerate(g.edges)}
    for eid, (u1, v1) in enumerate(g.edges):
        if not alive[eid]:
            continue
        b = 0
        for (u2, e2) in g.nbrs_v[v1]:
            if u2 == u1 or not alive[e2]:
                continue
            for (v2, ea) in g.nbrs_u[u1]:
                if v2 == v1 or not alive[ea]:
                    continue
                eb = eid_of.get((u2, v2))
                if eb is not None and alive[eb]:
                    b += 1
        be[eid] = b
    return be


def oracle_wings(g):
    alive = [True] * g.m
    wing = [0] * g.m
    k, remaining = 0, g.m
    while remaining:
        counts = butterflies_per_edge(g, alive)
        mn = min(counts[e] for e in range(g.m) if alive[e])
        k = max(k, mn)
        for e in range(g.m):
            if alive[e] and counts[e] == mn:
                wing[e] = k
                alive[e] = False
                remaining -= 1
    return wing


# ---------------------------------------------------------------------------
# Bucketing model (both Rust backends produce this exact sequence).
# ---------------------------------------------------------------------------

class Buckets:
    def __init__(self, counts):
        self.cur = list(counts)
        self.final = [False] * len(counts)

    def pop_min(self):
        live = [i for i in range(len(self.cur)) if not self.final[i]]
        if not live:
            return None
        mn = min(self.cur[i] for i in live)
        batch = [i for i in live if self.cur[i] == mn]
        for i in batch:
            self.final[i] = True
        return mn, batch

    def update(self, i, nc):
        if not self.final[i]:
            self.cur[i] = nc


# ---------------------------------------------------------------------------
# PEEL-V: aggregation path vs live-view intersect path.
# ---------------------------------------------------------------------------

def peel_v_agg(g, counts, peel_u):
    """update_v semantics of peel/vertex.rs: per-pair wedge
    multiplicities over the FULL adjacency, second endpoints filtered by
    the peeled[] array (previous rounds + current batch)."""
    nbrs_peel = g.nbrs_u if peel_u else g.nbrs_v
    nbrs_other = g.nbrs_v if peel_u else g.nbrs_u
    n = g.nu if peel_u else g.nv
    buckets = Buckets(counts)
    peeled = [False] * n
    tips = [0] * n
    k = 0
    while True:
        popped = buckets.pop_min()
        if popped is None:
            break
        c, batch = popped
        k = max(k, c)
        for x in batch:
            tips[x] = k
            peeled[x] = True
        delta = {}
        for x1 in batch:
            pair = {}
            for (y, _e) in nbrs_peel[x1]:
                for (x2, _e2) in nbrs_other[y]:
                    if x2 != x1 and not peeled[x2]:
                        pair[x2] = pair.get(x2, 0) + 1
            for x2, d in pair.items():
                b = d * (d - 1) // 2
                if b:
                    delta[x2] = delta.get(x2, 0) + b
        for x2, removed in delta.items():
            if not peeled[x2]:
                buckets.update(x2, max(buckets.cur[x2] - removed, k))
    return tips


def peel_v_intersect(g, counts, peel_u):
    """Live-view streaming path (the new PeelEngine::Intersect):
    remove the batch from every center's live list FIRST, then walk
    x1 -> y -> live x2 with a dense counter + touched list."""
    nbrs_peel = g.nbrs_u if peel_u else g.nbrs_v
    nbrs_other = g.nbrs_v if peel_u else g.nbrs_u
    n = g.nu if peel_u else g.nv
    n_other = g.nv if peel_u else g.nu
    # Live CSR: per center y, live peel-side neighbors with O(1)
    # swap-removal via a per-edge position index.
    live = [[(x, e) for (x, e) in nbrs_other[y]] for y in range(n_other)]
    llen = [len(live[y]) for y in range(n_other)]
    pos = [0] * g.m
    for y in range(n_other):
        for i, (_x, e) in enumerate(live[y]):
            pos[e] = i

    def remove(y, e):
        i = pos[e]
        last = llen[y] - 1
        assert live[y][i][1] == e
        live[y][i] = live[y][last]
        pos[live[y][i][1]] = i
        llen[y] = last

    buckets = Buckets(counts)
    tips = [0] * n
    k = 0
    cnt = [0] * n
    while True:
        popped = buckets.pop_min()
        if popped is None:
            break
        c, batch = popped
        k = max(k, c)
        for x in batch:
            tips[x] = k
        for x1 in batch:
            for (y, e) in nbrs_peel[x1]:
                remove(y, e)
        delta = {}
        for x1 in batch:
            touched = []
            for (y, _e) in nbrs_peel[x1]:
                row = live[y]
                for i in range(llen[y]):
                    x2 = row[i][0]
                    if cnt[x2] == 0:
                        touched.append(x2)
                    cnt[x2] += 1
            for x2 in touched:
                b = cnt[x2] * (cnt[x2] - 1) // 2
                if b:
                    delta[x2] = delta.get(x2, 0) + b
                cnt[x2] = 0
        for x2, removed in delta.items():
            buckets.update(x2, max(buckets.cur[x2] - removed, k))
    return tips


# ---------------------------------------------------------------------------
# PEEL-E: aggregation path vs live-view intersect path.
# ---------------------------------------------------------------------------

ALIVE = -1


def alive_for(round_of, rnd, x, e):
    r = round_of[x]
    return r == ALIVE or (r == rnd and x > e)


def peel_e_agg(g, counts):
    """update_e semantics of peel/edge.rs: sorted-list intersections
    over the full adjacency, same-round tie-break via alive_for."""
    eid_of = {e: i for i, e in enumerate(g.edges)}
    buckets = Buckets(counts)
    round_of = [ALIVE] * g.m
    wings = [0] * g.m
    k, rnd = 0, 0
    while True:
        popped = buckets.pop_min()
        if popped is None:
            break
        c, batch = popped
        k = max(k, c)
        for e in batch:
            wings[e] = k
            round_of[e] = rnd
        delta = {}

        def emit(eid):
            delta[eid] = delta.get(eid, 0) + 1

        for e in batch:
            u1, v1 = g.edges[e]
            for (u2, e2) in g.nbrs_v[v1]:
                if u2 == u1 or not alive_for(round_of, rnd, e2, e):
                    continue
                for (v2, ea) in g.nbrs_u[u1]:
                    if v2 == v1:
                        continue
                    eb = eid_of.get((u2, v2))
                    if eb is None:
                        continue
                    if alive_for(round_of, rnd, ea, e) and alive_for(round_of, rnd, eb, e):
                        emit(e2)
                        emit(ea)
                        emit(eb)
        for e, removed in delta.items():
            if round_of[e] == ALIVE:
                buckets.update(e, max(buckets.cur[e] - removed, k))
        rnd += 1
    return wings


def peel_e_intersect(g, counts):
    """Live-view streaming path: adjacency pruned of PREVIOUS rounds
    (batch edges removed only after the walk, so the same-round
    alive_for tie-break still sees them), dense v2 stamps instead of
    pairwise intersections."""
    buckets = Buckets(counts)
    round_of = [ALIVE] * g.m
    wings = [0] * g.m
    k, rnd = 0, 0
    # Live incident-edge lists for both sides, O(1) removal.
    live_u = [list(g.nbrs_u[u]) for u in range(g.nu)]
    live_v = [list(g.nbrs_v[v]) for v in range(g.nv)]
    ulen = [len(r) for r in live_u]
    vlen = [len(r) for r in live_v]
    pos_u = [0] * g.m
    pos_v = [0] * g.m
    for u in range(g.nu):
        for i, (_v, e) in enumerate(live_u[u]):
            pos_u[e] = i
    for v in range(g.nv):
        for i, (_u, e) in enumerate(live_v[v]):
            pos_v[e] = i

    def remove(e):
        u, v = g.edges[e]
        i = pos_u[e]
        last = ulen[u] - 1
        live_u[u][i] = live_u[u][last]
        pos_u[live_u[u][i][1]] = i
        ulen[u] = last
        i = pos_v[e]
        last = vlen[v] - 1
        live_v[v][i] = live_v[v][last]
        pos_v[live_v[v][i][1]] = i
        vlen[v] = last

    stamp_eid = [0] * g.nv   # v2 -> ea edge id
    stamp_tag = [-1] * g.nv  # validity tag (peeled-edge id being processed)
    while True:
        popped = buckets.pop_min()
        if popped is None:
            break
        c, batch = popped
        k = max(k, c)
        for e in batch:
            wings[e] = k
            round_of[e] = rnd
        delta = {}

        def emit(eid):
            delta[eid] = delta.get(eid, 0) + 1

        for e in batch:
            u1, v1 = g.edges[e]
            # Stamp live N(u1); edge e itself fails alive_for(e, e).
            for i in range(ulen[u1]):
                v2, ea = live_u[u1][i]
                if alive_for(round_of, rnd, ea, e):
                    stamp_eid[v2] = ea
                    stamp_tag[v2] = e
            for i in range(vlen[v1]):
                u2, e2 = live_v[v1][i]
                if not alive_for(round_of, rnd, e2, e):
                    continue
                for j in range(ulen[u2]):
                    v2, eb = live_u[u2][j]
                    if stamp_tag[v2] == e and alive_for(round_of, rnd, eb, e):
                        emit(e2)
                        emit(stamp_eid[v2])
                        emit(eb)
        for e in batch:
            remove(e)
        for e, removed in delta.items():
            if round_of[e] == ALIVE:
                buckets.update(e, max(buckets.cur[e] - removed, k))
        rnd += 1
    return wings


# ---------------------------------------------------------------------------
# Two-phase coarse->fine peeling (PeelEngine::TwoPhase; RECEIPT-style,
# arXiv 2110.12511).  Phase 1 partitions items into tip/wing-number
# ranges with threshold-staged bulk peels; phase 2 re-seeds each range
# with range-restricted butterfly counts and fine-peels every range
# independently.  The Rust engine parallelizes ACROSS ranges; the model
# runs them in order — the decompositions are identical by construction.
# ---------------------------------------------------------------------------

THR_INF = 1 << 62


def range_thresholds(counts):
    """Coarse range boundaries, balanced by butterfly mass: walk the
    distinct initial-count values ascending and cut whenever the
    accumulated mass crosses the next of P ~= sqrt(n) equal targets.
    Mirrors peel/two_phase.rs exactly (there the ascending value walk
    comes from draining rank-style MaxBuckets over log2 keys and
    sorting each claimed frontier; the concatenation is this sort).
    Always ends with a sentinel "infinite" threshold; all-equal or
    all-zero inputs degenerate to a single range."""
    n = len(counts)
    total = sum(counts)
    p = max(1, int(n ** 0.5))
    thr = []
    if total > 0 and p > 1:
        order = sorted(counts)
        acc, i, j = 0, 0, 1
        while i < n and j < p:
            v = order[i]
            while i < n and order[i] == v:
                acc += v
                i += 1
            if acc * p >= j * total:
                thr.append(v)
                while j < p and acc * p >= j * total:
                    j += 1
    thr.append(THR_INF)
    return thr


def peel_v_two_phase(g, counts, peel_u):
    """Two-phase PEEL-V.

    Coarse: each sub-round bulk-removes EVERY live vertex whose current
    count is <= the stage threshold and applies one intersect-style
    update walk; by the threshold-core property the set removed during
    stage j is exactly {x : tip(x) in (thr[j-1], thr[j]]}, which pins
    stage[x] without knowing exact tips.

    Seeds: pair wedge multiplicities d(x1, x2) are STATIC under PEEL-V
    (centers never die), so each vertex's butterfly count restricted to
    same-or-later ranges is one up-front pass: seed(x1) =
    sum_{stage(x2) >= stage(x1)} C(d(x1, x2), 2) — the cross-range
    support is subtracted once, up front, not maintained.

    Fine: each range peels independently over a sub-view holding only
    its own members; a range-local running max starting at 0 provably
    equals the global one (every seed exceeds the previous stage's
    threshold, which bounds the global k entering the range)."""
    nbrs_peel = g.nbrs_u if peel_u else g.nbrs_v
    nbrs_other = g.nbrs_v if peel_u else g.nbrs_u
    n = g.nu if peel_u else g.nv
    n_other = g.nv if peel_u else g.nu
    thr = range_thresholds(counts)

    # Phase 1: coarse staged peel over a live center view.
    live = [[(x, e) for (x, e) in nbrs_other[y]] for y in range(n_other)]
    llen = [len(live[y]) for y in range(n_other)]
    pos = [0] * g.m
    for y in range(n_other):
        for i, (_x, e) in enumerate(live[y]):
            pos[e] = i

    def remove(y, e):
        i = pos[e]
        last = llen[y] - 1
        assert live[y][i][1] == e
        live[y][i] = live[y][last]
        pos[live[y][i][1]] = i
        llen[y] = last

    cur = list(counts)
    alive = [True] * n
    stage = [0] * n
    cnt = [0] * n
    for j, th in enumerate(thr):
        while True:
            batch = [x for x in range(n) if alive[x] and cur[x] <= th]
            if not batch:
                break
            for x in batch:
                alive[x] = False
                stage[x] = j
            for x1 in batch:
                for (y, e) in nbrs_peel[x1]:
                    remove(y, e)
            delta = {}
            for x1 in batch:
                touched = []
                for (y, _e) in nbrs_peel[x1]:
                    row = live[y]
                    for i in range(llen[y]):
                        x2 = row[i][0]
                        if cnt[x2] == 0:
                            touched.append(x2)
                        cnt[x2] += 1
                for x2 in touched:
                    b = cnt[x2] * (cnt[x2] - 1) // 2
                    if b:
                        delta[x2] = delta.get(x2, 0) + b
                    cnt[x2] = 0
            # A butterfly holds exactly two peel-side vertices, so the
            # per-x1 sum is exact even for mixed-count bulk batches —
            # counts stay true (and non-negative) without clamping.
            for x2, removed in delta.items():
                cur[x2] -= removed

    # Seeds: one pass over static pair multiplicities.
    seed = [0] * n
    for x1 in range(n):
        s = stage[x1]
        pair = {}
        for (y, _e) in nbrs_peel[x1]:
            for (x2, _e2) in nbrs_other[y]:
                if x2 != x1 and stage[x2] >= s:
                    pair[x2] = pair.get(x2, 0) + 1
        seed[x1] = sum(d * (d - 1) // 2 for d in pair.values())

    # Phase 2: per-range fine peel over members-only sub-views.
    tips = [0] * n
    for j in range(len(thr)):
        members = [x for x in range(n) if stage[x] == j]
        if not members:
            continue
        fl = [[(x, e) for (x, e) in nbrs_other[y] if stage[x] == j]
              for y in range(n_other)]
        flen = [len(fl[y]) for y in range(n_other)]
        fpos = [0] * g.m
        for y in range(n_other):
            for i, (_x, e) in enumerate(fl[y]):
                fpos[e] = i

        def fremove(y, e):
            i = fpos[e]
            last = flen[y] - 1
            assert fl[y][i][1] == e
            fl[y][i] = fl[y][last]
            fpos[fl[y][i][1]] = i
            flen[y] = last

        idx = {x: i for i, x in enumerate(members)}
        buckets = Buckets([seed[x] for x in members])
        k = 0
        while True:
            popped = buckets.pop_min()
            if popped is None:
                break
            c, lbatch = popped
            k = max(k, c)
            batch = [members[i] for i in lbatch]
            for x in batch:
                tips[x] = k
            for x1 in batch:
                for (y, e) in nbrs_peel[x1]:
                    fremove(y, e)
            delta = {}
            for x1 in batch:
                touched = []
                for (y, _e) in nbrs_peel[x1]:
                    row = fl[y]
                    for i in range(flen[y]):
                        x2 = row[i][0]
                        if cnt[x2] == 0:
                            touched.append(x2)
                        cnt[x2] += 1
                for x2 in touched:
                    b = cnt[x2] * (cnt[x2] - 1) // 2
                    if b:
                        delta[x2] = delta.get(x2, 0) + b
                    cnt[x2] = 0
            for x2, removed in delta.items():
                buckets.update(idx[x2], max(buckets.cur[idx[x2]] - removed, k))
    return tips


def peel_e_two_phase(g, counts):
    """Two-phase PEEL-E.  Edge butterfly supports are NOT static, so
    the coarse pass runs threshold-staged bulk rounds with the exact
    intersect-style walk (same-frontier double counting resolved by the
    alive_for tie-break: every destroyed butterfly is enumerated by its
    smallest frontier edge only), the seed pass recounts, per edge,
    exactly the butterflies whose other three edges live in
    same-or-later ranges (one stamped enumeration over the full graph),
    and each range fine-peels a sub-view of the stage >= j edges in
    which later-range edges are permanently alive — present in every
    walk, never decremented, never re-bucketed."""
    eid_of = {e: i for i, e in enumerate(g.edges)}
    thr = range_thresholds(counts)

    # Phase 1: coarse staged bulk peel.
    live_u = [list(g.nbrs_u[u]) for u in range(g.nu)]
    live_v = [list(g.nbrs_v[v]) for v in range(g.nv)]
    ulen = [len(r) for r in live_u]
    vlen = [len(r) for r in live_v]
    pos_u = [0] * g.m
    pos_v = [0] * g.m
    for u in range(g.nu):
        for i, (_v, e) in enumerate(live_u[u]):
            pos_u[e] = i
    for v in range(g.nv):
        for i, (_u, e) in enumerate(live_v[v]):
            pos_v[e] = i

    def remove(e):
        u, v = g.edges[e]
        i = pos_u[e]
        last = ulen[u] - 1
        live_u[u][i] = live_u[u][last]
        pos_u[live_u[u][i][1]] = i
        ulen[u] = last
        i = pos_v[e]
        last = vlen[v] - 1
        live_v[v][i] = live_v[v][last]
        pos_v[live_v[v][i][1]] = i
        vlen[v] = last

    cur = list(counts)
    round_of = [ALIVE] * g.m
    stage = [0] * g.m
    stamp_eid = [0] * g.nv
    stamp_tag = [-1] * g.nv
    rnd = 0
    for j, th in enumerate(thr):
        while True:
            batch = [e for e in range(g.m) if round_of[e] == ALIVE and cur[e] <= th]
            if not batch:
                break
            for e in batch:
                round_of[e] = rnd
                stage[e] = j
            delta = {}

            def emit(eid):
                delta[eid] = delta.get(eid, 0) + 1

            for e in batch:
                u1, v1 = g.edges[e]
                for i in range(ulen[u1]):
                    v2, ea = live_u[u1][i]
                    if alive_for(round_of, rnd, ea, e):
                        stamp_eid[v2] = ea
                        stamp_tag[v2] = e
                for i in range(vlen[v1]):
                    u2, e2 = live_v[v1][i]
                    if not alive_for(round_of, rnd, e2, e):
                        continue
                    for jj in range(ulen[u2]):
                        v2, eb = live_u[u2][jj]
                        if stamp_tag[v2] == e and alive_for(round_of, rnd, eb, e):
                            emit(e2)
                            emit(stamp_eid[v2])
                            emit(eb)
            for e in batch:
                remove(e)
            for e, removed in delta.items():
                if round_of[e] == ALIVE:
                    cur[e] -= removed
            rnd += 1

    # Seeds: butterflies of e whose other three edges all have
    # stage >= stage(e).
    seed = [0] * g.m
    for e, (u1, v1) in enumerate(g.edges):
        s = stage[e]
        b = 0
        for (u2, e2) in g.nbrs_v[v1]:
            if u2 == u1 or stage[e2] < s:
                continue
            for (v2, ea) in g.nbrs_u[u1]:
                if v2 == v1 or stage[ea] < s:
                    continue
                eb = eid_of.get((u2, v2))
                if eb is not None and stage[eb] >= s:
                    b += 1
        seed[e] = b

    # Phase 2: per-range fine peel.  Fresh stamp arrays (an edge id may
    # have stamped v2 entries during the coarse walk); one set shared
    # across ranges is safe because every edge is walked in exactly one
    # range.  fr_round doubles as the peeled marker: stage > j edges
    # keep ALIVE for the whole of range j.
    wings = [0] * g.m
    fr_round = [ALIVE] * g.m
    fstamp_eid = [0] * g.nv
    fstamp_tag = [-1] * g.nv
    for j in range(len(thr)):
        members = [e for e in range(g.m) if stage[e] == j]
        if not members:
            continue
        fu = [[(v, e) for (v, e) in g.nbrs_u[u] if stage[e] >= j]
              for u in range(g.nu)]
        fv = [[(u, e) for (u, e) in g.nbrs_v[v] if stage[e] >= j]
              for v in range(g.nv)]
        fulen = [len(r) for r in fu]
        fvlen = [len(r) for r in fv]
        fpos_u = [0] * g.m
        fpos_v = [0] * g.m
        for u in range(g.nu):
            for i, (_v, e) in enumerate(fu[u]):
                fpos_u[e] = i
        for v in range(g.nv):
            for i, (_u, e) in enumerate(fv[v]):
                fpos_v[e] = i

        def fremove(e):
            u, v = g.edges[e]
            i = fpos_u[e]
            last = fulen[u] - 1
            fu[u][i] = fu[u][last]
            fpos_u[fu[u][i][1]] = i
            fulen[u] = last
            i = fpos_v[e]
            last = fvlen[v] - 1
            fv[v][i] = fv[v][last]
            fpos_v[fv[v][i][1]] = i
            fvlen[v] = last

        idx = {e: i for i, e in enumerate(members)}
        buckets = Buckets([seed[e] for e in members])
        k, rnd = 0, 0
        while True:
            popped = buckets.pop_min()
            if popped is None:
                break
            c, lbatch = popped
            k = max(k, c)
            batch = [members[i] for i in lbatch]
            for e in batch:
                wings[e] = k
                fr_round[e] = rnd
            delta = {}

            def emit(eid):
                delta[eid] = delta.get(eid, 0) + 1

            for e in batch:
                u1, v1 = g.edges[e]
                for i in range(fulen[u1]):
                    v2, ea = fu[u1][i]
                    if alive_for(fr_round, rnd, ea, e):
                        fstamp_eid[v2] = ea
                        fstamp_tag[v2] = e
                for i in range(fvlen[v1]):
                    u2, e2 = fv[v1][i]
                    if not alive_for(fr_round, rnd, e2, e):
                        continue
                    for jj in range(fulen[u2]):
                        v2, eb = fu[u2][jj]
                        if fstamp_tag[v2] == e and alive_for(fr_round, rnd, eb, e):
                            emit(e2)
                            emit(fstamp_eid[v2])
                            emit(eb)
            for e in batch:
                fremove(e)
            for e2, removed in delta.items():
                if stage[e2] == j and fr_round[e2] == ALIVE:
                    buckets.update(idx[e2], max(buckets.cur[idx[e2]] - removed, k))
            rnd += 1
    return wings


# ---------------------------------------------------------------------------
# Initial counts (the counting framework's per-vertex / per-edge output).
# ---------------------------------------------------------------------------

def initial_vertex_counts(g, peel_u):
    nbrs = g.nbrs_u if peel_u else g.nbrs_v
    n = g.nu if peel_u else g.nv
    adj = [[v for (v, _) in nbrs[x]] for x in range(n)]
    counts = [0] * n
    for x1 in range(n):
        for x2 in range(x1 + 1, n):
            c = common(adj[x1], adj[x2])
            b = c * (c - 1) // 2
            counts[x1] += b
            counts[x2] += b
    return counts


def initial_edge_counts(g):
    return butterflies_per_edge(g, [True] * g.m)


# ---------------------------------------------------------------------------
# Entrypoints.
# ---------------------------------------------------------------------------

def random_graph(rng):
    nu = rng.randrange(2, 13)
    nv = rng.randrange(2, 13)
    m = rng.randrange(0, min(nu * nv, 70))
    edges = {(rng.randrange(nu), rng.randrange(nv)) for _ in range(m)}
    if rng.random() < 0.3:
        # Heavy tail: promote one u to a hub wired across all of V, so
        # the two-phase range boundaries see skewed butterfly mass.
        hub = rng.randrange(nu)
        edges |= {(hub, v) for v in range(nv)}
    return Graph(nu, nv, edges)


def validate(trials):
    rng = random.Random(20260729)
    for t in range(trials):
        g = random_graph(rng)
        for peel_u in (True, False):
            counts = initial_vertex_counts(g, peel_u)
            expect = oracle_tips(g, peel_u)
            agg = peel_v_agg(g, counts, peel_u)
            isect = peel_v_intersect(g, counts, peel_u)
            two = peel_v_two_phase(g, counts, peel_u)
            assert agg == expect, f"trial {t} peel_u={peel_u}: agg {agg} != {expect}"
            assert isect == expect, f"trial {t} peel_u={peel_u}: intersect {isect} != {expect}"
            assert two == expect, f"trial {t} peel_u={peel_u}: two-phase {two} != {expect}"
        be = initial_edge_counts(g)
        expect = oracle_wings(g)
        agg = peel_e_agg(g, be)
        isect = peel_e_intersect(g, be)
        two = peel_e_two_phase(g, be)
        assert agg == expect, f"trial {t}: edge agg {agg} != {expect}"
        assert isect == expect, f"trial {t}: edge intersect {isect} != {expect}"
        assert two == expect, f"trial {t}: edge two-phase {two} != {expect}"
        if (t + 1) % 50 == 0:
            print(f"  {t + 1}/{trials} trials ok")
    print(f"validate: {trials} randomized graphs, all six peeling paths == oracle")


def two_phase_oracle(path):
    """`--two-phase` model oracle: print the full decomposition of one
    golden-format edge list, computed through the two-phase models (the
    differential layer can diff this against any Rust engine)."""
    g = load_golden(Path(path))
    tips_u = peel_v_two_phase(g, initial_vertex_counts(g, True), True)
    tips_v = peel_v_two_phase(g, initial_vertex_counts(g, False), False)
    wings = peel_e_two_phase(g, initial_edge_counts(g))
    print("tips_u " + " ".join(map(str, tips_u)))
    print("tips_v " + " ".join(map(str, tips_v)))
    print("wings " + " ".join(map(str, wings)))


# ---------------------------------------------------------------------------
# Golden corpus.  The first six graphs predate this file (headers name
# their gen:: recipes); the last six are peeling stress shapes owned by
# `corpus` below: heavy tails skew the two-phase range boundaries, tie
# blocks collapse them, disconnection and an empty side exercise the
# degenerate paths.
# ---------------------------------------------------------------------------

CORPUS = [
    "davis", "k6x7", "er20x25", "er16x16", "cl30x20", "blocks12",
    "hub30x22", "hub14x40", "ties16x16", "ties15x15", "disc20x17", "empty9x0",
]


def gen_hub30x22(rng):
    edges = {(0, v) for v in range(22)}
    edges |= {(1, v) for v in range(15)}
    edges |= {(2, v) for v in range(10)}
    for u in range(3, 30):
        for _ in range(rng.randrange(2, 5)):
            edges.add((u, rng.randrange(22)))
    return 30, 22, edges


def gen_hub14x40(rng):
    edges = {(u, 0) for u in range(14)}
    edges |= {(u, 1) for u in range(9)}
    for v in range(2, 40):
        for _ in range(rng.randrange(1, 4)):
            edges.add((rng.randrange(14), v))
    return 14, 40, edges


def gen_ties16x16(_rng):
    # Four disjoint copies of K_{4,4}: every vertex and every edge ties
    # at the same peel value — the coarse boundaries must degenerate to
    # a single range without losing exactness.
    edges = {(4 * b + i, 4 * b + j) for b in range(4) for i in range(4) for j in range(4)}
    return 16, 16, edges


def gen_ties15x15(_rng):
    # Three disjoint K_{3,3} plus three disjoint K_{2,2}: exactly two
    # big tie classes, so a mass-balanced cut lands INSIDE a tie run.
    edges = {(3 * b + i, 3 * b + j) for b in range(3) for i in range(3) for j in range(3)}
    edges |= {(9 + 2 * b + i, 9 + 2 * b + j) for b in range(3) for i in range(2) for j in range(2)}
    return 15, 15, edges


def gen_disc20x17(rng):
    # Disconnected: a K_{4,4} block, a random mid-density block, a
    # butterfly-free path, and isolated vertices on both sides.
    edges = {(u, v) for u in range(4) for v in range(4)}
    for _ in range(26):
        edges.add((5 + rng.randrange(8), 5 + rng.randrange(6)))
    edges |= {(14, 12), (15, 12), (15, 13), (16, 13), (16, 14), (17, 14), (17, 15), (18, 15)}
    return 20, 17, edges


def gen_empty9x0(_rng):
    return 9, 0, set()


NEW_CORPUS = {
    "hub30x22": ("heavy-tailed U side (degree-skewed hubs)", gen_hub30x22),
    "hub14x40": ("heavy-tailed V side (degree-skewed hubs)", gen_hub14x40),
    "ties16x16": ("tie-dense: 4 disjoint K4x4, all peel values equal", gen_ties16x16),
    "ties15x15": ("tie-dense: two tie classes (3xK3x3 + 3xK2x2)", gen_ties15x15),
    "disc20x17": ("disconnected components + isolated vertices", gen_disc20x17),
    "empty9x0": ("one-side-empty: no V vertices, no edges", gen_empty9x0),
}


def corpus():
    for name, (desc, build) in NEW_CORPUS.items():
        nu, nv, edges = build(random.Random(0x9E31))
        g = Graph(nu, nv, edges)
        total = sum(initial_vertex_counts(g, True)) // 2
        lines = [
            f"# golden butterfly-count dataset ({name}.txt)",
            "# regenerate: python3 scripts/peel_model.py corpus (deterministic builders in NEW_CORPUS)",
            f"# (peeling stress shape: {desc})",
            f"# expected total butterflies: {total}",
            f"# bip {g.nu} {g.nv}",
        ] + [f"{u} {v}" for (u, v) in g.edges]
        out = GOLDEN / f"{name}.txt"
        out.write_text("\n".join(lines) + "\n")
        print(f"wrote {out} (m={g.m}, butterflies={total})")


def golden():
    for name in CORPUS:
        g = load_golden(GOLDEN / f"{name}.txt")
        tips_u = oracle_tips(g, True)
        tips_v = oracle_tips(g, False)
        wings = oracle_wings(g)
        # Cross-check the pinned values against the incremental models
        # before writing anything.
        cu, cv = initial_vertex_counts(g, True), initial_vertex_counts(g, False)
        ce = initial_edge_counts(g)
        assert peel_v_intersect(g, cu, True) == tips_u, name
        assert peel_v_intersect(g, cv, False) == tips_v, name
        assert peel_e_intersect(g, ce) == wings, name
        assert peel_v_two_phase(g, cu, True) == tips_u, name
        assert peel_v_two_phase(g, cv, False) == tips_v, name
        assert peel_e_two_phase(g, ce) == wings, name
        out = GOLDEN / f"{name}.peel"
        lines = [
            f"# golden peeling decomposition for {name}.txt",
            "# regenerate: python3 scripts/peel_model.py golden "
            "(literal recount-every-round oracle, = testutil/brute.rs)",
            f"# rows: tips_u ({g.nu} values), tips_v ({g.nv} values), wings ({g.m} values)",
            "tips_u " + " ".join(map(str, tips_u)),
            "tips_v " + " ".join(map(str, tips_v)),
            "wings " + " ".join(map(str, wings)),
        ]
        out.write_text("\n".join(lines) + "\n")
        print(f"wrote {out} (max tip_u {max(tips_u, default=0)}, "
              f"max tip_v {max(tips_v, default=0)}, max wing {max(wings, default=0)})")


if __name__ == "__main__":
    cmd = sys.argv[1] if len(sys.argv) > 1 else "validate"
    if cmd == "validate":
        validate(int(sys.argv[2]) if len(sys.argv) > 2 else 300)
    elif cmd == "golden":
        golden()
    elif cmd == "corpus":
        corpus()
    elif cmd in ("two-phase", "--two-phase"):
        if len(sys.argv) < 3:
            sys.exit("usage: peel_model.py --two-phase <edge-list.txt>")
        two_phase_oracle(sys.argv[2])
    else:
        sys.exit(f"unknown command {cmd!r}")
