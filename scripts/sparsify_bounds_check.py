#!/usr/bin/env python3
"""Reproduces the z-score maxima pinned by the Rust seeded sparsify test.

`rust/tests/golden_counts.rs::sparsify_estimates_within_exact_variance_bounds_on_golden_corpus`
asserts fixed-seed sparsified estimates within 4.5σ (edge) / 8σ
(colorful) per seed and 2.5σ/√n on the mean, with σ² the exact
estimator variance from the butterfly overlap structure.  Those bounds
were pinned against the maxima this script computes: it ports the Rust
sampling streams bit-for-bit — splitmix64 `hash64`, the
`(p * u64::MAX as f64) as u64` edge threshold, `seed.rotate_left(17)` /
`rotate_left(29)` mixing, edge ids as positions in the sorted
deduplicated edge list — so its estimates are exactly what the Rust
test computes (the authoring container had no Rust toolchain).

Run: python3 scripts/sparsify_bounds_check.py
Asserts every pinned bound with the same constants as the Rust test and
prints the observed maxima.
"""
import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
from peel_model import CORPUS, GOLDEN, load_golden

M64 = (1 << 64) - 1
P = 0.5
NCOLORS = 2
SEEDS = range(20)


def hash64(x):
    """splitmix64 finalizer — exact port of prims::rng::hash64."""
    x = (x + 0x9E3779B97F4A7C15) & M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & M64
    return x ^ (x >> 31)


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


def total_of_edges(nu, edges):
    adj = [set() for _ in range(nu)]
    for (u, v) in edges:
        adj[u].add(v)
    b = 0
    for u1 in range(nu):
        for u2 in range(u1 + 1, nu):
            c = len(adj[u1] & adj[u2])
            b += c * (c - 1) // 2
    return b


def edge_sparsify(g, p, seed):
    # Rust: `(p * u64::MAX as f64) as u64`; float(M64) rounds to 2^64
    # exactly like `u64::MAX as f64`, and int() truncates like `as`.
    thr = int(p * float(M64))
    return [e for eid, e in enumerate(g.edges) if hash64(eid ^ rotl(seed, 17)) <= thr]


def colorful_sparsify(g, ncolors, seed):
    def color(gid):
        return hash64(gid ^ rotl(seed, 29)) % ncolors

    return [(u, v) for (u, v) in g.edges if color(u) == color(g.nu + v)]


def butterflies(g):
    """All butterflies as (edge-id frozenset, global-vertex frozenset)."""
    eid_of = {e: i for i, e in enumerate(g.edges)}
    adj = [set(v for v, _ in g.nbrs_u[u]) for u in range(g.nu)]
    out = []
    for u1 in range(g.nu):
        for u2 in range(u1 + 1, g.nu):
            com = sorted(adj[u1] & adj[u2])
            for i, v1 in enumerate(com):
                for v2 in com[i + 1:]:
                    out.append((
                        frozenset((eid_of[(u1, v1)], eid_of[(u1, v2)],
                                   eid_of[(u2, v1)], eid_of[(u2, v2)])),
                        frozenset((u1, u2, g.nu + v1, g.nu + v2)),
                    ))
    return out


def var_edge(bflies, p):
    var_x = sum(p ** len(ei | ej) - p ** 8 for (ei, _) in bflies for (ej, _) in bflies)
    return var_x / p ** 8


def var_colorful(bflies, p):
    var_x = 0.0
    for (_, vi) in bflies:
        for (_, vj) in bflies:
            both = p ** (len(vi | vj) - 1) if vi & vj else p ** 6
            var_x += both - p ** 6
    return var_x / p ** 6


def main():
    max_edge_z = max_col_z = max_mean_z = 0.0
    for name in CORPUS:
        g = load_golden(GOLDEN / f"{name}.txt")
        exact = total_of_edges(g.nu, g.edges)
        bf = butterflies(g)
        assert len(bf) == exact, name

        sd = math.sqrt(var_edge(bf, P))
        ests = [total_of_edges(g.nu, edge_sparsify(g, P, s)) / P ** 4 for s in SEEDS]
        zs = [abs(e - exact) / sd for e in ests]
        zmean = abs(sum(ests) / len(ests) - exact) / (sd / math.sqrt(len(ests)))
        assert all(z <= 4.5 for z in zs), (name, "edge per-seed bound", max(zs))
        assert zmean <= 2.5, (name, "edge mean bound", zmean)
        max_edge_z = max(max_edge_z, max(zs))
        max_mean_z = max(max_mean_z, zmean)

        sd = math.sqrt(var_colorful(bf, 1.0 / NCOLORS))
        # est = X / p^3 with p = 1/ncolors, i.e. X * ncolors^3.
        ests = [total_of_edges(g.nu, colorful_sparsify(g, NCOLORS, s)) * NCOLORS ** 3
                for s in SEEDS]
        zs = [abs(e - exact) / sd for e in ests]
        zmean = abs(sum(ests) / len(ests) - exact) / (sd / math.sqrt(len(ests)))
        assert all(z <= 8.0 for z in zs), (name, "colorful per-seed bound", max(zs))
        assert zmean <= 2.5, (name, "colorful mean bound", zmean)
        max_col_z = max(max_col_z, max(zs))
        max_mean_z = max(max_mean_z, zmean)
        print(f"{name:10} ok (B={exact})")
    print(f"observed maxima: edge per-seed {max_edge_z:.2f} (bound 4.5), "
          f"colorful per-seed {max_col_z:.2f} (bound 8.0), "
          f"mean {max_mean_z:.2f} (bound 2.5)")


if __name__ == "__main__":
    main()
