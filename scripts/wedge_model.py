"""Shared wedge-walk model kernels for the `bench_*_model.py` seed scripts.

Pure-Python mirrors of the Rust counting engines' ranked two-hop wedge
walk, at the algorithmic level:

* the materializing BatchS family (per-source wedge buffer),
* the flat streaming intersect engine (dense counters + touched-list
  reset, second credit pass),
* the hub-layout streaming engine (`Layout::Hub` in
  `rust/src/graph/ranked.rs` / `rust/src/count/intersect.rs`): vertices
  with degree above sqrt(m) get a dense bitmap adjacency, and second
  hops into them become a single bigint AND + popcount per (source,
  hub) pair instead of per-wedge counter bumps.  Python bigints stand
  in for the Rust `HubBitmap` word arrays; `int.bit_count()` is the
  popcount.

Model correspondence notes:

* Vertices are identified by *rank* throughout (the model's `adj` is
  indexed by rank), so the flat model is already "renumbered" — the
  Rust renumbering pass is a pure cache optimization with no Python
  analogue.
* Under the degree ranking used here, hubs are exactly the rank prefix
  `0..H` (degree is monotone decreasing in rank), so the hub-config
  fill walks each row only up to its first hub entry
  (`nonhub_len`) — the whole-pass hot-skip, no per-item branch — and a
  separate pass popcounts every hub above the source's rank.  Hub
  bitmap construction happens inside the timed region, mirroring the
  Rust dispatch (`HubView::build` per API call).
* The popcount identity: for source `src` and hub `z`,
  `|up(src) ∩ N(z)|` equals the number of flat counter bumps `z` would
  receive, because the rank-prefix filter constrains only `z`
  (`z > src`); membership masks of the two bipartition sides are
  disjoint in the global rank space, so a wrong-side AND is zero.

Every kernel pair is asserted element-identical by the bench scripts
before timing (and by `layout_model_check.py` on randomized graphs).
"""

import random


# --------------------------------------------------------------------------
# Deterministic graph generators (scaled-down twins of
# `rust/src/bench_support/workloads.rs`; ids match, sizes are reduced so
# the pure-Python kernels finish in seconds).
# --------------------------------------------------------------------------


def erdos_renyi(nu, nv, m, seed):
    rng = random.Random(seed)
    return nu, nv, sorted({(rng.randrange(nu), rng.randrange(nv)) for _ in range(m)})


def chung_lu(nu, nv, m, beta, seed):
    rng = random.Random(seed)
    wu = [(i + 1) ** (-1.0 / (beta - 1.0)) for i in range(nu)]
    wv = [(i + 1) ** (-1.0 / (beta - 1.0)) for i in range(nv)]
    us = rng.choices(range(nu), weights=wu, k=m)
    vs = rng.choices(range(nv), weights=wv, k=m)
    return nu, nv, sorted(set(zip(us, vs)))


def planted_blocks(nu, nv, k, bu, bv, p, noise, seed):
    rng = random.Random(seed)
    edges = set()
    for b in range(k):
        for u in range(b * bu, (b + 1) * bu):
            for v in range(b * bv, (b + 1) * bv):
                if rng.random() < p:
                    edges.add((u, v))
    for _ in range(noise):
        edges.add((rng.randrange(nu), rng.randrange(nv)))
    return nu, nv, sorted(edges)


WORKLOADS = [
    ("small", "ER 500x700 m~8k (model)", lambda: erdos_renyi(500, 700, 8_000, 101)),
    ("er", "ER near-regular 3000x3000 m~30k (model)", lambda: erdos_renyi(3000, 3000, 30_000, 103)),
    ("cl", "Chung-Lu beta=2.1 5000x8000 m~60k (model)", lambda: chung_lu(5000, 8000, 60_000, 2.1, 105)),
    ("dense", "8 planted 60x60 blocks p=0.85 + noise (model)",
     lambda: planted_blocks(1000, 1000, 8, 60, 60, 0.85, 2000, 109)),
]


# --------------------------------------------------------------------------
# PREPROCESS: degree ranking, rank-renamed adjacency, up-neighborhoods.
# --------------------------------------------------------------------------


def preprocess(nu, nv, edges):
    """Degree ranking (decreasing degree, ties by id), rank-renamed
    adjacency sorted by decreasing rank, up-degrees, edge ids, and the
    side of each rank (True = U)."""
    n = nu + nv
    deg = [0] * n
    for (u, v) in edges:
        deg[u] += 1
        deg[nu + v] += 1
    order = sorted(range(n), key=lambda g: (-deg[g], g))
    rank_of = [0] * n
    for r, gid in enumerate(order):
        rank_of[gid] = r
    side = [order[r] < nu for r in range(n)]
    adj = [[] for _ in range(n)]
    for eid, (u, v) in enumerate(edges):
        ru, rv = rank_of[u], rank_of[nu + v]
        adj[ru].append((rv, eid))
        adj[rv].append((ru, eid))
    for x in range(n):
        adj[x].sort(key=lambda pair: -pair[0])
    up_deg = [0] * n
    for x in range(n):
        up_deg[x] = sum(1 for (r, _) in adj[x] if r > x)
    up = [list(reversed(adj[x][: up_deg[x]])) for x in range(n)]
    return adj, up, side


def second_hop_prefix(row, r):
    """Length of the decreasing-rank prefix with rank > r (the Rust
    side's binary-searched `up_deg_above`)."""
    lo, hi = 0, len(row)
    while lo < hi:
        mid = (lo + hi) // 2
        if row[mid][0] > r:
            lo = mid + 1
        else:
            hi = mid
    return lo


# --------------------------------------------------------------------------
# Hub layout structures (model of graph::ranked::HubView / HubBitmap).
# --------------------------------------------------------------------------


def build_hub(n, m, adj, up, side):
    """Hub structures for the `Layout::Hub` model.

    Under the degree ranking, degree is monotone decreasing in rank, so
    the hubs (deg > sqrt(m), the Rust threshold) are exactly the rank
    prefix `0..H`.  Returns `(H, nonhub_len, nbits, upbits, side)`:
    `nonhub_len[y]` is where row `y`'s hub tail starts (rows are sorted
    by decreasing rank, so entries with rank < H are a suffix),
    `nbits[z]` / `upbits[x]` are bigint membership masks of `adj[z]` /
    `up[x]` over the global rank space.
    """
    thr = max(1, int(m ** 0.5))
    H = 0
    while H < n and len(adj[H]) > thr:
        H += 1
    if H == 0:
        # No heavy tail: no bitmaps to build, every row is all non-hub.
        return 0, [len(row) for row in adj], [], [], side
    nonhub_len = [0] * n
    for y in range(n):
        row = adj[y]
        # First index whose rank drops below H (decreasing order).
        lo, hi = 0, len(row)
        while lo < hi:
            mid = (lo + hi) // 2
            if row[mid][0] >= H:
                lo = mid + 1
            else:
                hi = mid
        nonhub_len[y] = lo
    nbits = [0] * H
    for z in range(H):
        b = 0
        for (r, _e) in adj[z]:
            b |= 1 << r
        nbits[z] = b
    # The hub popcount pass only runs for sources below the hub
    # boundary (`z` ranges over `src+1..H`), so only those sources need
    # an up-neighborhood mask.
    upbits = [0] * H
    for x in range(H):
        b = 0
        for (r, _e) in up[x]:
            b |= 1 << r
        upbits[x] = b
    return H, nonhub_len, nbits, upbits, side


# --------------------------------------------------------------------------
# Counting kernels.  Each returns/fills exact butterfly statistics; the
# three families (batch / flat intersect / hub intersect) must agree
# bit-for-bit.
# --------------------------------------------------------------------------


def total_batch(n, adj, up):
    """BatchS-analogue global count: materialize the per-source wedge
    buffer, then drain multiplicities."""
    cnt = [0] * n
    total = 0
    for src in range(n):
        touched = []
        wbuf = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
                wbuf.append(z)
        for z in touched:
            c = cnt[z]
            total += c * (c - 1) // 2
            cnt[z] = 0
    return total


def total_flat(n, adj, up):
    """Streaming global count: same walk, no wedge buffer."""
    cnt = [0] * n
    total = 0
    for src in range(n):
        touched = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
        for z in touched:
            c = cnt[z]
            total += c * (c - 1) // 2
            cnt[z] = 0
    return total


def total_hub(n, m, adj, up, side):
    """Hub-layout global count: flat walk stops at each row's hub tail,
    hubs above the source are popcounted."""
    H, nonhub_len, nbits, upbits, side = build_hub(n, m, adj, up, side)
    cnt = [0] * n
    total = 0
    for src in range(n):
        touched = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            stop = nonhub_len[y] if nonhub_len[y] < pre else pre
            for j in range(stop):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
        for z in touched:
            c = cnt[z]
            total += c * (c - 1) // 2
            cnt[z] = 0
        if src + 1 < H:
            ub = upbits[src]
            s = side[src]
            for z in range(src + 1, H):
                if side[z] is not s:
                    continue  # wrong-side AND is 0 anyway; skip the bigint op
                d = (ub & nbits[z]).bit_count()
                total += d * (d - 1) // 2
    return total


def per_vertex_batch(n, adj, up, out):
    """BatchS-analogue: materialize the source's wedges, then credit
    endpoints from multiplicities and centers from the wedge buffer."""
    cnt = [0] * n
    for src in range(n):
        touched = []
        wbuf = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
                wbuf.append((z, y))
        src_total = 0
        for z in touched:
            b = cnt[z] * (cnt[z] - 1) // 2
            src_total += b
            out[z] += b
        out[src] += src_total
        for (z, y) in wbuf:
            out[y] += cnt[z] - 1
        for z in touched:
            cnt[z] = 0


def per_vertex_intersect(n, adj, up, out):
    """Streaming engine: same walk, no wedge buffer, second pass."""
    cnt = [0] * n
    for src in range(n):
        touched = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
        src_total = 0
        for z in touched:
            b = cnt[z] * (cnt[z] - 1) // 2
            src_total += b
            out[z] += b
        out[src] += src_total
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            center = 0
            for j in range(pre):
                center += cnt[row[j][0]] - 1
            out[y] += center
        for z in touched:
            cnt[z] = 0
    return out


def per_vertex_hub(n, m, adj, up, side, out):
    """Hub-layout streaming engine: popcount fill for hubs, flat fill
    for the rest; drain and center-credit passes read the same `cnt`."""
    H, nonhub_len, nbits, upbits, side = build_hub(n, m, adj, up, side)
    cnt = [0] * n
    for src in range(n):
        touched = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            stop = nonhub_len[y] if nonhub_len[y] < pre else pre
            for j in range(stop):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
        if src + 1 < H:
            ub = upbits[src]
            s = side[src]
            for z in range(src + 1, H):
                if side[z] is not s:
                    continue
                d = (ub & nbits[z]).bit_count()
                if d:
                    cnt[z] = d
                    touched.append(z)
        src_total = 0
        for z in touched:
            b = cnt[z] * (cnt[z] - 1) // 2
            src_total += b
            out[z] += b
        out[src] += src_total
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            center = 0
            for j in range(pre):
                center += cnt[row[j][0]] - 1
            out[y] += center
        for z in touched:
            cnt[z] = 0
    return out


def per_edge_batch(n, m, adj, up, out):
    cnt = [0] * n
    for src in range(n):
        touched = []
        wbuf = []
        for (y, e_lo) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z, e_hi = row[j]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
                wbuf.append((z, e_lo, e_hi))
        for (z, e_lo, e_hi) in wbuf:
            d = cnt[z]
            if d > 1:
                out[e_lo] += d - 1
                out[e_hi] += d - 1
        for z in touched:
            cnt[z] = 0


def per_edge_intersect(n, m, adj, up, out):
    cnt = [0] * n
    for src in range(n):
        touched = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
        for (y, e_lo) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            lo_leg = 0
            for j in range(pre):
                z, e_hi = row[j]
                d = cnt[z]
                if d > 1:
                    lo_leg += d - 1
                    out[e_hi] += d - 1
            out[e_lo] += lo_leg
        for z in touched:
            cnt[z] = 0
    return out


def per_edge_hub(n, m, adj, up, side, out):
    """Hub layout for per-edge: only the fill is popcount-accelerated;
    the credit pass needs per-entry edge ids so it walks the full
    prefix, reading the already-filled `cnt` (set for hubs too)."""
    H, nonhub_len, nbits, upbits, side = build_hub(n, m, adj, up, side)
    cnt = [0] * n
    for src in range(n):
        touched = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            stop = nonhub_len[y] if nonhub_len[y] < pre else pre
            for j in range(stop):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
        if src + 1 < H:
            ub = upbits[src]
            s = side[src]
            for z in range(src + 1, H):
                if side[z] is not s:
                    continue
                d = (ub & nbits[z]).bit_count()
                if d:
                    cnt[z] = d
                    touched.append(z)
        for (y, e_lo) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            lo_leg = 0
            for j in range(pre):
                z, e_hi = row[j]
                d = cnt[z]
                if d > 1:
                    lo_leg += d - 1
                    out[e_hi] += d - 1
            out[e_lo] += lo_leg
        for z in touched:
            cnt[z] = 0
    return out


# --------------------------------------------------------------------------
# Brute-force oracle (for layout_model_check.py).
# --------------------------------------------------------------------------


def brute_total(nu, nv, edges):
    """Total butterflies via pairwise common-neighbor counts on U."""
    nbrs = [set() for _ in range(nu)]
    for (u, v) in edges:
        nbrs[u].add(v)
    total = 0
    for a in range(nu):
        for b in range(a + 1, nu):
            c = len(nbrs[a] & nbrs[b])
            total += c * (c - 1) // 2
    return total


if __name__ == "__main__":
    # Self-check on a tiny graph: all three families agree with brute force.
    nu, nv, edges = erdos_renyi(40, 50, 300, 7)
    n, m = nu + nv, len(edges)
    adj, up, side = preprocess(nu, nv, edges)
    t = brute_total(nu, nv, edges)
    assert total_batch(n, adj, up) == t
    assert total_flat(n, adj, up) == t
    assert total_hub(n, m, adj, up, side) == t
    vb, vf, vh = [0] * n, [0] * n, [0] * n
    per_vertex_batch(n, adj, up, vb)
    per_vertex_intersect(n, adj, up, vf)
    per_vertex_hub(n, m, adj, up, side, vh)
    assert vb == vf == vh and sum(vb) == 4 * t
    eb, ef, eh = [0] * m, [0] * m, [0] * m
    per_edge_batch(n, m, adj, up, eb)
    per_edge_intersect(n, m, adj, up, ef)
    per_edge_hub(n, m, adj, up, side, eh)
    assert eb == ef == eh and sum(eb) == 4 * t
    print(f"wedge_model self-checks pass (total={t}, m={m})")
