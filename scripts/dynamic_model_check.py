#!/usr/bin/env python3
"""Model check for the batch-dynamic butterfly maintenance algorithm
(`rust/src/dynamic/`), run in place of `cargo test` because the
authoring container has no Rust toolchain (same situation as
scripts/preprocess_model_check.py and scripts/peel_model.py in the
previous PRs).

It mirrors `DynGraph`'s update rule at the algorithmic level:

* Edges live in a CSR-ordered list (sorted by `(u, v)`); edge id =
  position, exactly as `BipartiteGraph::from_edges` assigns them.
* An insert batch B is deduplicated, filtered to genuinely new edges,
  and applied; the count delta is the number of butterflies of
  `G_new = G_old + B` that contain at least one batch edge.  Each such
  butterfly is enumerated exactly once, from its **maximum-edge-id
  batch edge**: walking batch edge `e`, the other three edges of a
  candidate butterfly must each be either non-batch or a batch edge
  with smaller id (batch ids are CSR-sorted, so "smaller id" ==
  "earlier batch position").
* A delete batch walks `G_old` (before removal) under the same filter,
  so each destroyed butterfly is subtracted exactly once.
* Per-vertex / per-edge counts get +-1 credit for every enumerated
  butterfly on each of its 4 vertices / 4 edges.

Both walk orientations (stamp N(u), iterate N(v) centers — and the
side-swapped mirror) are checked against each other and against the
brute-force recount, over randomized interleaved insert/delete streams
that include in-batch duplicates, inserts of already-present edges,
deletes of absent edges, and re-inserts of previously deleted edges.

Usage: python3 scripts/dynamic_model_check.py [iters]
"""
import itertools
import random
import sys
from collections import defaultdict


def brute_counts(nu, nv, edges):
    """Ground truth: total, per-vertex, per-(u,v)-edge butterfly counts."""
    adj_u = defaultdict(set)
    for (u, v) in edges:
        adj_u[u].add(v)
    total = 0
    bu = defaultdict(int)
    bv = defaultdict(int)
    be = defaultdict(int)
    us = sorted(adj_u)
    for i, u1 in enumerate(us):
        for u2 in us[i + 1:]:
            common = sorted(adj_u[u1] & adj_u[u2])
            c = len(common)
            b = c * (c - 1) // 2
            if b == 0:
                continue
            total += b
            bu[u1] += b
            bu[u2] += b
            for v1, v2 in itertools.combinations(common, 2):
                bv[v1] += 1
                bv[v2] += 1
                for e in ((u1, v1), (u1, v2), (u2, v1), (u2, v2)):
                    be[e] += 1
    return total, dict(bu), dict(bv), dict(be)


class Csr:
    """Edge-id view mirroring BipartiteGraph: ids are positions in the
    (u, v)-sorted edge list; both adjacency directions carry the id."""

    def __init__(self, edges):
        self.edges = sorted(edges)
        self.eid = {e: i for i, e in enumerate(self.edges)}
        self.nbrs_u = defaultdict(list)  # u -> [(v, eid)]
        self.nbrs_v = defaultdict(list)  # v -> [(u, eid)]
        for i, (u, v) in enumerate(self.edges):
            self.nbrs_u[u].append((v, i))
            self.nbrs_v[v].append((u, i))


class DynModel:
    """The DynGraph update rule over plain dicts."""

    def __init__(self, orientation="auto"):
        self.edges = set()
        self.total = 0
        self.bu = defaultdict(int)
        self.bv = defaultdict(int)
        self.be = defaultdict(int)  # keyed by (u, v); the Rust side keys
        # by edge id and remaps on rebuild — same content either way.
        self.orientation = orientation

    def _walk(self, csr, batch_eids, sign):
        """Enumerate butterflies containing >=1 batch edge, each exactly
        once (max-eid batch edge), crediting vertices and edges."""
        is_batch = set(batch_eids)
        for e in batch_eids:
            u, v = csr.edges[e]

            def passes(eid):
                return eid not in is_batch or eid < e

            cost_a = sum(len(csr.nbrs_u[u2]) for (u2, _) in csr.nbrs_v[v])
            cost_b = sum(len(csr.nbrs_v[v2]) for (v2, _) in csr.nbrs_u[u])
            if self.orientation == "a":
                use_a = True
            elif self.orientation == "b":
                use_a = False
            else:
                use_a = cost_a <= cost_b
            found = 0
            if use_a:
                # Stamp N(u): second V endpoints + the (u, v2) edge id.
                stamp = {v2: ev2 for (v2, ev2) in csr.nbrs_u[u]
                         if v2 != v and passes(ev2)}
                for (u2, e_u2v) in csr.nbrs_v[v]:
                    if u2 == u or not passes(e_u2v):
                        continue
                    cnt = 0
                    for (v2, e_u2v2) in csr.nbrs_u[u2]:
                        if not passes(e_u2v2) or v2 not in stamp:
                            continue
                        cnt += 1
                        self.bv[v2] += sign
                        self.be[csr.edges[stamp[v2]]] += sign
                        self.be[csr.edges[e_u2v2]] += sign
                    if cnt:
                        self.bu[u2] += sign * cnt
                        self.be[csr.edges[e_u2v]] += sign * cnt
                    found += cnt
            else:
                # Mirror: stamp N(v), iterate N(u) centers.
                stamp = {u2: e_u2v for (u2, e_u2v) in csr.nbrs_v[v]
                         if u2 != u and passes(e_u2v)}
                for (v2, e_uv2) in csr.nbrs_u[u]:
                    if v2 == v or not passes(e_uv2):
                        continue
                    cnt = 0
                    for (u2, e_u2v2) in csr.nbrs_v[v2]:
                        if not passes(e_u2v2) or u2 not in stamp:
                            continue
                        cnt += 1
                        self.bu[u2] += sign
                        self.be[csr.edges[stamp[u2]]] += sign
                        self.be[csr.edges[e_u2v2]] += sign
                    if cnt:
                        self.bv[v2] += sign * cnt
                        self.be[csr.edges[e_uv2]] += sign * cnt
                    found += cnt
            if found:
                self.bu[u] += sign * found
                self.bv[v] += sign * found
                self.be[(u, v)] += sign * found
            self.total += sign * found

    def insert(self, batch):
        fresh = sorted({e for e in batch if e not in self.edges})
        if not fresh:
            return
        self.edges |= set(fresh)
        csr = Csr(self.edges)  # G_new
        self._walk(csr, sorted(csr.eid[e] for e in fresh), +1)

    def delete(self, batch):
        gone = sorted({e for e in batch if e in self.edges})
        if not gone:
            return
        csr = Csr(self.edges)  # G_old: walk before removal
        self._walk(csr, sorted(csr.eid[e] for e in gone), -1)
        self.edges -= set(gone)
        for e in gone:
            assert self.be.get(e, 0) == 0, f"residual count on deleted {e}"
            self.be.pop(e, None)


def clean(d):
    return {k: c for k, c in d.items() if c}


def run_stream(rng, nu, nv, nbatches, orientation):
    model = DynModel(orientation)
    deleted_pool = []
    for step in range(nbatches):
        op = rng.random()
        size = rng.randrange(1, 12)
        if op < 0.55 or not model.edges:
            batch = [(rng.randrange(nu), rng.randrange(nv)) for _ in range(size)]
            if deleted_pool and rng.random() < 0.5:
                batch += rng.sample(deleted_pool, min(3, len(deleted_pool)))
            if model.edges and rng.random() < 0.4:  # already-present no-ops
                batch += rng.sample(sorted(model.edges), min(2, len(model.edges)))
            batch += batch[: max(1, size // 3)]  # in-batch duplicates
            model.insert(batch)
        else:
            present = rng.sample(sorted(model.edges), min(size, len(model.edges)))
            absent = [(rng.randrange(nu), rng.randrange(nv)) for _ in range(2)]
            batch = present + absent + present[:1]
            deleted_pool += present
            model.delete(batch)
        t, bu, bv, be = brute_counts(nu, nv, model.edges)
        assert model.total == t, f"step {step}: total {model.total} != {t}"
        assert clean(model.bu) == bu, f"step {step}: per-U mismatch"
        assert clean(model.bv) == bv, f"step {step}: per-V mismatch"
        assert clean(model.be) == be, f"step {step}: per-edge mismatch"
    return model


def main():
    iters = int(sys.argv[1]) if len(sys.argv) > 1 else 120
    rng = random.Random(20260730)
    shapes = [(4, 4), (6, 5), (9, 7), (12, 14), (20, 16)]
    for it in range(iters):
        nu, nv = shapes[it % len(shapes)]
        seed = rng.randrange(1 << 30)
        models = {}
        for orientation in ("a", "b", "auto"):
            r = random.Random(seed)
            models[orientation] = run_stream(r, nu, nv, 14, orientation)
        base = models["auto"]
        for o in ("a", "b"):
            m = models[o]
            assert m.total == base.total and m.edges == base.edges
            assert clean(m.be) == clean(base.be), f"orientation {o} drifts"
        if (it + 1) % 20 == 0:
            print(f"  {it + 1}/{iters} streams ok "
                  f"(last: {nu}x{nv}, {len(base.edges)} edges, "
                  f"{base.total} butterflies)")
    print(f"OK: {iters} randomized interleaved streams x 3 orientations, "
          f"all counts (total/per-vertex/per-edge) match brute recount "
          f"after every batch")


if __name__ == "__main__":
    main()
