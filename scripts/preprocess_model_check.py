#!/usr/bin/env python3
"""Model validation for the PR's parallel-preprocessing claims.

The authoring container has no Rust toolchain, so the three delicate
algorithm rewrites are mirrored here bit-for-bit at the algorithmic
level and fuzzed against their sequential references:

1. **Chunked parser stitching** — random edge-list files (comments,
   CRLF, KONECT/plain, headers, random malformed lines) parsed by the
   sequential scan and by the chunk-split + prefix-sum-stitch model at
   2/3/5/8 chunks: identical edge vectors, identical error *kind and
   absolute line number* (earliest failure wins).
2. **Round-based co-degeneracy** — the MaxBuckets + histogram rounds
   vs a direct sequential round-peel reference, exact and approx
   (log-bucket) modes: identical permutations; and vs the pre-refactor
   lazy-bucket loop: identical round *partitions* (the refactor only
   canonicalized intra-round tie order).
3. **V-side CSR via (v, eid) sort** — the parallel build's second sort
   vs the old sequential cursor scatter: identical `adj_v`/`eid_v`.

Usage: python3 scripts/preprocess_model_check.py  (exit 0 = all good)
"""
import random
import sys


# ---------------------------------------------------------------- 1. parser
def parse_serial(text):
    """Sequential reference: returns ('ok', header, edges) or
    ('err', kind, lineno0)."""
    konect = False
    header = None
    edges = []
    for lineno, line in enumerate(text.split("\n")[:-1] if text.endswith("\n")
                                  else text.split("\n")):
        t = line.rstrip("\r").strip()
        if lineno == 0 and t.startswith("%"):
            konect = True
        if not t or t.startswith("%"):
            continue
        if t.startswith("# bip"):
            toks = t[len("# bip"):].split()
            if len(toks) < 2 or not toks[0].isdigit() or not toks[1].isdigit():
                return ("err", "badheader", lineno)
            header = (int(toks[0]), int(toks[1]))
            continue
        if t.startswith("#"):
            continue
        toks = t.split()
        if len(toks) < 1 or not toks[0].isdigit():
            return ("err", "badid" if toks else "missing", lineno)
        if len(toks) < 2:
            return ("err", "missing", lineno)
        if not toks[1].isdigit():
            return ("err", "badid", lineno)
        u, v = int(toks[0]), int(toks[1])
        if konect:
            if u < 1 or v < 1:
                return ("err", "konect0", lineno)
            edges.append((u - 1, v - 1))
        else:
            if header is not None and (u >= header[0] or v >= header[1]):
                return ("err", "oob", lineno)
            edges.append((u, v))
    return ("ok", header, edges)


def parse_chunked(text, nchunks):
    """The Rust parallel path's structure: prologue, line-boundary
    chunks, per-chunk first-error, prefix-sum line stitch, serial
    fallback on late headers."""
    konect = False
    header = None
    pos = 0
    prologue_lines = 0
    data_start = len(text)
    while pos < len(text):
        nl = text.find("\n", pos)
        end = len(text) if nl < 0 else nl
        t = text[pos:end].rstrip("\r").strip()
        if prologue_lines == 0 and t.startswith("%"):
            konect = True
        if not t or t.startswith("%"):
            pass
        elif t.startswith("# bip"):
            toks = t[len("# bip"):].split()
            if len(toks) < 2 or not toks[0].isdigit() or not toks[1].isdigit():
                return ("err", "badheader", prologue_lines)
            header = (int(toks[0]), int(toks[1]))
        elif t.startswith("#"):
            pass
        else:
            data_start = pos
            break
        prologue_lines += 1
        pos = len(text) if nl < 0 else nl + 1
    if data_start >= len(text):
        return ("ok", header, [])
    span = len(text) - data_start
    bounds = [data_start]
    for c in range(1, nchunks):
        raw = max(data_start + c * span // nchunks, bounds[-1])
        nl = text.find("\n", raw)
        bounds.append(len(text) if nl < 0 else nl + 1)
    bounds.append(len(text))

    chunk_out = []
    for c in range(nchunks):
        lo, hi = bounds[c], bounds[c + 1]
        edges, nlines, err, late = [], 0, None, False
        p = lo
        while p < hi:
            nl = text.find("\n", p, hi)
            end = hi if nl < 0 else nl
            t = text[p:end].rstrip("\r").strip()
            local = nlines
            nlines += 1
            p = hi if nl < 0 else nl + 1
            if not t or t.startswith("%"):
                continue
            if t.startswith("# bip"):
                late = True
                break
            if t.startswith("#"):
                continue
            toks = t.split()
            if len(toks) < 1 or not toks[0].isdigit():
                err = ("badid" if toks else "missing", local)
                break
            if len(toks) < 2:
                err = ("missing", local)
                break
            if not toks[1].isdigit():
                err = ("badid", local)
                break
            u, v = int(toks[0]), int(toks[1])
            if konect:
                if u < 1 or v < 1:
                    err = ("konect0", local)
                    break
                edges.append((u - 1, v - 1))
            else:
                if header is not None and (u >= header[0] or v >= header[1]):
                    err = ("oob", local)
                    break
                edges.append((u, v))
        chunk_out.append((edges, nlines, err, late))
    if any(late for (_, _, _, late) in chunk_out):
        return parse_serial(text)
    offs = [0]
    for (_, nlines, _, _) in chunk_out:
        offs.append(offs[-1] + nlines)
    for c, (_, _, err, _) in enumerate(chunk_out):
        if err is not None:
            kind, local = err
            return ("err", kind, prologue_lines + offs[c] + local)
    out = []
    for (edges, _, _, _) in chunk_out:
        out.extend(edges)
    return ("ok", header, out)


def random_file(rng):
    lines = []
    kind = rng.choice(["plain", "headered", "konect"])
    if kind == "konect":
        lines.append("% bip konect")
    if kind == "headered":
        lines.append("# bip 40 40")
    if rng.random() < 0.5:
        lines.append("# a comment")
    nlines = rng.randint(0, 60)
    for _ in range(nlines):
        r = rng.random()
        if r < 0.08:
            lines.append(rng.choice(["# c", "%x", "", "   "]))
        elif r < 0.13:
            lines.append(rng.choice(["foo 3", "4", "-2 5", "3 bar", "7 -1", "0 99"]))
        else:
            lo = 1 if kind == "konect" else 0
            lines.append(f"{rng.randint(lo, 39)} {rng.randint(lo, 39)}")
    if rng.random() < 0.1 and kind != "konect":
        lines.append("# bip 40 40")  # late header
        lines.append("5 5")
    text = "\n".join(lines)
    if rng.random() < 0.7:
        text += "\n"
    if rng.random() < 0.3:
        text = text.replace("\n", "\r\n")
    return text


def check_parser(trials):
    rng = random.Random(7)
    fails = 0
    for _ in range(trials):
        text = random_file(rng)
        ref = parse_serial(text)
        for nchunks in (2, 3, 5, 8):
            got = parse_chunked(text, nchunks)
            if got != ref:
                print(f"PARSER DIVERGENCE nchunks={nchunks}\n  ref={ref}\n  got={got}\n"
                      f"  text={text!r}")
                fails += 1
    return fails


# ------------------------------------------------------------ 2. codegeneracy
def bucket_of(d, approx):
    return d if not approx else (0 if d == 0 else d.bit_length())


def old_codeg_rounds(nu, nv, adj_u, adj_v, approx):
    """Pre-refactor lazy-bucket sequential loop; returns the round
    partition (list of frozensets)."""
    n = nu + nv
    deg0 = lambda g: len(adj_u[g]) if g < nu else len(adj_v[g - nu])
    maxd = max((deg0(g) for g in range(n)), default=0)
    buckets = [[] for _ in range(bucket_of(maxd, approx) + 1)]
    cur = [deg0(g) for g in range(n)]
    for g in range(n):
        buckets[bucket_of(cur[g], approx)].append(g)
    removed = [False] * n
    rounds = []
    top = len(buckets) - 1
    while top >= 0:
        members, buckets[top] = buckets[top], []
        # Filter-and-mark in one pass: lazy entries contain duplicates,
        # a vertex is claimed the first time it is seen.
        valid = []
        for x in members:
            if not removed[x] and bucket_of(cur[x], approx) == top:
                removed[x] = True
                valid.append(x)
        if not valid:
            top -= 1
            continue
        rounds.append(frozenset(valid))
        for x in valid:
            for w in (adj_u[x] if x < nu else adj_v[x - nu]):
                wg = nu + w if x < nu else w
                if not removed[wg] and cur[wg] > 0:
                    cur[wg] -= 1
                    buckets[bucket_of(cur[wg], approx)].append(wg)
    return rounds


def seq_ref(nu, nv, adj_u, adj_v, approx):
    """testutil::rankref::co_degeneracy_seq."""
    n = nu + nv
    deg = [len(adj_u[g]) if g < nu else len(adj_v[g - nu]) for g in range(n)]
    live = [True] * n
    rank = [0] * n
    nxt = 0
    remaining = n
    rounds = []
    while remaining:
        top = max(bucket_of(deg[i], approx) for i in range(n) if live[i])
        frontier = [i for i in range(n) if live[i] and bucket_of(deg[i], approx) == top]
        rounds.append(frozenset(frontier))
        for x in frontier:
            live[x] = False
            rank[x] = nxt
            nxt += 1
        remaining -= len(frontier)
        for x in frontier:
            for w in (adj_u[x] if x < nu else adj_v[x - nu]):
                wg = nu + w if x < nu else w
                if live[wg]:
                    deg[wg] -= 1
    return rank, rounds


def new_codeg(nu, nv, adj_u, adj_v, approx):
    """rank::co_degeneracy: MaxBuckets pop_max rounds + histogrammed
    decrements, gid-sorted frontiers."""
    n = nu + nv
    deg = [len(adj_u[g]) if g < nu else len(adj_v[g - nu]) for g in range(n)]
    cur = [bucket_of(d, approx) for d in deg]
    nb = max(cur, default=-1) + 1
    buckets = [[] for _ in range(nb)]
    for g in range(n):
        buckets[cur[g]].append(g)
    fin = [False] * n
    rank = [0] * n
    nxt = 0
    rounds = []
    top = nb - 1
    while top >= 0:
        if not buckets[top]:
            top -= 1
            continue
        members, buckets[top] = buckets[top], []
        frontier = [x for x in members if not fin[x] and cur[x] == top]
        for x in frontier:
            fin[x] = True
        if not frontier:
            continue
        frontier.sort()
        rounds.append(frozenset(frontier))
        for i, x in enumerate(frontier):
            rank[x] = nxt + i
        nxt += len(frontier)
        hist = {}
        for x in frontier:
            for w in (adj_u[x] if x < nu else adj_v[x - nu]):
                wg = nu + w if x < nu else w
                hist[wg] = hist.get(wg, 0) + 1
        for wg, cnt in hist.items():
            if fin[wg]:
                continue
            deg[wg] = max(0, deg[wg] - cnt)
            nk = bucket_of(deg[wg], approx)
            if nk != cur[wg]:
                assert nk < cur[wg]
                cur[wg] = nk
                buckets[nk].append(wg)
    assert nxt == n
    return rank, rounds


def check_codeg(trials):
    rng = random.Random(42)
    fails = 0
    for _ in range(trials):
        nu, nv = rng.randint(1, 14), rng.randint(1, 14)
        edges = set()
        for _ in range(rng.randint(0, nu * nv)):
            edges.add((rng.randrange(nu), rng.randrange(nv)))
        adj_u = [sorted(v for (u, v) in edges if u == uu) for uu in range(nu)]
        adj_v = [sorted(u for (u, v) in edges if v == vv) for vv in range(nv)]
        for approx in (False, True):
            r_seq, rounds_seq = seq_ref(nu, nv, adj_u, adj_v, approx)
            r_new, rounds_new = new_codeg(nu, nv, adj_u, adj_v, approx)
            rounds_old = old_codeg_rounds(nu, nv, adj_u, adj_v, approx)
            if r_new != r_seq:
                print(f"CODEG PERMUTATION DIVERGENCE approx={approx}")
                fails += 1
            if rounds_new != rounds_seq or rounds_new != rounds_old:
                print(f"CODEG ROUND PARTITION DIVERGENCE approx={approx}")
                fails += 1
    return fails


# ------------------------------------------------------------- 3. V-side CSR
def check_vside(trials):
    rng = random.Random(5)
    fails = 0
    for _ in range(trials):
        nu, nv = rng.randint(1, 20), rng.randint(1, 20)
        edges = {(rng.randrange(nu), rng.randrange(nv))
                 for _ in range(rng.randint(0, 2 * nu * nv))}
        packed = sorted((u << 32) | v for (u, v) in edges)
        m = len(packed)
        # Old sequential cursor scatter.
        off_v = [0] * (nv + 1)
        for e in packed:
            off_v[(e & 0xFFFFFFFF) + 1] += 1
        for i in range(nv):
            off_v[i + 1] += off_v[i]
        adj_v_old, eid_v_old = [0] * m, [0] * m
        cursor = off_v[:]
        for eid, e in enumerate(packed):
            v = e & 0xFFFFFFFF
            adj_v_old[cursor[v]] = e >> 32
            eid_v_old[cursor[v]] = eid
            cursor[v] += 1
        # New (v, eid) sort.
        vkeys = sorted(((packed[eid] & 0xFFFFFFFF) << 32) | eid for eid in range(m))
        adj_v_new = [packed[k & 0xFFFFFFFF] >> 32 for k in vkeys]
        eid_v_new = [k & 0xFFFFFFFF for k in vkeys]
        off_v_new = [sum(1 for k in vkeys if (k >> 32) < x) for x in range(nv + 1)]
        if (adj_v_old, eid_v_old, off_v) != (adj_v_new, eid_v_new, off_v_new):
            print("V-SIDE CSR DIVERGENCE")
            fails += 1
    return fails


def main():
    fails = check_parser(600) + check_codeg(400) + check_vside(300)
    print(f"parser: 600 files x 4 chunkings; codeg: 400 graphs x 2 modes; "
          f"vside: 300 graphs — failures: {fails}")
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
