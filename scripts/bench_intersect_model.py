#!/usr/bin/env python3
"""Model harness seeding BENCH_intersect.json.

Mirrors `cargo bench --bench intersect_vs_agg` at the algorithmic level:
per-source butterfly counting with a materialized per-source wedge buffer
(the BatchS/BatchWA family — the fastest materializing aggregation in the
Rust suite) versus the streaming intersect engine (no wedge records, dense
counters + touched-list reset, second credit pass) under both memory
layouts — flat (`Intersect`) and hub (`Intersect-hub`: bitmap AND/popcount
second hops into the heavy-degree tail; see scripts/wedge_model.py).  All
configurations walk the same ranked two-hop structure, so the measured
gaps isolate exactly what the Rust engines differ in: materializing each
wedge vs streaming it, and per-wedge counter bumps vs per-hub popcounts.

This exists because the authoring container has no Rust toolchain; the
JSON it writes is labeled `"harness": "python-model"` and is superseded by
re-running the Rust bench, which overwrites the same file with native
numbers (and the full aggregation-family row set).

Usage: python3 scripts/bench_intersect_model.py
"""
import json
from pathlib import Path

import bench_model_common
import wedge_model as wm


def runners_for(stat, n, m, adj, up, side):
    """(label, callable) pairs for one statistic; each callable returns
    the comparable result (total, or the filled per-item vector)."""
    if stat == "total":
        return [
            ("BatchS", lambda: wm.total_batch(n, adj, up)),
            ("Intersect", lambda: wm.total_flat(n, adj, up)),
            ("Intersect-hub", lambda: wm.total_hub(n, m, adj, up, side)),
        ]
    if stat == "vertex":
        return [
            ("BatchS", lambda: (lambda o: (wm.per_vertex_batch(n, adj, up, o), o)[1])([0] * n)),
            ("Intersect", lambda: wm.per_vertex_intersect(n, adj, up, [0] * n)),
            ("Intersect-hub", lambda: wm.per_vertex_hub(n, m, adj, up, side, [0] * n)),
        ]
    return [
        ("BatchS", lambda: (lambda o: (wm.per_edge_batch(n, m, adj, up, o), o)[1])([0] * m)),
        ("Intersect", lambda: wm.per_edge_intersect(n, m, adj, up, [0] * m)),
        ("Intersect-hub", lambda: wm.per_edge_hub(n, m, adj, up, side, [0] * m)),
    ]


def butterflies(stat, result):
    if stat == "total":
        return result
    return sum(result) // 4  # 4 vertices / 4 edges per butterfly


def main():
    rows = []
    summary = []
    for wl_id, describe, gen in wm.WORKLOADS:
        nu, nv, edges = gen()
        n, m = nu + nv, len(edges)
        adj, up, side = wm.preprocess(nu, nv, edges)
        print(f"[{wl_id}] {describe}: n={n} m={m}")
        for stat in ["total", "vertex", "edge"]:
            runners = runners_for(stat, n, m, adj, up, side)
            # Cross-check outputs agree before timing.
            outs = [f() for _label, f in runners]
            for (label, _f), out in zip(runners[1:], outs[1:]):
                assert outs[0] == out, f"{wl_id}/{stat}: {label} disagrees with BatchS"
            ms = {}
            for label, f in runners:
                ms[label] = bench_model_common.bench(f)
                rows.append({"workload": wl_id, "stat": stat, "config": label,
                             "median_ms": round(ms[label], 3)})
                print(f"  {stat}/{label:<14} {ms[label]:10.2f} ms")
            speedup = ms["BatchS"] / ms["Intersect"]
            print(f"  {stat}: intersect speedup {speedup:.2f}x "
                  f"(hub {ms['BatchS'] / ms['Intersect-hub']:.2f}x)")
            summary.append({
                "workload": wl_id, "stat": stat,
                "best_materializing": "BatchS",
                "best_materializing_ms": round(ms["BatchS"], 3),
                "intersect_ms": round(ms["Intersect"], 3),
                "intersect_hub_ms": round(ms["Intersect-hub"], 3),
                "speedup": round(speedup, 3),
                "butterflies": butterflies(stat, outs[0]),
            })
    doc = {
        "bench": "intersect_vs_agg",
        "harness": "python-model",
        "note": ("Algorithmic model measurements (scripts/bench_intersect_model.py): "
                 "per-source counting with a materialized wedge buffer (BatchS family, "
                 "the fastest materializing aggregation) vs the streaming intersect "
                 "engine under the flat and hub memory layouts, same ranked two-hop "
                 "walk.  Model rows cover the BatchS/Intersect/Intersect-hub configs; "
                 "regenerate natively with `parbutterfly bench run --filter intersect` "
                 "(or `cargo bench --bench intersect_vs_agg`), which overwrites this "
                 "file with `harness: \"native\"` rows for the full aggregation "
                 "family; compare snapshots with `parbutterfly bench diff`."),
        "env": bench_model_common.environment(threads=1),
        "threads": 1,
        "rows": rows,
        "summary": summary,
    }
    out = Path(__file__).resolve().parent.parent / "BENCH_intersect.json"
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
