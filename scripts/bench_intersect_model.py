#!/usr/bin/env python3
"""Model harness seeding BENCH_intersect.json.

Mirrors `cargo bench --bench intersect_vs_agg` at the algorithmic level:
per-source butterfly counting with a materialized per-source wedge buffer
(the BatchS/BatchWA family — the fastest materializing aggregation in the
Rust suite) versus the streaming intersect engine (no wedge records, dense
counters + touched-list reset, second credit pass).  Both walk the same
ranked two-hop structure, so the measured gap isolates exactly what the
Rust engines differ in: materializing each wedge versus streaming it.

This exists because the authoring container has no Rust toolchain; the
JSON it writes is labeled `"harness": "python-model"` and is superseded by
re-running the Rust bench, which overwrites the same file with native
numbers.

Usage: python3 scripts/bench_intersect_model.py
"""
import json
import random
import time
from pathlib import Path

import bench_model_common


def erdos_renyi(nu, nv, m, seed):
    rng = random.Random(seed)
    return nu, nv, sorted({(rng.randrange(nu), rng.randrange(nv)) for _ in range(m)})


def chung_lu(nu, nv, m, beta, seed):
    rng = random.Random(seed)
    wu = [(i + 1) ** (-1.0 / (beta - 1.0)) for i in range(nu)]
    wv = [(i + 1) ** (-1.0 / (beta - 1.0)) for i in range(nv)]
    us = rng.choices(range(nu), weights=wu, k=m)
    vs = rng.choices(range(nv), weights=wv, k=m)
    return nu, nv, sorted(set(zip(us, vs)))


def planted_blocks(nu, nv, k, bu, bv, p, noise, seed):
    rng = random.Random(seed)
    edges = set()
    for b in range(k):
        for u in range(b * bu, (b + 1) * bu):
            for v in range(b * bv, (b + 1) * bv):
                if rng.random() < p:
                    edges.add((u, v))
    for _ in range(noise):
        edges.add((rng.randrange(nu), rng.randrange(nv)))
    return nu, nv, sorted(edges)


def preprocess(nu, nv, edges):
    """Degree ranking (decreasing degree, ties by id), rank-renamed
    adjacency sorted by decreasing rank, up-degrees, edge ids."""
    n = nu + nv
    deg = [0] * n
    for (u, v) in edges:
        deg[u] += 1
        deg[nu + v] += 1
    order = sorted(range(n), key=lambda g: (-deg[g], g))
    rank_of = [0] * n
    for r, gid in enumerate(order):
        rank_of[gid] = r
    adj = [[] for _ in range(n)]
    for eid, (u, v) in enumerate(edges):
        ru, rv = rank_of[u], rank_of[nu + v]
        adj[ru].append((rv, eid))
        adj[rv].append((ru, eid))
    for x in range(n):
        adj[x].sort(key=lambda pair: -pair[0])
    up_deg = [0] * n
    for x in range(n):
        up_deg[x] = sum(1 for (r, _) in adj[x] if r > x)
    up = [list(reversed(adj[x][: up_deg[x]])) for x in range(n)]
    return adj, up


def second_hop_prefix(row, r):
    """Length of the decreasing-rank prefix with rank > r (the Rust
    side's binary-searched `up_deg_above`)."""
    lo, hi = 0, len(row)
    while lo < hi:
        mid = (lo + hi) // 2
        if row[mid][0] > r:
            lo = mid + 1
        else:
            hi = mid
    return lo


def per_vertex_batch(n, adj, up, out):
    """BatchS-analogue: materialize the source's wedges, then credit
    endpoints from multiplicities and centers from the wedge buffer."""
    cnt = [0] * n
    for src in range(n):
        touched = []
        wbuf = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
                wbuf.append((z, y))
        src_total = 0
        for z in touched:
            b = cnt[z] * (cnt[z] - 1) // 2
            src_total += b
            out[z] += b
        out[src] += src_total
        for (z, y) in wbuf:
            out[y] += cnt[z] - 1
        for z in touched:
            cnt[z] = 0


def per_vertex_intersect(n, adj, up, out):
    """Streaming engine: same walk, no wedge buffer, second pass."""
    cnt = [0] * n
    for src in range(n):
        touched = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
        src_total = 0
        for z in touched:
            b = cnt[z] * (cnt[z] - 1) // 2
            src_total += b
            out[z] += b
        out[src] += src_total
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            center = 0
            for j in range(pre):
                center += cnt[row[j][0]] - 1
            out[y] += center
        for z in touched:
            cnt[z] = 0


def per_edge_batch(n, m, adj, up, out):
    cnt = [0] * n
    for src in range(n):
        touched = []
        wbuf = []
        for (y, e_lo) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z, e_hi = row[j]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
                wbuf.append((z, e_lo, e_hi))
        for (z, e_lo, e_hi) in wbuf:
            d = cnt[z]
            if d > 1:
                out[e_lo] += d - 1
                out[e_hi] += d - 1
        for z in touched:
            cnt[z] = 0


def per_edge_intersect(n, m, adj, up, out):
    cnt = [0] * n
    for src in range(n):
        touched = []
        for (y, _e) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            for j in range(pre):
                z = row[j][0]
                if cnt[z] == 0:
                    touched.append(z)
                cnt[z] += 1
        for (y, e_lo) in up[src]:
            row = adj[y]
            pre = second_hop_prefix(row, src)
            lo_leg = 0
            for j in range(pre):
                z, e_hi = row[j]
                d = cnt[z]
                if d > 1:
                    lo_leg += d - 1
                    out[e_hi] += d - 1
            out[e_lo] += lo_leg
        for z in touched:
            cnt[z] = 0


def bench(f, warmup=1, runs=3):
    for _ in range(warmup):
        f()
    samples = []
    for _ in range(runs):
        t = time.perf_counter()
        f()
        samples.append((time.perf_counter() - t) * 1e3)
    # Averaged-middle-pair median (see bench_model_common): the old
    # samples[len // 2] is the upper middle for even run counts.
    return bench_model_common.median(samples)


WORKLOADS = [
    ("er", "ER near-regular 3000x3000 m~30k (model)", erdos_renyi(3000, 3000, 30_000, 103)),
    ("cl", "Chung-Lu beta=2.1 5000x8000 m~60k (model)", chung_lu(5000, 8000, 60_000, 2.1, 105)),
    ("dense", "8 planted 60x60 blocks p=0.85 + noise (model)",
     planted_blocks(1000, 1000, 8, 60, 60, 0.85, 2000, 109)),
]


def main():
    rows = []
    summary = []
    for wl_id, describe, (nu, nv, edges) in WORKLOADS:
        n, m = nu + nv, len(edges)
        adj, up = preprocess(nu, nv, edges)
        print(f"[{wl_id}] {describe}: n={n} m={m}")
        for stat, runners in [
            ("vertex", [("BatchS", lambda: per_vertex_batch(n, adj, up, [0] * n)),
                        ("Intersect", lambda: per_vertex_intersect(n, adj, up, [0] * n))]),
            ("edge", [("BatchS", lambda: per_edge_batch(n, m, adj, up, [0] * m)),
                      ("Intersect", lambda: per_edge_intersect(n, m, adj, up, [0] * m))]),
        ]:
            # Cross-check outputs agree before timing.
            outs = []
            for _label, f in runners:
                sink = [0] * (n if stat == "vertex" else m)
                if stat == "vertex":
                    (per_vertex_batch if _label == "BatchS" else per_vertex_intersect)(n, adj, up, sink)
                else:
                    (per_edge_batch if _label == "BatchS" else per_edge_intersect)(n, m, adj, up, sink)
                outs.append(sink)
            assert outs[0] == outs[1], f"{wl_id}/{stat}: engines disagree"
            ms = {}
            for label, f in runners:
                ms[label] = bench(f)
                rows.append({"workload": wl_id, "stat": stat, "config": label,
                             "median_ms": round(ms[label], 3)})
                print(f"  {stat}/{label:<10} {ms[label]:10.2f} ms")
            speedup = ms["BatchS"] / ms["Intersect"]
            print(f"  {stat}: intersect speedup {speedup:.2f}x")
            summary.append({
                "workload": wl_id, "stat": stat,
                "best_materializing": "BatchS",
                "best_materializing_ms": round(ms["BatchS"], 3),
                "intersect_ms": round(ms["Intersect"], 3),
                "speedup": round(speedup, 3),
                "butterflies": sum(outs[0]) // 4,
            })
    doc = {
        "bench": "intersect_vs_agg",
        "harness": "python-model",
        "note": ("Algorithmic model measurements (scripts/bench_intersect_model.py): "
                 "per-source counting with a materialized wedge buffer (BatchS family, "
                 "the fastest materializing aggregation) vs the streaming intersect "
                 "engine, same ranked two-hop walk.  Regenerate natively with "
                 "`parbutterfly bench run --filter intersect` (or `cargo bench --bench "
                 "intersect_vs_agg`), which overwrites this file with `harness: "
                 "\"native\"` rows and the full 9-row comparison; compare snapshots "
                 "with `parbutterfly bench diff`."),
        "env": bench_model_common.environment(threads=1),
        "threads": 1,
        "rows": rows,
        "summary": summary,
    }
    out = Path(__file__).resolve().parent.parent / "BENCH_intersect.json"
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
