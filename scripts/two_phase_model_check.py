#!/usr/bin/env python3
"""Randomized differential check for the two-phase peeling models.

Python twin of `rust/tests/peel_differential.rs`, in the mold of
`scripts/layout_model_check.py`: on randomized graphs (including
heavy-tailed hub shapes), the two-phase coarse->fine models must
produce bit-identical tip and wing numbers to the agg and intersect
models on both peel sides.  The script tracks how many trials actually
split into more than one range — and fails if none do, so the
multi-range machinery (coarse staging, cross-range seed subtraction,
per-range fine peels) can never silently go untested.

Usage: python3 scripts/two_phase_model_check.py [trials]
"""
import random
import sys

import peel_model as pm


def random_graph(rng):
    kind = rng.randrange(4)
    nu = rng.randint(3, 28)
    nv = rng.randint(3, 28)
    m = rng.randint(0, min(nu * nv, 160))
    edges = {(rng.randrange(nu), rng.randrange(nv)) for _ in range(m)}
    if kind == 1:
        # Heavy tail: one full-degree hub per side.
        edges |= {(0, v) for v in range(nv)}
        edges |= {(u, 0) for u in range(nu)}
    elif kind == 2:
        # Tie-dense: disjoint identical blocks under the random noise.
        b = rng.randint(2, 3)
        k = min(nu, nv) // b
        edges |= {(b * blk + i, b * blk + j)
                  for blk in range(k) for i in range(b) for j in range(b)}
    elif kind == 3:
        # Sparse/disconnected: keep only edges touching low ids.
        edges = {(u, v) for (u, v) in edges if u < nu // 2 and v < nv // 2}
    return pm.Graph(nu, nv, edges)


def main():
    trials = int(sys.argv[1]) if len(sys.argv) > 1 else 200
    rng = random.Random(0x27A5E)
    multi_range = 0
    for t in range(trials):
        g = random_graph(rng)
        ctx = f"trial {t}: nu={g.nu} nv={g.nv} m={g.m}"
        for peel_u in (True, False):
            counts = pm.initial_vertex_counts(g, peel_u)
            multi_range += len(pm.range_thresholds(counts)) > 1
            agg = pm.peel_v_agg(g, counts, peel_u)
            isect = pm.peel_v_intersect(g, counts, peel_u)
            two = pm.peel_v_two_phase(g, counts, peel_u)
            assert two == isect == agg, f"{ctx} peel_u={peel_u}: tips diverge"
        ce = pm.initial_edge_counts(g)
        multi_range += len(pm.range_thresholds(ce)) > 1
        agg = pm.peel_e_agg(g, ce)
        isect = pm.peel_e_intersect(g, ce)
        two = pm.peel_e_two_phase(g, ce)
        assert two == isect == agg, f"{ctx}: wings diverge"
        if (t + 1) % 50 == 0:
            print(f"  {t + 1}/{trials} trials ok")
    assert multi_range > 0, "no trial split into >1 range — two-phase went untested"
    print(f"two_phase_model_check: {trials} trials OK "
          f"({multi_range} decompositions used multiple ranges)")


if __name__ == "__main__":
    main()
