#!/usr/bin/env python3
"""Model harness seeding BENCH_serve.json.

Mirrors `cargo bench --bench serve_latency` at the algorithmic level.
Serve-mode reads are answered from a published epoch snapshot — the
pre-counted global / per-vertex / per-edge arrays — so the model
precomputes those arrays once per workload (the wedge walk with a
dense counter) and then times what the daemon's query handlers do:

* `read/total`   — serialize the global count (batched 100/sample);
* `read/vertex`  — one per-vertex array index + serialize;
* `read/topk`    — top-10 selection over the V-side count array;
* `read/digest`  — checksum sums over all three count arrays;
* `update/roundtrip` — delete + re-insert one edge: two batch-edge
  delta walks (the `DynGraph` incremental rule) plus two snapshot
  publishes (graph + count-array copies), i.e. two epochs.

This exists because the authoring container has no Rust toolchain
(same situation as scripts/bench_dynamic_model.py and friends); the
JSON it writes is labeled `"harness": "python-model"` and is
overwritten by `cargo bench --bench serve_latency`.

Usage: python3 scripts/bench_serve_model.py
"""
import heapq
import json
from pathlib import Path

import bench_model_common
from wedge_model import chung_lu, erdos_renyi

# Same suite as bench_support::snapshots::serve_latency (Full profile);
# graph generators mirror bench_support::workloads::build.
WORKLOADS = [
    ("small", erdos_renyi(500, 700, 8_000, 101)),
    ("er", erdos_renyi(3_000, 3_000, 60_000, 103)),
    ("cl", chung_lu(5_000, 8_000, 120_000, 2.1, 105)),
]
READS_PER_SAMPLE = 100  # matches READS_PER_SAMPLE in the native bench


def count_all(nu, nv, edges):
    """Global / per-vertex / per-edge butterfly counts in one wedge
    walk: for each U source, a dense counter over the second hop
    (u2 > u1 avoids double counting), then endpoint/center/edge
    credits from the pair multiplicities."""
    adj_u = [[] for _ in range(nu)]  # (v, eid)
    adj_v = [[] for _ in range(nv)]  # (u, eid)
    for eid, (u, v) in enumerate(edges):
        adj_u[u].append((v, eid))
        adj_v[v].append((u, eid))
    per_u, per_v = [0] * nu, [0] * nv
    per_edge = [0] * len(edges)
    total = 0
    cnt = {}
    for u1 in range(nu):
        cnt.clear()
        wbuf = []
        for (v, e1) in adj_u[u1]:
            for (u2, e2) in adj_v[v]:
                if u2 > u1:
                    cnt[u2] = cnt.get(u2, 0) + 1
                    wbuf.append((u2, v, e1, e2))
        for u2, c in cnt.items():
            b = c * (c - 1) // 2
            total += b
            per_u[u1] += b
            per_u[u2] += b
        for (u2, v, e1, e2) in wbuf:
            c = cnt[u2]
            if c > 1:
                per_v[v] += c - 1
                per_edge[e1] += c - 1
                per_edge[e2] += c - 1
    return adj_u, adj_v, per_u, per_v, per_edge, total


def main():
    rows, summary = [], []
    for wl_id, (nu, nv, edges) in WORKLOADS:
        print(f"[{wl_id}] {nu} x {nv}, {len(edges)} edges: precounting ...")
        adj_u, adj_v, per_u, per_v, per_edge, total = count_all(nu, nv, edges)
        print(f"[{wl_id}] {total} butterflies; timing query handlers")
        u0, v0 = edges[0]
        epoch = 0
        m = len(edges)

        # --- read queries: format a protocol reply from the snapshot.
        def read_total():
            for _ in range(READS_PER_SAMPLE):
                s = f'{{"ok": true, "epoch": {epoch}, "degraded": false, "total": {total}}}'
            return s

        def read_vertex():
            for _ in range(READS_PER_SAMPLE):
                c = per_u[u0]
                s = (f'{{"ok": true, "epoch": {epoch}, "degraded": false, '
                     f'"side": "u", "id": {u0}, "count": {c}}}')
            return s

        def read_topk():
            for _ in range(READS_PER_SAMPLE):
                top = heapq.nlargest(10, enumerate(per_v), key=lambda p: (p[1], -p[0]))
                s = (f'{{"ok": true, "epoch": {epoch}, "degraded": false, "top": '
                     + json.dumps([[i, c] for i, c in top]) + "}")
            return s

        def read_digest():
            for _ in range(READS_PER_SAMPLE):
                s = (f'{{"ok": true, "epoch": {epoch}, "degraded": false, '
                     f'"total": {total}, "sum_u": {sum(per_u)}, "sum_v": {sum(per_v)}, '
                     f'"sum_edges": {sum(per_edge)}, "m": {m}}}')
            return s

        read_total_ms = None
        for label, f in [("read/total", read_total), ("read/vertex", read_vertex),
                         ("read/topk", read_topk), ("read/digest", read_digest)]:
            ms = bench_model_common.bench(f)
            if label == "read/total":
                read_total_ms = ms
            rows.append({
                "workload": wl_id, "query": label,
                "per_sample": READS_PER_SAMPLE, "median_ms": round(ms, 3),
            })
            print(f"  {label}: {ms:.3f} ms / {READS_PER_SAMPLE} queries")

        # --- update round trip: delete + re-insert (u0, v0), one
        # delta walk + one snapshot publish per batch (two epochs).
        set_u0 = {v for (v, _) in adj_u[u0]}

        def delta_edge():
            acc = 0
            for (u2, _) in adj_v[v0]:
                if u2 == u0:
                    continue
                w = sum(1 for (v2, _) in adj_u[u2] if v2 != v0 and v2 in set_u0)
                acc += w
            return acc

        def publish():
            nonlocal epoch
            epoch += 1
            return (list(edges), list(per_u), list(per_v), list(per_edge))

        def roundtrip():
            delta_edge()   # delete batch
            publish()
            delta_edge()   # insert batch
            publish()
            return epoch

        ms = bench_model_common.bench(roundtrip)
        rows.append({"workload": wl_id, "query": "update/roundtrip",
                     "median_ms": round(ms, 3)})
        print(f"  update/roundtrip: {ms:.3f} ms (2 epochs/sample)")
        summary.append({
            "workload": wl_id,
            "read_total_ms": round(read_total_ms, 3),
            "update_roundtrip_ms": round(ms, 3),
            "epochs_published": epoch,
        })

    out = {
        "bench": "serve_latency",
        "harness": "python-model",
        "note": ("Algorithmic model measurements (scripts/bench_serve_model.py): "
                 "read rows are per-100-queries medians answered from precounted "
                 "snapshot arrays; update/roundtrip is two delta walks plus two "
                 "snapshot publishes (two epochs).  Regenerate natively with "
                 "`parbutterfly bench run --filter serve` (or `cargo bench --bench "
                 "serve_latency`), which overwrites this file with `harness: "
                 "\"native\"` rows; compare snapshots with `parbutterfly bench diff`."),
        "env": bench_model_common.environment(threads=1),
        "threads": 1,
        "rows": rows,
        "summary": summary,
    }
    path = Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
