#!/usr/bin/env python3
"""Model harness seeding BENCH_dynamic.json.

Mirrors `cargo bench --bench fig_dynamic` at the algorithmic level:
the last 10% of each workload's edges is replayed as an update stream
(insert batches, then delete batches of the same edges), comparing

* the **delta** path — per batch edge, the max-edge-id-filtered
  intersection walk over only the touched adjacency lists (the
  `DynGraph` incremental rule, validated against brute force by
  scripts/dynamic_model_check.py), plus the CSR rebuild; against
* the **recount** baseline — the same CSR rebuild plus a full
  wedge-walk recount of the whole graph every batch (what serving the
  stream through the static pipeline costs).

This exists because the authoring container has no Rust toolchain
(same situation as scripts/bench_intersect_model.py and friends); the
JSON it writes is labeled `"harness": "python-model"`, runs the
algorithms serially (per-thread rows only record the decomposition,
real speedups need native threads), and is overwritten by
`cargo bench --bench fig_dynamic`.

Usage: python3 scripts/bench_dynamic_model.py
"""
import json
import time
from collections import defaultdict
from pathlib import Path

import bench_model_common
from wedge_model import chung_lu, erdos_renyi, planted_blocks

WORKLOADS = [
    ("er", erdos_renyi(3_000, 3_000, 60_000, 103)),
    ("cl", chung_lu(5_000, 8_000, 120_000, 2.1, 105)),
    ("dense", planted_blocks(1_000, 1_000, 8, 60, 60, 0.85, 2_000, 109)),
]
BATCH_SIZES = [64, 1_024, 16_384]
THREADS = [1, 4, 8]
UPDATE_FRACTION = 0.10


def build_adj(edges):
    nbrs_u, nbrs_v = defaultdict(set), defaultdict(set)
    for (u, v) in edges:
        nbrs_u[u].add(v)
        nbrs_v[v].add(u)
    return nbrs_u, nbrs_v


def count_via_sources(nbrs_u, nbrs_v):
    """Static global count via the per-source dense-counter two-hop walk
    (what the recount path runs every batch)."""
    total = 0
    for u1, nv1 in nbrs_u.items():
        cnt = defaultdict(int)
        for v in nv1:
            for u2 in nbrs_v[v]:
                if u2 > u1:
                    cnt[u2] += 1
        for c in cnt.values():
            total += c * (c - 1) // 2
    return total


def delta_insert(nbrs_u, nbrs_v, batch):
    """Batch-edge delta walks (insert), after adjacency already updated.
    Max-order convention via batch position: earlier batch edges and
    all old edges pass the filter."""
    batch_pos = {e: i for i, e in enumerate(batch)}
    gained = 0
    for i, (u, v) in enumerate(batch):
        def passes(e):
            p = batch_pos.get(e)
            return p is None or p < i
        stamp = {v2 for v2 in nbrs_u[u] if v2 != v and passes((u, v2))}
        for u2 in nbrs_v[v]:
            if u2 == u or not passes((u2, v)):
                continue
            for v2 in nbrs_u[u2]:
                if v2 in stamp and passes((u2, v2)):
                    gained += 1
    return gained


def replay(base_edges, updates, batch_size, path):
    nbrs_u, nbrs_v = build_adj(base_edges)
    for op in ("insert", "delete"):
        for lo in range(0, len(updates), batch_size):
            chunk = sorted(set(updates[lo:lo + batch_size]))
            if op == "insert":
                for (u, v) in chunk:
                    nbrs_u[u].add(v)
                    nbrs_v[v].add(u)
                if path == "delta":
                    delta_insert(nbrs_u, nbrs_v, chunk)
                else:
                    count_via_sources(nbrs_u, nbrs_v)
            else:
                if path == "delta":
                    delta_insert(nbrs_u, nbrs_v, chunk)  # pre-removal walk
                else:
                    count_via_sources(nbrs_u, nbrs_v)
                for (u, v) in chunk:
                    nbrs_u[u].discard(v)
                    nbrs_v[v].discard(u)


def main():
    rows, summary = [], []
    for wl_id, (nu, nv, edges) in WORKLOADS:
        split = len(edges) - int(len(edges) * UPDATE_FRACTION)
        base, updates = edges[:split], edges[split:]
        print(f"[{wl_id}] {len(updates)} update edges over {split} base")
        for batch in BATCH_SIZES:
            if batch > len(updates):
                continue
            timings = {}
            for path in ("delta", "recount"):
                t0 = time.perf_counter()
                replay(base, updates, batch, path)
                timings[path] = (time.perf_counter() - t0) * 1e3
            for t in THREADS:
                for path in ("delta", "recount"):
                    # Serial model: thread rows record the same serial
                    # measurement (see module docstring).
                    rows.append({
                        "workload": wl_id, "batch": batch, "threads": t,
                        "path": path, "median_ms": round(timings[path], 3),
                    })
                summary.append({
                    "workload": wl_id, "batch": batch, "threads": t,
                    "delta_ms": round(timings["delta"], 3),
                    "recount_ms": round(timings["recount"], 3),
                    "speedup": round(timings["recount"] / max(timings["delta"], 1e-9), 3),
                })
            print(f"  b{batch}: delta {timings['delta']:.1f} ms vs "
                  f"recount-per-batch {timings['recount']:.1f} ms "
                  f"({timings['recount'] / max(timings['delta'], 1e-9):.1f}x)")
    out = {
        "bench": "fig_dynamic",
        "harness": "python-model",
        "note": ("Algorithmic model measurements (scripts/bench_dynamic_model.py): "
                 "serial model — thread rows repeat the serial measurement (real "
                 "speedups need native threads).  Regenerate natively with "
                 "`parbutterfly bench run --filter dynamic` (or `cargo bench --bench "
                 "fig_dynamic`), which overwrites this file with `harness: "
                 "\"native\"` rows; compare snapshots with `parbutterfly bench diff`."),
        "env": bench_model_common.environment(threads=1),
        "rows": rows,
        "summary": summary,
    }
    path = Path(__file__).resolve().parent.parent / "BENCH_dynamic.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
