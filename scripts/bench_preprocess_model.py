#!/usr/bin/env python3
"""Model harness seeding BENCH_preprocess.json.

Mirrors `cargo bench --bench preprocess_pipeline` at the algorithmic
level: the pre-counting pipeline stages — edge-list parsing (sequential
line scan vs the chunked line-boundary parser with scan stitching),
CSR construction (sort + dedup + offset recovery), each of the five
vertex rankings (co-degeneracy via the round-based max-bucket peel,
not vertex-at-a-time), and the PREPROCESS build (rank rename +
per-vertex sort).  Rows are emitted for the 1/4/8-thread sweep the
Rust bench performs; the Python model executes the chunk-structured
algorithms serially (pure-Python threads cannot show real speedups),
so the per-thread rows measure the *decomposition overhead* only and
are superseded by native numbers.

This exists because the authoring container has no Rust toolchain
(same situation as scripts/bench_intersect_model.py and
scripts/bench_peel_model.py in the previous PRs); the JSON it writes
is labeled `"harness": "python-model"` and is overwritten by
`cargo bench --bench preprocess_pipeline`.

Usage: python3 scripts/bench_preprocess_model.py
"""
import json
import time
from pathlib import Path

import bench_model_common
from wedge_model import chung_lu, erdos_renyi

WORKLOADS = [
    ("er", "ER near-regular 3000x3000 m~60k (model)", erdos_renyi(3_000, 3_000, 60_000, 103)),
    ("cl", "Chung-Lu beta=2.1 5000x8000 m~120k (model)", chung_lu(5_000, 8_000, 120_000, 2.1, 105)),
    ("clL", "Chung-Lu beta=2.1 10000x15000 m~300k (model)",
     chung_lu(10_000, 15_000, 300_000, 2.1, 107)),
]

THREADS = [1, 4, 8]


def render_edge_list(nu, nv, edges):
    return ("# bip %d %d\n" % (nu, nv)) + "".join("%d %d\n" % e for e in edges)


def parse_serial(text):
    header = None
    edges = []
    for lineno, line in enumerate(text.split("\n")):
        t = line.strip()
        if not t or t.startswith("%"):
            continue
        if t.startswith("# bip"):
            parts = t.split()
            header = (int(parts[2]), int(parts[3]))
            continue
        if t.startswith("#"):
            continue
        toks = t.split()
        u, v = int(toks[0]), int(toks[1])
        if header is not None:
            assert u < header[0] and v < header[1], f"line {lineno + 1}"
        edges.append((u, v))
    return header, edges


def parse_chunked(text, nchunks):
    """The chunked parser's structure: prologue scan, line-boundary
    chunk split, independent chunk tokenization, prefix-sum stitch."""
    # Prologue: leading comment/header lines.
    header = None
    pos = 0
    while pos < len(text):
        end = text.find("\n", pos)
        end = len(text) if end < 0 else end
        t = text[pos:end].strip()
        if t.startswith("# bip"):
            parts = t.split()
            header = (int(parts[2]), int(parts[3]))
        elif t and not t.startswith("#") and not t.startswith("%"):
            break
        pos = end + 1
    data_start = min(pos, len(text))
    span = len(text) - data_start
    bounds = [data_start]
    for c in range(1, nchunks):
        raw = max(data_start + c * span // nchunks, bounds[-1])
        nl = text.find("\n", raw)
        bounds.append(len(text) if nl < 0 else nl + 1)
    bounds.append(len(text))
    chunk_edges = []
    for c in range(nchunks):
        edges = []
        for line in text[bounds[c]:bounds[c + 1]].split("\n"):
            t = line.strip()
            if not t or t.startswith("#") or t.startswith("%"):
                continue
            toks = t.split()
            u, v = int(toks[0]), int(toks[1])
            if header is not None:
                assert u < header[0] and v < header[1]
            edges.append((u, v))
        chunk_edges.append(edges)
    # Stitch (the Rust path prefix-sums chunk sizes and scatters).
    out = []
    for ce in chunk_edges:
        out.extend(ce)
    return header, out


def csr_build(nu, nv, edges):
    """Sort + dedup + boundary offsets + (v, eid) partition — the shape
    of the parallel BipartiteGraph::from_edges."""
    packed = sorted(set((u << 32) | v for (u, v) in edges))
    m = len(packed)
    adj_u = [e & 0xFFFFFFFF for e in packed]
    vkeys = sorted(((packed[eid] & 0xFFFFFFFF) << 32) | eid for eid in range(m))
    adj_v = [packed[k & 0xFFFFFFFF] >> 32 for k in vkeys]
    eid_v = [k & 0xFFFFFFFF for k in vkeys]
    return adj_u, adj_v, eid_v


def bucket_of(d, approx):
    if not approx:
        return d
    return 0 if d == 0 else d.bit_length()


def codeg_rounds(nu, nv, adj_u, adj_v, approx):
    """Round-based max-bucket co-degeneracy (the bucket-parallel
    model): claim the whole max frontier, histogram the decrements."""
    n = nu + nv
    deg = [len(adj_u[g]) if g < nu else len(adj_v[g - nu]) for g in range(n)]
    nb = max((bucket_of(d, approx) for d in deg), default=-1) + 1
    buckets = [[] for _ in range(nb)]
    cur = [bucket_of(d, approx) for d in deg]
    for g in range(n):
        buckets[cur[g]].append(g)
    fin = [False] * n
    rank = [0] * n
    nxt = 0
    top = nb - 1
    while top >= 0:
        if not buckets[top]:
            top -= 1
            continue
        members, buckets[top] = buckets[top], []
        frontier = []
        for x in members:
            if not fin[x] and cur[x] == top:
                fin[x] = True
                frontier.append(x)
        if not frontier:
            continue
        frontier.sort()
        for i, x in enumerate(frontier):
            rank[x] = nxt + i
        nxt += len(frontier)
        hist = {}
        for x in frontier:
            for w in (adj_u[x] if x < nu else adj_v[x - nu]):
                wg = nu + w if x < nu else w
                hist[wg] = hist.get(wg, 0) + 1
        for wg, cnt in hist.items():
            if fin[wg]:
                continue
            deg[wg] -= cnt
            nk = bucket_of(deg[wg], approx)
            if nk != cur[wg]:
                cur[wg] = nk
                buckets[nk].append(wg)
    assert nxt == n
    return rank


def adjacency(nu, nv, edges):
    adj_u = [[] for _ in range(nu)]
    adj_v = [[] for _ in range(nv)]
    for (u, v) in edges:
        adj_u[u].append(v)
        adj_v[v].append(u)
    return adj_u, adj_v


def rank_one(name, nu, nv, adj_u, adj_v):
    n = nu + nv
    deg = [len(adj_u[g]) if g < nu else len(adj_v[g - nu]) for g in range(n)]

    def key_rank(keyf):
        order = sorted(range(n), key=lambda g: (-keyf(g), g))
        rank = [0] * n
        for r, g in enumerate(order):
            rank[g] = r
        return rank

    if name == "side":
        return list(range(n))
    if name == "degree":
        return key_rank(lambda g: deg[g])
    if name == "adegree":
        return key_rank(lambda g: (deg[g] + 1).bit_length())
    if name == "codeg":
        return codeg_rounds(nu, nv, adj_u, adj_v, False)
    assert name == "acodeg"
    return codeg_rounds(nu, nv, adj_u, adj_v, True)


def preprocess_build(nu, nv, edges, rank):
    """Rank rename + decreasing-rank adjacency sort (Algorithm 1)."""
    n = nu + nv
    adj = [[] for _ in range(n)]
    for eid, (u, v) in enumerate(edges):
        adj[rank[u]].append((rank[nu + v], eid))
        adj[rank[nu + v]].append((rank[u], eid))
    up = [0] * n
    for x in range(n):
        adj[x].sort(key=lambda p: -p[0])
        up[x] = sum(1 for (r, _) in adj[x] if r > x)
    return adj, up


def bench(f, runs=2):
    samples = []
    for _ in range(runs):
        t = time.perf_counter()
        f()
        samples.append((time.perf_counter() - t) * 1e3)
    # With runs=2 the old samples[len // 2] silently reported the MAX
    # of the two runs, not a median; average the middle pair instead.
    return bench_model_common.median(samples)


def main():
    rows = []
    for wl_id, describe, (nu, nv, edges) in WORKLOADS:
        text = render_edge_list(nu, nv, edges)
        print(f"[{wl_id}] {describe}: m={len(edges)}")
        # Parity anchor, mirroring the Rust bench's pre-timing assert.
        hs, es = parse_serial(text)
        for nchunks in (2, 4, 8):
            hp, ep = parse_chunked(text, nchunks)
            assert (hs, sorted(es)) == (hp, sorted(ep)), f"{wl_id}: chunk parity nchunks={nchunks}"
            assert es == ep, f"{wl_id}: chunk stitching reordered edges"
        adj_u, adj_v = adjacency(nu, nv, edges)
        degree_rank = rank_one("degree", nu, nv, adj_u, adj_v)
        for t in THREADS:
            stages = {
                "parse-serial": lambda: parse_serial(text),
                "parse-parallel": lambda t=t: parse_chunked(text, max(t, 2)),
                "csr-build": lambda: csr_build(nu, nv, edges),
            }
            for name in ("side", "degree", "adegree", "codeg", "acodeg"):
                stages[f"rank-{name}"] = lambda nm=name: rank_one(nm, nu, nv, adj_u, adj_v)
            stages["preprocess-build"] = lambda: preprocess_build(nu, nv, edges, degree_rank)
            for name, f in stages.items():
                ms = bench(f)
                rows.append({"workload": wl_id, "stage": name, "threads": t,
                             "median_ms": round(ms, 3)})
                print(f"  t{t}/{name:<18} {ms:10.2f} ms")
    doc = {
        "bench": "preprocess_pipeline",
        "harness": "python-model",
        "note": ("Algorithmic model measurements (scripts/bench_preprocess_model.py): "
                 "serial vs chunked parsing, sort/dedup CSR construction, the five "
                 "rankings with round-based co-degeneracy, and the PREPROCESS build.  "
                 "The thread column mirrors the Rust sweep but pure-Python rows run "
                 "the chunk-structured algorithms serially.  Regenerate natively with "
                 "`parbutterfly bench run --filter preprocess` (or `cargo bench "
                 "--bench preprocess_pipeline`), which overwrites this file with "
                 "`harness: \"native\"` rows; compare snapshots with `parbutterfly "
                 "bench diff`."),
        "env": bench_model_common.environment(threads=1),
        "threads_swept": THREADS,
        "rows": rows,
    }
    out = Path(__file__).resolve().parent.parent / "BENCH_preprocess.json"
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
