#!/usr/bin/env python3
"""End-to-end serve-mode smoke: scripted stdio session, byte-exact diff.

Drives the *release binary* (not the library) through a full daemon
lifecycle on the Davis southern-women fixture: generate the edge list
with `parbutterfly gen`, start `parbutterfly serve --graph`, feed a
scripted request stream on stdin, and diff captured stdout against the
golden transcript below byte for byte.  The replies are the same
pinned lines rust/tests/serve_protocol.rs asserts through the library
API — this script proves the CLI wiring (arg parsing, stdin loop,
stdout purity: the banner goes to stderr) preserves them on the wire.

Usage: python3 scripts/serve_smoke.py   (after `cargo build --release`)
Override the binary location with PARBUTTERFLY_BIN.
"""
import os
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# One request per line; blank lines and `#` comments must produce no
# reply at all (that is part of what byte-exactness checks).
SCRIPT = """\
# serve smoke: scripted session over the Davis fixture
{"op": "total"}
{"op": "epoch"}

{"op": "vertex", "side": "u", "id": 0}
{"op": "edge", "u": 0, "v": 0}
{"op": "tip", "side": "v", "id": 2}
{"op": "wing", "u": 0, "v": 0}
{"op": "topk", "side": "u", "k": 3}
{"op": "digest"}
{"op": "update", "delete": [[0, 0]]}
{"op": "total"}
{"op": "update", "lines": ["+ 0 0"]}
{"op": "total"}
{"op": "rebuild"}
{"op": "this is not json"}
{"op": "shutdown"}
"""

GOLDEN = """\
{"ok": true, "epoch": 0, "degraded": false, "total": 341}
{"ok": true, "epoch": 0, "degraded": false, "nu": 18, "nv": 14, "m": 89}
{"ok": true, "epoch": 0, "degraded": false, "side": "u", "id": 0, "count": 75}
{"ok": true, "epoch": 0, "degraded": false, "u": 0, "v": 0, "count": 10}
{"ok": true, "epoch": 0, "degraded": false, "side": "v", "id": 2, "tip": 42}
{"ok": true, "epoch": 0, "degraded": false, "u": 0, "v": 0, "wing": 10}
{"ok": true, "epoch": 0, "degraded": false, "side": "u", "k": 3, "top": [{"id": 2, "count": 91}, {"id": 0, "count": 75}, {"id": 3, "count": 71}]}
{"ok": true, "epoch": 0, "degraded": false, "global": 341, "sum_u": 682, "sum_v": 682, "sum_edge": 1364, "m": 89}
{"ok": true, "epoch": 1, "degraded": false, "applied": 1, "skipped": 0, "recovered": false}
{"ok": true, "epoch": 1, "degraded": false, "total": 331}
{"ok": true, "epoch": 2, "degraded": false, "applied": 1, "skipped": 0, "recovered": false}
{"ok": true, "epoch": 2, "degraded": false, "total": 341}
{"ok": true, "epoch": 3, "degraded": false, "rebuilt": true}
{"ok": false, "error": "bad request: unknown op \\"this is not json\\""}
{"ok": true, "shutdown": true}
"""


def main():
    bin_path = os.environ.get("PARBUTTERFLY_BIN", str(ROOT / "target/release/parbutterfly"))
    if not Path(bin_path).exists():
        sys.exit(f"serve_smoke: no binary at {bin_path} (run `cargo build --release` "
                 "or set PARBUTTERFLY_BIN)")
    with tempfile.TemporaryDirectory() as tmp:
        graph = Path(tmp) / "davis.txt"
        subprocess.run(
            [bin_path, "gen", "--kind", "davis", "--out", str(graph)],
            check=True, capture_output=True, text=True,
        )
        proc = subprocess.run(
            [bin_path, "serve", "--graph", str(graph)],
            input=SCRIPT, capture_output=True, text=True, timeout=120,
        )
    if proc.returncode != 0:
        sys.exit(f"serve_smoke: daemon exited {proc.returncode}\nstderr:\n{proc.stderr}")
    if proc.stdout != GOLDEN:
        import difflib
        diff = "".join(difflib.unified_diff(
            GOLDEN.splitlines(keepends=True), proc.stdout.splitlines(keepends=True),
            fromfile="golden", tofile="daemon stdout",
        ))
        sys.exit(f"serve_smoke: transcript mismatch\n{diff}")
    if "serving 18 x 14" not in proc.stderr:
        sys.exit(f"serve_smoke: banner missing from stderr:\n{proc.stderr}")
    print(f"serve_smoke: OK — {len(GOLDEN.splitlines())} golden reply lines, "
          "byte-exact, banner on stderr only")


if __name__ == "__main__":
    main()
