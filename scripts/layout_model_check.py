#!/usr/bin/env python3
"""Randomized equality check for the hub-layout model kernels.

Python twin of `rust/tests/layout_equality.rs`: on randomized graphs
from every generator family, the hub-layout kernels (bitmap
AND/popcount second hops, whole-pass hot-skip) must produce exactly the
same global / per-vertex / per-edge butterfly counts as the flat
kernels, and the global count must match the brute-force
common-neighbor oracle.  Graph sizes are chosen so a good fraction of
trials actually have a heavy tail (H > 0) — the script fails if none
do, so the hub path can never silently go untested.

Usage: python3 scripts/layout_model_check.py [trials]
"""
import random
import sys

import wedge_model as wm


def random_graph(rng):
    kind = rng.randrange(3)
    nu = rng.randint(20, 250)
    nv = rng.randint(20, 250)
    m = rng.randint(50, 4000)
    if kind == 0:
        return wm.erdos_renyi(nu, nv, m, rng.getrandbits(32))
    if kind == 1:
        return wm.chung_lu(nu, nv, m, 1.9 + rng.random() * 0.4, rng.getrandbits(32))
    k = rng.randint(1, 3)
    bu, bv = max(1, nu // k), max(1, nv // k)
    return wm.planted_blocks(k * bu, k * bv, k, bu, bv,
                             0.5 + rng.random() / 2, m // 4, rng.getrandbits(32))


def main():
    trials = int(sys.argv[1]) if len(sys.argv) > 1 else 40
    rng = random.Random(0xB1F1)
    with_hubs = 0
    for t in range(trials):
        nu, nv, edges = random_graph(rng)
        n, m = nu + nv, len(edges)
        if m == 0:
            continue
        adj, up, side = wm.preprocess(nu, nv, edges)
        thr = max(1, int(m ** 0.5))
        H = 0
        while H < n and len(adj[H]) > thr:
            H += 1
        with_hubs += H > 0
        ctx = f"trial {t}: nu={nu} nv={nv} m={m} H={H}"
        expect = wm.brute_total(nu, nv, edges)
        assert wm.total_flat(n, adj, up) == expect, f"{ctx}: flat total != brute"
        assert wm.total_hub(n, m, adj, up, side) == expect, f"{ctx}: hub total != brute"
        vf = wm.per_vertex_intersect(n, adj, up, [0] * n)
        vh = wm.per_vertex_hub(n, m, adj, up, side, [0] * n)
        assert vf == vh, f"{ctx}: per-vertex differs"
        assert sum(vf) == 4 * expect, f"{ctx}: per-vertex sum != 4*total"
        ef = wm.per_edge_intersect(n, m, adj, up, [0] * m)
        eh = wm.per_edge_hub(n, m, adj, up, side, [0] * m)
        assert ef == eh, f"{ctx}: per-edge differs"
        assert sum(ef) == 4 * expect, f"{ctx}: per-edge sum != 4*total"
    assert with_hubs > 0, "no trial had hubs — the hub path went untested"
    print(f"layout_model_check: {trials} trials OK ({with_hubs} with a heavy tail)")


if __name__ == "__main__":
    main()
