#!/usr/bin/env python3
"""Model harness seeding BENCH_peel.json.

Mirrors `cargo bench --bench peel_intersect_vs_agg` at the algorithmic
level: the aggregation UPDATE-V/UPDATE-E paths (full-adjacency
re-scans with peeled/round_of filtering, per-pair aggregation — the
shape of every `WedgeAgg` strategy) versus the streaming intersect
peel engine (incrementally-shrinking live adjacency, dense counters /
stamps, no wedge records).  Both drive the identical bucket model, so
the measured gap isolates exactly what the Rust engines differ in:
re-filtering dead adjacency and materializing per-pair work versus
walking only the surviving graph.

This exists because the authoring container has no Rust toolchain
(same situation as scripts/bench_intersect_model.py in the previous
PR); the JSON it writes is labeled `"harness": "python-model"` and is
superseded by re-running the Rust bench, which overwrites the same
file with native numbers and the full 6-row aggregation comparison.

Usage: python3 scripts/bench_peel_model.py
"""
import json
import time
from pathlib import Path

import bench_model_common
from wedge_model import (chung_lu, erdos_renyi, per_edge_intersect,
                         planted_blocks, preprocess)
from peel_model import (Graph, initial_vertex_counts, peel_e_agg,
                        peel_e_intersect, peel_e_two_phase, peel_v_agg,
                        peel_v_intersect, peel_v_two_phase)

# Model-scale stand-ins for the Rust PEELING_SUITE (small / cl / dense),
# shrunk so the pure-Python rounds finish in bench time.
WORKLOADS = [
    ("small", "ER 500x700 m~5k (model)", erdos_renyi(500, 700, 5_000, 101)),
    ("cl", "Chung-Lu beta=2.1 1500x2400 m~14k (model)", chung_lu(1_500, 2_400, 14_000, 2.1, 105)),
    ("dense", "8 planted 36x36 blocks p=0.85 + noise (model)",
     planted_blocks(600, 600, 8, 36, 36, 0.85, 1_200, 109)),
]


def edge_counts(nu, nv, edges):
    """Per-edge butterfly counts via the ranked streaming model (edge
    ids = positions in the sorted edge list, same as the Rust CSR)."""
    n, m = nu + nv, len(edges)
    adj, up, _side = preprocess(nu, nv, edges)
    be = [0] * m
    per_edge_intersect(n, m, adj, up, be)
    return be


def bench(f, runs=2):
    samples = []
    for _ in range(runs):
        t = time.perf_counter()
        f()
        samples.append((time.perf_counter() - t) * 1e3)
    # With runs=2 the old samples[len // 2] silently reported the MAX
    # of the two runs, not a median; average the middle pair instead.
    return bench_model_common.median(samples)


def main():
    rows = []
    summary = []
    for wl_id, describe, (nu, nv, edges) in WORKLOADS:
        g = Graph(nu, nv, edges)
        peel_u = g.wedges_centered_v() <= g.wedges_centered_u()
        vc = initial_vertex_counts(g, peel_u)
        be = edge_counts(nu, nv, g.edges)
        print(f"[{wl_id}] {describe}: m={g.m} peel_u={peel_u}")
        for mode, agg_f, isect_f, two_f, counts in [
            ("tip", lambda: peel_v_agg(g, vc, peel_u),
             lambda: peel_v_intersect(g, vc, peel_u),
             lambda: peel_v_two_phase(g, vc, peel_u), vc),
            ("wing", lambda: peel_e_agg(g, be),
             lambda: peel_e_intersect(g, be),
             lambda: peel_e_two_phase(g, be), be),
        ]:
            a, b, c = agg_f(), isect_f(), two_f()
            assert a == b == c, f"{wl_id}/{mode}: engines disagree"
            rounds = len(set(a))  # distinct peel values ~ informative proxy
            ms = {"agg": bench(agg_f), "intersect": bench(isect_f),
                  "two-phase": bench(two_f)}
            for label in ("agg", "intersect", "two-phase"):
                rows.append({"workload": wl_id, "mode": mode, "config": label,
                             "median_ms": round(ms[label], 3)})
                print(f"  {mode}/{label:<10} {ms[label]:10.2f} ms")
            speedup = ms["agg"] / ms["intersect"]
            print(f"  {mode}: intersect speedup {speedup:.2f}x")
            summary.append({
                "workload": wl_id, "mode": mode,
                "best_agg": "agg-model",
                "best_agg_ms": round(ms["agg"], 3),
                "intersect_ms": round(ms["intersect"], 3),
                "two_phase_ms": round(ms["two-phase"], 3),
                "speedup": round(speedup, 3),
                "distinct_peel_values": rounds,
            })
    doc = {
        "bench": "peel_intersect_vs_agg",
        "harness": "python-model",
        "note": ("Algorithmic model measurements (scripts/bench_peel_model.py): "
                 "aggregation UPDATE paths (full-adjacency rescans + per-pair "
                 "aggregation) vs the streaming live-view intersect peel engine "
                 "and the two-phase coarse/fine range-parallel engine, identical "
                 "bucket model.  Regenerate natively with `parbutterfly "
                 "bench run --filter peel` (or `cargo bench --bench "
                 "peel_intersect_vs_agg`), which overwrites this file with "
                 "`harness: \"native\"` rows and the full per-aggregation "
                 "comparison; compare snapshots with `parbutterfly bench diff`."),
        "env": bench_model_common.environment(threads=1),
        "threads": 1,
        "rows": rows,
        "summary": summary,
    }
    out = Path(__file__).resolve().parent.parent / "BENCH_peel.json"
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
