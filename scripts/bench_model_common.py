"""Shared pieces of the `bench_*_model.py` seed scripts.

The model scripts exist for containers without a Rust toolchain: they
measure pure-Python implementations of the same algorithms so the
`BENCH_*.json` snapshots carry real (if model-scale) numbers instead
of placeholders.  `parbutterfly bench run` overwrites these files with
`harness: "native"` rows; until then every snapshot says
`harness: "python-model"` and carries the environment block below so
provenance is never ambiguous.

This module mirrors two pieces of `rust/src/bench_support`:

* `median` — the fixed estimator: even-length sample lists average the
  two middle samples (`samples[n // 2]` alone is the *upper* middle
  and biases medians high — with runs=2 it silently reported the max);
* `environment` — the same env metadata the native snapshot writer
  records (threads, host parallelism, git rev, date, profile).
"""

import datetime
import os
import subprocess


def median(samples):
    """Median of a sorted-or-not list; even lengths average the middle pair."""
    s = sorted(samples)
    n = len(s)
    if n == 0:
        raise ValueError("median of no samples")
    if n % 2 == 0:
        return (s[n // 2 - 1] + s[n // 2]) / 2.0
    return s[n // 2]


def bench(f, warmup=1, runs=3):
    """Time `f`: `warmup` untimed calls, then the median of `runs` timed ones."""
    import time

    for _ in range(warmup):
        f()
    samples = []
    for _ in range(runs):
        t = time.perf_counter()
        f()
        samples.append((time.perf_counter() - t) * 1e3)
    return median(samples)


def _git_rev():
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True,
            text=True,
            timeout=10,
        )
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else "unknown"
    except OSError:
        return "unknown"


def environment(threads=1):
    """The same env block `bench run` writes into native snapshots."""
    return {
        "threads": threads,
        "host_parallelism": os.cpu_count() or 1,
        "git_rev": _git_rev(),
        "date": datetime.date.today().isoformat(),
        "profile": "model",
    }


if __name__ == "__main__":
    assert median([1.0, 2.0, 4.0, 8.0]) == 3.0
    assert median([1.0, 2.0, 4.0]) == 2.0
    assert median([5.0]) == 5.0
    env = environment()
    assert env["threads"] == 1 and len(env["date"]) == 10
    print("bench_model_common self-checks pass;", env)
