"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

The counts are integers, so every comparison is *exact*
(``assert_allclose(..., rtol=0, atol=0)``) — any tiling or masking bug
shows up as an off-by-integer, not a tolerance wobble.

Hypothesis sweeps shapes, tile sizes, and edge densities; fixed tests
pin the analytically known cases (complete bipartite graph, empty
graph, single butterfly).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import butterfly, ref


def random_block(rng: np.random.Generator, u: int, v: int, density: float):
    return (rng.random((u, v)) < density).astype(np.float32)


def exact(actual, expected):
    np.testing.assert_allclose(
        np.asarray(actual, dtype=np.float64),
        np.asarray(expected, dtype=np.float64),
        rtol=0,
        atol=0,
    )


# ---------------------------------------------------------------------------
# bfly_rowsum_tiles (per-vertex kernel)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    ut=st.integers(1, 4),
    vt=st.integers(1, 4),
    tile=st.sampled_from([4, 8, 16]),
    density=st.sampled_from([0.0, 0.1, 0.4, 0.8, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rowsum_matches_ref(ut, vt, tile, density, seed):
    rng = np.random.default_rng(seed)
    a = random_block(rng, ut * tile, vt * tile, density)
    parts = butterfly.bfly_rowsum_tiles(jnp.asarray(a), tile=tile)
    b_u = np.sum(np.asarray(parts, dtype=np.float64), axis=0)
    expected, _ = ref.per_vertex_ref(a)
    exact(b_u, expected)


@settings(max_examples=25, deadline=None)
@given(
    ut=st.integers(1, 3),
    vt=st.integers(1, 3),
    tile=st.sampled_from([4, 8]),
    density=st.sampled_from([0.2, 0.6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rowsum_transpose_gives_v_side(ut, vt, tile, density, seed):
    rng = np.random.default_rng(seed)
    a = random_block(rng, ut * tile, vt * tile, density)
    parts = butterfly.bfly_rowsum_tiles(jnp.asarray(a.T), tile=tile)
    b_v = np.sum(np.asarray(parts, dtype=np.float64), axis=0)
    _, expected = ref.per_vertex_ref(a)
    exact(b_v, expected)


def test_rowsum_rejects_unaligned():
    a = jnp.zeros((10, 8), jnp.float32)
    with pytest.raises(ValueError):
        butterfly.bfly_rowsum_tiles(a, tile=4)


def test_complete_bipartite_counts():
    # K_{6,5}: total butterflies = C(6,2) * C(5,2) = 150;
    # every U vertex is in C(5,2)*(6-1) = 50 butterflies.
    a = np.ones((6, 5), np.float32)
    ap = np.zeros((8, 8), np.float32)
    ap[:6, :5] = a
    parts = butterfly.bfly_rowsum_tiles(jnp.asarray(ap), tile=4)
    b_u = np.sum(np.asarray(parts, np.float64), axis=0)
    assert b_u[:6].tolist() == [50.0] * 6
    assert b_u[6:].tolist() == [0.0, 0.0]
    assert float(np.sum(b_u)) / 2 == 150.0


def test_single_butterfly():
    a = np.zeros((4, 4), np.float32)
    a[0, 0] = a[0, 1] = a[1, 0] = a[1, 1] = 1.0
    parts = butterfly.bfly_rowsum_tiles(jnp.asarray(a), tile=2)
    b_u = np.sum(np.asarray(parts, np.float64), axis=0)
    exact(b_u, [1.0, 1.0, 0.0, 0.0])


# ---------------------------------------------------------------------------
# bfly_edge_counts (per-edge kernel)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    ut=st.integers(1, 4),
    vt=st.integers(1, 4),
    tile=st.sampled_from([4, 8, 16]),
    density=st.sampled_from([0.0, 0.1, 0.4, 0.8, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_edge_matches_ref(ut, vt, tile, density, seed):
    rng = np.random.default_rng(seed)
    a = random_block(rng, ut * tile, vt * tile, density)
    b_e = butterfly.bfly_edge_counts(jnp.asarray(a), tile=tile)
    exact(b_e, ref.per_edge_ref(a))


@settings(max_examples=10, deadline=None)
@given(
    u=st.integers(2, 7),
    v=st.integers(2, 7),
    density=st.sampled_from([0.3, 0.7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_edge_ref_matches_brute_force(u, v, density, seed):
    rng = np.random.default_rng(seed)
    a = random_block(rng, u, v, density)
    exact(ref.per_edge_ref(a), ref.brute_force_per_edge(a))


def test_edge_zero_off_edges():
    rng = np.random.default_rng(7)
    a = random_block(rng, 8, 8, 0.5)
    b_e = np.asarray(butterfly.bfly_edge_counts(jnp.asarray(a), tile=4))
    assert np.all(b_e[a == 0] == 0)


# ---------------------------------------------------------------------------
# wedge_matrix kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    ut=st.integers(1, 4),
    vt=st.integers(1, 4),
    tile=st.sampled_from([4, 8]),
    density=st.sampled_from([0.2, 0.5, 0.9]),
    seed=st.integers(0, 2**31 - 1),
)
def test_wedge_matrix_matches_ref(ut, vt, tile, density, seed):
    rng = np.random.default_rng(seed)
    a = random_block(rng, ut * tile, vt * tile, density)
    w = butterfly.wedge_matrix(jnp.asarray(a), tile=tile)
    exact(w, ref.wedge_matrix_ref(a))


# ---------------------------------------------------------------------------
# oracle self-consistency vs explicit enumeration
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    u=st.integers(1, 7),
    v=st.integers(1, 7),
    density=st.sampled_from([0.2, 0.5, 0.9]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ref_matches_brute_force(u, v, density, seed):
    rng = np.random.default_rng(seed)
    a = random_block(rng, u, v, density)
    b_u, b_v = ref.per_vertex_ref(a)
    bf_u, bf_v = ref.brute_force_per_vertex(a)
    exact(b_u, bf_u)
    exact(b_v, bf_v)
    exact(ref.total_ref(a), ref.brute_force_total(a))


def test_f32_exactness_at_cap():
    # Worst-case tile: all-ones 128x512 block — per-row partial hits
    # 127 * C(512, 2)?  No: per (i,j) tile partial is <= tile * C(V,2)
    # = 128 * 130816 = 16,744,448 < 2^24.  Verify the dense extreme.
    a = np.ones((128, 512), np.float32)
    parts = butterfly.bfly_rowsum_tiles(jnp.asarray(a), tile=128)
    b_u = np.sum(np.asarray(parts, np.float64), axis=0)
    expected = 127 * (512 * 511 // 2)
    assert b_u[0] == float(expected)
