"""Layer-2 correctness: model entry points + AOT lowering contract."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref


def random_block(rng, u, v, density):
    return (rng.random((u, v)) < density).astype(np.float32)


def exact(actual, expected):
    np.testing.assert_allclose(
        np.asarray(actual, np.float64), np.asarray(expected, np.float64),
        rtol=0, atol=0,
    )


@settings(max_examples=20, deadline=None)
@given(
    ut=st.integers(1, 3),
    vt=st.integers(1, 3),
    tile=st.sampled_from([8, 16]),
    density=st.sampled_from([0.1, 0.5, 0.9]),
    seed=st.integers(0, 2**31 - 1),
)
def test_count_dense_matches_ref(ut, vt, tile, density, seed):
    rng = np.random.default_rng(seed)
    a = random_block(rng, ut * tile, vt * tile, density)
    total, b_u, b_v, b_e = model.count_dense(jnp.asarray(a), tile=tile)
    exact(total, ref.total_ref(a))
    ref_u, ref_v = ref.per_vertex_ref(a)
    exact(b_u, ref_u)
    exact(b_v, ref_v)
    exact(b_e, ref.per_edge_ref(a))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_count_internal_consistency(seed):
    # total == sum(b_u)/2 == sum(b_v)/2 == sum(b_e)/4.
    rng = np.random.default_rng(seed)
    a = random_block(rng, 16, 16, 0.4)
    total, b_u, b_v, b_e = model.count_dense(jnp.asarray(a), tile=8)
    t = float(total)
    assert t == float(jnp.sum(b_u)) / 2
    assert t == float(jnp.sum(b_v)) / 2
    assert t == float(jnp.sum(b_e.astype(jnp.float64))) / 4


def test_count_total_entry():
    rng = np.random.default_rng(3)
    a = random_block(rng, 16, 16, 0.5)
    (total,) = model.count_total(jnp.asarray(a), tile=8)
    exact(total, ref.total_ref(a))


def test_wedge_stats_entry():
    rng = np.random.default_rng(4)
    a = random_block(rng, 16, 16, 0.5)
    wu, wv = model.wedge_stats(jnp.asarray(a), tile=8)
    deg_u = a.sum(axis=1)
    deg_v = a.sum(axis=0)
    exact(wu, np.sum(deg_v * (deg_v - 1) / 2))
    exact(wv, np.sum(deg_u * (deg_u - 1) / 2))


def test_padding_is_neutral():
    # Zero-padding a block must not change any count on real vertices.
    rng = np.random.default_rng(5)
    a = random_block(rng, 8, 8, 0.6)
    ap = np.zeros((16, 16), np.float32)
    ap[:8, :8] = a
    t1, bu1, bv1, be1 = model.count_dense(jnp.asarray(ap), tile=8)
    exact(t1, ref.total_ref(a))
    ref_u, ref_v = ref.per_vertex_ref(a)
    exact(np.asarray(bu1)[:8], ref_u)
    exact(np.asarray(bv1)[:8], ref_v)
    assert np.all(np.asarray(bu1)[8:] == 0)
    exact(np.asarray(be1)[:8, :8], ref.per_edge_ref(a))


# ---------------------------------------------------------------------------
# AOT lowering contract (what the Rust runtime depends on)
# ---------------------------------------------------------------------------

def test_lowering_emits_valid_hlo_text():
    text = aot.lower_entry(model.count_total, 128, 128)
    assert "HloModule" in text
    assert "f32[128,128]" in text  # the input parameter shape
    # return_tuple=True: root is a tuple instruction.
    assert "tuple(" in text or "ROOT" in text


def test_lowering_count_dense_output_shapes():
    text = aot.lower_entry(model.count_dense, 128, 128)
    assert "HloModule" in text
    assert "f64[128]" in text       # b_u / b_v
    assert "f32[128,128]" in text   # input and b_e


def test_lowered_executes_same_numbers():
    # Compile the lowered stablehlo back through jax and compare against
    # eager execution — guards against lowering-only bugs.
    spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    fn = lambda a: model.count_dense(a, tile=8)  # noqa: E731
    lowered = jax.jit(fn).lower(spec)
    compiled = lowered.compile()
    rng = np.random.default_rng(6)
    a = jnp.asarray(random_block(rng, 16, 16, 0.5))
    got = compiled(a)
    want = fn(a)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=0, atol=0)
