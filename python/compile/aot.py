"""AOT pipeline: lower the Layer-2 model to HLO text artifacts.

``python -m compile.aot --out-dir ../artifacts`` lowers each entry point
in ``model.py`` for a fixed menu of padded shapes and writes:

* ``artifacts/<name>_<U>x<V>.hlo.txt``  — HLO **text** modules.
* ``artifacts/manifest.txt``            — one line per artifact:
  ``<entry> <U> <V> <n_outputs> <filename>`` parsed by the Rust runtime.

HLO *text* (never ``.serialize()``) is the interchange format: jax>=0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  Lowering goes stablehlo -> XlaComputation with
``return_tuple=True``; the Rust side unwraps with ``to_tuple()``.

This module runs exactly once, at build time (``make artifacts``);
nothing here is on the request path.
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# (entry point, shapes) menu.  Tiles are 128 (MXU-aligned); shapes are
# capped at 512 to keep per-tile f32 partials exact (see kernels docs).
SHAPES = [(128, 128), (256, 256), (256, 512), (512, 512)]
ENTRIES = {
    "count_dense": (model.count_dense, 4),
    "count_total": (model.count_total, 1),
    "wedge_stats": (model.wedge_stats, 2),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, u: int, v: int) -> str:
    spec = jax.ShapeDtypeStruct((u, v), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--entries",
        default=",".join(ENTRIES),
        help="comma-separated subset of entry points to lower",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name in args.entries.split(","):
        fn, n_out = ENTRIES[name]
        for (u, v) in SHAPES:
            text = lower_entry(fn, u, v)
            fname = f"{name}_{u}x{v}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest_lines.append(f"{name} {u} {v} {n_out} {fname}")
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
