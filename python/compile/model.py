"""Layer-2 JAX compute graph for dense-tile butterfly counting.

Composes the Layer-1 Pallas kernels (``kernels.butterfly``) into the
entry points that get AOT-lowered to HLO text and executed by the Rust
coordinator's ``DenseCoreEngine``:

* ``count_dense(A)`` -> ``(total, b_u, b_v, b_e)``
  full dense-block butterfly statistics.
* ``wedge_stats(A)`` -> ``(wedges_u, wedges_v)``
  side-wedge totals for the ordering auto-tuner (f-metric, §6.2.2).

Numerics contract (see kernels/butterfly.py): Pallas tiles produce
*exact* f32 integer partials for blocks up to 512x512; the cross-tile
reduction here runs in f64 (``jax_enable_x64`` is switched on by
``aot.py`` and the tests).  Outputs: total f64 scalar, b_u/b_v f64
vectors, b_e f32 matrix (per-edge counts are bounded by U*V < 2^24).

Python (this module included) runs only at build time; the lowered HLO
is the runtime interface.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import butterfly


def count_dense(a, tile: int = butterfly.DEFAULT_TILE):
    """Dense-block butterfly statistics.

    Args:
      a: (U, V) f32 0/1 adjacency block; U, V multiples of ``tile``.
    Returns:
      total: f64 scalar — global butterfly count of the block.
      b_u:   (U,) f64 — per-vertex counts, U side.
      b_v:   (V,) f64 — per-vertex counts, V side.
      b_e:   (U, V) f32 — per-edge counts (0 off-edges).
    """
    a = a.astype(jnp.float32)
    # U side: exact f32 per-tile partials, f64 cross-tile reduction.
    parts_u = butterfly.bfly_rowsum_tiles(a, tile=tile)
    b_u = jnp.sum(parts_u.astype(jnp.float64), axis=0)
    # V side: same kernel on the transpose.
    at = jnp.transpose(a)
    parts_v = butterfly.bfly_rowsum_tiles(at, tile=tile)
    b_v = jnp.sum(parts_v.astype(jnp.float64), axis=0)
    # Every butterfly has exactly two U-side endpoints.
    total = jnp.sum(b_u) / 2.0
    b_e = butterfly.bfly_edge_counts(a, tile=tile)
    return total, b_u, b_v, b_e


def count_total(a, tile: int = butterfly.DEFAULT_TILE):
    """Global count only — lighter artifact for the hybrid scheduler."""
    a = a.astype(jnp.float32)
    parts_u = butterfly.bfly_rowsum_tiles(a, tile=tile)
    b_u = jnp.sum(parts_u.astype(jnp.float64), axis=0)
    return (jnp.sum(b_u) / 2.0,)


def wedge_stats(a, tile: int = butterfly.DEFAULT_TILE):
    """Side-wedge totals (sum_x C(deg(x), 2) per side) for ranking.

    Cheap, but routed through the Pallas wedge kernel so the artifact
    exercises the same HBM->VMEM schedule; the Rust side uses these for
    the side-ordering decision on densified cores.
    """
    a = a.astype(jnp.float32)
    w_u = butterfly.wedge_matrix(a, tile=tile)
    # Diagonal of W is deg(u); wedges with endpoints on the U side:
    # sum_v C(deg(v), 2) — note endpoints on U means centers on V.
    deg_u = jnp.diagonal(w_u).astype(jnp.float64)
    deg_v = jnp.sum(a, axis=0, dtype=jnp.float64)
    wedges_endp_u = jnp.sum(deg_v * (deg_v - 1.0) / 2.0)
    wedges_endp_v = jnp.sum(deg_u * (deg_u - 1.0) / 2.0)
    return wedges_endp_u, wedges_endp_v
