"""Pure-jnp / numpy oracles for the dense butterfly kernels.

Two tiers:

* ``*_ref``  — straightforward jnp linear-algebra formulations of
  Lemma 4.2.  Same math as the Pallas kernels but with none of the
  tiling; the kernels must match these bit-exactly (integer counts).
* ``brute_force_*`` — O(U^2 V^2) explicit enumeration in numpy for tiny
  inputs; anchors the linear-algebra formulation itself.
"""

from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np


def per_vertex_ref(a):
    """(b_u, b_v): per-vertex butterfly counts for both sides, f64."""
    a = jnp.asarray(a, jnp.float64)
    w_u = a @ a.T
    w_u = w_u - jnp.diag(jnp.diag(w_u))
    b_u = jnp.sum(w_u * (w_u - 1.0) / 2.0, axis=1)
    w_v = a.T @ a
    w_v = w_v - jnp.diag(jnp.diag(w_v))
    b_v = jnp.sum(w_v * (w_v - 1.0) / 2.0, axis=1)
    return b_u, b_v


def total_ref(a):
    """Global butterfly count, f64 scalar."""
    b_u, _ = per_vertex_ref(a)
    return jnp.sum(b_u) / 2.0


def per_edge_ref(a):
    """(U, V) per-edge butterfly counts, f64.

    b_e[u,v] = A[u,v] * ((W0 @ A)[u,v] - (deg(v) - 1))  (Lemma 4.2 Eq. 2).
    """
    a = jnp.asarray(a, jnp.float64)
    w0 = a @ a.T
    w0 = w0 - jnp.diag(jnp.diag(w0))
    degv = jnp.sum(a, axis=0)
    return a * (w0 @ a - (degv[None, :] - 1.0))


def wedge_matrix_ref(a):
    """W = A @ A^T (diagonal kept), f64."""
    a = jnp.asarray(a, jnp.float64)
    return a @ a.T


def brute_force_total(a) -> int:
    """Count butterflies by enumerating endpoint pairs explicitly."""
    a = np.asarray(a)
    u_n, _ = a.shape
    count = 0
    for u1, u2 in itertools.combinations(range(u_n), 2):
        common = int(np.sum(a[u1] * a[u2]))
        count += common * (common - 1) // 2
    return count


def brute_force_per_vertex(a):
    """(b_u, b_v) by explicit O(U^2 V^2) enumeration."""
    a = np.asarray(a)
    u_n, v_n = a.shape
    b_u = np.zeros(u_n, dtype=np.int64)
    b_v = np.zeros(v_n, dtype=np.int64)
    for u1, u2 in itertools.combinations(range(u_n), 2):
        for v1, v2 in itertools.combinations(range(v_n), 2):
            if a[u1, v1] and a[u1, v2] and a[u2, v1] and a[u2, v2]:
                b_u[u1] += 1
                b_u[u2] += 1
                b_v[v1] += 1
                b_v[v2] += 1
    return b_u, b_v


def brute_force_per_edge(a):
    """(U, V) per-edge counts by explicit O(U^2 V^2) enumeration."""
    a = np.asarray(a)
    u_n, v_n = a.shape
    b_e = np.zeros((u_n, v_n), dtype=np.int64)
    for u1, u2 in itertools.combinations(range(u_n), 2):
        for v1, v2 in itertools.combinations(range(v_n), 2):
            if a[u1, v1] and a[u1, v2] and a[u2, v1] and a[u2, v2]:
                for (uu, vv) in ((u1, v1), (u1, v2), (u2, v1), (u2, v2)):
                    b_e[uu, vv] += 1
    return b_e
