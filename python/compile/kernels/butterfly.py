"""Layer-1 Pallas kernels for dense-tile butterfly counting.

The paper's hot loop — aggregating wedges between pairs of same-side
vertices — is, restricted to a dense vertex block with 0/1 adjacency
matrix ``A`` (U x V), exactly the rank-V update ``W = A @ A^T``:
``W[u, u']`` is the number of wedges with endpoints ``(u, u')``.  The
butterfly statistics follow from W by purely local arithmetic:

* per-vertex (endpoint side):  ``b_u = sum_{u' != u} C(W[u,u'], 2)``
* total:                       ``sum_u b_u / 2``  (each butterfly has two
  endpoints on each side)
* per-edge: ``b_e[u,v] = A[u,v] * ((W0 @ A)[u,v] - (deg(v) - 1))`` where
  ``W0`` is W with its diagonal zeroed (Lemma 4.2, Eq. (2)).

These kernels tile the computation for the MXU: ``TU x V`` row-blocks of
A stream through VMEM, the ``TU x TU`` wedge tile is produced by a
systolic matmul and consumed in-register by the binomial epilogue, so W
is never materialized in HBM.  This is the TPU re-thinking of the
paper's cache-resident "simple batching" aggregation (see
DESIGN.md §Hardware-Adaptation).

Numerics: counts are integers carried in f32.  A single wedge tile
contributes a per-row partial of at most ``TU * C(V, 2)``; with
``TU = 128`` and ``V <= 512`` this stays within f32's exact-integer
window (2^24), so per-(i, j)-tile partials are exact and the Layer-2
model performs the cross-tile reduction in f64.  Artifacts are therefore
capped at 512x512 tiles; the Rust coordinator decomposes larger dense
cores into tiles and sums in u64/f64.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that
the Rust runtime can run (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile height.  128 matches the MXU systolic array
# dimension; tests shrink it to exercise multi-tile paths on small inputs.
DEFAULT_TILE = 128


def _bfly_rowsum_kernel(ai_ref, aj_ref, out_ref, *, tile: int):
    """One (i, j) wedge tile: rows ``i`` x rows ``j`` of A.

    Writes the per-row partial butterfly sums ``sum_{u' in tile j}
    C(W[u, u'], 2)`` (global diagonal masked) for the ``tile`` rows of
    tile ``i`` into the (1, tile) output block at grid position (j, i).
    Each grid step owns a distinct output block, so partials stay exact
    in f32 and the cross-tile reduction happens in f64 in Layer 2.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    # MXU op: (tile, V) x (V, tile) -> (tile, tile) wedge-count tile.
    w = jnp.dot(ai_ref[...], aj_ref[...].T, preferred_element_type=jnp.float32)
    # Mask the global diagonal (wedges need two *distinct* endpoints):
    # W[u, u] = deg(u) counts degenerate self-wedges.
    row = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 0) + i * tile
    col = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 1) + j * tile
    w = jnp.where(row == col, 0.0, w)
    # Binomial epilogue, fused so W never leaves VMEM: C(w, 2).
    b = w * (w - 1.0) * 0.5
    out_ref[...] = jnp.sum(b, axis=1).reshape(1, tile)


def bfly_rowsum_tiles(a: jax.Array, tile: int = DEFAULT_TILE) -> jax.Array:
    """Per-(row-tile) butterfly partial sums for the row side of ``a``.

    Args:
      a: (U, V) 0/1 adjacency block, f32, with U and V multiples of
        ``tile`` (Layer 2 pads).
    Returns:
      (U // tile, U) f32 array P where ``P[j, u]`` is u's butterfly
      contribution from wedges whose second endpoint lies in row-tile j.
      ``b_u = sum_j P[j, u]`` (reduce in f64 — see module docstring).
    """
    u, _ = a.shape
    if u % tile != 0:
        raise ValueError(f"U={u} not a multiple of tile={tile}")
    nt = u // tile
    kernel = functools.partial(_bfly_rowsum_kernel, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((tile, a.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, a.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((nt, u), jnp.float32),
        interpret=True,
    )(a, a)


def _bfly_edge_kernel(ai_ref, aj_ref, degv_ref, out_ref, *, tile: int):
    """Accumulate the per-edge butterfly tile for row-tile ``i``.

    Grid is (I, J) with J the reduction dimension: each step adds tile
    j's contribution ``W0[i, j] @ A[j]`` to the (tile, V) output block
    for row-tile i.  On the last j step the epilogue applies
    ``A * (acc - (deg(v) - 1))`` (Eq. (2) of the paper).
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    ai = ai_ref[...]
    aj = aj_ref[...]
    w = jnp.dot(ai, aj.T, preferred_element_type=jnp.float32)
    row = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 0) + i * tile
    col = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 1) + j * tile
    w = jnp.where(row == col, 0.0, w)
    # Contribution of row-tile j to (W0 @ A)[rows of tile i].
    part = jnp.dot(w, aj, preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        out_ref[...] += part

    @pl.when(j == nj - 1)
    def _epilogue():
        acc = out_ref[...]
        degv = degv_ref[...]  # (1, V) column degrees of the full block
        out_ref[...] = ai * (acc - (degv - 1.0))


def bfly_edge_counts(a: jax.Array, tile: int = DEFAULT_TILE) -> jax.Array:
    """Per-edge butterfly counts for a dense 0/1 block.

    Returns a (U, V) f32 array E with ``E[u, v]`` = number of butterflies
    containing edge (u, v) (0 where there is no edge).  Max accumulator
    value is ``U * V <= 512^2 < 2^24``, so in-kernel f32 accumulation is
    exact for supported tile sizes.
    """
    u, v = a.shape
    if u % tile != 0:
        raise ValueError(f"U={u} not a multiple of tile={tile}")
    nt = u // tile
    degv = jnp.sum(a, axis=0, dtype=jnp.float32).reshape(1, v)
    kernel = functools.partial(_bfly_edge_kernel, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((tile, v), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, v), lambda i, j: (j, 0)),
            pl.BlockSpec((1, v), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, v), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((u, v), jnp.float32),
        interpret=True,
    )(a, a, degv)


def _wedge_tile_kernel(ai_ref, aj_ref, out_ref, *, tile: int):
    """Raw wedge-count tile W[i-tile, j-tile] (diagonal kept).

    Exposed for the wedge-statistics artifact used by the Rust
    coordinator's ordering auto-tuner (the f-metric needs wedge counts,
    not butterfly counts).
    """
    w = jnp.dot(ai_ref[...], aj_ref[...].T, preferred_element_type=jnp.float32)
    out_ref[...] = w


def wedge_matrix(a: jax.Array, tile: int = DEFAULT_TILE) -> jax.Array:
    """Full wedge-count matrix ``W = A @ A^T`` via the tiled kernel."""
    u, v = a.shape
    if u % tile != 0:
        raise ValueError(f"U={u} not a multiple of tile={tile}")
    nt = u // tile
    kernel = functools.partial(_wedge_tile_kernel, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((tile, v), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, v), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((u, u), jnp.float32),
        interpret=True,
    )(a, a)
