//! Vertex rankings (§3.1.1, §4.5, §4.6).
//!
//! A ranking maps every vertex (global id: U-side `0..nu`, V-side
//! `nu..n`) to a rank in `0..n`; GET-WEDGES only retrieves wedges whose
//! center and second endpoint out-rank the first endpoint, so the
//! ranking controls how many wedges are processed.
//!
//! * [`Ranking::Side`] — one bipartition ordered first (Sanei-Mehri et
//!   al.); the side is chosen so that wedge *centers* fall on the side
//!   with fewer `C(deg, 2)` wedges.
//! * [`Ranking::Degree`] — decreasing degree (Chiba–Nishizeki); gives
//!   the `O(alpha m)` work bound.
//! * [`Ranking::ApproxDegree`] — decreasing `floor(log2 deg)`, ties by
//!   vertex id to preserve input locality (Theorem 4.11: same bound).
//! * [`Ranking::CoDegeneracy`] — repeatedly remove *max*-degree
//!   vertices (complement of the k-core peeling order; Theorem 4.12).
//! * [`Ranking::ApproxCoDegeneracy`] — same with log-degree buckets
//!   (fewer rounds; Theorem 4.13).
//!
//! [`f_metric`] computes the Table 3 quantity `f = (w_s - w_r) / w_s`;
//! [`choose_ranking`] applies the paper's rule of thumb (side ordering
//! unless some ranking saves >= 10% of wedges).

use crate::graph::{BipartiteGraph, RankedGraph};
use crate::prims::sort::par_sort;

/// The five vertex orderings of the ParButterfly framework.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Ranking {
    Side,
    Degree,
    ApproxDegree,
    CoDegeneracy,
    ApproxCoDegeneracy,
}

impl Ranking {
    pub const ALL: [Ranking; 5] = [
        Ranking::Side,
        Ranking::Degree,
        Ranking::ApproxDegree,
        Ranking::CoDegeneracy,
        Ranking::ApproxCoDegeneracy,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Ranking::Side => "side",
            Ranking::Degree => "degree",
            Ranking::ApproxDegree => "adegree",
            Ranking::CoDegeneracy => "codeg",
            Ranking::ApproxCoDegeneracy => "acodeg",
        }
    }

    pub fn parse(s: &str) -> Option<Ranking> {
        Ranking::ALL.into_iter().find(|r| r.name() == s)
    }
}

fn degree_of(g: &BipartiteGraph, gid: usize) -> usize {
    if gid < g.nu() {
        g.deg_u(gid)
    } else {
        g.deg_v(gid - g.nu())
    }
}

/// Compute `rank_of[global id] -> rank` for the chosen ordering.
pub fn rank_vertices(g: &BipartiteGraph, ranking: Ranking) -> Vec<u32> {
    let n = g.n();
    match ranking {
        Ranking::Side => {
            // Endpoints on the first side, centers on the second; put
            // the side whose *opposite* has fewer wedges first.
            let u_first = g.wedges_centered_v() <= g.wedges_centered_u();
            let mut rank = vec![0u32; n];
            if u_first {
                for gid in 0..n {
                    rank[gid] = gid as u32; // U already 0..nu
                }
            } else {
                let (nu, nv) = (g.nu(), g.nv());
                for v in 0..nv {
                    rank[nu + v] = v as u32;
                }
                for u in 0..nu {
                    rank[u] = (nv + u) as u32;
                }
            }
            rank
        }
        Ranking::Degree => by_key_desc(g, |g, gid| degree_of(g, gid) as u64),
        Ranking::ApproxDegree => {
            by_key_desc(g, |g, gid| 64 - (degree_of(g, gid) as u64 + 1).leading_zeros() as u64)
        }
        Ranking::CoDegeneracy => co_degeneracy(g, false),
        Ranking::ApproxCoDegeneracy => co_degeneracy(g, true),
    }
}

/// Rank by decreasing key, ties broken by increasing vertex id (keeps
/// input locality, which is why approximate degree order wins in
/// practice on well-laid-out graphs).
fn by_key_desc(g: &BipartiteGraph, key: impl Fn(&BipartiteGraph, usize) -> u64) -> Vec<u32> {
    let n = g.n();
    // Pack (key, id) so one u64 sort orders by key desc then id asc.
    // key <= n < 2^32 always (degree bound), id < 2^32.
    let mut packed: Vec<u64> = (0..n)
        .map(|gid| ((u32::MAX as u64 - key(g, gid)) << 32) | gid as u64)
        .collect();
    par_sort(&mut packed);
    let mut rank = vec![0u32; n];
    for (r, &p) in packed.iter().enumerate() {
        rank[(p & 0xffff_ffff) as usize] = r as u32;
    }
    rank
}

/// Complement (co-)degeneracy: repeatedly peel all vertices of maximum
/// (log-)degree from the remaining graph; rank in removal order.
///
/// Bucketing by current degree with lazy entries, mirroring the
/// Julienne-based implementation in the paper (but walking buckets from
/// the top).  Returns `rank_of`.
fn co_degeneracy(g: &BipartiteGraph, approx: bool) -> Vec<u32> {
    let n = g.n();
    let nu = g.nu();
    let bucket_of = |d: usize| -> usize {
        if approx {
            if d == 0 {
                0
            } else {
                usize::BITS as usize - (d.leading_zeros() as usize)
            }
        } else {
            d
        }
    };
    let maxd = g.max_degree();
    let nb = bucket_of(maxd) + 1;
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); nb];
    let mut cur_deg = vec![0usize; n];
    for gid in 0..n {
        let d = degree_of(g, gid);
        cur_deg[gid] = d;
        buckets[bucket_of(d)].push(gid as u32);
    }
    let mut removed = vec![false; n];
    let mut rank = vec![0u32; n];
    let mut next_rank = 0u32;
    let mut top = nb as isize - 1;
    while top >= 0 {
        // Collect the valid members of the top bucket (lazy deletion:
        // entries whose degree has since dropped are skipped; they are
        // re-inserted at their lower bucket on every decrement).
        let members: Vec<u32> = std::mem::take(&mut buckets[top as usize]);
        // Filter-and-mark in one pass: lazy bucket entries can contain
        // duplicates (a vertex is re-pushed on every decrement), so a
        // vertex is claimed (marked removed) the first time it is seen.
        let mut valid: Vec<u32> = Vec::new();
        for x in members {
            let gid = x as usize;
            if !removed[gid] && bucket_of(cur_deg[gid]) == top as usize {
                removed[gid] = true;
                rank[gid] = next_rank;
                next_rank += 1;
                valid.push(x);
            }
        }
        if valid.is_empty() {
            top -= 1;
            continue;
        }
        for &x in &valid {
            let gid = x as usize;
            let nbrs: &[u32] = if gid < nu { g.nbrs_u(gid) } else { g.nbrs_v(gid - nu) };
            for &w in nbrs {
                let wg = if gid < nu { nu + w as usize } else { w as usize };
                if !removed[wg] && cur_deg[wg] > 0 {
                    cur_deg[wg] -= 1;
                    // Lazy re-insertion at the (possibly same, for
                    // approx log-buckets) new bucket; stale entries are
                    // filtered on extraction.
                    buckets[bucket_of(cur_deg[wg])].push(wg as u32);
                }
            }
        }
    }
    debug_assert_eq!(next_rank as usize, n);
    rank
}

/// Preprocess (Algorithm 1) under the chosen ordering.
pub fn preprocess(g: &BipartiteGraph, ranking: Ranking) -> RankedGraph {
    RankedGraph::new(g, rank_vertices(g, ranking))
}

/// The Table 3 metric `f = (w_s - w_r) / w_s` where `w_s` / `w_r` are
/// the wedges processed under side ordering / under `ranking`.
pub fn f_metric(g: &BipartiteGraph, ranking: Ranking) -> f64 {
    let ws = preprocess(g, Ranking::Side).wedges_processed();
    let wr = preprocess(g, ranking).wedges_processed();
    if ws == 0 {
        return 0.0;
    }
    (ws as f64 - wr as f64) / ws as f64
}

/// Runtime ordering selection (§6.2.2): side ordering unless another
/// ranking saves at least 10% of the wedges (f >= 0.1); approximate
/// degree is the cheap representative of the degree-style orderings.
pub fn choose_ranking(g: &BipartiteGraph) -> Ranking {
    if f_metric(g, Ranking::ApproxDegree) >= 0.1 {
        Ranking::ApproxDegree
    } else {
        Ranking::Side
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn is_permutation(rank: &[u32]) -> bool {
        let mut seen = vec![false; rank.len()];
        for &r in rank {
            if seen[r as usize] {
                return false;
            }
            seen[r as usize] = true;
        }
        true
    }

    #[test]
    fn all_rankings_are_permutations() {
        let g = gen::chung_lu(200, 300, 2000, 2.2, 9);
        for r in Ranking::ALL {
            let rank = rank_vertices(&g, r);
            assert_eq!(rank.len(), g.n());
            assert!(is_permutation(&rank), "{:?}", r);
        }
    }

    #[test]
    fn side_order_puts_cheaper_centers_second() {
        // U degrees are huge -> wedges centered on U huge -> V should
        // be the center side is wrong; we want centers on the side
        // with FEWER wedges, i.e. V side first iff centers (U) cheap.
        let g = gen::complete_bipartite(3, 30); // wedges_u = 3*C(30..)? no:
        // deg_u = 30 each -> wedges centered U = 3*C(30,2)=1305;
        // deg_v = 3 each -> wedges centered V = 30*C(3,2)=90.
        let rank = rank_vertices(&g, Ranking::Side);
        // centers should be V (90 < 1305): endpoints = U side first.
        for u in 0..3 {
            assert!(rank[u] < 3, "U must be ranked first");
        }
    }

    #[test]
    fn degree_order_is_decreasing() {
        let g = gen::chung_lu(100, 150, 1500, 2.1, 4);
        let rank = rank_vertices(&g, Ranking::Degree);
        let mut by_rank = vec![0usize; g.n()];
        for gid in 0..g.n() {
            by_rank[rank[gid] as usize] = gid;
        }
        let deg = |gid: usize| {
            if gid < g.nu() {
                g.deg_u(gid)
            } else {
                g.deg_v(gid - g.nu())
            }
        };
        for w in by_rank.windows(2) {
            assert!(deg(w[0]) >= deg(w[1]));
        }
    }

    #[test]
    fn approx_degree_groups_by_log() {
        let g = gen::chung_lu(100, 150, 1500, 2.1, 4);
        let rank = rank_vertices(&g, Ranking::ApproxDegree);
        let mut by_rank = vec![0usize; g.n()];
        for gid in 0..g.n() {
            by_rank[rank[gid] as usize] = gid;
        }
        let logdeg = |gid: usize| {
            let d = if gid < g.nu() { g.deg_u(gid) } else { g.deg_v(gid - g.nu()) };
            64 - (d as u64 + 1).leading_zeros()
        };
        for w in by_rank.windows(2) {
            assert!(logdeg(w[0]) >= logdeg(w[1]));
        }
    }

    #[test]
    fn codegeneracy_first_round_is_max_degree() {
        let g = gen::complete_bipartite(4, 9);
        // U vertices have degree 9 (max) -> must get the first 4 ranks.
        let rank = rank_vertices(&g, Ranking::CoDegeneracy);
        for u in 0..4 {
            assert!(rank[u] < 4, "max-degree U vertex must be peeled first");
        }
    }

    #[test]
    fn work_efficient_orderings_process_at_most_side_wedges_on_skewed() {
        // On power-law graphs degree-style orderings must save wedges.
        let g = gen::chung_lu(500, 800, 8000, 2.1, 11);
        let ws = preprocess(&g, Ranking::Side).wedges_processed();
        for r in [Ranking::Degree, Ranking::CoDegeneracy, Ranking::ApproxCoDegeneracy] {
            let wr = preprocess(&g, r).wedges_processed();
            assert!(
                wr <= ws,
                "{:?}: {} > side {}",
                r,
                wr,
                ws
            );
        }
    }

    #[test]
    fn f_metric_signs() {
        let g = gen::chung_lu(500, 800, 8000, 2.1, 11);
        assert_eq!(f_metric(&g, Ranking::Side), 0.0);
        assert!(f_metric(&g, Ranking::Degree) > 0.0);
    }

    #[test]
    fn choose_ranking_prefers_side_on_regular() {
        // Near-regular bipartite graph: degree ordering saves nothing.
        let g = gen::erdos_renyi(300, 300, 3000, 5);
        assert_eq!(choose_ranking(&g), Ranking::Side);
        // Heavily skewed: degree-style ordering should be chosen.
        let g2 = gen::chung_lu(500, 800, 8000, 2.05, 3);
        assert_eq!(choose_ranking(&g2), Ranking::ApproxDegree);
    }
}
