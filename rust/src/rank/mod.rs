//! Vertex rankings (§3.1.1, §4.5, §4.6).
//!
//! A ranking maps every vertex (global id: U-side `0..nu`, V-side
//! `nu..n`) to a rank in `0..n`; GET-WEDGES only retrieves wedges whose
//! center and second endpoint out-rank the first endpoint, so the
//! ranking controls how many wedges are processed.
//!
//! * [`Ranking::Side`] — one bipartition ordered first (Sanei-Mehri et
//!   al.); the side is chosen so that wedge *centers* fall on the side
//!   with fewer `C(deg, 2)` wedges.
//! * [`Ranking::Degree`] — decreasing degree (Chiba–Nishizeki); gives
//!   the `O(alpha m)` work bound.
//! * [`Ranking::ApproxDegree`] — decreasing `floor(log2 deg)`, ties by
//!   vertex id to preserve input locality (Theorem 4.11: same bound).
//! * [`Ranking::CoDegeneracy`] — repeatedly remove *max*-degree
//!   vertices (complement of the k-core peeling order; Theorem 4.12).
//! * [`Ranking::ApproxCoDegeneracy`] — same with log-degree buckets
//!   (fewer rounds; Theorem 4.13).
//!
//! [`f_metric`] computes the Table 3 quantity `f = (w_s - w_r) / w_s`;
//! [`choose_ranking`] applies the paper's rule of thumb (side ordering
//! unless some ranking saves >= 10% of wedges).
//!
//! ## Bucket-parallel co-degeneracy
//!
//! The co-degeneracy orderings are computed in **rounds of max-degree
//! peeling** over the shared bucket machinery
//! ([`MaxBuckets`](crate::prims::bucket::MaxBuckets), the same lazy
//! bucketing family the peel loops drive): every round claims the
//! whole current-maximum frontier at once, expands its neighborhoods
//! in parallel (offsets by [`prefix_sum`], one scatter pass), and
//! aggregates the degree decrements with the parallel [`histogram`]
//! primitive — `O(m)` total update work across all rounds, with no
//! vertex-at-a-time peel loop anywhere.  Within a round, ranks are
//! assigned in increasing vertex id (the canonical tie-break), which
//! makes the permutation identical at every thread count.

use std::time::Instant;

use crate::graph::{BipartiteGraph, RankedGraph};
use crate::prims::bucket::MaxBuckets;
use crate::prims::histogram::histogram;
use crate::prims::pool::{parallel_for_chunks, parallel_map, SyncPtr};
use crate::prims::scan::prefix_sum;
use crate::prims::sort::par_sort;

/// The five vertex orderings of the ParButterfly framework.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Ranking {
    Side,
    Degree,
    ApproxDegree,
    CoDegeneracy,
    ApproxCoDegeneracy,
}

impl Ranking {
    pub const ALL: [Ranking; 5] = [
        Ranking::Side,
        Ranking::Degree,
        Ranking::ApproxDegree,
        Ranking::CoDegeneracy,
        Ranking::ApproxCoDegeneracy,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Ranking::Side => "side",
            Ranking::Degree => "degree",
            Ranking::ApproxDegree => "adegree",
            Ranking::CoDegeneracy => "codeg",
            Ranking::ApproxCoDegeneracy => "acodeg",
        }
    }

    pub fn parse(s: &str) -> Option<Ranking> {
        Ranking::ALL.into_iter().find(|r| r.name() == s)
    }
}

fn degree_of(g: &BipartiteGraph, gid: usize) -> usize {
    if gid < g.nu() {
        g.deg_u(gid)
    } else {
        g.deg_v(gid - g.nu())
    }
}

/// Compute `rank_of[global id] -> rank` for the chosen ordering.
pub fn rank_vertices(g: &BipartiteGraph, ranking: Ranking) -> Vec<u32> {
    let n = g.n();
    match ranking {
        Ranking::Side => {
            // Endpoints on the first side, centers on the second; put
            // the side whose *opposite* has fewer wedges first.
            let u_first = g.wedges_centered_v() <= g.wedges_centered_u();
            let mut rank = vec![0u32; n];
            if u_first {
                for gid in 0..n {
                    rank[gid] = gid as u32; // U already 0..nu
                }
            } else {
                let (nu, nv) = (g.nu(), g.nv());
                for v in 0..nv {
                    rank[nu + v] = v as u32;
                }
                for u in 0..nu {
                    rank[u] = (nv + u) as u32;
                }
            }
            rank
        }
        Ranking::Degree => by_key_desc(g, |g, gid| degree_of(g, gid) as u64),
        Ranking::ApproxDegree => {
            by_key_desc(g, |g, gid| 64 - (degree_of(g, gid) as u64 + 1).leading_zeros() as u64)
        }
        Ranking::CoDegeneracy => co_degeneracy(g, false),
        Ranking::ApproxCoDegeneracy => co_degeneracy(g, true),
    }
}

/// Rank by decreasing key, ties broken by increasing vertex id (keeps
/// input locality, which is why approximate degree order wins in
/// practice on well-laid-out graphs).
fn by_key_desc(g: &BipartiteGraph, key: impl Fn(&BipartiteGraph, usize) -> u64) -> Vec<u32> {
    let n = g.n();
    // Pack (key, id) so one u64 sort orders by key desc then id asc.
    // key <= n < 2^32 always (degree bound), id < 2^32.
    let mut packed: Vec<u64> = (0..n)
        .map(|gid| ((u32::MAX as u64 - key(g, gid)) << 32) | gid as u64)
        .collect();
    par_sort(&mut packed);
    let mut rank = vec![0u32; n];
    for (r, &p) in packed.iter().enumerate() {
        rank[(p & 0xffff_ffff) as usize] = r as u32;
    }
    rank
}

/// The (log-)degree bucket key of the co-degeneracy orderings.
#[inline]
pub(crate) fn codeg_bucket_of(d: u64, approx: bool) -> u64 {
    if approx {
        if d == 0 {
            0
        } else {
            64 - d.leading_zeros() as u64
        }
    } else {
        d
    }
}

/// Complement (co-)degeneracy: repeatedly peel **all** vertices of
/// maximum (log-)degree from the remaining graph; rank in removal
/// order, increasing vertex id within a round.
///
/// Bucket-parallel rounds over the shared [`MaxBuckets`] walk: each
/// round claims the whole max-bucket frontier, expands every frontier
/// neighborhood in one parallel scatter (scan offsets), aggregates the
/// per-neighbor decrements with the parallel [`histogram`], and
/// applies one lazy bucket update per touched vertex.  Total update
/// work is `O(m)` over the full drain; there is no per-vertex peel
/// loop.  Returns `rank_of`.
fn co_degeneracy(g: &BipartiteGraph, approx: bool) -> Vec<u32> {
    let n = g.n();
    let nu = g.nu();
    let mut deg: Vec<u64> = parallel_map(n, |gid| degree_of(g, gid) as u64);
    let keys: Vec<u64> = parallel_map(n, |gid| codeg_bucket_of(deg[gid], approx));
    let mut mb = MaxBuckets::new(&keys);
    let mut rank = vec![0u32; n];
    let mut next_rank = 0u32;
    while let Some((_key, mut frontier)) = mb.pop_max() {
        // Canonical intra-round order: increasing vertex id.  This is
        // what makes the ordering thread-count invariant (the lazy
        // bucket vec interleaves initial entries and re-pushes).
        par_sort(&mut frontier);
        {
            let rp = SyncPtr(rank.as_mut_ptr());
            let frontier = &frontier;
            let base = next_rank;
            parallel_for_chunks(frontier.len(), |r| {
                for i in r {
                    // SAFETY: frontier ids are distinct, one writer each.
                    unsafe { *rp.get().add(frontier[i] as usize) = base + i as u32 };
                }
            });
        }
        next_rank += frontier.len() as u32;
        // Expand the frontier's neighborhoods into a flat key array
        // (global vertex ids), scan offsets + parallel scatter.
        let sizes: Vec<usize> =
            parallel_map(frontier.len(), |i| degree_of(g, frontier[i] as usize));
        let (offs, total) = prefix_sum(&sizes);
        let mut touched = vec![0u64; total];
        {
            let tp = SyncPtr(touched.as_mut_ptr());
            let (frontier, offs) = (&frontier, &offs);
            parallel_for_chunks(frontier.len(), |r| {
                for i in r {
                    let gid = frontier[i] as usize;
                    let base = offs[i];
                    if gid < nu {
                        for (j, &v) in g.nbrs_u(gid).iter().enumerate() {
                            // SAFETY: rows [offs[i], offs[i]+deg) are disjoint.
                            unsafe { *tp.get().add(base + j) = (nu + v as usize) as u64 };
                        }
                    } else {
                        for (j, &u) in g.nbrs_v(gid - nu).iter().enumerate() {
                            unsafe { *tp.get().add(base + j) = u as u64 };
                        }
                    }
                }
            });
        }
        // Aggregate decrements per neighbor and apply one lazy bucket
        // update each.  Claimed (finalized) vertices — including the
        // frontier itself — ignore updates, matching the sequential
        // "skip removed neighbors" rule.
        for (wg, cnt) in histogram(&touched) {
            let idx = wg as usize;
            if mb.is_finalized(wg as u32) {
                continue;
            }
            deg[idx] = deg[idx].saturating_sub(cnt);
            mb.update(wg as u32, codeg_bucket_of(deg[idx], approx));
        }
    }
    debug_assert_eq!(next_rank as usize, n);
    rank
}

/// Wall-clock breakdown of the pre-counting pipeline stages measured
/// by [`preprocess_timed`] (the parse / CSR stages happen at load time
/// and are reported by the CLI / the `preprocess_pipeline` bench).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PreprocessTiming {
    /// [`rank_vertices`]: computing the rank permutation.
    pub rank_ms: f64,
    /// [`RankedGraph::new`]: rename + CSR + per-vertex sorts
    /// (Algorithm 1 proper).
    pub build_ms: f64,
}

impl PreprocessTiming {
    /// Total preprocessing time covered by this breakdown.
    pub fn total_ms(&self) -> f64 {
        self.rank_ms + self.build_ms
    }
}

/// Preprocess (Algorithm 1) under the chosen ordering.
pub fn preprocess(g: &BipartiteGraph, ranking: Ranking) -> RankedGraph {
    preprocess_timed(g, ranking).0
}

/// [`preprocess`] with a per-stage timing breakdown.
pub fn preprocess_timed(g: &BipartiteGraph, ranking: Ranking) -> (RankedGraph, PreprocessTiming) {
    let t0 = Instant::now();
    let rank_of = rank_vertices(g, ranking);
    let rank_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let rg = RankedGraph::new(g, rank_of);
    let build_ms = t1.elapsed().as_secs_f64() * 1e3;
    (rg, PreprocessTiming { rank_ms, build_ms })
}

/// The Table 3 metric `f = (w_s - w_r) / w_s` where `w_s` / `w_r` are
/// the wedges processed under side ordering / under `ranking`.
pub fn f_metric(g: &BipartiteGraph, ranking: Ranking) -> f64 {
    let ws = preprocess(g, Ranking::Side).wedges_processed();
    let wr = preprocess(g, ranking).wedges_processed();
    if ws == 0 {
        return 0.0;
    }
    (ws as f64 - wr as f64) / ws as f64
}

/// Runtime ordering selection (§6.2.2): side ordering unless another
/// ranking saves at least 10% of the wedges (f >= 0.1); approximate
/// degree is the cheap representative of the degree-style orderings.
pub fn choose_ranking(g: &BipartiteGraph) -> Ranking {
    if f_metric(g, Ranking::ApproxDegree) >= 0.1 {
        Ranking::ApproxDegree
    } else {
        Ranking::Side
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn is_permutation(rank: &[u32]) -> bool {
        let mut seen = vec![false; rank.len()];
        for &r in rank {
            if seen[r as usize] {
                return false;
            }
            seen[r as usize] = true;
        }
        true
    }

    #[test]
    fn all_rankings_are_permutations() {
        let g = gen::chung_lu(200, 300, 2000, 2.2, 9);
        for r in Ranking::ALL {
            let rank = rank_vertices(&g, r);
            assert_eq!(rank.len(), g.n());
            assert!(is_permutation(&rank), "{:?}", r);
        }
    }

    #[test]
    fn side_order_puts_cheaper_centers_second() {
        // U degrees are huge -> wedges centered on U huge -> V should
        // be the center side is wrong; we want centers on the side
        // with FEWER wedges, i.e. V side first iff centers (U) cheap.
        let g = gen::complete_bipartite(3, 30); // wedges_u = 3*C(30..)? no:
        // deg_u = 30 each -> wedges centered U = 3*C(30,2)=1305;
        // deg_v = 3 each -> wedges centered V = 30*C(3,2)=90.
        let rank = rank_vertices(&g, Ranking::Side);
        // centers should be V (90 < 1305): endpoints = U side first.
        for u in 0..3 {
            assert!(rank[u] < 3, "U must be ranked first");
        }
    }

    #[test]
    fn degree_order_is_decreasing() {
        let g = gen::chung_lu(100, 150, 1500, 2.1, 4);
        let rank = rank_vertices(&g, Ranking::Degree);
        let mut by_rank = vec![0usize; g.n()];
        for gid in 0..g.n() {
            by_rank[rank[gid] as usize] = gid;
        }
        let deg = |gid: usize| {
            if gid < g.nu() {
                g.deg_u(gid)
            } else {
                g.deg_v(gid - g.nu())
            }
        };
        for w in by_rank.windows(2) {
            assert!(deg(w[0]) >= deg(w[1]));
        }
    }

    #[test]
    fn approx_degree_groups_by_log() {
        let g = gen::chung_lu(100, 150, 1500, 2.1, 4);
        let rank = rank_vertices(&g, Ranking::ApproxDegree);
        let mut by_rank = vec![0usize; g.n()];
        for gid in 0..g.n() {
            by_rank[rank[gid] as usize] = gid;
        }
        let logdeg = |gid: usize| {
            let d = if gid < g.nu() { g.deg_u(gid) } else { g.deg_v(gid - g.nu()) };
            64 - (d as u64 + 1).leading_zeros()
        };
        for w in by_rank.windows(2) {
            assert!(logdeg(w[0]) >= logdeg(w[1]));
        }
    }

    #[test]
    fn codegeneracy_first_round_is_max_degree() {
        let g = gen::complete_bipartite(4, 9);
        // U vertices have degree 9 (max) -> must get the first 4 ranks.
        let rank = rank_vertices(&g, Ranking::CoDegeneracy);
        for u in 0..4 {
            assert!(rank[u] < 4, "max-degree U vertex must be peeled first");
        }
    }

    #[test]
    fn codegeneracy_rounds_match_sequential_reference() {
        use crate::prims::pool::with_threads;
        use crate::testutil::rankref::co_degeneracy_seq;
        for (g, label) in [
            (gen::chung_lu(150, 220, 2500, 2.1, 13), "cl"),
            (gen::erdos_renyi(120, 120, 1200, 8), "er"),
            (gen::complete_bipartite(7, 11), "kb"),
        ] {
            for approx in [false, true] {
                let expect = co_degeneracy_seq(&g, approx);
                for t in [1usize, 4] {
                    let got = with_threads(t, || co_degeneracy(&g, approx));
                    assert_eq!(got, expect, "{label} approx={approx} t={t}");
                }
            }
        }
    }

    #[test]
    fn codegeneracy_is_thread_count_invariant() {
        use crate::prims::pool::with_threads;
        let g = gen::chung_lu(300, 400, 6000, 2.1, 19);
        for r in [Ranking::CoDegeneracy, Ranking::ApproxCoDegeneracy] {
            let base = with_threads(1, || rank_vertices(&g, r));
            for t in [2usize, 8] {
                assert_eq!(with_threads(t, || rank_vertices(&g, r)), base, "{r:?} t={t}");
            }
        }
    }

    #[test]
    fn preprocess_timed_breakdown_is_sane() {
        let g = gen::erdos_renyi(60, 70, 600, 3);
        let (rg, timing) = preprocess_timed(&g, Ranking::Degree);
        assert_eq!(rg.n(), g.n());
        assert!(timing.rank_ms >= 0.0 && timing.build_ms >= 0.0);
        assert!(timing.total_ms() >= timing.rank_ms.max(timing.build_ms));
        // Same graph as the untimed entry point.
        let rg2 = preprocess(&g, Ranking::Degree);
        for x in 0..rg.n() {
            assert_eq!(rg.nbrs(x), rg2.nbrs(x));
        }
    }

    #[test]
    fn work_efficient_orderings_process_at_most_side_wedges_on_skewed() {
        // On power-law graphs degree-style orderings must save wedges.
        let g = gen::chung_lu(500, 800, 8000, 2.1, 11);
        let ws = preprocess(&g, Ranking::Side).wedges_processed();
        for r in [Ranking::Degree, Ranking::CoDegeneracy, Ranking::ApproxCoDegeneracy] {
            let wr = preprocess(&g, r).wedges_processed();
            assert!(
                wr <= ws,
                "{:?}: {} > side {}",
                r,
                wr,
                ws
            );
        }
    }

    #[test]
    fn f_metric_signs() {
        let g = gen::chung_lu(500, 800, 8000, 2.1, 11);
        assert_eq!(f_metric(&g, Ranking::Side), 0.0);
        assert!(f_metric(&g, Ranking::Degree) > 0.0);
    }

    #[test]
    fn choose_ranking_prefers_side_on_regular() {
        // Near-regular bipartite graph: degree ordering saves nothing.
        let g = gen::erdos_renyi(300, 300, 3000, 5);
        assert_eq!(choose_ranking(&g), Ranking::Side);
        // Heavily skewed: degree-style ordering should be chosen.
        let g2 = gen::chung_lu(500, 800, 8000, 2.05, 3);
        assert_eq!(choose_ranking(&g2), Ranking::ApproxDegree);
    }
}
