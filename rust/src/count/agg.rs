//! Fully-parallel wedge aggregations: **Sort**, **Hash**, **Hist**
//! (§3.1.2), with atomic-add or re-aggregation butterfly combining
//! (§3.1.3), processed in memory-bounded chunks (§3.1.4).
//!
//! All three share the same skeleton per chunk of sources:
//!   1. obtain `(key, multiplicity d)` for every endpoint pair
//!      (Sort: sort materialized records + segment; Hash: additive
//!      phase-concurrent table; Hist: parallel histogram);
//!   2. endpoints of a key with `d` wedges gain `C(d, 2)` butterflies
//!      each (Lemma 4.2 Eq. 1);
//!   3. the center of every wedge gains `d - 1` (per-vertex mode), or
//!      both legs of every wedge gain `d - 1` (per-edge mode,
//!      Lemma 4.2 Eq. 2).
//!
//! Chunks split at source-vertex boundaries so a key's wedges never
//! straddle chunks (see `wedges.rs`), making the nonlinear `C(d, 2)`
//! safe under chunking.

use std::sync::atomic::AtomicU64;

use super::wedges::{self, key_endpoints, Wedge};
use super::{atomic_add, choose2, BflyAgg, CountOpts, WedgeAgg};
use crate::graph::RankedGraph;
use crate::prims::hashtable::CountTable;
use crate::prims::histogram::histogram;
use crate::prims::pool::{parallel_for_chunks, parallel_for_dynamic};
use crate::prims::sort::par_sort_by_key;

/// Iterate `(start, end)` of every equal-key segment of a sorted slice.
fn for_each_segment<T: Sync>(
    items: &[T],
    key: impl Fn(&T) -> u64 + Sync,
    f: impl Fn(usize, usize) + Sync,
) {
    let n = items.len();
    if n == 0 {
        return;
    }
    parallel_for_chunks(n, |r| {
        let mut i = r.start;
        // Skip a segment that started in the previous block.
        if i > 0 {
            while i < r.end && key(&items[i]) == key(&items[i - 1]) {
                i += 1;
            }
        }
        while i < r.end {
            let k = key(&items[i]);
            let mut j = i + 1;
            while j < n && key(&items[j]) == k {
                j += 1;
            }
            f(i, j);
            i = j;
        }
    });
}

/// Apply accumulated `(index, delta)` updates through the re-aggregation
/// path: sort by index, segment-sum, single-writer add.  This is the
/// §3.1.3 "reuse the aggregation method" option; all three methods
/// reduce to a keyed combine, realized here with the parallel sort.
fn reagg_apply(mut deltas: Vec<(u32, u64)>, out: &[AtomicU64]) {
    par_sort_by_key(&mut deltas, |d| d.0);
    for_each_segment(&deltas, |d| d.0 as u64, |s, e| {
        let sum: u64 = deltas[s..e].iter().map(|d| d.1).sum();
        // Single writer per index — a plain store would race across
        // chunks, so keep the atomic add (uncontended here).
        atomic_add(&out[deltas[s].0 as usize], sum);
    });
}

/// Thread-safe delta collector for the re-aggregation path.
struct DeltaSink {
    inner: std::sync::Mutex<Vec<(u32, u64)>>,
}

impl DeltaSink {
    fn new() -> Self {
        Self { inner: std::sync::Mutex::new(Vec::new()) }
    }
    fn push_batch(&self, batch: Vec<(u32, u64)>) {
        if !batch.is_empty() {
            self.inner.lock().unwrap().extend(batch);
        }
    }
    fn into_vec(self) -> Vec<(u32, u64)> {
        self.inner.into_inner().unwrap()
    }
}

// ---------------------------------------------------------------------------
// total
// ---------------------------------------------------------------------------

/// Global count via Sort/Hash/Hist.
pub fn total_agg(rg: &RankedGraph, opts: &CountOpts) -> u64 {
    let counts = wedges::source_wedge_counts(rg, opts.cache_opt);
    let mut total = 0u64;
    for chunk in wedges::chunk_sources(&counts, opts.max_wedges) {
        total += match opts.agg {
            WedgeAgg::Sort => {
                let mut recs = wedges::materialize(rg, opts.cache_opt, chunk, &counts);
                par_sort_by_key(&mut recs, |w| w.key());
                let acc = AtomicU64::new(0);
                for_each_segment(&recs, |w| w.key(), |s, e| {
                    atomic_add(&acc, choose2((e - s) as u64));
                });
                acc.into_inner()
            }
            WedgeAgg::Hash => {
                let nw: usize = counts[chunk.clone()].iter().sum();
                let table = CountTable::with_capacity(nw.max(1));
                wedges::for_each_wedge(rg, opts.cache_opt, chunk, |w| {
                    table.insert_add(w.key(), 1)
                });
                let acc = AtomicU64::new(0);
                table.for_each(|_, d| atomic_add(&acc, choose2(d)));
                acc.into_inner()
            }
            WedgeAgg::Hist => {
                let recs = wedges::materialize(rg, opts.cache_opt, chunk, &counts);
                let keys: Vec<u64> = recs.iter().map(|w| w.key()).collect();
                histogram(&keys).into_iter().map(|(_, d)| choose2(d)).sum()
            }
            _ => unreachable!("batch handled elsewhere"),
        };
    }
    total
}

// ---------------------------------------------------------------------------
// per vertex
// ---------------------------------------------------------------------------

/// COUNT-V via Sort/Hash/Hist into a rank-indexed atomic array.
pub fn per_vertex_agg(rg: &RankedGraph, opts: &CountOpts, out: &[AtomicU64]) {
    let counts = wedges::source_wedge_counts(rg, opts.cache_opt);
    for chunk in wedges::chunk_sources(&counts, opts.max_wedges) {
        match opts.agg {
            WedgeAgg::Sort => per_vertex_sort(rg, opts, out, chunk, &counts),
            WedgeAgg::Hash | WedgeAgg::Hist => per_vertex_table(rg, opts, out, chunk, &counts),
            _ => unreachable!(),
        }
    }
}

fn per_vertex_sort(
    rg: &RankedGraph,
    opts: &CountOpts,
    out: &[AtomicU64],
    chunk: std::ops::Range<usize>,
    counts: &[usize],
) {
    let mut recs = wedges::materialize(rg, opts.cache_opt, chunk, counts);
    par_sort_by_key(&mut recs, |w| w.key());
    match opts.bfly {
        BflyAgg::Atomic => {
            for_each_segment(&recs, |w| w.key(), |s, e| {
                let d = (e - s) as u64;
                let (x1, x2) = key_endpoints(recs[s].key());
                atomic_add(&out[x1 as usize], choose2(d));
                atomic_add(&out[x2 as usize], choose2(d));
                for w in &recs[s..e] {
                    atomic_add(&out[w.center as usize], d - 1);
                }
            });
        }
        BflyAgg::Reagg => {
            let sink = DeltaSink::new();
            for_each_segment(&recs, |w| w.key(), |s, e| {
                let d = (e - s) as u64;
                let (x1, x2) = key_endpoints(recs[s].key());
                let mut local = Vec::with_capacity(e - s + 2);
                local.push((x1, choose2(d)));
                local.push((x2, choose2(d)));
                if d > 1 {
                    for w in &recs[s..e] {
                        local.push((w.center, d - 1));
                    }
                }
                sink.push_batch(local);
            });
            reagg_apply(sink.into_vec(), out);
        }
    }
}

/// Hash & Hist share the two-pass structure: build a key->d lookup,
/// credit endpoints from the aggregate, credit centers in a second
/// wedge sweep (GET-WEDGES-FUNC(f) on line 8 of Algorithm 3).
fn per_vertex_table(
    rg: &RankedGraph,
    opts: &CountOpts,
    out: &[AtomicU64],
    chunk: std::ops::Range<usize>,
    counts: &[usize],
) {
    let nw: usize = counts[chunk.clone()].iter().sum();
    let table = CountTable::with_capacity(nw.max(1));
    if opts.agg == WedgeAgg::Hash {
        wedges::for_each_wedge(rg, opts.cache_opt, chunk.clone(), |w| {
            table.insert_add(w.key(), 1)
        });
    } else {
        // Hist: parallel histogram first, then load the lookup table.
        let recs = wedges::materialize(rg, opts.cache_opt, chunk.clone(), counts);
        let keys: Vec<u64> = recs.iter().map(|w| w.key()).collect();
        let h = histogram(&keys);
        parallel_for_dynamic(h.len(), 256, |r| {
            for &(k, d) in &h[r] {
                table.insert_add(k, d);
            }
        });
    }
    match opts.bfly {
        BflyAgg::Atomic => {
            table.for_each(|k, d| {
                let (x1, x2) = key_endpoints(k);
                atomic_add(&out[x1 as usize], choose2(d));
                atomic_add(&out[x2 as usize], choose2(d));
            });
            wedges::for_each_wedge(rg, opts.cache_opt, chunk, |w| {
                let d = table.get(w.key());
                atomic_add(&out[w.center as usize], d - 1);
            });
        }
        BflyAgg::Reagg => {
            // Re-aggregate through a vertex-keyed additive table.
            let vt = CountTable::with_capacity(rg.n());
            table.for_each(|k, d| {
                if d > 1 {
                    let (x1, x2) = key_endpoints(k);
                    vt.insert_add(x1 as u64, choose2(d));
                    vt.insert_add(x2 as u64, choose2(d));
                }
            });
            wedges::for_each_wedge(rg, opts.cache_opt, chunk, |w| {
                let d = table.get(w.key());
                if d > 1 {
                    vt.insert_add(w.center as u64, d - 1);
                }
            });
            vt.for_each(|v, delta| atomic_add(&out[v as usize], delta));
        }
    }
}

// ---------------------------------------------------------------------------
// per edge
// ---------------------------------------------------------------------------

/// COUNT-E via Sort/Hash/Hist into an edge-id-indexed atomic array.
pub fn per_edge_agg(rg: &RankedGraph, opts: &CountOpts, out: &[AtomicU64]) {
    let counts = wedges::source_wedge_counts(rg, opts.cache_opt);
    for chunk in wedges::chunk_sources(&counts, opts.max_wedges) {
        match opts.agg {
            WedgeAgg::Sort => per_edge_sort(rg, opts, out, chunk, &counts),
            WedgeAgg::Hash | WedgeAgg::Hist => per_edge_table(rg, opts, out, chunk, &counts),
            _ => unreachable!(),
        }
    }
}

fn per_edge_sort(
    rg: &RankedGraph,
    opts: &CountOpts,
    out: &[AtomicU64],
    chunk: std::ops::Range<usize>,
    counts: &[usize],
) {
    let mut recs = wedges::materialize(rg, opts.cache_opt, chunk, counts);
    par_sort_by_key(&mut recs, |w| w.key());
    match opts.bfly {
        BflyAgg::Atomic => {
            for_each_segment(&recs, |w| w.key(), |s, e| {
                let d = (e - s) as u64;
                if d > 1 {
                    for w in &recs[s..e] {
                        atomic_add(&out[w.e_lo as usize], d - 1);
                        atomic_add(&out[w.e_hi as usize], d - 1);
                    }
                }
            });
        }
        BflyAgg::Reagg => {
            let sink = DeltaSink::new();
            for_each_segment(&recs, |w| w.key(), |s, e| {
                let d = (e - s) as u64;
                if d > 1 {
                    let mut local = Vec::with_capacity(2 * (e - s));
                    for w in &recs[s..e] {
                        local.push((w.e_lo, d - 1));
                        local.push((w.e_hi, d - 1));
                    }
                    sink.push_batch(local);
                }
            });
            reagg_apply(sink.into_vec(), out);
        }
    }
}

fn per_edge_table(
    rg: &RankedGraph,
    opts: &CountOpts,
    out: &[AtomicU64],
    chunk: std::ops::Range<usize>,
    counts: &[usize],
) {
    let nw: usize = counts[chunk.clone()].iter().sum();
    let table = CountTable::with_capacity(nw.max(1));
    if opts.agg == WedgeAgg::Hash {
        wedges::for_each_wedge(rg, opts.cache_opt, chunk.clone(), |w| {
            table.insert_add(w.key(), 1)
        });
    } else {
        let recs = wedges::materialize(rg, opts.cache_opt, chunk.clone(), counts);
        let keys: Vec<u64> = recs.iter().map(|w| w.key()).collect();
        let h = histogram(&keys);
        parallel_for_dynamic(h.len(), 256, |r| {
            for &(k, d) in &h[r] {
                table.insert_add(k, d);
            }
        });
    }
    let credit = |w: &Wedge, sink: Option<&CountTable>| {
        let d = table.get(w.key());
        if d > 1 {
            match sink {
                None => {
                    atomic_add(&out[w.e_lo as usize], d - 1);
                    atomic_add(&out[w.e_hi as usize], d - 1);
                }
                Some(et) => {
                    et.insert_add(w.e_lo as u64, d - 1);
                    et.insert_add(w.e_hi as u64, d - 1);
                }
            }
        }
    };
    match opts.bfly {
        BflyAgg::Atomic => {
            wedges::for_each_wedge(rg, opts.cache_opt, chunk, |w| credit(&w, None));
        }
        BflyAgg::Reagg => {
            let et = CountTable::with_capacity(2 * rg.m());
            wedges::for_each_wedge(rg, opts.cache_opt, chunk, |w| credit(&w, Some(&et)));
            et.for_each(|e, delta| atomic_add(&out[e as usize], delta));
        }
    }
}
