//! Approximate counting by graph sparsification (§4.4, after
//! Sanei-Mehri et al.).
//!
//! * **Edge sparsification**: keep each edge independently with
//!   probability `p`; every butterfly survives with probability `p^4`,
//!   so `count(sparse) / p^4` is an unbiased estimate.
//! * **Colorful sparsification**: color each vertex uniformly from
//!   `1/p` colors; keep monochromatic edges.  A butterfly survives iff
//!   its 4 vertices share a color, probability `p^3`, giving
//!   `count(sparse) / p^3`.
//!
//! Both run as a parallel filter over the adjacency and feed the exact
//! counting framework with any aggregation/ranking (total counts only).

use crate::graph::BipartiteGraph;
use crate::prims::rng::hash64;

use super::{count_total_raw, CountOpts};
use crate::error::{guard, Result};

/// Keep each edge with probability `p` (deterministic in `seed`).
pub fn edge_sparsify(g: &BipartiteGraph, p: f64, seed: u64) -> BipartiteGraph {
    assert!((0.0..=1.0).contains(&p));
    let threshold = (p * u64::MAX as f64) as u64;
    let mut edges = Vec::new();
    for (eid, (u, v)) in g.edges().into_iter().enumerate() {
        if hash64(eid as u64 ^ seed.rotate_left(17)) <= threshold {
            edges.push((u, v));
        }
    }
    BipartiteGraph::from_edges(g.nu(), g.nv(), &edges)
}

/// Keep edges whose endpoints hash to the same of `ncolors` colors.
pub fn colorful_sparsify(g: &BipartiteGraph, ncolors: u64, seed: u64) -> BipartiteGraph {
    assert!(ncolors >= 1);
    let color = |gid: u64| hash64(gid ^ seed.rotate_left(29)) % ncolors;
    let nu = g.nu() as u64;
    let mut edges = Vec::new();
    for (u, v) in g.edges() {
        if color(u as u64) == color(nu + v as u64) {
            edges.push((u, v));
        }
    }
    BipartiteGraph::from_edges(g.nu(), g.nv(), &edges)
}

/// Unbiased total-count estimate via edge sparsification.
///
/// Runs under [`CountOpts::budget`] (sparsification included); see
/// [`count_total`](super::count_total) for the error contract.
pub fn approx_total_edge(g: &BipartiteGraph, p: f64, seed: u64, opts: &CountOpts) -> Result<f64> {
    guard(&opts.budget, || {
        let sparse = edge_sparsify(g, p, seed);
        count_total_raw(&sparse, opts) as f64 / p.powi(4)
    })
}

/// Unbiased total-count estimate via colorful sparsification with
/// `ncolors` colors (`p = 1 / ncolors`).
pub fn approx_total_colorful(
    g: &BipartiteGraph,
    ncolors: u64,
    seed: u64,
    opts: &CountOpts,
) -> Result<f64> {
    guard(&opts.budget, || {
        let sparse = colorful_sparsify(g, ncolors, seed);
        let p = 1.0 / ncolors as f64;
        count_total_raw(&sparse, opts) as f64 / p.powi(3)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::count_total;
    use crate::graph::gen;

    #[test]
    fn p_one_is_exact() {
        let g = gen::erdos_renyi(40, 50, 400, 3);
        let exact = count_total(&g, &CountOpts::default()).unwrap() as f64;
        assert_eq!(approx_total_edge(&g, 1.0, 7, &CountOpts::default()).unwrap(), exact);
        assert_eq!(approx_total_colorful(&g, 1, 7, &CountOpts::default()).unwrap(), exact);
    }

    #[test]
    fn edge_sparsify_keeps_about_pm_edges() {
        let g = gen::erdos_renyi(200, 200, 8000, 5);
        let s = edge_sparsify(&g, 0.5, 11);
        let frac = s.m() as f64 / g.m() as f64;
        assert!((0.45..0.55).contains(&frac), "frac={frac}");
    }

    #[test]
    fn colorful_keeps_monochromatic_edges_only() {
        let g = gen::erdos_renyi(100, 100, 2000, 6);
        let c = 4u64;
        let s = colorful_sparsify(&g, c, 13);
        // Expected keep fraction ~ 1/c.
        let frac = s.m() as f64 / g.m() as f64;
        assert!((0.15..0.35).contains(&frac), "frac={frac}");
    }

    #[test]
    fn estimates_are_near_truth_when_averaged() {
        // Averaging over seeds shrinks variance; unbiasedness shows as
        // the mean landing near the exact count.
        let g = gen::chung_lu(150, 200, 4000, 2.2, 9);
        let exact = count_total(&g, &CountOpts::default()).unwrap() as f64;
        assert!(exact > 100.0, "workload too sparse: {exact}");
        let trials = 40;
        let mean_edge: f64 = (0..trials)
            .map(|s| approx_total_edge(&g, 0.6, s, &CountOpts::default()).unwrap())
            .sum::<f64>()
            / trials as f64;
        let rel = (mean_edge - exact).abs() / exact;
        assert!(rel < 0.35, "edge estimate rel err {rel}");
        let mean_col: f64 = (0..trials)
            .map(|s| approx_total_colorful(&g, 2, s, &CountOpts::default()).unwrap())
            .sum::<f64>()
            / trials as f64;
        let rel = (mean_col - exact).abs() / exact;
        assert!(rel < 0.35, "colorful estimate rel err {rel}");
    }
}
