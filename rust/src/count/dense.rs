//! Dense-core accelerator: butterfly counting for dense blocks through
//! a [`DenseBackend`] — the pure-Rust tiled reference kernel by
//! default, the AOT-compiled Layer-1/2 artifacts under the `pjrt`
//! feature (see ARCHITECTURE.md §Module map).
//!
//! Use cases:
//! * counting whole small-but-dense graphs (fits a backend tile);
//! * the **hybrid** path: extract the dense core (the top-degree
//!   vertices that degree ordering fronts), count core-internal
//!   butterflies on the dense kernel, and count the remaining
//!   wedge work on the sparse CPU path.
//!
//! For the hybrid split, butterflies are partitioned by *how many of
//! their two U-side (and two V-side) vertices are in the core*;
//! counting the core-induced subgraph densely and the complement of the
//! core-internal butterflies sparsely requires inclusion–exclusion:
//!   total(G) = total_sparse(G \ core-internal-edges ∪ ...)
//! which does not decompose cleanly edge-wise.  We therefore use the
//! paper-faithful decomposition instead: count on the full graph with
//! the sparse path but *skip pairs entirely inside the core*, and add
//! the dense core count.  A pair (x1, x2) is "inside the core" iff both
//! endpoints and all centers... — centers matter too, so the clean cut
//! is on **edges**: the dense engine counts the subgraph induced by the
//! core's edges, the sparse engine counts butterflies that use at least
//! one non-core vertex, on the graph with core-only butterflies
//! excluded by removing no edges but filtering counted pairs.  That
//! filtering is exact for butterflies (4 vertices: all-in-core or not),
//! implemented in [`count_total_hybrid`].

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use crate::graph::BipartiteGraph;
use crate::runtime::DenseBackend;

use super::{choose2, wedges, CountOpts};
use crate::rank::preprocess;

/// Counts from the dense path, mapped back to graph ids.
pub struct DenseCounts {
    pub total: u64,
    pub bu: Vec<u64>,
    pub bv: Vec<u64>,
    /// Per-edge counts aligned with the graph's edge ids.
    pub be: Vec<u64>,
}

/// Count a whole graph on the dense backend (must fit a supported
/// tile shape after padding).
pub fn count_dense(g: &BipartiteGraph, backend: &dyn DenseBackend) -> Result<DenseCounts> {
    let (pu, pv) = backend
        .plan(g.nu(), g.nv())
        .ok_or_else(|| anyhow::anyhow!("no dense tile fits {}x{}", g.nu(), g.nv()))?;
    let a = g.to_dense_f32(pu, pv);
    let out = backend.count_dense(pu, pv, &a)?;
    let total = out.total.round() as u64;
    let bu: Vec<u64> = out.bu[..g.nu()].iter().map(|&x| x.round() as u64).collect();
    let bv: Vec<u64> = out.bv[..g.nv()].iter().map(|&x| x.round() as u64).collect();
    let mut be = vec![0u64; g.m()];
    for u in 0..g.nu() {
        for (i, &v) in g.nbrs_u(u).iter().enumerate() {
            let eid = g.eid_u(u, i) as usize;
            be[eid] = out.be[u * pv + v as usize].round() as u64;
        }
    }
    Ok(DenseCounts { total, bu, bv, be })
}

/// Total count on the dense backend only.
pub fn count_total_dense(g: &BipartiteGraph, backend: &dyn DenseBackend) -> Result<u64> {
    let (pu, pv) = backend
        .plan(g.nu(), g.nv())
        .ok_or_else(|| anyhow::anyhow!("no dense tile fits {}x{}", g.nu(), g.nv()))?;
    let a = g.to_dense_f32(pu, pv);
    Ok(backend.count_total(pu, pv, &a)?.round() as u64)
}

/// Hybrid dense/sparse total count.
///
/// The core is the top `core_u x core_v` vertices by degree.  The dense
/// backend counts butterflies entirely inside the core; the sparse path
/// counts every remaining butterfly by enumerating all wedges but
/// splitting each endpoint-pair's multiplicity `d` into core-internal
/// centers `dc` vs rest: pairs fully in the core contribute
/// `C(d,2) - C(dc,2)` (their all-core butterflies are the dense
/// engine's), every other pair contributes `C(d,2)`.
pub fn count_total_hybrid(
    g: &BipartiteGraph,
    backend: &dyn DenseBackend,
    core_u: usize,
    core_v: usize,
    opts: &CountOpts,
) -> Result<u64> {
    let core_u = core_u.min(g.nu());
    let core_v = core_v.min(g.nv());
    // Core membership: top-degree vertices per side.
    let top = |n: usize, k: usize, deg: &dyn Fn(usize) -> usize| -> Vec<bool> {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(deg(i)));
        let mut keep = vec![false; n];
        for &i in idx.iter().take(k) {
            keep[i] = true;
        }
        keep
    };
    let in_core_u = top(g.nu(), core_u, &|u| g.deg_u(u));
    let in_core_v = top(g.nv(), core_v, &|v| g.deg_v(v));

    // Dense side: the induced core subgraph.
    let core = g.induced(&in_core_u, &in_core_v);
    let dense_total = count_total_dense(&core, backend)?;

    // Sparse side: full wedge enumeration with all-core butterflies
    // excluded pair-by-pair.
    let rg = preprocess(g, opts.ranking);
    let nu = g.nu();
    let in_core = |rank: u32| -> bool {
        let gid = rg.orig(rank as usize) as usize;
        if gid < nu {
            in_core_u[gid]
        } else {
            in_core_v[gid - nu]
        }
    };
    // Aggregate per pair: total multiplicity d and core-center
    // multiplicity dc; contribution = C(d,2) minus (C(dc,2) if the pair
    // itself is all-core).
    let table = crate::prims::hashtable::CountTable::with_capacity(
        rg.wedges_processed().max(4) as usize,
    );
    wedges::for_each_wedge(&rg, opts.cache_opt, 0..rg.n(), |w| {
        // Pack (d, dc) in one counter: low 32 bits d, high 32 bits dc.
        let core_center = in_core(w.center) && in_core(w.lo) && in_core(w.hi);
        table.insert_add(w.key(), if core_center { (1 << 32) | 1 } else { 1 });
    });
    let acc = AtomicU64::new(0);
    table.for_each(|_k, packed| {
        let d = packed & 0xffff_ffff;
        let dc = packed >> 32;
        let contrib = choose2(d) - choose2(dc);
        if contrib > 0 {
            acc.fetch_add(contrib, Ordering::Relaxed);
        }
    });
    Ok(dense_total + acc.into_inner())
}

/// Convenience: does an artifact directory exist with a manifest?
pub fn artifacts_available() -> bool {
    let dir = std::env::var("PARBUTTERFLY_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    Path::new(&dir).join("manifest.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::count_total;
    use crate::graph::gen;
    use crate::runtime::RustDense;
    use crate::testutil::brute;

    #[test]
    fn dense_counts_match_brute_force() {
        let backend = RustDense::default();
        let g = gen::erdos_renyi(30, 40, 350, 11);
        let got = count_dense(&g, &backend).unwrap();
        assert_eq!(got.total, brute::total(&g));
        let (ebu, ebv) = brute::per_vertex(&g);
        assert_eq!(got.bu, ebu);
        assert_eq!(got.bv, ebv);
        assert_eq!(got.be, brute::per_edge(&g));
    }

    #[test]
    fn hybrid_split_is_exact() {
        let backend = RustDense::default();
        let g = gen::chung_lu(120, 150, 2200, 2.1, 3);
        let expect = count_total(&g, &CountOpts::default()).unwrap();
        for (cu, cv) in [(20, 20), (64, 64), (120, 150)] {
            let got =
                count_total_hybrid(&g, &backend, cu, cv, &CountOpts::default()).unwrap();
            assert_eq!(got, expect, "core {cu}x{cv}");
        }
    }

    #[test]
    fn oversized_graph_is_rejected() {
        let backend = RustDense::with_max_dim(16);
        let g = gen::erdos_renyi(40, 10, 80, 2);
        assert!(count_total_dense(&g, &backend).is_err());
    }
}
