//! Butterfly counting (§3.1, §4.2): global, per-vertex, and per-edge,
//! parameterized over the counting **engine**, wedge-aggregation
//! strategy, butterfly-aggregation mode, ranking, the cache
//! optimization, and a wedge-memory budget.
//!
//! Two engine families sit behind the [`engine::WedgeEngine`] trait
//! (selected by [`CountOpts::engine`]):
//!
//! * **`wedges`** (default) — the paper's retrieve → aggregate →
//!   combine pipeline.  GET-WEDGES produces wedge records and one of
//!   the five [`WedgeAgg`] strategies aggregates them; memory scales
//!   with the wedge count (chunked by [`CountOpts::max_wedges`]).
//! * **`intersect`** — the streaming per-source counter (BFC-VP++
//!   style, Wang et al.): dense-counter two-hop walks that never
//!   materialize a wedge; memory scales with `m + threads * n`, so
//!   graphs whose wedge sets dwarf RAM still count exactly.
//!
//! Modules:
//!
//! * [`engine`] — the [`engine::WedgeEngine`] trait, [`Engine`]
//!   selector, and both engine implementations' dispatch.
//! * [`wedges`] — GET-WEDGES (Algorithm 2) + cache-optimized variant.
//! * [`agg`] — the fully-parallel aggregations: Sort, Hash, Hist.
//! * [`batch`] — the partially-parallel batching aggregations: BatchS
//!   (simple, static chunking) and BatchWA (wedge-aware, dynamic).
//! * [`intersect`] — the zero-materialization streaming engine.
//! * [`sparsify`] — approximate counting via edge / colorful
//!   sparsification (§4.4).
//! * [`dense`] — the PJRT dense-core accelerator (Layer 1/2 artifacts).

pub mod agg;
pub mod batch;
pub mod dense;
pub mod engine;
pub mod intersect;
pub mod sparsify;
pub mod wedges;

use std::sync::atomic::{AtomicU64, Ordering};

pub use engine::{engine_for, Engine, WedgeEngine};

use crate::error::{guard, Result};
use crate::graph::{BipartiteGraph, Layout, RankedGraph};
use crate::prims::budget::{self, Budget};
use crate::rank::{preprocess, Ranking};

/// Wedge-aggregation strategy (§3.1.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WedgeAgg {
    Sort,
    Hash,
    Hist,
    BatchS,
    BatchWA,
}

impl WedgeAgg {
    pub const ALL: [WedgeAgg; 5] =
        [WedgeAgg::Sort, WedgeAgg::Hash, WedgeAgg::Hist, WedgeAgg::BatchS, WedgeAgg::BatchWA];

    pub fn name(&self) -> &'static str {
        match self {
            WedgeAgg::Sort => "sort",
            WedgeAgg::Hash => "hash",
            WedgeAgg::Hist => "hist",
            WedgeAgg::BatchS => "batchs",
            WedgeAgg::BatchWA => "batchwa",
        }
    }

    pub fn parse(s: &str) -> Option<WedgeAgg> {
        WedgeAgg::ALL.into_iter().find(|a| a.name() == s)
    }
}

/// Butterfly-aggregation mode (§3.1.3): atomic adds into the output
/// array, or re-aggregation through the wedge-aggregation machinery.
/// Batching supports only atomic adds (footnote 4 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BflyAgg {
    Atomic,
    Reagg,
}

/// Options for a counting run.
#[derive(Clone, Debug)]
pub struct CountOpts {
    pub ranking: Ranking,
    /// Counting engine; [`Engine::Wedges`] runs the aggregation
    /// selected by `agg`, [`Engine::Intersect`] streams and ignores
    /// `agg`/`bfly`/`cache_opt`/`max_wedges`.
    pub engine: Engine,
    pub agg: WedgeAgg,
    pub bfly: BflyAgg,
    /// Enumerate wedges from the higher-ranked endpoint (Wang et al.).
    pub cache_opt: bool,
    /// Memory layout of the intersect hot loops
    /// ([`Layout::Auto`]/`Flat`/`Hub`); only [`Engine::Intersect`]
    /// consults it.  Outputs are bit-identical across layouts.  The
    /// default comes from `PARBUTTERFLY_LAYOUT`.
    pub layout: Layout,
    /// Memory budget: maximum wedges materialized/aggregated at once
    /// (§3.1.4).  Chunks split at source-vertex boundaries, which keeps
    /// every wedge key inside one chunk.
    pub max_wedges: usize,
    /// Cooperative limits (deadline / memory cap / cancel token) for
    /// this call; unlimited by default.  Checked at task granularity by
    /// the pool — a trip surfaces as a structured `Err` from the entry
    /// point.
    pub budget: Budget,
}

impl Default for CountOpts {
    fn default() -> Self {
        Self {
            ranking: Ranking::Degree,
            engine: Engine::Wedges,
            agg: WedgeAgg::BatchS,
            bfly: BflyAgg::Atomic,
            cache_opt: false,
            layout: Layout::default_from_env(),
            max_wedges: 1 << 26,
            budget: Budget::default(),
        }
    }
}

/// Per-vertex butterfly counts in original id space.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VertexCounts {
    pub bu: Vec<u64>,
    pub bv: Vec<u64>,
}

/// `C(d, 2)` as u64.
#[inline]
pub(crate) fn choose2(d: u64) -> u64 {
    d * d.saturating_sub(1) / 2
}

/// Global butterfly count (COUNT framework, total mode).
///
/// Runs under [`CountOpts::budget`]; a worker panic, injected fault, or
/// budget trip returns a structured [`Err`](crate::Error) instead of
/// aborting.
pub fn count_total(g: &BipartiteGraph, opts: &CountOpts) -> Result<u64> {
    guard(&opts.budget, || count_total_raw(g, opts))
}

pub(crate) fn count_total_raw(g: &BipartiteGraph, opts: &CountOpts) -> u64 {
    let rg = preprocess(g, opts.ranking);
    count_total_ranked_raw(&rg, opts)
}

/// Total count on an already-preprocessed graph.
///
/// ```
/// use parbutterfly::count::{count_total_ranked, CountOpts};
/// use parbutterfly::graph::gen;
/// use parbutterfly::rank::{preprocess, Ranking};
///
/// let g = gen::complete_bipartite(3, 4);
/// let rg = preprocess(&g, Ranking::Degree);
/// // K_{3,4} holds C(3,2)·C(4,2) = 18 butterflies.
/// assert_eq!(count_total_ranked(&rg, &CountOpts::default()).unwrap(), 18);
/// ```
pub fn count_total_ranked(rg: &RankedGraph, opts: &CountOpts) -> Result<u64> {
    guard(&opts.budget, || count_total_ranked_raw(rg, opts))
}

pub(crate) fn count_total_ranked_raw(rg: &RankedGraph, opts: &CountOpts) -> u64 {
    engine_for(opts).total(rg)
}

/// Per-vertex butterfly counts (COUNT-V, Algorithm 3).
///
/// Runs under [`CountOpts::budget`]; see [`count_total`] for the error
/// contract.
pub fn count_per_vertex(g: &BipartiteGraph, opts: &CountOpts) -> Result<VertexCounts> {
    guard(&opts.budget, || count_per_vertex_raw(g, opts))
}

pub(crate) fn count_per_vertex_raw(g: &BipartiteGraph, opts: &CountOpts) -> VertexCounts {
    let rg = preprocess(g, opts.ranking);
    let counts = count_per_vertex_ranked_raw(&rg, opts);
    // Scatter rank-space counts back to original side-local ids.
    let nu = g.nu();
    let mut bu = vec![0u64; nu];
    let mut bv = vec![0u64; g.nv()];
    for x in 0..rg.n() {
        let gid = rg.orig(x) as usize;
        if gid < nu {
            bu[gid] = counts[x];
        } else {
            bv[gid - nu] = counts[x];
        }
    }
    VertexCounts { bu, bv }
}

/// Per-vertex counts in *rank space* on a preprocessed graph.
pub fn count_per_vertex_ranked(rg: &RankedGraph, opts: &CountOpts) -> Result<Vec<u64>> {
    guard(&opts.budget, || count_per_vertex_ranked_raw(rg, opts))
}

pub(crate) fn count_per_vertex_ranked_raw(rg: &RankedGraph, opts: &CountOpts) -> Vec<u64> {
    budget::probe_alloc(rg.n() * 8, "per-vertex counts");
    let counts: Vec<AtomicU64> = (0..rg.n()).map(|_| AtomicU64::new(0)).collect();
    engine_for(opts).per_vertex(rg, &counts);
    counts.into_iter().map(|c| c.into_inner()).collect()
}

/// Per-edge butterfly counts indexed by edge id (COUNT-E, Algorithm 4).
///
/// Runs under [`CountOpts::budget`]; see [`count_total`] for the error
/// contract.
pub fn count_per_edge(g: &BipartiteGraph, opts: &CountOpts) -> Result<Vec<u64>> {
    guard(&opts.budget, || count_per_edge_raw(g, opts))
}

pub(crate) fn count_per_edge_raw(g: &BipartiteGraph, opts: &CountOpts) -> Vec<u64> {
    let rg = preprocess(g, opts.ranking);
    count_per_edge_ranked_raw(&rg, g.m(), opts)
}

/// Per-edge counts on a preprocessed graph (`m` = edge count).
pub fn count_per_edge_ranked(rg: &RankedGraph, m: usize, opts: &CountOpts) -> Result<Vec<u64>> {
    guard(&opts.budget, || count_per_edge_ranked_raw(rg, m, opts))
}

pub(crate) fn count_per_edge_ranked_raw(rg: &RankedGraph, m: usize, opts: &CountOpts) -> Vec<u64> {
    budget::probe_alloc(m * 8, "per-edge counts");
    let counts: Vec<AtomicU64> = (0..m).map(|_| AtomicU64::new(0)).collect();
    engine_for(opts).per_edge(rg, &counts);
    counts.into_iter().map(|c| c.into_inner()).collect()
}

/// Shared atomic-add helper.
#[inline]
pub(crate) fn atomic_add(a: &AtomicU64, v: u64) {
    if v != 0 {
        a.fetch_add(v, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::testutil::brute;

    fn all_opt_combos() -> Vec<CountOpts> {
        let mut v = Vec::new();
        for ranking in Ranking::ALL {
            for agg in WedgeAgg::ALL {
                for cache_opt in [false, true] {
                    for bfly in [BflyAgg::Atomic, BflyAgg::Reagg] {
                        v.push(CountOpts {
                            ranking,
                            engine: Engine::Wedges,
                            agg,
                            bfly,
                            cache_opt,
                            ..Default::default()
                        });
                    }
                }
            }
            // The streaming engine has no agg/bfly/cache knobs — one
            // combo per ranking and memory layout.
            for layout in Layout::ALL {
                v.push(CountOpts {
                    ranking,
                    engine: Engine::Intersect,
                    layout,
                    ..Default::default()
                });
            }
        }
        v
    }

    #[test]
    fn fig1_has_three_butterflies() {
        let g = BipartiteGraph::from_edges(
            3,
            3,
            &[(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2), (2, 2)],
        );
        for opts in all_opt_combos() {
            assert_eq!(count_total(&g, &opts).unwrap(), 3, "{opts:?}");
        }
    }

    #[test]
    fn complete_bipartite_closed_form() {
        let g = gen::complete_bipartite(5, 7);
        let expect = choose2(5) * choose2(7); // C(5,2)*C(7,2) = 210
        for opts in all_opt_combos() {
            assert_eq!(count_total(&g, &opts).unwrap(), expect, "{opts:?}");
        }
    }

    #[test]
    fn every_combo_matches_brute_force_total() {
        for seed in [3, 4] {
            let g = gen::erdos_renyi(25, 30, 220, seed);
            let expect = brute::total(&g);
            for opts in all_opt_combos() {
                assert_eq!(count_total(&g, &opts).unwrap(), expect, "seed={seed} {opts:?}");
            }
        }
    }

    #[test]
    fn every_combo_matches_brute_force_per_vertex() {
        let g = gen::erdos_renyi(20, 22, 160, 9);
        let (eu, ev) = brute::per_vertex(&g);
        for opts in all_opt_combos() {
            let vc = count_per_vertex(&g, &opts).unwrap();
            assert_eq!(vc.bu, eu, "{opts:?}");
            assert_eq!(vc.bv, ev, "{opts:?}");
        }
    }

    #[test]
    fn every_combo_matches_brute_force_per_edge() {
        let g = gen::erdos_renyi(18, 20, 140, 5);
        let expect = brute::per_edge(&g);
        for opts in all_opt_combos() {
            assert_eq!(count_per_edge(&g, &opts).unwrap(), expect, "{opts:?}");
        }
    }

    #[test]
    fn chunked_wedge_processing_is_exact() {
        let g = gen::chung_lu(80, 120, 1500, 2.2, 6);
        let baseline = count_total(&g, &CountOpts::default()).unwrap();
        for agg in [WedgeAgg::Sort, WedgeAgg::Hash, WedgeAgg::Hist] {
            for max_wedges in [16, 257, 4096] {
                let opts = CountOpts { agg, max_wedges, ..CountOpts::default() };
                assert_eq!(count_total(&g, &opts).unwrap(), baseline, "agg={agg:?} cap={max_wedges}");
                let vc = count_per_vertex(&g, &opts).unwrap();
                let full =
                    count_per_vertex(&g, &CountOpts { agg, ..CountOpts::default() }).unwrap();
                assert_eq!(vc, full);
            }
        }
    }

    #[test]
    fn davis_counts_are_consistent() {
        let g = gen::davis_southern_women();
        let total = count_total(&g, &CountOpts::default()).unwrap();
        assert_eq!(total, brute::total(&g));
        let vc = count_per_vertex(&g, &CountOpts::default()).unwrap();
        assert_eq!(vc.bu.iter().sum::<u64>(), 2 * total);
        assert_eq!(vc.bv.iter().sum::<u64>(), 2 * total);
        let pe = count_per_edge(&g, &CountOpts::default()).unwrap();
        assert_eq!(pe.iter().sum::<u64>(), 4 * total);
    }
}
