//! The counting-engine abstraction.
//!
//! Every counting path answers the same three questions — global,
//! per-vertex (rank-indexed), per-edge (edge-id-indexed) butterfly
//! counts on a preprocessed [`RankedGraph`] — so the stack exposes one
//! [`WedgeEngine`] trait and two implementation families:
//!
//! * [`AggEngine`] — the materializing "retrieve → aggregate →
//!   combine" skeleton of §3.1: GET-WEDGES materializes (or streams)
//!   wedge records, one of the five [`WedgeAgg`] strategies
//!   (Sort/Hash/Hist fully parallel, BatchS/BatchWA partially
//!   parallel) aggregates them by endpoint key, and butterfly counts
//!   are combined atomically or by re-aggregation.  Memory scales with
//!   the wedge count (bounded by `CountOpts::max_wedges` chunking).
//! * [`intersect`](super::intersect) — the streaming intersect engine:
//!   per-source dense-counter two-hop walks that never allocate a
//!   wedge record.  Memory scales with `m + threads * n`, independent
//!   of the wedge count.
//!
//! [`Engine`] is the user-facing selector carried by
//! [`CountOpts::engine`]; [`engine_for`] resolves it to a trait object.
//!
//! The peeling stack mirrors this split one-for-one: its
//! [`PeelEngine`](crate::peel::PeelEngine) selects between the same
//! two families for the per-round UPDATE-V/UPDATE-E computations, and
//! its intersect path reuses this module family's core scratch (the
//! [`intersect`] dense `TouchedCounter` walk discipline — shared
//! crate-internally, along with its `EdgeStamp` sibling that the
//! batch-dynamic delta walks use) over live shrinking views instead
//! of the static [`UpCsr`](crate::graph::UpCsr).

use std::sync::atomic::AtomicU64;

use super::{agg, batch, intersect, CountOpts, WedgeAgg};
use crate::graph::{Layout, RankedGraph};

/// Which counting engine a run uses (selected via [`CountOpts`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Engine {
    /// Materializing wedge aggregation; the strategy is
    /// [`CountOpts::agg`].
    Wedges,
    /// Streaming per-source intersect counting — zero wedge
    /// materialization, ignores [`CountOpts::agg`],
    /// [`CountOpts::bfly`], [`CountOpts::cache_opt`], and
    /// [`CountOpts::max_wedges`].
    Intersect,
}

impl Engine {
    pub const ALL: [Engine; 2] = [Engine::Wedges, Engine::Intersect];

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Wedges => "wedges",
            Engine::Intersect => "intersect",
        }
    }

    pub fn parse(s: &str) -> Option<Engine> {
        Engine::ALL.into_iter().find(|e| e.name() == s)
    }
}

/// A butterfly-counting engine over a preprocessed graph.
///
/// `out` arrays are zero-initialized by the caller; engines add into
/// them (atomic, relaxed) and must produce exact counts.
pub trait WedgeEngine: Sync {
    /// Short name for reports and CLI output.
    fn name(&self) -> &'static str;
    /// Global butterfly count.
    fn total(&self, rg: &RankedGraph) -> u64;
    /// Per-vertex counts into a rank-indexed array of length `rg.n()`.
    fn per_vertex(&self, rg: &RankedGraph, out: &[AtomicU64]);
    /// Per-edge counts into an edge-id-indexed array of length `rg.m()`.
    fn per_edge(&self, rg: &RankedGraph, out: &[AtomicU64]);
}

/// The materializing family: all five [`WedgeAgg`] strategies behind
/// one engine, parameterized by the full [`CountOpts`].
pub struct AggEngine<'a> {
    opts: &'a CountOpts,
}

impl<'a> AggEngine<'a> {
    pub fn new(opts: &'a CountOpts) -> Self {
        Self { opts }
    }
}

impl WedgeEngine for AggEngine<'_> {
    fn name(&self) -> &'static str {
        self.opts.agg.name()
    }

    fn total(&self, rg: &RankedGraph) -> u64 {
        match self.opts.agg {
            WedgeAgg::BatchS => batch::total_batch(rg, self.opts.cache_opt, false),
            WedgeAgg::BatchWA => batch::total_batch(rg, self.opts.cache_opt, true),
            _ => agg::total_agg(rg, self.opts),
        }
    }

    fn per_vertex(&self, rg: &RankedGraph, out: &[AtomicU64]) {
        match self.opts.agg {
            WedgeAgg::BatchS => batch::per_vertex_batch(rg, self.opts.cache_opt, false, out),
            WedgeAgg::BatchWA => batch::per_vertex_batch(rg, self.opts.cache_opt, true, out),
            _ => agg::per_vertex_agg(rg, self.opts, out),
        }
    }

    fn per_edge(&self, rg: &RankedGraph, out: &[AtomicU64]) {
        match self.opts.agg {
            WedgeAgg::BatchS => batch::per_edge_batch(rg, self.opts.cache_opt, false, out),
            WedgeAgg::BatchWA => batch::per_edge_batch(rg, self.opts.cache_opt, true, out),
            _ => agg::per_edge_agg(rg, self.opts, out),
        }
    }
}

/// The streaming intersect engine (see [`intersect`]), carrying the
/// memory [`Layout`] its hot loops run under.
pub struct IntersectEngine {
    pub layout: Layout,
}

impl WedgeEngine for IntersectEngine {
    fn name(&self) -> &'static str {
        "intersect"
    }

    fn total(&self, rg: &RankedGraph) -> u64 {
        intersect::total_intersect(rg, self.layout)
    }

    fn per_vertex(&self, rg: &RankedGraph, out: &[AtomicU64]) {
        intersect::per_vertex_intersect(rg, self.layout, out)
    }

    fn per_edge(&self, rg: &RankedGraph, out: &[AtomicU64]) {
        intersect::per_edge_intersect(rg, self.layout, out)
    }
}

/// Resolve the engine an option set selects.
pub fn engine_for(opts: &CountOpts) -> Box<dyn WedgeEngine + '_> {
    match opts.engine {
        Engine::Wedges => Box::new(AggEngine::new(opts)),
        Engine::Intersect => Box::new(IntersectEngine { layout: opts.layout }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::rank::{preprocess, Ranking};
    use crate::testutil::brute;

    #[test]
    fn engine_names_roundtrip() {
        for e in Engine::ALL {
            assert_eq!(Engine::parse(e.name()), Some(e));
        }
        assert_eq!(Engine::parse("nope"), None);
    }

    #[test]
    fn every_engine_agrees_through_the_trait() {
        let g = gen::erdos_renyi(20, 24, 170, 8);
        let rg = preprocess(&g, Ranking::Degree);
        let expect = brute::total(&g);
        for engine in Engine::ALL {
            for agg in WedgeAgg::ALL {
                let opts = CountOpts { engine, agg, ..Default::default() };
                let e = engine_for(&opts);
                assert_eq!(e.total(&rg), expect, "{engine:?}/{agg:?}");
                let load = |a: &AtomicU64| a.load(std::sync::atomic::Ordering::Relaxed);
                let pv: Vec<AtomicU64> = (0..rg.n()).map(|_| AtomicU64::new(0)).collect();
                e.per_vertex(&rg, &pv);
                let sum: u64 = pv.iter().map(load).sum();
                assert_eq!(sum, 4 * expect, "{engine:?}/{agg:?} per-vertex sum");
                let pe: Vec<AtomicU64> = (0..rg.m()).map(|_| AtomicU64::new(0)).collect();
                e.per_edge(&rg, &pe);
                let sum: u64 = pe.iter().map(load).sum();
                assert_eq!(sum, 4 * expect, "{engine:?}/{agg:?} per-edge sum");
            }
        }
    }
}
