//! Wedge retrieval (Algorithm 2 / GET-WEDGES) and its cache-optimized
//! variant (Wang et al. §3.1.4).
//!
//! A retrieved wedge is `(x1, x2, y)` with `rank(y) > rank(x1)` and
//! `rank(x2) > rank(x1)`: `x1` is the **low endpoint**, `x2` the **high
//! endpoint**, `y` the center.  Standard retrieval enumerates from the
//! low endpoint (`src = x1`); the cache optimization enumerates exactly
//! the same wedge set from the high endpoint (`src = x2`), improving the
//! locality of endpoint-indexed aggregation.
//!
//! Every wedge knows the edge ids of its two legs, so per-edge counting
//! needs no extra lookups.
//!
//! All butterfly counts of a wedge key `(x1, x2)` are derived from the
//! key's full multiplicity, so aggregation must see every wedge of a key
//! together.  Both enumeration orders keep a key's wedges within a
//! single source vertex, which is what makes the memory-bounded chunking
//! of [`chunk_sources`] sound (§3.1.4 "parameter ... processes subsets
//! of wedges").

use crate::graph::RankedGraph;
use crate::prims::pool::parallel_for_dynamic;
use crate::prims::scan::prefix_sum;

/// One retrieved wedge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Wedge {
    /// Low endpoint (minimum rank of the three).
    pub lo: u32,
    /// High endpoint.
    pub hi: u32,
    /// Center.
    pub center: u32,
    /// Edge id of (lo, center).
    pub e_lo: u32,
    /// Edge id of (center, hi).
    pub e_hi: u32,
}

impl Wedge {
    /// Aggregation key: endpoint pair packed as (lo << 32) | hi.
    #[inline]
    pub fn key(&self) -> u64 {
        ((self.lo as u64) << 32) | self.hi as u64
    }
}

/// Endpoints of a packed wedge key.
#[inline]
pub fn key_endpoints(key: u64) -> (u32, u32) {
    ((key >> 32) as u32, key as u32)
}

/// Number of wedges enumerated from source vertex `src`.
#[inline]
pub fn wedges_from(rg: &RankedGraph, cache_opt: bool, src: usize) -> u64 {
    let mut s = 0u64;
    if !cache_opt {
        let r = src as u32;
        for &y in &rg.nbrs(src)[..rg.up_deg(src)] {
            s += rg.up_deg_above(y as usize, r) as u64;
        }
    } else {
        let r = src as u32;
        for &y in rg.nbrs(src) {
            // x1 must out-rank neither y nor src: count neighbors of y
            // with rank < min(rank(y), rank(src)) — a suffix.  When
            // rank(src) < rank(y) the suffix contains src itself (the
            // degenerate x1 == x2 case) — subtract it.
            let min_r = r.min(y);
            let d = rg.deg(y as usize);
            let mut suffix = d - rg.up_deg_above(y as usize, min_r);
            if r < y {
                suffix -= 1; // src is in the suffix
            }
            s += suffix as u64;
        }
    }
    s
}

/// Per-source wedge counts (parallel).
pub fn source_wedge_counts(rg: &RankedGraph, cache_opt: bool) -> Vec<usize> {
    crate::prims::pool::parallel_map(rg.n(), |src| wedges_from(rg, cache_opt, src) as usize)
}

/// Split `0..n` into source ranges whose wedge totals stay below
/// `max_wedges` (a single over-budget source still gets its own chunk).
pub fn chunk_sources(counts: &[usize], max_wedges: usize) -> Vec<std::ops::Range<usize>> {
    let mut chunks = Vec::new();
    let mut start = 0usize;
    let mut acc = 0usize;
    for (i, &c) in counts.iter().enumerate() {
        if acc + c > max_wedges && acc > 0 {
            chunks.push(start..i);
            start = i;
            acc = 0;
        }
        acc += c;
    }
    if start < counts.len() {
        chunks.push(start..counts.len());
    }
    if counts.is_empty() {
        chunks.push(0..0);
    }
    chunks
}

/// Enumerate the wedges of a single source, sequentially.
#[inline]
pub fn wedges_of_source(rg: &RankedGraph, cache_opt: bool, src: usize, mut f: impl FnMut(Wedge)) {
    if !cache_opt {
        let x1 = src as u32;
        let nbrs = rg.nbrs(src);
        let eids = rg.eids(src);
        for i in 0..rg.up_deg(src) {
            let y = nbrs[i];
            let e_lo = eids[i];
            let cnt = rg.up_deg_above(y as usize, x1);
            let ynbrs = rg.nbrs(y as usize);
            let yeids = rg.eids(y as usize);
            for j in 0..cnt {
                f(Wedge { lo: x1, hi: ynbrs[j], center: y, e_lo, e_hi: yeids[j] });
            }
        }
    } else {
        let x2 = src as u32;
        let nbrs = rg.nbrs(src);
        let eids = rg.eids(src);
        for i in 0..rg.deg(src) {
            let y = nbrs[i];
            let e_hi = eids[i];
            let min_r = x2.min(y);
            let start = rg.up_deg_above(y as usize, min_r);
            let ynbrs = rg.nbrs(y as usize);
            let yeids = rg.eids(y as usize);
            for j in start..rg.deg(y as usize) {
                let x1 = ynbrs[j];
                // The suffix holds ranks <= min(rank(y), rank(x2)); the
                // equality case is x1 == x2 itself (when rank(x2) <
                // rank(y)), a degenerate wedge — skip it.
                if x1 == x2 {
                    continue;
                }
                f(Wedge { lo: x1, hi: x2, center: y, e_lo: yeids[j], e_hi });
            }
        }
    }
}

/// Parallel enumeration over a source range (dynamic scheduling — wedge
/// counts per source are heavily skewed).
pub fn for_each_wedge(
    rg: &RankedGraph,
    cache_opt: bool,
    sources: std::ops::Range<usize>,
    f: impl Fn(Wedge) + Sync,
) {
    let base = sources.start;
    let n = sources.end - sources.start;
    parallel_for_dynamic(n, 64, |r| {
        for off in r {
            wedges_of_source(rg, cache_opt, base + off, |w| f(w));
        }
    });
}

/// Materialize a chunk of wedges into a vector (records filled in
/// parallel via per-source offsets).
pub fn materialize(
    rg: &RankedGraph,
    cache_opt: bool,
    sources: std::ops::Range<usize>,
    counts: &[usize],
) -> Vec<Wedge> {
    let base = sources.start;
    let n = sources.end - sources.start;
    let local: Vec<usize> = counts[sources.clone()].to_vec();
    let (offsets, total) = prefix_sum(&local);
    let mut out = vec![Wedge { lo: 0, hi: 0, center: 0, e_lo: 0, e_hi: 0 }; total];
    {
        let op = crate::prims::pool::SyncPtr(out.as_mut_ptr());
        let offsets = &offsets;
        parallel_for_dynamic(n, 64, |r| {
            for off in r {
                let mut w = offsets[off];
                wedges_of_source(rg, cache_opt, base + off, |wd| {
                    unsafe { *op.get().add(w) = wd };
                    w += 1;
                });
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::rank::{preprocess, Ranking};
    use std::collections::BTreeSet;

    fn wedge_set(rg: &RankedGraph, cache_opt: bool) -> BTreeSet<(u32, u32, u32)> {
        let mut s = BTreeSet::new();
        for src in 0..rg.n() {
            wedges_of_source(rg, cache_opt, src, |w| {
                assert!((w.lo as usize) < w.hi as usize || w.lo < w.hi);
                s.insert((w.lo, w.hi, w.center));
            });
        }
        s
    }

    #[test]
    fn cache_opt_enumerates_identical_wedges() {
        for seed in [1, 2, 3] {
            let g = gen::erdos_renyi(40, 50, 400, seed);
            for r in Ranking::ALL {
                let rg = preprocess(&g, r);
                let std_set = wedge_set(&rg, false);
                let opt_set = wedge_set(&rg, true);
                assert_eq!(std_set, opt_set, "seed={seed} ranking={:?}", r);
            }
        }
    }

    #[test]
    fn wedge_count_matches_enumeration() {
        let g = gen::chung_lu(60, 80, 600, 2.2, 7);
        let rg = preprocess(&g, Ranking::Degree);
        for cache_opt in [false, true] {
            let counts = source_wedge_counts(&rg, cache_opt);
            for src in 0..rg.n() {
                let mut c = 0usize;
                wedges_of_source(&rg, cache_opt, src, |_| c += 1);
                assert_eq!(counts[src], c);
            }
            let total: usize = counts.iter().sum();
            assert_eq!(total as u64, rg.wedges_processed());
        }
    }

    #[test]
    fn edge_ids_are_the_wedge_legs() {
        let g = gen::erdos_renyi(20, 25, 150, 13);
        let rg = preprocess(&g, Ranking::Degree);
        for cache_opt in [false, true] {
            for src in 0..rg.n() {
                wedges_of_source(&rg, cache_opt, src, |w| {
                    // e_lo connects lo & center; e_hi connects center & hi
                    // (checked through the eids in the ranked adjacency).
                    let find = |a: u32, b: u32| -> Option<u32> {
                        let nbrs = rg.nbrs(a as usize);
                        let eids = rg.eids(a as usize);
                        nbrs.iter().position(|&z| z == b).map(|i| eids[i])
                    };
                    assert_eq!(find(w.lo, w.center), Some(w.e_lo));
                    assert_eq!(find(w.center, w.hi), Some(w.e_hi));
                });
            }
        }
    }

    #[test]
    fn chunking_respects_budget_and_covers() {
        let counts = vec![5usize, 10, 3, 50, 2, 2, 2, 40];
        let chunks = chunk_sources(&counts, 20);
        // Coverage and order.
        let mut next = 0;
        for c in &chunks {
            assert_eq!(c.start, next);
            next = c.end;
        }
        assert_eq!(next, counts.len());
        // Budget (single oversized sources allowed).
        for c in &chunks {
            let s: usize = counts[c.clone()].iter().sum();
            assert!(s <= 20 || c.len() == 1, "{c:?} sum {s}");
        }
    }

    #[test]
    fn materialize_matches_streaming() {
        let g = gen::chung_lu(50, 60, 500, 2.3, 5);
        let rg = preprocess(&g, Ranking::ApproxDegree);
        for cache_opt in [false, true] {
            let counts = source_wedge_counts(&rg, cache_opt);
            let all = materialize(&rg, cache_opt, 0..rg.n(), &counts);
            let mut streamed = Vec::new();
            for src in 0..rg.n() {
                wedges_of_source(&rg, cache_opt, src, |w| streamed.push(w));
            }
            assert_eq!(all, streamed);
        }
    }
}
