//! Streaming intersect counting (zero wedge materialization).
//!
//! The BFC-VP++-style per-source counter of Wang et al. ("Efficient
//! Butterfly Counting for Large Bipartite Networks"): for every source
//! `x1` — the rank-minimum endpoint, exactly the wedge order of
//! GET-WEDGES — walk its two-hop neighborhood and tally second
//! endpoints in a per-worker dense counter array.  Each distinct
//! second endpoint `x2` reached through `d` centers closes `C(d, 2)`
//! butterflies; per-vertex and per-edge credits come from a second
//! sweep of the same two-hop walk against the finished counters.  No
//! `Vec<Wedge>` (or any per-wedge record) is ever allocated: peak
//! memory is `O(m + threads * n)` — the shared [`UpCsr`] view plus the
//! per-worker counters — regardless of the wedge count, where the
//! materializing aggregations pay `O(#wedges)`.
//!
//! * First hop over the compact rank-ascending [`UpCsr`] view — one
//!   slot per edge, sequential scan across sources.
//! * Second hop over the decreasing-rank prefix of the center's full
//!   adjacency (`up_deg_above`), the same prefix GET-WEDGES scans.
//! * Counter reset via the touched-list, not a memset, so a sparse
//!   source costs its wedge count, not `O(n)`.
//! * Sources are claimed in small grains from an atomic counter
//!   ([`parallel_for_dynamic_with`]) — wedge counts per source are
//!   heavily skewed, so static splits would imbalance.

use std::sync::atomic::AtomicU64;

use super::{atomic_add, choose2};
use crate::graph::{RankedGraph, UpCsr};
use crate::prims::pool::parallel_for_dynamic_with;

/// Sources per dynamic claim (mirrors BatchWA's grain).
const GRAIN: usize = 8;

/// Dense `u32` tally with O(#touched) reset — the core scratch of
/// every streaming intersect walk.  Shared with the peel engine's
/// UPDATE-V path (`peel/vertex.rs`), which runs the same
/// counter-and-touched-list discipline over a shrinking live view.
pub(crate) struct TouchedCounter {
    pub(crate) cnt: Vec<u32>,
    pub(crate) touched: Vec<u32>,
}

impl TouchedCounter {
    pub(crate) fn new(n: usize) -> Self {
        Self { cnt: vec![0u32; n], touched: Vec::new() }
    }

    /// Increment slot `i`, recording first touches.
    #[inline]
    pub(crate) fn bump(&mut self, i: u32) {
        if self.cnt[i as usize] == 0 {
            self.touched.push(i);
        }
        self.cnt[i as usize] += 1;
    }

    /// Visit every touched `(index, count)` and reset it to zero.
    #[inline]
    pub(crate) fn drain(&mut self, mut f: impl FnMut(u32, u32)) {
        for &i in &self.touched {
            f(i, std::mem::take(&mut self.cnt[i as usize]));
        }
        self.touched.clear();
    }

    /// Zero all touched slots without visiting them.
    #[inline]
    pub(crate) fn reset(&mut self) {
        for &i in &self.touched {
            self.cnt[i as usize] = 0;
        }
        self.touched.clear();
    }
}

/// Dense stamp map `id -> edge id` with the same O(#touched)-reset
/// discipline as [`TouchedCounter`], for walks that need to recall
/// *which edge* reached a slot rather than how many times.  The
/// batch-dynamic delta walks (`dynamic`) stamp one endpoint's
/// adjacency with its edge ids, then test the two-hop frontier
/// against the stamp to close butterflies and credit the closing
/// edges.  `u32::MAX` marks an empty slot (edge ids are CSR positions
/// and [`BipartiteGraph`](crate::graph::BipartiteGraph) construction
/// guarantees `m < u32::MAX`).
pub(crate) struct EdgeStamp {
    slot: Vec<u32>,
    touched: Vec<u32>,
}

impl EdgeStamp {
    pub(crate) fn new(n: usize) -> Self {
        Self { slot: vec![u32::MAX; n], touched: Vec::new() }
    }

    /// Stamp slot `i` with `eid`, recording first touches.
    #[inline]
    pub(crate) fn set(&mut self, i: u32, eid: u32) {
        if self.slot[i as usize] == u32::MAX {
            self.touched.push(i);
        }
        self.slot[i as usize] = eid;
    }

    /// The edge id stamped on slot `i`, if any.
    #[inline]
    pub(crate) fn get(&self, i: u32) -> Option<u32> {
        match self.slot[i as usize] {
            u32::MAX => None,
            e => Some(e),
        }
    }

    /// Clear all stamped slots without visiting them.
    #[inline]
    pub(crate) fn reset(&mut self) {
        for &i in &self.touched {
            self.slot[i as usize] = u32::MAX;
        }
        self.touched.clear();
    }
}

/// Per-worker scratch: the dense second-endpoint counter plus the
/// current source's per-center prefix lengths so the credit sweep
/// doesn't redo `up_deg_above`'s binary search.
struct Scratch {
    ctr: TouchedCounter,
    pres: Vec<u32>,
}

impl Scratch {
    fn new(n: usize) -> Self {
        Self { ctr: TouchedCounter::new(n), pres: Vec::new() }
    }
}

/// Tally the wedges of `src` by second endpoint into `s.ctr`,
/// recording each center's second-hop prefix length in `s.pres`.
#[inline]
fn fill(rg: &RankedGraph, up: &UpCsr, src: usize, s: &mut Scratch) {
    let r = src as u32;
    s.pres.clear();
    for &y in up.nbrs(src) {
        let pre = rg.up_deg_above(y as usize, r);
        s.pres.push(pre as u32);
        for &z in &rg.nbrs(y as usize)[..pre] {
            s.ctr.bump(z);
        }
    }
}

/// Global butterfly count, single pass.
pub fn total_intersect(rg: &RankedGraph) -> u64 {
    let up = rg.up_csr();
    let n = rg.n();
    let acc = AtomicU64::new(0);
    parallel_for_dynamic_with(
        n,
        GRAIN,
        || Scratch::new(n),
        |s, range| {
            let mut local = 0u64;
            for src in range {
                fill(rg, &up, src, s);
                s.ctr.drain(|_z, d| local += choose2(d as u64));
            }
            atomic_add(&acc, local);
        },
    );
    acc.into_inner()
}

/// COUNT-V, two passes per source (rank-indexed output).
pub fn per_vertex_intersect(rg: &RankedGraph, out: &[AtomicU64]) {
    let up = rg.up_csr();
    let n = rg.n();
    parallel_for_dynamic_with(
        n,
        GRAIN,
        || Scratch::new(n),
        |s, range| {
            for src in range {
                fill(rg, &up, src, s);
                // Endpoints: `src` and each distinct second endpoint
                // gain C(d, 2) (Lemma 4.2 Eq. 1).
                let mut src_total = 0u64;
                for &z in &s.ctr.touched {
                    let b = choose2(s.ctr.cnt[z as usize] as u64);
                    if b > 0 {
                        src_total += b;
                        atomic_add(&out[z as usize], b);
                    }
                }
                atomic_add(&out[src], src_total);
                // Centers: d - 1 per wedge, re-walking the same two-hop
                // loop against the finished counters (this replaces the
                // wedge buffer the batching engines keep).
                for (i, &y) in up.nbrs(src).iter().enumerate() {
                    let pre = s.pres[i] as usize;
                    let mut center = 0u64;
                    for &z in &rg.nbrs(y as usize)[..pre] {
                        center += s.ctr.cnt[z as usize] as u64 - 1;
                    }
                    atomic_add(&out[y as usize], center);
                }
                s.ctr.reset();
            }
        },
    );
}

/// COUNT-E, two passes per source (edge-id-indexed output).
pub fn per_edge_intersect(rg: &RankedGraph, out: &[AtomicU64]) {
    let up = rg.up_csr();
    let n = rg.n();
    parallel_for_dynamic_with(
        n,
        GRAIN,
        || Scratch::new(n),
        |s, range| {
            for src in range {
                fill(rg, &up, src, s);
                // Both legs of every wedge gain d - 1 (Lemma 4.2
                // Eq. 2): the (src, y) leg accumulates across y's
                // wedges, the (y, z) leg is credited per wedge.
                let eids = up.eids(src);
                for (i, &y) in up.nbrs(src).iter().enumerate() {
                    let pre = s.pres[i] as usize;
                    let ynbrs = &rg.nbrs(y as usize)[..pre];
                    let yeids = &rg.eids(y as usize)[..pre];
                    let mut lo_leg = 0u64;
                    for j in 0..pre {
                        let d = s.ctr.cnt[ynbrs[j] as usize] as u64;
                        if d > 1 {
                            lo_leg += d - 1;
                            atomic_add(&out[yeids[j] as usize], d - 1);
                        }
                    }
                    atomic_add(&out[eids[i] as usize], lo_leg);
                }
                s.ctr.reset();
            }
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::{count_per_edge, count_per_vertex, count_total, CountOpts, Engine};
    use crate::graph::gen;
    use crate::rank::{preprocess, Ranking};
    use crate::testutil::brute;

    fn iopts() -> CountOpts {
        CountOpts { engine: Engine::Intersect, ..Default::default() }
    }

    #[test]
    fn davis_matches_brute_force() {
        let g = gen::davis_southern_women();
        assert_eq!(count_total(&g, &iopts()), brute::total(&g));
    }

    #[test]
    fn matches_brute_force_on_random_graphs_all_rankings() {
        for seed in [2, 11] {
            let g = gen::erdos_renyi(24, 28, 210, seed);
            let expect_t = brute::total(&g);
            let (ebu, ebv) = brute::per_vertex(&g);
            let ebe = brute::per_edge(&g);
            for ranking in Ranking::ALL {
                let opts = CountOpts { ranking, ..iopts() };
                assert_eq!(count_total(&g, &opts), expect_t, "seed={seed} {ranking:?}");
                let vc = count_per_vertex(&g, &opts);
                assert_eq!(vc.bu, ebu, "seed={seed} {ranking:?}");
                assert_eq!(vc.bv, ebv, "seed={seed} {ranking:?}");
                assert_eq!(count_per_edge(&g, &opts), ebe, "seed={seed} {ranking:?}");
            }
        }
    }

    #[test]
    fn skewed_graph_exercises_dynamic_claims() {
        let g = gen::chung_lu(90, 110, 1400, 2.1, 17);
        let rg = preprocess(&g, Ranking::Degree);
        for t in [1usize, 3, 8] {
            let total = crate::prims::pool::with_threads(t, || total_intersect(&rg));
            assert_eq!(total, brute::total(&g), "threads={t}");
        }
    }

    #[test]
    fn edge_stamp_set_get_reset() {
        let mut s = EdgeStamp::new(8);
        assert_eq!(s.get(3), None);
        s.set(3, 17);
        s.set(5, 0);
        s.set(3, 18); // overwrite keeps one touched entry
        assert_eq!(s.get(3), Some(18));
        assert_eq!(s.get(5), Some(0));
        assert_eq!(s.get(0), None);
        s.reset();
        assert_eq!(s.get(3), None);
        assert_eq!(s.get(5), None);
        assert!(s.touched.is_empty());
    }

    #[test]
    fn empty_and_wedgeless_graphs() {
        let g = gen::erdos_renyi(5, 5, 0, 1);
        assert_eq!(count_total(&g, &iopts()), 0);
        // A perfect matching has wedges nowhere.
        let edges: Vec<(u32, u32)> = (0..4).map(|i| (i, i)).collect();
        let g = crate::graph::BipartiteGraph::from_edges(4, 4, &edges);
        assert_eq!(count_total(&g, &iopts()), 0);
        assert!(count_per_edge(&g, &iopts()).iter().all(|&c| c == 0));
    }
}
