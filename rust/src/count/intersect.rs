//! Streaming intersect counting (zero wedge materialization).
//!
//! The BFC-VP++-style per-source counter of Wang et al. ("Efficient
//! Butterfly Counting for Large Bipartite Networks"): for every source
//! `x1` — the rank-minimum endpoint, exactly the wedge order of
//! GET-WEDGES — walk its two-hop neighborhood and tally second
//! endpoints in a per-worker dense counter array.  Each distinct
//! second endpoint `x2` reached through `d` centers closes `C(d, 2)`
//! butterflies; per-vertex and per-edge credits come from a second
//! sweep of the same two-hop walk against the finished counters.  No
//! `Vec<Wedge>` (or any per-wedge record) is ever allocated: peak
//! memory is `O(m + threads * n)` — the shared [`UpCsr`] view plus the
//! per-worker counters — regardless of the wedge count, where the
//! materializing aggregations pay `O(#wedges)`.
//!
//! * First hop over the compact rank-ascending [`UpCsr`] view — one
//!   slot per edge, sequential scan across sources.
//! * Second hop over the decreasing-rank prefix of the center's full
//!   adjacency (`up_deg_above`), the same prefix GET-WEDGES scans.
//! * Counter reset via the touched-list, not a memset, so a sparse
//!   source costs its wedge count, not `O(n)`.
//! * Sources are claimed in small grains from an atomic counter
//!   ([`parallel_for_dynamic_with`]) — wedge counts per source are
//!   heavily skewed, so static splits would imbalance.  The grain is
//!   derived from the cache-tile budget ([`walk_grain`]), not
//!   hard-coded.
//!
//! # Cache-aware fast path ([`Layout::Hub`])
//!
//! The flat walk is memory-bound: second hops scatter counter bumps
//! across `O(n)` slots.  The hub layout (BFC-VP++-style) reshapes the
//! same walk three ways, preserving bit-identical outputs:
//!
//! * **Hub bitmaps** — second endpoints in the heavy-degree prefix of
//!   a [`HubView`] get their full multiplicity `d = |N_up(src) ∩ N(z)|`
//!   from one word-wise AND/popcount ([`crate::prims::simd`]) on first
//!   touch, instead of `d` scattered bumps.  The hub counter slots
//!   (`cnt[0..hub_count]`) are a dense, cache-resident prefix.
//! * **Blocked traversal** — non-hub fills walk the centers' prefixes
//!   in descending-rank tiles of [`TILE_RANKS`] so every bump lands in
//!   an L2-resident counter slice; each center keeps a monotone cursor
//!   (the prefix is rank-sorted) so tiling adds no rescans.
//! * **Butterfly-sparsity credit skip** — the credit sweeps only add
//!   nonzero terms for endpoints with `d >= 2`; the hub path collects
//!   that "hot" set while draining, skips a source's entire credit
//!   re-walk when it is empty, and otherwise filters per entry through
//!   a dense hot-bitmap instead of re-touching cold counter slots.

use std::sync::atomic::AtomicU64;

use super::{atomic_add, choose2};
use crate::graph::ranked::{walk_grain, TILE_RANKS};
use crate::graph::{HubView, Layout, RankedGraph, UpCsr};
use crate::prims::pool::parallel_for_dynamic_with;
use crate::prims::simd::{and_popcount_at, Bitset};

/// Expected distinct-second-endpoint footprint of one source's fill
/// (average up-degree squared) — the per-item cost the tile-derived
/// grain policy budgets against.
fn footprint(rg: &RankedGraph) -> usize {
    let avg = rg.m().div_ceil(rg.n().max(1)).max(1);
    avg.saturating_mul(avg)
}

/// Dense `u32` tally with O(#touched) reset — the core scratch of
/// every streaming intersect walk.  Shared with the peel engine's
/// UPDATE-V path (`peel/vertex.rs`), which runs the same
/// counter-and-touched-list discipline over a shrinking live view.
pub(crate) struct TouchedCounter {
    pub(crate) cnt: Vec<u32>,
    pub(crate) touched: Vec<u32>,
}

impl TouchedCounter {
    pub(crate) fn new(n: usize) -> Self {
        Self { cnt: vec![0u32; n], touched: Vec::new() }
    }

    /// Increment slot `i`, recording first touches.
    #[inline]
    pub(crate) fn bump(&mut self, i: u32) {
        if self.cnt[i as usize] == 0 {
            self.touched.push(i);
        }
        self.cnt[i as usize] += 1;
    }

    /// Visit every touched `(index, count)` and reset it to zero.
    #[inline]
    pub(crate) fn drain(&mut self, mut f: impl FnMut(u32, u32)) {
        for &i in &self.touched {
            f(i, std::mem::take(&mut self.cnt[i as usize]));
        }
        self.touched.clear();
    }

    /// Zero all touched slots without visiting them.
    #[inline]
    pub(crate) fn reset(&mut self) {
        for &i in &self.touched {
            self.cnt[i as usize] = 0;
        }
        self.touched.clear();
    }
}

/// Dense stamp map `id -> edge id` with the same O(#touched)-reset
/// discipline as [`TouchedCounter`], for walks that need to recall
/// *which edge* reached a slot rather than how many times.  The
/// batch-dynamic delta walks (`dynamic`) stamp one endpoint's
/// adjacency with its edge ids, then test the two-hop frontier
/// against the stamp to close butterflies and credit the closing
/// edges.  `u32::MAX` marks an empty slot (edge ids are CSR positions
/// and [`BipartiteGraph`](crate::graph::BipartiteGraph) construction
/// guarantees `m < u32::MAX`).
///
/// Alongside the slot array it maintains a presence [`Bitset`] — 64x
/// denser, so scan loops that mostly miss ([`Self::hit`]) stay inside
/// cache instead of dragging the full `u32` slot array through it.
pub(crate) struct EdgeStamp {
    slot: Vec<u32>,
    touched: Vec<u32>,
    present: Bitset,
}

impl EdgeStamp {
    pub(crate) fn new(n: usize) -> Self {
        Self { slot: vec![u32::MAX; n], touched: Vec::new(), present: Bitset::new(n) }
    }

    /// Stamp slot `i` with `eid`, recording first touches.
    #[inline]
    pub(crate) fn set(&mut self, i: u32, eid: u32) {
        if self.slot[i as usize] == u32::MAX {
            self.touched.push(i);
            self.present.set(i);
        }
        self.slot[i as usize] = eid;
    }

    /// Word-test fast reject: is slot `i` stamped at all?
    #[inline]
    pub(crate) fn hit(&self, i: u32) -> bool {
        self.present.test(i)
    }

    /// The edge id stamped on slot `i`, if any.
    #[inline]
    pub(crate) fn get(&self, i: u32) -> Option<u32> {
        match self.slot[i as usize] {
            u32::MAX => None,
            e => Some(e),
        }
    }

    /// Clear all stamped slots without visiting them.
    #[inline]
    pub(crate) fn reset(&mut self) {
        for &i in &self.touched {
            self.slot[i as usize] = u32::MAX;
            self.present.clear(i);
        }
        self.touched.clear();
    }
}

/// Per-worker scratch: the dense second-endpoint counter plus the
/// current source's per-center prefix lengths so the credit sweep
/// doesn't redo `up_deg_above`'s binary search.
struct Scratch {
    ctr: TouchedCounter,
    pres: Vec<u32>,
}

impl Scratch {
    fn new(n: usize) -> Self {
        Self { ctr: TouchedCounter::new(n), pres: Vec::new() }
    }
}

/// Tally the wedges of `src` by second endpoint into `s.ctr`,
/// recording each center's second-hop prefix length in `s.pres`.
#[inline]
fn fill(rg: &RankedGraph, up: &UpCsr, src: usize, s: &mut Scratch) {
    let r = src as u32;
    s.pres.clear();
    for &y in up.nbrs(src) {
        let pre = rg.up_deg_above(y as usize, r);
        s.pres.push(pre as u32);
        for &z in &rg.nbrs(y as usize)[..pre] {
            s.ctr.bump(z);
        }
    }
}

/// Global butterfly count.
pub fn total_intersect(rg: &RankedGraph, layout: Layout) -> u64 {
    match layout.resolve(rg.m()) {
        Layout::Flat => total_flat(rg),
        _ => total_hub(rg, &HubView::build(rg, matches!(layout, Layout::Auto))),
    }
}

/// COUNT-V (rank-indexed output, caller's rank space).
pub fn per_vertex_intersect(rg: &RankedGraph, layout: Layout, out: &[AtomicU64]) {
    match layout.resolve(rg.m()) {
        Layout::Flat => per_vertex_flat(rg, out),
        _ => per_vertex_hub(rg, &HubView::build(rg, matches!(layout, Layout::Auto)), out),
    }
}

/// COUNT-E (edge-id-indexed output).
pub fn per_edge_intersect(rg: &RankedGraph, layout: Layout, out: &[AtomicU64]) {
    match layout.resolve(rg.m()) {
        Layout::Flat => per_edge_flat(rg, out),
        _ => per_edge_hub(rg, &HubView::build(rg, matches!(layout, Layout::Auto)), out),
    }
}

/// Global butterfly count, single pass, flat layout.
fn total_flat(rg: &RankedGraph) -> u64 {
    let up = rg.up_csr();
    let n = rg.n();
    let acc = AtomicU64::new(0);
    parallel_for_dynamic_with(
        n,
        walk_grain(n, footprint(rg)),
        || Scratch::new(n),
        |s, range| {
            let mut local = 0u64;
            for src in range {
                fill(rg, &up, src, s);
                s.ctr.drain(|_z, d| local += choose2(d as u64));
            }
            atomic_add(&acc, local);
        },
    );
    acc.into_inner()
}

/// COUNT-V, two passes per source, flat layout.
fn per_vertex_flat(rg: &RankedGraph, out: &[AtomicU64]) {
    let up = rg.up_csr();
    let n = rg.n();
    parallel_for_dynamic_with(
        n,
        walk_grain(n, footprint(rg)),
        || Scratch::new(n),
        |s, range| {
            for src in range {
                fill(rg, &up, src, s);
                // Endpoints: `src` and each distinct second endpoint
                // gain C(d, 2) (Lemma 4.2 Eq. 1).
                let mut src_total = 0u64;
                for &z in &s.ctr.touched {
                    let b = choose2(s.ctr.cnt[z as usize] as u64);
                    if b > 0 {
                        src_total += b;
                        atomic_add(&out[z as usize], b);
                    }
                }
                atomic_add(&out[src], src_total);
                // Centers: d - 1 per wedge, re-walking the same two-hop
                // loop against the finished counters (this replaces the
                // wedge buffer the batching engines keep).
                for (i, &y) in up.nbrs(src).iter().enumerate() {
                    let pre = s.pres[i] as usize;
                    let mut center = 0u64;
                    for &z in &rg.nbrs(y as usize)[..pre] {
                        center += s.ctr.cnt[z as usize] as u64 - 1;
                    }
                    atomic_add(&out[y as usize], center);
                }
                s.ctr.reset();
            }
        },
    );
}

/// COUNT-E, two passes per source, flat layout.
fn per_edge_flat(rg: &RankedGraph, out: &[AtomicU64]) {
    let up = rg.up_csr();
    let n = rg.n();
    parallel_for_dynamic_with(
        n,
        walk_grain(n, footprint(rg)),
        || Scratch::new(n),
        |s, range| {
            for src in range {
                fill(rg, &up, src, s);
                // Both legs of every wedge gain d - 1 (Lemma 4.2
                // Eq. 2): the (src, y) leg accumulates across y's
                // wedges, the (y, z) leg is credited per wedge.
                let eids = up.eids(src);
                for (i, &y) in up.nbrs(src).iter().enumerate() {
                    let pre = s.pres[i] as usize;
                    let ynbrs = &rg.nbrs(y as usize)[..pre];
                    let yeids = &rg.eids(y as usize)[..pre];
                    let mut lo_leg = 0u64;
                    for j in 0..pre {
                        let d = s.ctr.cnt[ynbrs[j] as usize] as u64;
                        if d > 1 {
                            lo_leg += d - 1;
                            atomic_add(&out[yeids[j] as usize], d - 1);
                        }
                    }
                    atomic_add(&out[eids[i] as usize], lo_leg);
                }
                s.ctr.reset();
            }
        },
    );
}

/// Per-worker scratch of the hub walk: the flat scratch plus the
/// hub-split/cursor arrays of the blocked fill, the source's
/// up-neighborhood bitmap (for AND/popcount hub probes), and the hot
/// set of butterfly-carrying endpoints for the credit sweeps.
struct HubScratch {
    ctr: TouchedCounter,
    pres: Vec<u32>,
    /// Per center: how many prefix entries are non-hub (the hub tail
    /// sits at the *end* of the decreasing-rank prefix).
    hsp: Vec<u32>,
    /// Per center: cursor into the non-hub prefix for the tiled fill.
    cur: Vec<u32>,
    srcbits: Bitset,
    /// Word indices `srcbits` populates, sorted (drives the sparse
    /// AND/popcount and the O(#words) bitmap reset).
    srcwords: Vec<u32>,
    hot: Vec<u32>,
    hotbits: Bitset,
}

impl HubScratch {
    fn new(n: usize) -> Self {
        Self {
            ctr: TouchedCounter::new(n),
            pres: Vec::new(),
            hsp: Vec::new(),
            cur: Vec::new(),
            srcbits: Bitset::new(n),
            srcwords: Vec::new(),
            hot: Vec::new(),
            hotbits: Bitset::new(n),
        }
    }
}

/// Tally the wedges of `src` into `s.ctr` under the hub layout.
///
/// Identical final counts to [`fill`]: a hub second endpoint `z` is
/// counted in the prefix of every center `y` in `N_up(src) ∩ N(z)`
/// (the prefix filter `rank > src` constrains only `z`, and every hub
/// outranks `src` wherever it appears in a prefix), so one AND/popcount
/// of the source's up-neighborhood bitmap against `z`'s adjacency row
/// equals its flat bump count.  Non-hub endpoints are bumped exactly as
/// in the flat walk, just tiled by descending rank.
fn fill_hub(eff: &RankedGraph, up: &UpCsr, view: &HubView, src: usize, s: &mut HubScratch) {
    let r = src as u32;
    let hubs = view.hub_count as u32;
    s.pres.clear();
    s.hsp.clear();
    let unbrs = up.nbrs(src);
    // Hub second endpoints must outrank `src`, so only sources ranked
    // below the hub prefix can ever meet one.
    let use_bm = hubs > 0 && r + 1 < hubs;
    if use_bm {
        s.srcwords.clear();
        for &y in unbrs {
            let w = y >> 6;
            if s.srcwords.last() != Some(&w) {
                s.srcwords.push(w);
            }
            s.srcbits.set(y);
        }
    }
    for &y in unbrs {
        let pre = eff.up_deg_above(y as usize, r);
        let slice = &eff.nbrs(y as usize)[..pre];
        // Decreasing rank: hubs (ranks < hub_count) are the tail.
        let hs = if use_bm { slice.partition_point(|&z| z >= hubs) } else { pre };
        s.pres.push(pre as u32);
        s.hsp.push(hs as u32);
        // One popcount per *distinct* hub endpoint; repeats find the
        // slot already filled (and L1-resident: hub slots are the
        // dense `cnt[0..hub_count]` prefix).
        for &z in &slice[hs..] {
            if s.ctr.cnt[z as usize] == 0 {
                s.ctr.touched.push(z);
                s.ctr.cnt[z as usize] =
                    and_popcount_at(&s.srcwords, s.srcbits.words(), view.bitmap.row(z as usize))
                        as u32;
            }
        }
    }
    if use_bm {
        s.srcbits.clear_words(&s.srcwords);
    }
    // Non-hub fill.  The whole remaining rank span usually fits one
    // tile; otherwise walk it in descending-rank tiles with a monotone
    // cursor per center (prefixes are rank-sorted, so cursors never
    // back up) — every bump then lands in a `TILE_RANKS`-slot counter
    // slice that stays L2-resident across all centers.
    let n = eff.n();
    let lo_bound = (src + 1).max(hubs as usize);
    if n.saturating_sub(lo_bound) <= TILE_RANKS {
        for (i, &y) in unbrs.iter().enumerate() {
            let hs = s.hsp[i] as usize;
            for &z in &eff.nbrs(y as usize)[..hs] {
                s.ctr.bump(z);
            }
        }
    } else {
        s.cur.clear();
        s.cur.resize(unbrs.len(), 0);
        let mut hi = n;
        while hi > lo_bound {
            let tile_lo = hi.saturating_sub(TILE_RANKS).max(lo_bound) as u32;
            for (i, &y) in unbrs.iter().enumerate() {
                let hs = s.hsp[i] as usize;
                let row = &eff.nbrs(y as usize)[..hs];
                let mut j = s.cur[i] as usize;
                while j < hs && row[j] >= tile_lo {
                    s.ctr.bump(row[j]);
                    j += 1;
                }
                s.cur[i] = j as u32;
            }
            hi = tile_lo as usize;
        }
    }
}

/// After a fill: credit endpoints (when `out` is given) and extract
/// the hot set — distinct second endpoints with `d >= 2`, the only
/// ones contributing nonzero credits anywhere.  Returns the source's
/// own butterfly total.
fn collect_hot(
    s: &mut HubScratch,
    view: &HubView,
    out: Option<&[AtomicU64]>,
    src_total: &mut u64,
) {
    s.hot.clear();
    *src_total = 0;
    for &z in &s.ctr.touched {
        let d = s.ctr.cnt[z as usize];
        if d >= 2 {
            let b = choose2(d as u64);
            *src_total += b;
            if let Some(out) = out {
                atomic_add(&out[view.back_rank(z as usize)], b);
            }
            s.hot.push(z);
            s.hotbits.set(z);
        }
    }
}

#[inline]
fn clear_hot(s: &mut HubScratch) {
    for &z in &s.hot {
        s.hotbits.clear(z);
    }
    s.ctr.reset();
}

/// Global butterfly count under the hub layout.
fn total_hub(rg: &RankedGraph, view: &HubView) -> u64 {
    let eff = view.graph(rg);
    let up = eff.up_csr();
    let n = eff.n();
    let acc = AtomicU64::new(0);
    parallel_for_dynamic_with(
        n,
        walk_grain(n, footprint(eff)),
        || HubScratch::new(n),
        |s, range| {
            let mut local = 0u64;
            for src in range {
                fill_hub(eff, &up, view, src, s);
                s.ctr.drain(|_z, d| local += choose2(d as u64));
            }
            atomic_add(&acc, local);
        },
    );
    acc.into_inner()
}

/// COUNT-V under the hub layout; `out` stays in the caller's rank
/// space (credits route through [`HubView::back_rank`]).
fn per_vertex_hub(rg: &RankedGraph, view: &HubView, out: &[AtomicU64]) {
    let eff = view.graph(rg);
    let up = eff.up_csr();
    let n = eff.n();
    parallel_for_dynamic_with(
        n,
        walk_grain(n, footprint(eff)),
        || HubScratch::new(n),
        |s, range| {
            for src in range {
                fill_hub(eff, &up, view, src, s);
                let mut src_total = 0u64;
                collect_hot(s, view, Some(out), &mut src_total);
                atomic_add(&out[view.back_rank(src)], src_total);
                // Center credits: a wedge contributes d - 1, which is
                // zero unless its second endpoint is hot — so skip the
                // whole re-walk for butterfly-free sources, and filter
                // the rest through the hot bitmap.
                if !s.hot.is_empty() {
                    for (i, &y) in up.nbrs(src).iter().enumerate() {
                        let pre = s.pres[i] as usize;
                        let mut center = 0u64;
                        for &z in &eff.nbrs(y as usize)[..pre] {
                            if s.hotbits.test(z) {
                                center += s.ctr.cnt[z as usize] as u64 - 1;
                            }
                        }
                        atomic_add(&out[view.back_rank(y as usize)], center);
                    }
                }
                clear_hot(s);
            }
        },
    );
}

/// COUNT-E under the hub layout (edge ids are rank-independent, so
/// `out` needs no mapping).
fn per_edge_hub(rg: &RankedGraph, view: &HubView, out: &[AtomicU64]) {
    let eff = view.graph(rg);
    let up = eff.up_csr();
    let n = eff.n();
    parallel_for_dynamic_with(
        n,
        walk_grain(n, footprint(eff)),
        || HubScratch::new(n),
        |s, range| {
            for src in range {
                fill_hub(eff, &up, view, src, s);
                let mut src_total = 0u64;
                collect_hot(s, view, None, &mut src_total);
                if !s.hot.is_empty() {
                    let eids = up.eids(src);
                    for (i, &y) in up.nbrs(src).iter().enumerate() {
                        let pre = s.pres[i] as usize;
                        let ynbrs = &eff.nbrs(y as usize)[..pre];
                        let yeids = &eff.eids(y as usize)[..pre];
                        let mut lo_leg = 0u64;
                        for j in 0..pre {
                            let z = ynbrs[j];
                            if s.hotbits.test(z) {
                                let d = s.ctr.cnt[z as usize] as u64;
                                lo_leg += d - 1;
                                atomic_add(&out[yeids[j] as usize], d - 1);
                            }
                        }
                        atomic_add(&out[eids[i] as usize], lo_leg);
                    }
                }
                clear_hot(s);
            }
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::{count_per_edge, count_per_vertex, count_total, CountOpts, Engine};
    use crate::graph::gen;
    use crate::rank::{preprocess, Ranking};
    use crate::testutil::brute;

    fn iopts() -> CountOpts {
        CountOpts { engine: Engine::Intersect, ..Default::default() }
    }

    #[test]
    fn davis_matches_brute_force() {
        let g = gen::davis_southern_women();
        assert_eq!(count_total(&g, &iopts()), brute::total(&g));
    }

    #[test]
    fn matches_brute_force_on_random_graphs_all_rankings() {
        for seed in [2, 11] {
            let g = gen::erdos_renyi(24, 28, 210, seed);
            let expect_t = brute::total(&g);
            let (ebu, ebv) = brute::per_vertex(&g);
            let ebe = brute::per_edge(&g);
            for ranking in Ranking::ALL {
                let opts = CountOpts { ranking, ..iopts() };
                assert_eq!(count_total(&g, &opts), expect_t, "seed={seed} {ranking:?}");
                let vc = count_per_vertex(&g, &opts);
                assert_eq!(vc.bu, ebu, "seed={seed} {ranking:?}");
                assert_eq!(vc.bv, ebv, "seed={seed} {ranking:?}");
                assert_eq!(count_per_edge(&g, &opts), ebe, "seed={seed} {ranking:?}");
            }
        }
    }

    #[test]
    fn skewed_graph_exercises_dynamic_claims() {
        let g = gen::chung_lu(90, 110, 1400, 2.1, 17);
        let rg = preprocess(&g, Ranking::Degree);
        for t in [1usize, 3, 8] {
            for layout in crate::graph::Layout::ALL {
                let total =
                    crate::prims::pool::with_threads(t, || total_intersect(&rg, layout));
                assert_eq!(total, brute::total(&g), "threads={t} layout={}", layout.name());
            }
        }
    }

    #[test]
    fn hub_layout_matches_flat_on_all_rankings() {
        use crate::graph::Layout;
        use std::sync::atomic::{AtomicU64, Ordering};
        // Skewed enough that the forced hub layout actually builds
        // bitmaps; non-Degree rankings exercise the renumbering path.
        let g = gen::chung_lu(80, 100, 1200, 2.1, 29);
        for ranking in Ranking::ALL {
            let rg = preprocess(&g, ranking);
            assert_eq!(
                total_intersect(&rg, Layout::Flat),
                total_intersect(&rg, Layout::Hub),
                "{ranking:?}"
            );
            let n = rg.n();
            let m = rg.m();
            let mk = |len: usize| -> Vec<AtomicU64> {
                (0..len).map(|_| AtomicU64::new(0)).collect()
            };
            let (vf, vh) = (mk(n), mk(n));
            per_vertex_intersect(&rg, Layout::Flat, &vf);
            per_vertex_intersect(&rg, Layout::Hub, &vh);
            for x in 0..n {
                assert_eq!(
                    vf[x].load(Ordering::Relaxed),
                    vh[x].load(Ordering::Relaxed),
                    "{ranking:?} vertex rank {x}"
                );
            }
            let (ef, eh) = (mk(m), mk(m));
            per_edge_intersect(&rg, Layout::Flat, &ef);
            per_edge_intersect(&rg, Layout::Hub, &eh);
            for e in 0..m {
                assert_eq!(
                    ef[e].load(Ordering::Relaxed),
                    eh[e].load(Ordering::Relaxed),
                    "{ranking:?} edge {e}"
                );
            }
        }
    }

    #[test]
    fn edge_stamp_set_get_hit_reset() {
        let mut s = EdgeStamp::new(8);
        assert_eq!(s.get(3), None);
        assert!(!s.hit(3));
        s.set(3, 17);
        s.set(5, 0);
        s.set(3, 18); // overwrite keeps one touched entry
        assert_eq!(s.get(3), Some(18));
        assert!(s.hit(3) && s.hit(5) && !s.hit(0));
        assert_eq!(s.get(5), Some(0));
        assert_eq!(s.get(0), None);
        s.reset();
        assert_eq!(s.get(3), None);
        assert!(!s.hit(3) && !s.hit(5));
        assert_eq!(s.get(5), None);
        assert!(s.touched.is_empty());
    }

    #[test]
    fn empty_and_wedgeless_graphs() {
        let g = gen::erdos_renyi(5, 5, 0, 1);
        assert_eq!(count_total(&g, &iopts()), 0);
        // A perfect matching has wedges nowhere.
        let edges: Vec<(u32, u32)> = (0..4).map(|i| (i, i)).collect();
        let g = crate::graph::BipartiteGraph::from_edges(4, 4, &edges);
        assert_eq!(count_total(&g, &iopts()), 0);
        assert!(count_per_edge(&g, &iopts()).iter().all(|&c| c == 0));
    }
}
