//! Batching wedge aggregation (§3.1.2, partially parallel).
//!
//! Sources are processed in parallel; each worker owns a dense
//! `n`-slot count array and aggregates the wedges of one source at a
//! time *serially* — "an array large enough to contain all possible
//! second endpoints".  Butterfly counts go straight into the output via
//! atomic adds (batching supports only atomic butterfly aggregation —
//! footnote 4).
//!
//! * **BatchS** (simple): static contiguous split of the sources over
//!   workers — best locality, but skewed wedge counts imbalance work.
//! * **BatchWA** (wedge-aware): workers claim small source ranges from
//!   an atomic counter, dynamically balancing by actual wedge work.
//!
//! Note a key's wedges all live within one source, so the per-source
//! serial aggregation sees every wedge of each key — `C(d, 2)` is
//! computed on complete multiplicities.

use std::sync::atomic::AtomicU64;

use super::wedges::{wedges_of_source, Wedge};
use super::{atomic_add, choose2};
use crate::graph::RankedGraph;
use crate::prims::pool::{parallel_for_chunks_with, parallel_for_dynamic_with};

/// Per-worker scratch: dense second-endpoint counts, touched list, and
/// the materialized wedges of the current source.
struct Scratch {
    cnt: Vec<u32>,
    touched: Vec<u32>,
    wbuf: Vec<Wedge>,
}

impl Scratch {
    fn new(n: usize) -> Self {
        Self { cnt: vec![0u32; n], touched: Vec::new(), wbuf: Vec::new() }
    }
}

/// Dynamic-claim grain for BatchWA (sources per claim).
const WA_GRAIN: usize = 8;

/// Run `handle(src, scratch)` for every source, with per-worker scratch
/// reuse.  `dynamic` picks BatchWA scheduling, otherwise BatchS.
/// `need_wedges` controls whether the per-source wedges are buffered
/// (§Perf: total counting only needs the per-endpoint multiplicities,
/// so skipping the 16-byte-per-wedge buffer removes most of its memory
/// traffic).
fn run_batch(
    rg: &RankedGraph,
    cache_opt: bool,
    dynamic: bool,
    need_wedges: bool,
    handle: impl Fn(usize, &mut Scratch) + Sync,
) {
    let n = rg.n();
    // Fill the per-source scratch: count wedges by second endpoint.
    let fill = |src: usize, s: &mut Scratch| {
        s.wbuf.clear();
        s.touched.clear();
        if need_wedges {
            wedges_of_source(rg, cache_opt, src, |w| {
                let other = if cache_opt { w.lo } else { w.hi };
                if s.cnt[other as usize] == 0 {
                    s.touched.push(other);
                }
                s.cnt[other as usize] += 1;
                s.wbuf.push(w);
            });
        } else {
            wedges_of_source(rg, cache_opt, src, |w| {
                let other = if cache_opt { w.lo } else { w.hi };
                if s.cnt[other as usize] == 0 {
                    s.touched.push(other);
                }
                s.cnt[other as usize] += 1;
            });
        }
    };
    let per_range = |s: &mut Scratch, r: std::ops::Range<usize>| {
        for src in r {
            fill(src, s);
            handle(src, s);
            for &o in &s.touched {
                s.cnt[o as usize] = 0;
            }
        }
    };
    if dynamic {
        parallel_for_dynamic_with(n, WA_GRAIN, || Scratch::new(n), per_range);
    } else {
        parallel_for_chunks_with(n, || Scratch::new(n), per_range);
    }
}

/// Global count via batching.
pub fn total_batch(rg: &RankedGraph, cache_opt: bool, dynamic: bool) -> u64 {
    let acc = AtomicU64::new(0);
    run_batch(rg, cache_opt, dynamic, false, |_src, s| {
        let mut local = 0u64;
        for &o in &s.touched {
            local += choose2(s.cnt[o as usize] as u64);
        }
        atomic_add(&acc, local);
    });
    acc.into_inner()
}

/// COUNT-V via batching (rank-indexed output).
pub fn per_vertex_batch(rg: &RankedGraph, cache_opt: bool, dynamic: bool, out: &[AtomicU64]) {
    run_batch(rg, cache_opt, dynamic, true, |src, s| {
        // Endpoints: the source and each distinct second endpoint gain
        // C(d, 2); the source's own contribution accumulates locally.
        let mut src_total = 0u64;
        for &o in &s.touched {
            let d = s.cnt[o as usize] as u64;
            let b = choose2(d);
            if b > 0 {
                src_total += b;
                atomic_add(&out[o as usize], b);
            }
        }
        atomic_add(&out[src], src_total);
        // Centers: d - 1 per wedge.
        for w in &s.wbuf {
            let other = if cache_opt { w.lo } else { w.hi };
            let d = s.cnt[other as usize] as u64;
            atomic_add(&out[w.center as usize], d - 1);
        }
    });
}

/// COUNT-E via batching (edge-id-indexed output).
pub fn per_edge_batch(rg: &RankedGraph, cache_opt: bool, dynamic: bool, out: &[AtomicU64]) {
    run_batch(rg, cache_opt, dynamic, true, |_src, s| {
        for w in &s.wbuf {
            let other = if cache_opt { w.lo } else { w.hi };
            let d = s.cnt[other as usize] as u64;
            if d > 1 {
                atomic_add(&out[w.e_lo as usize], d - 1);
                atomic_add(&out[w.e_hi as usize], d - 1);
            }
        }
    });
}
