//! Butterfly-as-a-service: a resident query daemon over [`DynGraph`].
//!
//! The rest of the crate is one-shot — load, count, exit.  This
//! module keeps graphs resident and serves concurrent read queries
//! (global / per-vertex / per-edge counts, tip and wing numbers,
//! top-k densest vertices) while a single writer thread applies
//! update batches through the paper's batch-dynamic delta-maintenance
//! path (ParButterfly, arXiv 1907.08607; delta rule after Wang et
//! al.).  See ARCHITECTURE.md §"Serve mode" for the epoch lifecycle
//! diagram.
//!
//! Layering:
//!
//! * [`snapshot`] — immutable [`ServedSnapshot`]s and the
//!   [`SnapshotCell`] epoch swap that gives readers snapshot isolation
//!   without ever blocking the writer.
//! * [`session`] — the [`Session`]: writer thread, admission batching
//!   ([`ServeOpts`]), the shared per-batch retry/error accounting, and
//!   graceful degradation (a poisoned writer serves stale snapshots
//!   with a warning flag instead of killing the daemon).
//! * [`protocol`] — the line/JSON request surface, shared verbatim by
//!   the stdin/stdout transport and the TCP listener below.
//!
//! ```no_run
//! use parbutterfly::graph::gen;
//! use parbutterfly::serve::{Session, ServeOpts};
//!
//! let g = gen::chung_lu(5_000, 8_000, 120_000, 2.1, 42);
//! let session = Session::open(g, ServeOpts::default()).unwrap();
//! let snap = session.snapshot();
//! println!("epoch {}: {} butterflies", snap.epoch, snap.global);
//! ```
//!
//! [`DynGraph`]: crate::dynamic::DynGraph

// Runtime-critical modules must not abort through unchecked unwraps:
// failures either unwind as structured panics the pool catches or are
// returned as `error::Result`.  Test code is exempt.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod protocol;
pub mod session;
pub mod snapshot;

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;
use std::thread;

pub use protocol::{handle_line, handle_request, Reply};
pub use session::{RebuildReply, ServeOpts, ServeStats, Session, UpdateReply};
pub use snapshot::{ServedSnapshot, SnapshotCell};

/// Drive the protocol over a pair of line streams: one response line
/// per request line, flushed immediately (clients pipeline over pipes
/// and sockets).  Returns after a `shutdown` request or at EOF.
pub fn serve_lines(
    session: &Session,
    input: impl BufRead,
    mut output: impl Write,
) -> io::Result<()> {
    for line in input.lines() {
        let line = line?;
        if let Some(reply) = protocol::handle_line(session, &line) {
            writeln!(output, "{}", reply.text)?;
            output.flush()?;
            if reply.shutdown {
                break;
            }
        }
    }
    Ok(())
}

/// Bind a TCP listener (e.g. `"127.0.0.1:0"` for an ephemeral port)
/// and accept connections on a background thread, each served by
/// [`serve_lines`] on its own thread.  Returns the bound address —
/// the part a test or example needs to connect a client.  The accept
/// loop runs until the process exits; a `shutdown` request stops the
/// session's writer but only closes the requesting connection.
pub fn spawn_listener(
    session: Arc<Session>,
    addr: &str,
) -> io::Result<(SocketAddr, thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let accept = thread::Builder::new().name("pb-serve-accept".into()).spawn(move || {
        for conn in listener.incoming() {
            let Ok(conn) = conn else { continue };
            let session = Arc::clone(&session);
            let spawned = thread::Builder::new().name("pb-serve-conn".into()).spawn(move || {
                let Ok(read_half) = conn.try_clone() else { return };
                let _ = serve_lines(&session, BufReader::new(read_half), conn);
            });
            drop(spawned); // a connection we failed to spawn for just closes
        }
    })?;
    Ok((local, accept))
}
