//! Immutable served snapshots and the epoch cell publishing them.
//!
//! Every read query in serve mode is answered from exactly one
//! [`ServedSnapshot`]: the writer thread builds a fresh snapshot after
//! each admitted batch and publishes it atomically through a
//! [`SnapshotCell`], so a reader that grabbed epoch `e` sees the
//! global count, both per-vertex arrays, the per-edge array, and the
//! optional tip/wing decompositions of the **same** post-batch state —
//! torn reads across granularities are impossible by construction,
//! not by locking discipline in the query handlers.

use std::sync::{Arc, RwLock};

use crate::dynamic::DynGraph;
use crate::error::Result;
use crate::graph::BipartiteGraph;
use crate::peel::{self, PeelEOpts, PeelSide, PeelVOpts};

/// One internally consistent set of served state.  Immutable once
/// published; readers hold it by `Arc` and the writer never touches a
/// published snapshot again.
#[derive(Clone, Debug)]
pub struct ServedSnapshot {
    /// Publication counter: 0 is the initial count, each admitted
    /// batch (and each successful rebuild) increments it.
    pub epoch: u64,
    /// True when the writer hit an unrecoverable failure and this
    /// snapshot is being served **stale**: its counts describe the
    /// last good epoch, updates are refused until a `rebuild`.
    pub degraded: bool,
    /// The failure that forced degradation, stringified.
    pub degraded_reason: Option<String>,
    /// The graph the counts describe (owned copy: edge-id lookups and
    /// static recounts of this epoch need the exact structure).
    pub graph: BipartiteGraph,
    /// Global butterfly count.
    pub global: u64,
    /// Per-vertex butterfly counts, U side.
    pub per_u: Vec<u64>,
    /// Per-vertex butterfly counts, V side.
    pub per_v: Vec<u64>,
    /// Per-edge butterfly counts, indexed by this graph's edge ids.
    pub per_edge: Vec<u64>,
    /// Tip numbers of the U side (`None` when decompositions are off).
    pub tips_u: Option<Vec<u64>>,
    /// Tip numbers of the V side.
    pub tips_v: Option<Vec<u64>>,
    /// Wing numbers, indexed by this graph's edge ids.
    pub wings: Option<Vec<u64>>,
}

impl ServedSnapshot {
    /// Materialize the current state of `dg` as epoch `epoch`.  With
    /// `decompositions`, tip numbers of both sides and wing numbers
    /// are peeled from the maintained counts (under the update budget
    /// carried by `dg`'s options); a failure in the peel surfaces as
    /// `Err` and the caller decides whether to degrade.
    pub fn build(dg: &DynGraph, epoch: u64, decompositions: bool) -> Result<Self> {
        let g = dg.graph().clone();
        let (tips_u, tips_v, wings) = if decompositions {
            let vopts = PeelVOpts { side: PeelSide::U, ..Default::default() };
            let tu = peel::peel_vertices(&g, dg.per_vertex_u(), dg.per_vertex_v(), &vopts)?;
            let vopts = PeelVOpts { side: PeelSide::V, ..Default::default() };
            let tv = peel::peel_vertices(&g, dg.per_vertex_u(), dg.per_vertex_v(), &vopts)?;
            let w = peel::peel_edges(&g, dg.per_edge(), &PeelEOpts::default())?;
            (Some(tu.tips), Some(tv.tips), Some(w.wings))
        } else {
            (None, None, None)
        };
        Ok(ServedSnapshot {
            epoch,
            degraded: false,
            degraded_reason: None,
            graph: g,
            global: dg.total(),
            per_u: dg.per_vertex_u().to_vec(),
            per_v: dg.per_vertex_v().to_vec(),
            per_edge: dg.per_edge().to_vec(),
            tips_u,
            tips_v,
            wings,
        })
    }

    /// The degraded twin of `prev`: same epoch, same counts (they are
    /// the last good state and stay servable), flag set.  Published
    /// when the writer cannot bring the counts forward — readers keep
    /// getting consistent answers, just stale and marked as such.
    pub(crate) fn degraded_from(prev: &ServedSnapshot, reason: String) -> Self {
        ServedSnapshot {
            degraded: true,
            degraded_reason: Some(reason),
            ..prev.clone()
        }
    }
}

/// The publication point: a single `RwLock<Arc<_>>` the writer stores
/// into and readers clone out of.  Readers hold the lock only for the
/// `Arc` clone (never across query evaluation), so the writer is never
/// blocked behind a slow query and a query never observes a half-
/// published snapshot.
pub struct SnapshotCell {
    cur: RwLock<Arc<ServedSnapshot>>,
}

impl SnapshotCell {
    pub fn new(snap: ServedSnapshot) -> Self {
        SnapshotCell { cur: RwLock::new(Arc::new(snap)) }
    }

    /// The currently published snapshot.  Lock poisoning cannot leave
    /// a torn value behind (the guarded section is a pointer clone /
    /// swap), so a poisoned lock is recovered, not propagated.
    pub fn load(&self) -> Arc<ServedSnapshot> {
        let guard = self.cur.read().unwrap_or_else(|p| p.into_inner());
        Arc::clone(&guard)
    }

    /// Publish a new snapshot (writer thread only).
    pub fn store(&self, snap: ServedSnapshot) {
        let mut guard = self.cur.write().unwrap_or_else(|p| p.into_inner());
        *guard = Arc::new(snap);
    }
}
