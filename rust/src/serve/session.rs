//! A resident serve-mode session: one writer thread owning a
//! [`DynGraph`], many readers holding [`Arc`] snapshots.
//!
//! ## Reader/writer coordination invariants
//!
//! 1. The writer is the **only** thread that ever touches the
//!    `DynGraph`; it applies admitted batches through the shared
//!    retry-and-rebuild policy
//!    ([`apply_batch_with_retry`](crate::dynamic::apply_batch_with_retry))
//!    and publishes each result as a fresh immutable
//!    [`ServedSnapshot`].
//! 2. Readers only ever [`SnapshotCell::load`] — an `Arc` clone under
//!    a read lock held for nanoseconds — so no query can block the
//!    writer and no writer step can tear a query.
//! 3. A failure the retry policy cannot absorb **degrades** the
//!    session instead of killing it: the last good snapshot keeps
//!    being served with its `degraded` flag set, update requests are
//!    refused with [`ErrorKind::Degraded`], and an explicit `rebuild`
//!    (a guarded full recount) is the way back to a live epoch.

use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use crate::dynamic::{
    apply_batch_with_retry, BatchError, BatchKind, BatchOutcome, DynGraph, DynOpts, RetryOutcome,
};
use crate::error::{Error, ErrorKind, Result};
use crate::graph::BipartiteGraph;
use crate::prims::pool::with_threads;

use super::snapshot::{ServedSnapshot, SnapshotCell};

/// Configuration of a serve-mode session.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Engine/budget configuration of the underlying [`DynGraph`];
    /// `dyn_opts.count.budget` is the cooperative budget of every
    /// batch application and rebuild.
    pub dyn_opts: DynOpts,
    /// Maintain tip/wing decompositions in every snapshot (tip/wing
    /// and decomposition top-k queries need them; counting-only
    /// deployments turn this off to cheapen the publish step).
    pub decompositions: bool,
    /// Admission batching: coalesce queued same-kind update requests
    /// into one batch until this many edges are pending...
    pub admit_max_edges: usize,
    /// ...or this much time has passed since the first request of the
    /// group (milliseconds).  `0` coalesces only what is already
    /// queued (pure size batching, no added latency) — the default,
    /// and what the deterministic protocol tests rely on.
    pub admit_max_ms: u64,
    /// Apply batches through the shared one-shot retry policy (the
    /// replay driver's behavior).  `false` degrades on the first
    /// failure — the deterministic choice for fault drills.
    pub retry: bool,
    /// Pin the writer's parallelism ([`with_threads`]); `None`
    /// inherits the process default.  The writer runs on its own
    /// thread, so a caller's thread-local override does not reach it —
    /// this is the explicit channel.
    pub threads: Option<usize>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        Self {
            dyn_opts: DynOpts::default(),
            decompositions: true,
            admit_max_edges: 4096,
            admit_max_ms: 0,
            retry: true,
            threads: None,
        }
    }
}

/// Aggregate accounting of a session's writer, readable at any time.
/// Per-batch failures reuse the replay driver's [`BatchError`] — one
/// error type across both drivers (`DynReport.errors` and serve).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Admitted batches applied (coalesced groups, not requests).
    pub batches: usize,
    /// Edges actually inserted / deleted across all batches.
    pub inserted: usize,
    pub deleted: usize,
    /// No-op edges (duplicates, present inserts, absent deletes).
    pub skipped: usize,
    /// Update requests refused while degraded.
    pub rejected: usize,
    /// True while the session serves a stale snapshot.
    pub degraded: bool,
    /// Per-batch failures, in admission order (`batch` is the
    /// admitted-group sequence number).
    pub errors: Vec<BatchError>,
}

/// Synchronous answer to an update request: the state of the session
/// after the admitted group containing the request was resolved.
/// `applied`/`skipped` describe the whole group (admission batching
/// folds concurrent same-kind requests into one batch).
#[derive(Clone, Debug)]
pub struct UpdateReply {
    /// Epoch of the snapshot the caller's edges are visible in (or the
    /// stale epoch still being served when the request was refused).
    pub epoch: u64,
    pub applied: usize,
    pub skipped: usize,
    /// The group failed once and the one-shot retry applied it.
    pub recovered: bool,
    /// The session is (now) degraded.
    pub degraded: bool,
    /// Set when the request was refused or dropped; `applied` and
    /// `skipped` are then 0.
    pub error: Option<String>,
}

/// Synchronous answer to a rebuild request.
#[derive(Clone, Debug)]
pub struct RebuildReply {
    /// Epoch after the rebuild (unchanged when it failed).
    pub epoch: u64,
    /// Set when the rebuild failed; the session stays degraded.
    pub error: Option<String>,
}

enum Cmd {
    Update { kind: BatchKind, edges: Vec<(u32, u32)>, done: mpsc::Sender<UpdateReply> },
    Rebuild { done: mpsc::Sender<RebuildReply> },
    Shutdown,
}

/// Recover a possibly poisoned mutex: the guarded values are plain
/// accounting structs a panicking writer cannot leave torn in any way
/// that matters more than losing the session entirely would.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// A resident graph with a single writer thread and any number of
/// snapshot readers.  Dropping the session shuts the writer down and
/// joins it; reads keep working off the final snapshot for as long as
/// the [`SnapshotCell`] is shared.
pub struct Session {
    cell: Arc<SnapshotCell>,
    tx: Mutex<Option<mpsc::Sender<Cmd>>>,
    stats: Arc<Mutex<ServeStats>>,
    writer: Mutex<Option<thread::JoinHandle<()>>>,
}

impl Session {
    /// Open a session over `g`: one guarded initial count (epoch 0),
    /// then a dedicated writer thread.
    pub fn open(g: BipartiteGraph, opts: ServeOpts) -> Result<Session> {
        let dg = DynGraph::new(g, opts.dyn_opts.clone())?;
        let snap = ServedSnapshot::build(&dg, 0, opts.decompositions)?;
        let cell = Arc::new(SnapshotCell::new(snap));
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let (tx, rx) = mpsc::channel();
        let threads = opts.threads;
        let w = Writer {
            dg,
            cell: Arc::clone(&cell),
            stats: Arc::clone(&stats),
            opts,
            epoch: 0,
            degraded: None,
            seq: 0,
        };
        let writer = thread::Builder::new()
            .name("pb-serve-writer".into())
            .spawn(move || match threads {
                Some(t) => with_threads(t, || w.run(rx)),
                None => w.run(rx),
            })
            .map_err(|e| Error::new(ErrorKind::Panic(format!("spawn writer thread: {e}"))))?;
        Ok(Session {
            cell,
            tx: Mutex::new(Some(tx)),
            stats,
            writer: Mutex::new(Some(writer)),
        })
    }

    /// The currently published snapshot (wait-free for the writer).
    pub fn snapshot(&self) -> Arc<ServedSnapshot> {
        self.cell.load()
    }

    /// Writer accounting so far.
    pub fn stats(&self) -> ServeStats {
        lock(&self.stats).clone()
    }

    /// Submit an update and wait for the admitted group containing it
    /// to resolve.  Never panics: a dead writer (shut down, or lost to
    /// a bug) yields a degraded reply while reads keep serving.
    pub fn update(&self, kind: BatchKind, edges: Vec<(u32, u32)>) -> UpdateReply {
        let (done, back) = mpsc::channel();
        if self.send(Cmd::Update { kind, edges, done }) {
            if let Ok(reply) = back.recv() {
                return reply;
            }
        }
        let snap = self.cell.load();
        UpdateReply {
            epoch: snap.epoch,
            applied: 0,
            skipped: 0,
            recovered: false,
            degraded: true,
            error: Some("writer is gone; reads still serve the last snapshot".into()),
        }
    }

    /// Request a guarded full recount (the way out of degradation).
    pub fn rebuild(&self) -> RebuildReply {
        let (done, back) = mpsc::channel();
        if self.send(Cmd::Rebuild { done }) {
            if let Ok(reply) = back.recv() {
                return reply;
            }
        }
        let snap = self.cell.load();
        RebuildReply {
            epoch: snap.epoch,
            error: Some("writer is gone; reads still serve the last snapshot".into()),
        }
    }

    /// Stop the writer and join it.  Reads keep answering from the
    /// final snapshot; later updates get the degraded writer-gone
    /// reply.  Idempotent.
    pub fn shutdown(&self) {
        let tx = lock(&self.tx).take();
        if let Some(tx) = tx {
            let _ = tx.send(Cmd::Shutdown);
        }
        let handle = lock(&self.writer).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    fn send(&self, cmd: Cmd) -> bool {
        match lock(&self.tx).as_ref() {
            Some(tx) => tx.send(cmd).is_ok(),
            None => false,
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Writer-thread state.  `epoch`/`degraded` mirror what the published
/// snapshot says; the writer is the only mutator of either.
struct Writer {
    dg: DynGraph,
    cell: Arc<SnapshotCell>,
    stats: Arc<Mutex<ServeStats>>,
    opts: ServeOpts,
    epoch: u64,
    degraded: Option<String>,
    seq: usize,
}

impl Writer {
    fn run(mut self, rx: mpsc::Receiver<Cmd>) {
        let mut carry: Option<Cmd> = None;
        loop {
            let cmd = match carry.take() {
                Some(c) => c,
                None => match rx.recv() {
                    Ok(c) => c,
                    Err(_) => return, // session dropped
                },
            };
            match cmd {
                Cmd::Shutdown => return,
                Cmd::Rebuild { done } => {
                    let reply = self.rebuild();
                    let _ = done.send(reply);
                }
                Cmd::Update { kind, edges, done } => {
                    let (batch, waiters, next) = self.admit(kind, edges, done, &rx);
                    carry = next;
                    self.apply_group(kind, batch, waiters);
                }
            }
        }
    }

    /// Admission batching: starting from one request, coalesce queued
    /// same-kind requests until [`ServeOpts::admit_max_edges`] edges
    /// are pending or [`ServeOpts::admit_max_ms`] has passed.  A
    /// different-kind (or non-update) command ends the group and is
    /// carried back to the main loop.
    fn admit(
        &self,
        kind: BatchKind,
        edges: Vec<(u32, u32)>,
        done: mpsc::Sender<UpdateReply>,
        rx: &mpsc::Receiver<Cmd>,
    ) -> (Vec<(u32, u32)>, Vec<mpsc::Sender<UpdateReply>>, Option<Cmd>) {
        let mut batch = edges;
        let mut waiters = vec![done];
        let mut carry = None;
        let cap = self.opts.admit_max_edges.max(1);
        let deadline = Instant::now() + Duration::from_millis(self.opts.admit_max_ms);
        while batch.len() < cap {
            let next = if self.opts.admit_max_ms == 0 {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(_) => break,
                }
            } else {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline.saturating_duration_since(now)) {
                    Ok(c) => c,
                    Err(_) => break,
                }
            };
            match next {
                Cmd::Update { kind: k2, edges: e2, done: d2 } if k2 == kind => {
                    batch.extend(e2);
                    waiters.push(d2);
                }
                other => {
                    carry = Some(other);
                    break;
                }
            }
        }
        (batch, waiters, carry)
    }

    fn apply_group(
        &mut self,
        kind: BatchKind,
        batch: Vec<(u32, u32)>,
        waiters: Vec<mpsc::Sender<UpdateReply>>,
    ) {
        if let Some(reason) = self.degraded.clone() {
            let err = Error::new(ErrorKind::Degraded { epoch: self.epoch, reason });
            lock(&self.stats).rejected += waiters.len();
            self.reply_all(waiters, UpdateReply {
                epoch: self.epoch,
                applied: 0,
                skipped: 0,
                recovered: false,
                degraded: true,
                error: Some(err.to_string()),
            });
            return;
        }
        let seq = self.seq;
        self.seq += 1;
        let resolved: Result<RetryOutcome> = if self.opts.retry {
            apply_batch_with_retry(&mut self.dg, kind, &batch)
        } else {
            // No retry: the first failure is terminal for this batch
            // and degrades the session — the deterministic fault path.
            match kind {
                BatchKind::Insert => self.dg.insert_edges(&batch),
                BatchKind::Delete => self.dg.delete_edges(&batch),
            }
            .map(RetryOutcome::Clean)
        };
        match resolved {
            Ok(RetryOutcome::Clean(out)) => self.publish_applied(kind, seq, out, None, waiters),
            Ok(RetryOutcome::Recovered { outcome, error }) => {
                self.publish_applied(kind, seq, outcome, Some(error), waiters)
            }
            Ok(RetryOutcome::Skipped { error }) => {
                // Batch dropped, but the retry policy rebuilt the
                // graph back to a usable state: not a degradation.
                lock(&self.stats).errors.push(BatchError {
                    batch: seq,
                    kind,
                    error: error.clone(),
                    recovered: false,
                });
                self.reply_all(waiters, UpdateReply {
                    epoch: self.epoch,
                    applied: 0,
                    skipped: 0,
                    recovered: false,
                    degraded: false,
                    error: Some(error.to_string()),
                });
            }
            Err(e) => self.enter_degraded(kind, seq, e, waiters),
        }
    }

    /// The batch is committed in `dg`; publish it as the next epoch.
    /// A snapshot build that fails (peel fault, budget trip) leaves
    /// the published state at the previous epoch and degrades.
    fn publish_applied(
        &mut self,
        kind: BatchKind,
        seq: usize,
        out: BatchOutcome,
        recovered_from: Option<Error>,
        waiters: Vec<mpsc::Sender<UpdateReply>>,
    ) {
        match ServedSnapshot::build(&self.dg, self.epoch + 1, self.opts.decompositions) {
            Ok(snap) => {
                self.epoch += 1;
                self.cell.store(snap);
                let recovered = recovered_from.is_some();
                {
                    let mut st = lock(&self.stats);
                    st.batches += 1;
                    match kind {
                        BatchKind::Insert => st.inserted += out.applied,
                        BatchKind::Delete => st.deleted += out.applied,
                    }
                    st.skipped += out.skipped;
                    if let Some(error) = recovered_from {
                        st.errors.push(BatchError { batch: seq, kind, error, recovered: true });
                    }
                }
                self.reply_all(waiters, UpdateReply {
                    epoch: self.epoch,
                    applied: out.applied,
                    skipped: out.skipped,
                    recovered,
                    degraded: false,
                    error: None,
                });
            }
            Err(e) => self.enter_degraded(kind, seq, e, waiters),
        }
    }

    /// Stale-snapshot-with-warning instead of daemon death: republish
    /// the last good counts with the degraded flag, refuse updates
    /// from here on, wait for an explicit rebuild.
    fn enter_degraded(
        &mut self,
        kind: BatchKind,
        seq: usize,
        e: Error,
        waiters: Vec<mpsc::Sender<UpdateReply>>,
    ) {
        let reason = e.to_string();
        self.degraded = Some(reason.clone());
        let prev = self.cell.load();
        self.cell.store(ServedSnapshot::degraded_from(&prev, reason.clone()));
        {
            let mut st = lock(&self.stats);
            st.degraded = true;
            st.errors.push(BatchError { batch: seq, kind, error: e, recovered: false });
        }
        let err = Error::new(ErrorKind::Degraded { epoch: self.epoch, reason });
        self.reply_all(waiters, UpdateReply {
            epoch: self.epoch,
            applied: 0,
            skipped: 0,
            recovered: false,
            degraded: true,
            error: Some(err.to_string()),
        });
    }

    fn rebuild(&mut self) -> RebuildReply {
        let rebuilt = self
            .dg
            .rebuild()
            .and_then(|()| ServedSnapshot::build(&self.dg, self.epoch + 1, self.opts.decompositions));
        match rebuilt {
            Ok(snap) => {
                self.epoch += 1;
                self.degraded = None;
                self.cell.store(snap);
                lock(&self.stats).degraded = false;
                RebuildReply { epoch: self.epoch, error: None }
            }
            Err(e) => {
                let reason = e.to_string();
                self.degraded = Some(reason.clone());
                let prev = self.cell.load();
                self.cell.store(ServedSnapshot::degraded_from(&prev, reason.clone()));
                lock(&self.stats).degraded = true;
                RebuildReply { epoch: self.epoch, error: Some(reason) }
            }
        }
    }

    fn reply_all(&self, waiters: Vec<mpsc::Sender<UpdateReply>>, reply: UpdateReply) {
        for w in waiters {
            let _ = w.send(reply.clone());
        }
    }
}
