//! The serve-mode wire protocol: one JSON object per line in, one
//! JSON object per line out (over stdin/stdout or a TCP connection —
//! the transport is [`super::serve_lines`]' concern).
//!
//! Every read query is answered from **one** snapshot load, so all
//! fields of a response describe the same epoch; responses carry no
//! timing or host fields, which is what lets the protocol tests pin
//! byte-exact transcripts.  Successful responses open with
//! `{"ok": true, "epoch": E, "degraded": B, ...}`; failures are
//! `{"ok": false, "error": "..."}` with stable error strings.
//!
//! Requests (`op` selects the query):
//!
//! ```text
//! {"op": "total"}                         global butterfly count
//! {"op": "vertex", "side": "u", "id": 3}  per-vertex count
//! {"op": "edge", "u": 0, "v": 1}          per-edge count
//! {"op": "tip", "side": "u", "id": 3}     tip number
//! {"op": "wing", "u": 0, "v": 1}          wing number
//! {"op": "topk", "side": "u", "k": 3}     densest vertices by count
//! {"op": "epoch"}                         epoch + graph shape
//! {"op": "digest"}                        count-array checksums
//! {"op": "stats"}                         writer accounting
//! {"op": "update", "insert": [[0, 1]]}    batch insert (or "delete")
//! {"op": "update", "lines": ["+ 0 1"]}    stream-format updates
//! {"op": "rebuild"}                       guarded full recount
//! {"op": "shutdown"}                      stop writer, end transport
//! ```

use std::sync::Arc;

use crate::bench_support::json::Json;
use crate::dynamic::stream::{self, group_batches};
use crate::serve::session::Session;
use crate::serve::snapshot::ServedSnapshot;

/// One protocol response: the serialized line plus whether the
/// transport loop should stop after sending it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Reply {
    pub text: String,
    pub shutdown: bool,
}

impl Reply {
    fn err(msg: impl Into<String>) -> Reply {
        let obj = Json::Obj(vec![
            ("ok".into(), Json::Bool(false)),
            ("error".into(), Json::str(msg)),
        ]);
        Reply { text: obj.compact(), shutdown: false }
    }

    fn ok(epoch: u64, degraded: bool, fields: Vec<(String, Json)>) -> Reply {
        let mut obj = vec![
            ("ok".to_string(), Json::Bool(true)),
            ("epoch".to_string(), num(epoch)),
            ("degraded".to_string(), Json::Bool(degraded)),
        ];
        obj.extend(fields);
        Reply { text: Json::Obj(obj).compact(), shutdown: false }
    }
}

fn num(n: u64) -> Json {
    Json::Num(n as f64)
}

fn field(key: &str, value: Json) -> (String, Json) {
    (key.to_string(), value)
}

/// Extract a required non-negative integer field.
fn get_index(req: &Json, key: &str) -> Result<usize, String> {
    match req.get(key).and_then(Json::as_f64) {
        Some(n) if n >= 0.0 && n.fract() == 0.0 && n < 9.0e15 => Ok(n as usize),
        _ => Err(format!("bad request: missing or invalid integer field {key:?}")),
    }
}

/// Extract the required `side` field; `true` means U.
fn get_side(req: &Json) -> Result<bool, String> {
    match req.get("side").and_then(Json::as_str) {
        Some("u") => Ok(true),
        Some("v") => Ok(false),
        _ => Err("bad request: field \"side\" must be \"u\" or \"v\"".to_string()),
    }
}

/// Resolve an `(u, v)` request pair to an edge id of the snapshot's
/// graph.
fn get_edge(req: &Json, snap: &ServedSnapshot) -> Result<(usize, usize, u32), String> {
    let u = get_index(req, "u")?;
    let v = get_index(req, "v")?;
    let eid = if u < snap.graph.nu() && v < snap.graph.nv() {
        snap.graph.edge_id(u, v as u32)
    } else {
        None
    };
    match eid {
        Some(e) => Ok((u, v, e)),
        None => Err(format!("edge ({u}, {v}) is not present")),
    }
}

/// Parse an `"insert"`/`"delete"` field: an array of `[u, v]` pairs.
fn parse_edges(val: &Json, what: &str) -> Result<Vec<(u32, u32)>, String> {
    let bad = || format!("bad request: {what:?} must be an array of [u, v] pairs");
    let items = val.as_arr().ok_or_else(bad)?;
    let mut edges = Vec::with_capacity(items.len());
    for item in items {
        let pair = item.as_arr().ok_or_else(bad)?;
        if pair.len() != 2 {
            return Err(bad());
        }
        let mut ids = [0u32; 2];
        for (slot, p) in ids.iter_mut().zip(pair) {
            match p.as_f64() {
                Some(n) if n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64 => {
                    *slot = n as u32;
                }
                _ => return Err(bad()),
            }
        }
        edges.push((ids[0], ids[1]));
    }
    Ok(edges)
}

/// Handle one raw input line.  `None` for blank lines and `#`
/// comments (the transport sends no response for those).
pub fn handle_line(session: &Session, line: &str) -> Option<Reply> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') {
        return None;
    }
    Some(handle_request(session, t))
}

/// Handle one request document.  Infallible at the transport level:
/// every parse or semantic failure becomes an `{"ok": false}` reply.
pub fn handle_request(session: &Session, text: &str) -> Reply {
    let req = match Json::parse(text) {
        Ok(r) => r,
        Err(e) => return Reply::err(format!("bad request: {e}")),
    };
    if req.as_obj().is_none() {
        return Reply::err("bad request: expected a JSON object");
    }
    let op = match req.get("op").and_then(Json::as_str) {
        Some(op) => op,
        None => return Reply::err("bad request: missing string field \"op\""),
    };
    match op {
        // Reads: everything below answers from this one snapshot.
        "total" | "vertex" | "edge" | "tip" | "wing" | "topk" | "epoch" | "digest" | "stats" => {
            let snap = session.snapshot();
            match read_query(session, op, &req, &snap) {
                Ok(fields) => Reply::ok(snap.epoch, snap.degraded, fields),
                Err(msg) => Reply::err(msg),
            }
        }
        "update" => handle_update(session, &req),
        "rebuild" => {
            let r = session.rebuild();
            match r.error {
                None => Reply::ok(r.epoch, false, vec![field("rebuilt", Json::Bool(true))]),
                Some(e) => Reply::err(format!("rebuild failed: {e}")),
            }
        }
        "shutdown" => {
            session.shutdown();
            let obj = Json::Obj(vec![
                ("ok".into(), Json::Bool(true)),
                ("shutdown".into(), Json::Bool(true)),
            ]);
            Reply { text: obj.compact(), shutdown: true }
        }
        other => Reply::err(format!("bad request: unknown op {other:?}")),
    }
}

fn read_query(
    session: &Session,
    op: &str,
    req: &Json,
    snap: &Arc<ServedSnapshot>,
) -> Result<Vec<(String, Json)>, String> {
    match op {
        "total" => Ok(vec![field("total", num(snap.global))]),
        "vertex" => {
            let is_u = get_side(req)?;
            let id = get_index(req, "id")?;
            let (side, arr) = if is_u { ("u", &snap.per_u) } else { ("v", &snap.per_v) };
            let count = *arr
                .get(id)
                .ok_or_else(|| format!("vertex id {id} out of range for side {side} (size {})", arr.len()))?;
            Ok(vec![
                field("side", Json::str(side)),
                field("id", num(id as u64)),
                field("count", num(count)),
            ])
        }
        "edge" => {
            let (u, v, eid) = get_edge(req, snap)?;
            Ok(vec![
                field("u", num(u as u64)),
                field("v", num(v as u64)),
                field("count", num(snap.per_edge[eid as usize])),
            ])
        }
        "tip" => {
            let is_u = get_side(req)?;
            let id = get_index(req, "id")?;
            let (side, tips) = if is_u {
                ("u", snap.tips_u.as_ref())
            } else {
                ("v", snap.tips_v.as_ref())
            };
            let tips = tips.ok_or_else(|| "decompositions are disabled for this session".to_string())?;
            let tip = *tips
                .get(id)
                .ok_or_else(|| format!("vertex id {id} out of range for side {side} (size {})", tips.len()))?;
            Ok(vec![
                field("side", Json::str(side)),
                field("id", num(id as u64)),
                field("tip", num(tip)),
            ])
        }
        "wing" => {
            let wings = snap
                .wings
                .as_ref()
                .ok_or_else(|| "decompositions are disabled for this session".to_string())?;
            let (u, v, eid) = get_edge(req, snap)?;
            Ok(vec![
                field("u", num(u as u64)),
                field("v", num(v as u64)),
                field("wing", num(wings[eid as usize])),
            ])
        }
        "topk" => {
            let is_u = get_side(req)?;
            let k = get_index(req, "k")?;
            let (side, arr) = if is_u { ("u", &snap.per_u) } else { ("v", &snap.per_v) };
            // Count-descending, id-ascending tie-break: deterministic
            // regardless of thread count or arrival order.
            let mut ids: Vec<usize> = (0..arr.len()).collect();
            ids.sort_by_key(|&i| (std::cmp::Reverse(arr[i]), i));
            ids.truncate(k);
            let top: Vec<Json> = ids
                .into_iter()
                .map(|i| {
                    Json::Obj(vec![
                        ("id".to_string(), num(i as u64)),
                        ("count".to_string(), num(arr[i])),
                    ])
                })
                .collect();
            Ok(vec![
                field("side", Json::str(side)),
                field("k", num(k as u64)),
                field("top", Json::Arr(top)),
            ])
        }
        "epoch" => Ok(vec![
            field("nu", num(snap.graph.nu() as u64)),
            field("nv", num(snap.graph.nv() as u64)),
            field("m", num(snap.graph.m() as u64)),
        ]),
        "digest" => {
            // Consistency checksums of one snapshot: torn reads (were
            // they possible) would violate sum_u == sum_v == 2*global
            // and sum_edge == 4*global.
            let sum_u: u64 = snap.per_u.iter().sum();
            let sum_v: u64 = snap.per_v.iter().sum();
            let sum_e: u64 = snap.per_edge.iter().sum();
            Ok(vec![
                field("global", num(snap.global)),
                field("sum_u", num(sum_u)),
                field("sum_v", num(sum_v)),
                field("sum_edge", num(sum_e)),
                field("m", num(snap.graph.m() as u64)),
            ])
        }
        "stats" => {
            let st = session.stats();
            let recovered = st.errors.iter().filter(|e| e.recovered).count();
            Ok(vec![
                field("batches", num(st.batches as u64)),
                field("inserted", num(st.inserted as u64)),
                field("deleted", num(st.deleted as u64)),
                field("skipped", num(st.skipped as u64)),
                field("rejected", num(st.rejected as u64)),
                field("errors", num(st.errors.len() as u64)),
                field("recovered", num(recovered as u64)),
            ])
        }
        _ => unreachable!("read_query called for a non-read op"),
    }
}

fn handle_update(session: &Session, req: &Json) -> Reply {
    use crate::dynamic::BatchKind;
    // Exactly one of "insert" / "delete" / "lines".
    let forms = [req.get("insert"), req.get("delete"), req.get("lines")];
    let present = forms.iter().flatten().count();
    if present != 1 {
        return Reply::err(
            "bad request: update needs exactly one of \"insert\", \"delete\", or \"lines\"",
        );
    }
    let groups = if let Some(val) = req.get("lines") {
        let items = match val.as_arr() {
            Some(items) => items,
            None => return Reply::err("bad request: \"lines\" must be an array of strings"),
        };
        let mut events = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            let line = match item.as_str() {
                Some(s) => s,
                None => return Reply::err("bad request: \"lines\" must be an array of strings"),
            };
            // The stream parser's strict errors, verbatim — same
            // messages as the `dynamic` subcommand's loader.
            match stream::parse_event(line, i) {
                Ok(e) => events.push(e),
                Err(e) => return Reply::err(format!("bad request: {e}")),
            }
        }
        if events.is_empty() {
            return Reply::err("bad request: empty update");
        }
        group_batches(&events, 0)
    } else {
        let (kind, key) = match req.get("insert") {
            Some(_) => (BatchKind::Insert, "insert"),
            None => (BatchKind::Delete, "delete"),
        };
        let val = match req.get(key) {
            Some(v) => v,
            None => return Reply::err("bad request: update needs \"insert\" or \"delete\""),
        };
        let edges = match parse_edges(val, key) {
            Ok(e) => e,
            Err(msg) => return Reply::err(msg),
        };
        vec![stream::Batch { kind, edges }]
    };
    let (mut applied, mut skipped) = (0usize, 0usize);
    let mut recovered = false;
    let mut last: Option<crate::serve::session::UpdateReply> = None;
    for b in groups {
        let r = session.update(b.kind, b.edges);
        if let Some(e) = r.error {
            return Reply::err(e);
        }
        applied += r.applied;
        skipped += r.skipped;
        recovered |= r.recovered;
        last = Some(r);
    }
    match last {
        Some(r) => Reply::ok(r.epoch, r.degraded, vec![
            field("applied", num(applied as u64)),
            field("skipped", num(skipped as u64)),
            field("recovered", Json::Bool(recovered)),
        ]),
        None => Reply::err("bad request: empty update"),
    }
}
