//! `RustDense` — the pure-Rust reference dense backend.
//!
//! A tiled CPU implementation of the Lemma 4.2 linear-algebra
//! formulation, bit-for-bit matching `python/compile/kernels/ref.py`
//! (all quantities are exact integer counts carried in floats):
//!
//! * wedge matrix `W = A Aᵀ` with the diagonal zeroed (`W0`);
//! * per-vertex: `b_u[i] = Σ_j C(W0[i,j], 2)`, `b_v` likewise on `AᵀA`;
//! * total: `Σ_i b_u[i] / 2`;
//! * per-edge: `B_e = A ∘ (W0 A − (deg_v − 1))`.
//!
//! The kernel walks the `U x U` wedge matrix one `row_tile`-row block
//! at a time (the same row-block grid the Pallas kernel tiles for the
//! MXU), never materializing `W` — each row's wedge counts are
//! consumed as they are produced — and parallelizes over row blocks
//! with the crate's fork-join pool.
//!
//! Exactness bound: with `max_dim = 2048`, every intermediate
//! (`W` entries `<= 2048`, `W0·A` entries `<= 2^22`, per-edge counts
//! `<= 2^22`) stays below the 2^24 f32-exact-integer limit, and the
//! f64 accumulators hold the per-vertex / total sums exactly.

use anyhow::Result;

use super::{DenseBackend, DenseOutputs};
use crate::prims::pool::{parallel_for_dynamic, SyncPtr};

/// Pure-Rust tiled dense kernel (see module docs).
pub struct RustDense {
    max_dim: usize,
    row_tile: usize,
}

impl Default for RustDense {
    fn default() -> Self {
        Self { max_dim: 2048, row_tile: 64 }
    }
}

impl RustDense {
    /// Backend with a smaller size cap (testing / memory-bound hosts).
    /// Caps above 2048 are rejected: beyond that the `W0·A` partial
    /// sums can exceed f32's exact-integer range (see module docs).
    pub fn with_max_dim(max_dim: usize) -> Self {
        assert!(max_dim <= 2048, "max_dim {max_dim} would break f32 exactness (limit 2048)");
        Self { max_dim, ..Self::default() }
    }
}

#[inline]
fn choose2f(w: f32) -> f64 {
    let d = w as f64;
    d * (d - 1.0) * 0.5
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0f32;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

/// Per-row butterfly endpoint counts of a row-major `n x k` 0/1
/// matrix: `out[i] = Σ_{j != i} C((M Mᵀ)[i,j], 2)`, tiled, parallel
/// over row blocks.
fn endpoint_counts(m: &[f32], n: usize, k: usize, row_tile: usize) -> Vec<f64> {
    let mut out = vec![0f64; n];
    let op = SyncPtr(out.as_mut_ptr());
    let nblocks = n.div_ceil(row_tile.max(1));
    parallel_for_dynamic(nblocks, 1, |blocks| {
        for b in blocks {
            let lo = b * row_tile;
            let hi = (lo + row_tile).min(n);
            for i in lo..hi {
                let mi = &m[i * k..(i + 1) * k];
                let mut acc = 0f64;
                for j in 0..n {
                    if j == i {
                        continue;
                    }
                    acc += choose2f(dot(mi, &m[j * k..(j + 1) * k]));
                }
                // SAFETY: row blocks are disjoint; each i written once.
                unsafe { *op.get().add(i) = acc };
            }
        }
    });
    out
}

/// Column sums (`deg_v`) of a row-major `u x v` matrix.
fn col_sums(a: &[f32], u: usize, v: usize) -> Vec<f32> {
    let mut deg = vec![0f32; v];
    for i in 0..u {
        for (d, x) in deg.iter_mut().zip(&a[i * v..(i + 1) * v]) {
            *d += x;
        }
    }
    deg
}

/// Transpose a row-major `u x v` matrix into `v x u`.
fn transpose(a: &[f32], u: usize, v: usize) -> Vec<f32> {
    let mut t = vec![0f32; u * v];
    for i in 0..u {
        for j in 0..v {
            t[j * u + i] = a[i * v + j];
        }
    }
    t
}

impl DenseBackend for RustDense {
    fn name(&self) -> &'static str {
        "rust-dense"
    }

    fn plan(&self, u: usize, v: usize) -> Option<(usize, usize)> {
        // Pad to multiples of 8 (mirrors the MXU-shaped artifacts and
        // keeps the padded-shape paths exercised under default builds).
        let pad = |d: usize| d.max(1).div_ceil(8) * 8;
        let (pu, pv) = (pad(u), pad(v));
        if pu <= self.max_dim && pv <= self.max_dim {
            Some((pu, pv))
        } else {
            None
        }
    }

    fn max_dim(&self) -> usize {
        self.max_dim
    }

    fn count_dense(&self, u: usize, v: usize, a: &[f32]) -> Result<DenseOutputs> {
        anyhow::ensure!(a.len() == u * v, "input is {} values, expected {}", a.len(), u * v);
        anyhow::ensure!(u.max(v) <= self.max_dim, "{u}x{v} exceeds max_dim {}", self.max_dim);
        let degv = col_sums(a, u, v);
        let at = transpose(a, u, v);
        let bv = endpoint_counts(&at, v, u, self.row_tile);

        // Per-vertex (U side) and per-edge in ONE row-block sweep over
        // `W0`: each row's wedge counts feed both `b_u[i] = Σ C(w, 2)`
        // and `B_e = A ∘ (W0 A − (deg_v − 1))` — the dominant
        // `O(u^2 * v)` dot products are computed once, not twice.
        let mut bu = vec![0f64; u];
        let mut be = vec![0f32; u * v];
        {
            let bp = SyncPtr(be.as_mut_ptr());
            let up = SyncPtr(bu.as_mut_ptr());
            let degv = &degv;
            let nblocks = u.div_ceil(self.row_tile.max(1));
            let row_tile = self.row_tile;
            parallel_for_dynamic(nblocks, 1, |blocks| {
                let mut wa = vec![0f32; v];
                for b in blocks {
                    let lo = b * row_tile;
                    let hi = (lo + row_tile).min(u);
                    for i in lo..hi {
                        let ai = &a[i * v..(i + 1) * v];
                        wa.fill(0.0);
                        let mut acc = 0f64;
                        for j in 0..u {
                            if j == i {
                                continue;
                            }
                            let aj = &a[j * v..(j + 1) * v];
                            let w = dot(ai, aj);
                            if w != 0.0 {
                                acc += choose2f(w);
                                for (s, x) in wa.iter_mut().zip(aj) {
                                    *s += w * x;
                                }
                            }
                        }
                        // SAFETY: row blocks are disjoint; each i (and
                        // each be row) is written by exactly one worker.
                        unsafe { *up.get().add(i) = acc };
                        for (x, ((&av, &wv), &dv)) in
                            ai.iter().zip(wa.iter()).zip(degv.iter()).enumerate()
                        {
                            unsafe { *bp.get().add(i * v + x) = av * (wv - (dv - 1.0)) };
                        }
                    }
                }
            });
        }
        let total: f64 = bu.iter().sum::<f64>() / 2.0;
        Ok(DenseOutputs { total, bu, bv, be })
    }

    fn count_total(&self, u: usize, v: usize, a: &[f32]) -> Result<f64> {
        anyhow::ensure!(a.len() == u * v, "input is {} values, expected {}", a.len(), u * v);
        anyhow::ensure!(u.max(v) <= self.max_dim, "{u}x{v} exceeds max_dim {}", self.max_dim);
        Ok(endpoint_counts(a, u, v, self.row_tile).iter().sum::<f64>() / 2.0)
    }

    fn wedge_stats(&self, u: usize, v: usize, a: &[f32]) -> Result<(f64, f64)> {
        anyhow::ensure!(a.len() == u * v, "input is {} values, expected {}", a.len(), u * v);
        // Wedges with endpoints on U are centered on V: Σ_v C(deg_v, 2)
        // (and symmetrically for endpoints on V).
        let wu: f64 = col_sums(a, u, v).into_iter().map(choose2f).sum();
        let mut wv = 0f64;
        for i in 0..u {
            let d: f32 = a[i * v..(i + 1) * v].iter().sum();
            wv += choose2f(d);
        }
        Ok((wu, wv))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, BipartiteGraph};
    use crate::testutil::brute;

    fn run_full(g: &BipartiteGraph, pad_u: usize, pad_v: usize) -> DenseOutputs {
        let b = RustDense::default();
        let a = g.to_dense_f32(pad_u, pad_v);
        b.count_dense(pad_u, pad_v, &a).unwrap()
    }

    #[test]
    fn fig1_graph_exact() {
        let g = BipartiteGraph::from_edges(
            3,
            3,
            &[(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2), (2, 2)],
        );
        let out = run_full(&g, 3, 3);
        assert_eq!(out.total.round() as u64, 3);
        let (ebu, ebv) = brute::per_vertex(&g);
        for (i, &e) in ebu.iter().enumerate() {
            assert_eq!(out.bu[i].round() as u64, e, "bu[{i}]");
        }
        for (j, &e) in ebv.iter().enumerate() {
            assert_eq!(out.bv[j].round() as u64, e, "bv[{j}]");
        }
    }

    #[test]
    fn padded_nonsquare_matches_brute_force() {
        let g = gen::erdos_renyi(37, 53, 400, 9);
        let out = run_full(&g, 40, 56);
        assert_eq!(out.total.round() as u64, brute::total(&g));
        let (ebu, _) = brute::per_vertex(&g);
        for (i, &e) in ebu.iter().enumerate() {
            assert_eq!(out.bu[i].round() as u64, e);
        }
        // Padding rows/cols must contribute nothing.
        for i in g.nu()..40 {
            assert_eq!(out.bu[i], 0.0);
        }
        let ebe = brute::per_edge(&g);
        for u in 0..g.nu() {
            for (k, &v) in g.nbrs_u(u).iter().enumerate() {
                let eid = g.eid_u(u, k) as usize;
                assert_eq!(out.be[u * 56 + v as usize].round() as u64, ebe[eid]);
            }
        }
    }

    #[test]
    fn wedge_stats_match_graph() {
        let g = gen::chung_lu(30, 45, 300, 2.2, 4);
        let b = RustDense::default();
        let (pu, pv) = b.plan(g.nu(), g.nv()).unwrap();
        let a = g.to_dense_f32(pu, pv);
        let (wu, wv) = b.wedge_stats(pu, pv, &a).unwrap();
        assert_eq!(wu.round() as u64, g.wedges_centered_v());
        assert_eq!(wv.round() as u64, g.wedges_centered_u());
    }

    #[test]
    fn empty_and_complete_blocks() {
        let b = RustDense::default();
        let a = vec![0f32; 64];
        assert_eq!(b.count_total(8, 8, &a).unwrap(), 0.0);
        let g = gen::complete_bipartite(6, 7);
        let out = run_full(&g, 8, 8);
        assert_eq!(out.total.round() as u64, 15 * 21);
    }
}
