//! PJRT engine: load and execute the AOT artifacts from Layer 1/2
//! (feature `pjrt`).
//!
//! `make artifacts` (Python, build time only) writes
//! `artifacts/<entry>_<U>x<V>.hlo.txt` plus `manifest.txt`; this module
//! compiles them once on the PJRT CPU client and serves executions from
//! the Rust hot path.  HLO **text** is the interchange format (jax>=0.5
//! serialized protos are rejected by xla_extension 0.5.1 — see
//! `python/compile/aot.py`).
//!
//! Compilation is lazy (first use per artifact) and cached.  The
//! in-tree `xla` dependency is a type-compatible stub whose client
//! constructor fails, so building with `--features pjrt` but without
//! the real bindings degrades to the [`super::RustDense`] fallback at
//! runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::{DenseBackend, DenseOutputs};

/// One artifact as described by `manifest.txt`.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub entry: String,
    pub u: usize,
    pub v: usize,
    pub n_out: usize,
    pub path: PathBuf,
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    n_out: usize,
}

/// PJRT engine over a directory of artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    specs: Vec<ArtifactSpec>,
    cache: Mutex<HashMap<(String, usize, usize), usize>>, // -> compiled idx
    compiled: Mutex<Vec<Option<Compiled>>>,
}

// The PJRT client and executables are used behind &self from multiple
// coordinator threads; the underlying C API objects are thread-safe for
// execution, and compilation is serialized through the mutex above.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load `manifest.txt` from `dir` and start a PJRT CPU client.
    pub fn load_dir(dir: &Path) -> Result<Engine> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut specs = Vec::new();
        for line in text.lines() {
            let t = line.trim();
            if t.is_empty() {
                continue;
            }
            let mut it = t.split_whitespace();
            let entry = it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?.to_string();
            let u: usize = it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?.parse()?;
            let v: usize = it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?.parse()?;
            let n_out: usize =
                it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?.parse()?;
            let fname = it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?;
            specs.push(ArtifactSpec { entry, u, v, n_out, path: dir.join(fname) });
        }
        anyhow::ensure!(!specs.is_empty(), "empty manifest {}", manifest.display());
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let n = specs.len();
        Ok(Engine {
            client,
            specs,
            cache: Mutex::new(HashMap::new()),
            compiled: Mutex::new((0..n).map(|_| None).collect()),
        })
    }

    /// Default artifact location: `$PARBUTTERFLY_ARTIFACTS` or
    /// `./artifacts`.
    pub fn load_default() -> Result<Engine> {
        let dir = std::env::var("PARBUTTERFLY_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load_dir(Path::new(&dir))
    }

    /// All artifact specs (for diagnostics / CLI `artifacts`).
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Smallest artifact of `entry` that fits a `u x v` block.
    pub fn pick(&self, entry: &str, u: usize, v: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| s.entry == entry && s.u >= u && s.v >= v)
            .min_by_key(|s| s.u * s.v)
    }

    fn compile_idx(&self, idx: usize) -> Result<()> {
        let mut compiled = self.compiled.lock().unwrap();
        if compiled[idx].is_some() {
            return Ok(());
        }
        let spec = &self.specs[idx];
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .map_err(|e| anyhow!("parse {}: {e:?}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", spec.path.display()))?;
        compiled[idx] = Some(Compiled { exe, n_out: spec.n_out });
        Ok(())
    }

    /// Execute `entry` at exactly `u x v` with a row-major f32 input.
    /// Returns the raw tuple elements as literals.
    pub fn run_raw(&self, entry: &str, u: usize, v: usize, a: &[f32]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(a.len() == u * v, "input is {} values, expected {}", a.len(), u * v);
        let idx = {
            let mut cache = self.cache.lock().unwrap();
            match cache.get(&(entry.to_string(), u, v)) {
                Some(&i) => i,
                None => {
                    let i = self
                        .specs
                        .iter()
                        .position(|s| s.entry == entry && s.u == u && s.v == v)
                        .ok_or_else(|| anyhow!("no artifact {entry} {u}x{v}"))?;
                    cache.insert((entry.to_string(), u, v), i);
                    i
                }
            }
        };
        self.compile_idx(idx)?;
        let compiled = self.compiled.lock().unwrap();
        let c = compiled[idx].as_ref().unwrap();
        let input = xla::Literal::vec1(a)
            .reshape(&[u as i64, v as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = c
            .exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == c.n_out,
            "artifact {entry} returned {} outputs, manifest says {}",
            parts.len(),
            c.n_out
        );
        Ok(parts)
    }

    /// Execute the `wedge_stats` artifact (kept off the trait's padded
    /// contract for direct artifact-shape callers).
    pub fn wedge_stats_raw(&self, u: usize, v: usize, a: &[f32]) -> Result<(f64, f64)> {
        let parts = self.run_raw("wedge_stats", u, v, a)?;
        let wu = parts[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?[0];
        let wv = parts[1].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok((wu, wv))
    }
}

/// Tight bounding box of the nonzero content of a row-major `u x v`
/// block.  Zero rows/columns contribute nothing to any dense model, so
/// the block may be re-shaped to anything covering this box.
fn content_dims(a: &[f32], u: usize, v: usize) -> (usize, usize) {
    let (mut cu, mut cv) = (0usize, 0usize);
    for i in 0..u {
        let row = &a[i * v..(i + 1) * v];
        if let Some(last) = row.iter().rposition(|&x| x != 0.0) {
            cu = i + 1;
            cv = cv.max(last + 1);
        }
    }
    (cu, cv)
}

/// Copy the leading `cu x cv` corner of a row-major `u x v` block into
/// a zero-padded `pu x pv` block.
fn reshape_block(a: &[f32], v: usize, cu: usize, cv: usize, pu: usize, pv: usize) -> Vec<f32> {
    debug_assert!(pu >= cu && pv >= cv);
    let mut out = vec![0f32; pu * pv];
    for i in 0..cu {
        out[i * pv..i * pv + cv].copy_from_slice(&a[i * v..i * v + cv]);
    }
    out
}

impl Engine {
    /// Resolve the artifact shape for `entry` covering a `u x v` block
    /// already padded by the caller: exact match when the manifest has
    /// one, else the smallest shape *for that entry* covering the
    /// block's nonzero content, with the input re-shaped (entries need
    /// not share shape sets, and `plan()` may have padded for a
    /// different entry).
    fn shape_for<'a>(
        &self,
        entry: &str,
        u: usize,
        v: usize,
        a: &'a [f32],
    ) -> Result<(usize, usize, std::borrow::Cow<'a, [f32]>)> {
        if self.specs.iter().any(|s| s.entry == entry && s.u == u && s.v == v) {
            return Ok((u, v, std::borrow::Cow::Borrowed(a)));
        }
        let (cu, cv) = content_dims(a, u, v);
        let spec = self
            .pick(entry, cu, cv)
            .ok_or_else(|| anyhow!("no artifact {entry} fits {cu}x{cv}"))?;
        let (pu, pv) = (spec.u, spec.v);
        Ok((pu, pv, std::borrow::Cow::Owned(reshape_block(a, v, cu, cv, pu, pv))))
    }
}

impl DenseBackend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn plan(&self, u: usize, v: usize) -> Option<(usize, usize)> {
        // Plan against the full-model entry, falling back to the
        // total-only entry; per-entry shape differences are absorbed by
        // `shape_for` at execution time.
        self.pick("count_dense", u, v)
            .or_else(|| self.pick("count_total", u, v))
            .map(|s| (s.u, s.v))
    }

    fn max_dim(&self) -> usize {
        self.specs.iter().map(|s| s.u.max(s.v)).max().unwrap_or(0)
    }

    fn count_dense(&self, u: usize, v: usize, a: &[f32]) -> Result<DenseOutputs> {
        let (pu, pv, a) = self.shape_for("count_dense", u, v, a)?;
        let parts = self.run_raw("count_dense", pu, pv, &a)?;
        anyhow::ensure!(parts.len() == 4, "count_dense must have 4 outputs");
        let total: f64 = parts[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?[0];
        let bu_art = parts[1].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
        let bv_art = parts[2].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
        let be_art = parts[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        // Map artifact-shape outputs back to the caller's `u x v`
        // shape.  The artifact may be larger or smaller than the
        // caller's padding; the nonzero content fits both, so anything
        // outside the copied corner is zero.
        let (rc, cc) = (u.min(pu), v.min(pv));
        let mut bu = vec![0f64; u];
        bu[..rc].copy_from_slice(&bu_art[..rc]);
        let mut bv = vec![0f64; v];
        bv[..cc].copy_from_slice(&bv_art[..cc]);
        let mut be = vec![0f32; u * v];
        for i in 0..rc {
            be[i * v..i * v + cc].copy_from_slice(&be_art[i * pv..i * pv + cc]);
        }
        Ok(DenseOutputs { total, bu, bv, be })
    }

    fn count_total(&self, u: usize, v: usize, a: &[f32]) -> Result<f64> {
        let (pu, pv, a) = self.shape_for("count_total", u, v, a)?;
        let parts = self.run_raw("count_total", pu, pv, &a)?;
        Ok(parts[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?[0])
    }

    fn wedge_stats(&self, u: usize, v: usize, a: &[f32]) -> Result<(f64, f64)> {
        let (pu, pv, a) = self.shape_for("wedge_stats", u, v, a)?;
        self.wedge_stats_raw(pu, pv, &a)
    }
}
