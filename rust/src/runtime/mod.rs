//! Dense-core runtime: pluggable backends for the dense-tile butterfly
//! kernels of Lemma 4.2.
//!
//! The dense path treats a (small or padded) bipartite block as a 0/1
//! adjacency matrix `A` and counts through the wedge matrix `W = A Aᵀ`
//! — the linear-algebra formulation AOT-lowered by the Python Layer 1/2
//! pipeline (`python/compile/kernels/ref.py` is the oracle).  Two
//! backends implement [`DenseBackend`]:
//!
//! * [`RustDense`] — the pure-Rust tiled reference kernel.  Always
//!   available, no artifacts, exact for every shape it accepts; this is
//!   what CI and the default build run.
//! * `pjrt::Engine` *(feature `pjrt`; the module only exists then, so
//!   this is intentionally not a doc link)* — loads the AOT artifacts
//!   (`make artifacts`) through the PJRT C API and serves executions
//!   from the hot path.  The in-tree `xla` dependency is a
//!   type-compatible stub, so the feature type-checks offline; point it
//!   at the real bindings to execute.
//!
//! [`default_backend`] picks at runtime: `PARBUTTERFLY_BACKEND` forces
//! `rust` / `pjrt` / `none`; unset or `auto` prefers PJRT when the
//! feature is on and artifacts are present, and falls back to
//! [`RustDense`].

pub mod rust_dense;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use rust_dense::RustDense;

#[cfg(feature = "pjrt")]
pub use pjrt::{ArtifactSpec, Engine};

use anyhow::Result;

/// Outputs of one dense-model execution (padded shapes; callers slice
/// back to logical dimensions).
pub struct DenseOutputs {
    /// Global butterfly count (f64 scalar output).
    pub total: f64,
    /// Per-vertex counts, U side (f64, length = padded U).
    pub bu: Vec<f64>,
    /// Per-vertex counts, V side (f64, length = padded V).
    pub bv: Vec<f64>,
    /// Per-edge counts (f32, row-major padded U x V).
    pub be: Vec<f32>,
}

/// A dense butterfly-counting backend.
///
/// Contract shared by every implementation:
/// * [`DenseBackend::plan`] maps a logical `u x v` block to the padded
///   execution shape the backend supports (`None` if the block cannot
///   fit any supported shape);
/// * the `count_*` entry points take the **planned** shape and a
///   row-major 0/1 `f32` adjacency of exactly `u * v` values (callers
///   pad with zeros, e.g. via `BipartiteGraph::to_dense_f32`);
/// * outputs are exact integer counts in floating storage, matching
///   `python/compile/kernels/ref.py` semantics.
pub trait DenseBackend: Send + Sync {
    /// Short stable name, used in reports ("rust-dense", "pjrt").
    fn name(&self) -> &'static str;

    /// Padded execution shape for a logical `u x v` block, or `None`
    /// if no supported shape fits it.
    fn plan(&self, u: usize, v: usize) -> Option<(usize, usize)>;

    /// Largest `max(u, v)` any plan of this backend can cover; the
    /// coordinator routes bigger graphs to the sparse CPU framework.
    fn max_dim(&self) -> usize;

    /// Full dense model: total, per-vertex (both sides), per-edge.
    fn count_dense(&self, u: usize, v: usize, a: &[f32]) -> Result<DenseOutputs>;

    /// Global count only.
    fn count_total(&self, u: usize, v: usize, a: &[f32]) -> Result<f64>;

    /// `(wedges with endpoints on U, wedges with endpoints on V)`.
    fn wedge_stats(&self, u: usize, v: usize, a: &[f32]) -> Result<(f64, f64)>;
}

/// Resolve a dense backend by name.
///
/// Names: `rust` (reference kernel), `pjrt` (artifact engine; errors
/// when the feature is off or artifacts fail to load), `none`/`off`
/// (disable the dense path), `auto` (PJRT when available, else
/// `rust`).  Unknown names are an error, never silently `auto`.
pub fn backend_for(choice: &str) -> Result<Option<Box<dyn DenseBackend>>> {
    match choice {
        "none" | "off" => Ok(None),
        "rust" => Ok(Some(Box::new(RustDense::default()))),
        "pjrt" => pjrt_backend_strict(),
        "auto" => Ok(auto_backend()),
        other => Err(anyhow::anyhow!(
            "unknown backend {other:?} (expected auto, rust, pjrt, or none)"
        )),
    }
}

/// Resolve the dense backend for this process from
/// `PARBUTTERFLY_BACKEND` (default `auto`; see [`backend_for`]).  An
/// unrecognized value warns on stderr and falls back to `auto` rather
/// than silently masking the misconfiguration.
pub fn default_backend() -> Option<Box<dyn DenseBackend>> {
    let choice = std::env::var("PARBUTTERFLY_BACKEND").unwrap_or_else(|_| "auto".into());
    match backend_for(&choice) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("warning: PARBUTTERFLY_BACKEND: {e:#}; using auto");
            auto_backend()
        }
    }
}

/// The `auto` policy: PJRT when the feature is on and artifacts load,
/// else the pure-Rust reference kernel.  Never `None`.
fn auto_backend() -> Option<Box<dyn DenseBackend>> {
    pjrt_backend().or_else(|| Some(Box::new(RustDense::default())))
}

#[cfg(feature = "pjrt")]
fn pjrt_backend() -> Option<Box<dyn DenseBackend>> {
    pjrt::Engine::load_default().ok().map(|e| Box::new(e) as Box<dyn DenseBackend>)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend() -> Option<Box<dyn DenseBackend>> {
    None
}

#[cfg(feature = "pjrt")]
fn pjrt_backend_strict() -> Result<Option<Box<dyn DenseBackend>>> {
    let engine = pjrt::Engine::load_default()?;
    Ok(Some(Box::new(engine)))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend_strict() -> Result<Option<Box<dyn DenseBackend>>> {
    Err(anyhow::anyhow!("the pjrt backend requires building with --features pjrt"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// True when PARBUTTERFLY_BACKEND is exported to something other
    /// than the default — assertions about `default_backend()` would
    /// then test the developer's environment, not the code.
    pub(super) fn env_overrides_backend() -> bool {
        std::env::var("PARBUTTERFLY_BACKEND").map(|v| v != "auto").unwrap_or(false)
    }

    #[test]
    fn default_backend_resolves_rust_dense_without_artifacts() {
        if env_overrides_backend() {
            return;
        }
        // Under default features there is no PJRT engine; auto must
        // fall back to the reference kernel rather than None.
        let b = default_backend().expect("a dense backend must always be available");
        assert!(b.max_dim() >= 512);
        if !crate::count::dense::artifacts_available() {
            assert_eq!(b.name(), "rust-dense");
        }
    }

    #[test]
    fn plan_rejects_oversized_blocks() {
        let b = RustDense::default();
        assert!(b.plan(1, 1).is_some());
        assert!(b.plan(b.max_dim() + 1, 4).is_none());
    }

    #[test]
    fn backend_for_validates_names() {
        assert!(backend_for("none").unwrap().is_none());
        assert!(backend_for("off").unwrap().is_none());
        assert_eq!(backend_for("rust").unwrap().unwrap().name(), "rust-dense");
        assert!(backend_for("auto").unwrap().is_some());
        let err = backend_for("rsut").unwrap_err();
        assert!(format!("{err}").contains("unknown backend"), "{err}");
        #[cfg(not(feature = "pjrt"))]
        assert!(backend_for("pjrt").is_err());
    }
}
