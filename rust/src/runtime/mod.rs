//! PJRT runtime: load and execute the AOT artifacts from Layer 1/2.
//!
//! `make artifacts` (Python, build time only) writes
//! `artifacts/<entry>_<U>x<V>.hlo.txt` plus `manifest.txt`; this module
//! compiles them once on the PJRT CPU client and serves executions from
//! the Rust hot path.  HLO **text** is the interchange format (jax>=0.5
//! serialized protos are rejected by xla_extension 0.5.1 — see
//! `python/compile/aot.py`).
//!
//! Compilation is lazy (first use per artifact) and cached.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// One artifact as described by `manifest.txt`.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub entry: String,
    pub u: usize,
    pub v: usize,
    pub n_out: usize,
    pub path: PathBuf,
}

/// Outputs of one dense-model execution.
pub struct DenseOutputs {
    /// Global butterfly count (f64 scalar output).
    pub total: f64,
    /// Per-vertex counts, U side (f64, length = padded U).
    pub bu: Vec<f64>,
    /// Per-vertex counts, V side (f64, length = padded V).
    pub bv: Vec<f64>,
    /// Per-edge counts (f32, row-major padded U x V).
    pub be: Vec<f32>,
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    n_out: usize,
}

/// PJRT engine over a directory of artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    specs: Vec<ArtifactSpec>,
    cache: Mutex<HashMap<(String, usize, usize), usize>>, // -> compiled idx
    compiled: Mutex<Vec<Option<Compiled>>>,
}

// The PJRT client and executables are used behind &self from multiple
// coordinator threads; the underlying C API objects are thread-safe for
// execution, and compilation is serialized through the mutex above.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load `manifest.txt` from `dir` and start a PJRT CPU client.
    pub fn load_dir(dir: &Path) -> Result<Engine> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut specs = Vec::new();
        for line in text.lines() {
            let t = line.trim();
            if t.is_empty() {
                continue;
            }
            let mut it = t.split_whitespace();
            let entry = it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?.to_string();
            let u: usize = it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?.parse()?;
            let v: usize = it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?.parse()?;
            let n_out: usize =
                it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?.parse()?;
            let fname = it.next().ok_or_else(|| anyhow!("bad manifest line: {t}"))?;
            specs.push(ArtifactSpec { entry, u, v, n_out, path: dir.join(fname) });
        }
        anyhow::ensure!(!specs.is_empty(), "empty manifest {}", manifest.display());
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let n = specs.len();
        Ok(Engine {
            client,
            specs,
            cache: Mutex::new(HashMap::new()),
            compiled: Mutex::new((0..n).map(|_| None).collect()),
        })
    }

    /// Default artifact location: `$PARBUTTERFLY_ARTIFACTS` or
    /// `./artifacts`.
    pub fn load_default() -> Result<Engine> {
        let dir = std::env::var("PARBUTTERFLY_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load_dir(Path::new(&dir))
    }

    /// All artifact specs (for diagnostics / CLI `info`).
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Smallest artifact of `entry` that fits a `u x v` block.
    pub fn pick(&self, entry: &str, u: usize, v: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| s.entry == entry && s.u >= u && s.v >= v)
            .min_by_key(|s| s.u * s.v)
    }

    fn compile_idx(&self, idx: usize) -> Result<()> {
        let mut compiled = self.compiled.lock().unwrap();
        if compiled[idx].is_some() {
            return Ok(());
        }
        let spec = &self.specs[idx];
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .map_err(|e| anyhow!("parse {}: {e:?}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", spec.path.display()))?;
        compiled[idx] = Some(Compiled { exe, n_out: spec.n_out });
        Ok(())
    }

    /// Execute `entry` at exactly `u x v` with a row-major f32 input.
    /// Returns the raw tuple elements as literals.
    pub fn run_raw(&self, entry: &str, u: usize, v: usize, a: &[f32]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(a.len() == u * v, "input is {} values, expected {}", a.len(), u * v);
        let idx = {
            let mut cache = self.cache.lock().unwrap();
            match cache.get(&(entry.to_string(), u, v)) {
                Some(&i) => i,
                None => {
                    let i = self
                        .specs
                        .iter()
                        .position(|s| s.entry == entry && s.u == u && s.v == v)
                        .ok_or_else(|| anyhow!("no artifact {entry} {u}x{v}"))?;
                    cache.insert((entry.to_string(), u, v), i);
                    i
                }
            }
        };
        self.compile_idx(idx)?;
        let compiled = self.compiled.lock().unwrap();
        let c = compiled[idx].as_ref().unwrap();
        let input = xla::Literal::vec1(a)
            .reshape(&[u as i64, v as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = c
            .exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == c.n_out,
            "artifact {entry} returned {} outputs, manifest says {}",
            parts.len(),
            c.n_out
        );
        Ok(parts)
    }

    /// Execute the `count_dense` artifact (padded to an available
    /// shape by the caller) and decode its four outputs.
    pub fn count_dense(&self, u: usize, v: usize, a: &[f32]) -> Result<DenseOutputs> {
        let parts = self.run_raw("count_dense", u, v, a)?;
        anyhow::ensure!(parts.len() == 4, "count_dense must have 4 outputs");
        let total: f64 = parts[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?[0];
        let bu = parts[1].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
        let bv = parts[2].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
        let be = parts[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(DenseOutputs { total, bu, bv, be })
    }

    /// Execute the `count_total` artifact.
    pub fn count_total(&self, u: usize, v: usize, a: &[f32]) -> Result<f64> {
        let parts = self.run_raw("count_total", u, v, a)?;
        Ok(parts[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?[0])
    }

    /// Execute the `wedge_stats` artifact: (wedges with endpoints on U,
    /// wedges with endpoints on V).
    pub fn wedge_stats(&self, u: usize, v: usize, a: &[f32]) -> Result<(f64, f64)> {
        let parts = self.run_raw("wedge_stats", u, v, a)?;
        let wu = parts[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?[0];
        let wv = parts[1].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok((wu, wv))
    }
}
