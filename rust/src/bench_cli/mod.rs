//! `parbutterfly bench` — the native benchmark harness CLI.
//!
//! Three subcommands, rebar-style (named workloads, one runner,
//! recorded results, a regression barometer):
//!
//! ```text
//! bench list                          # every registered target
//! bench run [--filter S] [--smoke] [--threads T] [--out-dir DIR]
//! bench diff OLD.json NEW.json [--threshold 1.15]
//! bench diff --check-schema FILE...
//! ```
//!
//! `bench run` executes targets from the shared
//! [`crate::bench_support::registry`] — the same code `cargo bench`
//! runs — and rewrites the `BENCH_*.json` snapshots with
//! `harness: "native"` rows plus environment metadata.  `--smoke` is
//! the CI profile: tiny workloads, 0 warmup + 1 timed run, snapshots
//! written to a temp directory (never dirtying the committed files)
//! unless `--out-dir` says otherwise.
//!
//! `bench diff` compares medians per identity row (all row fields
//! except the measured annotations) and exits nonzero when any row
//! regressed past the threshold — the perf gate CI and future PRs
//! cite instead of eyeballing `BENCHROW` dumps.

pub mod diff;

use std::path::PathBuf;

use crate::bench_support::registry::{self, Profile, Target};
use crate::prims::pool::with_threads;

const HELP: &str = "parbutterfly bench — native benchmark harness
  bench list                                   list registered targets
  bench run  [--filter S] [--smoke] [--threads T] [--out-dir DIR]
  bench diff OLD.json NEW.json [--threshold R]  (R > 1, default 1.15)
  bench diff --check-schema FILE...             validate snapshot schema";

/// Entry point from the main CLI dispatcher (`argv` excludes `bench`).
pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &argv[1.min(argv.len())..];
    match sub {
        "list" => cmd_list(rest),
        "run" => cmd_run(rest),
        "diff" => diff::cmd_diff(rest),
        "help" | "--help" => {
            println!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown bench subcommand {other:?} (valid: run|diff|list)"),
    }
}

/// Pull the value after a flag, erroring (not defaulting) when absent.
fn flag_value<'a>(argv: &'a [String], i: &mut usize, flag: &str) -> anyhow::Result<&'a str> {
    *i += 1;
    let v = argv.get(*i).ok_or_else(|| anyhow::anyhow!("{flag} needs a value"))?;
    *i += 1;
    Ok(v)
}

fn cmd_list(argv: &[String]) -> anyhow::Result<()> {
    anyhow::ensure!(argv.is_empty(), "bench list takes no arguments");
    println!("{:<12} {:<22} {:<22} description", "id", "cargo bench --bench", "snapshot");
    for t in registry::targets() {
        println!(
            "{:<12} {:<22} {:<22} {}",
            t.id,
            t.bin,
            t.snapshot.unwrap_or("-"),
            t.describe
        );
    }
    Ok(())
}

fn cmd_run(argv: &[String]) -> anyhow::Result<()> {
    let mut filter: Option<String> = None;
    let mut smoke = false;
    let mut threads: Option<usize> = None;
    let mut out_dir: Option<PathBuf> = None;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--smoke" => {
                smoke = true;
                i += 1;
            }
            "--filter" => filter = Some(flag_value(argv, &mut i, "--filter")?.to_string()),
            "--threads" => {
                let s = flag_value(argv, &mut i, "--threads")?;
                threads = match s.parse::<usize>() {
                    Ok(t) if t > 0 => Some(t),
                    _ => anyhow::bail!("bad --threads {s:?} (need a positive integer)"),
                };
            }
            "--out-dir" => {
                out_dir = Some(PathBuf::from(flag_value(argv, &mut i, "--out-dir")?))
            }
            other => anyhow::bail!(
                "unknown bench run flag {other:?} (valid: --filter|--smoke|--threads|--out-dir)"
            ),
        }
    }
    let profile = if smoke { Profile::Smoke } else { Profile::Full };
    // Full runs rewrite the committed snapshots at the workspace root;
    // smoke runs are a harness health check and land in a temp dir.
    let out_dir = out_dir.unwrap_or_else(|| match profile {
        Profile::Full => registry::workspace_root(),
        Profile::Smoke => std::env::temp_dir().join("pb_bench_smoke"),
    });
    let selected: Vec<&'static Target> = registry::targets()
        .iter()
        .filter(|t| match &filter {
            Some(f) => t.id.contains(f.as_str()) || t.bin.contains(f.as_str()),
            None => true,
        })
        .collect();
    anyhow::ensure!(
        !selected.is_empty(),
        "no bench targets match --filter {:?} (see `bench list`)",
        filter.as_deref().unwrap_or("")
    );
    let run_all = || -> anyhow::Result<usize> {
        let mut snapshots = 0;
        for t in &selected {
            println!("\n### bench {} — {}", t.id, t.describe);
            if let Some(path) = registry::run_target(t, profile, &out_dir)? {
                println!("snapshot: {}", path.display());
                snapshots += 1;
            }
        }
        Ok(snapshots)
    };
    let snapshots = match threads {
        Some(t) => with_threads(t, run_all),
        None => run_all(),
    }?;
    println!(
        "\nran {} target(s) at the {} profile ({} snapshot(s) written to {})",
        selected.len(),
        profile.name(),
        snapshots,
        out_dir.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn unknown_subcommands_and_flags_are_rejected() {
        assert!(run(&argv(&["frobnicate"])).is_err());
        assert!(run(&argv(&["run", "--no-such-flag"])).is_err());
        assert!(run(&argv(&["run", "--threads", "zero"])).is_err());
        assert!(run(&argv(&["run", "--threads", "0"])).is_err());
        assert!(run(&argv(&["run", "--filter"])).is_err(), "--filter needs a value");
        assert!(run(&argv(&["run", "--filter", "no-such-target"])).is_err());
        assert!(run(&argv(&["list", "stray"])).is_err());
        run(&argv(&["list"])).unwrap();
        run(&argv(&[])).unwrap(); // help
    }

    #[test]
    fn smoke_run_writes_native_snapshots_to_out_dir() {
        let dir = std::env::temp_dir().join("pb_bench_cli_smoke_test");
        std::fs::remove_dir_all(&dir).ok();
        run(&argv(&[
            "run",
            "--smoke",
            "--filter",
            "dynamic",
            "--threads",
            "2",
            "--out-dir",
            dir.to_str().unwrap(),
        ]))
        .unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_dynamic.json")).unwrap();
        let doc = crate::bench_support::json::Json::parse(&text).unwrap();
        assert_eq!(doc.get("harness").unwrap().as_str().unwrap(), "native");
        assert_eq!(
            doc.get("env").unwrap().get("profile").unwrap().as_str().unwrap(),
            "smoke"
        );
        assert!(!doc.get("rows").unwrap().as_arr().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
