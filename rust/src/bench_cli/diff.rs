//! `bench diff` — the regression barometer over `BENCH_*.json` files.
//!
//! Rows are matched by *identity*: every row field except the measured
//! annotations (`median_ms`, `min_ms`, `max_ms`, `p90_ms`, `runs`,
//! `value`, `rounds`, `butterflies`).  That makes the comparison
//! schema-agnostic across the four snapshot shapes — (workload, stat,
//! config) for counting, (workload, mode, config) for peeling,
//! (workload, stage, threads) for preprocessing, (workload, batch,
//! threads, path) for dynamic — and keeps python-model seed rows
//! comparable with native rows.
//!
//! A row regressed when `new_median / old_median > threshold`;
//! improvements are the mirror image.  `cmd_diff` prints a ranked
//! table and returns an error (nonzero process exit) when any
//! regression passes the threshold — that error is the CI perf gate.
//!
//! `--check-schema` instead validates each file against the stable
//! snapshot schema (`bench` / `harness` / `rows`; every row carries a
//! workload and a numeric `median_ms` or `value`), so CI catches a
//! malformed snapshot before it poisons future diffs.

use std::path::Path;

use crate::bench_support::json::Json;

/// Row fields that describe the *measurement*, not the row identity.
const ANNOTATIONS: [&str; 8] =
    ["median_ms", "min_ms", "max_ms", "p90_ms", "runs", "value", "rounds", "butterflies"];

/// Stable identity of a snapshot row: the non-annotation fields,
/// sorted, rendered `k=v` — robust to field order and to labels
/// containing spaces (never re-parsed from a composed string).
pub fn row_key(row: &Json) -> Option<String> {
    let obj = row.as_obj()?;
    let mut parts: Vec<String> = obj
        .iter()
        .filter(|(k, _)| !ANNOTATIONS.contains(&k.as_str()))
        .map(|(k, v)| match v.as_str() {
            Some(s) => format!("{k}={s}"),
            None => format!("{k}={}", v.compact()),
        })
        .collect();
    if parts.is_empty() {
        return None;
    }
    parts.sort();
    Some(parts.join(" "))
}

/// One compared row.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub key: String,
    pub old_ms: f64,
    pub new_ms: f64,
    /// `new_ms / old_ms` — above 1 is slower.
    pub ratio: f64,
}

/// Outcome of comparing two snapshots.
#[derive(Debug, Default)]
pub struct Diff {
    /// Rows past the threshold, worst first.
    pub regressions: Vec<DiffRow>,
    /// Rows past the mirrored threshold, best first.
    pub improvements: Vec<DiffRow>,
    /// Rows within the threshold either way.
    pub within: usize,
    /// Identity keys only in the new file.
    pub added: Vec<String>,
    /// Identity keys only in the old file.
    pub removed: Vec<String>,
}

fn timed_rows(doc: &Json) -> anyhow::Result<Vec<(String, f64)>> {
    let rows = doc
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("snapshot has no `rows` array"))?;
    let mut out = Vec::new();
    for row in rows {
        // Unmeasured rows (`value`-only: f-metrics, dataset stats)
        // carry no timing to compare.
        let Some(ms) = row.get("median_ms").and_then(Json::as_f64) else {
            continue;
        };
        let key = row_key(row).ok_or_else(|| {
            anyhow::anyhow!("row {} has no identity fields", row.compact())
        })?;
        out.push((key, ms));
    }
    Ok(out)
}

/// Compare two parsed snapshots at `threshold` (> 1).
pub fn diff_docs(old: &Json, new: &Json, threshold: f64) -> anyhow::Result<Diff> {
    anyhow::ensure!(threshold > 1.0, "bad --threshold {threshold} (need a ratio > 1)");
    let old_rows = timed_rows(old)?;
    let new_rows = timed_rows(new)?;
    let mut diff = Diff::default();
    for (key, new_ms) in &new_rows {
        // Duplicate identities would make "the" old median ambiguous;
        // first match wins and duplicates are a schema-check concern.
        match old_rows.iter().find(|(k, _)| k == key) {
            None => diff.added.push(key.clone()),
            Some((_, old_ms)) => {
                // Sub-precision medians (0.0 after 3-decimal rounding)
                // cannot support a ratio; treat as within-threshold.
                let ratio = if *old_ms > 0.0 && *new_ms > 0.0 { new_ms / old_ms } else { 1.0 };
                let row = DiffRow { key: key.clone(), old_ms: *old_ms, new_ms: *new_ms, ratio };
                if ratio > threshold {
                    diff.regressions.push(row);
                } else if ratio < 1.0 / threshold {
                    diff.improvements.push(row);
                } else {
                    diff.within += 1;
                }
            }
        }
    }
    for (key, _) in &old_rows {
        if !new_rows.iter().any(|(k, _)| k == key) {
            diff.removed.push(key.clone());
        }
    }
    diff.regressions.sort_by(|a, b| b.ratio.total_cmp(&a.ratio));
    diff.improvements.sort_by(|a, b| a.ratio.total_cmp(&b.ratio));
    Ok(diff)
}

/// Validate one snapshot file against the stable schema.
pub fn check_schema(path: &Path) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e:#}", path.display()))?;
    let fail = |what: &str| anyhow::anyhow!("{}: {what}", path.display());
    doc.get("bench").and_then(Json::as_str).ok_or_else(|| fail("missing string `bench`"))?;
    let harness =
        doc.get("harness").and_then(Json::as_str).ok_or_else(|| fail("missing string `harness`"))?;
    anyhow::ensure!(
        harness == "native" || harness == "python-model",
        fail(&format!("harness {harness:?} is neither \"native\" nor \"python-model\""))
    );
    let rows = doc.get("rows").and_then(Json::as_arr).ok_or_else(|| fail("missing `rows` array"))?;
    let mut keys: Vec<String> = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let rfail = |what: &str| fail(&format!("rows[{i}] {what}"));
        row.get("workload")
            .and_then(Json::as_str)
            .ok_or_else(|| rfail("has no string `workload`"))?;
        let timed = row.get("median_ms").and_then(Json::as_f64).is_some();
        let valued = row.get("value").is_some();
        anyhow::ensure!(timed || valued, rfail("has neither numeric `median_ms` nor `value`"));
        if timed {
            let key = row_key(row).ok_or_else(|| rfail("has no identity fields"))?;
            anyhow::ensure!(
                !keys.contains(&key),
                rfail(&format!("duplicates identity `{key}`"))
            );
            keys.push(key);
        }
    }
    Ok(())
}

fn print_section(title: &str, rows: &[DiffRow]) {
    if rows.is_empty() {
        return;
    }
    println!("{title}:");
    for r in rows {
        println!(
            "  {:>7.2}x  {:>10.3} ms -> {:>10.3} ms   {}",
            r.ratio, r.old_ms, r.new_ms, r.key
        );
    }
}

/// `bench diff` entry point (`argv` excludes `diff` itself).
pub fn cmd_diff(argv: &[String]) -> anyhow::Result<()> {
    let mut files: Vec<&str> = Vec::new();
    let mut threshold = 1.15_f64;
    let mut check = false;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--threshold" => {
                i += 1;
                let s = argv.get(i).ok_or_else(|| anyhow::anyhow!("--threshold needs a value"))?;
                threshold = s
                    .parse::<f64>()
                    .ok()
                    .filter(|t| *t > 1.0)
                    .ok_or_else(|| anyhow::anyhow!("bad --threshold {s:?} (need a ratio > 1)"))?;
                i += 1;
            }
            "--check-schema" => {
                check = true;
                i += 1;
            }
            other if other.starts_with("--") => {
                anyhow::bail!(
                    "unknown bench diff flag {other:?} (valid: --threshold|--check-schema)"
                )
            }
            file => {
                files.push(file);
                i += 1;
            }
        }
    }
    if check {
        anyhow::ensure!(!files.is_empty(), "bench diff --check-schema needs at least one file");
        for f in &files {
            check_schema(Path::new(f))?;
            println!("ok: {f}");
        }
        return Ok(());
    }
    anyhow::ensure!(
        files.len() == 2,
        "bench diff needs exactly two files: OLD.json NEW.json (got {})",
        files.len()
    );
    let load = |p: &str| -> anyhow::Result<Json> {
        Json::parse(&std::fs::read_to_string(p).map_err(|e| anyhow::anyhow!("{p}: {e}"))?)
            .map_err(|e| anyhow::anyhow!("{p}: {e:#}"))
    };
    let old = load(files[0])?;
    let new = load(files[1])?;
    let diff = diff_docs(&old, &new, threshold)?;
    println!(
        "bench diff: {} vs {} (threshold {threshold}x)",
        files[0], files[1]
    );
    print_section("regressions (worst first)", &diff.regressions);
    print_section("improvements (best first)", &diff.improvements);
    if !diff.added.is_empty() {
        println!("new rows (no baseline): {}", diff.added.len());
    }
    if !diff.removed.is_empty() {
        println!("removed rows:");
        for k in &diff.removed {
            println!("  {k}");
        }
    }
    println!(
        "{} row(s) within threshold, {} regressed, {} improved, {} added, {} removed",
        diff.within,
        diff.regressions.len(),
        diff.improvements.len(),
        diff.added.len(),
        diff.removed.len()
    );
    anyhow::ensure!(
        diff.regressions.is_empty(),
        "{} row(s) regressed past {threshold}x (worst: {} at {:.2}x)",
        diff.regressions.len(),
        diff.regressions[0].key,
        diff.regressions[0].ratio
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(rows: &str) -> Json {
        Json::parse(&format!(
            r#"{{"bench": "t", "harness": "native", "rows": [{rows}]}}"#
        ))
        .unwrap()
    }

    fn row(workload: &str, config: &str, ms: f64) -> String {
        format!(r#"{{"workload": "{workload}", "config": "{config}", "median_ms": {ms}}}"#)
    }

    #[test]
    fn regression_past_threshold_is_detected_and_ranked() {
        let old = snap(&[row("er", "a", 10.0), row("er", "b", 10.0)].join(", "));
        let new = snap(&[row("er", "a", 13.0), row("er", "b", 20.0)].join(", "));
        let d = diff_docs(&old, &new, 1.15).unwrap();
        assert_eq!(d.regressions.len(), 2);
        // Ranked worst-first: b at 2.0x before a at 1.3x.
        assert!(d.regressions[0].key.contains("config=b"));
        assert!((d.regressions[0].ratio - 2.0).abs() < 1e-9);
        assert!(d.regressions[1].key.contains("config=a"));
        assert_eq!(d.within, 0);
        assert!(d.improvements.is_empty());
    }

    #[test]
    fn within_threshold_rows_do_not_trip_the_gate() {
        let old = snap(&row("er", "a", 10.0));
        let new = snap(&row("er", "a", 11.0));
        let d = diff_docs(&old, &new, 1.15).unwrap();
        assert!(d.regressions.is_empty() && d.improvements.is_empty());
        assert_eq!(d.within, 1);
        // And the inverse direction counts as an improvement.
        let d = diff_docs(&old, &snap(&row("er", "a", 5.0)), 1.15).unwrap();
        assert_eq!(d.improvements.len(), 1);
        assert!((d.improvements[0].ratio - 0.5).abs() < 1e-9);
    }

    #[test]
    fn new_and_missing_rows_are_reported_not_compared() {
        let old = snap(&[row("er", "a", 10.0), row("er", "gone", 9.0)].join(", "));
        let new = snap(&[row("er", "a", 10.0), row("cl", "fresh", 3.0)].join(", "));
        let d = diff_docs(&old, &new, 1.15).unwrap();
        assert_eq!(d.added, vec!["config=fresh workload=cl".to_string()]);
        assert_eq!(d.removed, vec!["config=gone workload=er".to_string()]);
        assert_eq!(d.within, 1);
        assert!(d.regressions.is_empty());
    }

    #[test]
    fn identity_ignores_annotations_and_survives_spaces() {
        let a = Json::parse(
            r#"{"workload": "er", "config": "PB par", "median_ms": 1.0, "p90_ms": 2.0,
                "runs": 3, "rounds": 7}"#,
        )
        .unwrap();
        let b = Json::parse(r#"{"config": "PB par", "workload": "er", "median_ms": 99.0}"#)
            .unwrap();
        assert_eq!(row_key(&a).unwrap(), "config=PB par workload=er");
        assert_eq!(row_key(&a), row_key(&b), "field order and annotations must not matter");
    }

    #[test]
    fn cmd_diff_exits_nonzero_on_doctored_regression() {
        let dir = std::env::temp_dir().join("pb_bench_diff_test");
        std::fs::create_dir_all(&dir).unwrap();
        let old_p = dir.join("old.json");
        let new_p = dir.join("new.json");
        std::fs::write(&old_p, snap(&row("er", "a", 10.0)).pretty()).unwrap();
        std::fs::write(&new_p, snap(&row("er", "a", 30.0)).pretty()).unwrap();
        let argv = |v: &[&str]| -> Vec<String> { v.iter().map(|s| s.to_string()).collect() };
        let err = cmd_diff(&argv(&[old_p.to_str().unwrap(), new_p.to_str().unwrap()]))
            .expect_err("3x regression must fail the gate");
        assert!(format!("{err:#}").contains("regressed"));
        // A generous threshold lets the same pair pass.
        cmd_diff(&argv(&[
            old_p.to_str().unwrap(),
            new_p.to_str().unwrap(),
            "--threshold",
            "4.0",
        ]))
        .unwrap();
        // Flag hygiene.
        assert!(cmd_diff(&argv(&["--threshold", "0.5", "x", "y"])).is_err());
        assert!(cmd_diff(&argv(&["only-one.json"])).is_err());
        assert!(cmd_diff(&argv(&["a", "b", "--bogus"])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn check_schema_accepts_good_and_rejects_bad_files() {
        let dir = std::env::temp_dir().join("pb_bench_schema_test");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.json");
        std::fs::write(&good, snap(&row("er", "a", 1.0)).pretty()).unwrap();
        check_schema(&good).unwrap();
        for (name, text) in [
            ("not-json.json", "{nope"),
            ("no-harness.json", r#"{"bench": "t", "rows": []}"#),
            ("bad-harness.json", r#"{"bench": "t", "harness": "guess", "rows": []}"#),
            ("no-rows.json", r#"{"bench": "t", "harness": "native"}"#),
            (
                "bad-row.json",
                r#"{"bench": "t", "harness": "native", "rows": [{"workload": "er"}]}"#,
            ),
            (
                "dup-row.json",
                &format!(
                    r#"{{"bench": "t", "harness": "native", "rows": [{}, {}]}}"#,
                    row("er", "a", 1.0),
                    row("er", "a", 2.0)
                ),
            ),
        ] {
            let p = dir.join(name);
            std::fs::write(&p, text).unwrap();
            assert!(check_schema(&p).is_err(), "{name} must fail schema check");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_snapshot_is_rejected_naming_file_and_position() {
        let dir = std::env::temp_dir().join("pb_bench_truncated_test");
        std::fs::create_dir_all(&dir).unwrap();
        let full = snap(&row("er", "a", 1.0)).pretty();
        let p = dir.join("truncated.json");
        std::fs::write(&p, &full[..full.len() / 2]).unwrap();
        let err = check_schema(&p).expect_err("truncated snapshot must be rejected");
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated.json"), "{msg}");
        assert!(msg.contains("line "), "error should locate the failure: {msg}");
        // `bench diff` against the same file carries the same context.
        let good = dir.join("good.json");
        std::fs::write(&good, &full).unwrap();
        let argv: Vec<String> = [good.to_str().unwrap(), p.to_str().unwrap()]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = cmd_diff(&argv).expect_err("diff against a truncated file must fail");
        assert!(format!("{err:#}").contains("truncated.json"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn committed_snapshots_pass_the_schema_check_and_self_diff_clean() {
        let root = crate::bench_support::registry::workspace_root();
        for name in
            ["BENCH_intersect.json", "BENCH_layout.json", "BENCH_peel.json",
             "BENCH_preprocess.json", "BENCH_dynamic.json", "BENCH_serve.json"]
        {
            let path = root.join(name);
            check_schema(&path).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            let d = diff_docs(&doc, &doc, 1.15).unwrap();
            assert!(d.regressions.is_empty() && d.improvements.is_empty(), "{name} self-diff");
            assert!(d.added.is_empty() && d.removed.is_empty(), "{name} self-diff");
        }
    }
}
