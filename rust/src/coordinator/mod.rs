//! Coordinator: the framework facade gluing ranking selection,
//! counting, peeling, approximation, batch-dynamic maintenance, and
//! the pluggable dense-core backend behind one configuration surface.
//! This is the layer the CLI, examples, and benches drive.
//!
//! Static runs flow through [`count_report`] / [`tip_report`] /
//! [`wing_report`]; update streams flow through [`replay_stream`],
//! which drives a [`DynGraph`] batch by batch and summarizes the
//! replay in a [`DynReport`] (the dynamic sibling of [`CountReport`]).

// Runtime-critical modules must not abort through unchecked unwraps:
// failures either unwind as structured panics the pool catches or are
// returned as `error::Result`.  Test code is exempt.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]
use std::time::Instant;

use crate::count::{
    self, count_per_edge, count_per_vertex, CountOpts, VertexCounts,
};
use crate::dynamic::stream::ParseReject;
use crate::error::Result;
use crate::dynamic::stream::Batch;
use crate::dynamic::{
    apply_batch_with_retry, BatchKind, BatchOutcome, DynGraph, DynOpts, RetryOutcome,
};

pub use crate::dynamic::BatchError;
use crate::graph::BipartiteGraph;
use crate::peel::{self, PeelEOpts, PeelVOpts, TipResult, WingResult};
use crate::rank::{choose_ranking, PreprocessTiming, Ranking};
use crate::runtime::{self, DenseBackend};

/// What to compute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CountMode {
    Total,
    PerVertex,
    PerEdge,
    Full,
}

/// Counting configuration.
#[derive(Clone, Debug, Default)]
pub struct CountConfig {
    pub opts: CountOpts,
    /// Override `opts.ranking` with the runtime `f`-metric rule
    /// (§6.2.2): side ordering unless a degree-style ordering saves
    /// >= 10% of wedges.
    pub auto_rank: bool,
}

/// Peeling configuration.  The update engine
/// ([`peel::PeelEngine`], agg vs streaming intersect) rides in
/// `vopts`/`eopts`, mirroring how `count.opts.engine` selects the
/// counting engine.
#[derive(Clone, Debug, Default)]
pub struct PeelConfig {
    pub count: CountConfig,
    pub vopts: PeelVOpts,
    pub eopts: PeelEOpts,
}

/// Output of a coordinated counting run.
#[derive(Clone, Debug)]
pub struct CountReport {
    pub total: u64,
    pub per_vertex: Option<VertexCounts>,
    pub per_edge: Option<Vec<u64>>,
    /// Ranking actually used (after auto selection).
    pub ranking: Ranking,
    /// Wedges processed under that ranking.
    pub wedges: u64,
    /// Wall-clock milliseconds for the counting phase.
    pub millis: f64,
    /// Per-stage breakdown of the preprocessing pipeline (rank
    /// permutation + PREPROCESS build) that ran before counting;
    /// zeroed when a dense backend answered without preprocessing.
    pub preprocess: PreprocessTiming,
    /// "cpu" (sparse framework) or the dense backend's name
    /// ("rust-dense", "pjrt").
    pub backend: &'static str,
    /// Counting engine used on the CPU path ("wedges", "intersect");
    /// "dense" when a dense backend answered instead.
    pub engine: &'static str,
}

fn resolve_ranking(g: &BipartiteGraph, cfg: &CountConfig) -> Ranking {
    if cfg.auto_rank {
        choose_ranking(g)
    } else {
        cfg.opts.ranking
    }
}

/// Count butterflies under `cfg` (CPU framework path).  Runs under
/// `cfg.opts.budget`; a worker panic, injected fault, or budget trip
/// surfaces as a structured [`Err`](crate::Error).
pub fn count_report(
    g: &BipartiteGraph,
    mode: CountMode,
    cfg: &CountConfig,
) -> Result<CountReport> {
    let ranking = resolve_ranking(g, cfg);
    let opts = CountOpts { ranking, ..cfg.opts.clone() };
    let (rg, preprocess) = crate::rank::preprocess_timed(g, ranking);
    let wedges = rg.wedges_processed();
    let start = Instant::now();
    let (total, per_vertex, per_edge) = match mode {
        CountMode::Total => (count::count_total_ranked(&rg, &opts)?, None, None),
        CountMode::PerVertex => {
            let vc = count_per_vertex(g, &opts)?;
            let t = vc.bu.iter().sum::<u64>() / 2;
            (t, Some(vc), None)
        }
        CountMode::PerEdge => {
            let be = count_per_edge(g, &opts)?;
            let t = be.iter().sum::<u64>() / 4;
            (t, None, Some(be))
        }
        CountMode::Full => {
            let vc = count_per_vertex(g, &opts)?;
            let be = count_per_edge(g, &opts)?;
            let t = vc.bu.iter().sum::<u64>() / 2;
            (t, Some(vc), Some(be))
        }
    };
    Ok(CountReport {
        total,
        per_vertex,
        per_edge,
        ranking,
        wedges,
        millis: start.elapsed().as_secs_f64() * 1e3,
        preprocess,
        backend: "cpu",
        engine: opts.engine.name(),
    })
}

/// Shorthand: total count with the default pipeline.
pub fn count_butterflies(g: &BipartiteGraph, cfg: &CountConfig) -> Result<CountReport> {
    count_report(g, CountMode::Total, cfg)
}

/// Tip decomposition under `cfg`.  Counting runs under
/// `cfg.count.opts.budget`, peeling under `cfg.vopts.budget`.
pub fn tip_report(g: &BipartiteGraph, cfg: &PeelConfig) -> Result<(TipResult, f64)> {
    let counts = count_report(g, CountMode::PerVertex, &cfg.count)?;
    let vc = match counts.per_vertex {
        Some(vc) => vc,
        None => unreachable!("PerVertex report always carries counts"),
    };
    let start = Instant::now();
    let r = peel::peel_vertices(g, &vc.bu, &vc.bv, &cfg.vopts)?;
    Ok((r, start.elapsed().as_secs_f64() * 1e3))
}

/// Wing decomposition under `cfg`.  Budgets compose as in
/// [`tip_report`].
pub fn wing_report(g: &BipartiteGraph, cfg: &PeelConfig) -> Result<(WingResult, f64)> {
    let counts = count_report(g, CountMode::PerEdge, &cfg.count)?;
    let be = match counts.per_edge {
        Some(be) => be,
        None => unreachable!("PerEdge report always carries counts"),
    };
    let start = Instant::now();
    let r = peel::peel_edges(g, &be, &cfg.eopts)?;
    Ok((r, start.elapsed().as_secs_f64() * 1e3))
}

/// Outcome of replaying an update stream through [`DynGraph`] — the
/// dynamic-workload sibling of [`CountReport`].
#[derive(Clone, Debug)]
pub struct DynReport {
    /// Batches replayed (after grouping).
    pub batches: usize,
    /// Edges actually inserted / deleted across all batches.
    pub inserted: usize,
    pub deleted: usize,
    /// No-op events (duplicates, present inserts, absent deletes).
    pub skipped: usize,
    /// Batches answered by the incremental delta walk vs the
    /// rebuild-threshold full recount.
    pub delta_batches: usize,
    pub recount_batches: usize,
    /// Global butterfly count after the final batch.
    pub total: u64,
    /// Wall-clock milliseconds across all batch applications.
    pub millis: f64,
    /// Batches whose delta walk failed and were recovered by the
    /// graceful-degradation recount inside [`DynGraph`].
    pub fallback_batches: usize,
    /// Per-batch outcomes, in replay order (failed-and-skipped batches
    /// have no outcome — see `errors`).
    pub outcomes: Vec<BatchOutcome>,
    /// Per-batch failures, in replay order.  `recovered` batches were
    /// retried successfully (after a rebuild when the failure had
    /// poisoned the graph); unrecovered ones were skipped.
    pub errors: Vec<BatchError>,
    /// Malformed stream lines skipped by the lenient parser
    /// ([`crate::dynamic::stream::parse_stream_lenient`]); empty under
    /// strict parsing.  Filled in by the replay driver.
    pub parse_rejects: Vec<ParseReject>,
    /// `Some(ok)` when verification against a full static recount of
    /// the final graph was requested.
    pub verified: Option<bool>,
}

/// Replay grouped update batches over `g`, maintaining exact counts
/// incrementally; with `verify`, the final counts (all three
/// granularities) are checked against a full static recount through
/// the same engine.
/// Failed batches are retried once (rebuilding the graph first when
/// the failure poisoned it); a batch whose retry also fails is
/// recorded in [`DynReport::errors`] and **skipped** rather than
/// aborting the replay.  Only an unrecoverable graph — a rebuild that
/// itself fails — aborts with `Err`.
pub fn replay_stream(
    g: BipartiteGraph,
    batches: &[Batch],
    opts: &DynOpts,
    verify: bool,
) -> Result<(DynGraph, DynReport)> {
    let mut dg = DynGraph::new(g, opts.clone())?;
    let mut rep = DynReport {
        batches: batches.len(),
        inserted: 0,
        deleted: 0,
        skipped: 0,
        delta_batches: 0,
        recount_batches: 0,
        fallback_batches: 0,
        total: dg.total(),
        millis: 0.0,
        outcomes: Vec::with_capacity(batches.len()),
        errors: Vec::new(),
        parse_rejects: Vec::new(),
        verified: None,
    };
    for (i, b) in batches.iter().enumerate() {
        // The retry-and-rebuild policy (and its one aborting case: a
        // rebuild that itself fails) lives in
        // [`apply_batch_with_retry`], shared with the serve writer.
        let out = match apply_batch_with_retry(&mut dg, b.kind, &b.edges)? {
            RetryOutcome::Clean(out) => out,
            RetryOutcome::Recovered { outcome, error } => {
                rep.errors.push(BatchError {
                    batch: i,
                    kind: b.kind,
                    error,
                    recovered: true,
                });
                outcome
            }
            RetryOutcome::Skipped { error } => {
                rep.errors.push(BatchError {
                    batch: i,
                    kind: b.kind,
                    error,
                    recovered: false,
                });
                continue; // batch skipped
            }
        };
        match b.kind {
            BatchKind::Insert => rep.inserted += out.applied,
            BatchKind::Delete => rep.deleted += out.applied,
        }
        rep.skipped += out.skipped;
        rep.millis += out.millis;
        rep.outcomes.push(out);
    }
    // Path attribution comes from the graph's own counters (no-op
    // batches take neither path), so the report cannot drift from
    // [`DynGraph`]'s accounting.
    rep.delta_batches = dg.delta_batches();
    rep.recount_batches = dg.recount_batches();
    rep.fallback_batches = dg.fallback_batches();
    rep.total = dg.total();
    if verify {
        let opts = &opts.count;
        let vc = count_per_vertex(dg.graph(), opts)?;
        let pe = count_per_edge(dg.graph(), opts)?;
        let ok = dg.total() == vc.bu.iter().sum::<u64>() / 2
            && dg.per_vertex_u() == &vc.bu[..]
            && dg.per_vertex_v() == &vc.bv[..]
            && dg.per_edge() == &pe[..];
        rep.verified = Some(ok);
    }
    Ok((dg, rep))
}

/// Default routing cap for [`Coordinator::count_total_routed`]: the
/// dense model is `O(u^2 * v)` regardless of sparsity, so beyond small
/// blocks the sparse CPU framework wins even when the backend *could*
/// fit the graph in a tile.
const DENSE_ROUTE_LIMIT: usize = 512;

/// A coordinator that may hold a dense backend for small/dense blocks.
pub struct Coordinator {
    backend: Option<Box<dyn DenseBackend>>,
    /// Largest `max(nu, nv)` routed to the dense backend.  Defaults to
    /// `min(backend.max_dim(), 512)`; raise it (up to the backend's
    /// `max_dim`) to widen dense routing.
    pub dense_limit: usize,
}

impl Coordinator {
    /// CPU-only coordinator (no dense path at all).
    pub fn cpu_only() -> Self {
        Self { backend: None, dense_limit: 0 }
    }

    /// Coordinator over an explicit dense backend.
    pub fn with_backend(backend: Box<dyn DenseBackend>) -> Self {
        let dense_limit = backend.max_dim().min(DENSE_ROUTE_LIMIT);
        Self { backend: Some(backend), dense_limit }
    }

    /// Attach the process-default dense backend
    /// ([`runtime::default_backend`]): PJRT when the feature is on and
    /// artifacts load, the pure-Rust reference kernel otherwise;
    /// degrades to CPU-only when the dense path is disabled.
    pub fn with_default_backend() -> Self {
        match runtime::default_backend() {
            Some(backend) => Self::with_backend(backend),
            None => Self::cpu_only(),
        }
    }

    pub fn has_backend(&self) -> bool {
        self.backend.is_some()
    }

    pub fn backend(&self) -> Option<&dyn DenseBackend> {
        self.backend.as_deref()
    }

    /// Route a total count: dense backend when the graph fits a tile,
    /// CPU framework otherwise (including on dense-path errors).
    pub fn count_total_routed(
        &self,
        g: &BipartiteGraph,
        cfg: &CountConfig,
    ) -> Result<CountReport> {
        if let Some(backend) = &self.backend {
            if g.nu().max(g.nv()) <= self.dense_limit {
                if let Some((pu, pv)) = backend.plan(g.nu(), g.nv()) {
                    let start = Instant::now();
                    let a = g.to_dense_f32(pu, pv);
                    if let Ok(t) = backend.count_total(pu, pv, &a) {
                        return Ok(CountReport {
                            total: t.round() as u64,
                            per_vertex: None,
                            per_edge: None,
                            ranking: cfg.opts.ranking,
                            wedges: 0,
                            millis: start.elapsed().as_secs_f64() * 1e3,
                            preprocess: PreprocessTiming::default(),
                            backend: backend.name(),
                            engine: "dense",
                        });
                    }
                }
            }
        }
        count_report(g, CountMode::Total, cfg)
    }
}

/// The session-owning facade over the coordinator: static reports
/// delegate to the free functions above (and to the [`Coordinator`]'s
/// dense routing), while long-lived serve-mode state — named
/// [`serve::Session`](crate::serve::Session)s holding graphs resident
/// under a writer thread — is owned here.  This is the struct ROADMAP
/// item 1 asks for: the place sharding and cross-request caching can
/// later attach without another refactor.
pub struct Service {
    coordinator: Coordinator,
    sessions: Vec<(String, std::sync::Arc<crate::serve::Session>)>,
}

impl Service {
    pub fn new(coordinator: Coordinator) -> Self {
        Self { coordinator, sessions: Vec::new() }
    }

    /// Service without a dense path (see [`Coordinator::cpu_only`]).
    pub fn cpu_only() -> Self {
        Self::new(Coordinator::cpu_only())
    }

    /// Service over the process-default dense backend (see
    /// [`Coordinator::with_default_backend`]).
    pub fn with_default_backend() -> Self {
        Self::new(Coordinator::with_default_backend())
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coordinator
    }

    /// Static count with dense routing for totals
    /// ([`Coordinator::count_total_routed`]); other modes go through
    /// the CPU framework.
    pub fn count(&self, g: &BipartiteGraph, mode: CountMode, cfg: &CountConfig) -> Result<CountReport> {
        match mode {
            CountMode::Total => self.coordinator.count_total_routed(g, cfg),
            _ => count_report(g, mode, cfg),
        }
    }

    /// Static tip decomposition (see [`tip_report`]).
    pub fn tips(&self, g: &BipartiteGraph, cfg: &PeelConfig) -> Result<(TipResult, f64)> {
        tip_report(g, cfg)
    }

    /// Static wing decomposition (see [`wing_report`]).
    pub fn wings(&self, g: &BipartiteGraph, cfg: &PeelConfig) -> Result<(WingResult, f64)> {
        wing_report(g, cfg)
    }

    /// Replay an update stream (see [`replay_stream`]).
    pub fn replay(
        &self,
        g: BipartiteGraph,
        batches: &[Batch],
        opts: &DynOpts,
        verify: bool,
    ) -> Result<(DynGraph, DynReport)> {
        replay_stream(g, batches, opts, verify)
    }

    /// Open (or replace) a named resident session over `g`.  The
    /// returned handle is shared: queries can keep using it after the
    /// service itself is gone.
    pub fn open_session(
        &mut self,
        name: &str,
        g: BipartiteGraph,
        opts: crate::serve::ServeOpts,
    ) -> Result<std::sync::Arc<crate::serve::Session>> {
        let session = std::sync::Arc::new(crate::serve::Session::open(g, opts)?);
        self.sessions.retain(|(n, _)| n != name);
        self.sessions.push((name.to_string(), std::sync::Arc::clone(&session)));
        Ok(session)
    }

    /// Look up a resident session by name.
    pub fn session(&self, name: &str) -> Option<std::sync::Arc<crate::serve::Session>> {
        self.sessions
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| std::sync::Arc::clone(s))
    }

    /// Drop a named session (shutting its writer down unless other
    /// handles keep it alive); returns whether it existed.
    pub fn close_session(&mut self, name: &str) -> bool {
        let before = self.sessions.len();
        self.sessions.retain(|(n, _)| n != name);
        self.sessions.len() != before
    }

    /// Names of the open sessions, in opening order.
    pub fn session_names(&self) -> Vec<String> {
        self.sessions.iter().map(|(n, _)| n.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::testutil::brute;

    #[test]
    fn report_modes_are_consistent() {
        let g = gen::erdos_renyi(25, 30, 220, 4);
        let expect = brute::total(&g);
        let cfg = CountConfig::default();
        for mode in [CountMode::Total, CountMode::PerVertex, CountMode::PerEdge, CountMode::Full] {
            let r = count_report(&g, mode, &cfg).unwrap();
            assert_eq!(r.total, expect, "{mode:?}");
        }
    }

    #[test]
    fn intersect_engine_flows_through_the_facade() {
        let g = gen::erdos_renyi(25, 30, 220, 4);
        let expect = brute::total(&g);
        let cfg = CountConfig {
            opts: CountOpts { engine: count::Engine::Intersect, ..Default::default() },
            auto_rank: false,
        };
        for mode in [CountMode::Total, CountMode::PerVertex, CountMode::PerEdge, CountMode::Full] {
            let r = count_report(&g, mode, &cfg).unwrap();
            assert_eq!(r.total, expect, "{mode:?}");
            assert_eq!(r.engine, "intersect");
        }
    }

    #[test]
    fn auto_rank_resolves() {
        let g = gen::chung_lu(200, 300, 3000, 2.05, 7);
        let cfg = CountConfig { auto_rank: true, ..Default::default() };
        let r = count_butterflies(&g, &cfg).unwrap();
        assert_eq!(r.total, brute::total(&g));
        assert_eq!(r.ranking, crate::rank::choose_ranking(&g));
    }

    #[test]
    fn cpu_only_coordinator_routes_to_cpu() {
        let g = gen::erdos_renyi(15, 15, 80, 2);
        let c = Coordinator::cpu_only();
        let r = c.count_total_routed(&g, &CountConfig::default()).unwrap();
        assert_eq!(r.backend, "cpu");
        assert_eq!(r.total, brute::total(&g));
    }

    #[test]
    fn default_backend_coordinator_routes_small_graphs_dense() {
        if std::env::var("PARBUTTERFLY_BACKEND").map(|v| v == "none" || v == "off").unwrap_or(false)
        {
            return; // dense path disabled by the developer's environment
        }
        // Under the default (auto) selection a dense backend is always
        // available: small graphs go dense, oversized graphs fall back.
        let c = Coordinator::with_default_backend();
        assert!(c.has_backend());
        let g = gen::erdos_renyi(60, 70, 700, 9);
        let r = c.count_total_routed(&g, &CountConfig::default()).unwrap();
        assert_ne!(r.backend, "cpu");
        assert_eq!(r.total, brute::total(&g));
        let big = gen::erdos_renyi(c.dense_limit + 1, 10, 50, 1);
        let r2 = c.count_total_routed(&big, &CountConfig::default()).unwrap();
        assert_eq!(r2.backend, "cpu");
    }

    #[test]
    fn explicit_backend_coordinator_respects_tile_cap() {
        let c = Coordinator::with_backend(Box::new(crate::runtime::RustDense::with_max_dim(32)));
        assert_eq!(c.dense_limit, 32);
        let g = gen::erdos_renyi(20, 20, 120, 5);
        assert_eq!(c.count_total_routed(&g, &CountConfig::default()).unwrap().backend, "rust-dense");
        let big = gen::erdos_renyi(40, 40, 300, 5);
        assert_eq!(c.count_total_routed(&big, &CountConfig::default()).unwrap().backend, "cpu");
    }

    #[test]
    fn replay_stream_matches_static_and_verifies() {
        let g = gen::erdos_renyi(15, 16, 110, 6);
        let edges = g.edges();
        let half = edges.len() / 2;
        let g0 = BipartiteGraph::from_edges(g.nu(), g.nv(), &edges[..half]);
        let batches = vec![
            Batch { kind: BatchKind::Insert, edges: edges[half..].to_vec() },
            Batch { kind: BatchKind::Delete, edges: edges[..4].to_vec() },
            Batch { kind: BatchKind::Insert, edges: edges[..4].to_vec() },
        ];
        let (dg, rep) = replay_stream(g0, &batches, &DynOpts::default(), true).unwrap();
        assert_eq!(rep.batches, 3);
        assert_eq!(rep.inserted, edges.len() - half + 4);
        assert_eq!(rep.deleted, 4);
        assert_eq!(rep.verified, Some(true));
        assert_eq!(rep.total, brute::total(&g));
        assert_eq!(dg.total(), rep.total);
        assert_eq!(rep.outcomes.len(), 3);
        assert_eq!(rep.delta_batches + rep.recount_batches, 3);
    }

    #[test]
    fn service_owns_sessions_and_delegates_reports() {
        let g = gen::erdos_renyi(15, 15, 80, 2);
        let mut svc = Service::cpu_only();
        let r = svc.count(&g, CountMode::Total, &CountConfig::default()).unwrap();
        assert_eq!(r.total, brute::total(&g));
        let s = svc.open_session("main", g.clone(), crate::serve::ServeOpts::default()).unwrap();
        assert_eq!(svc.session_names(), vec!["main".to_string()]);
        assert_eq!(s.snapshot().global, brute::total(&g));
        assert!(svc.session("main").is_some());
        assert!(svc.close_session("main"));
        assert!(!svc.close_session("main"));
        assert!(svc.session("main").is_none());
    }

    #[test]
    fn peel_reports_run() {
        let g = gen::erdos_renyi(12, 13, 70, 3);
        let cfg = PeelConfig {
            vopts: PeelVOpts { side: peel::PeelSide::U, ..Default::default() },
            ..Default::default()
        };
        let (t, _) = tip_report(&g, &cfg).unwrap();
        assert_eq!(t.tips, brute::tip_numbers_u(&g));
        let (w, _) = wing_report(&g, &cfg).unwrap();
        assert_eq!(w.wings, brute::wing_numbers(&g));
    }

    #[test]
    fn intersect_peel_engine_flows_through_the_facade() {
        let g = gen::erdos_renyi(12, 13, 70, 3);
        let cfg = PeelConfig {
            vopts: PeelVOpts {
                engine: peel::PeelEngine::Intersect,
                side: peel::PeelSide::U,
                ..Default::default()
            },
            eopts: PeelEOpts { engine: peel::PeelEngine::Intersect, ..Default::default() },
            ..Default::default()
        };
        let (t, _) = tip_report(&g, &cfg).unwrap();
        assert_eq!(t.tips, brute::tip_numbers_u(&g));
        let (w, _) = wing_report(&g, &cfg).unwrap();
        assert_eq!(w.wings, brute::wing_numbers(&g));
    }
}
