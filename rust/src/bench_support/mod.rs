//! Benchmark substrate: the workload suite (the stand-ins for the
//! paper's KONECT datasets), a small timing harness (criterion is
//! unavailable offline; `cargo bench` drives `harness = false` targets
//! built on [`harness::bench`]), and the target [`registry`] both
//! `cargo bench` and `parbutterfly bench run` dispatch through.
//!
//! Layout:
//!
//! * [`harness`] — timing ([`harness::bench_n`]), row formats
//!   (`BENCHROW` / `BENCHJSON`), the `bench run` row recorder;
//! * [`json`] — minimal JSON value (parse / print), no serde offline;
//! * [`workloads`] — named generated graphs and suites;
//! * [`figures`] — the paper's figure/table workload bodies;
//! * [`snapshots`] — the four workloads recorded as `BENCH_*.json`;
//! * [`registry`] — named targets uniting all of the above; the
//!   snapshot writer with environment/provenance metadata.

pub mod figures;
pub mod harness;
pub mod json;
pub mod registry;
pub mod snapshots;
pub mod workloads;
