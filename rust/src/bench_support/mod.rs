//! Benchmark substrate: the workload suite (the stand-ins for the
//! paper's KONECT datasets) and a small timing harness (criterion is
//! unavailable offline; `cargo bench` drives `harness = false` targets
//! built on [`harness::bench`]).

pub mod figures;
pub mod harness;
pub mod workloads;
