//! Micro-bench harness (offline stand-in for criterion).
//!
//! Benches are `harness = false` binaries; each calls [`bench`] /
//! [`bench_n`] and prints three row formats:
//!
//! * human rows — the same row/series structure as the paper's table
//!   or figure;
//! * legacy machine rows — `BENCHROW <bench> <workload> <config>
//!   <median_ms>` (space-separated; composed config labels make this
//!   format ambiguous, so it is kept only for eyeball-grepping);
//! * structured machine rows — `BENCHJSON {...}` one-object-per-line
//!   JSON carrying the full [`Measurement`] plus any structured row
//!   fields.  This is the format the `bench run` recorder consumes
//!   ([`record`]) and the `BENCH_*.json` snapshots are built from.
//!
//! Timing: `warmup` un-timed runs, then `runs` timed runs; the median
//! is reported (min/max/p90 retained for dispersion).  Under
//! [`set_quick`] (the `bench run --smoke` profile) every call is
//! clamped to 0 warmup + 1 timed run.

use std::cell::Cell;
use std::time::Instant;

use super::json::Json;

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub median_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    /// 90th-percentile sample (nearest-rank); equals `max_ms` for
    /// small run counts — recorded so `bench diff` can report tail
    /// dispersion, not just medians.
    pub p90_ms: f64,
    pub runs: usize,
}

thread_local! {
    /// Quick (smoke) mode: clamp every bench to 0 warmup + 1 run.
    static QUICK: Cell<bool> = const { Cell::new(false) };
}

/// Enable/disable quick mode for this thread (smoke profile).
pub fn set_quick(on: bool) {
    QUICK.with(|q| q.set(on));
}

/// Is quick (smoke) mode active on this thread?
pub fn quick() -> bool {
    QUICK.with(|q| q.get())
}

/// Nearest-rank percentile over ascending `samples` (`q` in 0..=1).
fn percentile(samples: &[f64], q: f64) -> f64 {
    debug_assert!(!samples.is_empty());
    let rank = (samples.len() as f64 * q).ceil() as usize;
    samples[rank.clamp(1, samples.len()) - 1]
}

/// Time `f` with `warmup` + `runs` invocations; returns the stats.
pub fn bench_n<R>(warmup: usize, runs: usize, mut f: impl FnMut() -> R) -> Measurement {
    assert!(runs >= 1);
    let (warmup, runs) = if quick() { (0, 1) } else { (warmup, runs) };
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Even run counts average the two middle samples; `samples[n/2]`
    // alone is the *upper* middle and biases medians high.
    let n = samples.len();
    let median_ms = if n % 2 == 0 {
        (samples[n / 2 - 1] + samples[n / 2]) / 2.0
    } else {
        samples[n / 2]
    };
    Measurement {
        median_ms,
        min_ms: samples[0],
        max_ms: *samples.last().unwrap(),
        p90_ms: percentile(&samples, 0.9),
        runs: n,
    }
}

/// Default: 1 warmup + 3 timed runs (bench workloads are seconds-scale
/// on this substrate; medians stabilize quickly).
pub fn bench<R>(f: impl FnMut() -> R) -> Measurement {
    bench_n(1, 3, f)
}

/// The `bench run` recorder: an optional per-thread sink that
/// [`report_keyed`] / [`report_value`] push each structured
/// (`BENCHJSON`) row into.  The registry wraps every snapshot target
/// in [`record::start`] / [`record::finish`] and builds the
/// `BENCH_*.json` rows from exactly what was printed.
pub mod record {
    use super::Json;
    use std::cell::RefCell;

    thread_local! {
        static SINK: RefCell<Option<Vec<Json>>> = const { RefCell::new(None) };
    }

    /// Begin recording rows on this thread (replaces any prior sink).
    pub fn start() {
        SINK.with(|s| *s.borrow_mut() = Some(Vec::new()));
    }

    /// Stop recording and return everything captured since [`start`].
    pub fn finish() -> Vec<Json> {
        SINK.with(|s| s.borrow_mut().take()).unwrap_or_default()
    }

    pub(super) fn push(row: Json) {
        SINK.with(|s| {
            if let Some(rows) = s.borrow_mut().as_mut() {
                rows.push(row);
            }
        });
    }
}

/// Emit one measured row in all machine formats (legacy `BENCHROW`,
/// structured `BENCHJSON`, recorder).
///
/// `display` is the composed human/`BENCHROW` label (e.g.
/// `"total/BatchS"`); `fields` are the structured identity fields the
/// snapshot row keeps *separately* (e.g. `stat: "total"`,
/// `config: "BatchS"`), so composed labels never need re-parsing and
/// spaces in labels cannot corrupt the machine format.
pub fn report_keyed(
    bench_name: &str,
    workload: &str,
    display: &str,
    m: &Measurement,
    fields: &[(&str, Json)],
) {
    println!(
        "  {display:<24} median {:>10.2} ms   (min {:.2}, max {:.2}, p90 {:.2}, n={})",
        m.median_ms, m.min_ms, m.max_ms, m.p90_ms, m.runs
    );
    println!("BENCHROW {bench_name} {workload} {display} {:.3}", m.median_ms);
    let mut row: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str(bench_name)),
        ("workload".into(), Json::str(workload)),
    ];
    for (k, v) in fields {
        row.push(((*k).to_string(), v.clone()));
    }
    row.push(("median_ms".into(), Json::ms(m.median_ms)));
    row.push(("min_ms".into(), Json::ms(m.min_ms)));
    row.push(("max_ms".into(), Json::ms(m.max_ms)));
    row.push(("p90_ms".into(), Json::ms(m.p90_ms)));
    row.push(("runs".into(), Json::Num(m.runs as f64)));
    let row = Json::Obj(row);
    println!("BENCHJSON {}", row.compact());
    record::push(row);
}

/// Emit an *unmeasured* recorded value (an `f`-metric, a wedge count,
/// a dataset statistic) in the machine formats.
pub fn report_value(bench_name: &str, workload: &str, config: &str, value: Json) {
    println!("BENCHROW {bench_name} {workload} {config} {}", value.compact());
    let row = Json::Obj(vec![
        ("bench".into(), Json::str(bench_name)),
        ("workload".into(), Json::str(workload)),
        ("config".into(), Json::str(config)),
        ("value".into(), value),
    ]);
    println!("BENCHJSON {}", row.compact());
    record::push(row);
}

/// Print both row formats for a simple `config`-keyed measurement.
pub fn report(bench_name: &str, workload: &str, config: &str, m: &Measurement) {
    report_keyed(bench_name, workload, config, m, &[("config", Json::str(config))]);
}

/// Print a figure-style normalized bar: `value / best` per config.
pub fn report_normalized(bench_name: &str, workload: &str, rows: &[(String, Measurement)]) {
    let best = rows
        .iter()
        .map(|(_, m)| m.median_ms)
        .fold(f64::INFINITY, f64::min);
    println!("  [{workload}] fastest = {best:.2} ms; normalized:");
    for (config, m) in rows {
        let bar_len = ((m.median_ms / best).min(20.0) * 3.0) as usize;
        println!(
            "  {config:<24} {:>6.2}x {}",
            m.median_ms / best,
            "#".repeat(bar_len.max(1))
        );
        report_keyed(bench_name, workload, config, m, &[("config", Json::str(config))]);
    }
}

/// Section banner for a bench binary.
pub fn banner(title: &str, detail: &str) {
    println!("\n=== {title} ===");
    if !detail.is_empty() {
        println!("{detail}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let m = bench_n(0, 5, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(m.min_ms <= m.median_ms && m.median_ms <= m.max_ms);
        assert!(m.median_ms <= m.p90_ms && m.p90_ms <= m.max_ms);
        assert_eq!(m.runs, 5);
    }

    #[test]
    fn median_of_even_runs_averages_the_middle_pair() {
        // Feed deterministic "samples" by sorting a known multiset:
        // easier to pin the arithmetic directly on the helper path.
        let samples = [1.0, 2.0, 4.0, 8.0];
        let n = samples.len();
        let median = (samples[n / 2 - 1] + samples[n / 2]) / 2.0;
        assert_eq!(median, 3.0);
        // And through the public API: with identical work per run the
        // measured median must sit between min and max even for even
        // run counts (the old upper-middle bug made median == a raw
        // sample; the averaged version must satisfy the same bounds).
        let m = bench_n(0, 4, || std::hint::black_box(3u64.pow(7)));
        assert_eq!(m.runs, 4);
        assert!(m.min_ms <= m.median_ms && m.median_ms <= m.max_ms);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&s, 0.9), 9.0);
        assert_eq!(percentile(&s[..1], 0.9), 1.0);
        assert_eq!(percentile(&s[..4], 0.9), 4.0);
    }

    #[test]
    fn quick_mode_clamps_runs() {
        set_quick(true);
        let m = bench_n(3, 9, || ());
        set_quick(false);
        assert_eq!(m.runs, 1);
    }

    #[test]
    fn recorder_captures_structured_rows() {
        record::start();
        let m = bench_n(0, 1, || ());
        report_keyed(
            "t2",
            "er",
            "total/PB par",
            &m,
            &[("stat", Json::str("total")), ("config", Json::str("PB par"))],
        );
        report_value("t2", "er", "stats", Json::Num(42.0));
        let rows = record::finish();
        assert_eq!(rows.len(), 2);
        // Config names with spaces survive structurally.
        assert_eq!(rows[0].get("config").unwrap().as_str().unwrap(), "PB par");
        assert_eq!(rows[0].get("stat").unwrap().as_str().unwrap(), "total");
        assert!(rows[0].get("median_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert!(rows[0].get("p90_ms").is_some());
        assert_eq!(rows[1].get("value").unwrap().as_f64().unwrap(), 42.0);
        // A second finish without start is empty, not stale.
        assert!(record::finish().is_empty());
    }

    #[test]
    fn benchjson_lines_round_trip_through_the_parser() {
        record::start();
        let m = bench_n(0, 2, || ());
        report("fig5 test", "cl", "label with spaces", &m);
        let rows = record::finish();
        assert_eq!(rows.len(), 1);
        let reparsed = Json::parse(&rows[0].compact()).unwrap();
        assert_eq!(&reparsed, &rows[0]);
    }
}
