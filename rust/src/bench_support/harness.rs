//! Micro-bench harness (offline stand-in for criterion).
//!
//! Benches are `harness = false` binaries; each calls [`bench`] /
//! [`bench_n`] and prints two row formats:
//!
//! * human rows — the same row/series structure as the paper's table
//!   or figure;
//! * machine rows — `BENCHROW <bench> <workload> <config> <median_ms>`
//!   lines the `BENCH_*.json` snapshots record.
//!
//! Timing: `warmup` un-timed runs, then `runs` timed runs; the median
//! is reported (min/max retained for dispersion).

use std::time::Instant;

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub median_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub runs: usize,
}

/// Time `f` with `warmup` + `runs` invocations; returns the stats.
pub fn bench_n<R>(warmup: usize, runs: usize, mut f: impl FnMut() -> R) -> Measurement {
    assert!(runs >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measurement {
        median_ms: samples[samples.len() / 2],
        min_ms: samples[0],
        max_ms: *samples.last().unwrap(),
        runs,
    }
}

/// Default: 1 warmup + 3 timed runs (bench workloads are seconds-scale
/// on this substrate; medians stabilize quickly).
pub fn bench<R>(f: impl FnMut() -> R) -> Measurement {
    bench_n(1, 3, f)
}

/// Print both row formats.
pub fn report(bench_name: &str, workload: &str, config: &str, m: &Measurement) {
    println!(
        "  {config:<24} median {:>10.2} ms   (min {:.2}, max {:.2}, n={})",
        m.median_ms, m.min_ms, m.max_ms, m.runs
    );
    println!("BENCHROW {bench_name} {workload} {config} {:.3}", m.median_ms);
}

/// Print a figure-style normalized bar: `value / best` per config.
pub fn report_normalized(bench_name: &str, workload: &str, rows: &[(String, Measurement)]) {
    let best = rows
        .iter()
        .map(|(_, m)| m.median_ms)
        .fold(f64::INFINITY, f64::min);
    println!("  [{workload}] fastest = {best:.2} ms; normalized:");
    for (config, m) in rows {
        let bar_len = ((m.median_ms / best).min(20.0) * 3.0) as usize;
        println!(
            "  {config:<24} {:>6.2}x {}",
            m.median_ms / best,
            "#".repeat(bar_len.max(1))
        );
        println!("BENCHROW {bench_name} {workload} {config} {:.3}", m.median_ms);
    }
}

/// Section banner for a bench binary.
pub fn banner(title: &str, detail: &str) {
    println!("\n=== {title} ===");
    if !detail.is_empty() {
        println!("{detail}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let m = bench_n(0, 5, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(m.min_ms <= m.median_ms && m.median_ms <= m.max_ms);
        assert_eq!(m.runs, 5);
    }
}
