//! Minimal JSON value type: parser + serializer (no external crates
//! offline — this is the in-tree stand-in for `serde_json`).
//!
//! Used by the benchmark machinery end to end: [`super::harness`]
//! serializes each measurement as a `BENCHJSON` line, the
//! [`super::registry`] snapshot writer emits the `BENCH_*.json` files,
//! and `bench diff` / `bench diff --check-schema` parse them back.
//! Round-tripping through one implementation keeps the three in
//! lockstep.
//!
//! Numbers are stored as `f64` (every value the harness records fits
//! exactly); integers within `2^53` serialize without a decimal point,
//! so row fields like `"threads": 4` keep their integer spelling.

use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (duplicate keys are not merged).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: an owned string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// A millisecond measurement rounded to 3 decimals (the precision
    /// every `BENCHROW` line and snapshot row has always used).
    pub fn ms(v: f64) -> Json {
        Json::Num((v * 1e3).round() / 1e3)
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(fields) => Some(fields),
            _ => None,
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at {}", p.locate(p.pos));
        }
        Ok(v)
    }

    /// Compact single-line serialization (the `BENCHJSON` format).
    pub fn compact(&self) -> String {
        format!("{self}")
    }

    /// Pretty serialization, 2-space indent (the `BENCH_*.json`
    /// format), trailing newline included.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    out.push_str(if i == 0 { "\n" } else { ",\n" });
                    push_indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                push_indent(out, depth);
                out.push(']');
            }
            Json::Obj(fields) if !fields.is_empty() => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    out.push_str(if i == 0 { "\n" } else { ",\n" });
                    push_indent(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                push_indent(out, depth);
                out.push('}');
            }
            other => out.push_str(&other.compact()),
        }
    }
}

fn push_indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/inf; the harness never records them, but a
        // null is better than invalid output if one sneaks through.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // f64 Display prints the shortest round-tripping decimal.
        out.push_str(&format!("{n}"));
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(&mut s, *n),
            Json::Str(v) => write_escaped(&mut s, v),
            Json::Arr(items) => {
                s.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        s.push_str(", ");
                    }
                    s.push_str(&v.compact());
                }
                s.push(']');
            }
            Json::Obj(fields) => {
                s.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        s.push_str(", ");
                    }
                    write_escaped(&mut s, k);
                    s.push_str(": ");
                    s.push_str(&v.compact());
                }
                s.push('}');
            }
        }
        f.write_str(&s)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    /// Human position of `pos` (1-based line / column) for parse
    /// errors on hand-edited or truncated snapshot files — "byte 913"
    /// alone is useless in a 200-line pretty-printed file.
    fn locate(&self, pos: usize) -> String {
        let upto = pos.min(self.bytes.len());
        let line = 1 + self.bytes[..upto].iter().filter(|&&b| b == b'\n').count();
        let col = 1 + upto - self.bytes[..upto].iter().rposition(|&b| b == b'\n').map_or(0, |i| i + 1);
        format!("line {line} col {col} (byte {pos})")
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected {:?} at {} (found {:?})",
                b as char,
                self.locate(self.pos),
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at {}", self.locate(self.pos))
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            other => {
                anyhow::bail!("unexpected {:?} at {}", other.map(|c| c as char), self.locate(self.pos))
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            fields.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => anyhow::bail!("expected ',' or '}}' at {}", self.locate(self.pos)),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at {}", self.locate(self.pos)),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string at {}", self.locate(self.pos)),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let code = if (0xd800..0xdc00).contains(&hi)
                                && self.bytes[self.pos..].starts_with(b"\\u")
                            {
                                self.pos += 2;
                                let lo = self.hex4()?;
                                0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                            } else {
                                hi
                            };
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            continue; // hex4 already advanced
                        }
                        other => anyhow::bail!(
                            "invalid escape {:?} at {}",
                            other.map(|c| c as char),
                            self.locate(self.pos)
                        ),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so
                    // byte boundaries are valid).
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|e| anyhow::anyhow!("{e}"))?;
                    let c = match text.chars().next() {
                        Some(c) => c,
                        // peek() returned a byte, so the tail is nonempty
                        None => unreachable!("nonempty remainder has a first char"),
                    };
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> anyhow::Result<u32> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            anyhow::bail!("truncated \\u escape at {}", self.locate(self.pos));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..end])?;
        let v = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape {hex:?} at {}", self.locate(self.pos)))?;
        self.pos = end;
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            match b {
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9' => self.pos += 1,
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number {text:?} at {}", self.locate(start)))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e1").unwrap(), Json::Num(-125.0));
        assert_eq!(Json::parse("\"a b\"").unwrap(), Json::Str("a b".into()));
        let v = Json::parse("{\"rows\": [1, 2], \"ok\": false}").unwrap();
        assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("ok").unwrap(), &Json::Bool(false));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn parse_errors_locate_line_and_column() {
        // Error on line 3 of a pretty-printed document.
        let e = Json::parse("{\n  \"rows\": [1,\n  }").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("line 3"), "{msg}");
        // Truncation mid-array reports where the text ran out.
        let e = Json::parse("{\"rows\": [1, 2").unwrap_err();
        assert!(format!("{e:#}").contains("line 1 col 15"), "{e:#}");
        // Truncation mid-string.
        let e = Json::parse("{\"work").unwrap_err();
        assert!(format!("{e:#}").contains("unterminated string"), "{e:#}");
    }

    #[test]
    fn escapes_round_trip() {
        let original = Json::Obj(vec![
            ("config with spaces".into(), Json::str("tab\there \"quoted\" \\slash\n")),
            ("unicode".into(), Json::str("π ≈ 3")),
        ]);
        let compact = original.compact();
        assert_eq!(Json::parse(&compact).unwrap(), original);
        let pretty = original.pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), original);
    }

    #[test]
    fn unicode_escapes_parse() {
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        // Surrogate pair.
        assert_eq!(Json::parse("\"\\ud83d\\ude00\"").unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn integers_stay_integers() {
        assert_eq!(Json::Num(4.0).compact(), "4");
        assert_eq!(Json::Num(-0.5).compact(), "-0.5");
        assert_eq!(Json::ms(187.06149).compact(), "187.061");
        let doc = Json::parse("{\"threads\": 4}").unwrap();
        assert_eq!(doc.compact(), "{\"threads\": 4}");
    }

    #[test]
    fn existing_snapshot_files_parse() {
        for f in [
            "BENCH_intersect.json",
            "BENCH_peel.json",
            "BENCH_preprocess.json",
            "BENCH_dynamic.json",
            "BENCH_serve.json",
        ] {
            let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(f);
            let text = std::fs::read_to_string(&path).unwrap();
            let doc = Json::parse(&text).unwrap_or_else(|e| panic!("{f}: {e}"));
            assert!(doc.get("rows").unwrap().as_arr().unwrap().len() > 0, "{f}");
        }
    }
}
