//! The snapshot benches — the workloads whose results are recorded
//! in-repo as `BENCH_*.json` files at the workspace root.
//!
//! Each function here is the *single* definition of its workload:
//! the `harness = false` bench binary (`cargo bench --bench <name>`)
//! and the CLI runner (`parbutterfly bench run`) both dispatch through
//! [`super::registry`], which wraps these in the row recorder
//! ([`super::harness::record`]) and writes the snapshot file — so the
//! two entry points execute identical code and produce identical
//! rows.
//!
//! Rows are emitted through [`report_keyed`]: the structured fields
//! (`stat` / `mode` / `stage` / `batch` / `threads` / `path` /
//! `config`) are recorded separately from the composed human label, so
//! the snapshot schema never depends on re-parsing `BENCHROW` labels.

use super::figures::{agg_rows, peel_rows};
use super::harness::{banner, bench, bench_n, report_keyed, Measurement};
use super::json::Json;
use super::registry::{Profile, SnapshotMeta};
use super::workloads::{self, PEELING_SUITE};
use crate::count::{count_per_edge, count_per_vertex, count_total, CountOpts, Engine};
use crate::dynamic::{BatchKind, DynGraph, DynOpts};
use crate::graph::{io, BipartiteGraph, Layout, RankedGraph};
use crate::peel::{peel_edges, peel_vertices, BucketKind, PeelEOpts, PeelSide, PeelVOpts};
use crate::prims::pool::{num_threads, with_threads};
use crate::rank::{choose_ranking, rank_vertices, Ranking};
use crate::serve::{handle_request, ServeOpts, Session};

/// Round to 3 decimals (dimensionless ratios; [`Json::ms`] covers ms).
fn round3(v: f64) -> Json {
    Json::Num((v * 1e3).round() / 1e3)
}

fn run_stat(g: &BipartiteGraph, stat: &str, opts: &CountOpts) -> u64 {
    match stat {
        "total" => count_total(g, opts).unwrap(),
        "vertex" => count_per_vertex(g, opts).unwrap().bu.iter().sum::<u64>() / 2,
        _ => count_per_edge(g, opts).unwrap().iter().sum::<u64>() / 4,
    }
}

/// Streaming intersect engine vs the materializing aggregations
/// (`BENCH_intersect.json`).
pub fn intersect_vs_agg(profile: Profile) -> SnapshotMeta {
    // `small` is in the Full suite so the committed snapshot carries
    // every row identity the Smoke profile emits — `bench diff`
    // compares smoke runs against it in CI.
    let suite: &[&str] = match profile {
        Profile::Full => &["small", "er", "cl", "dense"],
        Profile::Smoke => &["small"],
    };
    banner(
        "intersect",
        "streaming intersect vs materializing aggregations; snapshot: BENCH_intersect.json",
    );
    let mut summary = Vec::new();
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let g = &wl.graph;
        let ranking = choose_ranking(g);
        println!("[{}] {} — ranking {}", wl.id, wl.describe, ranking.name());
        for stat in ["total", "vertex", "edge"] {
            let mut expected = None;
            let mut best_mat: Option<(&'static str, f64)> = None;
            let mut intersect_ms = f64::NAN;
            let mut hub_ms = f64::NAN;
            for (label, base) in agg_rows() {
                let opts = CountOpts { ranking, ..base };
                let mut result = 0u64;
                let m = bench(|| {
                    result = run_stat(g, stat, &opts);
                    result
                });
                match expected {
                    None => expected = Some(result),
                    Some(e) => assert_eq!(e, result, "{label} disagrees on {wl_id}/{stat}"),
                }
                report_keyed(
                    "intersect",
                    wl.id,
                    &format!("{stat}/{label}"),
                    &m,
                    &[("stat", Json::str(stat)), ("config", Json::str(label))],
                );
                if label == "Intersect" {
                    intersect_ms = m.median_ms;
                } else if label == "Intersect-hub" {
                    // The hub layout is a variant of the intersect
                    // engine, not a materializing competitor.
                    hub_ms = m.median_ms;
                } else if best_mat.map(|(_, ms)| m.median_ms < ms).unwrap_or(true) {
                    best_mat = Some((label, m.median_ms));
                }
            }
            let (best_label, best_ms) = best_mat.unwrap();
            let speedup = best_ms / intersect_ms;
            println!(
                "  [{}/{stat}] intersect {intersect_ms:.2} ms (hub {hub_ms:.2} ms) vs best \
                 materializing {best_label} {best_ms:.2} ms ({speedup:.2}x)",
                wl.id
            );
            summary.push(Json::Obj(vec![
                ("workload".into(), Json::str(wl.id)),
                ("stat".into(), Json::str(stat)),
                ("best_materializing".into(), Json::str(best_label)),
                ("best_materializing_ms".into(), Json::ms(best_ms)),
                ("intersect_ms".into(), Json::ms(intersect_ms)),
                ("intersect_hub_ms".into(), Json::ms(hub_ms)),
                ("speedup".into(), round3(speedup)),
                ("butterflies".into(), Json::Num(expected.unwrap() as f64)),
            ]));
        }
    }
    SnapshotMeta {
        note: "per-source counting across the materializing aggregations (BatchS family et \
               al.) vs the streaming intersect engine, same ranked two-hop walk; regenerate \
               with `parbutterfly bench run --filter intersect` or `cargo bench --bench \
               intersect_vs_agg`"
            .into(),
        top: vec![("threads".into(), Json::Num(num_threads() as f64))],
        summary: Some(Json::Arr(summary)),
    }
}

/// Flat vs hub memory layout for the intersect engine's wedge walks
/// (`BENCH_layout.json`) — the PR 7 locality fast path: hub-first
/// renumbering, dense hub bitmaps (word-wise AND/popcount second
/// hops), and L2-tiled fill/drain walks.
pub fn layout_sweep(profile: Profile) -> SnapshotMeta {
    let suite: &[&str] = match profile {
        Profile::Full => &["small", "er", "cl", "dense"],
        Profile::Smoke => &["small"],
    };
    banner(
        "layout",
        "flat vs hub memory layout for the intersect engine; snapshot: BENCH_layout.json",
    );
    let mut summary = Vec::new();
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let g = &wl.graph;
        let ranking = choose_ranking(g);
        println!("[{}] {} — ranking {}", wl.id, wl.describe, ranking.name());
        for stat in ["total", "vertex", "edge"] {
            let mut expected = None;
            let mut flat_ms = f64::NAN;
            let mut hub_ms = f64::NAN;
            for (label, layout) in [("flat", Layout::Flat), ("hub", Layout::Hub)] {
                let opts = CountOpts {
                    ranking,
                    engine: Engine::Intersect,
                    layout,
                    ..Default::default()
                };
                let mut result = 0u64;
                let m = bench(|| {
                    result = run_stat(g, stat, &opts);
                    result
                });
                // Layouts must be bit-identical, not just fast.
                match expected {
                    None => expected = Some(result),
                    Some(e) => assert_eq!(e, result, "{label} disagrees on {wl_id}/{stat}"),
                }
                report_keyed(
                    "layout",
                    wl.id,
                    &format!("{stat}/{label}"),
                    &m,
                    &[("stat", Json::str(stat)), ("config", Json::str(label))],
                );
                if label == "flat" {
                    flat_ms = m.median_ms;
                } else {
                    hub_ms = m.median_ms;
                }
            }
            let speedup = flat_ms / hub_ms;
            println!(
                "  [{}/{stat}] flat {flat_ms:.2} ms vs hub {hub_ms:.2} ms ({speedup:.2}x)",
                wl.id
            );
            summary.push(Json::Obj(vec![
                ("workload".into(), Json::str(wl.id)),
                ("stat".into(), Json::str(stat)),
                ("flat_ms".into(), Json::ms(flat_ms)),
                ("hub_ms".into(), Json::ms(hub_ms)),
                ("speedup".into(), round3(speedup)),
                ("butterflies".into(), Json::Num(expected.unwrap() as f64)),
            ]));
        }
    }
    SnapshotMeta {
        note: "intersect-engine counting under the flat rank-ordered layout vs the hub \
               layout (hub-first renumbering + bitmap AND/popcount second hops + L2-tiled \
               walks), outputs asserted bit-identical; regenerate with `parbutterfly bench \
               run --filter layout` or `cargo bench --bench layout_sweep`"
            .into(),
        top: vec![("threads".into(), Json::Num(num_threads() as f64))],
        summary: Some(Json::Arr(summary)),
    }
}

/// Aggregation UPDATE paths vs the streaming live-view intersect peel
/// engine (`BENCH_peel.json`).
pub fn peel_intersect_vs_agg(profile: Profile) -> SnapshotMeta {
    // The smoke workload is a member of the full suite so that the CI
    // bench gate (`bench run --smoke --filter peel` + `bench diff`
    // against the committed BENCH_peel.json) compares identical row
    // identities instead of diffing two disjoint workload sets.
    let suite: &[&str] = match profile {
        Profile::Full => &PEELING_SUITE,
        Profile::Smoke => &["small"],
    };
    banner(
        "peel",
        "aggregation UPDATE paths vs streaming intersect peeling; snapshot: BENCH_peel.json",
    );
    let mut summary = Vec::new();
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let g = &wl.graph;
        let vc = count_per_vertex(g, &CountOpts::default()).unwrap();
        let be = count_per_edge(g, &CountOpts::default()).unwrap();
        println!("[{}] {}", wl.id, wl.describe);
        for mode in ["tip", "wing"] {
            let mut expected: Option<Vec<u64>> = None;
            let mut rounds = 0usize;
            let mut sync_rounds = 0usize;
            let mut best_agg: Option<(&'static str, f64)> = None;
            let mut intersect_ms = f64::NAN;
            let mut two_phase_ms = f64::NAN;
            for (label, engine, agg) in peel_rows() {
                let mut result = Vec::new();
                let m = bench_n(0, 2, || {
                    if mode == "tip" {
                        let vopts = PeelVOpts {
                            engine,
                            agg,
                            buckets: BucketKind::Julienne,
                            side: PeelSide::Auto,
                            ..Default::default()
                        };
                        let r = peel_vertices(g, &vc.bu, &vc.bv, &vopts).unwrap();
                        rounds = r.rounds;
                        result = r.tips;
                    } else {
                        let eopts = PeelEOpts {
                            engine,
                            agg,
                            buckets: BucketKind::Julienne,
                            ..Default::default()
                        };
                        let r = peel_edges(g, &be, &eopts).unwrap();
                        rounds = r.rounds;
                        result = r.wings;
                    }
                });
                if let Some(e) = &expected {
                    assert_eq!(e, &result, "{label} disagrees on {wl_id}/{mode}");
                } else {
                    expected = Some(std::mem::take(&mut result));
                }
                report_keyed(
                    "peel",
                    wl.id,
                    &format!("{mode}/{label}"),
                    &m,
                    &[
                        ("mode", Json::str(mode)),
                        ("config", Json::str(label)),
                        ("rounds", Json::Num(rounds as f64)),
                    ],
                );
                if label == "intersect" {
                    intersect_ms = m.median_ms;
                    // The round-synchronous engines (agg + intersect)
                    // share one round count; two-phase reports its own
                    // coarse + max-fine depth, so the summary pins the
                    // synchronous one.
                    sync_rounds = rounds;
                } else if label == "two-phase" {
                    two_phase_ms = m.median_ms;
                } else if best_agg.map(|(_, ms)| m.median_ms < ms).unwrap_or(true) {
                    best_agg = Some((label, m.median_ms));
                }
            }
            let (best_label, best_ms) = best_agg.unwrap();
            let speedup = best_ms / intersect_ms;
            println!(
                "  [{}/{mode}] intersect {intersect_ms:.2} ms / two-phase {two_phase_ms:.2} ms \
                 vs best aggregation {best_label} {best_ms:.2} ms ({speedup:.2}x, {sync_rounds} \
                 rounds)",
                wl.id
            );
            summary.push(Json::Obj(vec![
                ("workload".into(), Json::str(wl.id)),
                ("mode".into(), Json::str(mode)),
                ("best_agg".into(), Json::str(best_label)),
                ("best_agg_ms".into(), Json::ms(best_ms)),
                ("intersect_ms".into(), Json::ms(intersect_ms)),
                ("two_phase_ms".into(), Json::ms(two_phase_ms)),
                ("speedup".into(), round3(speedup)),
                ("rounds".into(), Json::Num(sync_rounds as f64)),
            ]));
        }
    }
    SnapshotMeta {
        note: "aggregation UPDATE paths (full-adjacency rescans + per-pair aggregation) vs \
               the streaming live-view intersect peel engine and the two-phase coarse/fine \
               range-parallel engine, identical Julienne buckets; regenerate with \
               `parbutterfly bench run --filter peel` or `cargo bench \
               --bench peel_intersect_vs_agg`"
            .into(),
        top: vec![("threads".into(), Json::Num(num_threads() as f64))],
        summary: Some(Json::Arr(summary)),
    }
}

/// Parse / CSR / rank / PREPROCESS stage timings over a thread sweep
/// (`BENCH_preprocess.json`).
pub fn preprocess_pipeline(profile: Profile) -> SnapshotMeta {
    let (suite, threads): (&[&str], &[usize]) = match profile {
        Profile::Full => (&["er", "cl", "clL"], &[1, 4, 8]),
        Profile::Smoke => (&["small"], &[1, 2]),
    };
    banner(
        "preprocess",
        "parse / CSR / rank / PREPROCESS stage timings over the thread sweep; snapshot: \
         BENCH_preprocess.json",
    );
    let dir = std::env::temp_dir().join("pb_preprocess_bench");
    std::fs::create_dir_all(&dir).expect("create temp dir");
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let g = &wl.graph;
        let path = dir.join(format!("{wl_id}.txt"));
        io::save_edge_list(g, &path).expect("write workload edge list");
        println!("[{}] {} — m={}", wl.id, wl.describe, g.m());
        // Parity anchor: both parse paths must agree before timing.
        let parsed = io::parse_edge_list_serial(&path).expect("serial parse");
        assert_eq!(parsed, io::parse_edge_list_parallel(&path).expect("parallel parse"));
        let (nu, nv, edges) = parsed;
        for &t in threads {
            with_threads(t, || {
                let stage = |name: &str, m: &Measurement| {
                    report_keyed(
                        "preprocess",
                        wl.id,
                        &format!("t{t}/{name}"),
                        m,
                        &[("stage", Json::str(name)), ("threads", Json::Num(t as f64))],
                    );
                };
                let m = bench(|| io::parse_edge_list_serial(&path).unwrap());
                stage("parse-serial", &m);
                let m = bench(|| io::parse_edge_list_parallel(&path).unwrap());
                stage("parse-parallel", &m);
                let m = bench(|| BipartiteGraph::from_edges(nu, nv, &edges));
                stage("csr-build", &m);
                for ranking in Ranking::ALL {
                    let m = bench(|| rank_vertices(g, ranking));
                    stage(&format!("rank-{}", ranking.name()), &m);
                }
                let rank = rank_vertices(g, Ranking::Degree);
                let m = bench(|| RankedGraph::new(g, rank.clone()));
                stage("preprocess-build", &m);
            });
        }
    }
    SnapshotMeta {
        note: "stages: parse-serial / parse-parallel (chunked loader), csr-build \
               (BipartiteGraph::from_edges), rank-* (rank_vertices per ordering), \
               preprocess-build (RankedGraph::new, Algorithm 1); regenerate with \
               `parbutterfly bench run --filter preprocess` or `cargo bench --bench \
               preprocess_pipeline`"
            .into(),
        top: vec![(
            "threads_swept".into(),
            Json::Arr(threads.iter().map(|&t| Json::Num(t as f64)).collect()),
        )],
        summary: None,
    }
}

/// Fraction of each workload's edges replayed as the update stream.
const UPDATE_FRACTION: f64 = 0.10;

fn replay(
    base: &BipartiteGraph,
    updates: &[(u32, u32)],
    batch: usize,
    rebuild_fraction: f64,
) -> u64 {
    let mut dg =
        DynGraph::new(base.clone(), DynOpts { rebuild_fraction, ..Default::default() }).unwrap();
    for chunk in updates.chunks(batch) {
        dg.insert_edges(chunk).unwrap();
    }
    let total_at_peak = dg.total();
    for chunk in updates.chunks(batch) {
        dg.delete_edges(chunk).unwrap();
    }
    assert_eq!(dg.graph().m(), base.m(), "stream returns to the base graph");
    total_at_peak
}

/// Batch-dynamic maintenance vs full recount over batch size × thread
/// count (`BENCH_dynamic.json`).
pub fn fig_dynamic(profile: Profile) -> SnapshotMeta {
    let (suite, batch_sizes, threads): (&[&str], &[usize], &[usize]) = match profile {
        Profile::Full => (&["er", "cl", "dense"], &[64, 1_024, 16_384], &[1, 4, 8]),
        Profile::Smoke => (&["small"], &[64], &[1, 2]),
    };
    banner(
        "dynamic",
        "incremental batch maintenance vs recount-per-batch; snapshot: BENCH_dynamic.json",
    );
    let mut summary = Vec::new();
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let edges = wl.graph.edges();
        let split = edges.len() - (edges.len() as f64 * UPDATE_FRACTION) as usize;
        let base = BipartiteGraph::from_edges(wl.graph.nu(), wl.graph.nv(), &edges[..split]);
        let updates = &edges[split..];
        println!("[{}] {} — {} update edges over {split} base", wl.id, wl.describe, updates.len());
        for &batch in batch_sizes {
            if batch > updates.len() {
                continue;
            }
            for &t in threads {
                let mut expect = None;
                let mut delta_ms = f64::NAN;
                let mut recount_ms = f64::NAN;
                for (label, fraction) in [("delta", f64::INFINITY), ("recount", 0.0)] {
                    let mut peak = 0u64;
                    let m = with_threads(t, || {
                        bench_n(1, 3, || {
                            peak = replay(&base, updates, batch, fraction);
                            peak
                        })
                    });
                    match expect {
                        None => expect = Some(peak),
                        Some(e) => assert_eq!(e, peak, "{label} diverges on {wl_id}"),
                    }
                    report_keyed(
                        "dynamic",
                        wl.id,
                        &format!("b{batch}/t{t}/{label}"),
                        &m,
                        &[
                            ("batch", Json::Num(batch as f64)),
                            ("threads", Json::Num(t as f64)),
                            ("path", Json::str(label)),
                        ],
                    );
                    if label == "delta" {
                        delta_ms = m.median_ms;
                    } else {
                        recount_ms = m.median_ms;
                    }
                }
                let speedup = recount_ms / delta_ms;
                println!(
                    "  [b{batch}/t{t}] delta {delta_ms:.2} ms vs recount-per-batch \
                     {recount_ms:.2} ms ({speedup:.2}x)"
                );
                summary.push(Json::Obj(vec![
                    ("workload".into(), Json::str(wl.id)),
                    ("batch".into(), Json::Num(batch as f64)),
                    ("threads".into(), Json::Num(t as f64)),
                    ("delta_ms".into(), Json::ms(delta_ms)),
                    ("recount_ms".into(), Json::ms(recount_ms)),
                    ("speedup".into(), round3(speedup)),
                    ("butterflies_at_peak".into(), Json::Num(expect.unwrap() as f64)),
                ]));
            }
        }
    }
    SnapshotMeta {
        note: "replay of an insert-then-delete update stream (10% of edges): incremental \
               delta path (rebuild_fraction = inf) vs recount-every-batch baseline \
               (rebuild_fraction = 0); regenerate with `parbutterfly bench run --filter \
               dynamic` or `cargo bench --bench fig_dynamic`"
            .into(),
        top: vec![],
        summary: Some(Json::Arr(summary)),
    }
}

/// Serve-mode latency: protocol read queries answered from the epoch
/// snapshot, plus the synchronous update round trip (admit → apply →
/// publish) (`BENCH_serve.json`).
pub fn serve_latency(profile: Profile) -> SnapshotMeta {
    let suite: &[&str] = match profile {
        Profile::Full => &["small", "er", "cl"],
        Profile::Smoke => &["small"],
    };
    // Snapshot loads are sub-microsecond; batch the reads so each timed
    // sample registers above timer noise.
    const READS_PER_SAMPLE: usize = 100;
    banner(
        "serve",
        "resident-daemon query latency and update-epoch round trip; snapshot: BENCH_serve.json",
    );
    let mut summary = Vec::new();
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let (u0, v0) = wl.graph.edges()[0];
        let session = Session::open(
            wl.graph.clone(),
            // Counting-focused deployment: snapshots carry the count
            // arrays but skip per-epoch decompositions.
            ServeOpts { decompositions: false, ..ServeOpts::default() },
        )
        .expect("open serve session");
        println!("[{}] {}", wl.id, wl.describe);
        let mut read_total_ms = f64::NAN;
        for (label, req) in [
            ("read/total", r#"{"op": "total"}"#.to_string()),
            ("read/vertex", format!(r#"{{"op": "vertex", "side": "u", "id": {u0}}}"#)),
            ("read/topk", r#"{"op": "topk", "side": "v", "k": 10}"#.to_string()),
            ("read/digest", r#"{"op": "digest"}"#.to_string()),
        ] {
            let m = bench(|| {
                let mut bytes = 0usize;
                for _ in 0..READS_PER_SAMPLE {
                    bytes += handle_request(&session, &req).text.len();
                }
                bytes
            });
            report_keyed(
                "serve",
                wl.id,
                label,
                &m,
                &[
                    ("query", Json::str(label)),
                    ("per_sample", Json::Num(READS_PER_SAMPLE as f64)),
                ],
            );
            if label == "read/total" {
                read_total_ms = m.median_ms;
            }
        }
        // Update round trip: delete + re-insert one edge — two admitted
        // batches, two published epochs, and the graph ends each sample
        // exactly where it started.
        let m = bench(|| {
            let d = session.update(BatchKind::Delete, vec![(u0, v0)]);
            let i = session.update(BatchKind::Insert, vec![(u0, v0)]);
            assert!(d.error.is_none() && i.error.is_none(), "bench update failed");
            i.epoch
        });
        report_keyed("serve", wl.id, "update/roundtrip", &m, &[(
            "query",
            Json::str("update/roundtrip"),
        )]);
        summary.push(Json::Obj(vec![
            ("workload".into(), Json::str(wl.id)),
            ("read_total_ms".into(), Json::ms(read_total_ms)),
            ("update_roundtrip_ms".into(), Json::ms(m.median_ms)),
            ("epochs_published".into(), Json::Num(session.snapshot().epoch as f64)),
        ]));
        session.shutdown();
    }
    SnapshotMeta {
        note: "serve-mode daemon latency: read queries (batched 100 per timed sample, so \
               row medians are per-100-queries) answered from the published epoch snapshot, \
               and the synchronous delete+reinsert update round trip through the writer \
               thread (two epochs per sample); regenerate with `parbutterfly bench run \
               --filter serve` or `cargo bench --bench serve_latency`"
            .into(),
        top: vec![("threads".into(), Json::Num(num_threads() as f64))],
        summary: Some(Json::Arr(summary)),
    }
}
