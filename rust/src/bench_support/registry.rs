//! Registry of named benchmark targets — one entry per bench binary.
//!
//! This is the single list both entry points dispatch through:
//!
//! * `cargo bench --bench <bin>` — each `rust/benches/*.rs` is a thin
//!   wrapper calling [`run_from_bench_binary`];
//! * `parbutterfly bench run` — the CLI runner iterates the same
//!   [`targets`] list.
//!
//! Because both paths execute the same target function under the same
//! recorder, "what `bench run` measured" and "what `cargo bench`
//! measured" are identical by construction (rebar-style: named
//! workloads, one runner, recorded results).
//!
//! Targets whose results are tracked in-repo declare a `snapshot`
//! file; [`run_target`] wraps those in the row recorder and rewrites
//! `BENCH_<id>.json` in the stable schema (`bench` / `harness` /
//! `note` / `env` / `rows` / optional `summary`), tagging rows with
//! `harness: "native"` plus environment metadata so provenance is
//! never ambiguous.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::{SystemTime, UNIX_EPOCH};

use super::figures::{self, Stat};
use super::harness::{self, record};
use super::json::Json;
use super::snapshots;
use crate::prims::pool;

/// How much work a run does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// The real measurement: full suites, full warmup/run counts.
    Full,
    /// CI smoke: tiny workloads, 0 warmup + 1 timed run (via
    /// [`harness::set_quick`]).  Keeps the harness compiling and the
    /// snapshot schema valid without minutes of wall clock.
    Smoke,
}

impl Profile {
    pub fn name(self) -> &'static str {
        match self {
            Profile::Full => "full",
            Profile::Smoke => "smoke",
        }
    }
}

/// Snapshot metadata a target returns when it owns a `BENCH_*.json`.
pub struct SnapshotMeta {
    /// Human provenance note written into the snapshot.
    pub note: String,
    /// Extra top-level fields (e.g. `threads`, `threads_swept`).
    pub top: Vec<(String, Json)>,
    /// Optional `summary` array.
    pub summary: Option<Json>,
}

/// One named benchmark target.
pub struct Target {
    /// Short id — also the `bench` field of its recorded rows.
    pub id: &'static str,
    /// The `cargo bench --bench <bin>` binary name.
    pub bin: &'static str,
    /// One-line description for `bench list`.
    pub describe: &'static str,
    /// Snapshot file name at the workspace root, if tracked in-repo.
    pub snapshot: Option<&'static str>,
    run: fn(Profile) -> Option<SnapshotMeta>,
}

/// Tiny suites for the smoke profile.
const SMOKE_COUNTING: &[&str] = &["small"];
const SMOKE_PEELING: &[&str] = &["women"];

fn run_fig5(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => figures::agg_figure("fig5", Stat::PerVertex, false),
        Profile::Smoke => figures::agg_figure_on("fig5", Stat::PerVertex, false, SMOKE_COUNTING),
    }
    None
}

fn run_fig6(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => figures::agg_figure("fig6", Stat::PerEdge, false),
        Profile::Smoke => figures::agg_figure_on("fig6", Stat::PerEdge, false, SMOKE_COUNTING),
    }
    None
}

fn run_fig7(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => figures::agg_figure("fig7", Stat::Total, false),
        Profile::Smoke => figures::agg_figure_on("fig7", Stat::Total, false, SMOKE_COUNTING),
    }
    None
}

fn run_fig8(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => figures::scaling_figure("fig8", false),
        Profile::Smoke => figures::scaling_figure_on("fig8", false, "small", &[1, 2]),
    }
    None
}

fn run_fig10(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => {
            figures::rankings_figure("fig10", false);
            figures::wedge_ablation("table3-wedges");
        }
        Profile::Smoke => {
            figures::rankings_figure_on("fig10", false, SMOKE_COUNTING);
            figures::wedge_ablation_on("table3-wedges", SMOKE_COUNTING);
        }
    }
    None
}

fn run_fig11(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => {
            figures::approx_figure("fig11", false);
            figures::approx_figure("fig20", true);
        }
        Profile::Smoke => figures::approx_figure_on("fig11", false, "small", &[0.5]),
    }
    None
}

fn run_fig12(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => figures::peel_figure("fig12"),
        Profile::Smoke => figures::peel_figure_on("fig12", SMOKE_PEELING),
    }
    None
}

fn run_fig14(p: Profile) -> Option<SnapshotMeta> {
    let suite: &[&str] = match p {
        Profile::Full => &["cl", "clL"],
        Profile::Smoke => SMOKE_COUNTING,
    };
    figures::agg_figure_on("fig14", Stat::PerVertex, true, suite);
    figures::agg_figure_on("fig15", Stat::PerEdge, true, suite);
    figures::agg_figure_on("fig16", Stat::Total, true, suite);
    figures::rankings_figure_on("fig19", true, suite);
    figures::counting_table_on("table5", true, suite);
    None
}

fn run_table1(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => figures::datasets_table("table1"),
        Profile::Smoke => figures::datasets_table_on("table1", SMOKE_PEELING),
    }
    None
}

fn run_table2(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => figures::counting_table("table2", false),
        Profile::Smoke => figures::counting_table_on("table2", false, SMOKE_PEELING),
    }
    None
}

fn run_table4(p: Profile) -> Option<SnapshotMeta> {
    match p {
        Profile::Full => figures::peeling_table("table4"),
        Profile::Smoke => figures::peeling_table_on("table4", SMOKE_PEELING),
    }
    None
}

fn run_dense(p: Profile) -> Option<SnapshotMeta> {
    figures::dense_core_bench_sized("dense", matches!(p, Profile::Smoke));
    None
}

fn run_intersect(p: Profile) -> Option<SnapshotMeta> {
    Some(snapshots::intersect_vs_agg(p))
}

fn run_layout(p: Profile) -> Option<SnapshotMeta> {
    Some(snapshots::layout_sweep(p))
}

fn run_peel(p: Profile) -> Option<SnapshotMeta> {
    Some(snapshots::peel_intersect_vs_agg(p))
}

fn run_preprocess(p: Profile) -> Option<SnapshotMeta> {
    Some(snapshots::preprocess_pipeline(p))
}

fn run_dynamic(p: Profile) -> Option<SnapshotMeta> {
    Some(snapshots::fig_dynamic(p))
}

fn run_serve(p: Profile) -> Option<SnapshotMeta> {
    Some(snapshots::serve_latency(p))
}

/// Every benchmark target, in rough paper order.
pub fn targets() -> &'static [Target] {
    static TARGETS: [Target; 18] = [
        Target {
            id: "fig5",
            bin: "fig5_agg_vertex",
            describe: "per-vertex counting across wedge aggregations (paper Fig. 5)",
            snapshot: None,
            run: run_fig5,
        },
        Target {
            id: "fig6",
            bin: "fig6_agg_edge",
            describe: "per-edge counting across wedge aggregations (paper Fig. 6)",
            snapshot: None,
            run: run_fig6,
        },
        Target {
            id: "fig7",
            bin: "fig7_agg_total",
            describe: "total counting across wedge aggregations (paper Fig. 7)",
            snapshot: None,
            run: run_fig7,
        },
        Target {
            id: "fig8",
            bin: "fig8_scaling",
            describe: "self-relative scaling over the thread sweep (paper Fig. 8)",
            snapshot: None,
            run: run_fig8,
        },
        Target {
            id: "fig10",
            bin: "fig10_rankings",
            describe: "ranking comparison + wedge-count ablation (paper Fig. 10 / Table 3)",
            snapshot: None,
            run: run_fig10,
        },
        Target {
            id: "fig11",
            bin: "fig11_approx",
            describe: "approximate counting via edge/colorful sparsification (paper Figs. 11/20)",
            snapshot: None,
            run: run_fig11,
        },
        Target {
            id: "fig12",
            bin: "fig12_peel",
            describe: "tip/wing peeling across engines (paper Fig. 12)",
            snapshot: None,
            run: run_fig12,
        },
        Target {
            id: "fig14",
            bin: "fig14_cacheopt",
            describe: "cache-optimized counting figures + Table 5 (paper Figs. 14-16/19)",
            snapshot: None,
            run: run_fig14,
        },
        Target {
            id: "table1",
            bin: "table1_datasets",
            describe: "dataset statistics (paper Table 1)",
            snapshot: None,
            run: run_table1,
        },
        Target {
            id: "table2",
            bin: "table2_counting",
            describe: "counting comparison vs baselines (paper Table 2)",
            snapshot: None,
            run: run_table2,
        },
        Target {
            id: "table4",
            bin: "table4_peeling",
            describe: "peeling comparison vs baselines (paper Table 4)",
            snapshot: None,
            run: run_table4,
        },
        Target {
            id: "dense",
            bin: "dense_core",
            describe: "dense-core rectangle counting backends + hybrid crossover",
            snapshot: None,
            run: run_dense,
        },
        Target {
            id: "intersect",
            bin: "intersect_vs_agg",
            describe: "streaming intersect vs materializing aggregations",
            snapshot: Some("BENCH_intersect.json"),
            run: run_intersect,
        },
        Target {
            id: "layout",
            bin: "layout_sweep",
            describe: "flat vs hub memory layout for the intersect engine's wedge walks",
            snapshot: Some("BENCH_layout.json"),
            run: run_layout,
        },
        Target {
            id: "peel",
            bin: "peel_intersect_vs_agg",
            describe: "peeling UPDATE paths vs streaming intersect engine",
            snapshot: Some("BENCH_peel.json"),
            run: run_peel,
        },
        Target {
            id: "preprocess",
            bin: "preprocess_pipeline",
            describe: "parse / CSR / rank / PREPROCESS stage timings",
            snapshot: Some("BENCH_preprocess.json"),
            run: run_preprocess,
        },
        Target {
            id: "dynamic",
            bin: "fig_dynamic",
            describe: "batch-dynamic maintenance vs recount-per-batch",
            snapshot: Some("BENCH_dynamic.json"),
            run: run_dynamic,
        },
        Target {
            id: "serve",
            bin: "serve_latency",
            describe: "serve-mode daemon query latency + update-epoch round trip",
            snapshot: Some("BENCH_serve.json"),
            run: run_serve,
        },
    ];
    &TARGETS
}

/// Find a target by id or bench-binary name.
pub fn find(name: &str) -> Option<&'static Target> {
    targets().iter().find(|t| t.id == name || t.bin == name)
}

/// The workspace root (parent of the `rust/` crate).
pub fn workspace_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn git_rev() -> String {
    Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .current_dir(workspace_root())
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// `YYYY-MM-DD` (UTC) without a date crate: Howard Hinnant's
/// `civil_from_days`, epoch 1970-01-01.
fn utc_date() -> String {
    let secs = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let z = (secs / 86_400) as i64 + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1_460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = yoe + era * 400 + i64::from(m <= 2);
    format!("{y:04}-{m:02}-{d:02}")
}

/// Environment metadata recorded into every snapshot.
pub fn environment(profile: Profile) -> Json {
    Json::Obj(vec![
        ("threads".into(), Json::Num(pool::num_threads() as f64)),
        (
            "host_parallelism".into(),
            Json::Num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64),
        ),
        ("git_rev".into(), Json::str(git_rev())),
        ("date".into(), Json::str(utc_date())),
        ("profile".into(), Json::str(profile.name())),
    ])
}

/// Run one target; if it owns a snapshot, rewrite
/// `<out_dir>/<snapshot>` from the recorded rows and return the path.
pub fn run_target(
    target: &Target,
    profile: Profile,
    out_dir: &Path,
) -> anyhow::Result<Option<PathBuf>> {
    let quick_before = harness::quick();
    harness::set_quick(matches!(profile, Profile::Smoke));
    if target.snapshot.is_some() {
        record::start();
    }
    let meta = (target.run)(profile);
    harness::set_quick(quick_before);
    let Some(file) = target.snapshot else {
        return Ok(None);
    };
    let meta = meta.expect("snapshot target returned no metadata");
    // Rows keep their structured fields; the per-row `bench` key is
    // redundant with the file-level field and is stripped for schema
    // compatibility with the seeded snapshots.
    let rows: Vec<Json> = record::finish()
        .into_iter()
        .map(|row| match row {
            Json::Obj(fields) => {
                Json::Obj(fields.into_iter().filter(|(k, _)| k != "bench").collect())
            }
            other => other,
        })
        .collect();
    let mut doc: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str(target.bin)),
        ("harness".into(), Json::str("native")),
        ("note".into(), Json::str(meta.note)),
        ("env".into(), environment(profile)),
    ];
    doc.extend(meta.top);
    doc.push(("rows".into(), Json::Arr(rows)));
    if let Some(summary) = meta.summary {
        doc.push(("summary".into(), summary));
    }
    std::fs::create_dir_all(out_dir)?;
    let path = out_dir.join(file);
    std::fs::write(&path, Json::Obj(doc).pretty())?;
    Ok(Some(path))
}

/// Entry point for the thin `rust/benches/*.rs` wrappers: run the
/// target owning this binary at the full profile, writing any snapshot
/// to the workspace root (the historical `cargo bench` behavior).
pub fn run_from_bench_binary(bin: &str) {
    let target = find(bin).unwrap_or_else(|| panic!("no bench target for binary {bin:?}"));
    match run_target(target, Profile::Full, &workspace_root()) {
        Ok(Some(path)) => println!("wrote {}", path.display()),
        Ok(None) => {}
        Err(e) => panic!("bench target {bin}: {e:#}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_bench_binary() {
        let bins: Vec<&str> = targets().iter().map(|t| t.bin).collect();
        let mut dir: Vec<String> = std::fs::read_dir(workspace_root().join("rust/benches"))
            .expect("read benches dir")
            .map(|e| {
                e.unwrap()
                    .file_name()
                    .to_string_lossy()
                    .trim_end_matches(".rs")
                    .to_string()
            })
            .collect();
        dir.sort();
        for bin in &dir {
            assert!(bins.contains(&bin.as_str()), "bench binary {bin} missing from registry");
        }
        assert_eq!(dir.len(), targets().len(), "registry has stale entries");
    }

    #[test]
    fn ids_and_bins_are_unique_and_findable() {
        let ts = targets();
        for t in ts {
            assert!(std::ptr::eq(find(t.id).unwrap(), t), "id {} not findable", t.id);
            assert!(std::ptr::eq(find(t.bin).unwrap(), t), "bin {} not findable", t.bin);
        }
        let mut ids: Vec<&str> = ts.iter().map(|t| t.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), ts.len());
        assert!(find("no-such-target").is_none());
    }

    #[test]
    fn utc_date_is_iso_shaped() {
        let d = utc_date();
        assert_eq!(d.len(), 10);
        assert_eq!(&d[4..5], "-");
        assert_eq!(&d[7..8], "-");
        let year: i64 = d[..4].parse().unwrap();
        assert!((2024..2200).contains(&year), "implausible year in {d}");
    }

    #[test]
    fn smoke_snapshot_round_trips() {
        // The smallest snapshot target, smoke profile, temp out dir:
        // the written file must parse and carry the stable schema.
        let target = find("dynamic").unwrap();
        let dir = std::env::temp_dir().join("pb_registry_test");
        let path = run_target(target, Profile::Smoke, &dir)
            .expect("run smoke target")
            .expect("snapshot path");
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "fig_dynamic");
        assert_eq!(doc.get("harness").unwrap().as_str().unwrap(), "native");
        let env = doc.get("env").unwrap();
        assert_eq!(env.get("profile").unwrap().as_str().unwrap(), "smoke");
        assert!(env.get("git_rev").is_some() && env.get("date").is_some());
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert!(!rows.is_empty());
        for row in rows {
            assert!(row.get("bench").is_none(), "per-row bench key must be stripped");
            assert!(row.get("workload").is_some());
            assert!(row.get("median_ms").is_some());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
