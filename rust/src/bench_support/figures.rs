//! Parameterized regenerators for every table and figure of §6.
//!
//! Each `cargo bench` target is a thin `harness = false` binary
//! delegating here (the mapping rationale lives in ARCHITECTURE.md).  All output
//! uses [`super::harness`]'s human + `BENCHROW` machine formats.
//!
//! Configuration axes follow the paper's notation: aggregation rows
//! are `Sort/ASort/Hash/AHash/Hist/AHist/BatchS/BatchWA`, where the
//! `A` prefix means atomic-add butterfly aggregation and its absence
//! means re-aggregation (§6.1); batching is always atomic (footnote 4).

use crate::baseline::{seq_count, seq_peel};
use crate::count::{
    count_per_edge, count_per_vertex, count_total, sparsify, BflyAgg, CountOpts, Engine, WedgeAgg,
};
use crate::graph::{BipartiteGraph, Layout};
use crate::peel::{
    peel_edges, peel_vertices, BucketKind, PeelEOpts, PeelEngine, PeelSide, PeelVOpts, WedgeStore,
};
use crate::prims::pool::with_threads;
use crate::rank::{choose_ranking, f_metric, preprocess, Ranking};

use super::harness::{banner, bench, bench_n, report, report_normalized, report_value};
use super::json::Json;
use super::workloads::{self, COUNTING_SUITE, PEELING_SUITE};

/// Counting target: which statistic a figure measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stat {
    Total,
    PerVertex,
    PerEdge,
}

impl Stat {
    pub fn name(&self) -> &'static str {
        match self {
            Stat::Total => "total",
            Stat::PerVertex => "per-vertex",
            Stat::PerEdge => "per-edge",
        }
    }
}

fn run_count(g: &BipartiteGraph, stat: Stat, opts: &CountOpts) -> u64 {
    match stat {
        Stat::Total => count_total(g, opts).unwrap(),
        Stat::PerVertex => count_per_vertex(g, opts).unwrap().bu.iter().sum::<u64>() / 2,
        Stat::PerEdge => count_per_edge(g, opts).unwrap().iter().sum::<u64>() / 4,
    }
}

/// The comparison rows: the paper's eight aggregation configurations
/// plus the streaming intersect engine.  Each row is a base
/// [`CountOpts`]; figures overlay ranking / cache_opt via struct
/// update.
pub fn agg_rows() -> Vec<(&'static str, CountOpts)> {
    let wedges = |agg: WedgeAgg, bfly: BflyAgg| CountOpts { agg, bfly, ..Default::default() };
    vec![
        ("Sort", wedges(WedgeAgg::Sort, BflyAgg::Reagg)),
        ("ASort", wedges(WedgeAgg::Sort, BflyAgg::Atomic)),
        ("Hash", wedges(WedgeAgg::Hash, BflyAgg::Reagg)),
        ("AHash", wedges(WedgeAgg::Hash, BflyAgg::Atomic)),
        ("Hist", wedges(WedgeAgg::Hist, BflyAgg::Reagg)),
        ("AHist", wedges(WedgeAgg::Hist, BflyAgg::Atomic)),
        ("BatchS", wedges(WedgeAgg::BatchS, BflyAgg::Atomic)),
        ("BatchWA", wedges(WedgeAgg::BatchWA, BflyAgg::Atomic)),
        // The layout axis is pinned on both intersect rows: the flat
        // baseline must survive even when the env default resolves to
        // hub, and vice versa.
        (
            "Intersect",
            CountOpts { engine: Engine::Intersect, layout: Layout::Flat, ..Default::default() },
        ),
        (
            "Intersect-hub",
            CountOpts { engine: Engine::Intersect, layout: Layout::Hub, ..Default::default() },
        ),
    ]
}

/// Figures 5/6/7 (and 14/15/16 with `cache_opt`): counting runtime per
/// aggregation method, normalized to the fastest, best ranking per
/// dataset (approximated by the runtime `f`-metric rule).
pub fn agg_figure(bench_name: &str, stat: Stat, cache_opt: bool) {
    agg_figure_on(bench_name, stat, cache_opt, &COUNTING_SUITE);
}

/// [`agg_figure`] on an explicit workload list (the cache-opt suite
/// runs a reduced set to bound total bench time).
pub fn agg_figure_on(bench_name: &str, stat: Stat, cache_opt: bool, suite: &[&str]) {
    banner(
        bench_name,
        &format!(
            "counting {} across wedge/butterfly aggregations (cache_opt={cache_opt}); \
             paper: Figs 5-7 (14-16 with cache opt)",
            stat.name()
        ),
    );
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let ranking = choose_ranking(&wl.graph);
        println!("[{}] {} — ranking {}", wl.id, wl.describe, ranking.name());
        let mut rows = Vec::new();
        let mut expected = None;
        for (label, base) in agg_rows() {
            let opts = CountOpts { ranking, cache_opt, ..base };
            let mut result = 0u64;
            let m = bench(|| {
                result = run_count(&wl.graph, stat, &opts);
                result
            });
            // Cross-check: every configuration must agree.
            match expected {
                None => expected = Some(result),
                Some(e) => assert_eq!(e, result, "{label} disagrees on {wl_id}"),
            }
            rows.push((label.to_string(), m));
        }
        report_normalized(bench_name, wl.id, &rows);
    }
}

/// Table 2 (Table 5 with `cache_opt`): best parallel vs single-thread
/// vs the sequential baselines, for all three statistics.
pub fn counting_table(bench_name: &str, cache_opt: bool) {
    counting_table_on(bench_name, cache_opt, &COUNTING_SUITE);
}

/// [`counting_table`] on an explicit workload list.
pub fn counting_table_on(bench_name: &str, cache_opt: bool, suite: &[&str]) {
    banner(
        bench_name,
        "best-config counting vs sequential baselines; paper: Table 2 (5 with cache opt)",
    );
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let g = &wl.graph;
        let ranking = choose_ranking(g);
        let opts = CountOpts { ranking, cache_opt, ..Default::default() }; // BatchS default
        let iopts = CountOpts { ranking, engine: Engine::Intersect, ..Default::default() };
        println!("[{}] {}", wl.id, wl.describe);

        // --- total ---
        let expect = count_total(g, &opts).unwrap();
        let m = bench(|| count_total(g, &opts).unwrap());
        report(bench_name, wl.id, "total/PB-par", &m);
        let m = bench(|| with_threads(1, || count_total(g, &opts).unwrap()));
        report(bench_name, wl.id, "total/PB-T1", &m);
        assert_eq!(count_total(g, &iopts).unwrap(), expect, "intersect disagrees on {wl_id}");
        let m = bench(|| count_total(g, &iopts).unwrap());
        report(bench_name, wl.id, "total/PB-intersect", &m);
        let m = bench_n(0, 1, || seq_count::sanei_mehri_total(g));
        report(bench_name, wl.id, "total/SaneiMehri-T1", &m);
        let m = bench_n(0, 1, || seq_count::chiba_nishizeki_total(g));
        report(bench_name, wl.id, "total/ChibaNishizeki-T1", &m);
        // PGD gets a time budget, like the paper's "> 5.5 hrs" rows.
        let budget = std::time::Duration::from_secs(60);
        let mut pgd = None;
        let m = bench_n(0, 1, || {
            pgd = seq_count::pgd_like_total_deadline(g, budget);
            pgd
        });
        match pgd {
            Some(t) => {
                assert_eq!(t, expect);
                report(bench_name, wl.id, "total/PGD-like", &m);
            }
            None => {
                println!("  {:<24} > {:?} (budget exhausted)", "total/PGD-like", budget);
                report_value(bench_name, wl.id, "total/PGD-like-timeout", Json::Num(60_000.0));
            }
        }
        assert_eq!(seq_count::sanei_mehri_total(g), expect);

        // --- per-vertex ---
        let m = bench(|| count_per_vertex(g, &opts).unwrap());
        report(bench_name, wl.id, "vertex/PB-par", &m);
        let m = bench(|| with_threads(1, || count_per_vertex(g, &opts).unwrap()));
        report(bench_name, wl.id, "vertex/PB-T1", &m);
        let m = bench(|| count_per_vertex(g, &iopts).unwrap());
        report(bench_name, wl.id, "vertex/PB-intersect", &m);
        let m = bench_n(0, 1, || seq_count::wang_vanilla(g));
        report(bench_name, wl.id, "vertex/Wang2014-T1", &m);

        // --- per-edge ---
        let m = bench(|| count_per_edge(g, &opts).unwrap());
        report(bench_name, wl.id, "edge/PB-par", &m);
        let m = bench(|| with_threads(1, || count_per_edge(g, &opts).unwrap()));
        report(bench_name, wl.id, "edge/PB-T1", &m);
        let m = bench(|| count_per_edge(g, &iopts).unwrap());
        report(bench_name, wl.id, "edge/PB-intersect", &m);
    }
}

/// Figures 8/9 (17/18 with `cache_opt`): thread-count sweep.
pub fn scaling_figure(bench_name: &str, cache_opt: bool) {
    scaling_figure_on(bench_name, cache_opt, "clL", &[1, 2, 4]);
}

/// [`scaling_figure`] on an explicit workload and thread matrix.
pub fn scaling_figure_on(bench_name: &str, cache_opt: bool, wl_id: &str, threads: &[usize]) {
    banner(
        bench_name,
        "thread sweep; paper: Figs 8/9 (17/18 with cache opt).  NOTE: the bench \
         substrate has ONE physical core — the sweep exercises the fork-join machinery \
         and records overhead, it cannot show real speedup (see ARCHITECTURE.md).",
    );
    let wl = workloads::build(wl_id);
    let ranking = choose_ranking(&wl.graph);
    for (stat, label) in [(Stat::PerVertex, "per-vertex"), (Stat::PerEdge, "per-edge")] {
        for (agg_label, base) in agg_rows() {
            // The paper sweeps every aggregation; keep the figure's
            // shape but one row per aggregation family (plus the
            // streaming engine).
            if !matches!(agg_label, "AHash" | "BatchS" | "BatchWA" | "Intersect") {
                continue;
            }
            for &t in threads {
                let opts = CountOpts { ranking, cache_opt, ..base.clone() };
                let m = bench_n(0, 2, || with_threads(t, || run_count(&wl.graph, stat, &opts)));
                report(bench_name, wl.id, &format!("{label}/{agg_label}/t{t}"), &m);
            }
        }
    }
}

/// Figure 10 (19 with `cache_opt`) + Table 3: rankings and the
/// `f` metric.
pub fn rankings_figure(bench_name: &str, cache_opt: bool) {
    rankings_figure_on(bench_name, cache_opt, &COUNTING_SUITE);
}

/// [`rankings_figure`] on an explicit workload list.
pub fn rankings_figure_on(bench_name: &str, cache_opt: bool, suite: &[&str]) {
    banner(
        bench_name,
        "per-vertex counting across rankings (BatchS), ranking time included; \
         paper: Fig 10 (19 with cache opt) + Table 3 f-metric",
    );
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        println!("[{}] {}", wl.id, wl.describe);
        // Table 3: f metric per ranking.
        for r in Ranking::ALL {
            let f = f_metric(&wl.graph, r);
            println!("  f({:<7}) = {:+.4}", r.name(), f);
            report_value(&format!("{bench_name}-f"), wl.id, r.name(), Json::Num(f));
        }
        // Fig 10: runtime per ranking (rank+count together).
        let mut rows = Vec::new();
        for r in Ranking::ALL {
            let opts = CountOpts { ranking: r, cache_opt, ..Default::default() };
            let m = bench(|| count_per_vertex(&wl.graph, &opts).unwrap());
            rows.push((r.name().to_string(), m));
        }
        report_normalized(bench_name, wl.id, &rows);
    }
}

/// Figure 11 (20 with `cache_opt`): sparsification sweep, 1-thread vs
/// parallel, plus estimate quality.
pub fn approx_figure(bench_name: &str, cache_opt: bool) {
    approx_figure_on(bench_name, cache_opt, "clL", &[0.1, 0.25, 0.5, 0.75]);
}

/// [`approx_figure`] on an explicit workload and `p` sweep.
pub fn approx_figure_on(bench_name: &str, cache_opt: bool, wl_id: &str, ps: &[f64]) {
    banner(
        bench_name,
        "edge & colorful sparsification over p; paper: Fig 11 (20 with cache opt)",
    );
    let wl = workloads::build(wl_id);
    let g = &wl.graph;
    let opts = CountOpts { cache_opt, ..Default::default() };
    let exact = count_total(g, &opts).unwrap() as f64;
    println!("exact = {exact}");
    for &p in ps {
        let mut est = 0.0;
        let m = bench(|| {
            est = sparsify::approx_total_edge(g, p, 7, &opts).unwrap();
            est
        });
        report(bench_name, wl.id, &format!("edge/p{p}"), &m);
        println!("    estimate {est:.0} (err {:+.1}%)", 100.0 * (est - exact) / exact);
        let m1 = bench(|| with_threads(1, || sparsify::approx_total_edge(g, p, 7, &opts).unwrap()));
        report(bench_name, wl.id, &format!("edge/p{p}/t1"), &m1);

        let c = (1.0 / p).round() as u64;
        let m = bench(|| {
            est = sparsify::approx_total_colorful(g, c, 7, &opts).unwrap();
            est
        });
        report(bench_name, wl.id, &format!("colorful/p{p}"), &m);
        println!("    estimate {est:.0} (err {:+.1}%)", 100.0 * (est - exact) / exact);
    }
}

/// The peeling comparison rows: the five aggregation strategies plus
/// the streaming intersect engine and the two-phase range-parallel
/// engine (labels shared by fig12/13 and the `peel_intersect_vs_agg`
/// bench).
pub fn peel_rows() -> Vec<(&'static str, PeelEngine, WedgeAgg)> {
    let mut rows: Vec<(&'static str, PeelEngine, WedgeAgg)> = WedgeAgg::ALL
        .into_iter()
        .map(|agg| (agg.name(), PeelEngine::Agg, agg))
        .collect();
    rows.push(("intersect", PeelEngine::Intersect, WedgeAgg::BatchS));
    rows.push(("two-phase", PeelEngine::TwoPhase, WedgeAgg::BatchS));
    rows
}

/// Figures 12/13: peeling runtime per aggregation method, plus the
/// streaming intersect engine as a ninth row.
pub fn peel_figure(bench_name: &str) {
    peel_figure_on(bench_name, &PEELING_SUITE);
}

/// [`peel_figure`] on an explicit workload list.
pub fn peel_figure_on(bench_name: &str, suite: &[&str]) {
    banner(
        bench_name,
        "tip & wing decomposition across aggregations + intersect engine (Julienne \
         buckets); paper: Figs 12/13",
    );
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let g = &wl.graph;
        let vc = count_per_vertex(g, &CountOpts::default()).unwrap();
        let be = count_per_edge(g, &CountOpts::default()).unwrap();
        println!("[{}] {}", wl.id, wl.describe);
        let mut vrows = Vec::new();
        let mut erows = Vec::new();
        for (label, engine, agg) in peel_rows() {
            let vopts = PeelVOpts {
                engine,
                agg,
                buckets: BucketKind::Julienne,
                side: PeelSide::Auto,
                ..Default::default()
            };
            let m = bench_n(0, 2, || peel_vertices(g, &vc.bu, &vc.bv, &vopts).unwrap());
            vrows.push((format!("V/{label}"), m));
            let eopts =
                PeelEOpts { engine, agg, buckets: BucketKind::Julienne, ..Default::default() };
            let m = bench_n(0, 2, || peel_edges(g, &be, &eopts).unwrap());
            erows.push((format!("E/{label}"), m));
        }
        report_normalized(bench_name, wl.id, &vrows);
        report_normalized(bench_name, wl.id, &erows);
    }
}

/// Table 4: peeling — parallel vs single-thread vs Sariyüce–Pinar
/// dense-array baseline (with its empty-bucket scan count), plus the
/// WPEEL and Fibonacci-heap variants as ablations.
pub fn peeling_table(bench_name: &str) {
    peeling_table_on(bench_name, &PEELING_SUITE);
}

/// [`peeling_table`] on an explicit workload list.
pub fn peeling_table_on(bench_name: &str, suite: &[&str]) {
    banner(
        bench_name,
        "peeling vs the dense-bucket sequential baseline; paper: Table 4",
    );
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let g = &wl.graph;
        let vc = count_per_vertex(g, &CountOpts::default()).unwrap();
        let be = count_per_edge(g, &CountOpts::default()).unwrap();
        println!("[{}] {}", wl.id, wl.describe);

        // Baseline rows pin engine: Agg explicitly — the labels imply
        // the aggregation path, and PeelVOpts::default() follows
        // PARBUTTERFLY_PEEL_ENGINE (the CI matrix sets it).
        let vopts = PeelVOpts { engine: PeelEngine::Agg, ..Default::default() };
        let mut rounds_v = 0usize;
        let m = bench_n(0, 2, || {
            let r = peel_vertices(g, &vc.bu, &vc.bv, &vopts).unwrap();
            rounds_v = r.rounds;
            r
        });
        report(bench_name, wl.id, "tip/PB-par", &m);
        let m = bench_n(0, 2, || with_threads(1, || peel_vertices(g, &vc.bu, &vc.bv, &vopts).unwrap()));
        report(bench_name, wl.id, "tip/PB-T1", &m);
        let isect = PeelVOpts { engine: PeelEngine::Intersect, ..Default::default() };
        let m = bench_n(0, 2, || peel_vertices(g, &vc.bu, &vc.bv, &isect).unwrap());
        report(bench_name, wl.id, "tip/PB-intersect", &m);
        let fib = PeelVOpts {
            engine: PeelEngine::Agg,
            buckets: BucketKind::FibHeap,
            ..Default::default()
        };
        let m = bench_n(0, 2, || peel_vertices(g, &vc.bu, &vc.bv, &fib).unwrap());
        report(bench_name, wl.id, "tip/PB-fibheap", &m);
        let store = WedgeStore::build(g, Ranking::Degree);
        let m = bench_n(0, 2, || {
            crate::peel::wpeel_vertices(g, &store, &vc.bu, &vc.bv, PeelSide::Auto, BucketKind::Julienne)
        });
        report(bench_name, wl.id, "tip/PB-wstore", &m);
        // Sequential baseline peels the same side as Auto.
        let peel_u = g.wedges_centered_v() <= g.wedges_centered_u();
        let counts: &[u64] = if peel_u { &vc.bu } else { &vc.bv };
        let mut empties = 0u64;
        let m = bench_n(0, 1, || {
            let (tips, e) = if peel_u {
                seq_peel::sp_tip_numbers_u(g, counts)
            } else {
                // mirror: the baseline is side-symmetric via transpose
                seq_peel::sp_tip_numbers_u(&mirror(g), counts)
            };
            empties = e;
            tips
        });
        report(bench_name, wl.id, "tip/SariyucePinar-T1", &m);
        println!("    rho_v = {rounds_v}, baseline scanned {empties} empty buckets");

        let eopts = PeelEOpts { engine: PeelEngine::Agg, ..Default::default() };
        let mut rounds_e = 0usize;
        let m = bench_n(0, 2, || {
            let r = peel_edges(g, &be, &eopts).unwrap();
            rounds_e = r.rounds;
            r
        });
        report(bench_name, wl.id, "wing/PB-par", &m);
        let m = bench_n(0, 2, || with_threads(1, || peel_edges(g, &be, &eopts).unwrap()));
        report(bench_name, wl.id, "wing/PB-T1", &m);
        let isect = PeelEOpts { engine: PeelEngine::Intersect, ..Default::default() };
        let m = bench_n(0, 2, || peel_edges(g, &be, &isect).unwrap());
        report(bench_name, wl.id, "wing/PB-intersect", &m);
        let m = bench_n(0, 1, || seq_peel::sp_wing_numbers(g, &be));
        report(bench_name, wl.id, "wing/SariyucePinar-T1", &m);
        println!("    rho_e = {rounds_e}");
    }
}

fn mirror(g: &BipartiteGraph) -> BipartiteGraph {
    let edges: Vec<(u32, u32)> = g.edges().into_iter().map(|(u, v)| (v, u)).collect();
    BipartiteGraph::from_edges(g.nv(), g.nu(), &edges)
}

/// Table 1: the dataset statistics table.
pub fn datasets_table(bench_name: &str) {
    datasets_table_on(bench_name, &workloads::ALL);
}

/// [`datasets_table`] on an explicit workload list.
pub fn datasets_table_on(bench_name: &str, suite: &[&str]) {
    banner(bench_name, "workload statistics; paper: Table 1");
    println!(
        "{:<8} {:>8} {:>8} {:>9} {:>14} {:>7} {:>7}",
        "dataset", "|U|", "|V|", "|E|", "#butterflies", "rho_v", "rho_e"
    );
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        let g = &wl.graph;
        let total = count_total(g, &CountOpts::default()).unwrap();
        // Peeling complexities only where the suite peels (mirrors the
        // paper's dashes for graphs whose baseline never finished).
        let (rv, re) = if PEELING_SUITE.contains(&wl_id) || wl_id == "women" {
            let vc = count_per_vertex(g, &CountOpts::default()).unwrap();
            let be = count_per_edge(g, &CountOpts::default()).unwrap();
            let rv = peel_vertices(g, &vc.bu, &vc.bv, &PeelVOpts::default()).unwrap().rounds;
            let re = peel_edges(g, &be, &PeelEOpts::default()).unwrap().rounds;
            (rv.to_string(), re.to_string())
        } else {
            ("-".to_string(), "-".to_string())
        };
        println!(
            "{:<8} {:>8} {:>8} {:>9} {:>14} {:>7} {:>7}",
            wl.id,
            g.nu(),
            g.nv(),
            g.m(),
            total,
            rv,
            re
        );
        report_value(bench_name, wl.id, "stats", Json::Num(total as f64));
    }
}

/// Dense-core accelerator bench (ours): the selected dense backend
/// (PJRT artifacts when available, the pure-Rust reference kernel
/// otherwise) vs CPU framework on dense-block workloads, plus the
/// hybrid split.
pub fn dense_core_bench(bench_name: &str) {
    dense_core_bench_sized(bench_name, false);
}

/// [`dense_core_bench`]; `quick` restricts to the smallest tile and
/// skips the hybrid sweep (smoke profile).
pub fn dense_core_bench_sized(bench_name: &str, quick: bool) {
    banner(
        bench_name,
        "dense-core backend vs CPU sparse path (PARBUTTERFLY_BACKEND selects; \
         PJRT needs `make artifacts`)",
    );
    let backend = match crate::runtime::default_backend() {
        Some(b) => b,
        None => {
            println!("SKIPPED: dense path disabled (PARBUTTERFLY_BACKEND=none)");
            return;
        }
    };
    use crate::graph::gen;
    println!("backend: {}", backend.name());
    let mut tiles = vec![("er-256", gen::erdos_renyi(256, 256, 8_000, 21))];
    if !quick {
        tiles.push(("dense-256", gen::planted_blocks(256, 256, 4, 64, 64, 0.9, 500, 22)));
        tiles.push(("er-512", gen::erdos_renyi(512, 512, 30_000, 23)));
        tiles.push(("k-128x128", gen::complete_bipartite(128, 128)));
    }
    for (label, g) in tiles {
        let expect = count_total(&g, &CountOpts::default()).unwrap();
        let m = bench(|| crate::count::dense::count_total_dense(&g, backend.as_ref()).unwrap());
        report(bench_name, label, &format!("dense-{}", backend.name()), &m);
        let m = bench(|| count_total(&g, &CountOpts::default()).unwrap());
        report(bench_name, label, "cpu-framework", &m);
        let got = crate::count::dense::count_total_dense(&g, backend.as_ref()).unwrap();
        assert_eq!(got, expect, "{label}");
    }
    if quick {
        return;
    }
    // Hybrid on a larger skewed graph.
    let g = gen::chung_lu(2_000, 3_000, 60_000, 2.05, 25);
    let expect = count_total(&g, &CountOpts::default()).unwrap();
    let m = bench(|| {
        crate::count::dense::count_total_hybrid(
            &g,
            backend.as_ref(),
            256,
            256,
            &CountOpts::default(),
        )
        .unwrap()
    });
    report(bench_name, "cl-2kx3k", "hybrid-256core", &m);
    let m = bench(|| count_total(&g, &CountOpts::default()).unwrap());
    report(bench_name, "cl-2kx3k", "cpu-framework", &m);
    let got = crate::count::dense::count_total_hybrid(
        &g,
        backend.as_ref(),
        256,
        256,
        &CountOpts::default(),
    )
    .unwrap();
    assert_eq!(got, expect);
}

/// Extra ablation: wedge counts per ranking (drives the Fig 10 story
/// without timing noise) — used by fig10 and the `BENCH_*.json` snapshots.
pub fn wedge_ablation(bench_name: &str) {
    wedge_ablation_on(bench_name, &COUNTING_SUITE);
}

/// [`wedge_ablation`] on an explicit workload list.
pub fn wedge_ablation_on(bench_name: &str, suite: &[&str]) {
    banner(bench_name, "wedges processed per ranking (exact counts)");
    for &wl_id in suite {
        let wl = workloads::build(wl_id);
        for r in Ranking::ALL {
            let w = preprocess(&wl.graph, r).wedges_processed();
            report_value(bench_name, wl.id, r.name(), Json::Num(w as f64));
        }
    }
}
