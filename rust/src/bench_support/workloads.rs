//! The benchmark workload suite (Table 1 stand-ins).
//!
//! Each workload is a deterministic synthetic graph chosen to
//! reproduce the *structural regime* of one of the paper's KONECT
//! datasets (see ARCHITECTURE.md for the mapping rationale):
//!
//! | id       | family            | regime it stands in for              |
//! |----------|-------------------|--------------------------------------|
//! | `small`  | ER                | dblp/github-scale sanity workload    |
//! | `er`     | ER near-regular   | itwiki/livejournal (f ~ 0, side wins)|
//! | `cl`     | Chung-Lu 2.1      | discogs (f >> 0.1, degree wins)      |
//! | `clL`    | Chung-Lu 2.1, big | enwiki/delicious-scale skew          |
//! | `dense`  | planted blocks    | discogs_style (few distinct counts)  |
//! | `women`  | Davis (real data) | real-data smoke row                  |
//!
//! Sizes are scaled so the *sequential baselines* still finish within
//! a bench run on the single-core substrate.

use crate::graph::{gen, BipartiteGraph};

/// A named benchmark workload.
pub struct Workload {
    pub id: &'static str,
    pub describe: &'static str,
    pub graph: BipartiteGraph,
}

/// Build one workload by id.
pub fn build(id: &str) -> Workload {
    match id {
        "small" => Workload {
            id: "small",
            describe: "ER 500x700 m~8k",
            graph: gen::erdos_renyi(500, 700, 8_000, 101),
        },
        "er" => Workload {
            id: "er",
            describe: "ER near-regular 3000x3000 m~60k",
            graph: gen::erdos_renyi(3_000, 3_000, 60_000, 103),
        },
        "cl" => Workload {
            id: "cl",
            describe: "Chung-Lu beta=2.1 5000x8000 m~120k",
            graph: gen::chung_lu(5_000, 8_000, 120_000, 2.1, 105),
        },
        "clL" => Workload {
            id: "clL",
            describe: "Chung-Lu beta=2.1 20000x30000 m~600k",
            graph: gen::chung_lu(20_000, 30_000, 600_000, 2.1, 107),
        },
        "dense" => Workload {
            id: "dense",
            describe: "8 planted 60x60 blocks p=0.85 + noise",
            graph: gen::planted_blocks(1_000, 1_000, 8, 60, 60, 0.85, 2_000, 109),
        },
        "women" => Workload {
            id: "women",
            describe: "Davis Southern Women (real, 18x14)",
            graph: gen::davis_southern_women(),
        },
        other => panic!("unknown workload {other}"),
    }
}

/// The counting suite (Figures 5–7, Table 2).
pub const COUNTING_SUITE: [&str; 4] = ["er", "cl", "clL", "dense"];

/// The peeling suite (Figures 12–13, Table 4) — smaller, peeling
/// rounds multiply the work.
pub const PEELING_SUITE: [&str; 3] = ["small", "cl", "dense"];

/// Everything (Table 1).
pub const ALL: [&str; 6] = ["small", "er", "cl", "clL", "dense", "women"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workloads_build_and_are_deterministic() {
        for id in ALL {
            let a = build(id);
            let b = build(id);
            assert_eq!(a.graph.m(), b.graph.m(), "{id}");
            assert!(a.graph.m() > 0, "{id} empty");
        }
    }

    #[test]
    fn cl_is_skewed_er_is_not() {
        let cl = build("cl").graph;
        let er = build("er").graph;
        let skew = |g: &BipartiteGraph| g.max_degree() as f64 / (g.m() as f64 / g.n() as f64);
        assert!(skew(&cl) > 4.0 * skew(&er));
    }
}
