//! Atomic helpers: CAS-loop min/max and cache-line-padded counters.
//!
//! The paper's framework assumes priority-write/fetch-and-add
//! primitives from the Cilk/PBBS substrate; these are their `std`
//! equivalents.  `fetch_min`/`fetch_max` are lock-free CAS loops —
//! O(1) amortized per call under low contention, with the usual
//! retry-under-contention caveat — used for bucket thresholds and
//! report maxima.  [`PaddedCounter`] spaces per-worker counters a
//! cache line apart so contiguous `Vec<PaddedCounter>` tallies don't
//! false-share.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// `a = min(a, v)` atomically; returns true if `a` changed.
#[inline]
pub fn fetch_min_u64(a: &AtomicU64, v: u64) -> bool {
    let mut cur = a.load(Ordering::Relaxed);
    while v < cur {
        match a.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(c) => cur = c,
        }
    }
    false
}

/// `a = max(a, v)` atomically; returns true if `a` changed.
#[inline]
pub fn fetch_max_u64(a: &AtomicU64, v: u64) -> bool {
    let mut cur = a.load(Ordering::Relaxed);
    while v > cur {
        match a.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(c) => cur = c,
        }
    }
    false
}

/// A cache-line-padded atomic counter (avoids false sharing when one
/// counter per worker lives in a contiguous Vec).
#[repr(align(64))]
#[derive(Default)]
pub struct PaddedCounter(pub AtomicUsize);

impl PaddedCounter {
    #[inline]
    pub fn add(&self, v: usize) -> usize {
        self.0.fetch_add(v, Ordering::Relaxed)
    }
    #[inline]
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::pool::{parallel_for, with_threads};

    #[test]
    fn min_max_converge_under_contention() {
        with_threads(4, || {
            let mn = AtomicU64::new(u64::MAX);
            let mx = AtomicU64::new(0);
            parallel_for(10_000, |i| {
                let v = ((i as u64).wrapping_mul(2654435761)) % 100_000;
                fetch_min_u64(&mn, v);
                fetch_max_u64(&mx, v);
            });
            let vals: Vec<u64> =
                (0..10_000).map(|i| ((i as u64).wrapping_mul(2654435761)) % 100_000).collect();
            assert_eq!(mn.load(Ordering::Relaxed), *vals.iter().min().unwrap());
            assert_eq!(mx.load(Ordering::Relaxed), *vals.iter().max().unwrap());
        });
    }

    #[test]
    fn padded_counter_is_cacheline_sized() {
        assert_eq!(std::mem::align_of::<PaddedCounter>(), 64);
        let c = PaddedCounter::default();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
    }
}
