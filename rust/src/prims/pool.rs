//! Fork-join parallelism on `std::thread::scope`.
//!
//! Thread count resolution order: the innermost [`with_threads`] scope,
//! then the `PARBUTTERFLY_THREADS` environment variable, then
//! `std::thread::available_parallelism()`.  With one thread every
//! combinator degenerates to an inline sequential loop (no spawn cost),
//! which is also the fast path on the single-core benchmark substrate —
//! thread sweeps in the benches exercise the real fork-join machinery.
//!
//! Two scheduling modes mirror the paper's batching options:
//! * static chunking ([`parallel_for_chunks`]) — one contiguous range per
//!   worker, the "simple batching" layout (preserves vertex locality);
//! * dynamic self-scheduling ([`parallel_for_dynamic`]) — workers claim
//!   fixed-size grains from an atomic counter, the "wedge-aware" layout
//!   (balances skewed per-item work).
//!
//! ## Panic isolation and cooperative checks
//!
//! Every combinator runs its workers under `catch_unwind`: a panicking
//! task records a structured failure ([`PoolError`] — worker index,
//! task range, payload message) into a shared slot, the surviving
//! workers **drain** (they stop claiming new tasks at the next check
//! point), the scope joins normally (no hang, no abort), and the
//! failure is re-raised on the calling thread for the entry-point
//! guard ([`crate::error`]) to convert into an `Err`.  Nested
//! combinators keep the innermost failure.  The same per-task check
//! point runs the fault-injection hooks ([`crate::prims::fault`]) and
//! the cooperative budget ([`crate::prims::budget`]); workers inherit
//! the caller's active budget.
//!
//! Unwind safety: per-worker scratch is built *inside* the catch, so
//! unwinding drops it (a [`ScratchPool`] guard discards — never
//! re-pools — a scratch dropped mid-panic), and outputs written by a
//! failed run are discarded wholesale by the caller.  Static chunks
//! are processed as `MIN_GRAIN`-sized sub-ranges (the documented
//! contract — workers hand their state "to each range" they process)
//! so drain/budget checks stay amortized yet responsive even at one
//! thread.
//!
//! [`PoolError`]: crate::error::PoolError

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::error::{payload_message, raise, ErrorKind, PoolError, Raised};
use crate::prims::{budget, fault};

thread_local! {
    static OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
}

fn env_default() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| match std::env::var("PARBUTTERFLY_THREADS") {
        // Set-but-invalid must not silently fall back to full
        // parallelism: a typo'd sweep would then record full-machine
        // numbers under a 1-thread label.
        Ok(s) => s.parse::<usize>().ok().filter(|&t| t > 0).unwrap_or_else(|| {
            panic!("PARBUTTERFLY_THREADS={s:?} is not a positive integer")
        }),
        Err(_) => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    })
}

/// Number of worker threads parallel combinators will use.
pub fn num_threads() -> usize {
    OVERRIDE.with(|o| o.get()).unwrap_or_else(env_default)
}

/// Run `f` with the thread count pinned to `t` (scoped, re-entrant).
/// The previous count is restored even if `f` unwinds, so a caught
/// entry-point error cannot leak a pinned thread count into later
/// calls on the same thread.
///
/// Benches use this for the thread-sweep figures (Figs. 8/9/17/18).
pub fn with_threads<R>(t: usize, f: impl FnOnce() -> R) -> R {
    assert!(t > 0, "thread count must be positive");
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0.take();
            OVERRIDE.with(|o| o.set(prev));
        }
    }
    let _restore = Restore(OVERRIDE.with(|o| o.replace(Some(t))));
    f()
}

/// Minimum items per spawned chunk; below this we run inline.  Also
/// the sub-range size static chunks are processed in (the drain /
/// fault / budget check amortization quantum).
const MIN_GRAIN: usize = 1024;

/// Shared first-failure slot: the first panicking worker records a
/// structured cause, every worker drains once the flag is up, and the
/// calling thread re-raises after the join.
struct Failure {
    poisoned: AtomicBool,
    slot: Mutex<Option<ErrorKind>>,
}

impl Failure {
    fn new() -> Self {
        Failure { poisoned: AtomicBool::new(false), slot: Mutex::new(None) }
    }

    #[inline]
    fn poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Relaxed)
    }

    fn record(&self, worker: usize, span: (usize, usize), payload: Box<dyn Any + Send>) {
        let kind = match payload.downcast::<Raised>() {
            // A nested combinator (or a budget / fault-injection trip)
            // already attached structure: keep the innermost cause.
            Ok(r) => r.0,
            Err(p) => ErrorKind::Pool(PoolError {
                worker,
                range: span.0..span.1,
                message: payload_message(p.as_ref()),
            }),
        };
        let mut slot = self.slot.lock().unwrap_or_else(|p| p.into_inner());
        if slot.is_none() {
            *slot = Some(kind);
        }
        drop(slot);
        self.poisoned.store(true, Ordering::Release);
    }

    /// After the join: re-raise the first recorded failure (if any)
    /// for the entry-point guard to convert into an `Err`.
    fn rethrow(&self) {
        let kind = self.slot.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(kind) = kind {
            raise(kind);
        }
    }
}

/// Run `body` under the worker-level catch, recording any unwind into
/// `fail` against the task span current at panic time.
fn run_worker(fail: &Failure, worker: usize, span: &Cell<(usize, usize)>, body: impl FnOnce()) {
    if let Err(p) = catch_unwind(AssertUnwindSafe(body)) {
        fail.record(worker, span.get(), p);
    }
}

/// Degenerate sequential path shared by the chunked combinators: one
/// lazily-built state, `step`-sized sub-ranges with the same check
/// points (drain is moot, fault/budget are not) and the same
/// structured-failure surface as the spawned path.
fn inline_run<S, I, F>(n: usize, step: usize, init: I, f: F)
where
    I: Fn() -> S,
    F: Fn(&mut S, std::ops::Range<usize>),
{
    if n == 0 {
        return;
    }
    let fail = Failure::new();
    let span = Cell::new((0, n));
    run_worker(&fail, 0, &span, || {
        let mut state: Option<S> = None;
        let mut pos = 0;
        while pos < n {
            let end = (pos + step).min(n);
            span.set((pos, end));
            fault::on_task();
            budget::check();
            f(state.get_or_insert_with(&init), pos..end);
            pos = end;
        }
    });
    fail.rethrow();
}

/// Parallel loop over `0..n`, static chunking, one chunk per worker.
pub fn parallel_for_chunks<F>(n: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    parallel_for_chunks_with(n, || (), |_, r| f(r));
}

/// Parallel loop over `0..n`, one index at a time (static chunking).
pub fn parallel_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_chunks(n, |r| {
        for i in r {
            f(i)
        }
    });
}

/// [`parallel_for_chunks`] with per-worker state: every worker builds
/// one `S` via `init` and hands it to each range it processes.  Use for
/// reusable scratch (dense counter arrays, wedge buffers) that is too
/// expensive to allocate per range.
pub fn parallel_for_chunks_with<S, I, F>(n: usize, init: I, f: F)
where
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, std::ops::Range<usize>) + Sync,
{
    let t = num_threads();
    if t <= 1 || n < MIN_GRAIN.min(2 * t) {
        inline_run(n, MIN_GRAIN, init, f);
        return;
    }
    let nchunks = t.min(n);
    let chunk = n.div_ceil(nchunks);
    let fail = Failure::new();
    let active = budget::current();
    // Propagate the thread-count override into the spawned workers so
    // nested parallel_for calls see a consistent budget (they run inline:
    // we already used the budget at this level).
    std::thread::scope(|s| {
        for c in 0..nchunks {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let (f, init, fail) = (&f, &init, &fail);
            let ab = active.clone();
            s.spawn(move || {
                OVERRIDE.with(|o| o.set(Some(1)));
                budget::adopt(ab);
                let span = Cell::new((lo, hi));
                run_worker(fail, c, &span, || {
                    let mut state: Option<S> = None;
                    let mut pos = lo;
                    while pos < hi {
                        if fail.poisoned() {
                            return;
                        }
                        let end = (pos + MIN_GRAIN).min(hi);
                        span.set((pos, end));
                        fault::on_task();
                        budget::check();
                        f(state.get_or_insert_with(init), pos..end);
                        pos = end;
                    }
                });
            });
        }
    });
    fail.rethrow();
}

/// Fork-per-block loop for **coarse-grained** block work: each index is
/// a whole block of work (a scan pass over `n/t` items, a sorted run,
/// a parser chunk), so the spawn is always worth it.
///
/// [`parallel_for_chunks`] assumes per-index work is tiny and refuses
/// to fork when `n < min(MIN_GRAIN, 2t)` — the right call for element
/// loops, but block loops pass `n == nblocks ~ t`, which always lands
/// under that threshold and silently serialized every block-level pass
/// (scan, histogram, merge-sort rounds).  This combinator forks
/// whenever more than one worker *and* more than one block exist,
/// assigning each worker a contiguous range of blocks.  Check points
/// (drain / fault / budget) run once per block — blocks are coarse by
/// contract, so a block is never subdivided.
pub fn parallel_for_blocks<F>(nblocks: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let t = num_threads();
    if t <= 1 || nblocks <= 1 {
        if nblocks == 0 {
            return;
        }
        let fail = Failure::new();
        let span = Cell::new((0, nblocks));
        run_worker(&fail, 0, &span, || {
            for b in 0..nblocks {
                span.set((b, b + 1));
                fault::on_task();
                budget::check();
                f(b);
            }
        });
        fail.rethrow();
        return;
    }
    let w = t.min(nblocks);
    let per = nblocks.div_ceil(w);
    let fail = Failure::new();
    let active = budget::current();
    std::thread::scope(|s| {
        for c in 0..w {
            let lo = c * per;
            let hi = ((c + 1) * per).min(nblocks);
            if lo >= hi {
                break;
            }
            let (f, fail) = (&f, &fail);
            let ab = active.clone();
            s.spawn(move || {
                OVERRIDE.with(|o| o.set(Some(1)));
                budget::adopt(ab);
                let span = Cell::new((lo, hi));
                run_worker(fail, c, &span, || {
                    for b in lo..hi {
                        if fail.poisoned() {
                            return;
                        }
                        span.set((b, b + 1));
                        fault::on_task();
                        budget::check();
                        f(b);
                    }
                });
            });
        }
    });
    fail.rethrow();
}

/// Self-scheduling parallel loop: workers claim `grain`-sized ranges
/// from a shared atomic counter.  Use when per-index work is skewed
/// (wedge-aware batching, peeling frontiers).
pub fn parallel_for_dynamic<F>(n: usize, grain: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    parallel_for_dynamic_with(n, grain, || (), |_, r| f(r));
}

/// [`parallel_for_dynamic`] with per-worker state: every worker builds
/// one `S` via `init`, then reuses it across all the grains it claims.
/// This is the scheduling substrate for batching/intersect counting,
/// where each worker owns a dense `n`-slot scratch array that must not
/// be reallocated per claim.
pub fn parallel_for_dynamic_with<S, I, F>(n: usize, grain: usize, init: I, f: F)
where
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, std::ops::Range<usize>) + Sync,
{
    let grain = grain.max(1);
    let t = num_threads();
    if t <= 1 || n <= grain {
        inline_run(n, grain, init, f);
        return;
    }
    let next = AtomicUsize::new(0);
    let fail = Failure::new();
    let active = budget::current();
    std::thread::scope(|s| {
        for w in 0..t.min(n.div_ceil(grain)) {
            let (f, init, next, fail) = (&f, &init, &next, &fail);
            let ab = active.clone();
            s.spawn(move || {
                OVERRIDE.with(|o| o.set(Some(1)));
                budget::adopt(ab);
                let span = Cell::new((0, 0));
                run_worker(fail, w, &span, || {
                    let mut state: Option<S> = None;
                    loop {
                        if fail.poisoned() {
                            return;
                        }
                        let lo = next.fetch_add(grain, Ordering::Relaxed);
                        if lo >= n {
                            break;
                        }
                        let hi = (lo + grain).min(n);
                        span.set((lo, hi));
                        fault::on_task();
                        budget::check();
                        f(state.get_or_insert_with(init), lo..hi);
                    }
                });
            });
        }
    });
    fail.rethrow();
}

/// A reusable bag of per-worker scratch states.
///
/// [`parallel_for_dynamic_with`] builds fresh per-worker state on every
/// call, which is fine for one-shot sweeps but wasteful inside a loop
/// that forks thousands of times (peeling runs one fork-join per
/// round).  A `ScratchPool` owns the states across calls: workers take
/// one on entry (building it only on first use) and return it on exit,
/// so steady-state rounds allocate nothing.  Between calls the caller
/// has exclusive access ([`ScratchPool::items_mut`]) — that is where
/// peeling merges the per-worker delta accumulators.
pub struct ScratchPool<S> {
    pool: Mutex<Vec<S>>,
}

impl<S> Default for ScratchPool<S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S> ScratchPool<S> {
    pub fn new() -> Self {
        Self { pool: Mutex::new(Vec::new()) }
    }

    fn take(&self, make: impl FnOnce() -> S) -> S {
        let reused = self.pool.lock().unwrap_or_else(|p| p.into_inner()).pop();
        reused.unwrap_or_else(make)
    }

    fn put(&self, s: S) {
        self.pool.lock().unwrap_or_else(|p| p.into_inner()).push(s);
    }

    /// Exclusive access to the pooled states (between parallel calls).
    pub fn items_mut(&mut self) -> &mut Vec<S> {
        self.pool.get_mut().unwrap_or_else(|p| p.into_inner())
    }
}

/// Guard returning a pooled scratch on drop (worker exit).
struct PoolGuard<'a, S> {
    s: Option<S>,
    pool: &'a ScratchPool<S>,
}

impl<S> Drop for PoolGuard<'_, S> {
    fn drop(&mut self) {
        if let Some(s) = self.s.take() {
            // A drop during unwinding means the worker died mid-range:
            // the scratch may be mid-mutation (stamps set, touched
            // lists unreset), so discard it — a dirty scratch re-pooled
            // here would corrupt the next round's counts.
            if !std::thread::panicking() {
                self.pool.put(s);
            }
        }
    }
}

/// [`parallel_for_dynamic_with`] drawing per-worker state from `pool`
/// instead of building it fresh: each worker takes a pooled state (or
/// builds one via `init` when the pool runs dry) and returns it when
/// the loop finishes.  The sequential degenerate path reuses one pooled
/// state the same way, so a 1-thread decomposition allocates its
/// scratch exactly once.
pub fn parallel_for_dynamic_pooled<S, I, F>(
    n: usize,
    grain: usize,
    pool: &ScratchPool<S>,
    init: I,
    f: F,
) where
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, std::ops::Range<usize>) + Sync,
{
    parallel_for_dynamic_with(
        n,
        grain,
        || PoolGuard { s: Some(pool.take(&init)), pool },
        |g, r| match g.s.as_mut() {
            Some(s) => f(s, r),
            None => unreachable!("pooled scratch taken"),
        },
    );
}

/// Parallel map producing a `Vec<T>`.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SyncPtr(out.as_mut_ptr());
        parallel_for_chunks(n, |r| {
            for i in r {
                // SAFETY: each index written by exactly one worker.
                unsafe { *slots.get().add(i) = f(i) };
            }
        });
    }
    out
}

/// Parallel reduce: `reduce(map(0), map(1), ...)` with identity `id`.
/// Partials merge in chunk order (not completion order), so the result
/// is identical at every thread count even for merely-associative
/// reductions.
pub fn parallel_reduce<T, M, R>(n: usize, id: T, map: M, reduce: R) -> T
where
    T: Send + Clone,
    M: Fn(usize) -> T + Sync,
    R: Fn(T, T) -> T + Sync + Send,
{
    let t = num_threads();
    if t <= 1 || n < MIN_GRAIN.min(2 * t) {
        let fail = Failure::new();
        let span = Cell::new((0, n));
        let mut out = None;
        run_worker(&fail, 0, &span, || {
            let mut acc = id.clone();
            let mut pos = 0;
            while pos < n {
                let end = (pos + MIN_GRAIN).min(n);
                span.set((pos, end));
                fault::on_task();
                budget::check();
                for i in pos..end {
                    acc = reduce(acc, map(i));
                }
                pos = end;
            }
            out = Some(acc);
        });
        fail.rethrow();
        return out.unwrap_or(id);
    }
    let nchunks = t.min(n);
    let chunk = n.div_ceil(nchunks);
    let fail = Failure::new();
    let active = budget::current();
    let partials: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(nchunks));
    std::thread::scope(|s| {
        for c in 0..nchunks {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let (map, reduce, partials, fail, id) = (&map, &reduce, &partials, &fail, id.clone());
            let ab = active.clone();
            s.spawn(move || {
                OVERRIDE.with(|o| o.set(Some(1)));
                budget::adopt(ab);
                let span = Cell::new((lo, hi));
                run_worker(fail, c, &span, || {
                    let mut acc = id;
                    let mut pos = lo;
                    while pos < hi {
                        if fail.poisoned() {
                            return;
                        }
                        let end = (pos + MIN_GRAIN).min(hi);
                        span.set((pos, end));
                        fault::on_task();
                        budget::check();
                        for i in pos..end {
                            acc = reduce(acc, map(i));
                        }
                        pos = end;
                    }
                    partials.lock().unwrap_or_else(|p| p.into_inner()).push((c, acc));
                });
            });
        }
    });
    fail.rethrow();
    let mut parts = partials.into_inner().unwrap_or_else(|p| p.into_inner());
    parts.sort_by_key(|&(c, _)| c);
    let mut acc = id;
    for (_, p) in parts {
        acc = reduce(acc, p);
    }
    acc
}

/// Shareable raw pointer for disjoint-index parallel writes.
///
/// Accessed through [`SyncPtr::get`] (not the field) so that edition-2021
/// closures capture the `Sync` wrapper, not the raw pointer inside.
pub(crate) struct SyncPtr<T>(pub *mut T);
unsafe impl<T> Sync for SyncPtr<T> {}
unsafe impl<T> Send for SyncPtr<T> {}

impl<T> SyncPtr<T> {
    #[inline(always)]
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::catch;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        for t in [1, 2, 4, 7] {
            with_threads(t, || {
                let n = 10_000;
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                parallel_for(n, |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            });
        }
    }

    #[test]
    fn blocks_visit_every_block_once_even_when_nblocks_equals_threads() {
        // The regression this combinator exists for: nblocks == t used
        // to fall under parallel_for_chunks' spawn threshold.
        for t in [1, 2, 4, 8] {
            with_threads(t, || {
                for nblocks in [1usize, t, 2 * t + 1] {
                    let hits: Vec<AtomicU64> = (0..nblocks).map(|_| AtomicU64::new(0)).collect();
                    parallel_for_blocks(nblocks, |b| {
                        hits[b].fetch_add(1, Ordering::Relaxed);
                    });
                    assert!(
                        hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                        "t={t} nblocks={nblocks}"
                    );
                }
            });
        }
        parallel_for_blocks(0, |_| panic!("must not be called"));
    }

    #[test]
    fn dynamic_visits_every_index_once() {
        for t in [1, 3, 8] {
            with_threads(t, || {
                let n = 5_000;
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                parallel_for_dynamic(n, 64, |r| {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            });
        }
    }

    #[test]
    fn map_and_reduce_agree_with_sequential() {
        for t in [1, 2, 5] {
            with_threads(t, || {
                let v = parallel_map(1000, |i| (i * i) as u64);
                assert_eq!(v.len(), 1000);
                assert_eq!(v[999], 999 * 999);
                let s = parallel_reduce(1000, 0u64, |i| i as u64, |a, b| a + b);
                assert_eq!(s, 999 * 1000 / 2);
            });
        }
    }

    #[test]
    fn with_threads_scopes_and_restores() {
        let outer = num_threads();
        with_threads(3, || {
            assert_eq!(num_threads(), 3);
            with_threads(2, || assert_eq!(num_threads(), 2));
            assert_eq!(num_threads(), 3);
        });
        assert_eq!(num_threads(), outer);
    }

    #[test]
    fn with_threads_restores_across_unwinds() {
        let outer = num_threads();
        let r = catch(|| {
            with_threads(3, || -> () { panic!("die inside the scope") });
        });
        assert!(r.is_err());
        assert_eq!(num_threads(), outer, "override leaked past a panic");
    }

    #[test]
    fn pooled_scratch_visits_every_index_and_recycles() {
        for t in [1usize, 3, 8] {
            with_threads(t, || {
                let mut pool: ScratchPool<Vec<u64>> = ScratchPool::new();
                let n = 4_000;
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                // Two rounds over the same pool: the second must reuse
                // the first round's scratches (pool never exceeds the
                // worker count).
                for _round in 0..2 {
                    parallel_for_dynamic_pooled(
                        n,
                        64,
                        &pool,
                        || vec![0u64; 8],
                        |s, r| {
                            s[0] += r.len() as u64;
                            for i in r {
                                hits[i].fetch_add(1, Ordering::Relaxed);
                            }
                        },
                    );
                    assert!(!pool.items_mut().is_empty(), "scratch returned to pool");
                    assert!(pool.items_mut().len() <= t, "at most one scratch per worker");
                }
                assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 2));
                let total: u64 = pool.items_mut().iter().map(|s| s[0]).sum();
                assert_eq!(total, 2 * n as u64, "per-scratch tallies cover every index");
            });
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        parallel_for(0, |_| panic!("must not be called"));
        parallel_for_dynamic(0, 16, |_| panic!("must not be called"));
        let v = parallel_map(1, |i| i);
        assert_eq!(v, vec![0]);
    }

    #[test]
    fn worker_panics_surface_as_structured_pool_errors() {
        for t in [1usize, 4, 8] {
            with_threads(t, || {
                let e = catch(|| {
                    parallel_for(5_000, |i| {
                        if i == 1700 {
                            panic!("task bug at {i}")
                        }
                    })
                })
                .unwrap_err();
                match e.kind() {
                    ErrorKind::Pool(p) => {
                        assert!(p.message.contains("task bug at 1700"), "t={t}: {p}");
                        assert!(p.range.start <= 1700 && 1700 < p.range.end + MIN_GRAIN);
                    }
                    k => panic!("t={t}: unexpected kind {k:?}"),
                }
                // The combinator is reusable after a caught failure.
                let hits = AtomicU64::new(0);
                parallel_for(100, |_| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
                assert_eq!(hits.load(Ordering::Relaxed), 100);
            });
        }
    }

    #[test]
    fn dynamic_and_blocks_panics_are_caught_and_drained() {
        for t in [1usize, 4, 8] {
            with_threads(t, || {
                let e = catch(|| {
                    parallel_for_dynamic(2_000, 32, |r| {
                        if r.contains(&999) {
                            panic!("dyn bug")
                        }
                    })
                })
                .unwrap_err();
                assert!(matches!(e.kind(), ErrorKind::Pool(_)), "t={t}: {e}");
                let e = catch(|| {
                    parallel_for_blocks(2 * t + 1, |b| {
                        if b == t {
                            panic!("block bug")
                        }
                    })
                })
                .unwrap_err();
                assert!(matches!(e.kind(), ErrorKind::Pool(_)), "t={t}: {e}");
                let e = catch(|| {
                    parallel_reduce(5_000, 0u64, |i| if i == 700 { panic!("red bug") } else { 1 }, |a, b| a + b)
                })
                .unwrap_err();
                assert!(matches!(e.kind(), ErrorKind::Pool(_)), "t={t}: {e}");
            });
        }
    }

    #[test]
    fn nested_combinators_keep_the_innermost_failure() {
        for t in [1usize, 4] {
            with_threads(t, || {
                let e = catch(|| {
                    parallel_for_blocks(t.max(2), |b| {
                        parallel_for(2_000, |i| {
                            if b == 0 && i == 3 {
                                panic!("inner bug")
                            }
                        });
                    })
                })
                .unwrap_err();
                match e.kind() {
                    ErrorKind::Pool(p) => assert!(p.message.contains("inner bug"), "t={t}: {p}"),
                    k => panic!("t={t}: unexpected kind {k:?}"),
                }
            });
        }
    }

    #[test]
    fn panicked_scratch_is_discarded_not_repooled() {
        with_threads(1, || {
            let mut pool: ScratchPool<Vec<u64>> = ScratchPool::new();
            parallel_for_dynamic_pooled(100, 16, &pool, || vec![0u64; 4], |_, _| {});
            assert_eq!(pool.items_mut().len(), 1);
            let r = catch(|| {
                parallel_for_dynamic_pooled(
                    100,
                    16,
                    &pool,
                    || vec![0u64; 4],
                    |s, r| {
                        s[1] = 77; // dirty the scratch, then die
                        if r.start >= 32 {
                            panic!("mid-round death")
                        }
                    },
                );
            });
            assert!(r.is_err());
            assert!(
                pool.items_mut().is_empty(),
                "a scratch dropped mid-panic must not be re-pooled"
            );
        });
    }
}
