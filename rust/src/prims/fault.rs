//! Deterministic fault injection for the parallel runtime.
//!
//! A [`FaultPlan`] names up to three single-shot faults, addressed by
//! global event ordinals so a plan means the same thing at every
//! thread count:
//!
//! * `panic@task=K` — the K-th task claimed by any pool combinator
//!   (0-based, counted across the whole process run) panics with a
//!   plain string payload, exercising the worker `catch_unwind` path
//!   exactly like a real bug would;
//! * `delay@task=J:MS` — the J-th claimed task sleeps `MS`
//!   milliseconds first (stragglers must not change results or hang
//!   the drain logic);
//! * `fail@alloc=N` — the N-th allocation probe
//!   ([`budget::probe_alloc`](crate::prims::budget::probe_alloc))
//!   unwinds with [`ErrorKind::AllocFailed`], simulating an
//!   out-of-memory scratch allocation.
//!
//! Enable a plan process-wide with `PARBUTTERFLY_FAULT=<spec>` (a
//! comma-separated list of the directives above; a malformed spec
//! panics rather than silently running fault-free), or scoped in tests
//! with [`with_plan`], which serializes plan-holding tests behind a
//! global lock and restores the previous plan afterwards.
//!
//! When no plan is installed the hooks are a single relaxed atomic
//! load — cheap enough to sit on every task claim of every hot loop.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, Once};
use std::time::Duration;

use crate::error::{raise, ErrorKind};

/// Sentinel for "directive not set" in the atomic plan slots.
const OFF: u64 = u64::MAX;

/// Fast path: false means every hook returns immediately.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// The installed plan, flattened into atomics so hooks stay lock-free.
static PANIC_AT: AtomicU64 = AtomicU64::new(OFF);
static DELAY_AT: AtomicU64 = AtomicU64::new(OFF);
static DELAY_MS: AtomicU64 = AtomicU64::new(0);
static ALLOC_AT: AtomicU64 = AtomicU64::new(OFF);

/// Global event ordinals (reset when a plan is installed).
static TASKS: AtomicU64 = AtomicU64::new(0);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Serializes [`with_plan`] callers (the plan is process-global).
static TEST_LOCK: Mutex<()> = Mutex::new(());

/// A deterministic single-shot fault plan; see the module docs for the
/// directive semantics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic the k-th claimed task.
    pub panic_task: Option<u64>,
    /// Delay the j-th claimed task by the given milliseconds.
    pub delay_task: Option<(u64, u64)>,
    /// Fail the n-th allocation probe.
    pub fail_alloc: Option<u64>,
}

impl FaultPlan {
    /// Plan that panics the `k`-th claimed task.
    pub fn panic_at_task(k: u64) -> Self {
        FaultPlan { panic_task: Some(k), ..Default::default() }
    }

    /// Plan that delays the `j`-th claimed task by `ms` milliseconds.
    pub fn delay_at_task(j: u64, ms: u64) -> Self {
        FaultPlan { delay_task: Some((j, ms)), ..Default::default() }
    }

    /// Plan that fails the `n`-th allocation probe.
    pub fn fail_at_alloc(n: u64) -> Self {
        FaultPlan { fail_alloc: Some(n), ..Default::default() }
    }

    /// Derive a panic-task plan from a seed: a cheap splitmix step maps
    /// the seed onto `0..max_task`, so test sweeps cover the task space
    /// without hand-picking ordinals.
    pub fn seeded_panic(seed: u64, max_task: u64) -> Self {
        let mut z = seed.wrapping_add(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^= z >> 31;
        Self::panic_at_task(z % max_task.max(1))
    }

    /// Parse a `PARBUTTERFLY_FAULT` spec: comma-separated
    /// `panic@task=K` / `delay@task=J:MS` / `fail@alloc=N` directives.
    /// Strict: an unknown directive or malformed number is an error
    /// naming the offending part.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(k) = part.strip_prefix("panic@task=") {
                plan.panic_task =
                    Some(k.parse().map_err(|_| format!("bad task ordinal in {part:?}"))?);
            } else if let Some(rest) = part.strip_prefix("delay@task=") {
                let (j, ms) = rest
                    .split_once(':')
                    .ok_or_else(|| format!("{part:?} needs the form delay@task=J:MS"))?;
                plan.delay_task = Some((
                    j.parse().map_err(|_| format!("bad task ordinal in {part:?}"))?,
                    ms.parse().map_err(|_| format!("bad delay millis in {part:?}"))?,
                ));
            } else if let Some(n) = part.strip_prefix("fail@alloc=") {
                plan.fail_alloc =
                    Some(n.parse().map_err(|_| format!("bad alloc ordinal in {part:?}"))?);
            } else {
                return Err(format!(
                    "{part:?} is not a fault directive \
                     (panic@task=K | delay@task=J:MS | fail@alloc=N)"
                ));
            }
        }
        Ok(plan)
    }

    fn is_empty(&self) -> bool {
        self.panic_task.is_none() && self.delay_task.is_none() && self.fail_alloc.is_none()
    }
}

/// Snapshot of the installed atomics, for save/restore in [`with_plan`].
fn snapshot() -> (bool, u64, u64, u64, u64) {
    (
        ENABLED.load(Ordering::SeqCst),
        PANIC_AT.load(Ordering::SeqCst),
        DELAY_AT.load(Ordering::SeqCst),
        DELAY_MS.load(Ordering::SeqCst),
        ALLOC_AT.load(Ordering::SeqCst),
    )
}

/// Flatten `plan` into the atomic slots and reset the event ordinals.
fn install(plan: &FaultPlan) {
    PANIC_AT.store(plan.panic_task.unwrap_or(OFF), Ordering::SeqCst);
    let (j, ms) = plan.delay_task.unwrap_or((OFF, 0));
    DELAY_AT.store(j, Ordering::SeqCst);
    DELAY_MS.store(ms, Ordering::SeqCst);
    ALLOC_AT.store(plan.fail_alloc.unwrap_or(OFF), Ordering::SeqCst);
    TASKS.store(0, Ordering::SeqCst);
    ALLOCS.store(0, Ordering::SeqCst);
    ENABLED.store(!plan.is_empty(), Ordering::SeqCst);
}

/// Parse `PARBUTTERFLY_FAULT` (once) and install it.  A set-but-
/// malformed spec panics: a typo'd CI plan must not silently run the
/// fault leg fault-free.
fn env_init() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        if let Ok(spec) = std::env::var("PARBUTTERFLY_FAULT") {
            match FaultPlan::parse(&spec) {
                Ok(plan) => install(&plan),
                Err(e) => panic!("PARBUTTERFLY_FAULT={spec:?}: {e}"),
            }
        }
    });
}

/// True when a fault plan (env or [`with_plan`]) is currently armed.
pub fn active() -> bool {
    env_init();
    ENABLED.load(Ordering::Relaxed)
}

/// Run `f` with `plan` installed, restoring the previous plan (usually
/// none) afterwards — even if `f` panics.  Plan-holding callers are
/// serialized behind a global lock, so concurrent tests cannot see
/// each other's faults.
pub fn with_plan<R>(plan: &FaultPlan, f: impl FnOnce() -> R) -> R {
    env_init();
    let _lock: MutexGuard<'_, ()> = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let prev = snapshot();
    install(plan);
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
    ENABLED.store(prev.0, Ordering::SeqCst);
    PANIC_AT.store(prev.1, Ordering::SeqCst);
    DELAY_AT.store(prev.2, Ordering::SeqCst);
    DELAY_MS.store(prev.3, Ordering::SeqCst);
    ALLOC_AT.store(prev.4, Ordering::SeqCst);
    match out {
        Ok(r) => r,
        Err(p) => std::panic::resume_unwind(p),
    }
}

/// Task-claim hook, called by the pool once per claimed task range.
/// May sleep (delay directive) or panic (panic directive).
#[inline]
pub(crate) fn on_task() {
    env_init();
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let t = TASKS.fetch_add(1, Ordering::Relaxed);
    if t == DELAY_AT.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(DELAY_MS.load(Ordering::Relaxed)));
    }
    if t == PANIC_AT.load(Ordering::Relaxed) {
        panic!("injected fault: panic at task {t}");
    }
}

/// Allocation-probe hook, called by
/// [`budget::probe_alloc`](crate::prims::budget::probe_alloc).
#[inline]
pub(crate) fn on_alloc(bytes: usize, what: &'static str) {
    env_init();
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let a = ALLOCS.fetch_add(1, Ordering::Relaxed);
    if a == ALLOC_AT.load(Ordering::Relaxed) {
        raise(ErrorKind::AllocFailed { bytes, what });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::catch;

    #[test]
    fn specs_parse_strictly() {
        let p = FaultPlan::parse("panic@task=3, delay@task=5:20,fail@alloc=2").unwrap();
        assert_eq!(p.panic_task, Some(3));
        assert_eq!(p.delay_task, Some((5, 20)));
        assert_eq!(p.fail_alloc, Some(2));
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        for bad in ["panic@task=x", "delay@task=5", "nonsense", "fail@alloc="] {
            let e = FaultPlan::parse(bad).unwrap_err();
            assert!(e.contains(bad.split('=').next().unwrap_or(bad)), "{bad} -> {e}");
        }
    }

    #[test]
    fn panic_directive_fires_exactly_once() {
        with_plan(&FaultPlan::panic_at_task(1), || {
            on_task(); // task 0: clean
            let e = catch(on_task).unwrap_err(); // task 1: injected
            assert!(format!("{e}").contains("injected fault"));
            on_task(); // task 2: clean again (single shot)
        });
        on_task(); // plan restored to none
    }

    #[test]
    fn alloc_directive_raises_structured_kind() {
        with_plan(&FaultPlan::fail_at_alloc(0), || {
            let e = catch(|| on_alloc(128, "scratch")).unwrap_err();
            assert_eq!(e.kind(), &ErrorKind::AllocFailed { bytes: 128, what: "scratch" });
            on_alloc(64, "later"); // single shot
        });
    }

    #[test]
    fn with_plan_restores_after_inner_panic() {
        let r = catch(|| {
            with_plan(&FaultPlan::panic_at_task(0), || {
                on_task();
            })
        });
        assert!(r.is_err());
        assert!(!active(), "plan must be uninstalled after the unwind");
    }

    #[test]
    fn seeded_plans_land_in_range() {
        for seed in 0..50 {
            let p = FaultPlan::seeded_panic(seed, 7);
            assert!(p.panic_task.unwrap() < 7);
        }
    }
}
