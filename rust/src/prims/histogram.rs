//! Parallel histogram (Dhulipala–Blelloch–Shun style).
//!
//! Counts occurrences of `u64` keys by hash-partitioning keys into
//! `O(#workers)` buckets (pass 1: per-worker bucket counts + scatter),
//! then counting within each bucket in parallel with a local open-address
//! table.  Matches the semisort work/span bound but trades the full sort
//! for two scatter passes — the paper's `Hist` aggregation option.

use std::collections::HashMap;

use super::pool::{num_threads, parallel_for_blocks, SyncPtr};
use super::rng::hash64;
use super::scan::prefix_sum;

/// Count key multiplicities; returns `(key, count)` pairs (unordered
/// across buckets, grouped within).
pub fn histogram(keys: &[u64]) -> Vec<(u64, u64)> {
    let n = keys.len();
    if n == 0 {
        return Vec::new();
    }
    let t = num_threads();
    if t <= 1 || n < 8192 {
        let mut m: HashMap<u64, u64> = HashMap::with_capacity(n.min(1 << 16));
        for &k in keys {
            *m.entry(k).or_insert(0) += 1;
        }
        return m.into_iter().collect();
    }
    let nbuckets = (4 * t).next_power_of_two();
    let bmask = (nbuckets - 1) as u64;
    let nblocks = t;
    let block = n.div_ceil(nblocks);
    // Pass 1: per-(block, bucket) counts.
    let mut counts = vec![0usize; nblocks * nbuckets];
    {
        let cp = SyncPtr(counts.as_mut_ptr());
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let base = b * nbuckets;
            for i in lo..hi {
                let bk = (hash64(keys[i]) & bmask) as usize;
                unsafe { *cp.get().add(base + bk) += 1 };
            }
        });
    }
    // Column-major offsets so each bucket's slots are contiguous.
    let mut col = vec![0usize; nblocks * nbuckets];
    for bk in 0..nbuckets {
        for b in 0..nblocks {
            col[bk * nblocks + b] = counts[b * nbuckets + bk];
        }
    }
    let (offsets, _) = prefix_sum(&col);
    // Pass 2: scatter keys into bucket-contiguous scratch.
    let mut scratch = vec![0u64; n];
    {
        let sp = SyncPtr(scratch.as_mut_ptr());
        let offsets = &offsets;
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let mut cursor: Vec<usize> =
                (0..nbuckets).map(|bk| offsets[bk * nblocks + b]).collect();
            for i in lo..hi {
                let bk = (hash64(keys[i]) & bmask) as usize;
                unsafe { *sp.get().add(cursor[bk]) = keys[i] };
                cursor[bk] += 1;
            }
        });
    }
    // Pass 3: count within each bucket in parallel.
    let bucket_start: Vec<usize> = (0..nbuckets).map(|bk| offsets[bk * nblocks]).collect();
    let out = std::sync::Mutex::new(Vec::with_capacity(n / 4));
    parallel_for_blocks(nbuckets, |bk| {
        let lo = bucket_start[bk];
        let hi = if bk + 1 < nbuckets { bucket_start[bk + 1] } else { n };
        if lo >= hi {
            return;
        }
        let mut m: HashMap<u64, u64> = HashMap::with_capacity((hi - lo).min(1 << 14));
        for &k in &scratch[lo..hi] {
            *m.entry(k).or_insert(0) += 1;
        }
        let local: Vec<(u64, u64)> = m.into_iter().collect();
        // As in `CountTable::drain`: recover the collector guard even
        // if another worker's panic poisoned it mid-drain.
        out.lock().unwrap_or_else(|p| p.into_inner()).extend(local);
    });
    out.into_inner().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::pool::with_threads;
    use crate::prims::rng::Pcg32;

    fn model(keys: &[u64]) -> Vec<(u64, u64)> {
        let mut m: HashMap<u64, u64> = HashMap::new();
        for &k in keys {
            *m.entry(k).or_insert(0) += 1;
        }
        let mut v: Vec<(u64, u64)> = m.into_iter().collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn histogram_matches_model() {
        let mut r = Pcg32::new(21);
        for &n in &[0usize, 1, 100, 9000, 40_000] {
            let keys: Vec<u64> = (0..n).map(|_| r.next_below(777)).collect();
            for t in [1, 2, 4] {
                with_threads(t, || {
                    let mut h = histogram(&keys);
                    h.sort_unstable();
                    assert_eq!(h, model(&keys), "n={n} t={t}");
                });
            }
        }
    }

    #[test]
    fn skewed_keys() {
        with_threads(4, || {
            let mut keys = vec![42u64; 50_000];
            keys.extend(0..100u64);
            let mut h = histogram(&keys);
            h.sort_unstable();
            // keys 0..100 already include 42, so 100 distinct keys total.
            assert_eq!(h.len(), 100);
            assert!(h.contains(&(42, 50_001)));
        });
    }
}
