//! Parallel sorting.
//!
//! * [`par_sort`] / [`par_sort_by_key`] — parallel merge sort: split into
//!   per-worker runs, sort each with the std unstable sort, then merge
//!   runs pairwise in parallel rounds.  `O(n log n)` work, `O(log^2 n)`-ish
//!   span; the paper uses PBBS sample sort for the same role (wedge
//!   aggregation by sorting).
//! * [`radix_sort_u64`] — LSD radix sort (8-bit digits) for dense `u64`
//!   keys; used by semisort when the key universe is known to be packed.

use super::pool::{num_threads, parallel_for_chunks, with_threads, SyncPtr};

/// Sort a vector in parallel (unstable within equal keys).
pub fn par_sort<T: Ord + Clone + Send + Sync>(v: &mut Vec<T>) {
    par_sort_by_key(v, |x| x.clone());
}

/// Sort by an extracted key in parallel (unstable within equal keys).
pub fn par_sort_by_key<T, K, F>(v: &mut Vec<T>, key: F)
where
    T: Clone + Send + Sync,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    let n = v.len();
    let t = num_threads();
    if t <= 1 || n < 8192 {
        v.sort_unstable_by(|a, b| key(a).cmp(&key(b)));
        return;
    }
    let nruns = t.next_power_of_two().min(n);
    let run = n.div_ceil(nruns);
    // Sort runs in parallel.
    {
        let base = SyncPtr(v.as_mut_ptr());
        let key = &key;
        parallel_for_chunks(nruns, |r| {
            for b in r {
                let lo = b * run;
                let hi = ((b + 1) * run).min(n);
                if lo < hi {
                    // SAFETY: runs are disjoint slices of v.
                    let s = unsafe { std::slice::from_raw_parts_mut(base.get().add(lo), hi - lo) };
                    s.sort_unstable_by(|a, b| key(a).cmp(&key(b)));
                }
            }
        });
    }
    // Merge runs pairwise, ping-ponging between v and a scratch buffer.
    let mut src: Vec<T> = v.clone();
    let mut dst: Vec<T> = v.clone();
    let mut width = run;
    let mut rounds = 0usize;
    while width < n {
        let npairs = n.div_ceil(2 * width);
        {
            let dp = SyncPtr(dst.as_mut_ptr());
            let src = &src;
            let key = &key;
            parallel_for_chunks(npairs, |r| {
                for p in r {
                    let lo = p * 2 * width;
                    let mid = (lo + width).min(n);
                    let hi = (lo + 2 * width).min(n);
                    merge_into(&src[lo..mid], &src[mid..hi], key, unsafe {
                        std::slice::from_raw_parts_mut(dp.get().add(lo), hi - lo)
                    });
                }
            });
        }
        std::mem::swap(&mut src, &mut dst);
        width *= 2;
        rounds += 1;
    }
    if rounds > 0 {
        *v = src;
    }
}

fn merge_into<T: Clone, K: Ord>(a: &[T], b: &[T], key: &(impl Fn(&T) -> K + ?Sized), out: &mut [T]) {
    let (mut i, mut j, mut w) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        if key(&a[i]) <= key(&b[j]) {
            out[w] = a[i].clone();
            i += 1;
        } else {
            out[w] = b[j].clone();
            j += 1;
        }
        w += 1;
    }
    while i < a.len() {
        out[w] = a[i].clone();
        i += 1;
        w += 1;
    }
    while j < b.len() {
        out[w] = b[j].clone();
        j += 1;
        w += 1;
    }
}

/// LSD radix sort of `u64` keys, 8 bits per pass, skipping dead digits.
///
/// Sequential per pass but cache-friendly; used for packed wedge keys
/// whose high bits are zero (then only a few passes run).
pub fn radix_sort_u64(v: &mut Vec<u64>) {
    let n = v.len();
    if n < 2 {
        return;
    }
    let max = with_threads(num_threads(), || v.iter().copied().max().unwrap_or(0));
    let buf = vec![0u64; n];
    let mut shift = 0u32;
    let mut src_is_v = true;
    while shift < 64 && (max >> shift) != 0 {
        let mut counts = [0usize; 256];
        {
            let src: &[u64] = if src_is_v { v } else { &buf };
            for &x in src {
                counts[((x >> shift) & 0xff) as usize] += 1;
            }
            let mut acc = 0usize;
            let mut offsets = [0usize; 256];
            for d in 0..256 {
                offsets[d] = acc;
                acc += counts[d];
            }
            let dst_ptr = if src_is_v { buf.as_ptr() as *mut u64 } else { v.as_ptr() as *mut u64 };
            for &x in src {
                let d = ((x >> shift) & 0xff) as usize;
                unsafe { *dst_ptr.add(offsets[d]) = x };
                offsets[d] += 1;
            }
        }
        src_is_v = !src_is_v;
        shift += 8;
    }
    if !src_is_v {
        v.copy_from_slice(&buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::pool::with_threads;
    use crate::prims::rng::Pcg32;

    fn random_vec(n: usize, seed: u64) -> Vec<u64> {
        let mut r = Pcg32::new(seed);
        (0..n).map(|_| r.next_u64() % 1_000_000).collect()
    }

    #[test]
    fn par_sort_matches_std() {
        for t in [1, 2, 4] {
            with_threads(t, || {
                for n in [0, 1, 5, 100, 8192, 50_000] {
                    let mut v = random_vec(n, 42 + n as u64);
                    let mut expect = v.clone();
                    expect.sort_unstable();
                    par_sort(&mut v);
                    assert_eq!(v, expect, "n={n} t={t}");
                }
            });
        }
    }

    #[test]
    fn par_sort_by_key_reverse() {
        with_threads(4, || {
            let mut v: Vec<u64> = random_vec(20_000, 7);
            par_sort_by_key(&mut v, |x| u64::MAX - *x);
            for w in v.windows(2) {
                assert!(w[0] >= w[1]);
            }
        });
    }

    #[test]
    fn radix_matches_std() {
        for n in [0, 1, 3, 1000, 30_000] {
            let mut v = random_vec(n, 9 + n as u64);
            let mut expect = v.clone();
            expect.sort_unstable();
            radix_sort_u64(&mut v);
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn radix_high_bits() {
        let mut v = vec![u64::MAX, 0, 1 << 63, 42, u64::MAX - 1];
        let mut expect = v.clone();
        expect.sort_unstable();
        radix_sort_u64(&mut v);
        assert_eq!(v, expect);
    }
}
