//! Parallel sorting.
//!
//! * [`par_sort`] / [`par_sort_by_key`] — parallel merge sort: split into
//!   per-worker runs, sort each with the std unstable sort, then merge
//!   runs pairwise in parallel rounds.  `O(n log n)` work, `O(log^2 n)`-ish
//!   span; the paper uses PBBS sample sort for the same role (wedge
//!   aggregation by sorting).  Rounds ping-pong between the input and a
//!   single uninitialized scratch buffer, moving elements bitwise — no
//!   per-round clones and only one `n`-slot allocation.
//! * [`radix_sort_u64`] — LSD radix sort (8-bit digits) for dense `u64`
//!   keys; used by semisort when the key universe is known to be packed.

use super::pool::{num_threads, parallel_for_blocks, with_threads, SyncPtr};

/// Sort a vector in parallel (unstable within equal keys).
pub fn par_sort<T: Ord + Clone + Send + Sync>(v: &mut Vec<T>) {
    par_sort_by_key(v, |x| x.clone());
}

/// Sort by an extracted key in parallel (unstable within equal keys).
pub fn par_sort_by_key<T, K, F>(v: &mut Vec<T>, key: F)
where
    T: Clone + Send + Sync,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    let n = v.len();
    let t = num_threads();
    if t <= 1 || n < 8192 {
        v.sort_unstable_by(|a, b| key(a).cmp(&key(b)));
        return;
    }
    let nruns = t.next_power_of_two().min(n);
    let run = n.div_ceil(nruns);
    // Sort runs in parallel.
    {
        let base = SyncPtr(v.as_mut_ptr());
        let key = &key;
        parallel_for_blocks(nruns, |b| {
            let lo = b * run;
            let hi = ((b + 1) * run).min(n);
            if lo < hi {
                // SAFETY: runs are disjoint slices of v.
                let s = unsafe { std::slice::from_raw_parts_mut(base.get().add(lo), hi - lo) };
                s.sort_unstable_by(|a, b| key(a).cmp(&key(b)));
            }
        });
    }
    // Merge runs pairwise, ping-ponging between v and ONE uninitialized
    // scratch buffer (`with_capacity`, length kept at 0 so drops never
    // see its slots).  Elements are *moved* bitwise between the two
    // buffers with `ptr::read`/`ptr::write` — no clones, and every
    // round relocates all `n` elements, so after an odd number of
    // rounds the data lives in the scratch and is copied back once.
    // Panic safety: while the rounds run, *neither* Vec owns elements
    // (`v`'s length is parked at 0, the scratch's never leaves 0), so
    // a user `key` panic can only leak the elements — it can never
    // double-drop one whose bits sit in two slots mid-merge.
    let mut scratch: Vec<T> = Vec::with_capacity(n);
    let vp = SyncPtr(v.as_mut_ptr());
    let sp = SyncPtr(scratch.as_mut_ptr());
    // SAFETY: length restored to `n` after the rounds; the allocation
    // is untouched (raw-pointer writes only, no push/reserve).
    unsafe { v.set_len(0) };
    let mut width = run;
    let mut in_v = true;
    while width < n {
        let npairs = n.div_ceil(2 * width);
        {
            let (srcp, dstp) = if in_v { (&vp, &sp) } else { (&sp, &vp) };
            let key = &key;
            parallel_for_blocks(npairs, |p| {
                let lo = p * 2 * width;
                let mid = (lo + width).min(n);
                let hi = (lo + 2 * width).min(n);
                // SAFETY: pairs tile 0..n disjointly; src slots were
                // fully written by the previous round (or are v's
                // initial contents) and dst slots are exclusively
                // ours this round.
                unsafe {
                    merge_moves(srcp.get().add(lo), mid - lo, hi - mid, key, dstp.get().add(lo))
                };
            });
        }
        in_v = !in_v;
        width *= 2;
    }
    if !in_v {
        // Odd round count: the fully merged data sits in the scratch.
        // SAFETY: both buffers hold >= n slots and do not overlap.
        unsafe { std::ptr::copy_nonoverlapping(sp.get(), vp.get(), n) };
    }
    // SAFETY: every slot of v[0..n] holds an initialized element again
    // (each round rewrites the full prefix; the copy above covers the
    // odd case), so v may resume ownership.
    unsafe { v.set_len(n) };
    // `scratch` drops here with len 0: capacity freed, no element drops
    // (its bits are either stale or bitwise-duplicated into `v`).
}

/// Merge the sorted runs `src[0..alen]` and `src[alen..alen+blen]` into
/// `dst[0..alen+blen]` by *moving* elements (bitwise reads/writes).
///
/// # Safety
/// `src` must hold `alen + blen` initialized elements, `dst` must have
/// room for as many, and the two ranges must not overlap.
unsafe fn merge_moves<T, K: Ord>(
    src: *const T,
    alen: usize,
    blen: usize,
    key: &(impl Fn(&T) -> K + ?Sized),
    dst: *mut T,
) {
    let (mut i, mut j, mut w) = (0, alen, 0);
    let bend = alen + blen;
    while i < alen && j < bend {
        let take_a = key(&*src.add(i)) <= key(&*src.add(j));
        let from = if take_a { &mut i } else { &mut j };
        std::ptr::write(dst.add(w), std::ptr::read(src.add(*from)));
        *from += 1;
        w += 1;
    }
    if i < alen {
        std::ptr::copy_nonoverlapping(src.add(i), dst.add(w), alen - i);
        w += alen - i;
    }
    if j < bend {
        std::ptr::copy_nonoverlapping(src.add(j), dst.add(w), bend - j);
        w += bend - j;
    }
    debug_assert_eq!(w, bend);
}

/// LSD radix sort of `u64` keys, 8 bits per pass, skipping dead digits.
///
/// Sequential per pass but cache-friendly; used for packed wedge keys
/// whose high bits are zero (then only a few passes run).
pub fn radix_sort_u64(v: &mut Vec<u64>) {
    let n = v.len();
    if n < 2 {
        return;
    }
    let max = with_threads(num_threads(), || v.iter().copied().max().unwrap_or(0));
    let buf = vec![0u64; n];
    let mut shift = 0u32;
    let mut src_is_v = true;
    while shift < 64 && (max >> shift) != 0 {
        let mut counts = [0usize; 256];
        {
            let src: &[u64] = if src_is_v { v } else { &buf };
            for &x in src {
                counts[((x >> shift) & 0xff) as usize] += 1;
            }
            let mut acc = 0usize;
            let mut offsets = [0usize; 256];
            for d in 0..256 {
                offsets[d] = acc;
                acc += counts[d];
            }
            let dst_ptr = if src_is_v { buf.as_ptr() as *mut u64 } else { v.as_ptr() as *mut u64 };
            for &x in src {
                let d = ((x >> shift) & 0xff) as usize;
                unsafe { *dst_ptr.add(offsets[d]) = x };
                offsets[d] += 1;
            }
        }
        src_is_v = !src_is_v;
        shift += 8;
    }
    if !src_is_v {
        v.copy_from_slice(&buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::pool::with_threads;
    use crate::prims::rng::Pcg32;

    fn random_vec(n: usize, seed: u64) -> Vec<u64> {
        let mut r = Pcg32::new(seed);
        (0..n).map(|_| r.next_u64() % 1_000_000).collect()
    }

    #[test]
    fn par_sort_matches_std() {
        for t in [1, 2, 4] {
            with_threads(t, || {
                for n in [0, 1, 5, 100, 8192, 50_000] {
                    let mut v = random_vec(n, 42 + n as u64);
                    let mut expect = v.clone();
                    expect.sort_unstable();
                    par_sort(&mut v);
                    assert_eq!(v, expect, "n={n} t={t}");
                }
            });
        }
    }

    #[test]
    fn par_sort_by_key_reverse() {
        with_threads(4, || {
            let mut v: Vec<u64> = random_vec(20_000, 7);
            par_sort_by_key(&mut v, |x| u64::MAX - *x);
            for w in v.windows(2) {
                assert!(w[0] >= w[1]);
            }
        });
    }

    #[test]
    fn ping_pong_parity_odd_and_even_merge_rounds() {
        // The merge loop runs exactly log2(next_power_of_two(t)) rounds
        // on large inputs: t=2 -> 1 round (odd: the merged data ends in
        // the scratch and must be copied back), t=4 -> 2 rounds (even:
        // it ends in `v`), t=7 -> 8 runs -> 3 rounds (odd again).  All
        // parities must produce the identical sorted output.
        for t in [2usize, 4, 7, 8] {
            with_threads(t, || {
                for n in [8192usize, 10_000, 65_536, 100_001] {
                    let mut v = random_vec(n, 1000 + (t * n) as u64);
                    let mut expect = v.clone();
                    expect.sort_unstable();
                    par_sort(&mut v);
                    assert_eq!(v, expect, "t={t} n={n}");
                    // Pre-sorted and reverse-sorted inputs stress the
                    // copy tails of the move-based merge.
                    let mut asc: Vec<u64> = (0..n as u64).collect();
                    par_sort(&mut asc);
                    assert!(asc.windows(2).all(|w| w[0] <= w[1]));
                    let mut desc: Vec<u64> = (0..n as u64).rev().collect();
                    par_sort(&mut desc);
                    assert_eq!(desc, (0..n as u64).collect::<Vec<_>>(), "t={t} n={n} desc");
                }
            });
        }
    }

    #[test]
    fn radix_matches_std() {
        for n in [0, 1, 3, 1000, 30_000] {
            let mut v = random_vec(n, 9 + n as u64);
            let mut expect = v.clone();
            expect.sort_unstable();
            radix_sort_u64(&mut v);
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn radix_high_bits() {
        let mut v = vec![u64::MAX, 0, 1 << 63, 42, u64::MAX - 1];
        let mut expect = v.clone();
        expect.sort_unstable();
        radix_sort_u64(&mut v);
        assert_eq!(v, expect);
    }
}
