//! Cooperative execution budgets: deadline, memory cap, cancel token.
//!
//! A [`Budget`] rides in the option structs
//! ([`CountOpts`](crate::count::CountOpts),
//! [`PeelVOpts`](crate::peel::PeelVOpts),
//! [`PeelEOpts`](crate::peel::PeelEOpts),
//! [`DynOpts`](crate::dynamic::DynOpts)); the entry-point guard
//! ([`crate::error`]) installs it as the thread-local *active* budget
//! for the duration of the call, and the pool combinators re-install
//! it inside every spawned worker.  The hot loops never thread a
//! handle around: [`check`] reads the thread-local and is a no-op when
//! no budget is active.
//!
//! Checks are **amortized**: the pool calls [`check`] once per claimed
//! task (a `MIN_GRAIN`-sized range, ≥1024 items), and round-based
//! algorithms (peeling, the dynamic walks) add one call per round — so
//! the cost is one thread-local read and, at most, one `Instant::now`
//! per thousand items.  A tripped budget unwinds with a structured
//! payload ([`crate::error::raise`]) that the entry-point guard
//! converts to [`ErrorKind::DeadlineExceeded`] /
//! [`MemoryBudgetExceeded`](ErrorKind::MemoryBudgetExceeded) /
//! [`Cancelled`](ErrorKind::Cancelled).
//!
//! Memory accounting is **charge-only**: [`probe_alloc`] sums the
//! bytes of every major scratch allocation and never decrements, so
//! the charged total is an upper bound on live scratch — a run that
//! stays under the cap is guaranteed never to have held more live
//! probe-tracked bytes than the cap.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{raise, ErrorKind};
use crate::prims::fault;

/// Cooperative limits for one entry-point call.  `Default` is
/// unlimited; construct with the builders or struct syntax.
///
/// ```
/// use parbutterfly::prims::budget::Budget;
///
/// let b = Budget::default().with_timeout_ms(250).with_max_live_bytes(1 << 30);
/// assert!(!b.is_unlimited());
/// assert!(Budget::default().is_unlimited());
/// ```
#[derive(Clone, Debug, Default)]
pub struct Budget {
    /// Wall-clock limit for the call, measured from entry.
    pub timeout: Option<Duration>,
    /// Cap on probe-tracked scratch bytes (charge-only upper bound).
    pub max_live_bytes: Option<usize>,
    /// External cancel token: set it from another thread and the call
    /// returns [`ErrorKind::Cancelled`] at the next check.
    pub cancel: Option<Arc<AtomicBool>>,
}

impl Budget {
    pub fn with_timeout_ms(mut self, ms: u64) -> Self {
        self.timeout = Some(Duration::from_millis(ms));
        self
    }

    pub fn with_max_live_bytes(mut self, bytes: usize) -> Self {
        self.max_live_bytes = Some(bytes);
        self
    }

    pub fn with_cancel(mut self, token: Arc<AtomicBool>) -> Self {
        self.cancel = Some(token);
        self
    }

    /// No limits at all — [`check`] short-circuits to a no-op.
    pub fn is_unlimited(&self) -> bool {
        self.timeout.is_none() && self.max_live_bytes.is_none() && self.cancel.is_none()
    }
}

/// A budget armed at entry time: deadline resolved, charge counter
/// live.  Shared (`Arc`) between the entry thread and pool workers.
pub(crate) struct ActiveBudget {
    deadline: Option<Instant>,
    limit_ms: u64,
    max_live_bytes: Option<usize>,
    charged: AtomicUsize,
    cancel: Option<Arc<AtomicBool>>,
}

impl ActiveBudget {
    fn arm(b: &Budget) -> Self {
        ActiveBudget {
            deadline: b.timeout.map(|t| Instant::now() + t),
            limit_ms: b.timeout.map(|t| t.as_millis() as u64).unwrap_or(0),
            max_live_bytes: b.max_live_bytes,
            charged: AtomicUsize::new(0),
            cancel: b.cancel.clone(),
        }
    }

    fn check(&self) {
        if let Some(c) = &self.cancel {
            if c.load(Ordering::Relaxed) {
                raise(ErrorKind::Cancelled);
            }
        }
        if let Some(d) = self.deadline {
            if Instant::now() > d {
                raise(ErrorKind::DeadlineExceeded { limit_ms: self.limit_ms });
            }
        }
    }

    fn charge(&self, bytes: usize, what: &'static str) {
        if let Some(limit) = self.max_live_bytes {
            let before = self.charged.fetch_add(bytes, Ordering::Relaxed);
            if before.saturating_add(bytes) > limit {
                raise(ErrorKind::MemoryBudgetExceeded {
                    requested: bytes,
                    charged: before,
                    limit,
                    what,
                });
            }
        }
    }
}

thread_local! {
    /// The budget governing work on this thread, if any.
    static ACTIVE: RefCell<Option<Arc<ActiveBudget>>> = const { RefCell::new(None) };
}

/// RAII scope restoring the previously-active budget on drop (also on
/// unwind, so a caught budget trip leaves the thread clean for retry).
pub(crate) struct Scope {
    prev: Option<Arc<ActiveBudget>>,
}

impl Drop for Scope {
    fn drop(&mut self) {
        ACTIVE.with(|a| *a.borrow_mut() = self.prev.take());
    }
}

/// Arm `b` as this thread's active budget until the scope drops.  An
/// unlimited budget still replaces the previous one: each entry point
/// is governed by exactly the budget its own options carry.
pub(crate) fn enter(b: &Budget) -> Scope {
    let armed = if b.is_unlimited() { None } else { Some(Arc::new(ActiveBudget::arm(b))) };
    Scope { prev: ACTIVE.with(|a| a.replace(armed)) }
}

/// Suspend any active budget until the scope drops — used by the
/// dynamic fallback path, where the *recovery* recount must not be
/// killed by the budget that killed the fast path (exactness over
/// latency once degradation has begun).
pub(crate) fn suspend() -> Scope {
    Scope { prev: ACTIVE.with(|a| a.replace(None)) }
}

/// Snapshot the active budget for handing to a spawned worker.
pub(crate) fn current() -> Option<Arc<ActiveBudget>> {
    ACTIVE.with(|a| a.borrow().clone())
}

/// Install a snapshot on a fresh worker thread (no restore needed —
/// the thread is scoped to one combinator call).
pub(crate) fn adopt(ab: Option<Arc<ActiveBudget>>) {
    ACTIVE.with(|a| *a.borrow_mut() = ab);
}

/// Cooperative check point: unwinds with a structured payload when the
/// active budget's cancel token is set or its deadline has passed.
/// No-op (one thread-local read) when no budget is active.
#[inline]
pub fn check() {
    ACTIVE.with(|a| {
        if let Some(ab) = a.borrow().as_ref() {
            ab.check();
        }
    });
}

/// Allocation probe: report an imminent major scratch allocation.
/// Feeds the fault-injection plan (which may fail the probe) and the
/// active budget's memory accounting (which may trip the cap).
#[inline]
pub fn probe_alloc(bytes: usize, what: &'static str) {
    fault::on_alloc(bytes, what);
    ACTIVE.with(|a| {
        if let Some(ab) = a.borrow().as_ref() {
            ab.charge(bytes, what);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::{catch, ErrorKind};

    #[test]
    fn unlimited_budget_checks_are_noops() {
        let _s = enter(&Budget::default());
        check();
        probe_alloc(usize::MAX, "nothing");
    }

    #[test]
    fn cancel_token_trips_check() {
        let token = Arc::new(AtomicBool::new(false));
        let b = Budget::default().with_cancel(token.clone());
        let _s = enter(&b);
        check(); // not cancelled yet
        token.store(true, Ordering::Relaxed);
        let e = catch(check).unwrap_err();
        assert_eq!(e.kind(), &ErrorKind::Cancelled);
    }

    #[test]
    fn expired_deadline_trips_check() {
        let b = Budget { timeout: Some(Duration::from_millis(0)), ..Default::default() };
        let _s = enter(&b);
        std::thread::sleep(Duration::from_millis(2));
        let e = catch(check).unwrap_err();
        assert_eq!(e.kind(), &ErrorKind::DeadlineExceeded { limit_ms: 0 });
    }

    #[test]
    fn memory_cap_trips_on_cumulative_charge() {
        let b = Budget::default().with_max_live_bytes(100);
        let _s = enter(&b);
        probe_alloc(60, "first");
        let e = catch(|| probe_alloc(60, "second")).unwrap_err();
        match e.kind() {
            ErrorKind::MemoryBudgetExceeded { requested, charged, limit, what } => {
                assert_eq!((*requested, *charged, *limit, *what), (60, 60, 100, "second"));
            }
            k => panic!("unexpected kind {k:?}"),
        }
    }

    #[test]
    fn scopes_nest_and_restore() {
        let outer = Budget::default().with_max_live_bytes(10);
        let s1 = enter(&outer);
        {
            let _s2 = enter(&Budget::default()); // inner unlimited replaces
            probe_alloc(1 << 40, "inner"); // no trip
        }
        // outer budget restored
        let e = catch(|| probe_alloc(11, "outer")).unwrap_err();
        assert!(matches!(e.kind(), ErrorKind::MemoryBudgetExceeded { .. }));
        {
            let _s3 = suspend();
            probe_alloc(1 << 40, "suspended"); // no trip
        }
        drop(s1);
        probe_alloc(1 << 40, "after"); // no active budget
    }
}
