//! Batch-parallel Fibonacci heap (§5).
//!
//! Arena-based (index links, no `Rc`): nodes live in a `Vec`, sibling
//! lists are circular doubly-linked via indices, and freed slots are
//! recycled.  Marks are integer *counters* rather than booleans — the
//! paper's batch decrease-key accumulates marks from concurrent cuts
//! and cuts a parent once it holds more than one mark (Algorithm 10);
//! with a batch of size one this degenerates to the classical boolean
//! behaviour.
//!
//! The batch operations ([`FibHeap::batch_insert`],
//! [`FibHeap::batch_decrease_key`]) implement the algorithms of §5.1
//! and §5.3: insertion is a root-list splice of all new singletons
//! followed by one min update; decrease-key performs all independent
//! cuts, then propagates parent cuts level by level (the paper's
//! while-loop over marked parents).  Work matches the sequential
//! amortized bounds; the span analysis in the paper assumes the levels
//! run in parallel — here levels are processed as rounds, preserving
//! the round structure the proof counts.
//!
//! Delete-min consolidates by rank groups exactly as Algorithm 9:
//! round-based pairwise merging within equal-rank groups until all
//! ranks are distinct.

/// Handle to a heap node (stable until the node is deleted).
pub type Handle = u32;

const NIL: u32 = u32::MAX;

struct Node<V> {
    key: u64,
    val: Option<V>,
    parent: u32,
    child: u32, // any one child (head of its sibling ring)
    left: u32,
    right: u32,
    degree: u32,
    marks: u32,
    in_use: bool,
}

/// A Fibonacci heap with u64 keys and arbitrary values.
pub struct FibHeap<V> {
    nodes: Vec<Node<V>>,
    free: Vec<u32>,
    min: u32,
    len: usize,
}

impl<V> Default for FibHeap<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> FibHeap<V> {
    pub fn new() -> Self {
        Self { nodes: Vec::new(), free: Vec::new(), min: NIL, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Key of a live node.
    pub fn key(&self, h: Handle) -> u64 {
        debug_assert!(self.nodes[h as usize].in_use);
        self.nodes[h as usize].key
    }

    /// Value of a live node.
    pub fn value(&self, h: Handle) -> &V {
        match self.nodes[h as usize].val.as_ref() {
            Some(v) => v,
            None => unreachable!("live handle always holds a value"),
        }
    }

    /// Mutable value of a live node.
    pub fn value_mut(&mut self, h: Handle) -> &mut V {
        match self.nodes[h as usize].val.as_mut() {
            Some(v) => v,
            None => unreachable!("live handle always holds a value"),
        }
    }

    fn alloc(&mut self, key: u64, val: V) -> u32 {
        let node = Node {
            key,
            val: Some(val),
            parent: NIL,
            child: NIL,
            left: NIL,
            right: NIL,
            degree: 0,
            marks: 0,
            in_use: true,
        };
        match self.free.pop() {
            Some(i) => {
                self.nodes[i as usize] = node;
                i
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as u32
            }
        }
    }

    /// Splice node `x` into the ring that contains `anchor` (or make it
    /// a singleton ring if `anchor` is NIL).  Returns the ring anchor.
    fn ring_insert(&mut self, anchor: u32, x: u32) -> u32 {
        if anchor == NIL {
            self.nodes[x as usize].left = x;
            self.nodes[x as usize].right = x;
            x
        } else {
            let r = self.nodes[anchor as usize].right;
            self.nodes[x as usize].left = anchor;
            self.nodes[x as usize].right = r;
            self.nodes[anchor as usize].right = x;
            self.nodes[r as usize].left = x;
            anchor
        }
    }

    /// Remove `x` from its ring; returns another ring member (or NIL).
    fn ring_remove(&mut self, x: u32) -> u32 {
        let l = self.nodes[x as usize].left;
        let r = self.nodes[x as usize].right;
        if l == x {
            self.nodes[x as usize].left = x;
            self.nodes[x as usize].right = x;
            return NIL;
        }
        self.nodes[l as usize].right = r;
        self.nodes[r as usize].left = l;
        self.nodes[x as usize].left = x;
        self.nodes[x as usize].right = x;
        l
    }

    /// Insert a single key/value; O(1).
    pub fn insert(&mut self, key: u64, val: V) -> Handle {
        let x = self.alloc(key, val);
        self.add_root(x);
        self.len += 1;
        x
    }

    fn add_root(&mut self, x: u32) {
        self.nodes[x as usize].parent = NIL;
        if self.min == NIL {
            self.nodes[x as usize].left = x;
            self.nodes[x as usize].right = x;
            self.min = x;
        } else {
            self.ring_insert(self.min, x);
            if self.nodes[x as usize].key < self.nodes[self.min as usize].key {
                self.min = x;
            }
        }
    }

    /// §5.1 batch insertion: all singletons spliced, one min update.
    pub fn batch_insert(&mut self, items: Vec<(u64, V)>) -> Vec<Handle> {
        let mut handles = Vec::with_capacity(items.len());
        for (k, v) in items {
            handles.push(self.insert(k, v));
        }
        handles
    }

    /// Current minimum (key, handle).
    pub fn peek_min(&self) -> Option<(u64, Handle)> {
        if self.min == NIL {
            None
        } else {
            Some((self.nodes[self.min as usize].key, self.min))
        }
    }

    /// Algorithm 9: delete the minimum, consolidate by rank groups.
    pub fn delete_min(&mut self) -> Option<(u64, V)> {
        if self.min == NIL {
            return None;
        }
        let z = self.min;
        let key = self.nodes[z as usize].key;
        let val = match self.nodes[z as usize].val.take() {
            Some(v) => v,
            None => unreachable!("the minimum root always holds a value"),
        };
        // Detach z from the root ring *first* (ring edits while z is
        // still linked would corrupt neighbours).
        let mut anchor = self.ring_remove(z);
        // Promote children to roots.
        let mut child = self.nodes[z as usize].child;
        if child != NIL {
            let mut kids = Vec::with_capacity(self.nodes[z as usize].degree as usize);
            let start = child;
            loop {
                kids.push(child);
                child = self.nodes[child as usize].right;
                if child == start {
                    break;
                }
            }
            for k in kids {
                self.nodes[k as usize].parent = NIL;
                self.nodes[k as usize].marks = 0;
                self.nodes[k as usize].left = k;
                self.nodes[k as usize].right = k;
                anchor = self.ring_insert(anchor, k);
            }
        }
        self.nodes[z as usize].in_use = false;
        self.nodes[z as usize].child = NIL;
        self.free.push(z);
        self.len -= 1;
        if self.len == 0 {
            self.min = NIL;
            return Some((key, val));
        }
        // Gather all roots.
        debug_assert_ne!(anchor, NIL);
        let mut roots = Vec::new();
        let start = anchor;
        let mut cur = start;
        loop {
            roots.push(cur);
            cur = self.nodes[cur as usize].right;
            if cur == start {
                break;
            }
        }
        // Rank-group consolidation (Algorithm 9): merge pairs within
        // each rank group per round until all ranks distinct.
        let max_rank = 2 + (usize::BITS - self.len.leading_zeros()) as usize * 2;
        let mut groups: Vec<Vec<u32>> = vec![Vec::new(); max_rank + 2];
        for r in roots {
            let d = self.nodes[r as usize].degree as usize;
            if d + 1 >= groups.len() {
                groups.resize(d + 2, Vec::new());
            }
            groups[d].push(r);
        }
        loop {
            let mut any = false;
            for d in 0..groups.len() {
                while groups[d].len() > 1 {
                    any = true;
                    let (Some(a), Some(b)) = (groups[d].pop(), groups[d].pop()) else {
                        unreachable!("len > 1 guarantees two roots to link")
                    };
                    let merged = self.link(a, b);
                    if d + 2 >= groups.len() {
                        groups.resize(d + 3, Vec::new());
                    }
                    groups[d + 1].push(merged);
                }
            }
            if !any {
                break;
            }
        }
        // Rebuild the root ring and min pointer.
        self.min = NIL;
        let survivors: Vec<u32> =
            groups.into_iter().flatten().collect();
        let mut anchor = NIL;
        for s in &survivors {
            self.nodes[*s as usize].left = *s;
            self.nodes[*s as usize].right = *s;
        }
        for s in survivors {
            self.nodes[s as usize].parent = NIL;
            anchor = self.ring_insert(anchor, s);
            if self.min == NIL || self.nodes[s as usize].key < self.nodes[self.min as usize].key {
                self.min = s;
            }
        }
        Some((key, val))
    }

    /// Make the larger-keyed root a child of the smaller; returns the
    /// surviving root.
    fn link(&mut self, a: u32, b: u32) -> u32 {
        let (small, big) = if self.nodes[a as usize].key <= self.nodes[b as usize].key {
            (a, b)
        } else {
            (b, a)
        };
        self.nodes[big as usize].parent = small;
        self.nodes[big as usize].marks = 0;
        let child = self.nodes[small as usize].child;
        self.nodes[big as usize].left = big;
        self.nodes[big as usize].right = big;
        let nc = self.ring_insert(child, big);
        self.nodes[small as usize].child = nc;
        self.nodes[small as usize].degree += 1;
        small
    }

    /// Classical decrease-key (batch size 1 of Algorithm 10).
    pub fn decrease_key(&mut self, h: Handle, new_key: u64) {
        self.batch_decrease_key(vec![(h, new_key)]);
    }

    /// Algorithm 10: batch decrease-key with counted marks.
    pub fn batch_decrease_key(&mut self, batch: Vec<(Handle, u64)>) {
        let mut marked: Vec<u32> = Vec::new();
        for (h, new_key) in batch {
            let x = h;
            debug_assert!(self.nodes[x as usize].in_use);
            debug_assert!(new_key <= self.nodes[x as usize].key, "keys only decrease");
            self.nodes[x as usize].key = new_key;
            let p = self.nodes[x as usize].parent;
            if p != NIL && new_key < self.nodes[p as usize].key {
                self.cut(x, p);
                self.nodes[p as usize].marks += 1;
                marked.push(p);
            } else if p == NIL && new_key < self.nodes[self.min as usize].key {
                self.min = x;
            }
        }
        // Propagate: cut every parent holding more than one mark
        // (paper: "> 1 marks"); a root collecting marks just clears.
        let mut frontier: Vec<u32> = marked
            .iter()
            .copied()
            .filter(|&p| self.nodes[p as usize].in_use && self.nodes[p as usize].marks > 1)
            .collect();
        frontier.sort_unstable();
        frontier.dedup();
        while !frontier.is_empty() {
            let mut next: Vec<u32> = Vec::new();
            for p in frontier {
                if !self.nodes[p as usize].in_use || self.nodes[p as usize].marks <= 1 {
                    continue;
                }
                let gp = self.nodes[p as usize].parent;
                if gp == NIL {
                    // Roots don't cascade; normalize the counter.
                    self.nodes[p as usize].marks = 0;
                    continue;
                }
                let parity = self.nodes[p as usize].marks % 2;
                self.cut(p, gp);
                self.nodes[p as usize].marks = parity; // even -> 0, odd -> 1
                self.nodes[gp as usize].marks += 1;
                if self.nodes[gp as usize].marks > 1 {
                    next.push(gp);
                }
            }
            next.sort_unstable();
            next.dedup();
            frontier = next;
        }
    }

    /// Cut `x` from parent `p` and add it to the root list.
    fn cut(&mut self, x: u32, p: u32) {
        let other = self.ring_remove(x);
        if self.nodes[p as usize].child == x {
            self.nodes[p as usize].child = other;
        }
        self.nodes[p as usize].degree -= 1;
        self.add_root(x);
    }

    /// Walk all live nodes (testing/diagnostics).
    pub fn iter_live(&self) -> impl Iterator<Item = (u64, Handle)> + '_ {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.in_use)
            .map(|(i, n)| (n.key, i as Handle))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::rng::Pcg32;
    use std::collections::BTreeMap;

    #[test]
    fn insert_and_delete_min_sorted() {
        let mut h = FibHeap::new();
        for k in [5u64, 3, 9, 1, 7, 3] {
            h.insert(k, k);
        }
        let mut out = Vec::new();
        while let Some((k, _)) = h.delete_min() {
            out.push(k);
        }
        assert_eq!(out, vec![1, 3, 3, 5, 7, 9]);
    }

    #[test]
    fn decrease_key_changes_order() {
        let mut h = FibHeap::new();
        let a = h.insert(50, 'a');
        let _b = h.insert(10, 'b');
        let c = h.insert(30, 'c');
        h.decrease_key(a, 5);
        assert_eq!(h.delete_min().unwrap(), (5, 'a'));
        h.decrease_key(c, 1);
        assert_eq!(h.delete_min().unwrap(), (1, 'c'));
        assert_eq!(h.delete_min().unwrap(), (10, 'b'));
        assert!(h.delete_min().is_none());
    }

    #[test]
    fn batch_ops_match_btreemap_model() {
        // Randomized differential test against a sorted-multimap model.
        let mut rng = Pcg32::new(2024);
        for _trial in 0..20 {
            let mut heap: FibHeap<u64> = FibHeap::new();
            let mut model: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
            let mut handles: Vec<(Handle, u64)> = Vec::new(); // (handle, id)
            let mut next_id = 0u64;
            for _op in 0..300 {
                match rng.next_below(10) {
                    0..=4 => {
                        // batch insert 1-8 items
                        let k = rng.next_below(8) + 1;
                        let mut items = Vec::new();
                        for _ in 0..k {
                            let key = rng.next_below(1000);
                            items.push((key, next_id));
                            model.entry(key).or_default().push(next_id);
                            next_id += 1;
                        }
                        let ids: Vec<u64> = items.iter().map(|x| x.1).collect();
                        let hs = heap.batch_insert(items);
                        handles.extend(hs.into_iter().zip(ids));
                    }
                    5..=6 => {
                        // delete-min
                        let got = heap.delete_min();
                        let want_key = model.keys().next().copied();
                        match (got, want_key) {
                            (None, None) => {}
                            (Some((k, id)), Some(wk)) => {
                                assert_eq!(k, wk, "min key mismatch");
                                let ids = model.get_mut(&wk).unwrap();
                                let pos = ids.iter().position(|&x| x == id).expect("wrong id");
                                ids.swap_remove(pos);
                                if ids.is_empty() {
                                    model.remove(&wk);
                                }
                                handles.retain(|&(_, hid)| hid != id);
                            }
                            (g, w) => panic!("mismatch: {g:?} vs {w:?}"),
                        }
                    }
                    _ => {
                        // batch decrease-key on up to 4 random handles
                        if handles.is_empty() {
                            continue;
                        }
                        let mut batch = Vec::new();
                        let mut chosen = std::collections::HashSet::new();
                        for _ in 0..rng.next_below(4) + 1 {
                            let i = rng.next_below(handles.len() as u64) as usize;
                            if !chosen.insert(i) {
                                continue;
                            }
                            let (h, id) = handles[i];
                            let old = heap.key(h);
                            let nk = rng.next_below(old + 1);
                            batch.push((h, nk));
                            // update model
                            let ids = model.get_mut(&old).unwrap();
                            let pos = ids.iter().position(|&x| x == id).unwrap();
                            ids.swap_remove(pos);
                            if ids.is_empty() {
                                model.remove(&old);
                            }
                            model.entry(nk).or_default().push(id);
                        }
                        heap.batch_decrease_key(batch);
                    }
                }
                // Invariant: peek matches model min.
                assert_eq!(heap.peek_min().map(|(k, _)| k), model.keys().next().copied());
                assert_eq!(heap.len(), model.values().map(|v| v.len()).sum::<usize>());
            }
        }
    }

    #[test]
    fn heavy_decrease_key_cascades() {
        // Build a deep-ish heap then hammer decrease-keys to force
        // cascading cuts; drain and verify sortedness.
        let mut rng = Pcg32::new(77);
        let mut h = FibHeap::new();
        let mut handles = Vec::new();
        for i in 0..500u64 {
            handles.push(h.insert(1000 + i, i));
        }
        // Interleave delete-mins (to build trees) with decreases.
        for _ in 0..50 {
            h.delete_min();
        }
        let live: Vec<Handle> =
            h.iter_live().map(|(_, hd)| hd).collect();
        let mut batch = Vec::new();
        for &hd in live.iter().take(200) {
            let k = h.key(hd);
            batch.push((hd, k - rng.next_below(k.min(900))));
        }
        h.batch_decrease_key(batch);
        let mut prev = 0u64;
        let mut count = 0;
        while let Some((k, _)) = h.delete_min() {
            assert!(k >= prev);
            prev = k;
            count += 1;
        }
        assert_eq!(count, 450);
    }

    /// Sorted-vec oracle: a plain `Vec<(key, id)>` re-sorted after
    /// every mutation — dumber than the BTreeMap model above (no
    /// structure shared with the heap at all), used to cross-check
    /// long scripted batch-op sequences.
    struct VecOracle {
        items: Vec<(u64, u64)>,
    }

    impl VecOracle {
        fn new() -> Self {
            Self { items: Vec::new() }
        }
        fn insert(&mut self, key: u64, id: u64) {
            self.items.push((key, id));
            self.items.sort_unstable();
        }
        /// Remove the entry the heap extracted, checking its key was
        /// minimal (ties may be broken by either id).
        fn delete_exact(&mut self, key: u64, id: u64) {
            assert_eq!(self.min(), Some(key), "extracted key not minimal");
            let pos = self.items.iter().position(|&(k, i)| k == key && i == id).unwrap();
            self.items.remove(pos);
        }
        fn decrease(&mut self, id: u64, new_key: u64) {
            let slot = self.items.iter_mut().find(|(_, i)| *i == id).unwrap();
            assert!(new_key <= slot.0);
            slot.0 = new_key;
            self.items.sort_unstable();
        }
        fn min(&self) -> Option<u64> {
            self.items.first().map(|&(k, _)| k)
        }
    }

    #[test]
    fn scripted_batch_sequences_match_sorted_vec_oracle() {
        // Deterministic long scripts of batch_insert / delete_min /
        // batch_decrease_key; after every operation the heap's minimum
        // and length must equal the oracle's, and full drains must
        // produce the oracle's sorted key sequence.
        let mut rng = Pcg32::new(4096);
        for trial in 0..10 {
            let mut heap: FibHeap<u64> = FibHeap::new();
            let mut oracle = VecOracle::new();
            let mut handles: Vec<(Handle, u64)> = Vec::new();
            let mut next_id = 0u64;
            for op in 0..400 {
                match rng.next_below(12) {
                    0..=5 => {
                        let batch_size = rng.next_below(6) + 1;
                        let mut items = Vec::new();
                        for _ in 0..batch_size {
                            let key = rng.next_below(500);
                            items.push((key, next_id));
                            oracle.insert(key, next_id);
                            next_id += 1;
                        }
                        let ids: Vec<u64> = items.iter().map(|x| x.1).collect();
                        handles.extend(heap.batch_insert(items).into_iter().zip(ids));
                    }
                    6..=8 => match heap.delete_min() {
                        Some((k, id)) => {
                            handles.retain(|&(_, hid)| hid != id);
                            oracle.delete_exact(k, id);
                        }
                        None => {
                            assert!(oracle.items.is_empty(), "trial {trial} op {op}: empty heap")
                        }
                    },
                    _ => {
                        if handles.is_empty() {
                            continue;
                        }
                        let mut batch = Vec::new();
                        let mut chosen = std::collections::HashSet::new();
                        for _ in 0..rng.next_below(5) + 1 {
                            let i = rng.next_below(handles.len() as u64) as usize;
                            if !chosen.insert(i) {
                                continue;
                            }
                            let (h, id) = handles[i];
                            let nk = rng.next_below(heap.key(h) + 1);
                            batch.push((h, nk));
                            oracle.decrease(id, nk);
                        }
                        heap.batch_decrease_key(batch);
                    }
                }
                assert_eq!(heap.peek_min().map(|(k, _)| k), oracle.min(), "trial {trial} op {op}");
                assert_eq!(heap.len(), oracle.items.len(), "trial {trial} op {op}");
            }
            // Full drain, key order must match exactly.
            let mut got = Vec::new();
            while let Some((k, _)) = heap.delete_min() {
                got.push(k);
            }
            let expect: Vec<u64> = oracle.items.iter().map(|&(k, _)| k).collect();
            assert_eq!(got, expect, "trial {trial} drain");
        }
    }

    #[test]
    fn freed_slots_are_recycled() {
        // Arena hygiene: delete-min frees slots that later inserts must
        // reuse, so long insert/delete churn cannot grow the arena
        // unboundedly.
        let mut h: FibHeap<u64> = FibHeap::new();
        for i in 0..64u64 {
            h.insert(i, i);
        }
        let arena_after_fill = h.nodes.len();
        for _round in 0..50 {
            for _ in 0..32 {
                h.delete_min().unwrap();
            }
            for i in 0..32u64 {
                h.insert(1_000 + i, i);
            }
        }
        assert_eq!(h.len(), 64);
        assert_eq!(h.nodes.len(), arena_after_fill, "arena grew despite recycling");
        let mut prev = 0;
        let mut drained = 0;
        while let Some((k, _)) = h.delete_min() {
            assert!(k >= prev);
            prev = k;
            drained += 1;
        }
        assert_eq!(drained, 64);
    }

    #[test]
    fn interleaved_stress_small_keys() {
        let mut h = FibHeap::new();
        let mut inserted = 0u64;
        let mut popped = Vec::new();
        for round in 0..20u64 {
            let items: Vec<(u64, u64)> = (0..10).map(|i| (round * 10 + i, i)).collect();
            h.batch_insert(items);
            inserted += 10;
            for _ in 0..5 {
                if let Some((k, _)) = h.delete_min() {
                    popped.push(k);
                }
            }
        }
        while let Some((k, _)) = h.delete_min() {
            popped.push(k);
        }
        assert_eq!(popped.len() as u64, inserted);
        let mut sorted = popped.clone();
        sorted.sort_unstable();
        assert_eq!(popped, sorted, "pops must come out in key order given monotone inserts");
    }
}
