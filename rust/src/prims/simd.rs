//! Explicitly autovectorizable hot-loop kernels (stable Rust only).
//!
//! The wedge hot loops spend their time in two tiny inner shapes:
//! word-wise bitmap AND + popcount (the hub-adjacency probes of the
//! cache-aware intersect layout, the `EdgeStamp` presence tests of the
//! dynamic delta walks) and sorted-adjacency intersection (UPDATE-E's
//! `N(u1) ∩ N(u2)` enumeration).  This module is their single home.
//!
//! No nightly `std::simd`: every kernel is written so the *stable*
//! compiler's autovectorizer can lift it — fixed-width chunks
//! (`chunks_exact`), independent accumulator lanes, `count_ones` for
//! popcount (a single `popcnt`/`cnt` instruction on x86-64/AArch64) —
//! and degrades to good scalar code where it can't.  Correctness never
//! depends on vectorization; the unit suite pins every kernel against
//! a scalar oracle on adversarial inputs (empty, disjoint, fully
//! overlapping, unaligned lengths).

/// AND the two word slices and count the surviving bits.
///
/// Lengths may differ; the comparison covers the common prefix (a
/// missing word is an all-zero word).  Four independent accumulator
/// lanes keep the loop free of a serial dependence so it vectorizes.
pub fn and_popcount(a: &[u64], b: &[u64]) -> u64 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut lanes = [0u64; 4];
    for (ca, cb) in a.chunks_exact(4).zip(b.chunks_exact(4)) {
        lanes[0] += (ca[0] & cb[0]).count_ones() as u64;
        lanes[1] += (ca[1] & cb[1]).count_ones() as u64;
        lanes[2] += (ca[2] & cb[2]).count_ones() as u64;
        lanes[3] += (ca[3] & cb[3]).count_ones() as u64;
    }
    let rem = n - n % 4;
    let mut tail = 0u64;
    for (&x, &y) in a[rem..].iter().zip(&b[rem..]) {
        tail += (x & y).count_ones() as u64;
    }
    lanes.iter().sum::<u64>() + tail
}

/// Sparse AND + popcount: inspect only the word indices in `idx`.
///
/// The hub probes of the intersect engine use this with `idx` = the
/// (few) words the source bitmap actually populates, so the cost per
/// probe is `O(|up-neighborhood| / 64)` instead of `O(n / 64)`.
/// Indices must be in range for both slices.
pub fn and_popcount_at(idx: &[u32], a: &[u64], b: &[u64]) -> u64 {
    let mut lanes = [0u64; 4];
    for c in idx.chunks_exact(4) {
        lanes[0] += (a[c[0] as usize] & b[c[0] as usize]).count_ones() as u64;
        lanes[1] += (a[c[1] as usize] & b[c[1] as usize]).count_ones() as u64;
        lanes[2] += (a[c[2] as usize] & b[c[2] as usize]).count_ones() as u64;
        lanes[3] += (a[c[3] as usize] & b[c[3] as usize]).count_ones() as u64;
    }
    let rem = idx.len() - idx.len() % 4;
    let mut tail = 0u64;
    for &w in &idx[rem..] {
        tail += (a[w as usize] & b[w as usize]).count_ones() as u64;
    }
    lanes.iter().sum::<u64>() + tail
}

/// Size of the intersection of two strictly increasing slices.
pub fn intersect_count(a: &[u32], b: &[u32]) -> u64 {
    let mut c = 0u64;
    intersect_pairs(a, b, |_, _| c += 1);
    c
}

/// Visit `(i, j)` for every pair with `a[i] == b[j]`, both slices
/// strictly increasing, in increasing value order.
///
/// Strategy follows the paper's min-degree intersection bound: when one
/// list is much shorter (8x), scan it and binary-search the other —
/// `O(min · log max)`, which is what makes power-law hubs affordable —
/// otherwise a two-pointer merge.
#[inline]
pub fn intersect_pairs(a: &[u32], b: &[u32], mut hit: impl FnMut(usize, usize)) {
    if a.is_empty() || b.is_empty() {
        return;
    }
    if a.len() * 8 < b.len() {
        for (i, &x) in a.iter().enumerate() {
            if let Ok(j) = b.binary_search(&x) {
                hit(i, j);
            }
        }
    } else if b.len() * 8 < a.len() {
        for (j, &y) in b.iter().enumerate() {
            if let Ok(i) = a.binary_search(&y) {
                hit(i, j);
            }
        }
    } else {
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    hit(i, j);
                    i += 1;
                    j += 1;
                }
            }
        }
    }
}

/// Fixed-capacity bitmap with word access for the AND/popcount kernels.
///
/// The hot loops keep one of these per worker (source up-neighborhoods,
/// butterfly-carrying endpoint sets, `EdgeStamp` presence) and clear it
/// via the touched list, never a memset — the same O(#touched) reset
/// discipline as `TouchedCounter`.
#[derive(Clone, Debug)]
pub struct Bitset {
    words: Vec<u64>,
}

impl Bitset {
    /// All-zero bitmap with capacity for `bits` bits.
    pub fn new(bits: usize) -> Self {
        Self { words: vec![0u64; bits.div_ceil(64)] }
    }

    #[inline]
    pub fn set(&mut self, i: u32) {
        self.words[(i >> 6) as usize] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear(&mut self, i: u32) {
        self.words[(i >> 6) as usize] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn test(&self, i: u32) -> bool {
        (self.words[(i >> 6) as usize] >> (i & 63)) & 1 != 0
    }

    /// Zero whole words by index (the bulk form of [`Self::clear`] for
    /// callers that tracked which words they populated).
    #[inline]
    pub fn clear_words(&mut self, idx: &[u32]) {
        for &w in idx {
            self.words[w as usize] = 0;
        }
    }

    /// The backing words, for the AND/popcount kernels.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::rng::Pcg32;

    fn oracle_and_popcount(a: &[u64], b: &[u64]) -> u64 {
        a.iter().zip(b).map(|(&x, &y)| (x & y).count_ones() as u64).sum()
    }

    fn oracle_intersect(a: &[u32], b: &[u32]) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for (i, &x) in a.iter().enumerate() {
            for (j, &y) in b.iter().enumerate() {
                if x == y {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// Sorted distinct values below `max`, roughly `len` of them.
    fn sorted_set(rng: &mut Pcg32, len: usize, max: u32) -> Vec<u32> {
        let mut v: Vec<u32> = (0..len).map(|_| rng.next_u32() % max).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    #[test]
    fn and_popcount_adversarial_shapes() {
        // Empty, disjoint, fully overlapping, unaligned lengths.
        assert_eq!(and_popcount(&[], &[]), 0);
        assert_eq!(and_popcount(&[u64::MAX; 3], &[]), 0);
        assert_eq!(and_popcount(&[0b1010, 0], &[0b0101, u64::MAX]), 0);
        assert_eq!(and_popcount(&[u64::MAX; 7], &[u64::MAX; 7]), 7 * 64);
        // Unaligned length (not a multiple of the 4-lane chunk) and
        // mismatched lengths: the shorter slice wins.
        assert_eq!(and_popcount(&[u64::MAX; 5], &[u64::MAX; 9]), 5 * 64);
        assert_eq!(and_popcount(&[1, 2, 3], &[3, 3, 3, 3]), 1 + 1 + 2);
    }

    #[test]
    fn and_popcount_matches_oracle_randomized() {
        let mut rng = Pcg32::new(7);
        for len in [0usize, 1, 3, 4, 5, 8, 13, 64, 129] {
            let a: Vec<u64> =
                (0..len).map(|_| (rng.next_u32() as u64) << 32 | rng.next_u32() as u64).collect();
            let b: Vec<u64> =
                (0..len).map(|_| (rng.next_u32() as u64) << 32 | rng.next_u32() as u64).collect();
            assert_eq!(and_popcount(&a, &b), oracle_and_popcount(&a, &b), "len={len}");
            // The sparse form over every index must agree with the
            // dense kernel, as must any subset against its own oracle.
            let all: Vec<u32> = (0..len as u32).collect();
            assert_eq!(and_popcount_at(&all, &a, &b), and_popcount(&a, &b), "len={len}");
            let some: Vec<u32> = (0..len as u32).filter(|w| w % 3 == 1).collect();
            let expect: u64 =
                some.iter().map(|&w| (a[w as usize] & b[w as usize]).count_ones() as u64).sum();
            assert_eq!(and_popcount_at(&some, &a, &b), expect, "len={len}");
        }
    }

    #[test]
    fn intersect_adversarial_shapes() {
        let hits = |a: &[u32], b: &[u32]| {
            let mut v = Vec::new();
            intersect_pairs(a, b, |i, j| v.push((i, j)));
            v
        };
        // Empty either side.
        assert!(hits(&[], &[1, 2]).is_empty());
        assert!(hits(&[1, 2], &[]).is_empty());
        // Disjoint.
        assert!(hits(&[1, 3, 5], &[2, 4, 6]).is_empty());
        assert_eq!(intersect_count(&[1, 3, 5], &[2, 4, 6]), 0);
        // Fully overlapping.
        assert_eq!(hits(&[2, 4, 9], &[2, 4, 9]), vec![(0, 0), (1, 1), (2, 2)]);
        // Skewed enough to take both galloping branches.
        let long: Vec<u32> = (0..100).map(|i| i * 3).collect();
        assert_eq!(hits(&[30, 31, 99], &long), vec![(0, 10), (2, 33)]);
        assert_eq!(hits(&long, &[30, 31, 99]), vec![(10, 0), (33, 2)]);
    }

    #[test]
    fn intersect_matches_oracle_randomized() {
        let mut rng = Pcg32::new(11);
        for case in 0..200 {
            // Mix of balanced and skewed lengths so every branch runs.
            let la = 1 + (rng.next_u32() % 40) as usize;
            let lb = if case % 3 == 0 { 1 + (rng.next_u32() % 600) as usize } else { la };
            let a = sorted_set(&mut rng, la, 128);
            let b = sorted_set(&mut rng, lb, 128);
            let mut got = Vec::new();
            intersect_pairs(&a, &b, |i, j| got.push((i, j)));
            assert_eq!(got, oracle_intersect(&a, &b), "case={case}");
            assert_eq!(intersect_count(&a, &b), got.len() as u64, "case={case}");
        }
    }

    #[test]
    fn bitset_set_test_clear() {
        let mut s = Bitset::new(200);
        assert!(!s.test(0) && !s.test(199));
        s.set(0);
        s.set(63);
        s.set(64);
        s.set(199);
        assert!(s.test(0) && s.test(63) && s.test(64) && s.test(199));
        assert!(!s.test(1) && !s.test(65));
        s.clear(63);
        assert!(!s.test(63) && s.test(0) && s.test(64));
        s.clear_words(&[0, 3]);
        assert!(!s.test(0) && !s.test(199) && s.test(64));
        assert_eq!(s.words().len(), 4);
    }
}
