//! Parallel-primitive substrate.
//!
//! The paper builds on Cilk Plus and the Problem Based Benchmark Suite
//! (PBBS).  Neither exists in this environment, so this module is a
//! from-scratch equivalent on `std::thread::scope`:
//!
//! * [`pool`] — fork-join `parallel_for` (static chunking) and a
//!   self-scheduling dynamic variant (the paper's "wedge-aware" batching
//!   needs load balancing by wedge count, not vertex count).
//! * [`scan`] — parallel prefix sum and `filter`/`pack`.
//! * [`sort`] — parallel merge sort over `u64`-keyed records plus an
//!   LSD radix sort (the paper uses PBBS sample sort; merge sort has the
//!   same work bound and much simpler code).
//! * [`semisort`] — group-equal-keys via sorting (Gu et al. semantics:
//!   equal keys contiguous, no total-order guarantee needed).
//! * [`hashtable`] — phase-concurrent additive hash table with linear
//!   probing and atomic-add value combining (Shun–Blelloch style).
//! * [`histogram`] — parallel counting of `u64` keys by hash
//!   partitioning + local counting (Dhulipala et al. style).
//! * [`atomics`] — CAS min/max helpers.
//! * [`rng`] — splittable PCG32 used by generators, sparsification, and
//!   the property-test harness.
//! * [`simd`] — explicitly autovectorizable (stable-Rust) kernels for
//!   sorted-adjacency intersection and bitmap AND/popcount, plus the
//!   touched-list-reset [`simd::Bitset`] the hot loops share.
//! * [`bucket`] — lazy bucketing structures (Julienne window,
//!   Fibonacci-heap buckets, descending max-walk) shared by the peeling
//!   round loops and the co-degeneracy rankings.
//! * [`fibheap`] — the batch-parallel Fibonacci heap of §5 backing
//!   [`bucket::FibBuckets`].
//! * [`budget`] — cooperative deadlines / memory caps / cancel tokens
//!   checked at task granularity by [`pool`].
//! * [`fault`] — deterministic fault injection for the runtime's
//!   panic-isolation tests (`PARBUTTERFLY_FAULT`).

// Runtime-critical modules must not abort through unchecked unwraps:
// failures either unwind as structured panics the pool catches or are
// returned as `error::Result`.  Test code is exempt.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]
pub mod atomics;
pub mod bucket;
pub mod budget;
pub mod fault;
pub mod fibheap;
pub mod hashtable;
pub mod histogram;
pub mod pool;
pub mod rng;
pub mod scan;
pub mod semisort;
pub mod simd;
pub mod sort;

pub use hashtable::CountTable;
pub use pool::{num_threads, parallel_for, parallel_for_chunks, parallel_for_dynamic, with_threads};
pub use scan::{dedup_sorted, filter, pack_indices, prefix_sum};
pub use sort::{par_sort, par_sort_by_key};
