//! Semisort-based key aggregation (Gu–Shun–Sun–Blelloch semantics).
//!
//! [`aggregate_counts`] groups a sequence of `u64` keys and returns
//! `(key, multiplicity)` pairs.  We realize the semisort by sorting —
//! the paper's implementation also switched from true semisort to PBBS
//! sample sort for cache efficiency (§3.1.2) — then computing segment
//! boundaries with a parallel pack.

use super::pool::{num_threads, parallel_for_chunks, SyncPtr};
use super::scan::prefix_sum;
use super::sort::{par_sort, radix_sort_u64};

/// Group equal keys; returns `(key, count)` pairs sorted by key.
pub fn aggregate_counts(mut keys: Vec<u64>, use_radix: bool) -> Vec<(u64, u64)> {
    if keys.is_empty() {
        return Vec::new();
    }
    if use_radix {
        radix_sort_u64(&mut keys);
    } else {
        par_sort(&mut keys);
    }
    counts_of_sorted(&keys)
}

/// Segment a *sorted* key sequence into `(key, count)` pairs.
pub fn counts_of_sorted(keys: &[u64]) -> Vec<(u64, u64)> {
    let n = keys.len();
    if n == 0 {
        return Vec::new();
    }
    let t = num_threads();
    if t <= 1 || n < 8192 {
        let mut out: Vec<(u64, u64)> = Vec::new();
        let mut i = 0;
        while i < n {
            let mut j = i + 1;
            while j < n && keys[j] == keys[i] {
                j += 1;
            }
            out.push((keys[i], (j - i) as u64));
            i = j;
        }
        return out;
    }
    // Parallel: find segment heads, prefix-sum them into output slots.
    let nblocks = t.min(n);
    let block = n.div_ceil(nblocks);
    let mut head_counts = vec![0usize; nblocks];
    {
        let hp = SyncPtr(head_counts.as_mut_ptr());
        parallel_for_chunks(nblocks, |r| {
            for b in r {
                let lo = b * block;
                let hi = ((b + 1) * block).min(n);
                let mut c = 0usize;
                for i in lo..hi {
                    if i == 0 || keys[i] != keys[i - 1] {
                        c += 1;
                    }
                }
                unsafe { *hp.get().add(b) = c };
            }
        });
    }
    let (offsets, nseg) = prefix_sum(&head_counts);
    let mut heads = vec![0usize; nseg];
    {
        let hp = SyncPtr(heads.as_mut_ptr());
        let offsets = &offsets;
        parallel_for_chunks(nblocks, |r| {
            for b in r {
                let lo = b * block;
                let hi = ((b + 1) * block).min(n);
                let mut w = offsets[b];
                for i in lo..hi {
                    if i == 0 || keys[i] != keys[i - 1] {
                        unsafe { *hp.get().add(w) = i };
                        w += 1;
                    }
                }
            }
        });
    }
    let mut out = vec![(0u64, 0u64); nseg];
    {
        let op = SyncPtr(out.as_mut_ptr());
        let heads = &heads;
        parallel_for_chunks(nseg, |r| {
            for s in r {
                let start = heads[s];
                let end = if s + 1 < nseg { heads[s + 1] } else { n };
                unsafe { *op.get().add(s) = (keys[start], (end - start) as u64) };
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::pool::with_threads;
    use crate::prims::rng::Pcg32;
    use std::collections::HashMap;

    fn model(keys: &[u64]) -> Vec<(u64, u64)> {
        let mut m: HashMap<u64, u64> = HashMap::new();
        for &k in keys {
            *m.entry(k).or_insert(0) += 1;
        }
        let mut v: Vec<(u64, u64)> = m.into_iter().collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn aggregate_matches_model() {
        let mut r = Pcg32::new(3);
        for &n in &[0usize, 1, 17, 5000, 30_000] {
            let keys: Vec<u64> = (0..n).map(|_| r.next_below(500)).collect();
            for t in [1, 4] {
                with_threads(t, || {
                    for radix in [false, true] {
                        assert_eq!(
                            aggregate_counts(keys.clone(), radix),
                            model(&keys),
                            "n={n} t={t} radix={radix}"
                        );
                    }
                });
            }
        }
    }

    #[test]
    fn all_equal_and_all_distinct() {
        with_threads(2, || {
            assert_eq!(aggregate_counts(vec![7; 10_000], true), vec![(7, 10_000)]);
            let keys: Vec<u64> = (0..10_000).collect();
            let out = aggregate_counts(keys, false);
            assert_eq!(out.len(), 10_000);
            assert!(out.iter().all(|&(_, c)| c == 1));
        });
    }
}
