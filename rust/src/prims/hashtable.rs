//! Phase-concurrent additive hash table (Shun–Blelloch style).
//!
//! Open addressing over power-of-two capacity with linear probing.
//! Keys are `u64` (callers pack `(u32, u32)` endpoint pairs), values are
//! `u64` counts combined by atomic add.  "Phase-concurrent": concurrent
//! `insert_add`s are fine; iteration happens in a separate phase.
//!
//! The paper uses this table (with an atomic-add combiner) as the `Hash`
//! wedge-aggregation strategy and for butterfly-count aggregation; space
//! is proportional to the number of *distinct* keys, giving the
//! `O(min(n^2, alpha*m))` bound of Lemma 4.3.

use std::sync::atomic::{AtomicU64, Ordering};

use super::pool::{num_threads, parallel_for_chunks};
use super::rng::hash64;

const EMPTY: u64 = u64::MAX;

/// Concurrent `u64 -> u64` additive map.
pub struct CountTable {
    keys: Vec<AtomicU64>,
    vals: Vec<AtomicU64>,
    mask: usize,
}

impl CountTable {
    /// Table sized for `n` distinct keys (load factor <= 0.5).
    ///
    /// Keys must never equal `u64::MAX` (reserved sentinel); packed
    /// vertex/edge pairs never do.
    pub fn with_capacity(n: usize) -> Self {
        let cap = (2 * n.max(4)).next_power_of_two();
        Self {
            keys: (0..cap).map(|_| AtomicU64::new(EMPTY)).collect(),
            vals: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            mask: cap - 1,
        }
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.keys.len()
    }

    /// Add `delta` to the count for `key` (insert if absent).
    ///
    /// Panics if the table is full — capacity is the caller's contract
    /// (wedge aggregation sizes tables by the wedge-batch bound).
    #[inline]
    pub fn insert_add(&self, key: u64, delta: u64) {
        debug_assert_ne!(key, EMPTY);
        let mut i = (hash64(key) as usize) & self.mask;
        for _probe in 0..=self.mask {
            let k = self.keys[i].load(Ordering::Acquire);
            if k == key {
                self.vals[i].fetch_add(delta, Ordering::Relaxed);
                return;
            }
            if k == EMPTY {
                match self.keys[i].compare_exchange(
                    EMPTY,
                    key,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => {
                        self.vals[i].fetch_add(delta, Ordering::Relaxed);
                        return;
                    }
                    Err(found) if found == key => {
                        self.vals[i].fetch_add(delta, Ordering::Relaxed);
                        return;
                    }
                    Err(_) => { /* someone else claimed it; keep probing */ }
                }
            }
            i = (i + 1) & self.mask;
        }
        panic!("CountTable full (capacity {})", self.capacity());
    }

    /// Read the count for `key` (0 if absent).  Safe concurrently with
    /// inserts of *other* keys; exact after the insert phase.
    #[inline]
    pub fn get(&self, key: u64) -> u64 {
        let mut i = (hash64(key) as usize) & self.mask;
        for _probe in 0..=self.mask {
            let k = self.keys[i].load(Ordering::Acquire);
            if k == key {
                return self.vals[i].load(Ordering::Relaxed);
            }
            if k == EMPTY {
                return 0;
            }
            i = (i + 1) & self.mask;
        }
        0
    }

    /// Parallel iteration phase: `f(key, count)` for every occupied slot.
    pub fn for_each(&self, f: impl Fn(u64, u64) + Sync) {
        parallel_for_chunks(self.keys.len(), |r| {
            for i in r {
                let k = self.keys[i].load(Ordering::Acquire);
                if k != EMPTY {
                    f(k, self.vals[i].load(Ordering::Relaxed));
                }
            }
        });
    }

    /// Drain to a vector of `(key, count)` pairs (unordered).
    pub fn to_vec(&self) -> Vec<(u64, u64)> {
        let t = num_threads();
        if t <= 1 {
            let mut out = Vec::new();
            for i in 0..self.keys.len() {
                let k = self.keys[i].load(Ordering::Acquire);
                if k != EMPTY {
                    out.push((k, self.vals[i].load(Ordering::Relaxed)));
                }
            }
            return out;
        }
        let out = std::sync::Mutex::new(Vec::new());
        parallel_for_chunks(self.keys.len(), |r| {
            let mut local = Vec::new();
            for i in r {
                let k = self.keys[i].load(Ordering::Acquire);
                if k != EMPTY {
                    local.push((k, self.vals[i].load(Ordering::Relaxed)));
                }
            }
            // Collector mutex: a poisoning panic is already being
            // propagated by the pool, so recover the guard either way.
            out.lock().unwrap_or_else(|p| p.into_inner()).extend(local);
        });
        out.into_inner().unwrap_or_else(|p| p.into_inner())
    }

    /// Number of occupied slots (iteration-phase exact).
    pub fn len(&self) -> usize {
        (0..self.keys.len())
            .filter(|&i| self.keys[i].load(Ordering::Acquire) != EMPTY)
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Pack two `u32` ids into a `u64` key (order-sensitive).
#[inline]
pub fn pack(a: u32, b: u32) -> u64 {
    ((a as u64) << 32) | b as u64
}

/// Unpack a `u64` key into two `u32` ids.
#[inline]
pub fn unpack(k: u64) -> (u32, u32) {
    ((k >> 32) as u32, k as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::pool::{parallel_for, with_threads};
    use std::collections::HashMap;

    #[test]
    fn concurrent_adds_are_exact() {
        for t in [1, 2, 4, 8] {
            with_threads(t, || {
                let table = CountTable::with_capacity(1000);
                // 100k inserts over 1000 distinct keys.
                parallel_for(100_000, |i| {
                    table.insert_add((i % 1000) as u64, 1);
                });
                for k in 0..1000u64 {
                    assert_eq!(table.get(k), 100, "key {k} threads {t}");
                }
            });
        }
    }

    #[test]
    fn matches_hashmap_model() {
        let mut r = crate::prims::rng::Pcg32::new(11);
        let mut model: HashMap<u64, u64> = HashMap::new();
        let table = CountTable::with_capacity(5000);
        for _ in 0..20_000 {
            let k = r.next_below(5000);
            let d = r.next_below(7) + 1;
            *model.entry(k).or_insert(0) += d;
            table.insert_add(k, d);
        }
        assert_eq!(table.len(), model.len());
        for (k, v) in &model {
            assert_eq!(table.get(*k), *v);
        }
        let mut drained = table.to_vec();
        drained.sort_unstable();
        let mut expect: Vec<(u64, u64)> = model.into_iter().collect();
        expect.sort_unstable();
        assert_eq!(drained, expect);
    }

    #[test]
    fn get_absent_is_zero() {
        let table = CountTable::with_capacity(16);
        table.insert_add(3, 5);
        assert_eq!(table.get(4), 0);
        assert_eq!(table.get(3), 5);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for (a, b) in [(0, 0), (1, 2), (u32::MAX, 0), (12345, u32::MAX - 1)] {
            assert_eq!(unpack(pack(a, b)), (a, b));
        }
        assert_ne!(pack(1, 2), pack(2, 1));
    }

    #[test]
    #[should_panic(expected = "CountTable full")]
    fn overflow_panics() {
        let table = CountTable::with_capacity(2); // cap 8
        for k in 0..9 {
            table.insert_add(k, 1);
        }
    }
}
