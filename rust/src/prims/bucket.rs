//! Bucketing structures shared by peeling and ranking.
//!
//! Originally `peel::bucket`; hoisted into `prims` so that both the
//! PEEL-V/PEEL-E round loops *and* the bucket-parallel co-degeneracy
//! ranking (`rank::co_degeneracy`) drive the same lazy-bucket
//! machinery instead of each growing its own.  `peel` re-exports this
//! module, so existing `peel::bucket::...` paths keep resolving.
//!
//! [`BucketStruct`] is the interface the peeling loops drive: pop the
//! minimum-count bucket (finalizing its members), push decreased counts
//! back.  Two implementations:
//!
//! * [`JulienneBuckets`] — the Dhulipala et al. structure the paper's
//!   implementation uses: 128 materialized buckets above a moving base,
//!   an overflow set for counts beyond the window, lazy (possibly
//!   stale) entries filtered on extraction, and the paper's
//!   **skip-ahead** optimization — when the window empties, the next
//!   base jumps straight to the minimum overflow count instead of
//!   scanning empty buckets (this is where the 30696x win of Table 4
//!   comes from).
//! * [`FibBuckets`] — §5.4: one Fibonacci-heap node per *distinct*
//!   count, keyed by count, holding the bucket's members; a
//!   supplemental hash map from count to heap handle aggregates equal
//!   counts (Algorithm 11).  Work-efficient: no empty buckets are ever
//!   touched.
//!
//! Shared semantics: items are `0..n`; counts only decrease; an item's
//! *current* count lives in the structure's `cur` array; finalized
//! items ignore further updates.  `update` clamps to the threshold of
//! the bucket being processed by the caller (peeling convention: counts
//! never drop below the current peel value `k`).

use std::collections::HashMap;

use super::fibheap::{FibHeap, Handle};

/// Driver interface for the peeling loops.
pub trait BucketStruct {
    /// Build over items `0..counts.len()` with initial counts.
    fn new(counts: &[u64]) -> Self
    where
        Self: Sized;
    /// Extract all items with the minimum current count; marks them
    /// finalized.  Returns `(count, items)`, or None when drained.
    fn pop_min(&mut self) -> Option<(u64, Vec<u32>)>;
    /// Decrease `item`'s count to `new_count` (no-op on finalized
    /// items; `new_count` must be <= the current count).
    fn update(&mut self, item: u32, new_count: u64);
    /// Current count of an item.
    fn current(&self, item: u32) -> u64;
    /// Items not yet finalized.
    fn remaining(&self) -> usize;
}

/// Number of materialized buckets per window (Julienne uses 128).
const WINDOW: u64 = 128;

/// Julienne-style bucketing with skip-ahead.
pub struct JulienneBuckets {
    cur: Vec<u64>,
    finalized: Vec<bool>,
    base: u64,
    /// `window[i]` holds items believed to have count `base + i`
    /// (lazy: verified on pop).
    window: Vec<Vec<u32>>,
    /// Items with count >= base + WINDOW (lazy).
    overflow: Vec<u32>,
    remaining: usize,
}

impl JulienneBuckets {
    fn materialize(&mut self, new_base: u64) {
        self.base = new_base;
        let overflow = std::mem::take(&mut self.overflow);
        for item in overflow {
            if self.finalized[item as usize] {
                continue;
            }
            let c = self.cur[item as usize];
            debug_assert!(c >= self.base, "skip-ahead base above a live count");
            if c < self.base + WINDOW {
                self.window[(c - self.base) as usize].push(item);
            } else {
                self.overflow.push(item);
            }
        }
    }
}

impl BucketStruct for JulienneBuckets {
    fn new(counts: &[u64]) -> Self {
        let n = counts.len();
        let base = counts.iter().copied().min().unwrap_or(0);
        let mut s = Self {
            cur: counts.to_vec(),
            finalized: vec![false; n],
            base,
            window: (0..WINDOW).map(|_| Vec::new()).collect(),
            overflow: Vec::new(),
            remaining: n,
        };
        for (item, &c) in counts.iter().enumerate() {
            if c < base + WINDOW {
                s.window[(c - base) as usize].push(item as u32);
            } else {
                s.overflow.push(item as u32);
            }
        }
        s
    }

    fn pop_min(&mut self) -> Option<(u64, Vec<u32>)> {
        if self.remaining == 0 {
            return None;
        }
        loop {
            // Scan the materialized window.
            for i in 0..WINDOW {
                let c = self.base + i;
                if self.window[i as usize].is_empty() {
                    continue;
                }
                let entries = std::mem::take(&mut self.window[i as usize]);
                let mut valid = Vec::new();
                for item in entries {
                    let idx = item as usize;
                    if self.finalized[idx] {
                        continue;
                    }
                    let cc = self.cur[idx];
                    if cc == c {
                        self.finalized[idx] = true;
                        valid.push(item);
                    } else {
                        // Stale entry: the live entry sits in a later
                        // bucket or in overflow (updates always
                        // re-push), drop this one.  cc < c cannot
                        // happen: peeling clamps updates to >= the
                        // current threshold, which is >= base.
                        debug_assert!(cc > c, "update below the current threshold");
                    }
                }
                if !valid.is_empty() {
                    self.remaining -= valid.len();
                    return Some((c, valid));
                }
            }
            // Window exhausted: skip ahead to the minimum live
            // overflow count (the Table 4 optimization).
            let min_over = self
                .overflow
                .iter()
                .filter(|&&it| !self.finalized[it as usize])
                .map(|&it| self.cur[it as usize])
                .min();
            match min_over {
                Some(mb) => self.materialize(mb),
                None => {
                    debug_assert_eq!(self.remaining, 0);
                    return None;
                }
            }
        }
    }

    fn update(&mut self, item: u32, new_count: u64) {
        let idx = item as usize;
        if self.finalized[idx] || new_count == self.cur[idx] {
            return;
        }
        debug_assert!(new_count < self.cur[idx], "counts only decrease");
        self.cur[idx] = new_count;
        if new_count < self.base + WINDOW {
            let slot = new_count.saturating_sub(self.base);
            self.window[slot as usize].push(item);
        } else {
            self.overflow.push(item);
        }
    }

    fn current(&self, item: u32) -> u64 {
        self.cur[item as usize]
    }

    fn remaining(&self) -> usize {
        self.remaining
    }
}

/// Fibonacci-heap bucketing (§5.4, Algorithm 11).
pub struct FibBuckets {
    cur: Vec<u64>,
    finalized: Vec<bool>,
    heap: FibHeap<Vec<u32>>,
    /// count -> heap node holding that bucket (supplemental table T).
    by_count: HashMap<u64, Handle>,
    remaining: usize,
}

impl FibBuckets {
    fn push_item(&mut self, count: u64, item: u32) {
        match self.by_count.get(&count) {
            Some(&h) => self.heap.value_mut(h).push(item),
            None => {
                let h = self.heap.insert(count, vec![item]);
                self.by_count.insert(count, h);
            }
        }
    }
}

impl BucketStruct for FibBuckets {
    fn new(counts: &[u64]) -> Self {
        let n = counts.len();
        let mut s = Self {
            cur: counts.to_vec(),
            finalized: vec![false; n],
            heap: FibHeap::new(),
            by_count: HashMap::new(),
            remaining: n,
        };
        // Group items by count, then batch-insert one node per count.
        let mut groups: HashMap<u64, Vec<u32>> = HashMap::new();
        for (item, &c) in counts.iter().enumerate() {
            groups.entry(c).or_default().push(item as u32);
        }
        let items: Vec<(u64, Vec<u32>)> = groups.into_iter().collect();
        let keys: Vec<u64> = items.iter().map(|(k, _)| *k).collect();
        let handles = s.heap.batch_insert(items);
        for (k, h) in keys.into_iter().zip(handles) {
            s.by_count.insert(k, h);
        }
        s
    }

    fn pop_min(&mut self) -> Option<(u64, Vec<u32>)> {
        while let Some((count, bucket)) = self.heap.delete_min() {
            self.by_count.remove(&count);
            // Lazy filtering: entries may be stale (moved buckets) or
            // finalized.
            let valid: Vec<u32> = bucket
                .into_iter()
                .filter(|&it| {
                    let idx = it as usize;
                    !self.finalized[idx] && self.cur[idx] == count
                })
                .collect();
            if !valid.is_empty() {
                for &it in &valid {
                    self.finalized[it as usize] = true;
                }
                self.remaining -= valid.len();
                return Some((count, valid));
            }
        }
        None
    }

    fn update(&mut self, item: u32, new_count: u64) {
        let idx = item as usize;
        if self.finalized[idx] || new_count == self.cur[idx] {
            return;
        }
        debug_assert!(new_count < self.cur[idx], "counts only decrease");
        self.cur[idx] = new_count;
        // Algorithm 11 moves the value to the bucket keyed new_count,
        // creating it via heap insert if absent; the old entry is
        // left to lazy filtering (the decrease-key fast path for the
        // all-items-move case is handled by the same mechanism).
        self.push_item(new_count, item);
    }

    fn current(&self, item: u32) -> u64 {
        self.cur[item as usize]
    }

    fn remaining(&self) -> usize {
        self.remaining
    }
}

/// Descending lazy-bucket walk for *max-first* peeling orders (the
/// co-degeneracy rankings of §4.6): items `0..n` carry small integer
/// keys that only **decrease**; [`MaxBuckets::pop_max`] claims every
/// live item currently holding the maximum key — one ranking round —
/// with the same lazy re-insertion discipline as [`JulienneBuckets`]
/// (an item is re-pushed on every decrease; stale entries are filtered
/// on extraction).
///
/// Because keys only decrease, the walk never has to revisit a higher
/// bucket: after a round at key `k`, no live item can hold a key above
/// `k`, so the structure visits each bucket index at most once plus
/// one extra take per round — `O(n + max_key + total_updates)` work
/// over a full drain.
pub struct MaxBuckets {
    cur: Vec<u64>,
    finalized: Vec<bool>,
    /// `buckets[k]` holds items believed to have key `k` (lazy).
    buckets: Vec<Vec<u32>>,
    top: isize,
    remaining: usize,
}

impl MaxBuckets {
    /// Build over items `0..keys.len()` with initial keys.
    pub fn new(keys: &[u64]) -> Self {
        let n = keys.len();
        let nb = keys.iter().copied().max().map(|k| k as usize + 1).unwrap_or(0);
        let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); nb];
        for (item, &k) in keys.iter().enumerate() {
            buckets[k as usize].push(item as u32);
        }
        Self {
            cur: keys.to_vec(),
            finalized: vec![false; n],
            buckets,
            top: nb as isize - 1,
            remaining: n,
        }
    }

    /// Claim **all** live items at the current maximum key (marking
    /// them finalized).  Returns `(key, items)` in lazy insertion
    /// order — callers needing a canonical order sort the frontier —
    /// or `None` when drained.
    pub fn pop_max(&mut self) -> Option<(u64, Vec<u32>)> {
        while self.top >= 0 {
            let t = self.top as usize;
            if self.buckets[t].is_empty() {
                self.top -= 1;
                continue;
            }
            let members = std::mem::take(&mut self.buckets[t]);
            // Filter-and-mark in one pass: lazy entries can contain
            // duplicates (re-pushed on every decrease), so an item is
            // claimed the first time it is seen at its live key.
            let mut valid = Vec::new();
            for item in members {
                let idx = item as usize;
                if !self.finalized[idx] && self.cur[idx] == t as u64 {
                    self.finalized[idx] = true;
                    valid.push(item);
                }
            }
            if valid.is_empty() {
                continue; // all stale; the live entries sit lower
            }
            self.remaining -= valid.len();
            return Some((t as u64, valid));
        }
        debug_assert_eq!(self.remaining, 0);
        None
    }

    /// Decrease `item`'s key to `new_key` (no-op on finalized items or
    /// unchanged keys; `new_key` must not exceed the current key).
    pub fn update(&mut self, item: u32, new_key: u64) {
        let idx = item as usize;
        if self.finalized[idx] || new_key == self.cur[idx] {
            return;
        }
        debug_assert!(new_key < self.cur[idx], "keys only decrease");
        self.cur[idx] = new_key;
        self.buckets[new_key as usize].push(item);
    }

    /// Current key of an item.
    pub fn current(&self, item: u32) -> u64 {
        self.cur[item as usize]
    }

    /// Has `item` been claimed by a previous [`Self::pop_max`]?
    pub fn is_finalized(&self, item: u32) -> bool {
        self.finalized[item as usize]
    }

    /// Items not yet finalized.
    pub fn remaining(&self) -> usize {
        self.remaining
    }
}

/// Which bucketing backend a peeling run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BucketKind {
    Julienne,
    FibHeap,
}

impl BucketKind {
    pub const ALL: [BucketKind; 2] = [BucketKind::Julienne, BucketKind::FibHeap];

    pub fn name(&self) -> &'static str {
        match self {
            BucketKind::Julienne => "julienne",
            BucketKind::FibHeap => "fibheap",
        }
    }
}

/// Construct the chosen backend.
pub fn make_buckets(kind: BucketKind, counts: &[u64]) -> Box<dyn BucketStruct> {
    match kind {
        BucketKind::Julienne => Box::new(JulienneBuckets::new(counts)),
        BucketKind::FibHeap => Box::new(FibBuckets::new(counts)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::rng::Pcg32;

    fn drain(kind: BucketKind, counts: &[u64]) -> Vec<(u64, Vec<u32>)> {
        let mut b = make_buckets(kind, counts);
        let mut out = Vec::new();
        while let Some((c, mut items)) = b.pop_min() {
            items.sort_unstable();
            out.push((c, items));
        }
        out
    }

    #[test]
    fn drains_in_count_order() {
        let counts = vec![5u64, 0, 3, 5, 0, 1_000_000, 3];
        for kind in BucketKind::ALL {
            let out = drain(kind, &counts);
            assert_eq!(
                out,
                vec![
                    (0, vec![1, 4]),
                    (3, vec![2, 6]),
                    (5, vec![0, 3]),
                    (1_000_000, vec![5]),
                ],
                "{kind:?}"
            );
        }
    }

    #[test]
    fn skip_ahead_handles_huge_gaps() {
        // Counts far beyond the 128-window force overflow + skip-ahead.
        let counts: Vec<u64> = (0..50).map(|i| i * 1_000_003).collect();
        for kind in BucketKind::ALL {
            let out = drain(kind, &counts);
            assert_eq!(out.len(), 50);
            for (i, (c, items)) in out.iter().enumerate() {
                assert_eq!(*c, i as u64 * 1_000_003);
                assert_eq!(items, &vec![i as u32]);
            }
        }
    }

    #[test]
    fn updates_move_items_between_buckets() {
        for kind in BucketKind::ALL {
            let mut b = make_buckets(kind, &[10, 20, 30, 40]);
            let (c, items) = b.pop_min().unwrap();
            assert_eq!((c, items), (10, vec![0]));
            // Peeling item 0 drops item 2's count to 12, item 3's to 20.
            b.update(2, 12);
            b.update(3, 20);
            assert_eq!(b.pop_min().unwrap(), (12, vec![2]));
            let (c, mut items) = b.pop_min().unwrap();
            items.sort_unstable();
            assert_eq!((c, items), (20, vec![1, 3]));
            assert!(b.pop_min().is_none());
            assert_eq!(b.remaining(), 0);
        }
    }

    #[test]
    fn finalized_items_ignore_updates() {
        for kind in BucketKind::ALL {
            let mut b = make_buckets(kind, &[1, 2]);
            let (_, items) = b.pop_min().unwrap();
            assert_eq!(items, vec![0]);
            b.update(0, 0); // must be ignored
            assert_eq!(b.pop_min().unwrap(), (2, vec![1]));
            assert!(b.pop_min().is_none());
        }
    }

    #[test]
    fn fib_adapter_matches_current_count_oracle() {
        // §5.4 adapter semantics against a direct oracle over the
        // `cur` array: pop_min must return exactly the non-finalized
        // items holding the minimum current count, whatever sequence
        // of lazy re-pushes preceded it.
        let mut rng = Pcg32::new(31);
        for _trial in 0..10 {
            let n = 40usize;
            let counts: Vec<u64> = (0..n).map(|_| rng.next_below(200)).collect();
            let mut fb = FibBuckets::new(&counts);
            let mut cur = counts.clone();
            let mut finalized = vec![false; n];
            let mut k = 0u64;
            while let Some((c, items)) = fb.pop_min() {
                let live_min = (0..n)
                    .filter(|&i| !finalized[i])
                    .map(|i| cur[i])
                    .min()
                    .expect("pop from drained oracle");
                assert_eq!(c, live_min, "popped count is not the live minimum");
                let mut expect: Vec<u32> = (0..n)
                    .filter(|&i| !finalized[i] && cur[i] == live_min)
                    .map(|i| i as u32)
                    .collect();
                let mut got = items.clone();
                got.sort_unstable();
                expect.sort_unstable();
                assert_eq!(got, expect, "popped members differ from oracle");
                for &i in &items {
                    finalized[i as usize] = true;
                }
                k = k.max(c);
                // Random clamped decrements, mirrored into the oracle.
                for _ in 0..rng.next_below(6) {
                    let i = rng.next_below(n as u64) as usize;
                    if finalized[i] || cur[i] <= k {
                        continue;
                    }
                    let nc = k + rng.next_below(cur[i] - k);
                    fb.update(i as u32, nc);
                    cur[i] = nc;
                }
            }
            assert!(finalized.iter().all(|&f| f), "drain left live items");
        }
    }

    #[test]
    fn max_buckets_drain_in_descending_rounds() {
        let keys = vec![5u64, 0, 3, 5, 0, 9, 3];
        let mut mb = MaxBuckets::new(&keys);
        let mut out = Vec::new();
        while let Some((k, mut items)) = mb.pop_max() {
            items.sort_unstable();
            out.push((k, items));
        }
        assert_eq!(
            out,
            vec![(9, vec![5]), (5, vec![0, 3]), (3, vec![2, 6]), (0, vec![1, 4])]
        );
        assert_eq!(mb.remaining(), 0);
    }

    #[test]
    fn max_buckets_lazy_updates_match_oracle() {
        // pop_max must always return exactly the live items at the
        // maximum current key, under random clamped decreases mirrored
        // into a direct oracle over the `cur` array.
        let mut rng = Pcg32::new(93);
        for _trial in 0..10 {
            let n = 50usize;
            let keys: Vec<u64> = (0..n).map(|_| rng.next_below(40)).collect();
            let mut mb = MaxBuckets::new(&keys);
            let mut cur = keys.clone();
            let mut finalized = vec![false; n];
            while let Some((k, items)) = mb.pop_max() {
                let live_max = (0..n)
                    .filter(|&i| !finalized[i])
                    .map(|i| cur[i])
                    .max()
                    .expect("pop from drained oracle");
                assert_eq!(k, live_max, "popped key is not the live maximum");
                let mut expect: Vec<u32> = (0..n)
                    .filter(|&i| !finalized[i] && cur[i] == live_max)
                    .map(|i| i as u32)
                    .collect();
                let mut got = items.clone();
                got.sort_unstable();
                expect.sort_unstable();
                assert_eq!(got, expect, "popped members differ from oracle");
                for &i in &items {
                    finalized[i as usize] = true;
                }
                // Random decreases on survivors (keys may only drop).
                for _ in 0..rng.next_below(8) {
                    let i = rng.next_below(n as u64) as usize;
                    if finalized[i] || cur[i] == 0 {
                        continue;
                    }
                    let nk = rng.next_below(cur[i]);
                    mb.update(i as u32, nk);
                    cur[i] = nk;
                }
            }
            assert!(finalized.iter().all(|&f| f), "drain left live items");
        }
    }

    #[test]
    fn max_buckets_ignores_finalized_and_equal_updates() {
        let mut mb = MaxBuckets::new(&[2, 1]);
        let (k, items) = mb.pop_max().unwrap();
        assert_eq!((k, items), (2, vec![0]));
        mb.update(0, 0); // finalized: ignored
        mb.update(1, 1); // equal key: ignored
        assert_eq!(mb.pop_max().unwrap(), (1, vec![1]));
        assert!(mb.pop_max().is_none());
    }

    #[test]
    fn max_buckets_empty() {
        let mut mb = MaxBuckets::new(&[]);
        assert!(mb.pop_max().is_none());
        assert_eq!(mb.remaining(), 0);
    }

    #[test]
    fn randomized_model_equivalence() {
        // Both backends must produce identical pop sequences under an
        // identical random update schedule.
        let mut rng = Pcg32::new(55);
        for _trial in 0..10 {
            let n = 60usize;
            let counts: Vec<u64> = (0..n).map(|_| rng.next_below(300)).collect();
            let mut jb = JulienneBuckets::new(&counts);
            let mut fb = FibBuckets::new(&counts);
            let mut schedule_rng = rng.split(7);
            loop {
                let ja = jb.pop_min();
                let fa = fb.pop_min();
                let (jc, mut jitems) = match (ja, fa) {
                    (None, None) => break,
                    (Some((jc, ji)), Some((fc, fi))) => {
                        assert_eq!(jc, fc);
                        let mut fi2 = fi.clone();
                        fi2.sort_unstable();
                        let mut ji2 = ji.clone();
                        ji2.sort_unstable();
                        assert_eq!(ji2, fi2);
                        (jc, ji2)
                    }
                    other => panic!("backend divergence: {other:?}"),
                };
                jitems.sort_unstable();
                // Random decrements to survivors, identical for both.
                for _ in 0..schedule_rng.next_below(8) {
                    let item = schedule_rng.next_below(n as u64) as u32;
                    let cur = jb.current(item);
                    if cur > jc {
                        let nc = jc + schedule_rng.next_below(cur - jc + 1).min(cur - jc);
                        jb.update(item, nc);
                        fb.update(item, nc);
                    }
                }
            }
        }
    }
}
