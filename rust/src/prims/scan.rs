//! Parallel prefix sum, filter, pack, and sorted-dedup.
//!
//! Classic two-pass blocked scan: per-block sums, sequential scan of the
//! (tiny) block-sum array, then a parallel down-sweep.  `O(n)` work,
//! `O(log n)` span with the usual block-count caveat.

use super::pool::{num_threads, parallel_for_blocks, SyncPtr};

/// Exclusive prefix sum of `a`; returns `(sums, total)` where
/// `sums[i] = a[0] + ... + a[i-1]`.
pub fn prefix_sum(a: &[usize]) -> (Vec<usize>, usize) {
    let n = a.len();
    if n == 0 {
        return (Vec::new(), 0);
    }
    let t = num_threads();
    if t <= 1 || n < 4096 {
        let mut out = Vec::with_capacity(n);
        let mut acc = 0usize;
        for &x in a {
            out.push(acc);
            acc += x;
        }
        return (out, acc);
    }
    let nblocks = t.min(n);
    let block = n.div_ceil(nblocks);
    // Pass 1: per-block sums.
    let mut block_sums = vec![0usize; nblocks];
    {
        let bs = SyncPtr(block_sums.as_mut_ptr());
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let mut s = 0usize;
            for i in lo..hi {
                s += a[i];
            }
            unsafe { *bs.get().add(b) = s };
        });
    }
    // Scan block sums sequentially (nblocks == #threads, tiny).
    let mut acc = 0usize;
    let mut block_offsets = vec![0usize; nblocks];
    for b in 0..nblocks {
        block_offsets[b] = acc;
        acc += block_sums[b];
    }
    let total = acc;
    // Pass 2: down-sweep.
    let mut out = vec![0usize; n];
    {
        let op = SyncPtr(out.as_mut_ptr());
        let offs = &block_offsets;
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let mut s = offs[b];
            for i in lo..hi {
                unsafe { *op.get().add(i) = s };
                s += a[i];
            }
        });
    }
    (out, total)
}

/// Parallel filter: elements of `a` satisfying `pred`, order preserved.
pub fn filter<T: Clone + Send + Sync>(a: &[T], pred: impl Fn(&T) -> bool + Sync) -> Vec<T> {
    let n = a.len();
    let t = num_threads();
    if t <= 1 || n < 4096 {
        return a.iter().filter(|x| pred(x)).cloned().collect();
    }
    let nblocks = t.min(n);
    let block = n.div_ceil(nblocks);
    let mut counts = vec![0usize; nblocks];
    {
        let cp = SyncPtr(counts.as_mut_ptr());
        let pred = &pred;
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let c = a[lo..hi].iter().filter(|x| pred(x)).count();
            unsafe { *cp.get().add(b) = c };
        });
    }
    let (offsets, total) = prefix_sum(&counts);
    let mut out: Vec<T> = Vec::with_capacity(total);
    {
        let op = SyncPtr(out.as_mut_ptr());
        let pred = &pred;
        let offsets = &offsets;
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let mut w = offsets[b];
            for x in &a[lo..hi] {
                if pred(x) {
                    unsafe { std::ptr::write(op.get().add(w), x.clone()) };
                    w += 1;
                }
            }
        });
    }
    // SAFETY: length adopted only after every slot was written — a
    // panicking clone()/pred() mid-scatter leaks the written clones
    // instead of dropping uninitialized slots.
    unsafe { out.set_len(total) };
    out
}

/// Remove adjacent duplicates from a **sorted** vector in parallel
/// (scan-based compaction): keep flags compare each slot with its
/// predecessor, per-block survivor counts are prefix-summed, and
/// survivors scatter to their final positions.  Equivalent to
/// `Vec::dedup` on sorted input, `O(n)` work, one scan of span.
pub fn dedup_sorted<T: PartialEq + Clone + Send + Sync>(v: Vec<T>) -> Vec<T> {
    let n = v.len();
    let t = num_threads();
    if t <= 1 || n < 4096 {
        let mut v = v;
        v.dedup();
        return v;
    }
    let keep = |i: usize| i == 0 || v[i] != v[i - 1];
    let nblocks = t.min(n);
    let block = n.div_ceil(nblocks);
    let mut counts = vec![0usize; nblocks];
    {
        let cp = SyncPtr(counts.as_mut_ptr());
        let keep = &keep;
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let c = (lo..hi).filter(|&i| keep(i)).count();
            unsafe { *cp.get().add(b) = c };
        });
    }
    let (offsets, total) = prefix_sum(&counts);
    let mut out: Vec<T> = Vec::with_capacity(total);
    {
        let op = SyncPtr(out.as_mut_ptr());
        let keep = &keep;
        let offsets = &offsets;
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let mut w = offsets[b];
            for i in lo..hi {
                if keep(i) {
                    unsafe { std::ptr::write(op.get().add(w), v[i].clone()) };
                    w += 1;
                }
            }
        });
    }
    // SAFETY: length adopted only after every slot was written — a
    // panicking clone()/eq() mid-scatter leaks the written clones
    // instead of dropping uninitialized slots.
    unsafe { out.set_len(total) };
    out
}

/// Indices `i` in `0..n` with `pred(i)`, in increasing order.
pub fn pack_indices(n: usize, pred: impl Fn(usize) -> bool + Sync) -> Vec<usize> {
    let t = num_threads();
    if t <= 1 || n < 4096 {
        return (0..n).filter(|&i| pred(i)).collect();
    }
    let nblocks = t.min(n);
    let block = n.div_ceil(nblocks);
    let mut counts = vec![0usize; nblocks];
    {
        let cp = SyncPtr(counts.as_mut_ptr());
        let pred = &pred;
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let c = (lo..hi).filter(|&i| pred(i)).count();
            unsafe { *cp.get().add(b) = c };
        });
    }
    let (offsets, total) = prefix_sum(&counts);
    let mut out = vec![0usize; total];
    {
        let op = SyncPtr(out.as_mut_ptr());
        let pred = &pred;
        let offsets = &offsets;
        parallel_for_blocks(nblocks, |b| {
            let lo = b * block;
            let hi = ((b + 1) * block).min(n);
            let mut w = offsets[b];
            for i in lo..hi {
                if pred(i) {
                    unsafe { *op.get().add(w) = i };
                    w += 1;
                }
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::pool::with_threads;

    #[test]
    fn prefix_sum_matches_sequential() {
        for t in [1, 2, 4] {
            with_threads(t, || {
                let a: Vec<usize> = (0..10_000).map(|i| (i * 7 + 3) % 11).collect();
                let (sums, total) = prefix_sum(&a);
                let mut acc = 0;
                for i in 0..a.len() {
                    assert_eq!(sums[i], acc, "index {i}");
                    acc += a[i];
                }
                assert_eq!(total, acc);
            });
        }
    }

    #[test]
    fn prefix_sum_empty() {
        let (s, t) = prefix_sum(&[]);
        assert!(s.is_empty());
        assert_eq!(t, 0);
    }

    #[test]
    fn filter_preserves_order() {
        for t in [1, 3] {
            with_threads(t, || {
                let a: Vec<u32> = (0..20_000).collect();
                let f = filter(&a, |x| x % 3 == 0);
                let expect: Vec<u32> = (0..20_000).filter(|x| x % 3 == 0).collect();
                assert_eq!(f, expect);
            });
        }
    }

    #[test]
    fn dedup_sorted_matches_vec_dedup() {
        for t in [1, 2, 4] {
            with_threads(t, || {
                for n in [0usize, 1, 100, 5000, 30_000] {
                    let mut a: Vec<u64> = (0..n as u64).map(|i| (i * 7 + 3) % 997).collect();
                    a.sort_unstable();
                    let mut expect = a.clone();
                    expect.dedup();
                    assert_eq!(dedup_sorted(a), expect, "n={n} t={t}");
                }
                // All-equal and all-distinct extremes.
                assert_eq!(dedup_sorted(vec![9u64; 20_000]), vec![9u64]);
                let distinct: Vec<u64> = (0..20_000).collect();
                assert_eq!(dedup_sorted(distinct.clone()), distinct);
            });
        }
    }

    #[test]
    fn pack_indices_matches_filter() {
        for t in [1, 4] {
            with_threads(t, || {
                let idx = pack_indices(9_999, |i| i % 7 == 2);
                let expect: Vec<usize> = (0..9_999).filter(|i| i % 7 == 2).collect();
                assert_eq!(idx, expect);
            });
        }
    }
}
