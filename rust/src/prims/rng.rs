//! Small deterministic PRNG (PCG32) — graph generators, sparsification,
//! ranking tie-breaks, and the property-test harness all need seeded,
//! splittable randomness; no `rand` crate is available offline.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014): O(1) per draw, 64-bit state with
//! per-stream increments, so [`Pcg32::split`] hands independent
//! deterministic streams to parallel workers — the property the
//! sparsification estimators (§4.4) rely on for reproducible seeds.

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut s = Self { state: 0, inc: (stream << 1) | 1 };
        s.next_u32();
        s.state = s.state.wrapping_add(seed);
        s.next_u32();
        s
    }

    /// Derive an independent generator (new stream) — used to hand each
    /// parallel worker its own deterministic sequence.
    pub fn split(&mut self, salt: u64) -> Pcg32 {
        Pcg32::with_stream(self.next_u64() ^ salt, salt.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire rejection-free approximation is
    /// fine for our purposes; we use the widening-multiply method).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

/// 64-bit finalizer (splitmix64) — used as the hash for hash tables,
/// histograms, and colorful sparsification.
#[inline]
pub fn hash64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(123);
        let mut b = Pcg32::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_roughly_uniform() {
        let mut r = Pcg32::new(99);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut base = Pcg32::new(5);
        let mut s1 = base.split(1);
        let mut s2 = base.split(2);
        let same = (0..64).filter(|_| s1.next_u32() == s2.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn hash64_mixes() {
        assert_ne!(hash64(0), 0);
        assert_ne!(hash64(1), hash64(2));
        // Avalanche sanity: flipping one input bit flips ~half the output.
        let a = hash64(0x1234_5678);
        let b = hash64(0x1234_5679);
        let flips = (a ^ b).count_ones();
        assert!((16..=48).contains(&flips), "flips={flips}");
    }
}
