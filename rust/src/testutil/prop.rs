//! Mini property-test harness (offline stand-in for `proptest`).
//!
//! ```no_run
//! use parbutterfly::testutil::prop::{check, prop_assert, Gen};
//! check("sum is commutative", 100, |g| {
//!     let a = g.u64_below(1000);
//!     let b = g.u64_below(1000);
//!     prop_assert(a + b == b + a, format!("{a} {b}"))
//! });
//! ```
//!
//! On failure the panic message carries the iteration seed, so a case
//! reproduces with `Gen::from_seed(seed)`.

use crate::graph::gen as graph_gen;
use crate::graph::BipartiteGraph;
use crate::prims::rng::Pcg32;

/// Random-input source handed to each property iteration.
pub struct Gen {
    rng: Pcg32,
    seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Self { rng: Pcg32::new(seed), seed }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn u64_below(&mut self, bound: u64) -> u64 {
        self.rng.next_below(bound)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_bool(p)
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.next_below(items.len() as u64) as usize]
    }

    /// A small random bipartite graph drawn from a random family —
    /// ER, Chung-Lu, planted blocks, or complete — so properties see
    /// regular, skewed, clustered, and extremal inputs.
    pub fn bipartite(&mut self, max_side: usize, max_m: usize) -> BipartiteGraph {
        let nu = self.usize_in(1, max_side);
        let nv = self.usize_in(1, max_side);
        let m = self.usize_in(0, max_m);
        match self.u64_below(4) {
            0 => graph_gen::erdos_renyi(nu, nv, m, self.rng.next_u64()),
            1 => graph_gen::chung_lu(nu, nv, m, 1.8 + self.f64_unit(), self.rng.next_u64()),
            2 => {
                let k = self.usize_in(1, 3);
                let bu = (nu / k).max(1);
                let bv = (nv / k).max(1);
                graph_gen::planted_blocks(
                    k * bu.max(1),
                    k * bv.max(1),
                    k,
                    bu,
                    bv,
                    0.5 + self.f64_unit() / 2.0,
                    m / 4,
                    self.rng.next_u64(),
                )
            }
            _ => graph_gen::complete_bipartite(nu.min(8).max(1), nv.min(8).max(1)),
        }
    }
}

/// Run `body` for `iters` seeded iterations; panics with the seed on
/// the first failure.
pub fn check(name: &str, iters: u64, mut body: impl FnMut(&mut Gen) -> Result<(), String>) {
    // Derive per-iteration seeds from the property name so adding
    // properties doesn't reshuffle others' cases.
    let base = crate::prims::rng::hash64(name.len() as u64 ^ name.bytes().map(u64::from).sum::<u64>());
    for i in 0..iters {
        let seed = base.wrapping_add(i.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut g = Gen::from_seed(seed);
        if let Err(msg) = body(&mut g) {
            panic!("property '{name}' failed at iteration {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// `assert!` that returns an Err for use inside [`check`] bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Equality assertion with debug formatting.
pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{a:?} != {b:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut count = 0u64;
        check("trivially true", 25, |_g| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn failing_property_reports_seed() {
        check("always fails", 5, |_g| Err("nope".into()));
    }

    #[test]
    fn generated_graphs_are_valid() {
        check("graphs within bounds", 30, |g| {
            let bg = g.bipartite(20, 100);
            prop_assert(bg.nu() >= 1 && bg.nv() >= 1, "side empty")?;
            // CSR self-consistency: every edge visible from both sides.
            for u in 0..bg.nu() {
                for &v in bg.nbrs_u(u) {
                    prop_assert(
                        bg.nbrs_v(v as usize).contains(&(u as u32)),
                        format!("edge ({u},{v}) missing from V side"),
                    )?;
                }
            }
            Ok(())
        });
    }
}
