//! Sequential reference implementation of the co-degeneracy orderings.
//!
//! An independent oracle for the bucket-parallel
//! `rank::co_degeneracy` rounds: no bucket structure, no laziness —
//! each round scans the live vertices for the maximum (log-)degree,
//! claims that whole frontier in increasing vertex id (the canonical
//! intra-round tie-break), then applies the degree decrements
//! edge by edge.  `O(n * rounds)` — fine at test scale, never used on
//! the production path.

use crate::graph::BipartiteGraph;

/// `rank_of` under max-first (log-)degree round peeling, canonical
/// gid-ascending order within each round.
pub fn co_degeneracy_seq(g: &BipartiteGraph, approx: bool) -> Vec<u32> {
    let n = g.n();
    let nu = g.nu();
    let bucket_of = |d: u64| crate::rank::codeg_bucket_of(d, approx);
    let mut deg: Vec<u64> = (0..n)
        .map(|gid| if gid < nu { g.deg_u(gid) } else { g.deg_v(gid - nu) } as u64)
        .collect();
    let mut live = vec![true; n];
    let mut rank = vec![0u32; n];
    let mut next_rank = 0u32;
    let mut remaining = n;
    while remaining > 0 {
        let top = (0..n).filter(|&i| live[i]).map(|i| bucket_of(deg[i])).max().unwrap();
        let frontier: Vec<usize> =
            (0..n).filter(|&i| live[i] && bucket_of(deg[i]) == top).collect();
        for &x in &frontier {
            live[x] = false;
            rank[x] = next_rank;
            next_rank += 1;
        }
        remaining -= frontier.len();
        for &x in &frontier {
            if x < nu {
                for &v in g.nbrs_u(x) {
                    let wg = nu + v as usize;
                    if live[wg] {
                        deg[wg] -= 1;
                    }
                }
            } else {
                for &u in g.nbrs_v(x - nu) {
                    let wg = u as usize;
                    if live[wg] {
                        deg[wg] -= 1;
                    }
                }
            }
        }
    }
    rank
}
