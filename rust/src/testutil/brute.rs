//! Brute-force butterfly oracles — the ground truth every framework
//! configuration is checked against.  All are O(n^2 m)-ish or worse;
//! use on small graphs only.

use crate::graph::BipartiteGraph;

/// Wedge multiplicity of the U-side pair `(u1, u2)`: `|N(u1) ∩ N(u2)|`
/// (sorted-merge intersection).
fn common_nbrs(a: &[u32], b: &[u32]) -> u64 {
    let (mut i, mut j, mut c) = (0, 0, 0u64);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

/// Total butterflies: `sum_{u1 < u2} C(|N(u1) ∩ N(u2)|, 2)`.
pub fn total(g: &BipartiteGraph) -> u64 {
    let mut b = 0u64;
    for u1 in 0..g.nu() {
        for u2 in (u1 + 1)..g.nu() {
            let c = common_nbrs(g.nbrs_u(u1), g.nbrs_u(u2));
            b += c * c.saturating_sub(1) / 2;
        }
    }
    b
}

/// Per-vertex butterfly counts `(b_u, b_v)`.
pub fn per_vertex(g: &BipartiteGraph) -> (Vec<u64>, Vec<u64>) {
    let mut bu = vec![0u64; g.nu()];
    let mut bv = vec![0u64; g.nv()];
    for u1 in 0..g.nu() {
        for u2 in (u1 + 1)..g.nu() {
            let c = common_nbrs(g.nbrs_u(u1), g.nbrs_u(u2));
            let b = c * c.saturating_sub(1) / 2;
            bu[u1] += b;
            bu[u2] += b;
        }
    }
    for v1 in 0..g.nv() {
        for v2 in (v1 + 1)..g.nv() {
            let c = common_nbrs(g.nbrs_v(v1), g.nbrs_v(v2));
            let b = c * c.saturating_sub(1) / 2;
            bv[v1] += b;
            bv[v2] += b;
        }
    }
    (bu, bv)
}

/// Per-edge butterfly counts, indexed by edge id.
pub fn per_edge(g: &BipartiteGraph) -> Vec<u64> {
    let mut be = vec![0u64; g.m()];
    for u1 in 0..g.nu() {
        for (i, &v1) in g.nbrs_u(u1).iter().enumerate() {
            let eid = g.eid_u(u1, i) as usize;
            // Butterflies on (u1, v1): u2 in N(v1)\{u1}, common
            // neighbors of u1, u2 other than v1.
            let mut b = 0u64;
            for &u2 in g.nbrs_v(v1 as usize) {
                if u2 as usize == u1 {
                    continue;
                }
                let c = common_nbrs(g.nbrs_u(u1), g.nbrs_u(u2 as usize));
                b += c.saturating_sub(1); // v1 itself is always common
            }
            be[eid] = b;
        }
    }
    be
}

/// Tip numbers of U-side vertices by literal sequential peeling with
/// full recount each step (the definition, not an algorithm).
pub fn tip_numbers_u(g: &BipartiteGraph) -> Vec<u64> {
    let nu = g.nu();
    let mut alive = vec![true; nu];
    let mut tip = vec![0u64; nu];
    let mut k = 0u64;
    let mut remaining = nu;
    while remaining > 0 {
        // Butterfly counts among alive U vertices.
        let mut counts = vec![0u64; nu];
        for u1 in 0..nu {
            if !alive[u1] {
                continue;
            }
            for u2 in (u1 + 1)..nu {
                if !alive[u2] {
                    continue;
                }
                let c = common_nbrs(g.nbrs_u(u1), g.nbrs_u(u2));
                let b = c * c.saturating_sub(1) / 2;
                counts[u1] += b;
                counts[u2] += b;
            }
        }
        let min = (0..nu).filter(|&u| alive[u]).map(|u| counts[u]).min().unwrap();
        k = k.max(min);
        for u in 0..nu {
            if alive[u] && counts[u] == min {
                tip[u] = k;
                alive[u] = false;
                remaining -= 1;
            }
        }
    }
    tip
}

/// Wing numbers of edges by literal sequential peeling with full
/// recount each step.
pub fn wing_numbers(g: &BipartiteGraph) -> Vec<u64> {
    let m = g.m();
    let mut alive = vec![true; m];
    let mut wing = vec![0u64; m];
    let mut k = 0u64;
    let mut remaining = m;
    let edges = g.edges();
    // counts butterflies on each alive edge, only via alive edges.
    let count_edge = |alive: &[bool]| -> Vec<u64> {
        let mut be = vec![0u64; m];
        for (eid, &(u1, v1)) in edges.iter().enumerate() {
            if !alive[eid] {
                continue;
            }
            let mut b = 0u64;
            for (j, &u2) in g.nbrs_v(v1 as usize).iter().enumerate() {
                if u2 == u1 {
                    continue;
                }
                let e2 = g.eids_v(v1 as usize)[j];
                if !alive[e2 as usize] {
                    continue;
                }
                // common alive-edge neighbors of u1, u2 besides v1.
                for &v2 in g.nbrs_u(u1 as usize) {
                    if v2 == v1 {
                        continue;
                    }
                    let ea = g.edge_id(u1 as usize, v2).unwrap();
                    let eb = match g.edge_id(u2 as usize, v2) {
                        Some(e) => e,
                        None => continue,
                    };
                    if alive[ea as usize] && alive[eb as usize] {
                        b += 1;
                    }
                }
            }
            be[eid] = b;
        }
        be
    };
    while remaining > 0 {
        let counts = count_edge(&alive);
        let min = (0..m).filter(|&e| alive[e]).map(|e| counts[e]).min().unwrap();
        k = k.max(min);
        for e in 0..m {
            if alive[e] && counts[e] == min {
                wing[e] = k;
                alive[e] = false;
                remaining -= 1;
            }
        }
    }
    wing
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn fig1_oracle() {
        let g = BipartiteGraph::from_edges(
            3,
            3,
            &[(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2), (2, 2)],
        );
        assert_eq!(total(&g), 3);
        let (bu, bv) = per_vertex(&g);
        assert_eq!(bu, vec![3, 3, 0]);
        assert_eq!(bv, vec![2, 2, 2]);
        // Per-edge sum = 4 * total.
        assert_eq!(per_edge(&g).iter().sum::<u64>(), 12);
    }

    #[test]
    fn complete_bipartite_tips() {
        // K_{3,4}: every U vertex is in C(2,1)*C(4,2) = 12 butterflies;
        // peeling removes them all at once -> tip number 12 for all.
        let g = gen::complete_bipartite(3, 4);
        assert_eq!(tip_numbers_u(&g), vec![12, 12, 12]);
    }

    #[test]
    fn single_butterfly_wings() {
        let g = gen::complete_bipartite(2, 2);
        assert_eq!(wing_numbers(&g), vec![1, 1, 1, 1]);
    }

    #[test]
    fn per_vertex_sums_match_total() {
        let g = gen::erdos_renyi(15, 18, 120, 3);
        let t = total(&g);
        let (bu, bv) = per_vertex(&g);
        assert_eq!(bu.iter().sum::<u64>(), 2 * t);
        assert_eq!(bv.iter().sum::<u64>(), 2 * t);
        assert_eq!(per_edge(&g).iter().sum::<u64>(), 4 * t);
    }
}
