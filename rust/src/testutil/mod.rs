//! Test substrate: brute-force oracles and a mini property-test
//! harness.
//!
//! `proptest` is unavailable in this offline environment (see ARCHITECTURE.md),
//! so [`prop`] provides the minimal machinery the invariants need:
//! seeded random generation, many-iteration checks, and failing-seed
//! reporting for reproduction.

pub mod brute;
pub mod prop;
pub mod rankref;
