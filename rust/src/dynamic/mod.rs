//! Batch-dynamic butterfly maintenance.
//!
//! The ParButterfly framework (and the paper) counts over a *static*
//! bipartite graph; this module opens the dynamic workload class: a
//! [`DynGraph`] wraps [`BipartiteGraph`] with batched
//! [`insert_edges`](DynGraph::insert_edges) /
//! [`delete_edges`](DynGraph::delete_edges) and keeps the global,
//! per-vertex, and per-edge butterfly counts **exact** after every
//! batch without recounting from scratch.
//!
//! ## The update rule
//!
//! The per-edge delta structure follows Wang et al. ("Efficient
//! Butterfly Counting for Large Bipartite Networks"): the butterflies
//! gained by inserting edge `(u, v)` are exactly the wedge closures
//! `(u, v, u2, v2)` with `u2 ∈ N(v)`, `v2 ∈ N(u)`, `(u2, v2) ∈ E` —
//! an intersection walk over only the touched adjacency lists.  For a
//! **batch** the subtlety is double counting: a butterfly created by
//! two batch edges would be found from both.  `DynGraph` fixes the
//! convention with edge ids: batch edges are deduplicated and
//! parallel-sorted ([`prims::sort`](crate::prims::sort) /
//! [`prims::scan`](crate::prims::scan)) into CSR order, so their edge
//! ids ascend, and each new (or destroyed) butterfly is enumerated
//! exactly once — from its **maximum-edge-id batch edge**, with the
//! other three edges filtered to "non-batch, or batch with a smaller
//! id".  Insertions walk the post-insertion graph; deletions walk the
//! pre-deletion graph; the enumeration credits all four vertices and
//! all four edges of every butterfly it finds, so the three count
//! granularities stay consistent (`Σ per-vertex = 2·total`,
//! `Σ per-edge = 4·total` — debug builds assert this after every
//! batch).
//!
//! The walk itself is the intersect engine's discipline
//! ([`count::intersect`](crate::count::intersect)): a per-worker dense
//! stamp (`EdgeStamp`, the sibling of `TouchedCounter`) over one
//! endpoint's adjacency, a two-hop scan from the other endpoint, and
//! an O(#touched) reset — batch edges are claimed dynamically
//! ([`parallel_for_dynamic_with`]) because per-edge wedge counts are
//! heavily skewed.  Each edge's walk is oriented from whichever side
//! scans fewer adjacency entries (the degree-ordered choice of the
//! rank-ordered static walks).
//!
//! ## Cost model and the rebuild threshold
//!
//! A batch of `b` edges costs `O(m log m)` for the parallel CSR
//! rebuild plus `O(Σ_{(u,v) ∈ B} min(Σ_{u2 ∈ N(v)} deg(u2),
//! Σ_{v2 ∈ N(u)} deg(v2)))` for the delta walk — the batch's wedge
//! frontier, independent of the total butterfly count.  When the
//! update log outgrows the graph the walk loses to a full recount, so
//! [`DynOpts::rebuild_fraction`] bounds it: once the edges applied
//! since the last full count exceed `rebuild_fraction · m`, the batch
//! falls back to the static `count_*_ranked` pipeline (through the
//! engine selected by [`DynOpts::count`], i.e. the whole
//! [`WedgeEngine`](crate::count::WedgeEngine) machinery) and the log
//! resets — the classic amortized rebuild.  `rebuild_fraction = 0`
//! forces a recount every batch (the benchmark baseline);
//! `f64::INFINITY` never recounts.
//!
//! Determinism: deltas are exact integers combined by commutative
//! atomic adds, so counts are identical at every thread count (the
//! `dynamic_oracle` suite pins 1/4/8 threads).
//!
//! ## Fault tolerance and graceful degradation
//!
//! Every update runs under the [`Budget`] carried by
//! `DynOpts::count.budget` and returns `Result`: a worker panic, an
//! injected fault, or a budget trip during the **delta walk** does not
//! abort — the batch falls back to a full static recount of the
//! already-committed post-batch graph, run with the budget *suspended*
//! (exactness over latency once degradation has begun), and the
//! outcome records `fallback = true`.  Only when that recovery recount
//! itself fails does the instance become **poisoned**: counts and
//! graph may disagree, every further update returns
//! [`ErrorKind::Poisoned`](crate::ErrorKind::Poisoned), and
//! [`DynGraph::rebuild`] (a guarded recount) is the way back.  A
//! failure *before* anything was committed (batch staging, CSR
//! construction on the recount path) leaves the pre-batch state fully
//! intact and the instance usable.
//!
//! [`stream`] parses the timestamped edge streams the CLI `dynamic`
//! subcommand replays.

// Runtime-critical modules must not abort through unchecked unwraps:
// failures either unwind as structured panics the pool catches or are
// returned as `error::Result`.  Test code is exempt.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]
pub mod stream;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::count::intersect::EdgeStamp;
use crate::count::{atomic_add, count_per_edge_ranked_raw, count_per_vertex_ranked_raw, CountOpts};
use crate::error::{catch, guard, Error, Result};
use crate::graph::ranked::walk_grain;
use crate::prims::budget::{self, Budget};
use crate::graph::BipartiteGraph;
use crate::prims::pool::{parallel_for, parallel_for_chunks, parallel_for_dynamic_with, SyncPtr};
use crate::prims::scan::{dedup_sorted, pack_indices};
use crate::prims::sort::par_sort;
use crate::rank::preprocess;

/// Options for a [`DynGraph`].
#[derive(Clone, Debug)]
pub struct DynOpts {
    /// Ranking + engine used by full recounts (initial count and
    /// rebuild-threshold fallbacks).  `count.budget` also governs the
    /// delta walks: it is the cooperative budget for every update.  The memory
    /// [`Layout`](crate::graph::Layout) the intersect engine runs
    /// recounts under is inherited from `count.layout`; the delta
    /// walks themselves are layout-independent (they stream the
    /// unranked CSR).
    pub count: CountOpts,
    /// Fall back to a full static recount once the edges applied since
    /// the last full count exceed this fraction of the current edge
    /// count.  `0` recounts every batch; `f64::INFINITY` never does.
    /// Default `0.25`, overridable via `PARBUTTERFLY_DYN_REBUILD`.
    pub rebuild_fraction: f64,
}

impl Default for DynOpts {
    fn default() -> Self {
        let rebuild_fraction = std::env::var("PARBUTTERFLY_DYN_REBUILD")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .filter(|f| *f >= 0.0)
            .unwrap_or(0.25);
        Self { count: CountOpts::default(), rebuild_fraction }
    }
}

/// Which kind of batch an outcome describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchKind {
    Insert,
    Delete,
}

impl BatchKind {
    pub fn name(&self) -> &'static str {
        match self {
            BatchKind::Insert => "insert",
            BatchKind::Delete => "delete",
        }
    }
}

/// How a batch's counts were brought up to date.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdatePath {
    /// Incremental wedge-walk delta over the touched frontier.
    Delta,
    /// Full static recount (rebuild threshold exceeded).
    Recount,
}

impl UpdatePath {
    pub fn name(&self) -> &'static str {
        match self {
            UpdatePath::Delta => "delta",
            UpdatePath::Recount => "recount",
        }
    }
}

/// Per-batch summary returned by
/// [`insert_edges`](DynGraph::insert_edges) /
/// [`delete_edges`](DynGraph::delete_edges) — the batch-level sibling
/// of [`CountReport`](crate::coordinator::CountReport).
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    pub kind: BatchKind,
    /// Edges actually inserted/deleted.
    pub applied: usize,
    /// No-ops: in-batch duplicates, inserts of present edges, deletes
    /// of absent edges.
    pub skipped: usize,
    /// Signed change in the global butterfly count.
    pub delta: i64,
    /// Global count after the batch.
    pub total: u64,
    pub path: UpdatePath,
    /// True when the delta walk failed (panic, injected fault, or
    /// budget trip) and the batch was recovered by the degradation
    /// recount; `path` is then [`UpdatePath::Recount`].
    pub fallback: bool,
    pub millis: f64,
}

/// One failed batch application, recorded by both replay
/// ([`DynReport::errors`](crate::coordinator::DynReport::errors)) and
/// the serve-mode writer ([`crate::serve`]) — the shared per-batch
/// error accounting of the two drivers.
#[derive(Clone, Debug)]
pub struct BatchError {
    /// Index into the driver's batch sequence (replay order for
    /// [`replay_stream`](crate::coordinator::replay_stream), admission
    /// order for the serve writer).
    pub batch: usize,
    pub kind: BatchKind,
    /// The first failure the batch hit.
    pub error: Error,
    /// True when the one-shot retry (with rebuild if needed) applied
    /// the batch after all; false when the batch was skipped.
    pub recovered: bool,
}

/// How [`apply_batch_with_retry`] resolved a batch.
#[derive(Clone, Debug)]
pub enum RetryOutcome {
    /// Applied cleanly on the first attempt.
    Clean(BatchOutcome),
    /// The first attempt failed but the one-shot retry (after a
    /// rebuild when the failure had poisoned the graph) applied it.
    Recovered { outcome: BatchOutcome, error: Error },
    /// Both attempts failed; the batch was dropped and the graph was
    /// rebuilt back to a usable state for the next batch.
    Skipped { error: Error },
}

/// Apply one batch with the shared retry-and-rebuild policy: a failed
/// batch is retried once (rebuilding first when the failure poisoned
/// the graph); a batch whose retry also fails is dropped after a final
/// rebuild.  The only `Err` case is a rebuild that itself fails —
/// there is no usable graph left to continue on.  Both
/// [`replay_stream`](crate::coordinator::replay_stream) and the serve
/// writer thread resolve batches through this function, so their
/// per-batch error accounting cannot drift apart.
pub fn apply_batch_with_retry(
    dg: &mut DynGraph,
    kind: BatchKind,
    edges: &[(u32, u32)],
) -> Result<RetryOutcome> {
    fn apply(dg: &mut DynGraph, kind: BatchKind, edges: &[(u32, u32)]) -> Result<BatchOutcome> {
        match kind {
            BatchKind::Insert => dg.insert_edges(edges),
            BatchKind::Delete => dg.delete_edges(edges),
        }
    }
    match apply(dg, kind, edges) {
        Ok(out) => Ok(RetryOutcome::Clean(out)),
        Err(first) => {
            if dg.poisoned().is_some() {
                dg.rebuild()?;
            }
            match apply(dg, kind, edges) {
                Ok(out) => Ok(RetryOutcome::Recovered { outcome: out, error: first }),
                Err(_second) => {
                    if dg.poisoned().is_some() {
                        dg.rebuild()?;
                    }
                    Ok(RetryOutcome::Skipped { error: first })
                }
            }
        }
    }
}

/// A bipartite graph under batch edge updates, with exact butterfly
/// counts (global, per-vertex, per-edge) maintained incrementally.
///
/// The vertex universe grows on demand: inserting an edge whose ids
/// exceed the current `|U|`/`|V|` extends the side (deletion never
/// shrinks it).  Per-edge counts are indexed by the **current**
/// graph's edge ids (CSR positions, remapped across rebuilds).
pub struct DynGraph {
    g: BipartiteGraph,
    total: u64,
    bu: Vec<u64>,
    bv: Vec<u64>,
    per_edge: Vec<u64>,
    opts: DynOpts,
    /// Edges applied through the delta path since the last full count.
    pending: usize,
    delta_batches: usize,
    recount_batches: usize,
    fallback_batches: usize,
    /// Set when a failure left counts and graph possibly inconsistent;
    /// every update refuses until [`rebuild`](DynGraph::rebuild).
    poisoned: Option<String>,
}

impl DynGraph {
    /// Wrap an existing graph; runs one full static count (under
    /// `opts.count.budget`).
    pub fn new(g: BipartiteGraph, opts: DynOpts) -> Result<Self> {
        let budget = opts.count.budget.clone();
        let mut dg = Self {
            g,
            total: 0,
            bu: Vec::new(),
            bv: Vec::new(),
            per_edge: Vec::new(),
            opts,
            pending: 0,
            delta_batches: 0,
            recount_batches: 0,
            fallback_batches: 0,
            poisoned: None,
        };
        guard(&budget, || dg.recount())?;
        Ok(dg)
    }

    /// Build from an edge list (see [`BipartiteGraph::from_edges`]).
    pub fn from_edges(
        nu: usize,
        nv: usize,
        edges: &[(u32, u32)],
        opts: DynOpts,
    ) -> Result<Self> {
        Self::new(BipartiteGraph::from_edges(nu, nv, edges), opts)
    }

    /// The current graph.
    pub fn graph(&self) -> &BipartiteGraph {
        &self.g
    }

    /// Global butterfly count.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Per-vertex butterfly counts of the U side (original ids).
    pub fn per_vertex_u(&self) -> &[u64] {
        &self.bu
    }

    /// Per-vertex butterfly counts of the V side (original ids).
    pub fn per_vertex_v(&self) -> &[u64] {
        &self.bv
    }

    /// Per-edge butterfly counts, indexed by the current edge ids.
    pub fn per_edge(&self) -> &[u64] {
        &self.per_edge
    }

    /// Edges applied through the delta path since the last full count.
    pub fn pending_updates(&self) -> usize {
        self.pending
    }

    /// Batches answered by the incremental walk.
    pub fn delta_batches(&self) -> usize {
        self.delta_batches
    }

    /// Batches answered by the rebuild-threshold full recount.
    pub fn recount_batches(&self) -> usize {
        self.recount_batches
    }

    /// Batches whose delta walk failed and were recovered by the
    /// graceful-degradation recount.
    pub fn fallback_batches(&self) -> usize {
        self.fallback_batches
    }

    /// Why the instance is poisoned, if it is.  A poisoned instance
    /// refuses updates until [`rebuild`](DynGraph::rebuild) succeeds.
    pub fn poisoned(&self) -> Option<&str> {
        self.poisoned.as_deref()
    }

    /// Clear a poisoned state: one guarded full recount of the current
    /// graph.  On success the counts once more match the graph and
    /// updates are accepted again; on failure the instance stays
    /// poisoned and the error is returned.
    pub fn rebuild(&mut self) -> Result<()> {
        let budget = self.opts.count.budget.clone();
        match guard(&budget, || self.recount()) {
            Ok(()) => {
                self.poisoned = None;
                Ok(())
            }
            Err(e) => {
                self.poisoned = Some(format!("rebuild recount failed: {e}"));
                Err(e)
            }
        }
    }

    fn check_usable(&self) -> Result<()> {
        match &self.poisoned {
            Some(why) => Err(Error::poisoned(why.clone())),
            None => Ok(()),
        }
    }

    /// Guarded full recount after a committed structural change; a
    /// failure poisons the instance (graph and counts may disagree).
    fn recount_checked(&mut self, budget: &Budget) -> Result<()> {
        let r = guard(budget, || self.recount());
        if let Err(e) = &r {
            self.poisoned = Some(format!("recount failed after a committed batch: {e}"));
        }
        r
    }

    /// Graceful degradation: the delta walk failed mid-batch, so
    /// recount the already-committed post-batch graph with any active
    /// budget suspended (the recovery must not be killed by the budget
    /// that killed the fast path).  Returns the batch's signed delta
    /// against `before`; a failure here poisons the instance.
    fn fallback_recount(&mut self, before: u64, cause: &Error) -> Result<i64> {
        let _quiet = budget::suspend();
        match catch(|| self.recount()) {
            Ok(()) => {
                self.fallback_batches += 1;
                Ok(self.total as i64 - before as i64)
            }
            Err(e) => {
                self.poisoned = Some(format!(
                    "delta walk failed ({cause}) and the fallback recount also failed: {e}"
                ));
                Err(e)
            }
        }
    }

    /// Insert a batch of edges.  The batch is deduplicated and edges
    /// already present are skipped as no-ops; ids beyond the current
    /// `|U|`/`|V|` grow the vertex universe.
    ///
    /// ```
    /// use parbutterfly::dynamic::{DynGraph, DynOpts};
    ///
    /// // Figure 1 of the paper, grown one batch at a time.
    /// let mut dg = DynGraph::from_edges(3, 3, &[(0, 0), (0, 1), (1, 0)], DynOpts::default())
    ///     .unwrap();
    /// assert_eq!(dg.total(), 0);
    /// let out = dg.insert_edges(&[(1, 1), (0, 2), (1, 2), (2, 2), (1, 1)]).unwrap();
    /// assert_eq!(out.applied, 4); // the repeated (1, 1) is a no-op
    /// assert_eq!(out.delta, 3);
    /// assert_eq!(dg.total(), 3);
    /// let out = dg.delete_edges(&[(0, 0)]).unwrap();
    /// assert_eq!(out.delta, -2);
    /// assert_eq!(dg.total(), 1);
    /// ```
    pub fn insert_edges(&mut self, edges: &[(u32, u32)]) -> Result<BatchOutcome> {
        self.check_usable()?;
        let start = Instant::now();
        let budget = self.opts.count.budget.clone();
        let (nu0, nv0) = (self.g.nu(), self.g.nv());

        // Staging: dedup + CSR-sort the batch, keep genuinely new
        // edges, grow the universe, and rebuild the CSR over old +
        // fresh edges (parallel sort-based build).  A failure anywhere
        // in here leaves the pre-batch graph and counts fully intact,
        // so the instance stays usable.
        let staged = guard(&budget, || {
            let fresh: Vec<(u32, u32)> = sorted_unique(edges)
                .into_iter()
                .filter(|&(u, v)| {
                    (u as usize) >= nu0
                        || (v as usize) >= nv0
                        || self.g.edge_id(u as usize, v).is_none()
                })
                .collect();
            if fresh.is_empty() {
                return None;
            }
            let nu = nu0.max(fresh.iter().map(|&(u, _)| u as usize + 1).max().unwrap_or(0));
            let nv = nv0.max(fresh.iter().map(|&(_, v)| v as usize + 1).max().unwrap_or(0));
            let m0 = self.g.m();
            let mut all = self.edges_by_id();
            all.resize(m0 + fresh.len(), (0, 0));
            all[m0..].copy_from_slice(&fresh);
            let g_new = BipartiteGraph::from_edges(nu, nv, &all);
            Some((fresh, g_new, nu, nv))
        })?;
        let Some((fresh, g_new, nu, nv)) = staged else {
            return Ok(self.noop(BatchKind::Insert, edges.len(), start));
        };

        let applied = fresh.len();
        let skipped = edges.len() - applied;
        self.bu.resize(nu, 0);
        self.bv.resize(nv, 0);
        let m0 = self.g.m();
        let path = self.choose_path(applied, m0 + applied);
        let before = self.total;
        let mut fallback = false;

        let delta = match path {
            UpdatePath::Recount => {
                self.g = g_new;
                self.recount_checked(&budget)?;
                self.recount_batches += 1;
                self.total as i64 - before as i64
            }
            UpdatePath::Delta => {
                // Carry per-edge counts into the new id space (fresh
                // edges start at zero); fresh ids ascend with the
                // (u, v)-sorted batch order — the max-id convention
                // the delta walk depends on.
                let old_pe = std::mem::take(&mut self.per_edge);
                let prep = guard(&budget, || {
                    let pe = remap_per_edge(&self.g, &old_pe, &g_new);
                    let batch_eids: Vec<u32> = fresh
                        .iter()
                        .map(|&(u, v)| match g_new.edge_id(u as usize, v) {
                            Some(e) => e,
                            None => unreachable!("batch edge absent after rebuild"),
                        })
                        .collect();
                    (pe, batch_eids)
                });
                // Structural commit happens regardless: the fallback
                // recount needs the post-batch graph in place.
                self.g = g_new;
                let walked = prep.and_then(|(pe, batch_eids)| {
                    self.per_edge = pe;
                    guard(&budget, || self.apply_delta(&batch_eids, true))
                });
                match walked {
                    Ok(gained) => {
                        self.total += gained;
                        self.pending += applied;
                        self.delta_batches += 1;
                        gained as i64
                    }
                    Err(e) => {
                        fallback = true;
                        self.fallback_recount(before, &e)?
                    }
                }
            }
        };
        self.check_invariants();
        Ok(BatchOutcome {
            kind: BatchKind::Insert,
            applied,
            skipped,
            delta,
            total: self.total,
            path: if fallback { UpdatePath::Recount } else { path },
            fallback,
            millis: ms(start),
        })
    }

    /// Delete a batch of edges.  The batch is deduplicated; edges not
    /// present are skipped as no-ops.  The vertex universe never
    /// shrinks.
    pub fn delete_edges(&mut self, edges: &[(u32, u32)]) -> Result<BatchOutcome> {
        self.check_usable()?;
        let start = Instant::now();
        let budget = self.opts.count.budget.clone();
        let (nu0, nv0) = (self.g.nu(), self.g.nv());

        // Staging: dedup the batch and keep edges actually present.  A
        // failure leaves the pre-batch state intact.
        let (gone, gone_eids) = guard(&budget, || {
            let mut gone = Vec::new();
            let mut gone_eids = Vec::new();
            for (u, v) in sorted_unique(edges) {
                if (u as usize) < nu0 && (v as usize) < nv0 {
                    if let Some(e) = self.g.edge_id(u as usize, v) {
                        gone.push((u, v));
                        gone_eids.push(e);
                    }
                }
            }
            (gone, gone_eids)
        })?;
        let skipped = edges.len() - gone.len();
        if gone.is_empty() {
            return Ok(self.noop(BatchKind::Delete, skipped, start));
        }

        let applied = gone.len();
        let path = self.choose_path(applied, self.g.m() - applied);
        let before = self.total;
        let mut fallback = false;

        // The destroyed butterflies are walked in the *pre-deletion*
        // graph, subtracting per-edge credits in the old id space;
        // afterwards every deleted edge's count is exactly zero and
        // the remap below drops those slots.  The recount path skips
        // both the walk and the remap it would overwrite.  A failed
        // walk may have applied partial credits — recoverable, but
        // only once the post-deletion graph is committed below.
        let mut delta = 0i64;
        let mut walk_failed: Option<Error> = None;
        if path == UpdatePath::Delta {
            match guard(&budget, || self.apply_delta(&gone_eids, false)) {
                Ok(lost) => {
                    self.total -= lost;
                    delta = -(lost as i64);
                }
                Err(e) => walk_failed = Some(e),
            }
        }

        // Build the post-deletion CSR.  If this fails *after* delta
        // credits were (possibly partially) applied, the counts no
        // longer describe any graph we hold — poison.
        let built = guard(&budget, || {
            let mut is_gone = vec![false; self.g.m()];
            for &e in &gone_eids {
                is_gone[e as usize] = true;
            }
            let all = self.edges_by_id();
            let keep = pack_indices(all.len(), |i| !is_gone[i]);
            let remaining: Vec<(u32, u32)> =
                crate::prims::pool::parallel_map(keep.len(), |j| all[keep[j]]);
            BipartiteGraph::from_edges(nu0, nv0, &remaining)
        });
        let g_new = match built {
            Ok(g) => g,
            Err(e) => {
                if path == UpdatePath::Delta {
                    self.poisoned = Some(format!(
                        "post-deletion CSR rebuild failed after delta credits \
                         were applied: {e}"
                    ));
                }
                return Err(e);
            }
        };

        match path {
            UpdatePath::Recount => {
                self.g = g_new;
                self.recount_checked(&budget)?;
                self.recount_batches += 1;
                delta = self.total as i64 - before as i64;
            }
            UpdatePath::Delta => match walk_failed {
                None => {
                    let old_pe = std::mem::take(&mut self.per_edge);
                    if cfg!(debug_assertions) {
                        for &e in &gone_eids {
                            debug_assert_eq!(
                                old_pe[e as usize],
                                0,
                                "residual count on deleted edge {e}"
                            );
                        }
                    }
                    let remapped = guard(&budget, || remap_per_edge(&self.g, &old_pe, &g_new));
                    self.g = g_new;
                    match remapped {
                        Ok(pe) => {
                            self.per_edge = pe;
                            self.pending += applied;
                            self.delta_batches += 1;
                        }
                        Err(e) => {
                            fallback = true;
                            delta = self.fallback_recount(before, &e)?;
                        }
                    }
                }
                Some(e) => {
                    self.g = g_new;
                    fallback = true;
                    delta = self.fallback_recount(before, &e)?;
                }
            },
        }
        self.check_invariants();
        Ok(BatchOutcome {
            kind: BatchKind::Delete,
            applied,
            skipped,
            delta,
            total: self.total,
            path: if fallback { UpdatePath::Recount } else { path },
            fallback,
            millis: ms(start),
        })
    }

    fn noop(&self, kind: BatchKind, skipped: usize, start: Instant) -> BatchOutcome {
        BatchOutcome {
            kind,
            applied: 0,
            skipped,
            delta: 0,
            total: self.total,
            path: UpdatePath::Delta,
            fallback: false,
            millis: ms(start),
        }
    }

    /// Amortized rebuild rule (see [`DynOpts::rebuild_fraction`]):
    /// `m_after` is the edge count the batch will leave behind.
    fn choose_path(&self, applied: usize, m_after: usize) -> UpdatePath {
        let cap = self.opts.rebuild_fraction * m_after.max(1) as f64;
        if (self.pending + applied) as f64 >= cap {
            UpdatePath::Recount
        } else {
            UpdatePath::Delta
        }
    }

    /// Full static recount through the configured counting engine;
    /// resets the update log.
    fn recount(&mut self) {
        let opts = &self.opts.count;
        let rg = preprocess(&self.g, opts.ranking);
        let pv = count_per_vertex_ranked_raw(&rg, opts);
        let nu = self.g.nu();
        self.bu = vec![0; nu];
        self.bv = vec![0; self.g.nv()];
        for (x, &c) in pv.iter().enumerate() {
            let gid = rg.orig(x) as usize;
            if gid < nu {
                self.bu[gid] = c;
            } else {
                self.bv[gid - nu] = c;
            }
        }
        self.per_edge = count_per_edge_ranked_raw(&rg, self.g.m(), opts);
        self.total = self.bu.iter().sum::<u64>() / 2;
        self.pending = 0;
    }

    /// Walk every batch edge's butterfly frontier in `self.g` under the
    /// max-edge-id filter, crediting all four vertices and edges of
    /// each butterfly found; apply the credits with `+1`/`-1` sign and
    /// return the number of butterflies (the |delta|).
    fn apply_delta(&mut self, batch_eids: &[u32], gain: bool) -> u64 {
        let g = &self.g;
        let (nu, nv, m) = (g.nu(), g.nv(), g.m());
        budget::probe_alloc((nu + nv + m) * 8, "dynamic delta accumulators");
        let mut is_batch = vec![false; m];
        for &e in batch_eids {
            is_batch[e as usize] = true;
        }
        let d_bu: Vec<AtomicU64> = (0..nu).map(|_| AtomicU64::new(0)).collect();
        let d_bv: Vec<AtomicU64> = (0..nv).map(|_| AtomicU64::new(0)).collect();
        let d_pe: Vec<AtomicU64> = (0..m).map(|_| AtomicU64::new(0)).collect();
        let found = AtomicU64::new(0);
        let stamp_len = nu.max(nv);
        let (is_batch, d_bu2, d_bv2, d_pe2) = (&is_batch, &d_bu, &d_bv, &d_pe);
        // Per-edge walk costs are skewed, so batch edges are claimed
        // dynamically; the claim grain derives from the expected stamp-
        // walk footprint against the cache-tile budget.
        let fp = {
            let du = m.div_ceil(nu.max(1)).max(1);
            let dv = m.div_ceil(nv.max(1)).max(1);
            du.saturating_mul(dv)
        };
        parallel_for_dynamic_with(
            batch_eids.len(),
            walk_grain(batch_eids.len(), fp),
            || EdgeStamp::new(stamp_len),
            |stamp, range| {
                let mut local = 0u64;
                for bi in range {
                    local += walk_one(g, is_batch, batch_eids[bi], stamp, d_bu2, d_bv2, d_pe2);
                }
                atomic_add(&found, local);
            },
        );
        apply_signed(&mut self.bu, &d_bu, gain);
        apply_signed(&mut self.bv, &d_bv, gain);
        apply_signed(&mut self.per_edge, &d_pe, gain);
        found.into_inner()
    }

    /// All edges indexed by edge id (parallel row copy; the sibling of
    /// the sequential [`BipartiteGraph::edges`]).
    fn edges_by_id(&self) -> Vec<(u32, u32)> {
        let g = &self.g;
        let mut all = vec![(0u32, 0u32); g.m()];
        {
            let ap = SyncPtr(all.as_mut_ptr());
            parallel_for_chunks(g.nu(), |range| {
                for u in range {
                    let base = g.eid_u(u, 0) as usize;
                    for (i, &v) in g.nbrs_u(u).iter().enumerate() {
                        // SAFETY: edge ids are disjoint per row.
                        unsafe { *ap.get().add(base + i) = (u as u32, v) };
                    }
                }
            });
        }
        all
    }

    /// `Σ per-vertex = 2·total` and `Σ per-edge = 4·total` after every
    /// batch (debug builds only — O(n + m) per batch).
    fn check_invariants(&self) {
        if cfg!(debug_assertions) {
            let su: u64 = self.bu.iter().sum();
            let sv: u64 = self.bv.iter().sum();
            let se: u64 = self.per_edge.iter().sum();
            debug_assert_eq!(su, 2 * self.total, "U-side per-vertex sum");
            debug_assert_eq!(sv, 2 * self.total, "V-side per-vertex sum");
            debug_assert_eq!(se, 4 * self.total, "per-edge sum");
        }
    }
}

/// Milliseconds since `start`.
fn ms(start: Instant) -> f64 {
    start.elapsed().as_secs_f64() * 1e3
}

/// Dedup a batch into CSR (`(u, v)`-ascending) order via the parallel
/// sort + scan primitives.
fn sorted_unique(edges: &[(u32, u32)]) -> Vec<(u32, u32)> {
    let mut packed: Vec<u64> =
        edges.iter().map(|&(u, v)| ((u as u64) << 32) | v as u64).collect();
    par_sort(&mut packed);
    let packed = dedup_sorted(packed);
    packed.into_iter().map(|k| ((k >> 32) as u32, k as u32)).collect()
}

/// Scatter per-edge counts from `old`'s id space into `new`'s (edges
/// absent from `old` start at zero, edges absent from `new` drop).
fn remap_per_edge(old: &BipartiteGraph, old_pe: &[u64], new: &BipartiteGraph) -> Vec<u64> {
    let mut pe = vec![0u64; new.m()];
    {
        let ap = SyncPtr(pe.as_mut_ptr());
        parallel_for_chunks(new.nu(), |range| {
            for u in range {
                let base = new.eid_u(u, 0) as usize;
                for (i, &v) in new.nbrs_u(u).iter().enumerate() {
                    let c = if u < old.nu() && (v as usize) < old.nv() {
                        old.edge_id(u, v).map(|e| old_pe[e as usize]).unwrap_or(0)
                    } else {
                        0
                    };
                    // SAFETY: edge ids are disjoint per row.
                    unsafe { *ap.get().add(base + i) = c };
                }
            }
        });
    }
    pe
}

/// Fold a delta array into `dst` with sign (parallel, disjoint slots).
fn apply_signed(dst: &mut [u64], delta: &[AtomicU64], gain: bool) {
    debug_assert_eq!(dst.len(), delta.len());
    let p = SyncPtr(dst.as_mut_ptr());
    parallel_for(dst.len(), |i| {
        let d = delta[i].load(Ordering::Relaxed);
        if d != 0 {
            // SAFETY: each index written by exactly one worker.
            unsafe {
                let s = p.get().add(i);
                if gain {
                    *s += d;
                } else {
                    *s -= d;
                }
            }
        }
    });
}

/// Enumerate every butterfly of `g` containing batch edge `e` whose
/// other three edges each pass the max-id filter (non-batch, or batch
/// with a smaller edge id); credit the 4 vertices and 4 edges of each
/// into the delta arrays and return the number found.
fn walk_one(
    g: &BipartiteGraph,
    is_batch: &[bool],
    e: u32,
    stamp: &mut EdgeStamp,
    d_bu: &[AtomicU64],
    d_bv: &[AtomicU64],
    d_pe: &[AtomicU64],
) -> u64 {
    let (eu, ev) = g.edge(e);
    let (u, v) = (eu as usize, ev as usize);
    let passes = |x: u32| !is_batch[x as usize] || x < e;
    // Orient from the cheaper side: the walk scans every center's full
    // adjacency once, so compare the two centers' degree sums.
    let cost_a: usize = g.nbrs_v(v).iter().map(|&u2| g.deg_u(u2 as usize)).sum();
    let cost_b: usize = g.nbrs_u(u).iter().map(|&v2| g.deg_v(v2 as usize)).sum();
    let mut found = 0u64;
    if cost_a <= cost_b {
        // Stamp N(u) — the candidate second V endpoints, remembering
        // the (u, v2) edge id — then walk centers u2 ∈ N(v) and scan
        // their adjacency against the stamp.
        for (i, &v2) in g.nbrs_u(u).iter().enumerate() {
            let e_uv2 = g.eid_u(u, i);
            if v2 as usize != v && passes(e_uv2) {
                stamp.set(v2, e_uv2);
            }
        }
        let (centers, center_eids) = (g.nbrs_v(v), g.eids_v(v));
        for (i, &u2) in centers.iter().enumerate() {
            let e_u2v = center_eids[i];
            if u2 as usize == u || !passes(e_u2v) {
                continue;
            }
            let u2 = u2 as usize;
            let mut cnt = 0u64;
            for (k, &v2) in g.nbrs_u(u2).iter().enumerate() {
                // Bitset probe first: the common miss answers from a
                // 32x denser structure than the stamp's eid slots.
                if !stamp.hit(v2) {
                    continue;
                }
                let e_u2v2 = g.eid_u(u2, k);
                if !passes(e_u2v2) {
                    continue;
                }
                if let Some(e_uv2) = stamp.get(v2) {
                    cnt += 1;
                    atomic_add(&d_bv[v2 as usize], 1);
                    atomic_add(&d_pe[e_uv2 as usize], 1);
                    atomic_add(&d_pe[e_u2v2 as usize], 1);
                }
            }
            if cnt > 0 {
                atomic_add(&d_bu[u2], cnt);
                atomic_add(&d_pe[e_u2v as usize], cnt);
                found += cnt;
            }
        }
    } else {
        // Mirror: stamp N(v), walk centers v2 ∈ N(u).
        let (unbrs, ueids) = (g.nbrs_v(v), g.eids_v(v));
        for (i, &u2) in unbrs.iter().enumerate() {
            let e_u2v = ueids[i];
            if u2 as usize != u && passes(e_u2v) {
                stamp.set(u2, e_u2v);
            }
        }
        for (i, &v2) in g.nbrs_u(u).iter().enumerate() {
            let e_uv2 = g.eid_u(u, i);
            if v2 as usize == v || !passes(e_uv2) {
                continue;
            }
            let v2 = v2 as usize;
            let mut cnt = 0u64;
            let (nbrs2, eids2) = (g.nbrs_v(v2), g.eids_v(v2));
            for (k, &u2) in nbrs2.iter().enumerate() {
                // Bitset probe first (see the mirrored loop above).
                if !stamp.hit(u2) {
                    continue;
                }
                let e_u2v2 = eids2[k];
                if !passes(e_u2v2) {
                    continue;
                }
                if let Some(e_u2v) = stamp.get(u2) {
                    cnt += 1;
                    atomic_add(&d_bu[u2 as usize], 1);
                    atomic_add(&d_pe[e_u2v as usize], 1);
                    atomic_add(&d_pe[e_u2v2 as usize], 1);
                }
            }
            if cnt > 0 {
                atomic_add(&d_bv[v2], cnt);
                atomic_add(&d_pe[e_uv2 as usize], cnt);
                found += cnt;
            }
        }
    }
    stamp.reset();
    if found > 0 {
        atomic_add(&d_bu[u], found);
        atomic_add(&d_bv[v], found);
        atomic_add(&d_pe[e as usize], found);
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::{count_per_edge, count_per_vertex, CountOpts};
    use crate::graph::gen;
    use crate::prims::rng::Pcg32;
    use crate::testutil::brute;

    fn delta_only() -> DynOpts {
        DynOpts { rebuild_fraction: f64::INFINITY, ..Default::default() }
    }

    fn recount_only() -> DynOpts {
        DynOpts { rebuild_fraction: 0.0, ..Default::default() }
    }

    /// Assert dg's three count granularities against a static recount.
    fn assert_matches_static(dg: &DynGraph, ctx: &str) {
        let g = dg.graph();
        assert_eq!(dg.total(), brute::total(g), "{ctx}: total");
        let (ebu, ebv) = brute::per_vertex(g);
        assert_eq!(dg.per_vertex_u(), &ebu[..], "{ctx}: per-vertex U");
        assert_eq!(dg.per_vertex_v(), &ebv[..], "{ctx}: per-vertex V");
        assert_eq!(dg.per_edge(), &brute::per_edge(g)[..], "{ctx}: per-edge");
    }

    #[test]
    fn fig1_grown_and_shrunk_edge_by_edge() {
        let fig1 = [(0u32, 0u32), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2), (2, 2)];
        for opts in [delta_only(), recount_only()] {
            let mut dg = DynGraph::from_edges(3, 3, &[], opts).unwrap();
            for (i, &e) in fig1.iter().enumerate() {
                let out = dg.insert_edges(&[e]).unwrap();
                assert_eq!(out.applied, 1);
                assert_matches_static(&dg, &format!("insert {i}"));
            }
            assert_eq!(dg.total(), 3);
            for (i, &e) in fig1.iter().enumerate() {
                dg.delete_edges(&[e]).unwrap();
                assert_matches_static(&dg, &format!("delete {i}"));
            }
            assert_eq!(dg.total(), 0);
            assert_eq!(dg.graph().m(), 0);
        }
    }

    #[test]
    fn batch_insert_matches_static_count() {
        let g = gen::erdos_renyi(18, 20, 150, 7);
        let edges = g.edges();
        let (a, b) = (edges.len() / 3, 2 * edges.len() / 3);
        for opts in [delta_only(), DynOpts::default()] {
            let mut dg = DynGraph::from_edges(g.nu(), g.nv(), &edges[..a], opts).unwrap();
            dg.insert_edges(&edges[a..b]).unwrap();
            assert_matches_static(&dg, "mid");
            dg.insert_edges(&edges[b..]).unwrap();
            assert_matches_static(&dg, "full");
            assert_eq!(dg.total(), brute::total(&g));
        }
    }

    #[test]
    fn duplicate_and_noop_batches() {
        let g = gen::erdos_renyi(10, 10, 40, 3);
        let edges = g.edges();
        let mut dg = DynGraph::from_edges(10, 10, &edges, delta_only()).unwrap();
        let before = dg.total();
        // Re-inserting present edges and deleting absent ones are no-ops.
        let out = dg.insert_edges(&edges[..10]).unwrap();
        assert_eq!((out.applied, out.delta), (0, 0));
        assert_eq!(out.skipped, 10);
        let absent: Vec<(u32, u32)> =
            (0..5).map(|i| (i, 9)).filter(|&(u, v)| g.edge_id(u as usize, v).is_none()).collect();
        let out = dg.delete_edges(&absent).unwrap();
        assert_eq!((out.applied, out.delta), (0, 0));
        assert_eq!(dg.total(), before);
        assert_matches_static(&dg, "noop");
    }

    #[test]
    fn vertex_universe_grows_on_insert() {
        let mut dg = DynGraph::from_edges(2, 2, &[(0, 0), (1, 1)], delta_only()).unwrap();
        let out = dg.insert_edges(&[(3, 4), (0, 1), (1, 0)]).unwrap();
        assert_eq!(out.applied, 3);
        assert_eq!(dg.graph().nu(), 4);
        assert_eq!(dg.graph().nv(), 5);
        assert_eq!(dg.per_vertex_u().len(), 4);
        assert_eq!(dg.per_vertex_v().len(), 5);
        assert_matches_static(&dg, "grown");
    }

    #[test]
    fn interleaved_stream_matches_static_at_every_batch() {
        // Randomized insert/delete interleaving with duplicate and
        // no-op pollution, checked against the brute-force oracle
        // after every batch — the Rust twin of
        // scripts/dynamic_model_check.py.
        let (nu, nv) = (14usize, 12usize);
        let mut rng = Pcg32::new(2026);
        for opts in [delta_only(), DynOpts::default()] {
            let mut dg = DynGraph::from_edges(nu, nv, &[], opts).unwrap();
            let mut removed: Vec<(u32, u32)> = Vec::new();
            for step in 0..40 {
                let sz = 1 + (rng.next_below(9) as usize);
                if rng.next_below(100) < 55 || dg.graph().m() == 0 {
                    let mut batch: Vec<(u32, u32)> = (0..sz)
                        .map(|_| {
                            (rng.next_below(nu as u64) as u32, rng.next_below(nv as u64) as u32)
                        })
                        .collect();
                    if let Some(&re) = removed.last() {
                        batch.push(re); // re-insert a deleted edge
                    }
                    let dup = batch[0];
                    batch.push(dup); // in-batch duplicate
                    dg.insert_edges(&batch).unwrap();
                } else {
                    let edges = dg.graph().edges();
                    let mut batch: Vec<(u32, u32)> = (0..sz.min(edges.len()))
                        .map(|_| edges[rng.next_below(edges.len() as u64) as usize])
                        .collect();
                    removed.extend(batch.iter().copied());
                    batch.push((nu as u32 - 1, nv as u32 - 1)); // maybe absent
                    dg.delete_edges(&batch).unwrap();
                }
                assert_matches_static(&dg, &format!("step {step}"));
            }
            assert!(dg.delta_batches() + dg.recount_batches() > 0);
        }
    }

    #[test]
    fn delta_and_recount_paths_agree() {
        let g = gen::chung_lu(40, 50, 400, 2.1, 9);
        let edges = g.edges();
        let half = edges.len() / 2;
        let mut a = DynGraph::from_edges(g.nu(), g.nv(), &edges[..half], delta_only()).unwrap();
        let mut b = DynGraph::from_edges(g.nu(), g.nv(), &edges[..half], recount_only()).unwrap();
        for chunk in edges[half..].chunks(37) {
            let oa = a.insert_edges(chunk).unwrap();
            let ob = b.insert_edges(chunk).unwrap();
            assert_eq!(oa.path, UpdatePath::Delta);
            assert_eq!(ob.path, UpdatePath::Recount);
            assert_eq!(oa.total, ob.total);
            assert_eq!(oa.delta, ob.delta);
        }
        assert_eq!(a.per_edge(), b.per_edge());
        assert_eq!(a.per_vertex_u(), b.per_vertex_u());
        assert!(a.recount_batches() == 0 && b.delta_batches() == 0);
    }

    #[test]
    fn rebuild_threshold_switches_paths() {
        let g = gen::erdos_renyi(30, 30, 300, 5);
        let edges = g.edges();
        let base = edges.len() - 5;
        let opts = DynOpts { rebuild_fraction: 0.25, ..Default::default() };
        let mut dg = DynGraph::from_edges(30, 30, &edges[..base], opts.clone()).unwrap();
        // Small batch stays on the delta path…
        let out = dg.insert_edges(&edges[base..]).unwrap();
        assert_eq!(out.path, UpdatePath::Delta);
        assert_eq!(dg.pending_updates(), 5);
        // …until the pending log crosses the fraction: recount + reset.
        // 150 fresh edges against ~250 old ones clears 0.25·m.
        let big: Vec<(u32, u32)> = (0..150u32).map(|i| (i % 30, 30 + i / 30)).collect();
        let mut dg2 = DynGraph::from_edges(30, 31, &edges[..base], opts).unwrap();
        let out = dg2.insert_edges(&big).unwrap();
        assert_eq!(out.path, UpdatePath::Recount);
        assert_eq!(dg2.pending_updates(), 0);
        assert_matches_static(&dg2, "post-recount");
    }

    #[test]
    fn engine_choice_flows_into_recounts() {
        use crate::count::Engine;
        let g = gen::erdos_renyi(20, 20, 160, 11);
        let edges = g.edges();
        let opts = DynOpts {
            count: CountOpts { engine: Engine::Intersect, ..Default::default() },
            rebuild_fraction: 0.0,
        };
        let half = edges.len() / 2;
        let mut dg = DynGraph::from_edges(20, 20, &edges[..half], opts).unwrap();
        dg.insert_edges(&edges[half..]).unwrap();
        assert_eq!(dg.total(), brute::total(&g));
        assert_eq!(dg.recount_batches(), 1);
    }

    #[test]
    fn static_counters_agree_with_dyn_per_edge_ids() {
        // Per-edge ids must line up with a static count on the same
        // graph (CSR construction is deterministic in the edge set).
        let g = gen::erdos_renyi(16, 18, 120, 13);
        let edges = g.edges();
        let half = edges.len() / 2;
        let mut dg = DynGraph::from_edges(16, 18, &edges[..half], delta_only()).unwrap();
        dg.insert_edges(&edges[half..]).unwrap();
        let opts = CountOpts::default();
        let vc = count_per_vertex(dg.graph(), &opts).unwrap();
        assert_eq!(dg.per_vertex_u(), &vc.bu[..]);
        assert_eq!(dg.per_vertex_v(), &vc.bv[..]);
        assert_eq!(dg.per_edge(), &count_per_edge(dg.graph(), &opts).unwrap()[..]);
    }
}
