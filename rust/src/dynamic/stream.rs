//! Timestamped edge-stream parsing and batching.
//!
//! The CLI `dynamic` subcommand replays streams of edge updates; this
//! module defines the on-disk format and the batching rule.  One event
//! per line, blank lines and `#`/`%` comments skipped:
//!
//! ```text
//! [ts] op u v
//! ```
//!
//! `op` is `+` (insert) or `-` (delete), `u`/`v` are 0-indexed
//! side-local vertex ids, and `ts` is an optional non-negative integer
//! timestamp — four-field lines carry one, three-field lines default
//! to timestamp 0 (so untimestamped streams batch purely by operation
//! and cap).  Under the default **strict** parse ([`parse_stream`])
//! malformed lines fail with a line-numbered error, the same contract
//! as the [`graph::io`](crate::graph::io) loaders; the **lenient**
//! parse ([`parse_stream_lenient`], CLI `--skip-bad-lines`) records
//! each malformed line as a [`ParseReject`] and keeps going, so one
//! corrupt line does not discard an otherwise-replayable stream.
//!
//! [`group_batches`] groups consecutive events into maximal batches: a
//! batch extends while the operation and the timestamp stay the same
//! and the size cap is not exceeded.  Batching preserves stream order,
//! so replays are semantically the one-at-a-time sequential replay —
//! [`DynGraph`](super::DynGraph) deduplicates and no-op-filters within
//! each batch.  Parsing is a sequential line scan: update streams are
//! replayed in order anyway, so batch application (not parsing) is the
//! parallel phase.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use super::BatchKind;

/// One edge update in a replayable stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamEvent {
    pub ts: u64,
    pub kind: BatchKind,
    pub u: u32,
    pub v: u32,
}

/// A replayable batch: one operation applied to a set of edges.
#[derive(Clone, Debug)]
pub struct Batch {
    pub kind: BatchKind,
    pub edges: Vec<(u32, u32)>,
}

/// One malformed line skipped by [`parse_stream_lenient`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParseReject {
    /// 1-indexed line number in the stream file.
    pub line: usize,
    /// The offending line, verbatim (trimmed).
    pub content: String,
    /// Why it was rejected (the strict parser's error message).
    pub reason: String,
}

fn parse_id(tok: &str, what: &str, lineno: usize) -> anyhow::Result<u32> {
    tok.parse::<u32>().map_err(|_| {
        anyhow::anyhow!("line {}: bad {what} id {tok:?} (expected an integer)", lineno + 1)
    })
}

/// Parse one non-comment, non-blank line (`lineno` is 0-indexed).
fn parse_line(t: &str, lineno: usize) -> anyhow::Result<StreamEvent> {
    let toks: Vec<&str> = t.split_whitespace().collect();
    let (ts, rest) = match toks.len() {
        3 => (0u64, &toks[..]),
        4 => {
            let ts = toks[0].parse::<u64>().map_err(|_| {
                anyhow::anyhow!(
                    "line {}: bad timestamp {:?} (expected a non-negative integer)",
                    lineno + 1,
                    toks[0]
                )
            })?;
            (ts, &toks[1..])
        }
        _ => anyhow::bail!(
            "line {}: expected `[ts] op u v`, got {} fields",
            lineno + 1,
            toks.len()
        ),
    };
    let kind = match rest[0] {
        "+" => BatchKind::Insert,
        "-" => BatchKind::Delete,
        other => {
            anyhow::bail!("line {}: bad op {other:?} (expected `+` or `-`)", lineno + 1)
        }
    };
    let u = parse_id(rest[1], "u", lineno)?;
    let v = parse_id(rest[2], "v", lineno)?;
    Ok(StreamEvent { ts, kind, u, v })
}

/// Parse one stream-format event line (`[ts] op u v`) outside a file
/// scan — the serve protocol accepts update lines in this format, and
/// routing them through the same strict parser keeps the two surfaces'
/// error messages identical.  `lineno` is 0-indexed, as in
/// [`parse_stream`]'s internal scan; comments and blank lines are the
/// caller's concern.
pub fn parse_event(t: &str, lineno: usize) -> anyhow::Result<StreamEvent> {
    parse_line(t, lineno)
}

fn scan_stream(
    path: &Path,
    mut on_bad: impl FnMut(usize, &str, anyhow::Error) -> anyhow::Result<()>,
) -> anyhow::Result<Vec<StreamEvent>> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    let mut events = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        // I/O errors are never skippable: the rest of the stream is
        // unreadable, not merely malformed.
        let line = line.map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        match parse_line(t, lineno) {
            Ok(e) => events.push(e),
            Err(e) => on_bad(lineno, t, e)?,
        }
    }
    Ok(events)
}

/// Parse a stream file (see the module docs for the format).  Strict:
/// the first malformed line fails the whole parse with a line-numbered
/// error.
pub fn parse_stream(path: &Path) -> anyhow::Result<Vec<StreamEvent>> {
    scan_stream(path, |_lineno, _content, e| Err(e))
}

/// Lenient parse (CLI `--skip-bad-lines`): malformed lines are
/// recorded as [`ParseReject`]s — line number, content, and the strict
/// parser's reason — and skipped; I/O errors still fail.  The replay
/// driver surfaces the rejects through
/// [`DynReport::parse_rejects`](crate::coordinator::DynReport::parse_rejects).
pub fn parse_stream_lenient(
    path: &Path,
) -> anyhow::Result<(Vec<StreamEvent>, Vec<ParseReject>)> {
    let mut rejects = Vec::new();
    let events = scan_stream(path, |lineno, content, e| {
        rejects.push(ParseReject {
            line: lineno + 1,
            content: content.to_string(),
            reason: e.to_string(),
        });
        Ok(())
    })?;
    Ok((events, rejects))
}

/// Write a stream file (timestamps included; round-trips
/// [`parse_stream`]).
pub fn save_stream(events: &[StreamEvent], path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("create {}: {e}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# parbutterfly edge stream: ts op u v")?;
    for e in events {
        let op = if e.kind == BatchKind::Insert { "+" } else { "-" };
        writeln!(w, "{} {} {} {}", e.ts, op, e.u, e.v)?;
    }
    Ok(())
}

/// Group consecutive events into maximal batches: same operation, same
/// timestamp, at most `cap` events per batch (`cap = 0` means
/// unbounded).
pub fn group_batches(events: &[StreamEvent], cap: usize) -> Vec<Batch> {
    let mut out: Vec<Batch> = Vec::new();
    let mut last_ts = 0u64;
    for e in events {
        let split = match out.last() {
            None => true,
            Some(b) => {
                b.kind != e.kind || last_ts != e.ts || (cap > 0 && b.edges.len() >= cap)
            }
        };
        if split {
            out.push(Batch { kind: e.kind, edges: vec![(e.u, e.v)] });
        } else if let Some(b) = out.last_mut() {
            b.edges.push((e.u, e.v));
        }
        last_ts = e.ts;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pb_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_and_grouping() {
        let events = vec![
            StreamEvent { ts: 1, kind: BatchKind::Insert, u: 0, v: 0 },
            StreamEvent { ts: 1, kind: BatchKind::Insert, u: 0, v: 1 },
            StreamEvent { ts: 2, kind: BatchKind::Insert, u: 1, v: 0 },
            StreamEvent { ts: 2, kind: BatchKind::Delete, u: 0, v: 0 },
            StreamEvent { ts: 2, kind: BatchKind::Delete, u: 0, v: 1 },
        ];
        let path = tmp("s.txt");
        save_stream(&events, &path).unwrap();
        let back = parse_stream(&path).unwrap();
        assert_eq!(back, events);
        let batches = group_batches(&back, 0);
        assert_eq!(batches.len(), 3, "split on ts change and op change");
        assert_eq!(batches[0].edges, vec![(0, 0), (0, 1)]);
        assert_eq!(batches[1].kind, BatchKind::Insert);
        assert_eq!(batches[2].kind, BatchKind::Delete);
        assert_eq!(batches[2].edges.len(), 2);
        // Cap forces further splits.
        let capped = group_batches(&back, 1);
        assert_eq!(capped.len(), 5);
    }

    #[test]
    fn untimestamped_lines_and_comments() {
        let path = tmp("u.txt");
        std::fs::write(&path, "# comment\n% другой\n+ 3 4\n+ 1 2\n\n- 3 4\n").unwrap();
        let events = parse_stream(&path).unwrap();
        assert_eq!(events.len(), 3);
        assert!(events.iter().all(|e| e.ts == 0));
        let batches = group_batches(&events, 0);
        assert_eq!(batches.len(), 2, "op flip splits; ts stays 0");
    }

    #[test]
    fn lenient_parse_skips_and_records_bad_lines() {
        let path = tmp("lenient.txt");
        std::fs::write(&path, "+ 0 1\nnope\n+ 1 2\n7 ? 3 4\n- 0 1\n").unwrap();
        let (events, rejects) = parse_stream_lenient(&path).unwrap();
        assert_eq!(events.len(), 3, "good lines survive");
        assert_eq!(events[2].kind, BatchKind::Delete);
        assert_eq!(rejects.len(), 2);
        assert_eq!((rejects[0].line, rejects[1].line), (2, 4));
        assert_eq!(rejects[0].content, "nope");
        assert!(rejects[0].reason.contains("line 2"), "{}", rejects[0].reason);
        assert!(rejects[1].reason.contains("bad op"), "{}", rejects[1].reason);
        // Strict mode still rejects the same file outright.
        assert!(parse_stream(&path).is_err());
        // A clean file parses identically under both modes.
        let clean = tmp("clean.txt");
        std::fs::write(&clean, "+ 0 1\n- 0 1\n").unwrap();
        let (ev2, rj2) = parse_stream_lenient(&clean).unwrap();
        assert_eq!(ev2, parse_stream(&clean).unwrap());
        assert!(rj2.is_empty());
    }

    #[test]
    fn malformed_lines_are_line_numbered() {
        for (body, needle) in [
            ("+ 1\n", "line 1"),
            ("+ 1 2 3 4\n", "line 1"),
            ("1 ? 2 3\n", "bad op"),
            ("+ x 2\n", "bad u id"),
            ("+ 1 -2\n", "bad v id"),
            ("ts + 1 2\n", "bad timestamp"),
            ("+ 1 2\nnope\n", "line 2"),
        ] {
            let path = tmp("bad.txt");
            std::fs::write(&path, body).unwrap();
            let err = parse_stream(&path).unwrap_err().to_string();
            assert!(err.contains(needle), "{body:?} -> {err}");
        }
    }
}
