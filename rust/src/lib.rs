//! # ParButterfly — parallel butterfly computations on bipartite graphs
//!
//! Rust implementation of the ParButterfly framework from *"Parallel
//! Algorithms for Butterfly Computations"* (Shi & Shun, 2019): global /
//! per-vertex / per-edge butterfly counting, tip decomposition (vertex
//! peeling) and wing decomposition (edge peeling), parameterized over
//! vertex **rankings** (side, degree, approximate degree, complement
//! degeneracy, approximate complement degeneracy) and **wedge
//! aggregation** strategies (sort, hash, histogram, simple batching,
//! wedge-aware batching), plus approximate counting via edge / colorful
//! sparsification and the Wang et al. cache optimization.  Beyond the
//! paper's static setting, [`dynamic`] maintains exact counts under
//! batched edge insertions/deletions (incremental wedge-walk deltas
//! with an amortized full-recount fallback).
//!
//! See `ARCHITECTURE.md` at the repository root for the module map,
//! the paper-section cross-reference, and the invariants each layer
//! guarantees.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack: a JAX +
//! Pallas build-time pipeline (`python/compile/`) AOT-lowers a dense-tile
//! butterfly-counting model to HLO text.  [`runtime`] exposes that dense
//! model behind a pluggable [`runtime::DenseBackend`] trait: the default
//! build runs the pure-Rust tiled reference kernel
//! ([`runtime::RustDense`]); the `pjrt` feature adds an engine that
//! loads the AOT artifacts through the PJRT C API.  [`count::dense`]
//! and the [`coordinator`] route dense blocks to whichever backend is
//! selected.  Python never runs at request time.
//!
//! ## Quickstart
//!
//! ```no_run
//! use parbutterfly::graph::gen;
//! use parbutterfly::coordinator::{count_butterflies, CountConfig};
//!
//! let g = gen::chung_lu(5_000, 8_000, 120_000, 2.1, 42);
//! let res = count_butterflies(&g, &CountConfig::default()).unwrap();
//! println!("{} butterflies", res.total);
//! ```
//!
//! Every public entry point returns [`error::Result`]: worker panics
//! are caught at the pool boundary and surfaced as structured
//! [`Error`]s, and cooperative [`Budget`]s (deadline / live-memory cap
//! / cancel token, carried in the option structs) stop long runs at
//! chunk granularity instead of mid-write.  See ARCHITECTURE.md
//! §"Fault tolerance & budgets".
//!
//! See `examples/` for end-to-end drivers and `rust/benches/` for the
//! harness regenerating every table and figure of the paper.

pub mod baseline;
pub mod bench_cli;
pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod count;
pub mod dynamic;
pub mod error;
pub mod graph;
pub mod peel;
pub mod prims;
pub mod rank;
pub mod runtime;
pub mod serve;
pub mod testutil;

pub use coordinator::{CountConfig, PeelConfig};
pub use error::{Error, ErrorKind, PoolError, Result};
pub use prims::budget::Budget;
