//! Crate-wide structured errors for the fault-tolerant runtime.
//!
//! The parallel engines themselves stay infallible: a failing task
//! *unwinds*, the pool combinators catch it, drain the remaining work,
//! and re-raise a structured payload.  Every **public entry point**
//! (`count_*`, `peel_*`, [`DynGraph`](crate::dynamic::DynGraph)
//! updates, the coordinator facade, the CLI) converts that payload
//! into an [`Error`] through [`guard`], so a panic inside any worker
//! closure — a bug, an injected fault ([`crate::prims::fault`]), or a
//! cooperative-budget trip ([`crate::prims::budget`]) — surfaces as a
//! clean `Err` instead of aborting the process.
//!
//! Unwind-safety contract: results computed under a [`guard`] are
//! **discarded on error** — per-worker scratch is dropped (never
//! re-pooled, see `PoolGuard`), partially-written output arrays are
//! thrown away with the closure's captures, and retrying the entry
//! point re-runs from clean inputs.  That discard-on-error semantics
//! is what justifies the `AssertUnwindSafe` below.

use std::any::Any;
use std::cell::Cell;
use std::fmt;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Once;

use crate::prims::budget::Budget;

/// `Result` specialized to the crate's [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

/// A worker task failure caught by the pool: which worker, which task
/// range it was processing, and the panic payload's message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PoolError {
    /// Index of the failing worker (0 on the inline 1-thread path).
    pub worker: usize,
    /// The task range the worker was processing when it unwound.
    pub range: Range<usize>,
    /// The panic payload, stringified.
    pub message: String,
}

impl fmt::Display for PoolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "worker {} panicked on tasks {}..{}: {}",
            self.worker, self.range.start, self.range.end, self.message
        )
    }
}

/// What went wrong, structurally.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// A worker task panicked inside a parallel combinator.
    Pool(PoolError),
    /// A panic outside the pool machinery (entry-point serial code).
    Panic(String),
    /// The [`Budget`] deadline passed.
    DeadlineExceeded {
        /// The configured timeout, in milliseconds.
        limit_ms: u64,
    },
    /// A probed allocation would push live scratch past the budget.
    MemoryBudgetExceeded {
        /// Bytes the failing probe asked for.
        requested: usize,
        /// Bytes charged so far (an upper bound on live scratch).
        charged: usize,
        /// The configured cap.
        limit: usize,
        /// What the allocation was for.
        what: &'static str,
    },
    /// The [`Budget`] cancel token was set.
    Cancelled,
    /// An injected allocation-probe failure
    /// ([`crate::prims::fault::FaultPlan`]).
    AllocFailed {
        /// Bytes the failing probe asked for.
        bytes: usize,
        /// What the allocation was for.
        what: &'static str,
    },
    /// The structure's counts may not match its graph after an earlier
    /// failure; rebuild before further updates
    /// ([`DynGraph::rebuild`](crate::dynamic::DynGraph::rebuild)).
    Poisoned(String),
    /// A serve-mode session is running degraded: the writer hit an
    /// unrecoverable batch failure, reads are answered from the stale
    /// snapshot at `epoch`, and updates are refused until an explicit
    /// `rebuild` succeeds ([`crate::serve`]).
    Degraded {
        /// Epoch of the stale snapshot still being served.
        epoch: u64,
        /// The failure that forced degradation, stringified.
        reason: String,
    },
}

/// Structured crate error; see [`ErrorKind`] for the cases.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Error {
    kind: ErrorKind,
}

impl Error {
    pub fn kind(&self) -> &ErrorKind {
        &self.kind
    }

    pub(crate) fn new(kind: ErrorKind) -> Self {
        Error { kind }
    }

    pub(crate) fn poisoned(msg: impl Into<String>) -> Self {
        Error { kind: ErrorKind::Poisoned(msg.into()) }
    }

    /// True for cooperative-budget exhaustion (deadline, memory cap,
    /// cancellation) — the CLI maps these to their own exit code.
    pub fn is_budget(&self) -> bool {
        matches!(
            self.kind,
            ErrorKind::DeadlineExceeded { .. }
                | ErrorKind::MemoryBudgetExceeded { .. }
                | ErrorKind::Cancelled
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.kind {
            ErrorKind::Pool(p) => write!(f, "parallel task failed: {p}"),
            ErrorKind::Panic(m) => write!(f, "panicked: {m}"),
            ErrorKind::DeadlineExceeded { limit_ms } => {
                write!(f, "budget exhausted: deadline of {limit_ms} ms passed")
            }
            ErrorKind::MemoryBudgetExceeded { requested, charged, limit, what } => write!(
                f,
                "budget exhausted: allocating {requested} bytes for {what} \
                 would push charged scratch ({charged} bytes) past the \
                 {limit}-byte cap"
            ),
            ErrorKind::Cancelled => write!(f, "budget exhausted: cancelled"),
            ErrorKind::AllocFailed { bytes, what } => {
                write!(f, "allocation of {bytes} bytes for {what} failed (injected)")
            }
            ErrorKind::Poisoned(m) => write!(f, "poisoned: {m}"),
            ErrorKind::Degraded { epoch, reason } => write!(
                f,
                "degraded: updates refused, reads serve stale epoch {epoch} \
                 until rebuild ({reason})"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Panic payload used to carry an [`ErrorKind`] through unwinding:
/// budget trips and pool re-raises travel as this instead of a string,
/// so nested catch layers keep the innermost structured cause.
pub(crate) struct Raised(pub(crate) ErrorKind);

thread_local! {
    /// Set immediately before a [`raise`] so the panic hook stays
    /// quiet: a structured raise is control flow, not a crash report.
    static SILENT: Cell<bool> = const { Cell::new(false) };
}

/// Install (once) a panic hook that swallows exactly the panics
/// [`raise`] marked as silent and delegates everything else.
fn install_quiet_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !SILENT.with(|s| s.replace(false)) {
                prev(info);
            }
        }));
    });
}

/// Unwind with a structured [`ErrorKind`] payload (no hook noise).
pub(crate) fn raise(kind: ErrorKind) -> ! {
    install_quiet_hook();
    SILENT.with(|s| s.set(true));
    std::panic::panic_any(Raised(kind));
}

/// Stringify a panic payload (`String` / `&str` / opaque).
pub(crate) fn payload_message(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = p.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Classify a caught panic payload into an [`ErrorKind`]: structured
/// [`Raised`] payloads pass through (keeping the innermost cause from
/// nested combinators), anything else becomes [`ErrorKind::Panic`].
pub(crate) fn classify_payload(p: Box<dyn Any + Send>) -> ErrorKind {
    match p.downcast::<Raised>() {
        Ok(r) => r.0,
        Err(p) => ErrorKind::Panic(payload_message(p.as_ref())),
    }
}

/// Catch any unwind out of `f` and convert it to an [`Error`].
///
/// Used at interior fallback points (the dynamic delta walk) where a
/// failure is recovered from in place rather than surfaced.
pub(crate) fn catch<T>(f: impl FnOnce() -> T) -> Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => Ok(v),
        Err(p) => Err(Error::new(classify_payload(p))),
    }
}

/// Entry-point boundary: install `budget` as the active cooperative
/// budget for the duration of `f` (workers inherit it), catch any
/// unwind, and convert it to a structured [`Error`].
pub(crate) fn guard<T>(budget: &Budget, f: impl FnOnce() -> T) -> Result<T> {
    let _scope = crate::prims::budget::enter(budget);
    catch(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_converts_plain_panics() {
        let r: Result<()> = guard(&Budget::default(), || panic!("boom {}", 7));
        let e = r.unwrap_err();
        assert_eq!(e.kind(), &ErrorKind::Panic("boom 7".into()));
        assert!(!e.is_budget());
        assert!(format!("{e}").contains("boom 7"));
    }

    #[test]
    fn guard_passes_raised_kinds_through() {
        let r: Result<()> =
            guard(&Budget::default(), || raise(ErrorKind::DeadlineExceeded { limit_ms: 5 }));
        let e = r.unwrap_err();
        assert!(e.is_budget());
        assert_eq!(e.kind(), &ErrorKind::DeadlineExceeded { limit_ms: 5 });
    }

    #[test]
    fn nested_catch_keeps_innermost_cause() {
        let inner = PoolError { worker: 3, range: 10..20, message: "x".into() };
        let r: Result<()> = catch(|| {
            let _: Result<()> = Ok(()); // outer serial work
            raise(ErrorKind::Pool(inner.clone()));
        });
        assert_eq!(r.unwrap_err().kind(), &ErrorKind::Pool(inner));
    }

    #[test]
    fn errors_format_without_panicking() {
        for kind in [
            ErrorKind::Pool(PoolError { worker: 1, range: 0..4, message: "m".into() }),
            ErrorKind::Panic("p".into()),
            ErrorKind::DeadlineExceeded { limit_ms: 10 },
            ErrorKind::MemoryBudgetExceeded { requested: 8, charged: 64, limit: 32, what: "w" },
            ErrorKind::Cancelled,
            ErrorKind::AllocFailed { bytes: 4, what: "a" },
            ErrorKind::Poisoned("q".into()),
            ErrorKind::Degraded { epoch: 3, reason: "r".into() },
        ] {
            let e = Error::new(kind);
            assert!(!format!("{e}").is_empty());
            assert!(!format!("{e:?}").is_empty());
        }
    }

    #[test]
    fn anyhow_interop_via_question_mark() {
        fn inner() -> anyhow::Result<()> {
            Err(Error::new(ErrorKind::Cancelled))?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("cancelled"));
    }
}
