//! Hand-rolled CLI (no `clap` offline).
//!
//! ```text
//! parbutterfly gen    --kind er|cl|blocks|davis --nu N --nv N --m M [--seed S] --out FILE
//! parbutterfly info   --graph FILE
//! parbutterfly count  --graph FILE [--mode total|vertex|edge] [--rank R] [--agg A]
//!                     [--engine wedges|intersect] [--layout auto|flat|hub]
//!                     [--cache-opt] [--auto-rank] [--threads T]
//!                     [--timeout-ms MS] [--memory-budget BYTES]
//! parbutterfly peel   --graph FILE [--mode vertex|edge] [--engine agg|intersect|two-phase]
//!                     [--count-engine wedges|intersect] [--agg A]
//!                     [--buckets julienne|fibheap] [--layout auto|flat|hub] [--threads T]
//!                     [--timeout-ms MS] [--memory-budget BYTES]
//! parbutterfly approx --graph FILE --method edge|colorful --p P [--seed S]
//! parbutterfly dynamic --stream FILE [--graph FILE] [--batch N] [--rebuild-fraction F]
//!                     [--engine wedges|intersect] [--rank R] [--layout auto|flat|hub]
//!                     [--threads T] [--verify] [--per-batch] [--skip-bad-lines]
//!                     [--timeout-ms MS] [--memory-budget BYTES]
//! parbutterfly serve  [--graph FILE] [--listen HOST:PORT] [--admit-max-edges N]
//!                     [--admit-max-ms MS] [--no-decompositions] [--no-retry]
//!                     [--rebuild-fraction F] [--engine wedges|intersect] [--rank R]
//!                     [--layout auto|flat|hub] [--threads T]
//!                     [--timeout-ms MS] [--memory-budget BYTES]
//! parbutterfly dense  --graph FILE [--backend auto|rust|pjrt]  # dense-core path
//! parbutterfly backends                       # dense backend availability
//! parbutterfly artifacts                      # list PJRT artifacts (feature pjrt)
//! ```
//!
//! Exit codes: `0` success, `2` error, `4` cooperative-budget
//! exhaustion (`--timeout-ms` / `--memory-budget` / cancellation
//! tripped before the computation finished).

use std::collections::HashMap;
use std::path::Path;

use crate::coordinator::{
    count_report, replay_stream, tip_report, wing_report, Coordinator, CountConfig, CountMode,
    CountReport, PeelConfig,
};
use crate::count::{sparsify, BflyAgg, CountOpts, Engine, WedgeAgg};
use crate::dynamic::{stream, DynOpts};
use crate::graph::{gen, io, BipartiteGraph, Layout};
use crate::peel::{BucketKind, PeelEngine, PeelSide};
use crate::rank::Ranking;

struct Args {
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut bools = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    bools.push(name.to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { flags, bools }
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    // Numeric flags are strict: an unparseable value is a hard error
    // naming the flag, never a silent default ("--m 10k" must not
    // quietly run with m = 10_000 and report those numbers).

    fn get_usize(&self, k: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(k) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --{k} {s:?} (need an unsigned integer)")),
        }
    }

    fn get_u64(&self, k: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(k) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --{k} {s:?} (need an unsigned integer)")),
        }
    }

    fn get_f64(&self, k: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(k) {
            None => Ok(default),
            Some(s) => {
                s.parse().map_err(|_| anyhow::anyhow!("bad --{k} {s:?} (need a number)"))
            }
        }
    }

    fn has(&self, k: &str) -> bool {
        self.bools.iter().any(|b| b == k)
    }
}

fn load(args: &Args) -> anyhow::Result<BipartiteGraph> {
    let path = args
        .get("graph")
        .ok_or_else(|| anyhow::anyhow!("--graph FILE required"))?;
    io::load_edge_list(Path::new(path))
}

/// Counting options minus `--engine` — `peel` reuses this because its
/// own `--engine` selects the *peeling* engine, not the counting one.
fn count_opts_base(args: &Args) -> anyhow::Result<CountOpts> {
    let ranking = match args.get("rank") {
        None => Ranking::Degree,
        Some(s) => Ranking::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown --rank {s:?} (valid: side|degree|adegree|codeg|acodeg)")
        })?,
    };
    let agg = match args.get("agg") {
        None => WedgeAgg::BatchS,
        Some(s) => WedgeAgg::parse(s).ok_or_else(|| {
            let all = WedgeAgg::ALL.map(|a| a.name()).join("|");
            anyhow::anyhow!("unknown --agg {s:?} (valid: {all})")
        })?,
    };
    // `--layout` wires through every wedge-walk consumer (counting,
    // peeling, dynamic recounts); default is PARBUTTERFLY_LAYOUT, else
    // auto (hub bitmaps only when degree skew justifies them).
    let layout = match args.get("layout") {
        None => Layout::default_from_env(),
        Some(s) => Layout::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown --layout {s:?} (valid: auto|flat|hub)"))?,
    };
    Ok(CountOpts {
        ranking,
        engine: Engine::Wedges,
        agg,
        bfly: if args.has("reagg") { BflyAgg::Reagg } else { BflyAgg::Atomic },
        cache_opt: args.has("cache-opt"),
        max_wedges: args.get_usize("max-wedges", 1 << 26)?,
        layout,
        budget: budget_arg(args)?,
    })
}

/// Cooperative budget from `--timeout-ms` / `--memory-budget` (bytes).
/// The engines check it at chunk granularity; exhaustion surfaces as a
/// structured error mapped to process exit code 4, never as a partial
/// result.
fn budget_arg(args: &Args) -> anyhow::Result<crate::prims::budget::Budget> {
    let mut budget = crate::prims::budget::Budget::default();
    if let Some(s) = args.get("timeout-ms") {
        let ms: u64 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --timeout-ms {s:?} (need milliseconds)"))?;
        budget = budget.with_timeout_ms(ms);
    }
    if let Some(s) = args.get("memory-budget") {
        let bytes: usize = s
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --memory-budget {s:?} (need bytes)"))?;
        budget = budget.with_max_live_bytes(bytes);
    }
    Ok(budget)
}

fn count_opts(args: &Args) -> anyhow::Result<CountOpts> {
    let mut opts = count_opts_base(args)?;
    if let Some(s) = args.get("engine") {
        opts.engine = Engine::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown --engine {s:?} (valid: wedges|intersect)"))?;
    }
    Ok(opts)
}

/// Apply `--threads` around `f`.  Invalid values are a hard error: a
/// typo'd `--threads` silently running at the default width would
/// label measurements with a thread count that never ran.
fn with_threads_arg<R>(args: &Args, f: impl FnOnce() -> R) -> anyhow::Result<R> {
    match args.get("threads") {
        None => Ok(f()),
        Some(s) => match s.parse::<usize>() {
            Ok(t) if t > 0 => Ok(crate::prims::pool::with_threads(t, f)),
            _ => anyhow::bail!("bad --threads {s:?} (need a positive integer)"),
        },
    }
}

/// Entry point used by `main.rs`.  Returns the process exit code.
pub fn run() -> i32 {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run_inner(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            // Budget exhaustion gets its own exit code so harnesses can
            // tell "ran out of time/memory" from "wrong".
            let budget =
                e.downcast_ref::<crate::error::Error>().map(|c| c.is_budget()).unwrap_or(false);
            if budget {
                4
            } else {
                2
            }
        }
    }
}

fn run_inner(argv: &[String]) -> anyhow::Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "gen" => cmd_gen(&args),
        "info" => cmd_info(&args),
        "count" => cmd_count(&args),
        "peel" => cmd_peel(&args),
        "approx" => cmd_approx(&args),
        "dynamic" => cmd_dynamic(&args),
        "serve" => cmd_serve(&args),
        "dense" => cmd_dense(&args),
        "backends" => cmd_backends(),
        "artifacts" => cmd_artifacts(),
        // `bench` has its own subcommand grammar (run/diff/list with
        // positional file arguments) — hand it the raw argv tail.
        "bench" => crate::bench_cli::run(&argv[1..]),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "parbutterfly — parallel butterfly computations (Shi & Shun 2019)
commands: gen, info, count, peel, approx, dynamic, serve, dense, backends,
          artifacts, bench (run | diff | list — the native benchmark harness)
serve:    resident query daemon over the line/JSON protocol on stdin/stdout
          (plus --listen HOST:PORT for TCP); see README §Serve protocol
shared:   --timeout-ms MS / --memory-budget BYTES set a cooperative budget
          (exit code 4 when exhausted); dynamic takes --skip-bad-lines to
          record malformed stream lines instead of aborting
run `parbutterfly <cmd> --help-flags` or see rust/src/cli.rs for flags";

fn cmd_gen(args: &Args) -> anyhow::Result<()> {
    let kind = args.get("kind").unwrap_or("er");
    let nu = args.get_usize("nu", 1000)?;
    let nv = args.get_usize("nv", 1000)?;
    let m = args.get_usize("m", 10_000)?;
    let seed = args.get_u64("seed", 42)?;
    let g = match kind {
        "er" => gen::erdos_renyi(nu, nv, m, seed),
        "cl" => gen::chung_lu(nu, nv, m, args.get_f64("beta", 2.1)?, seed),
        "blocks" => {
            let k = args.get_usize("k", 4)?;
            gen::planted_blocks(nu, nv, k, nu / (2 * k), nv / (2 * k), 0.9, m / 4, seed)
        }
        "davis" => gen::davis_southern_women(),
        other => anyhow::bail!("unknown --kind {other:?} (valid: er|cl|blocks|davis)"),
    };
    let out = args.get("out").ok_or_else(|| anyhow::anyhow!("--out FILE required"))?;
    io::save_edge_list(&g, Path::new(out))?;
    println!("wrote {} ({} x {}, {} edges)", out, g.nu(), g.nv(), g.m());
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let g = load(args)?;
    let cfg = CountConfig::default();
    let r = count_report(&g, CountMode::Total, &cfg)?;
    println!("|U| = {}", g.nu());
    println!("|V| = {}", g.nv());
    println!("|E| = {}", g.m());
    println!("max degree     = {}", g.max_degree());
    println!("wedges (ctr U) = {}", g.wedges_centered_u());
    println!("wedges (ctr V) = {}", g.wedges_centered_v());
    println!("# butterflies  = {}", r.total);
    for rk in Ranking::ALL {
        println!("f({:<7}) = {:+.4}", rk.name(), crate::rank::f_metric(&g, rk));
    }
    Ok(())
}

fn cmd_count(args: &Args) -> anyhow::Result<()> {
    let cfg = CountConfig { opts: count_opts(args)?, auto_rank: args.has("auto-rank") };
    let mode = match args.get("mode").unwrap_or("total") {
        "total" => CountMode::Total,
        "vertex" => CountMode::PerVertex,
        "edge" => CountMode::PerEdge,
        "full" => CountMode::Full,
        other => anyhow::bail!("unknown --mode {other:?} (valid: total|vertex|edge|full)"),
    };
    // `--threads` must cover the load too: the parser and CSR build are
    // parallel stages of the measured pipeline, so timing them outside
    // the override would mix thread settings in the breakdown below.
    let (load_ms, r) = with_threads_arg(args, || -> anyhow::Result<(f64, CountReport)> {
        let t_load = std::time::Instant::now();
        let g = load(args)?;
        let load_ms = t_load.elapsed().as_secs_f64() * 1e3;
        Ok((load_ms, count_report(&g, mode, &cfg)?))
    })??;
    println!(
        "total = {} (ranking {}, engine {}, {} wedges, {:.2} ms, backend {})",
        r.total,
        r.ranking.name(),
        r.engine,
        r.wedges,
        r.millis,
        r.backend
    );
    println!(
        "preprocess: load {:.2} ms (parse + CSR), rank {:.2} ms, build {:.2} ms \
         (pipeline {:.2} ms before counting)",
        load_ms,
        r.preprocess.rank_ms,
        r.preprocess.build_ms,
        load_ms + r.preprocess.total_ms()
    );
    if let Some(vc) = &r.per_vertex {
        let mx_u = vc.bu.iter().max().unwrap_or(&0);
        let mx_v = vc.bv.iter().max().unwrap_or(&0);
        println!("max per-vertex: U {} V {}", mx_u, mx_v);
    }
    if let Some(be) = &r.per_edge {
        println!("max per-edge: {}", be.iter().max().unwrap_or(&0));
    }
    Ok(())
}

fn cmd_peel(args: &Args) -> anyhow::Result<()> {
    let g = load(args)?;
    let agg = match args.get("agg") {
        None => WedgeAgg::Hist,
        Some(s) => WedgeAgg::parse(s).ok_or_else(|| {
            let all = WedgeAgg::ALL.map(|a| a.name()).join("|");
            anyhow::anyhow!("unknown --agg {s:?} (valid: {all})")
        })?,
    };
    // `peel --engine` selects ONLY the peeling UPDATE engine (default:
    // PARBUTTERFLY_PEEL_ENGINE env var, else agg).  The counting phase
    // keeps its own default unless `--count-engine` overrides it — so
    // flipping the peel engine never silently changes what is timed in
    // the counting phase.
    let engine = match args.get("engine") {
        Some(s) => PeelEngine::parse(s).ok_or_else(|| {
            let all = PeelEngine::ALL.map(|e| e.name()).join("|");
            anyhow::anyhow!("unknown --engine {s:?} (valid: {all})")
        })?,
        None => PeelEngine::default(),
    };
    let mut copts = count_opts_base(args)?;
    copts.engine = match args.get("count-engine") {
        Some(s) => Engine::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown --count-engine {s:?} (valid: wedges|intersect)")
        })?,
        None => CountOpts::default().engine,
    };
    let buckets = match args.get("buckets").unwrap_or("julienne") {
        "julienne" => BucketKind::Julienne,
        "fibheap" => BucketKind::FibHeap,
        other => anyhow::bail!("unknown --buckets {other:?} (valid: julienne|fibheap)"),
    };
    // The one parsed `--layout` reaches both the counting phase (via
    // `copts`) and the peel engines' dense walks.
    let layout = copts.layout;
    let cfg = PeelConfig {
        count: CountConfig { opts: copts, auto_rank: false },
        vopts: crate::peel::PeelVOpts { engine, agg, buckets, side: PeelSide::Auto, layout },
        eopts: crate::peel::PeelEOpts { engine, agg, buckets, layout },
    };
    match args.get("mode").unwrap_or("vertex") {
        "edge" => {
            let (w, ms) = with_threads_arg(args, || wing_report(&g, &cfg))??;
            let max = w.wings.iter().max().copied().unwrap_or(0);
            println!(
                "wing decomposition ({} engine): {} rounds, max wing {}, {:.2} ms",
                engine.name(),
                w.rounds,
                max,
                ms
            );
        }
        "vertex" => {
            let (t, ms) = with_threads_arg(args, || tip_report(&g, &cfg))??;
            let max = t.tips.iter().max().copied().unwrap_or(0);
            println!(
                "tip decomposition ({} side, {} engine): {} rounds, max tip {}, {:.2} ms",
                if t.peeled_u { "U" } else { "V" },
                engine.name(),
                t.rounds,
                max,
                ms
            );
        }
        other => anyhow::bail!("unknown --mode {other:?} (valid: vertex|edge)"),
    }
    Ok(())
}

fn cmd_approx(args: &Args) -> anyhow::Result<()> {
    let g = load(args)?;
    let p = args.get_f64("p", 0.5)?;
    anyhow::ensure!(p > 0.0 && p <= 1.0, "bad --p {p} (need a probability in (0, 1])");
    let seed = args.get_u64("seed", 1)?;
    let opts = count_opts(args)?;
    let est = match args.get("method").unwrap_or("edge") {
        "colorful" => {
            let c = (1.0 / p).round().max(1.0) as u64;
            sparsify::approx_total_colorful(&g, c, seed, &opts)?
        }
        "edge" => sparsify::approx_total_edge(&g, p, seed, &opts)?,
        other => anyhow::bail!("unknown --method {other:?} (valid: edge|colorful)"),
    };
    println!("estimated butterflies = {est:.1}");
    Ok(())
}

fn cmd_dynamic(args: &Args) -> anyhow::Result<()> {
    let spath = args
        .get("stream")
        .ok_or_else(|| anyhow::anyhow!("--stream FILE required (lines: `[ts] op u v`)"))?;
    // Strict parsing is the default; `--skip-bad-lines` switches to the
    // recover-and-continue mode that records line-numbered rejects in
    // the report instead of aborting on the first malformed line.
    let (events, rejects) = if args.has("skip-bad-lines") {
        stream::parse_stream_lenient(Path::new(spath))?
    } else {
        (stream::parse_stream(Path::new(spath))?, Vec::new())
    };
    // Batches split on timestamp/op changes; the cap bounds one batch
    // (0 = unbounded).
    let batches = stream::group_batches(&events, args.get_usize("batch", 1024)?);
    // Start from --graph when given, otherwise from an empty graph
    // that grows as the stream names vertices.
    let g0 = match args.get("graph") {
        Some(p) => io::load_edge_list(Path::new(p))?,
        None => BipartiteGraph::from_edges(0, 0, &[]),
    };
    // All knobs reject typos (count_opts / with_threads_arg are strict
    // everywhere now) — a replay misconfig silently changes what every
    // batch measures.
    let mut dopts = DynOpts { count: count_opts(args)?, ..Default::default() };
    if let Some(f) = args.get("rebuild-fraction") {
        dopts.rebuild_fraction = f
            .parse::<f64>()
            .ok()
            .filter(|x| *x >= 0.0)
            .ok_or_else(|| anyhow::anyhow!("bad --rebuild-fraction {f:?} (need a float >= 0)"))?;
    }
    let verify = args.has("verify");
    let (dg, mut rep) =
        with_threads_arg(args, || replay_stream(g0, &batches, &dopts, verify))??;
    rep.parse_rejects = rejects;
    if args.has("per-batch") {
        for (i, o) in rep.outcomes.iter().enumerate() {
            println!(
                "batch {i:>4} {:<6} applied {:>6} skipped {:>4} delta {:>8} total {:>10} \
                 [{}] {:.2} ms",
                o.kind.name(),
                o.applied,
                o.skipped,
                o.delta,
                o.total,
                o.path.name(),
                o.millis
            );
        }
    }
    if !rep.parse_rejects.is_empty() {
        println!("skipped {} malformed stream line(s):", rep.parse_rejects.len());
        for r in &rep.parse_rejects {
            println!("  line {}: {:?} ({})", r.line, r.content, r.reason);
        }
    }
    for be in &rep.errors {
        println!(
            "batch {} ({}) failed: {} [{}]",
            be.batch,
            be.kind.name(),
            be.error,
            if be.recovered { "recovered on retry" } else { "skipped" }
        );
    }
    println!(
        "replayed {} events in {} batches: {} inserted, {} deleted, {} no-ops",
        events.len(),
        rep.batches,
        rep.inserted,
        rep.deleted,
        rep.skipped
    );
    let g = dg.graph();
    println!(
        "graph now {} x {}, {} edges; butterflies = {} ({} delta batches, {} recounts, \
         {} fallback recounts, {:.2} ms total)",
        g.nu(),
        g.nv(),
        g.m(),
        rep.total,
        rep.delta_batches,
        rep.recount_batches,
        rep.fallback_batches,
        rep.millis
    );
    if let Some(ok) = rep.verified {
        anyhow::ensure!(ok, "incremental counts diverge from the static recount");
        println!("verify: incremental counts match the full static recount");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    // Start from --graph when given, otherwise from an empty graph that
    // grows as `update` requests name vertices (mirrors `dynamic`).
    let g0 = match args.get("graph") {
        Some(p) => io::load_edge_list(Path::new(p))?,
        None => BipartiteGraph::from_edges(0, 0, &[]),
    };
    let mut dopts = DynOpts { count: count_opts(args)?, ..Default::default() };
    if let Some(f) = args.get("rebuild-fraction") {
        dopts.rebuild_fraction = f
            .parse::<f64>()
            .ok()
            .filter(|x| *x >= 0.0)
            .ok_or_else(|| anyhow::anyhow!("bad --rebuild-fraction {f:?} (need a float >= 0)"))?;
    }
    // The writer runs on its own thread, which does not inherit the
    // thread-local pool override — pass --threads through ServeOpts so
    // the writer's recounts run at the requested width.
    let threads = match args.get("threads") {
        None => None,
        Some(s) => match s.parse::<usize>() {
            Ok(t) if t > 0 => Some(t),
            _ => anyhow::bail!("bad --threads {s:?} (need a positive integer)"),
        },
    };
    let opts = crate::serve::ServeOpts {
        dyn_opts: dopts,
        decompositions: !args.has("no-decompositions"),
        admit_max_edges: args.get_usize("admit-max-edges", 4096)?,
        admit_max_ms: args.get_u64("admit-max-ms", 0)?,
        retry: !args.has("no-retry"),
        threads,
    };
    let mut service = crate::coordinator::Service::cpu_only();
    let session = service.open_session("default", g0, opts)?;
    // The banner goes to stderr: stdout carries exactly one JSON reply
    // per request line and nothing else, so transcripts stay diffable.
    let snap = session.snapshot();
    eprintln!(
        "serving {} x {} ({} edges, {} butterflies) at epoch {}",
        snap.graph.nu(),
        snap.graph.nv(),
        snap.graph.m(),
        snap.global,
        snap.epoch
    );
    if let Some(addr) = args.get("listen") {
        let (local, _accept) = crate::serve::spawn_listener(std::sync::Arc::clone(&session), addr)?;
        eprintln!("listening on {local}");
    }
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    crate::serve::serve_lines(&session, stdin.lock(), stdout.lock())?;
    Ok(())
}

fn cmd_dense(args: &Args) -> anyhow::Result<()> {
    let g = load(args)?;
    // --backend (auto | rust | pjrt | none) overrides the
    // PARBUTTERFLY_BACKEND env selection for this run; resolution
    // errors (unknown name, pjrt feature off, artifacts missing)
    // surface directly instead of degrading.
    let coord = match args.get("backend") {
        Some(choice) => match crate::runtime::backend_for(choice)? {
            Some(backend) => Coordinator::with_backend(backend),
            None => anyhow::bail!("dense path disabled by --backend {choice}"),
        },
        None => Coordinator::with_default_backend(),
    };
    anyhow::ensure!(coord.has_backend(), "no dense backend available (PARBUTTERFLY_BACKEND=none?)");
    let r = coord.count_total_routed(&g, &CountConfig::default())?;
    println!("total = {} via {} backend ({:.2} ms)", r.total, r.backend, r.millis);
    Ok(())
}

fn cmd_backends() -> anyhow::Result<()> {
    use crate::runtime::DenseBackend;
    println!("counting engines (count --engine E):");
    let aggs = WedgeAgg::ALL.map(|a| a.name()).join("/");
    println!("  wedges     materializing aggregation ({aggs})");
    println!("  intersect  streaming per-source counter (no wedge materialization)");
    println!("peeling engines (peel --engine E, default via PARBUTTERFLY_PEEL_ENGINE):");
    println!("  agg        UPDATE-V/E through the wedge aggregations ({aggs})");
    println!("  intersect  streaming live-view updates (no wedge materialization)");
    println!("  two-phase  coarse range staging + concurrent per-range fine peels");
    println!("  selected default: {}", PeelEngine::default().name());
    println!("memory layouts (--layout L, default via PARBUTTERFLY_LAYOUT):");
    println!("  auto       hub bitmaps + renumbering when degree skew justifies them");
    println!("  flat       rank-ordered CSR walks only");
    println!("  hub        force the hub renumbering / bitmap fast path");
    println!("  selected default: {}", Layout::default().name());
    println!("dense backends (dense --backend B):");
    let rd = crate::runtime::RustDense::default();
    println!("rust-dense  available  (max tile {0} x {0})", rd.max_dim());
    // Availability probe is a manifest check only — `selected` below is
    // the one place a PJRT client actually starts.
    #[cfg(feature = "pjrt")]
    if crate::count::dense::artifacts_available() {
        println!("pjrt        artifacts present");
    } else {
        println!("pjrt        unavailable (no artifacts manifest; run `make artifacts`)");
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt        disabled   (build with --features pjrt)");
    let selected = crate::runtime::default_backend();
    println!(
        "selected: {}",
        selected.as_deref().map(|b| b.name()).unwrap_or("none (dense path off)")
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts() -> anyhow::Result<()> {
    let engine = crate::runtime::Engine::load_default()?;
    for s in engine.specs() {
        println!("{:<14} {:>4} x {:<4} {} outputs  {}", s.entry, s.u, s.v, s.n_out, s.path.display());
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts() -> anyhow::Result<()> {
    anyhow::bail!("built without the `pjrt` feature; rebuild with --features pjrt")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parsing() {
        let argv: Vec<String> = ["--nu", "5", "--cache-opt", "--out", "x.txt"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.get_usize("nu", 0).unwrap(), 5);
        assert!(a.has("cache-opt"));
        assert_eq!(a.get("out"), Some("x.txt"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn gen_info_count_roundtrip() {
        let dir = std::env::temp_dir().join("pb_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        let argv: Vec<String> = [
            "gen", "--kind", "davis", "--out", path.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run_inner(&argv).unwrap();
        let argv: Vec<String> =
            ["count", "--graph", path.to_str().unwrap()].iter().map(|s| s.to_string()).collect();
        run_inner(&argv).unwrap();
        let argv: Vec<String> =
            ["count", "--graph", path.to_str().unwrap(), "--engine", "intersect", "--mode", "full"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run_inner(&argv).unwrap();
        let argv: Vec<String> =
            ["peel", "--graph", path.to_str().unwrap()].iter().map(|s| s.to_string()).collect();
        run_inner(&argv).unwrap();
        let argv: Vec<String> =
            ["peel", "--graph", path.to_str().unwrap(), "--engine", "intersect", "--mode", "edge"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run_inner(&argv).unwrap();
        let argv: Vec<String> =
            ["peel", "--graph", path.to_str().unwrap(), "--engine", "two-phase", "--mode", "edge"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run_inner(&argv).unwrap();
        let argv: Vec<String> =
            ["peel", "--graph", path.to_str().unwrap(), "--engine", "bogus"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert!(run_inner(&argv).is_err(), "unknown peel engine must be rejected");
    }

    #[test]
    fn invalid_option_values_are_rejected_naming_the_flag() {
        let dir = std::env::temp_dir().join("pb_cli_reject_test");
        std::fs::create_dir_all(&dir).unwrap();
        let gpath = dir.join("g.txt");
        io::save_edge_list(&gen::davis_southern_women(), &gpath).unwrap();
        let graph = gpath.to_str().unwrap();
        // (argv, flag the error must name) — every enum/numeric knob
        // that used to fall back to its default silently.
        let cases: Vec<(Vec<&str>, &str)> = vec![
            (vec!["count", "--graph", graph, "--engine", "intesect"], "--engine"),
            (vec!["count", "--graph", graph, "--rank", "degre"], "--rank"),
            (vec!["count", "--graph", graph, "--agg", "histo"], "--agg"),
            (vec!["count", "--graph", graph, "--mode", "vertx"], "--mode"),
            (vec!["count", "--graph", graph, "--layout", "hubs"], "--layout"),
            (vec!["peel", "--graph", graph, "--layout", "flt"], "--layout"),
            (vec!["count", "--graph", graph, "--threads", "two"], "--threads"),
            (vec!["count", "--graph", graph, "--threads", "0"], "--threads"),
            (vec!["count", "--graph", graph, "--max-wedges", "1e6"], "--max-wedges"),
            (vec!["peel", "--graph", graph, "--agg", "sortx"], "--agg"),
            (vec!["peel", "--graph", graph, "--buckets", "julienn"], "--buckets"),
            (vec!["peel", "--graph", graph, "--mode", "both"], "--mode"),
            (vec!["peel", "--graph", graph, "--count-engine", "agg"], "--count-engine"),
            (vec!["approx", "--graph", graph, "--method", "color"], "--method"),
            (vec!["approx", "--graph", graph, "--p", "2.0"], "--p"),
            (vec!["approx", "--graph", graph, "--seed", "x"], "--seed"),
            (vec!["gen", "--kind", "er", "--m", "10k", "--out", "/dev/null"], "--m"),
            (vec!["gen", "--kind", "grid", "--out", "/dev/null"], "--kind"),
            (vec!["count", "--graph", graph, "--timeout-ms", "5s"], "--timeout-ms"),
            (vec!["count", "--graph", graph, "--memory-budget", "1GB"], "--memory-budget"),
        ];
        for (argv, flag) in cases {
            let argv: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
            let err = run_inner(&argv).expect_err(&format!("{argv:?} must be rejected"));
            let msg = format!("{err:#}");
            assert!(msg.contains(flag), "error for {argv:?} must name {flag}; got: {msg}");
        }
        // Valid values still work after the strictness pass.
        let argv: Vec<String> =
            ["count", "--graph", graph, "--engine", "intersect", "--rank", "codeg", "--agg",
             "hist", "--threads", "2", "--layout", "hub"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run_inner(&argv).unwrap();
        // Generous budgets parse and complete normally.
        let argv: Vec<String> = ["count", "--graph", graph, "--timeout-ms", "600000",
             "--memory-budget", "4000000000"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        run_inner(&argv).unwrap();
    }

    #[test]
    fn dynamic_skip_bad_lines_records_and_continues() {
        let dir = std::env::temp_dir().join("pb_cli_skipbad_test");
        std::fs::create_dir_all(&dir).unwrap();
        let spath = dir.join("s.txt");
        std::fs::write(&spath, "+ 0 0\nnot a line\n+ 1 1\n+ 0 1\n+ 1 0\n").unwrap();
        let strict: Vec<String> = ["dynamic", "--stream", spath.to_str().unwrap()]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(run_inner(&strict).is_err(), "strict mode still rejects bad lines");
        let mut lenient = strict.clone();
        lenient.push("--skip-bad-lines".to_string());
        lenient.push("--verify".to_string());
        run_inner(&lenient).unwrap();
    }

    #[test]
    fn dynamic_replays_a_stream() {
        let dir = std::env::temp_dir().join("pb_cli_dyn_test");
        std::fs::create_dir_all(&dir).unwrap();
        let spath = dir.join("stream.txt");
        // Build Fig. 1, then remove one edge of the 3-butterfly core.
        std::fs::write(
            &spath,
            "# fig1 as a stream\n1 + 0 0\n1 + 0 1\n1 + 0 2\n1 + 1 0\n1 + 1 1\n1 + 1 2\n\
             2 + 2 2\n3 - 0 0\n",
        )
        .unwrap();
        let argv: Vec<String> =
            ["dynamic", "--stream", spath.to_str().unwrap(), "--verify", "--per-batch"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run_inner(&argv).unwrap();
        // Starting from an existing graph + thread override also works.
        let gpath = dir.join("g.txt");
        let g = gen::davis_southern_women();
        io::save_edge_list(&g, &gpath).unwrap();
        let s2 = dir.join("s2.txt");
        std::fs::write(&s2, "+ 0 0\n- 0 0\n").unwrap();
        let argv: Vec<String> = [
            "dynamic",
            "--stream",
            s2.to_str().unwrap(),
            "--graph",
            gpath.to_str().unwrap(),
            "--threads",
            "2",
            "--rebuild-fraction",
            "0.5",
            "--verify",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run_inner(&argv).unwrap();
        let argv: Vec<String> = ["dynamic", "--stream", "/nonexistent/s.txt"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(run_inner(&argv).is_err());
        // Replay misconfigs are rejected, not silently defaulted.
        for bad in [
            ["--engine", "intersct"],
            ["--rank", "degre"],
            ["--rebuild-fraction", "-1"],
            ["--layout", "dense"],
        ] {
            let argv: Vec<String> = ["dynamic", "--stream", s2.to_str().unwrap(), bad[0], bad[1]]
                .iter()
                .map(|s| s.to_string())
                .collect();
            assert!(run_inner(&argv).is_err(), "{bad:?} must be rejected");
        }
    }
}
