//! `parbutterfly` CLI — see `cli.rs` for commands.
fn main() {
    std::process::exit(parbutterfly::cli::run());
}
