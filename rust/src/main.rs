//! `parbutterfly` CLI — see `cli.rs` for commands.  One-shot commands
//! exit when done; `serve` stays resident until `shutdown` or EOF.
fn main() {
    std::process::exit(parbutterfly::cli::run());
}
