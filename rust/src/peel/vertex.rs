//! PEEL-V — parallel tip decomposition (Algorithm 5).
//!
//! Peels one bipartition (the cheaper one, or the caller's choice);
//! each round extracts every vertex with the minimum butterfly count,
//! recomputes the butterflies destroyed by the batch through the same
//! wedge-aggregation machinery as counting (UPDATE-V = GET-V-WEDGES +
//! COUNT-V-WEDGES), and re-buckets the survivors.  Tip numbers are the
//! running maximum of the extracted counts.
//!
//! Liveness rules (the §4.3.1 double-counting discussion):
//! * wedges are only charged to second endpoints that are still live —
//!   previously peeled vertices and same-round batch members are
//!   skipped entirely (butterflies between two batch members die with
//!   them and charge no one; V-side counts are untracked);
//! * centers are on the un-peeled side and stay valid throughout.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::count::wedges::key_endpoints;
use crate::count::{choose2, WedgeAgg};
use crate::graph::BipartiteGraph;
use crate::prims::hashtable::CountTable;
use crate::prims::histogram::histogram;
use crate::prims::pool::{num_threads, parallel_for_chunks, parallel_for_dynamic};
use crate::prims::semisort::aggregate_counts;

use super::bucket::{make_buckets, BucketKind};
use super::delta::DenseDelta;

/// Which bipartition to peel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeelSide {
    U,
    V,
    /// Pick the side whose peeling processes fewer wedges (§4.3.1).
    Auto,
}

/// Result of a tip decomposition.
#[derive(Clone, Debug)]
pub struct TipResult {
    /// True if the U side was peeled.
    pub peeled_u: bool,
    /// Tip number per vertex of the peeled side.
    pub tips: Vec<u64>,
    /// Number of peeling rounds (rho_v).
    pub rounds: usize,
}

/// Options for vertex peeling.
#[derive(Clone, Debug)]
pub struct PeelVOpts {
    pub agg: WedgeAgg,
    pub buckets: BucketKind,
    pub side: PeelSide,
}

impl Default for PeelVOpts {
    fn default() -> Self {
        // §Perf: batch aggregation wins on this substrate (Fig 12 rows:
        // BatchS 431 ms vs Hist 678 ms on `cl`); the paper found
        // histogramming best on 48 cores — the option is one field away.
        Self { agg: WedgeAgg::BatchS, buckets: BucketKind::Julienne, side: PeelSide::Auto }
    }
}

/// Presents the peeled side uniformly regardless of orientation.
struct SideView<'a> {
    g: &'a BipartiteGraph,
    peel_u: bool,
}

impl<'a> SideView<'a> {
    fn n_peel(&self) -> usize {
        if self.peel_u {
            self.g.nu()
        } else {
            self.g.nv()
        }
    }
    fn nbrs_peel(&self, x: usize) -> &[u32] {
        if self.peel_u {
            self.g.nbrs_u(x)
        } else {
            self.g.nbrs_v(x)
        }
    }
    fn nbrs_other(&self, y: usize) -> &[u32] {
        if self.peel_u {
            self.g.nbrs_v(y)
        } else {
            self.g.nbrs_u(y)
        }
    }
}

/// Tip decomposition given per-vertex butterfly counts for both sides
/// (from the counting framework — step 1 of Figure 4).
pub fn peel_vertices(g: &BipartiteGraph, bu: &[u64], bv: &[u64], opts: &PeelVOpts) -> TipResult {
    let peel_u = match opts.side {
        PeelSide::U => true,
        PeelSide::V => false,
        // Peeling side X retrieves wedges with endpoints in X, whose
        // centers are on the other side: pick the cheaper direction.
        PeelSide::Auto => g.wedges_centered_v() <= g.wedges_centered_u(),
    };
    let view = SideView { g, peel_u };
    let counts: &[u64] = if peel_u { bu } else { bv };
    let n = view.n_peel();
    assert_eq!(counts.len(), n, "counts must cover the peeled side");
    let mut buckets = make_buckets(opts.buckets, counts);
    let mut peeled = vec![false; n];
    let mut tips = vec![0u64; n];
    let mut k = 0u64;
    let mut rounds = 0usize;
    // §Perf: allocate the delta accumulator and the batch-aggregation
    // scratch once per decomposition (per-round Mutex<HashMap> merging
    // used to dominate at high rho_v — see EXPERIMENTS.md §Perf).
    let mut delta = DenseDelta::new(n);
    let mut scratch = BatchScratch { cnt: vec![0u32; n], touched: Vec::new() };

    while let Some((c, batch)) = buckets.pop_min() {
        rounds += 1;
        k = k.max(c);
        for &x in &batch {
            tips[x as usize] = k;
            peeled[x as usize] = true;
        }
        update_v(&view, &batch, &peeled, opts.agg, &mut delta, &mut scratch);
        delta.drain(|x2, removed| {
            if peeled[x2 as usize] {
                return;
            }
            let cur = buckets.current(x2);
            let nc = cur.saturating_sub(removed).max(k);
            buckets.update(x2, nc);
        });
    }
    TipResult { peeled_u: peel_u, tips, rounds }
}

/// Persistent scratch for the batch aggregation path.
struct BatchScratch {
    cnt: Vec<u32>,
    touched: Vec<u32>,
}

/// UPDATE-V: butterflies destroyed per live second endpoint,
/// accumulated into `out`.
fn update_v(
    view: &SideView<'_>,
    batch: &[u32],
    peeled: &[bool],
    agg: WedgeAgg,
    out: &mut DenseDelta,
    scratch: &mut BatchScratch,
) {
    match agg {
        WedgeAgg::Hash => update_v_hash(view, batch, peeled, out),
        WedgeAgg::Sort | WedgeAgg::Hist => update_v_sorted(view, batch, peeled, agg, out),
        WedgeAgg::BatchS | WedgeAgg::BatchWA => {
            update_v_batch(view, batch, peeled, agg == WedgeAgg::BatchWA, out, scratch)
        }
    }
}

/// Merge per-pair multiplicities into per-x2 removals.
fn fold_pairs(pairs: impl IntoIterator<Item = (u64, u64)>, out: &mut DenseDelta) {
    for (key, d) in pairs {
        let b = choose2(d);
        if b > 0 {
            let (_x1, x2) = key_endpoints(key);
            out.add(x2, b);
        }
    }
}

/// Enumerate wedge keys `(x1 peeled, x2 live)` into `sink`.
fn enumerate_keys(
    view: &SideView<'_>,
    batch: &[u32],
    peeled: &[bool],
    sink: &(impl Fn(u64) + Sync),
) {
    parallel_for_dynamic(batch.len(), 2, |r| {
        for bi in r {
            let x1 = batch[bi];
            for &y in view.nbrs_peel(x1 as usize) {
                for &x2 in view.nbrs_other(y as usize) {
                    if x2 != x1 && !peeled[x2 as usize] {
                        sink(((x1 as u64) << 32) | x2 as u64);
                    }
                }
            }
        }
    });
}

fn update_v_hash(view: &SideView<'_>, batch: &[u32], peeled: &[bool], out: &mut DenseDelta) {
    let cap = estimate_wedges(view, batch).max(4);
    let table = CountTable::with_capacity(cap);
    enumerate_keys(view, batch, peeled, &|key| table.insert_add(key, 1));
    fold_pairs(table.to_vec(), out);
}

fn update_v_sorted(
    view: &SideView<'_>,
    batch: &[u32],
    peeled: &[bool],
    agg: WedgeAgg,
    out: &mut DenseDelta,
) {
    let keys = Mutex::new(Vec::<u64>::new());
    // Buffer per worker chunk to cut lock traffic.
    parallel_for_dynamic(batch.len(), 2, |r| {
        let mut local = Vec::new();
        for bi in r {
            let x1 = batch[bi];
            for &y in view.nbrs_peel(x1 as usize) {
                for &x2 in view.nbrs_other(y as usize) {
                    if x2 != x1 && !peeled[x2 as usize] {
                        local.push(((x1 as u64) << 32) | x2 as u64);
                    }
                }
            }
        }
        if !local.is_empty() {
            keys.lock().unwrap().extend(local);
        }
    });
    let keys = keys.into_inner().unwrap();
    match agg {
        WedgeAgg::Sort => fold_pairs(aggregate_counts(keys, false), out),
        _ => fold_pairs(histogram(&keys), out),
    }
}

/// Batch aggregation: workers own a dense count array indexed by the
/// second endpoint and aggregate each peeled vertex's wedges serially.
/// Sequential fast path reuses the decomposition-lifetime scratch
/// (zero allocation per round).
fn update_v_batch(
    view: &SideView<'_>,
    batch: &[u32],
    peeled: &[bool],
    dynamic: bool,
    out: &mut DenseDelta,
    scratch: &mut BatchScratch,
) {
    let n = view.n_peel();
    if num_threads() <= 1 {
        let cnt = &mut scratch.cnt;
        let touched = &mut scratch.touched;
        for &x1 in batch {
            for &y in view.nbrs_peel(x1 as usize) {
                for &x2 in view.nbrs_other(y as usize) {
                    if x2 != x1 && !peeled[x2 as usize] {
                        if cnt[x2 as usize] == 0 {
                            touched.push(x2);
                        }
                        cnt[x2 as usize] += 1;
                    }
                }
            }
            for &x2 in touched.iter() {
                out.add(x2, choose2(cnt[x2 as usize] as u64));
                cnt[x2 as usize] = 0;
            }
            touched.clear();
        }
        return;
    }
    let merged = Mutex::new(HashMap::<u32, u64>::new());
    let process = |range: std::ops::Range<usize>| {
        let mut cnt = vec![0u32; n];
        let mut touched: Vec<u32> = Vec::new();
        let mut local: HashMap<u32, u64> = HashMap::new();
        for bi in range {
            let x1 = batch[bi];
            for &y in view.nbrs_peel(x1 as usize) {
                for &x2 in view.nbrs_other(y as usize) {
                    if x2 != x1 && !peeled[x2 as usize] {
                        if cnt[x2 as usize] == 0 {
                            touched.push(x2);
                        }
                        cnt[x2 as usize] += 1;
                    }
                }
            }
            for &x2 in &touched {
                let b = choose2(cnt[x2 as usize] as u64);
                if b > 0 {
                    *local.entry(x2).or_insert(0) += b;
                }
                cnt[x2 as usize] = 0;
            }
            touched.clear();
        }
        let mut g = merged.lock().unwrap();
        for (x2, b) in local {
            *g.entry(x2).or_insert(0) += b;
        }
    };
    if dynamic {
        parallel_for_dynamic(batch.len(), 1, process);
    } else {
        parallel_for_chunks(batch.len(), process);
    }
    for (x2, b) in merged.into_inner().unwrap() {
        out.add(x2, b);
    }
}

fn estimate_wedges(view: &SideView<'_>, batch: &[u32]) -> usize {
    batch
        .iter()
        .map(|&x1| {
            view.nbrs_peel(x1 as usize)
                .iter()
                .map(|&y| view.nbrs_other(y as usize).len())
                .sum::<usize>()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::{count_per_vertex, CountOpts};
    use crate::graph::gen;
    use crate::testutil::brute;

    fn tips_via(g: &BipartiteGraph, opts: &PeelVOpts) -> TipResult {
        let vc = count_per_vertex(g, &CountOpts::default());
        peel_vertices(g, &vc.bu, &vc.bv, opts)
    }

    #[test]
    fn complete_bipartite_all_equal() {
        let g = gen::complete_bipartite(4, 5);
        let r = tips_via(
            &g,
            &PeelVOpts { side: PeelSide::U, ..Default::default() },
        );
        assert!(r.peeled_u);
        assert_eq!(r.tips, brute::tip_numbers_u(&g));
        assert_eq!(r.rounds, 1);
    }

    #[test]
    fn matches_brute_force_over_all_configs() {
        for seed in [1, 5, 9] {
            let g = gen::erdos_renyi(12, 14, 80, seed);
            let expect = brute::tip_numbers_u(&g);
            for agg in WedgeAgg::ALL {
                for buckets in BucketKind::ALL {
                    let r = tips_via(&g, &PeelVOpts { agg, buckets, side: PeelSide::U });
                    assert_eq!(r.tips, expect, "seed={seed} agg={agg:?} {buckets:?}");
                }
            }
        }
    }

    #[test]
    fn v_side_peeling_matches_mirrored_graph() {
        let g = gen::erdos_renyi(10, 13, 60, 2);
        // Peel V of g == peel U of the transposed graph.
        let edges_t: Vec<(u32, u32)> = g.edges().into_iter().map(|(u, v)| (v, u)).collect();
        let gt = BipartiteGraph::from_edges(g.nv(), g.nu(), &edges_t);
        let rv = tips_via(&g, &PeelVOpts { side: PeelSide::V, ..Default::default() });
        let ru = tips_via(&gt, &PeelVOpts { side: PeelSide::U, ..Default::default() });
        assert!(!rv.peeled_u);
        assert_eq!(rv.tips, ru.tips);
    }

    #[test]
    fn auto_picks_cheaper_side() {
        // K_{3,30}: wedges centered V (C(3,2)*30=90) << centered U
        // (3*C(30,2)=1305): endpoints on U are cheap -> peel U.
        let g = gen::complete_bipartite(3, 30);
        let r = tips_via(&g, &PeelVOpts::default());
        assert!(r.peeled_u);
    }

    #[test]
    fn planted_blocks_have_block_tips() {
        // Two disjoint K_{5,5} blocks: every U vertex has tip number
        // C(4,1)*C(5,2)... = butterflies per vertex = 4*10 = 40.
        let g = gen::planted_blocks(10, 10, 2, 5, 5, 1.0, 0, 1);
        let r = tips_via(&g, &PeelVOpts { side: PeelSide::U, ..Default::default() });
        assert_eq!(r.tips, vec![40u64; 10]);
        assert_eq!(r.tips, brute::tip_numbers_u(&g));
    }
}
