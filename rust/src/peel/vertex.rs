//! PEEL-V — parallel tip decomposition (Algorithm 5).
//!
//! Peels one bipartition (the cheaper one, or the caller's choice);
//! each round extracts every vertex with the minimum butterfly count,
//! recomputes the butterflies destroyed by the batch, and re-buckets
//! the survivors.  Tip numbers are the running maximum of the
//! extracted counts.  Three UPDATE-V engines ([`PeelEngine`]):
//!
//! * **Agg** — the paper's GET-V-WEDGES + COUNT-V-WEDGES through the
//!   configured wedge-aggregation strategy; per-round memory scales
//!   with the batch's wedge count.
//! * **Intersect** — streaming two-hop walks (batch vertex -> center
//!   -> live second endpoint) over a [`LiveCsr`] view that the peeled
//!   side is removed from as it dies, with a dense `TouchedCounter`
//!   (crate-internal, shared with the streaming count engine) per
//!   worker and per-worker [`DenseDelta`]
//!   accumulators merged in parallel.  No wedge record is ever
//!   materialized, and late rounds never rescan peeled vertices.
//! * **TwoPhase** — coarse range staging followed by concurrent
//!   per-range fine peels ([`super::two_phase`]); reuses the intersect
//!   round machinery inside each range.
//!
//! Liveness rules (the §4.3.1 double-counting discussion):
//! * wedges are only charged to second endpoints that are still live —
//!   previously peeled vertices and same-round batch members are
//!   skipped entirely (butterflies between two batch members die with
//!   them and charge no one; V-side counts are untracked).  The
//!   intersect engine gets this by construction: the whole batch is
//!   retired from the live view before the walk;
//! * centers are on the un-peeled side and stay valid throughout.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::count::intersect::TouchedCounter;
use crate::error::{guard, Result};
use crate::count::wedges::key_endpoints;
use crate::count::{choose2, WedgeAgg};
use crate::graph::ranked::walk_grain;
use crate::graph::{BipartiteGraph, Layout};
use crate::prims::budget::{self, Budget};
use crate::prims::hashtable::CountTable;
use crate::prims::histogram::histogram;
use crate::prims::pool::{
    num_threads, parallel_for_chunks, parallel_for_dynamic, parallel_for_dynamic_pooled,
    ScratchPool,
};
use crate::prims::semisort::aggregate_counts;

use super::bucket::{make_buckets, BucketKind};
use super::delta::DenseDelta;
use super::live::LiveCsr;
use super::PeelEngine;

/// Which bipartition to peel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeelSide {
    U,
    V,
    /// Pick the side whose peeling processes fewer wedges (§4.3.1).
    Auto,
}

/// Result of a tip decomposition.
#[derive(Clone, Debug)]
pub struct TipResult {
    /// True if the U side was peeled.
    pub peeled_u: bool,
    /// Tip number per vertex of the peeled side.
    pub tips: Vec<u64>,
    /// Number of peeling rounds (rho_v).
    pub rounds: usize,
}

/// Options for vertex peeling.
///
/// ```
/// use parbutterfly::count::CountOpts;
/// use parbutterfly::graph::gen;
/// use parbutterfly::peel::{tip_decomposition, PeelSide, PeelVOpts};
///
/// let g = gen::complete_bipartite(3, 4);
/// let opts = PeelVOpts { side: PeelSide::U, ..Default::default() };
/// let t = tip_decomposition(&g, &CountOpts::default(), &opts).unwrap();
/// // Every U vertex of K_{3,4} sits in C(2,1)·C(4,2) = 12 butterflies
/// // and they all peel together.
/// assert_eq!(t.tips, vec![12, 12, 12]);
/// ```
#[derive(Clone, Debug)]
pub struct PeelVOpts {
    /// UPDATE-V engine; [`PeelEngine::Intersect`] ignores `agg`.
    pub engine: PeelEngine,
    pub agg: WedgeAgg,
    pub buckets: BucketKind,
    pub side: PeelSide,
    /// Memory layout of the intersect walks (hub = degree-descending
    /// relabeling so the counter hot slots cluster; see
    /// [`peel_vertices_relabeled`]).  Only [`PeelEngine::Intersect`]
    /// and [`PeelEngine::TwoPhase`] consult it; tip numbers are
    /// identical across layouts.
    pub layout: Layout,
    /// Cooperative limits for this decomposition (see
    /// [`CountOpts::budget`](crate::count::CountOpts::budget)).
    pub budget: Budget,
}

impl Default for PeelVOpts {
    fn default() -> Self {
        // §Perf: batch aggregation wins on this substrate (Fig 12 rows:
        // BatchS 431 ms vs Hist 678 ms on `cl`); the paper found
        // histogramming best on 48 cores — the option is one field away.
        Self {
            engine: PeelEngine::default(),
            agg: WedgeAgg::BatchS,
            buckets: BucketKind::Julienne,
            side: PeelSide::Auto,
            layout: Layout::default_from_env(),
            budget: Budget::default(),
        }
    }
}

/// Presents the peeled side uniformly regardless of orientation.
/// Shared with the two-phase engine ([`super::two_phase`]).
pub(super) struct SideView<'a> {
    pub(super) g: &'a BipartiteGraph,
    pub(super) peel_u: bool,
}

impl<'a> SideView<'a> {
    pub(super) fn n_peel(&self) -> usize {
        if self.peel_u {
            self.g.nu()
        } else {
            self.g.nv()
        }
    }
    pub(super) fn nbrs_peel(&self, x: usize) -> &[u32] {
        if self.peel_u {
            self.g.nbrs_u(x)
        } else {
            self.g.nbrs_v(x)
        }
    }
    pub(super) fn nbrs_other(&self, y: usize) -> &[u32] {
        if self.peel_u {
            self.g.nbrs_v(y)
        } else {
            self.g.nbrs_u(y)
        }
    }
    /// Edge id of the `i`-th neighbor slot of peel-side vertex `x`.
    pub(super) fn eid_peel(&self, x: usize, i: usize) -> u32 {
        if self.peel_u {
            self.g.eid_u(x, i)
        } else {
            self.g.eids_v(x)[i]
        }
    }
    /// Live view whose rows are the centers (the un-peeled side) and
    /// whose entries are peel-side vertices.
    pub(super) fn live_centers(&self) -> LiveCsr {
        if self.peel_u {
            LiveCsr::v_view(self.g)
        } else {
            LiveCsr::u_view(self.g)
        }
    }
    /// [`Self::live_centers`] restricted to the peel-side entries
    /// `keep(x, eid)` accepts — the two-phase engine's per-range
    /// sub-views.
    pub(super) fn live_centers_filtered(
        &self,
        keep: &(impl Fn(u32, u32) -> bool + ?Sized),
    ) -> LiveCsr {
        if self.peel_u {
            LiveCsr::v_view_filtered(self.g, keep)
        } else {
            LiveCsr::u_view_filtered(self.g, keep)
        }
    }
}

/// Tip decomposition given per-vertex butterfly counts for both sides
/// (from the counting framework — step 1 of Figure 4).
///
/// Runs under [`PeelVOpts::budget`]; a worker panic, injected fault,
/// or budget trip returns a structured [`Err`](crate::Error) instead
/// of aborting.
pub fn peel_vertices(
    g: &BipartiteGraph,
    bu: &[u64],
    bv: &[u64],
    opts: &PeelVOpts,
) -> Result<TipResult> {
    guard(&opts.budget, || peel_vertices_raw(g, bu, bv, opts))
}

pub(crate) fn peel_vertices_raw(
    g: &BipartiteGraph,
    bu: &[u64],
    bv: &[u64],
    opts: &PeelVOpts,
) -> TipResult {
    let peel_u = match opts.side {
        PeelSide::U => true,
        PeelSide::V => false,
        // Peeling side X retrieves wedges with endpoints in X, whose
        // centers are on the other side: pick the cheaper direction.
        PeelSide::Auto => g.wedges_centered_v() <= g.wedges_centered_u(),
    };
    // Cache-aware layout: only the intersect engine walks the dense
    // counter this helps (Agg ignores `layout` exactly as Intersect
    // ignores `agg`).
    if matches!(opts.engine, PeelEngine::Intersect | PeelEngine::TwoPhase)
        && opts.layout.resolve(g.m()) == Layout::Hub
    {
        return peel_vertices_relabeled(g, bu, bv, opts, peel_u);
    }
    let view = SideView { g, peel_u };
    let counts: &[u64] = if peel_u { bu } else { bv };
    assert_eq!(counts.len(), view.n_peel(), "counts must cover the peeled side");
    match opts.engine {
        PeelEngine::Agg => peel_vertices_agg(&view, counts, opts),
        PeelEngine::Intersect => peel_vertices_intersect(&view, counts, opts),
        PeelEngine::TwoPhase => super::two_phase::peel_vertices_two_phase(&view, counts, opts),
    }
}

/// Stable permutation `old id -> new id` ordering vertices by
/// decreasing degree (ties by id).
fn degree_desc_perm(n: usize, deg: impl Fn(usize) -> usize) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by(|&a, &b| {
        deg(b as usize).cmp(&deg(a as usize)).then_with(|| a.cmp(&b))
    });
    let mut perm = vec![0u32; n];
    for (new, &old) in order.iter().enumerate() {
        perm[old as usize] = new as u32;
    }
    perm
}

/// The peel analogue of the counting engine's hub renumbering: rebuild
/// the graph with both sides relabeled by decreasing degree, peel the
/// relabeled graph flat, and un-permute the tips.
///
/// The intersect walk's scratch — the dense `TouchedCounter` over the
/// peeled side and the `DenseDelta` accumulators — is indexed by
/// peel-side vertex id, and hot slots are exactly the high-degree
/// vertices (reached through many centers).  Degree-descending ids
/// cluster them into a cache-resident prefix.  Hub *bitmaps* don't
/// apply here: the live view shrinks every round, so a static bitmap
/// would go stale.
///
/// Tip numbers are graph properties: rounds, batch sets, and all
/// removal sums are invariant under relabeling, so the un-permuted
/// result is bit-identical to the flat path's.
fn peel_vertices_relabeled(
    g: &BipartiteGraph,
    bu: &[u64],
    bv: &[u64],
    opts: &PeelVOpts,
    peel_u: bool,
) -> TipResult {
    let perm_u = degree_desc_perm(g.nu(), |u| g.deg_u(u));
    let perm_v = degree_desc_perm(g.nv(), |v| g.deg_v(v));
    let edges: Vec<(u32, u32)> = g
        .edges()
        .into_iter()
        .map(|(u, v)| (perm_u[u as usize], perm_v[v as usize]))
        .collect();
    let g2 = BipartiteGraph::from_edges(g.nu(), g.nv(), &edges);
    let mut bu2 = vec![0u64; g.nu()];
    for (u, &c) in bu.iter().enumerate() {
        bu2[perm_u[u] as usize] = c;
    }
    let mut bv2 = vec![0u64; g.nv()];
    for (v, &c) in bv.iter().enumerate() {
        bv2[perm_v[v] as usize] = c;
    }
    // Pin the side (Auto would re-derive it, identically — the wedge
    // totals are degree-multiset invariants — but pinning is free) and
    // drop to the flat path on the relabeled graph.
    let opts2 = PeelVOpts {
        layout: Layout::Flat,
        side: if peel_u { PeelSide::U } else { PeelSide::V },
        ..opts.clone()
    };
    let r2 = peel_vertices_raw(&g2, &bu2, &bv2, &opts2);
    let perm = if peel_u { &perm_u } else { &perm_v };
    let tips = perm.iter().map(|&p| r2.tips[p as usize]).collect();
    TipResult { peeled_u: peel_u, tips, rounds: r2.rounds }
}

/// The aggregation engine: UPDATE-V through `opts.agg`.
fn peel_vertices_agg(view: &SideView<'_>, counts: &[u64], opts: &PeelVOpts) -> TipResult {
    let n = view.n_peel();
    budget::probe_alloc(n * (8 + 1) + 2 * n * 8, "peel-v buckets/tips/delta scratch");
    let mut buckets = make_buckets(opts.buckets, counts);
    let mut peeled = vec![false; n];
    let mut tips = vec![0u64; n];
    let mut k = 0u64;
    let mut rounds = 0usize;
    // §Perf: allocate the delta accumulator and the batch-aggregation
    // scratch once per decomposition (per-round Mutex<HashMap> merging
    // used to dominate at high rho_v — measured on the e2e workload).
    let mut delta = DenseDelta::new(n);
    let mut scratch = TouchedCounter::new(n);

    while let Some((c, batch)) = buckets.pop_min() {
        rounds += 1;
        k = k.max(c);
        for &x in &batch {
            tips[x as usize] = k;
            peeled[x as usize] = true;
        }
        update_v(view, &batch, &peeled, opts.agg, &mut delta, &mut scratch);
        delta.drain(|x2, removed| {
            if peeled[x2 as usize] {
                return;
            }
            let cur = buckets.current(x2);
            let nc = cur.saturating_sub(removed).max(k);
            buckets.update(x2, nc);
        });
    }
    TipResult { peeled_u: view.peel_u, tips, rounds }
}

/// Per-worker scratch for the intersect engine: the dense wedge tally
/// for the source being walked and the worker's share of the round's
/// deltas.  Pooled across rounds — steady state allocates nothing.
pub(super) struct VScratch {
    pub(super) ctr: TouchedCounter,
    pub(super) delta: DenseDelta,
}

/// The streaming intersect engine: per-batch-vertex two-hop walks over
/// a shrinking live view.  No wedge records, no `peeled[]` filtering —
/// dead vertices are simply no longer in the view.
fn peel_vertices_intersect(view: &SideView<'_>, counts: &[u64], opts: &PeelVOpts) -> TipResult {
    let n = view.n_peel();
    budget::probe_alloc(n * 8 + 2 * n * 8, "peel-v live view/tips/delta");
    let mut live = view.live_centers();
    let mut buckets = make_buckets(opts.buckets, counts);
    let mut tips = vec![0u64; n];
    let mut k = 0u64;
    let mut rounds = 0usize;
    let mut delta = DenseDelta::new(n);
    let mut pool: ScratchPool<VScratch> = ScratchPool::new();
    // Expected touched-counter footprint of one batch vertex's walk:
    // drives the tile-derived claim grain instead of the old
    // hard-coded constant.
    let fp = wedge_footprint(view);

    while let Some((c, batch)) = buckets.pop_min() {
        rounds += 1;
        k = k.max(c);
        for &x in &batch {
            tips[x as usize] = k;
        }
        // Retire the whole batch from the live view up front: a walk
        // then meets neither previously-peeled vertices, nor same-round
        // members, nor the source itself (§4.3.1's liveness rules, by
        // construction instead of by filtering).
        for &x1 in &batch {
            for (i, &y) in view.nbrs_peel(x1 as usize).iter().enumerate() {
                live.remove(y as usize, view.eid_peel(x1 as usize, i));
            }
        }
        // UPDATE-V: for each batch vertex, tally live second endpoints
        // through its centers; each endpoint reached through d centers
        // loses C(d, 2) butterflies.
        {
            let (live, batch) = (&live, &batch[..]);
            parallel_for_dynamic_pooled(
                batch.len(),
                walk_grain(batch.len(), fp),
                &pool,
                || {
                    budget::probe_alloc(2 * n * 8, "peel-v worker scratch");
                    VScratch { ctr: TouchedCounter::new(n), delta: DenseDelta::new(n) }
                },
                |s, range| {
                    for bi in range {
                        let x1 = batch[bi];
                        for &y in view.nbrs_peel(x1 as usize) {
                            for &x2 in live.nbrs(y as usize) {
                                s.ctr.bump(x2);
                            }
                        }
                        let delta = &mut s.delta;
                        s.ctr.drain(|x2, d| delta.add(x2, choose2(d as u64)));
                    }
                },
            );
        }
        // Fold the per-worker accumulators in parallel, then re-bucket.
        let mut parts: Vec<&mut DenseDelta> =
            pool.items_mut().iter_mut().map(|s| &mut s.delta).collect();
        delta.merge_parallel(&mut parts);
        delta.drain(|x2, removed| {
            let cur = buckets.current(x2);
            buckets.update(x2, cur.saturating_sub(removed).max(k));
        });
    }
    TipResult { peeled_u: view.peel_u, tips, rounds }
}

/// UPDATE-V: butterflies destroyed per live second endpoint,
/// accumulated into `out`.  `scratch` is the decomposition-lifetime
/// dense counter the batch path tallies into.
fn update_v(
    view: &SideView<'_>,
    batch: &[u32],
    peeled: &[bool],
    agg: WedgeAgg,
    out: &mut DenseDelta,
    scratch: &mut TouchedCounter,
) {
    match agg {
        WedgeAgg::Hash => update_v_hash(view, batch, peeled, out),
        WedgeAgg::Sort | WedgeAgg::Hist => update_v_sorted(view, batch, peeled, agg, out),
        WedgeAgg::BatchS | WedgeAgg::BatchWA => {
            update_v_batch(view, batch, peeled, agg == WedgeAgg::BatchWA, out, scratch)
        }
    }
}

/// Merge per-pair multiplicities into per-x2 removals.
fn fold_pairs(pairs: impl IntoIterator<Item = (u64, u64)>, out: &mut DenseDelta) {
    for (key, d) in pairs {
        let b = choose2(d);
        if b > 0 {
            let (_x1, x2) = key_endpoints(key);
            out.add(x2, b);
        }
    }
}

/// Enumerate wedge keys `(x1 peeled, x2 live)` into `sink`.
fn enumerate_keys(
    view: &SideView<'_>,
    batch: &[u32],
    peeled: &[bool],
    sink: &(impl Fn(u64) + Sync),
) {
    parallel_for_dynamic(batch.len(), walk_grain(batch.len(), wedge_footprint(view)), |r| {
        for bi in r {
            let x1 = batch[bi];
            for &y in view.nbrs_peel(x1 as usize) {
                for &x2 in view.nbrs_other(y as usize) {
                    if x2 != x1 && !peeled[x2 as usize] {
                        sink(((x1 as u64) << 32) | x2 as u64);
                    }
                }
            }
        }
    });
}

fn update_v_hash(view: &SideView<'_>, batch: &[u32], peeled: &[bool], out: &mut DenseDelta) {
    let cap = estimate_wedges(view, batch).max(4);
    let table = CountTable::with_capacity(cap);
    enumerate_keys(view, batch, peeled, &|key| table.insert_add(key, 1));
    fold_pairs(table.to_vec(), out);
}

fn update_v_sorted(
    view: &SideView<'_>,
    batch: &[u32],
    peeled: &[bool],
    agg: WedgeAgg,
    out: &mut DenseDelta,
) {
    let keys = Mutex::new(Vec::<u64>::new());
    // Buffer per worker chunk to cut lock traffic.
    parallel_for_dynamic(batch.len(), walk_grain(batch.len(), wedge_footprint(view)), |r| {
        let mut local = Vec::new();
        for bi in r {
            let x1 = batch[bi];
            for &y in view.nbrs_peel(x1 as usize) {
                for &x2 in view.nbrs_other(y as usize) {
                    if x2 != x1 && !peeled[x2 as usize] {
                        local.push(((x1 as u64) << 32) | x2 as u64);
                    }
                }
            }
        }
        if !local.is_empty() {
            keys.lock().unwrap().extend(local);
        }
    });
    let keys = keys.into_inner().unwrap();
    match agg {
        WedgeAgg::Sort => fold_pairs(aggregate_counts(keys, false), out),
        _ => fold_pairs(histogram(&keys), out),
    }
}

/// Batch aggregation: workers own a dense count array indexed by the
/// second endpoint and aggregate each peeled vertex's wedges serially.
/// Sequential fast path reuses the decomposition-lifetime scratch
/// (zero allocation per round).
fn update_v_batch(
    view: &SideView<'_>,
    batch: &[u32],
    peeled: &[bool],
    dynamic: bool,
    out: &mut DenseDelta,
    scratch: &mut TouchedCounter,
) {
    let n = view.n_peel();
    if num_threads() <= 1 {
        for &x1 in batch {
            for &y in view.nbrs_peel(x1 as usize) {
                for &x2 in view.nbrs_other(y as usize) {
                    if x2 != x1 && !peeled[x2 as usize] {
                        scratch.bump(x2);
                    }
                }
            }
            scratch.drain(|x2, d| out.add(x2, choose2(d as u64)));
        }
        return;
    }
    let merged = Mutex::new(HashMap::<u32, u64>::new());
    let process = |range: std::ops::Range<usize>| {
        let mut ctr = TouchedCounter::new(n);
        let mut local: HashMap<u32, u64> = HashMap::new();
        for bi in range {
            let x1 = batch[bi];
            for &y in view.nbrs_peel(x1 as usize) {
                for &x2 in view.nbrs_other(y as usize) {
                    if x2 != x1 && !peeled[x2 as usize] {
                        ctr.bump(x2);
                    }
                }
            }
            ctr.drain(|x2, d| {
                let b = choose2(d as u64);
                if b > 0 {
                    *local.entry(x2).or_insert(0) += b;
                }
            });
        }
        let mut g = merged.lock().unwrap();
        for (x2, b) in local {
            *g.entry(x2).or_insert(0) += b;
        }
    };
    if dynamic {
        // Each claimed vertex walks a dense counter of the same
        // expected footprint as the intersect engine's, so the claim
        // grain derives from the tile budget the same way.
        parallel_for_dynamic(batch.len(), walk_grain(batch.len(), wedge_footprint(view)), process);
    } else {
        parallel_for_chunks(batch.len(), process);
    }
    for (x2, b) in merged.into_inner().unwrap() {
        out.add(x2, b);
    }
}

/// Expected wedge work per batch vertex (avg peel-side degree × avg
/// center degree), in counter-slot units: the footprint argument that
/// [`walk_grain`] balances against the cache-tile budget.  Shared by
/// the intersect round walks and the wedge-enumeration aggregation
/// paths so no call site hard-codes a claim grain.
pub(super) fn wedge_footprint(view: &SideView<'_>) -> usize {
    let m = view.g.m();
    let a = m.div_ceil(view.n_peel().max(1)).max(1);
    let n_other = view.g.n() - view.n_peel();
    let b = m.div_ceil(n_other.max(1)).max(1);
    a.saturating_mul(b)
}

fn estimate_wedges(view: &SideView<'_>, batch: &[u32]) -> usize {
    batch
        .iter()
        .map(|&x1| {
            view.nbrs_peel(x1 as usize)
                .iter()
                .map(|&y| view.nbrs_other(y as usize).len())
                .sum::<usize>()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::{count_per_vertex, CountOpts};
    use crate::graph::gen;
    use crate::testutil::brute;

    fn tips_via(g: &BipartiteGraph, opts: &PeelVOpts) -> TipResult {
        let vc = count_per_vertex(g, &CountOpts::default()).unwrap();
        peel_vertices(g, &vc.bu, &vc.bv, opts).unwrap()
    }

    #[test]
    fn complete_bipartite_all_equal() {
        let g = gen::complete_bipartite(4, 5);
        let r = tips_via(
            &g,
            &PeelVOpts { side: PeelSide::U, ..Default::default() },
        );
        assert!(r.peeled_u);
        assert_eq!(r.tips, brute::tip_numbers_u(&g));
        assert_eq!(r.rounds, 1);
    }

    #[test]
    fn matches_brute_force_over_all_configs() {
        for seed in [1, 5, 9] {
            let g = gen::erdos_renyi(12, 14, 80, seed);
            let expect = brute::tip_numbers_u(&g);
            for engine in PeelEngine::ALL {
                for agg in WedgeAgg::ALL {
                    for buckets in BucketKind::ALL {
                        // Hub layout forces the degree-descending
                        // relabeled path even on these tiny graphs.
                        for layout in [Layout::Flat, Layout::Hub] {
                            let r = tips_via(
                                &g,
                                &PeelVOpts { engine, agg, buckets, side: PeelSide::U, layout },
                            );
                            assert_eq!(
                                r.tips, expect,
                                "seed={seed} {engine:?} agg={agg:?} {buckets:?} {layout:?}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn v_side_peeling_matches_mirrored_graph() {
        let g = gen::erdos_renyi(10, 13, 60, 2);
        // Peel V of g == peel U of the transposed graph.
        let edges_t: Vec<(u32, u32)> = g.edges().into_iter().map(|(u, v)| (v, u)).collect();
        let gt = BipartiteGraph::from_edges(g.nv(), g.nu(), &edges_t);
        for engine in PeelEngine::ALL {
            let rv = tips_via(&g, &PeelVOpts { engine, side: PeelSide::V, ..Default::default() });
            let ru = tips_via(&gt, &PeelVOpts { engine, side: PeelSide::U, ..Default::default() });
            assert!(!rv.peeled_u);
            assert_eq!(rv.tips, ru.tips, "{engine:?}");
        }
    }

    #[test]
    fn intersect_engine_under_real_fork_join() {
        // The pooled-scratch + parallel-merge machinery must produce
        // identical tips at every thread count.
        let g = gen::chung_lu(40, 50, 500, 2.1, 13);
        let vc = count_per_vertex(&g, &CountOpts::default()).unwrap();
        let base = peel_vertices(
            &g,
            &vc.bu,
            &vc.bv,
            &PeelVOpts { engine: PeelEngine::Agg, side: PeelSide::U, ..Default::default() },
        )
        .unwrap();
        for t in [1usize, 3, 8] {
            let r = crate::prims::pool::with_threads(t, || {
                peel_vertices(
                    &g,
                    &vc.bu,
                    &vc.bv,
                    &PeelVOpts {
                        engine: PeelEngine::Intersect,
                        side: PeelSide::U,
                        ..Default::default()
                    },
                )
                .unwrap()
            });
            assert_eq!(r.tips, base.tips, "threads={t}");
            assert_eq!(r.rounds, base.rounds, "threads={t}");
        }
    }

    #[test]
    fn auto_picks_cheaper_side() {
        // K_{3,30}: wedges centered V (C(3,2)*30=90) << centered U
        // (3*C(30,2)=1305): endpoints on U are cheap -> peel U.
        let g = gen::complete_bipartite(3, 30);
        let r = tips_via(&g, &PeelVOpts::default());
        assert!(r.peeled_u);
    }

    #[test]
    fn planted_blocks_have_block_tips() {
        // Two disjoint K_{5,5} blocks: every U vertex has tip number
        // C(4,1)*C(5,2)... = butterflies per vertex = 4*10 = 40.
        let g = gen::planted_blocks(10, 10, 2, 5, 5, 1.0, 0, 1);
        let r = tips_via(&g, &PeelVOpts { side: PeelSide::U, ..Default::default() });
        assert_eq!(r.tips, vec![40u64; 10]);
        assert_eq!(r.tips, brute::tip_numbers_u(&g));
    }
}
