//! WPEEL-V / WPEEL-E — peeling with stored wedges (Algorithms 7–8).
//!
//! The rank-filtered wedge set of GET-WEDGES is materialized once into
//! an index, after which each peeling round touches only the
//! butterflies actually destroyed — `O(rho log + b)` total work at
//! `O(alpha m)` space (Theorems 4.8/4.9) instead of re-enumerating
//! two-hop neighbourhoods.
//!
//! Index layout (global vertex ids):
//! * `pairs`: endpoint-pair key -> the wedges of that pair, each as
//!   `(center, leg_lo, leg_hi)` (edge ids);
//! * `by_endpoint[x]`: pair keys with `x` as an endpoint;
//! * `by_center[x]`: positions of the wedges centered at `x`.
//!
//! A butterfly's *retrieved representation* is unique (its lowest-rank
//! vertex is an endpoint of both its retrieved wedges), so the two
//! update cases of Algorithm 7 — peeled vertex as endpoint vs as
//! center — partition the destroyed butterflies exactly.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::count::{choose2, wedges};
use crate::graph::BipartiteGraph;
use crate::prims::pool::parallel_for_dynamic;
use crate::rank::{preprocess, Ranking};

use super::bucket::{make_buckets, BucketKind};
use super::edge::WingResult;
use super::vertex::{PeelSide, TipResult};

/// One stored wedge: center + the two leg edge ids.
#[derive(Clone, Copy, Debug)]
struct StoredWedge {
    center: u32,
    e_lo: u32,
    e_hi: u32,
}

/// The materialized wedge index.
pub struct WedgeStore {
    /// pair key (packed global endpoint ids, lo-rank first) -> wedges.
    pairs: HashMap<u64, Vec<StoredWedge>>,
    /// per global vertex: pair keys where it is an endpoint.
    by_endpoint: Vec<Vec<u64>>,
    /// per global vertex: pair keys where it is a wedge center.
    by_center: Vec<Vec<u64>>,
    /// per edge id: (other leg edge id, pair key) for each wedge the
    /// edge participates in (WPEEL-E's `W`).
    by_edge: Vec<Vec<(u32, u64)>>,
    nu: usize,
}

impl WedgeStore {
    /// Materialize the retrieved wedges of `g` under `ranking`.
    pub fn build(g: &BipartiteGraph, ranking: Ranking) -> Self {
        let rg = preprocess(g, ranking);
        let n = g.n();
        let mut store = WedgeStore {
            pairs: HashMap::new(),
            by_endpoint: vec![Vec::new(); n],
            by_center: vec![Vec::new(); n],
            by_edge: vec![Vec::new(); g.m()],
            nu: g.nu(),
        };
        // Sequential build (one pass over the O(alpha m) wedges); the
        // peeling rounds dominate, and HashMap insertion rules out the
        // trivially-parallel fill.
        for src in 0..rg.n() {
            wedges::wedges_of_source(&rg, false, src, |w| {
                let a = rg.orig(w.lo as usize);
                let b = rg.orig(w.hi as usize);
                let c = rg.orig(w.center as usize);
                let key = ((a as u64) << 32) | b as u64;
                let entry = store.pairs.entry(key).or_default();
                if entry.is_empty() {
                    store.by_endpoint[a as usize].push(key);
                    store.by_endpoint[b as usize].push(key);
                }
                entry.push(StoredWedge { center: c, e_lo: w.e_lo, e_hi: w.e_hi });
                store.by_center[c as usize].push(key);
                store.by_edge[w.e_lo as usize].push((w.e_hi, key));
                store.by_edge[w.e_hi as usize].push((w.e_lo, key));
            });
        }
        store
    }

    fn other_endpoint(key: u64, x: u32) -> u32 {
        let a = (key >> 32) as u32;
        let b = key as u32;
        if a == x {
            b
        } else {
            a
        }
    }

    /// Total stored wedges (diagnostics).
    pub fn len(&self) -> usize {
        self.pairs.values().map(|v| v.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

/// WPEEL-V (Algorithm 7): tip decomposition over the stored wedges.
pub fn wpeel_vertices(
    g: &BipartiteGraph,
    store: &WedgeStore,
    bu: &[u64],
    bv: &[u64],
    side: PeelSide,
    buckets_kind: BucketKind,
) -> TipResult {
    let peel_u = match side {
        PeelSide::U => true,
        PeelSide::V => false,
        PeelSide::Auto => g.wedges_centered_v() <= g.wedges_centered_u(),
    };
    let counts: &[u64] = if peel_u { bu } else { bv };
    let n = counts.len();
    let gid_of = |x: u32| -> usize {
        if peel_u {
            x as usize
        } else {
            store.nu + x as usize
        }
    };
    let local_of = |gid: u32| -> u32 {
        if peel_u {
            gid
        } else {
            gid - store.nu as u32
        }
    };
    let on_peel_side =
        |gid: u32| -> bool { ((gid as usize) < store.nu) == peel_u };

    let mut buckets = make_buckets(buckets_kind, counts);
    let mut peeled = vec![false; n];
    let mut tips = vec![0u64; n];
    let mut k = 0u64;
    let mut rounds = 0usize;

    while let Some((c, batch)) = buckets.pop_min() {
        rounds += 1;
        k = k.max(c);
        for &x in &batch {
            tips[x as usize] = k;
            peeled[x as usize] = true;
        }
        // WUPDATE-V over the stored index.
        let deltas = Mutex::new(HashMap::<u32, u64>::new());
        parallel_for_dynamic(batch.len(), 2, |r| {
            let mut local = HashMap::<u32, u64>::new();
            for bi in r {
                let x = batch[bi];
                let xg = gid_of(x) as u32;
                // Case 1: x is an endpoint — the pair's whole butterfly
                // block leaves the live second endpoint.
                for &key in &store.by_endpoint[xg as usize] {
                    let yg = WedgeStore::other_endpoint(key, xg);
                    debug_assert!(on_peel_side(yg) == on_peel_side(xg));
                    if !on_peel_side(yg) {
                        continue;
                    }
                    let y = local_of(yg);
                    if peeled[y as usize] {
                        continue;
                    }
                    let d = store.pairs[&key].len() as u64;
                    let b = choose2(d);
                    if b > 0 {
                        *local.entry(y).or_insert(0) += b;
                    }
                }
                // Case 2: x is a center — each co-center of the pair
                // loses one butterfly.
                for &key in &store.by_center[xg as usize] {
                    for w in &store.pairs[&key] {
                        let zg = w.center;
                        if zg == xg || !on_peel_side(zg) {
                            continue;
                        }
                        let z = local_of(zg);
                        if !peeled[z as usize] {
                            *local.entry(z).or_insert(0) += 1;
                        }
                    }
                }
            }
            if !local.is_empty() {
                let mut g = deltas.lock().unwrap();
                for (z, b) in local {
                    *g.entry(z).or_insert(0) += b;
                }
            }
        });
        for (x2, removed) in deltas.into_inner().unwrap() {
            if peeled[x2 as usize] {
                continue;
            }
            let cur = buckets.current(x2);
            buckets.update(x2, cur.saturating_sub(removed).max(k));
        }
    }
    TipResult { peeled_u: peel_u, tips, rounds }
}

const ALIVE: u32 = u32::MAX;

#[inline]
fn alive_for(round_of: &[u32], round: u32, x: u32, e: u32) -> bool {
    let r = round_of[x as usize];
    r == ALIVE || (r == round && x > e)
}

/// WPEEL-E (Algorithm 8): wing decomposition over the stored wedges.
pub fn wpeel_edges(
    g: &BipartiteGraph,
    store: &WedgeStore,
    be: &[u64],
    buckets_kind: BucketKind,
) -> WingResult {
    let m = g.m();
    assert_eq!(be.len(), m);
    let mut buckets = make_buckets(buckets_kind, be);
    let mut round_of = vec![ALIVE; m];
    let mut wings = vec![0u64; m];
    let mut k = 0u64;
    let mut round = 0u32;

    while let Some((c, batch)) = buckets.pop_min() {
        k = k.max(c);
        for &e in &batch {
            wings[e as usize] = k;
            round_of[e as usize] = round;
        }
        // WUPDATE-E: walk each peeled edge's stored wedges; every live
        // co-center closes a destroyed butterfly.
        let deltas = Mutex::new(HashMap::<u32, u64>::new());
        parallel_for_dynamic(batch.len(), 2, |r| {
            let mut local = HashMap::<u32, u64>::new();
            let mut dec = |e: u32| *local.entry(e).or_insert(0) += 1;
            for bi in r {
                let e = batch[bi];
                for &(e3, key) in &store.by_edge[e as usize] {
                    if !alive_for(&round_of, round, e3, e) {
                        continue;
                    }
                    for w in &store.pairs[&key] {
                        // Skip the wedge (e, e3) itself.
                        if w.e_lo == e || w.e_hi == e {
                            continue;
                        }
                        if alive_for(&round_of, round, w.e_lo, e)
                            && alive_for(&round_of, round, w.e_hi, e)
                        {
                            dec(e3);
                            dec(w.e_lo);
                            dec(w.e_hi);
                        }
                    }
                }
            }
            if !local.is_empty() {
                let mut g = deltas.lock().unwrap();
                for (e, d) in local {
                    *g.entry(e).or_insert(0) += d;
                }
            }
        });
        for (e, removed) in deltas.into_inner().unwrap() {
            if round_of[e as usize] != ALIVE {
                continue;
            }
            let cur = buckets.current(e);
            buckets.update(e, cur.saturating_sub(removed).max(k));
        }
        round += 1;
    }
    WingResult { wings, rounds: round as usize }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::{count_per_edge, count_per_vertex, CountOpts};
    use crate::graph::gen;
    use crate::testutil::brute;

    #[test]
    fn store_holds_all_retrieved_wedges() {
        let g = gen::erdos_renyi(15, 18, 100, 3);
        for ranking in [Ranking::Side, Ranking::Degree] {
            let store = WedgeStore::build(&g, ranking);
            let rg = preprocess(&g, ranking);
            assert_eq!(store.len() as u64, rg.wedges_processed(), "{ranking:?}");
        }
    }

    #[test]
    fn wpeel_v_matches_brute_force() {
        for seed in [1, 4, 8] {
            let g = gen::erdos_renyi(12, 13, 70, seed);
            let expect = brute::tip_numbers_u(&g);
            let vc = count_per_vertex(&g, &CountOpts::default()).unwrap();
            for ranking in [Ranking::Side, Ranking::Degree] {
                let store = WedgeStore::build(&g, ranking);
                for bk in BucketKind::ALL {
                    let r = wpeel_vertices(&g, &store, &vc.bu, &vc.bv, PeelSide::U, bk);
                    assert_eq!(r.tips, expect, "seed={seed} {ranking:?} {bk:?}");
                }
            }
        }
    }

    #[test]
    fn wpeel_v_v_side() {
        let g = gen::erdos_renyi(10, 11, 60, 6);
        let vc = count_per_vertex(&g, &CountOpts::default()).unwrap();
        // Mirror graph for the brute-force expectation.
        let edges_t: Vec<(u32, u32)> = g.edges().into_iter().map(|(u, v)| (v, u)).collect();
        let gt = BipartiteGraph::from_edges(g.nv(), g.nu(), &edges_t);
        let expect = brute::tip_numbers_u(&gt);
        let store = WedgeStore::build(&g, Ranking::Degree);
        let r =
            wpeel_vertices(&g, &store, &vc.bu, &vc.bv, PeelSide::V, BucketKind::Julienne);
        assert_eq!(r.tips, expect);
    }

    #[test]
    fn wpeel_e_matches_brute_force() {
        for seed in [2, 5] {
            let g = gen::erdos_renyi(8, 9, 40, seed);
            let expect = brute::wing_numbers(&g);
            let be = count_per_edge(&g, &CountOpts::default()).unwrap();
            for ranking in [Ranking::Side, Ranking::Degree] {
                let store = WedgeStore::build(&g, ranking);
                for bk in BucketKind::ALL {
                    let r = wpeel_edges(&g, &store, &be, bk);
                    assert_eq!(r.wings, expect, "seed={seed} {ranking:?} {bk:?}");
                }
            }
        }
    }

    #[test]
    fn wpeel_agrees_with_peel() {
        let g = gen::planted_blocks(10, 10, 2, 5, 5, 0.9, 10, 7);
        let vc = count_per_vertex(&g, &CountOpts::default()).unwrap();
        let be = count_per_edge(&g, &CountOpts::default()).unwrap();
        let store = WedgeStore::build(&g, Ranking::Degree);
        let wv = wpeel_vertices(&g, &store, &vc.bu, &vc.bv, PeelSide::U, BucketKind::FibHeap);
        let pv = super::super::vertex::peel_vertices(
            &g,
            &vc.bu,
            &vc.bv,
            &super::super::vertex::PeelVOpts {
                side: PeelSide::U,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(wv.tips, pv.tips);
        let we = wpeel_edges(&g, &store, &be, BucketKind::FibHeap);
        let pe = super::super::edge::peel_edges(&g, &be, &Default::default()).unwrap();
        assert_eq!(we.wings, pe.wings);
    }
}
