//! Butterfly peeling (§3.2, §4.3): tip decomposition (vertex peeling)
//! and wing decomposition (edge peeling).
//!
//! * [`bucket`] — Julienne-style bucketing (128-bucket window +
//!   skip-ahead) and the Fibonacci-heap bucketing of §5.4.
//! * [`fibheap`] — the batch-parallel Fibonacci heap (§5).
//! * [`vertex`] — PEEL-V (Algorithm 5).
//! * [`edge`] — PEEL-E (Algorithm 6).
//! * [`wstore`] — WPEEL-V / WPEEL-E, the wedge-storing O(b)-work
//!   variants (Algorithms 7–8).
//!
//! Convenience drivers [`tip_decomposition`] / [`wing_decomposition`]
//! run counting + peeling end to end.

pub mod bucket;
pub mod delta;
pub mod edge;
pub mod fibheap;
pub mod vertex;
pub mod wstore;

pub use bucket::{BucketKind, BucketStruct};
pub use edge::{peel_edges, PeelEOpts, WingResult};
pub use vertex::{peel_vertices, PeelSide, PeelVOpts, TipResult};
pub use wstore::{wpeel_edges, wpeel_vertices, WedgeStore};

use crate::count::{count_per_edge, count_per_vertex, CountOpts};
use crate::graph::BipartiteGraph;

/// Count + vertex-peel in one call.
pub fn tip_decomposition(g: &BipartiteGraph, copts: &CountOpts, popts: &PeelVOpts) -> TipResult {
    let vc = count_per_vertex(g, copts);
    peel_vertices(g, &vc.bu, &vc.bv, popts)
}

/// Count + edge-peel in one call.
pub fn wing_decomposition(g: &BipartiteGraph, copts: &CountOpts, popts: &PeelEOpts) -> WingResult {
    let be = count_per_edge(g, copts);
    peel_edges(g, &be, popts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::testutil::brute;

    #[test]
    fn drivers_match_brute_force() {
        let g = gen::erdos_renyi(10, 12, 55, 11);
        let t = tip_decomposition(
            &g,
            &CountOpts::default(),
            &PeelVOpts { side: PeelSide::U, ..Default::default() },
        );
        assert_eq!(t.tips, brute::tip_numbers_u(&g));
        let w = wing_decomposition(&g, &CountOpts::default(), &PeelEOpts::default());
        assert_eq!(w.wings, brute::wing_numbers(&g));
    }

    #[test]
    fn davis_decompositions_are_stable() {
        // Golden values pinned from the brute-force oracle on the real
        // Davis Southern Women data (women side).
        let g = gen::davis_southern_women();
        let t = tip_decomposition(
            &g,
            &CountOpts::default(),
            &PeelVOpts { side: PeelSide::U, ..Default::default() },
        );
        assert_eq!(t.tips, brute::tip_numbers_u(&g));
        // The most social women (Theresa/Evelyn cluster) survive the
        // longest: their tip numbers are maximal.
        let max = *t.tips.iter().max().unwrap();
        assert!(t.tips[0] == max || t.tips[2] == max, "{:?}", t.tips);
    }
}
