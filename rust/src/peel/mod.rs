//! Butterfly peeling (§3.2, §4.3): tip decomposition (vertex peeling)
//! and wing decomposition (edge peeling).
//!
//! * [`bucket`] — Julienne-style bucketing (128-bucket window +
//!   skip-ahead) and the Fibonacci-heap bucketing of §5.4; now lives
//!   in [`crate::prims::bucket`] (shared with the co-degeneracy
//!   rankings) and is re-exported here.
//! * [`fibheap`] — the batch-parallel Fibonacci heap (§5), re-exported
//!   from [`crate::prims::fibheap`].
//! * [`vertex`] — PEEL-V (Algorithm 5).
//! * [`edge`] — PEEL-E (Algorithm 6).
//! * [`live`] — the shrinking adjacency views the intersect engine
//!   peels over.
//! * [`two_phase`] — the coarse→fine range-parallel engine (RECEIPT-
//!   style) layered on the intersect machinery.
//! * [`wstore`] — WPEEL-V / WPEEL-E, the wedge-storing O(b)-work
//!   variants (Algorithms 7–8).
//!
//! Like counting, peeling now has **engines** behind one option
//! surface ([`PeelEngine`], carried by [`PeelVOpts`]/[`PeelEOpts`] and
//! mirroring [`count::Engine`](crate::count::Engine)):
//!
//! * [`PeelEngine::Agg`] — the paper's UPDATE-V/UPDATE-E through the
//!   materializing [`WedgeAgg`](crate::count::WedgeAgg) strategies;
//!   per-round memory scales with the round's wedge count.
//! * [`PeelEngine::Intersect`] — streaming per-source two-hop walks
//!   over a [`live::LiveCsr`] view that shrinks as vertices/edges are
//!   peeled: dense counters + touched-list resets, per-worker
//!   [`delta::DenseDelta`] accumulators merged in parallel, and **no
//!   wedge record is ever allocated** in the round loop.
//! * [`PeelEngine::TwoPhase`] — a coarse pass stages vertices/edges
//!   into ~sqrt(n) tip/wing-number ranges balanced by butterfly mass,
//!   then the ranges peel **concurrently**, each running intersect-
//!   style rounds over its own sub-view; exactness argued in
//!   [`two_phase`]'s docs.
//!
//! Convenience drivers [`tip_decomposition`] / [`wing_decomposition`]
//! run counting + peeling end to end.

use std::sync::OnceLock;

pub mod delta;
pub mod edge;
pub mod live;
pub mod two_phase;
pub mod vertex;
pub mod wstore;

pub use crate::prims::{bucket, fibheap};

pub use crate::prims::bucket::{BucketKind, BucketStruct};
pub use edge::{peel_edges, PeelEOpts, WingResult};
pub use vertex::{peel_vertices, PeelSide, PeelVOpts, TipResult};
pub use wstore::{wpeel_edges, wpeel_vertices, WedgeStore};

use crate::count::{count_per_edge, count_per_vertex, CountOpts};
use crate::error::Result;
use crate::graph::BipartiteGraph;

/// Which update engine a peeling run uses (carried by
/// [`PeelVOpts`]/[`PeelEOpts`], selected on the CLI via
/// `peel --engine E`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PeelEngine {
    /// UPDATE-V/UPDATE-E through the configured wedge-aggregation
    /// strategy (`opts.agg`).
    Agg,
    /// Streaming live-view intersect updates — zero wedge
    /// materialization, ignores `opts.agg`.
    Intersect,
    /// Coarse range staging + concurrent per-range fine peels over
    /// intersect-style sub-views ([`two_phase`]); ignores `opts.agg`.
    TwoPhase,
}

impl PeelEngine {
    /// The canonical engine listing: the CLI `--engine` values, the
    /// `PARBUTTERFLY_PEEL_ENGINE` values, and the sweep the golden
    /// corpus tests derive from — a new engine added here is
    /// automatically exercised everywhere.
    pub const ALL: [PeelEngine; 3] =
        [PeelEngine::Agg, PeelEngine::Intersect, PeelEngine::TwoPhase];

    pub fn name(&self) -> &'static str {
        match self {
            PeelEngine::Agg => "agg",
            PeelEngine::Intersect => "intersect",
            PeelEngine::TwoPhase => "two-phase",
        }
    }

    pub fn parse(s: &str) -> Option<PeelEngine> {
        PeelEngine::ALL.into_iter().find(|e| e.name() == s)
    }

    /// Process default: the `PARBUTTERFLY_PEEL_ENGINE` environment
    /// variable when set (the CI matrix leg sets it), otherwise
    /// [`PeelEngine::Agg`].  A set-but-invalid value panics instead of
    /// silently falling back — a typo in the CI matrix must not turn
    /// the intersect leg into a second agg leg.
    pub fn default_from_env() -> PeelEngine {
        static DEFAULT: OnceLock<PeelEngine> = OnceLock::new();
        *DEFAULT.get_or_init(|| match std::env::var("PARBUTTERFLY_PEEL_ENGINE") {
            Ok(s) => PeelEngine::parse(&s).unwrap_or_else(|| {
                let valid = PeelEngine::ALL.map(|e| e.name()).join("|");
                panic!("PARBUTTERFLY_PEEL_ENGINE={s:?} names no peel engine ({valid})")
            }),
            Err(_) => PeelEngine::Agg,
        })
    }
}

impl Default for PeelEngine {
    fn default() -> Self {
        PeelEngine::default_from_env()
    }
}

/// Count + vertex-peel in one call.  The counting step runs under
/// `copts.budget` and the peel under `popts.budget`; the first failure
/// surfaces as a structured `Err`.
pub fn tip_decomposition(
    g: &BipartiteGraph,
    copts: &CountOpts,
    popts: &PeelVOpts,
) -> Result<TipResult> {
    let vc = count_per_vertex(g, copts)?;
    peel_vertices(g, &vc.bu, &vc.bv, popts)
}

/// Count + edge-peel in one call.  Budgets compose as in
/// [`tip_decomposition`].
pub fn wing_decomposition(
    g: &BipartiteGraph,
    copts: &CountOpts,
    popts: &PeelEOpts,
) -> Result<WingResult> {
    let be = count_per_edge(g, copts)?;
    peel_edges(g, &be, popts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::testutil::brute;

    #[test]
    fn engine_names_roundtrip() {
        for e in PeelEngine::ALL {
            assert_eq!(PeelEngine::parse(e.name()), Some(e));
        }
        assert_eq!(PeelEngine::parse("wedges"), None);
    }

    #[test]
    fn engine_listing_is_pinned() {
        // The golden corpus sweep, the CLI `--engine` values, and the
        // env-var values all derive from `ALL`: pin the canonical
        // listing so an engine can neither vanish from it silently nor
        // change its spelling.
        assert_eq!(PeelEngine::ALL.map(|e| e.name()), ["agg", "intersect", "two-phase"]);
    }

    #[test]
    fn drivers_match_brute_force_on_both_engines() {
        let g = gen::erdos_renyi(10, 12, 55, 11);
        for engine in PeelEngine::ALL {
            let t = tip_decomposition(
                &g,
                &CountOpts::default(),
                &PeelVOpts { engine, side: PeelSide::U, ..Default::default() },
            )
            .unwrap();
            assert_eq!(t.tips, brute::tip_numbers_u(&g), "{engine:?}");
            let w = wing_decomposition(
                &g,
                &CountOpts::default(),
                &PeelEOpts { engine, ..Default::default() },
            )
            .unwrap();
            assert_eq!(w.wings, brute::wing_numbers(&g), "{engine:?}");
        }
    }

    #[test]
    fn davis_decompositions_are_stable() {
        // Golden values pinned from the brute-force oracle on the real
        // Davis Southern Women data (women side).
        let g = gen::davis_southern_women();
        let t = tip_decomposition(
            &g,
            &CountOpts::default(),
            &PeelVOpts { side: PeelSide::U, ..Default::default() },
        )
        .unwrap();
        assert_eq!(t.tips, brute::tip_numbers_u(&g));
        // The most social women (Theresa/Evelyn cluster) survive the
        // longest: their tip numbers are maximal.
        let max = *t.tips.iter().max().unwrap();
        assert!(t.tips[0] == max || t.tips[2] == max, "{:?}", t.tips);
    }
}
