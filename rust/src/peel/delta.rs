//! Reusable dense delta accumulator for peeling rounds.
//!
//! §Perf: the first implementation merged per-round deltas through
//! `Mutex<HashMap>` (PEEL-V) or a freshly allocated phase-concurrent
//! table sized by `m` (PEEL-E).  With thousands of rounds the per-round
//! allocation/zeroing dominated — e.g. wing decomposition on the e2e
//! workload spent ~95% of its 23 s allocating and clearing 8 MB tables
//! 7k times.  `DenseDelta` is allocated once per decomposition and
//! cleared in O(#touched) via the touched list.
//!
//! Two write phases (NOT single-writer any more):
//!
//! * **Exclusive** — [`DenseDelta::add`] / [`DenseDelta::drain`] take
//!   `&mut self`; this is how per-worker *local* accumulators are
//!   filled during round enumeration, and how the aggregation peel
//!   paths fill the global one directly.
//! * **Parallel merge** — [`DenseDelta::merge_parallel`] folds a set of
//!   local accumulators into `self` concurrently: slot additions are
//!   relaxed `fetch_add`s, and the worker whose add observes the slot
//!   at zero claims it for the touched list (each slot is claimed
//!   exactly once).  The merge is bounded by the deltas actually
//!   produced, which the peeling work bounds already account for.
//!
//! The two phases must not interleave: `add`/`drain` are exclusive-
//! access by signature, and a debug assertion (`merging`) additionally
//! guards against a future caller leaking shared handles into the
//! merge window.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::prims::pool::{parallel_for_dynamic, SyncPtr};

/// Dense index->u64 accumulator with O(touched) drain.
pub struct DenseDelta {
    vals: Vec<AtomicU64>,
    touched: Vec<u32>,
    /// True only inside [`Self::merge_parallel`]; guards exclusive-
    /// phase entry points against concurrent misuse (debug builds).
    merging: AtomicBool,
}

impl DenseDelta {
    pub fn new(n: usize) -> Self {
        Self {
            vals: (0..n).map(|_| AtomicU64::new(0)).collect(),
            touched: Vec::new(),
            merging: AtomicBool::new(false),
        }
    }

    #[inline]
    pub fn add(&mut self, i: u32, delta: u64) {
        debug_assert!(
            !self.merging.load(Ordering::Relaxed),
            "DenseDelta::add during a parallel merge"
        );
        if delta == 0 {
            return;
        }
        let slot = self.vals[i as usize].get_mut();
        if *slot == 0 {
            self.touched.push(i);
        }
        *slot += delta;
    }

    /// Below this many combined touched entries the merge folds
    /// serially: most peel rounds are tiny, and a fork-join per round
    /// would cost more than the merge itself.
    const PAR_MERGE_MIN: usize = 1 << 14;

    /// Fold `parts` into `self` (each part is visited by exactly one
    /// worker; slot sums go through relaxed atomic adds) and reset
    /// every part to empty so its owner can reuse it next round.
    /// Claims for the touched list ride on the adds: the worker whose
    /// `fetch_add` saw zero owns the slot's entry.  Small rounds skip
    /// the fork-join entirely and fold inline.
    pub fn merge_parallel(&mut self, parts: &mut [&mut DenseDelta]) {
        let total: usize = parts.iter().map(|p| p.touched.len()).sum();
        if parts.len() <= 1 || total < Self::PAR_MERGE_MIN {
            for part in parts.iter_mut() {
                let DenseDelta { vals: pvals, touched: ptouched, .. } = &mut **part;
                for &i in ptouched.iter() {
                    let v = std::mem::take(pvals[i as usize].get_mut());
                    self.add(i, v);
                }
                ptouched.clear();
            }
            return;
        }
        let was_merging = self.merging.swap(true, Ordering::Relaxed);
        debug_assert!(!was_merging, "re-entrant merge");
        let claimed = Mutex::new(Vec::<u32>::new());
        {
            let vals = &self.vals;
            let pp = SyncPtr(parts.as_mut_ptr());
            parallel_for_dynamic(parts.len(), 1, |range| {
                let mut local: Vec<u32> = Vec::new();
                for pi in range {
                    // SAFETY: dynamic scheduling hands each part index
                    // to exactly one worker, so this &mut is unique.
                    let part: &mut DenseDelta = unsafe { &mut **pp.get().add(pi) };
                    debug_assert!(
                        !part.merging.load(Ordering::Relaxed),
                        "a part is itself mid-merge"
                    );
                    let DenseDelta { vals: pvals, touched: ptouched, .. } = part;
                    for &i in ptouched.iter() {
                        let v = std::mem::take(pvals[i as usize].get_mut());
                        debug_assert!(v != 0, "touched slot holds zero");
                        if vals[i as usize].fetch_add(v, Ordering::Relaxed) == 0 {
                            local.push(i);
                        }
                    }
                    ptouched.clear();
                }
                if !local.is_empty() {
                    claimed.lock().unwrap().append(&mut local);
                }
            });
        }
        self.touched.append(&mut claimed.into_inner().unwrap());
        self.merging.store(false, Ordering::Relaxed);
    }

    /// Visit and reset every nonzero slot.
    pub fn drain(&mut self, mut f: impl FnMut(u32, u64)) {
        debug_assert!(
            !self.merging.load(Ordering::Relaxed),
            "DenseDelta::drain during a parallel merge"
        );
        let Self { vals, touched, .. } = self;
        for &i in touched.iter() {
            let v = std::mem::take(vals[i as usize].get_mut());
            if v != 0 {
                f(i, v);
            }
        }
        touched.clear();
    }

    pub fn is_clear(&self) -> bool {
        self.touched.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prims::pool::with_threads;

    #[test]
    fn accumulates_and_resets() {
        let mut d = DenseDelta::new(10);
        d.add(3, 5);
        d.add(3, 2);
        d.add(7, 1);
        d.add(2, 0); // no-op
        let mut got = Vec::new();
        d.drain(|i, v| got.push((i, v)));
        got.sort_unstable();
        assert_eq!(got, vec![(3, 7), (7, 1)]);
        assert!(d.is_clear());
        // Reusable after drain.
        d.add(3, 1);
        let mut got = Vec::new();
        d.drain(|i, v| got.push((i, v)));
        assert_eq!(got, vec![(3, 1)]);
    }

    #[test]
    fn merge_matches_sequential_fold_in_both_regimes() {
        // Small totals take the inline serial fold; totals above
        // PAR_MERGE_MIN exercise the atomic claim-on-zero protocol.
        let large = DenseDelta::PAR_MERGE_MIN / 4;
        for (n, per_part) in [(200usize, 40usize), (6 * large, large)] {
            for t in [1usize, 4, 8] {
                with_threads(t, || {
                    let mut global = DenseDelta::new(n);
                    global.add(0, 7); // pre-existing entry must not be double-claimed
                    let mut parts: Vec<DenseDelta> =
                        (0..6).map(|_| DenseDelta::new(n)).collect();
                    let mut expect = vec![0u64; n];
                    expect[0] = 7;
                    for (pi, p) in parts.iter_mut().enumerate() {
                        for j in 0..per_part {
                            let i = ((pi * 31 + j * 7 + 1) % n) as u32;
                            let v = (pi + j + 1) as u64;
                            p.add(i, v);
                            expect[i as usize] += v;
                        }
                    }
                    let mut refs: Vec<&mut DenseDelta> = parts.iter_mut().collect();
                    global.merge_parallel(&mut refs);
                    // Parts are reset and reusable.
                    assert!(parts.iter().all(|p| p.is_clear()));
                    let mut got = vec![0u64; n];
                    let mut seen = std::collections::HashSet::new();
                    global.drain(|i, v| {
                        assert!(seen.insert(i), "slot {i} claimed twice (threads={t})");
                        got[i as usize] = v;
                    });
                    assert_eq!(got, expect, "n={n} threads={t}");
                    assert!(global.is_clear());
                });
            }
        }
    }
}
