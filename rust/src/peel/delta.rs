//! Reusable dense delta accumulator for peeling rounds.
//!
//! §Perf: the first implementation merged per-round deltas through
//! `Mutex<HashMap>` (PEEL-V) or a freshly allocated phase-concurrent
//! table sized by `m` (PEEL-E).  With thousands of rounds the per-round
//! allocation/zeroing dominated — e.g. wing decomposition on the e2e
//! workload spent ~95% of its 23 s allocating and clearing 8 MB tables
//! 7k times.  `DenseDelta` is allocated once per decomposition and
//! cleared in O(#touched) via the touched list.
//!
//! Single-writer semantics: parallel enumeration accumulates into
//! per-worker locals that are merged into the `DenseDelta` by one
//! thread (the merge is bounded by the deltas actually produced, which
//! the peeling work bounds already account for).

/// Dense index->u64 accumulator with O(touched) drain.
pub struct DenseDelta {
    vals: Vec<u64>,
    touched: Vec<u32>,
}

impl DenseDelta {
    pub fn new(n: usize) -> Self {
        Self { vals: vec![0; n], touched: Vec::new() }
    }

    #[inline]
    pub fn add(&mut self, i: u32, delta: u64) {
        if delta == 0 {
            return;
        }
        let slot = &mut self.vals[i as usize];
        if *slot == 0 {
            self.touched.push(i);
        }
        *slot += delta;
    }

    /// Visit and reset every nonzero slot.
    pub fn drain(&mut self, mut f: impl FnMut(u32, u64)) {
        for &i in &self.touched {
            let v = self.vals[i as usize];
            if v != 0 {
                self.vals[i as usize] = 0;
                f(i, v);
            }
        }
        self.touched.clear();
    }

    pub fn is_clear(&self) -> bool {
        self.touched.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_resets() {
        let mut d = DenseDelta::new(10);
        d.add(3, 5);
        d.add(3, 2);
        d.add(7, 1);
        d.add(2, 0); // no-op
        let mut got = Vec::new();
        d.drain(|i, v| got.push((i, v)));
        got.sort_unstable();
        assert_eq!(got, vec![(3, 7), (7, 1)]);
        assert!(d.is_clear());
        // Reusable after drain.
        d.add(3, 1);
        let mut got = Vec::new();
        d.drain(|i, v| got.push((i, v)));
        assert_eq!(got, vec![(3, 1)]);
    }
}
