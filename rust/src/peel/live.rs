//! Incrementally-shrinking adjacency views for the intersect peel
//! engine.
//!
//! The aggregation UPDATE paths re-scan full adjacency lists every
//! round and filter dead entries with `peeled[]` / `round_of[]`
//! checks; late in a decomposition almost everything they scan is
//! dead.  [`LiveCsr`] keeps, per row, the *live* entries compacted at
//! the front of the row, with O(1) removal: every edge records its
//! slot position inside its row, and removal swap-pops the slot (the
//! swapped survivor's position is patched).  A two-hop walk over the
//! view touches only live wedges, so round cost tracks the surviving
//! graph instead of the original one.
//!
//! One view is one *orientation*: rows are the vertices of one side,
//! entries are that side's neighbors on the other side (plus edge
//! ids).  PEEL-V uses a single view (rows = the centers' side, i.e.
//! the side **not** being peeled); PEEL-E uses both orientations and
//! removes each peeled edge from the two views it appears in.

use crate::graph::BipartiteGraph;

/// CSR adjacency whose rows shrink as edges are removed.
pub struct LiveCsr {
    off: Vec<usize>,
    nbr: Vec<u32>,
    eid: Vec<u32>,
    /// Live prefix length per row.
    len: Vec<u32>,
    /// Edge id -> slot index of that edge within its row.
    pos: Vec<u32>,
}

impl LiveCsr {
    /// Build from per-row entry counts and a filler that writes row
    /// `r`'s `(neighbor, edge id)` pairs through the given emit
    /// callback — straight into the CSR arrays, no intermediate
    /// per-row buffers.
    fn build(
        m: usize,
        nrows: usize,
        row_len: impl Fn(usize) -> usize,
        fill_row: impl Fn(usize, &mut dyn FnMut(u32, u32)),
    ) -> Self {
        let mut off = vec![0usize; nrows + 1];
        for r in 0..nrows {
            off[r + 1] = off[r] + row_len(r);
        }
        let total = off[nrows];
        let mut nbr = vec![0u32; total];
        let mut eid = vec![0u32; total];
        let mut len = vec![0u32; nrows];
        let mut pos = vec![0u32; m];
        for r in 0..nrows {
            let base = off[r];
            let mut i = 0usize;
            fill_row(r, &mut |x, e| {
                nbr[base + i] = x;
                eid[base + i] = e;
                pos[e as usize] = i as u32;
                i += 1;
            });
            debug_assert_eq!(i, off[r + 1] - base, "row {r} filler length drift");
            len[r] = i as u32;
        }
        Self { off, nbr, eid, len, pos }
    }

    /// Rows = U vertices, entries = (v neighbor, edge id).
    pub fn u_view(g: &BipartiteGraph) -> Self {
        Self::build(
            g.m(),
            g.nu(),
            |u| g.deg_u(u),
            |u, emit| {
                for (i, &v) in g.nbrs_u(u).iter().enumerate() {
                    emit(v, g.eid_u(u, i));
                }
            },
        )
    }

    /// Rows = V vertices, entries = (u neighbor, edge id).
    pub fn v_view(g: &BipartiteGraph) -> Self {
        Self::build(
            g.m(),
            g.nv(),
            |v| g.deg_v(v),
            |v, emit| {
                for (&u, &e) in g.nbrs_v(v).iter().zip(g.eids_v(v)) {
                    emit(u, e);
                }
            },
        )
    }

    /// [`Self::u_view`] restricted to the entries `keep(nbr, eid)`
    /// accepts — the two-phase engine's per-range sub-views (range
    /// members for PEEL-V, the `stage >= j` residual for PEEL-E).
    /// The position index is still sized by the full graph's `m`, so
    /// removal stays O(1) under global edge ids.
    pub fn u_view_filtered(g: &BipartiteGraph, keep: &(impl Fn(u32, u32) -> bool + ?Sized)) -> Self {
        Self::build(
            g.m(),
            g.nu(),
            |u| {
                g.nbrs_u(u)
                    .iter()
                    .enumerate()
                    .filter(|&(i, &v)| keep(v, g.eid_u(u, i)))
                    .count()
            },
            |u, emit| {
                for (i, &v) in g.nbrs_u(u).iter().enumerate() {
                    let e = g.eid_u(u, i);
                    if keep(v, e) {
                        emit(v, e);
                    }
                }
            },
        )
    }

    /// [`Self::v_view`] restricted to the entries `keep(nbr, eid)`
    /// accepts (see [`Self::u_view_filtered`]).
    pub fn v_view_filtered(g: &BipartiteGraph, keep: &(impl Fn(u32, u32) -> bool + ?Sized)) -> Self {
        Self::build(
            g.m(),
            g.nv(),
            |v| {
                g.nbrs_v(v)
                    .iter()
                    .zip(g.eids_v(v))
                    .filter(|&(&u, &e)| keep(u, e))
                    .count()
            },
            |v, emit| {
                for (&u, &e) in g.nbrs_v(v).iter().zip(g.eids_v(v)) {
                    if keep(u, e) {
                        emit(u, e);
                    }
                }
            },
        )
    }

    /// Live neighbors of `row` (unordered — removal swap-pops).
    #[inline]
    pub fn nbrs(&self, row: usize) -> &[u32] {
        &self.nbr[self.off[row]..self.off[row] + self.len[row] as usize]
    }

    /// Edge ids parallel to [`Self::nbrs`].
    #[inline]
    pub fn eids(&self, row: usize) -> &[u32] {
        &self.eid[self.off[row]..self.off[row] + self.len[row] as usize]
    }

    /// Live degree of `row`.
    #[inline]
    pub fn deg(&self, row: usize) -> usize {
        self.len[row] as usize
    }

    /// Remove edge `e` from `row` in O(1) (must currently be live in
    /// that row).
    pub fn remove(&mut self, row: usize, e: u32) {
        let base = self.off[row];
        let i = self.pos[e as usize] as usize;
        let last = self.len[row] as usize - 1;
        debug_assert_eq!(self.eid[base + i], e, "stale position for edge {e}");
        self.nbr[base + i] = self.nbr[base + last];
        self.eid[base + i] = self.eid[base + last];
        self.pos[self.eid[base + i] as usize] = i as u32;
        self.len[row] = last as u32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::prims::rng::Pcg32;

    fn sorted(mut v: Vec<u32>) -> Vec<u32> {
        v.sort_unstable();
        v
    }

    #[test]
    fn views_start_equal_to_the_graph() {
        let g = gen::erdos_renyi(9, 11, 50, 3);
        let u = LiveCsr::u_view(&g);
        let v = LiveCsr::v_view(&g);
        for x in 0..g.nu() {
            assert_eq!(sorted(u.nbrs(x).to_vec()), g.nbrs_u(x).to_vec());
            assert_eq!(u.deg(x), g.deg_u(x));
        }
        for x in 0..g.nv() {
            assert_eq!(sorted(v.nbrs(x).to_vec()), g.nbrs_v(x).to_vec());
            assert_eq!(sorted(v.eids(x).to_vec()), sorted(g.eids_v(x).to_vec()));
        }
    }

    #[test]
    fn removal_shrinks_exactly_the_removed_edge() {
        let g = gen::erdos_renyi(8, 8, 40, 5);
        let mut u = LiveCsr::u_view(&g);
        let mut v = LiveCsr::v_view(&g);
        let mut alive: Vec<bool> = vec![true; g.m()];
        let mut rng = Pcg32::new(9);
        // Remove every edge in a random order, checking the views
        // against a filtered model after each removal.
        let mut order: Vec<u32> = (0..g.m() as u32).collect();
        for i in (1..order.len()).rev() {
            order.swap(i, rng.next_below(i as u64 + 1) as usize);
        }
        for e in order {
            let (eu, ev) = g.edge(e);
            u.remove(eu as usize, e);
            v.remove(ev as usize, e);
            alive[e as usize] = false;
            let expect_u: Vec<u32> = g
                .nbrs_u(eu as usize)
                .iter()
                .enumerate()
                .filter(|(i, _)| alive[g.eid_u(eu as usize, *i) as usize])
                .map(|(_, &x)| x)
                .collect();
            assert_eq!(sorted(u.nbrs(eu as usize).to_vec()), expect_u);
            let expect_v: Vec<u32> = g
                .nbrs_v(ev as usize)
                .iter()
                .zip(g.eids_v(ev as usize))
                .filter(|(_, &e2)| alive[e2 as usize])
                .map(|(&x, _)| x)
                .collect();
            assert_eq!(sorted(v.nbrs(ev as usize).to_vec()), sorted(expect_v));
        }
        assert!((0..g.nu()).all(|x| u.deg(x) == 0));
        assert!((0..g.nv()).all(|x| v.deg(x) == 0));
    }

    #[test]
    fn filtered_views_drop_exactly_the_rejected_entries() {
        let g = gen::erdos_renyi(9, 11, 50, 7);
        let keep = |_x: u32, e: u32| e % 2 == 0;
        let mut u = LiveCsr::u_view_filtered(&g, &keep);
        let v = LiveCsr::v_view_filtered(&g, &keep);
        for x in 0..g.nu() {
            let expect: Vec<u32> = g
                .nbrs_u(x)
                .iter()
                .enumerate()
                .filter(|(i, _)| g.eid_u(x, *i) % 2 == 0)
                .map(|(_, &y)| y)
                .collect();
            assert_eq!(sorted(u.nbrs(x).to_vec()), sorted(expect));
        }
        for x in 0..g.nv() {
            let expect: Vec<u32> = g
                .nbrs_v(x)
                .iter()
                .zip(g.eids_v(x))
                .filter(|(_, &e)| e % 2 == 0)
                .map(|(&y, _)| y)
                .collect();
            assert_eq!(sorted(v.nbrs(x).to_vec()), sorted(expect));
        }
        // Removal still works under *global* edge ids.
        for e in (0..g.m() as u32).filter(|e| e % 2 == 0) {
            let (eu, _) = g.edge(e);
            u.remove(eu as usize, e);
        }
        assert!((0..g.nu()).all(|x| u.deg(x) == 0));
    }
}
