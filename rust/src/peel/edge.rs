//! PEEL-E — parallel wing decomposition (Algorithm 6).
//!
//! Buckets edges by butterfly count; each round peels every minimum-
//! count edge and recomputes the destroyed butterflies by explicit
//! intersection (UPDATE-E): for peeled edge `(u1, v1)` and each live
//! co-edge `(u2, v1)`, every live `v2 ∈ N(u1) ∩ N(u2) \ {v1}` closes a
//! butterfly whose three surviving edges each lose one count.  Three
//! engines ([`PeelEngine`]):
//!
//! * **Agg** — sorted-list intersections over the full adjacency with
//!   `round_of[]` liveness filtering, deltas combined through the
//!   configured aggregation strategy.
//! * **Intersect** — dense-stamp two-hop walks over [`LiveCsr`] views
//!   pruned of every *previous* round's edges (the batch is removed
//!   only after its walk, so the same-round tie-break below still
//!   applies): stamp `u1`'s live neighborhood, stream `u2`'s live
//!   neighborhood against the stamps, accumulate the three per-
//!   butterfly decrements into per-worker [`DenseDelta`]s merged in
//!   parallel.  No decrement list or wedge record is materialized.
//! * **TwoPhase** — coarse range staging followed by concurrent
//!   per-range fine peels ([`super::two_phase`]); both phases run the
//!   same stamp walk ([`update_e_stamped`]) over full or `stage >= j`
//!   filtered views.
//!
//! Double-counting control (the §4.3.2 tie-break): an edge peeled in a
//! *previous* round is dead everywhere; among edges peeled in the
//! *same* round, a butterfly is processed only by its minimum-id peeled
//! edge — lower-id same-round edges are treated as dead, higher-id ones
//! as alive (their copies of the butterfly are suppressed when they
//! look back at us).  Deltas to finalized edges are dropped at apply
//! time.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::count::WedgeAgg;
use crate::error::{guard, Result};
use crate::graph::ranked::walk_grain;
use crate::graph::{BipartiteGraph, Layout};
use crate::prims::budget::{self, Budget};
use crate::prims::histogram::histogram;
use crate::prims::pool::{
    num_threads, parallel_for_dynamic, parallel_for_dynamic_pooled, ScratchPool,
};
use crate::prims::semisort::aggregate_counts;
use crate::prims::simd::{intersect_pairs, Bitset};

use super::bucket::{make_buckets, BucketKind};
use super::delta::DenseDelta;
use super::live::LiveCsr;
use super::PeelEngine;

/// Result of a wing decomposition.
#[derive(Clone, Debug)]
pub struct WingResult {
    /// Wing number per edge id.
    pub wings: Vec<u64>,
    /// Number of peeling rounds (rho_e).
    pub rounds: usize,
}

/// Options for edge peeling.
///
/// ```
/// use parbutterfly::count::CountOpts;
/// use parbutterfly::graph::gen;
/// use parbutterfly::peel::{wing_decomposition, PeelEOpts};
///
/// let g = gen::complete_bipartite(2, 2); // one butterfly
/// let w = wing_decomposition(&g, &CountOpts::default(), &PeelEOpts::default()).unwrap();
/// assert_eq!(w.wings, vec![1, 1, 1, 1]);
/// ```
#[derive(Clone, Debug)]
pub struct PeelEOpts {
    /// UPDATE-E engine; [`PeelEngine::Intersect`] ignores `agg`.
    pub engine: PeelEngine,
    pub agg: WedgeAgg,
    pub buckets: BucketKind,
    /// Memory layout for the intersect engine's stamp walks
    /// ([`Layout::Hub`] = degree-descending relabeling of both sides
    /// with edge ids mapped through the rebuild); only
    /// [`PeelEngine::Intersect`] and [`PeelEngine::TwoPhase`] consult
    /// it.  Wing numbers are identical across layouts.
    pub layout: Layout,
    /// Cooperative limits for this decomposition (see
    /// [`CountOpts::budget`](crate::count::CountOpts::budget)).
    pub budget: Budget,
}

impl Default for PeelEOpts {
    fn default() -> Self {
        Self {
            engine: PeelEngine::default(),
            agg: WedgeAgg::Hash,
            buckets: BucketKind::Julienne,
            layout: Layout::default_from_env(),
            budget: Budget::default(),
        }
    }
}

/// Round tags: `u32::MAX` = alive, otherwise the round the edge was
/// finalized in.
pub(super) const ALIVE: u32 = u32::MAX;

/// Wing decomposition given per-edge butterfly counts.
///
/// Runs under [`PeelEOpts::budget`]; a worker panic, injected fault,
/// or budget trip returns a structured [`Err`](crate::Error) instead
/// of aborting.
pub fn peel_edges(g: &BipartiteGraph, be: &[u64], opts: &PeelEOpts) -> Result<WingResult> {
    guard(&opts.budget, || peel_edges_raw(g, be, opts))
}

pub(crate) fn peel_edges_raw(g: &BipartiteGraph, be: &[u64], opts: &PeelEOpts) -> WingResult {
    // Cache-aware layout: only the stamp-walking engines' dense scratch
    // benefits (Agg ignores `layout` exactly as Intersect ignores
    // `agg`).
    if matches!(opts.engine, PeelEngine::Intersect | PeelEngine::TwoPhase)
        && opts.layout.resolve(g.m()) == Layout::Hub
    {
        return peel_edges_relabeled(g, be, opts);
    }
    match opts.engine {
        PeelEngine::Agg => peel_edges_agg(g, be, opts),
        PeelEngine::Intersect => peel_edges_intersect(g, be, opts),
        PeelEngine::TwoPhase => super::two_phase::peel_edges_two_phase(g, be, opts),
    }
}

/// The peel-edge analogue of the counting engine's hub renumbering:
/// relabel both vertex sides by decreasing degree, rebuild, peel the
/// relabeled graph flat, and route wing numbers back through the edge-
/// id map the rebuild induces.
///
/// The stamp walk's hot state — `stamp_tag`/`stamp_eid` slots indexed
/// by `v2` and the per-edge `DenseDelta` — concentrates on high-degree
/// vertices (stamped and probed through many co-edges), so degree-
/// descending ids pack the hot slots into a cache-resident prefix.
///
/// Wing numbers are invariant under the relabeling: every butterfly is
/// processed exactly once (by its minimum-*id* same-round peeled edge,
/// and *which* edge that is may change — but each surviving edge still
/// receives exactly one decrement per destroyed butterfly, and same-
/// round decrements are dropped at apply time either way), so bucket
/// trajectories and rounds are identical.
fn peel_edges_relabeled(g: &BipartiteGraph, be: &[u64], opts: &PeelEOpts) -> WingResult {
    let m = g.m();
    assert_eq!(be.len(), m);
    let perm_u = degree_desc_perm(g.nu(), |u| g.deg_u(u));
    let perm_v = degree_desc_perm(g.nv(), |v| g.deg_v(v));
    // Relabeled endpoint pairs indexed by *old* edge id.
    let edges2: Vec<(u32, u32)> = (0..m)
        .map(|e| {
            let (u, v) = g.edge(e as u32);
            (perm_u[u as usize], perm_v[v as usize])
        })
        .collect();
    let g2 = BipartiteGraph::from_edges(g.nu(), g.nv(), &edges2);
    // `from_edges` assigns edge ids by sorted (u, v) order, so the old
    // edge's new id is the rank of its relabeled pair.
    let mut by_pair: Vec<u32> = (0..m as u32).collect();
    by_pair.sort_unstable_by_key(|&e| edges2[e as usize]);
    let mut emap = vec![0u32; m];
    for (new, &old) in by_pair.iter().enumerate() {
        emap[old as usize] = new as u32;
    }
    let mut be2 = vec![0u64; m];
    for (e, &c) in be.iter().enumerate() {
        be2[emap[e] as usize] = c;
    }
    let opts2 = PeelEOpts { layout: Layout::Flat, ..opts.clone() };
    let r2 = peel_edges_raw(&g2, &be2, &opts2);
    let wings = emap.iter().map(|&e2| r2.wings[e2 as usize]).collect();
    WingResult { wings, rounds: r2.rounds }
}

/// Stable permutation `old id -> new id` ordering vertices by
/// decreasing degree (ties by id).
fn degree_desc_perm(n: usize, deg: impl Fn(usize) -> usize) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by(|&a, &b| {
        deg(b as usize).cmp(&deg(a as usize)).then_with(|| a.cmp(&b))
    });
    let mut perm = vec![0u32; n];
    for (new, &old) in order.iter().enumerate() {
        perm[old as usize] = new as u32;
    }
    perm
}

/// The aggregation engine: UPDATE-E through `opts.agg`.
fn peel_edges_agg(g: &BipartiteGraph, be: &[u64], opts: &PeelEOpts) -> WingResult {
    let m = g.m();
    assert_eq!(be.len(), m);
    budget::probe_alloc(m * (4 + 8) + m * 8, "peel-e buckets/wings/delta");
    let mut buckets = make_buckets(opts.buckets, be);
    let mut round_of = vec![ALIVE; m];
    let mut wings = vec![0u64; m];
    let mut k = 0u64;
    let mut round = 0u32;
    // §Perf: one dense delta accumulator for the whole decomposition
    // (per-round table allocation used to dominate at high rho_e).
    let mut delta = DenseDelta::new(m);

    while let Some((c, batch)) = buckets.pop_min() {
        k = k.max(c);
        for &e in &batch {
            wings[e as usize] = k;
            round_of[e as usize] = round;
        }
        update_e(g, &batch, &round_of, round, opts.agg, &mut delta);
        delta.drain(|e, removed| {
            if round_of[e as usize] != ALIVE {
                return; // finalized edges ignore updates
            }
            let cur = buckets.current(e);
            let nc = cur.saturating_sub(removed).max(k);
            buckets.update(e, nc);
        });
        round += 1;
    }
    WingResult { wings, rounds: round as usize }
}

/// Per-worker scratch for the intersect engine: `v2` stamps keyed by
/// the peeled edge being processed (so stale stamps from other batch
/// edges or earlier rounds never need clearing — every edge id is
/// peeled at most once) plus the worker's share of the round's deltas.
pub(super) struct EScratch {
    /// `v2` -> edge id of `(u1, v2)` when stamped for the current edge.
    stamp_eid: Vec<u32>,
    /// `v2` -> the peeled edge id the stamp belongs to (`ALIVE` =
    /// never stamped).
    stamp_tag: Vec<u32>,
    /// One bit per currently stamped `v2` — the probe loop's fast
    /// reject (32x denser than `stamp_tag`, so the hot working set of
    /// the `N(u2)` scans stays cache-resident).  Cleared per edge.
    stamped: Bitset,
    pub(super) delta: DenseDelta,
}

/// The streaming intersect engine: dense-stamp two-hop walks over live
/// views pruned of previous rounds' edges (see the module docs).
fn peel_edges_intersect(g: &BipartiteGraph, be: &[u64], opts: &PeelEOpts) -> WingResult {
    let m = g.m();
    assert_eq!(be.len(), m);
    budget::probe_alloc(m * (4 + 8) + m * 8, "peel-e buckets/wings/delta");
    let mut buckets = make_buckets(opts.buckets, be);
    let mut round_of = vec![ALIVE; m];
    let mut wings = vec![0u64; m];
    let mut k = 0u64;
    let mut round = 0u32;
    let mut delta = DenseDelta::new(m);
    let mut live_u = LiveCsr::u_view(g);
    let mut live_v = LiveCsr::v_view(g);
    let mut pool: ScratchPool<EScratch> = ScratchPool::new();
    let fp = edge_walk_footprint(g);

    while let Some((c, batch)) = buckets.pop_min() {
        k = k.max(c);
        for &e in &batch {
            wings[e as usize] = k;
            round_of[e as usize] = round;
        }
        // UPDATE-E over the live views.  Batch edges are still present
        // (pruned only after the walk), so the same-round alive_for
        // tie-break sees them exactly as the aggregation engine does;
        // everything peeled earlier is already gone from the views.
        update_e_stamped(g, &live_u, &live_v, &batch, &round_of, round, fp, &pool);
        // Prune the batch from the live views, fold the per-worker
        // accumulators in parallel, re-bucket the survivors.
        for &e in &batch {
            let (u, v) = g.edge(e);
            live_u.remove(u as usize, e);
            live_v.remove(v as usize, e);
        }
        let mut parts: Vec<&mut DenseDelta> =
            pool.items_mut().iter_mut().map(|s| &mut s.delta).collect();
        delta.merge_parallel(&mut parts);
        delta.drain(|e, removed| {
            if round_of[e as usize] != ALIVE {
                return; // finalized edges ignore updates
            }
            let cur = buckets.current(e);
            buckets.update(e, cur.saturating_sub(removed).max(k));
        });
        round += 1;
    }
    WingResult { wings, rounds: round as usize }
}

/// Liveness of edge `x` from the perspective of same-round peeled edge
/// `e` (the tie-break rule in the module docs).  The rule is exact for
/// *mixed-count* bulk frontiers too (the two-phase coarse batches):
/// every destroyed butterfly is still enumerated exactly once, by its
/// minimum-id same-batch edge.
#[inline]
pub(super) fn alive_for(round_of: &[u32], round: u32, x: u32, e: u32) -> bool {
    let r = round_of[x as usize];
    r == ALIVE || (r == round && x > e)
}

/// Expected stamp-walk footprint of one batch edge (stamp deg(u1)
/// slots, probe through deg(v1) co-edges): drives the tile-derived
/// claim grain instead of a hard-coded constant.
pub(super) fn edge_walk_footprint(g: &BipartiteGraph) -> usize {
    let du = g.m().div_ceil(g.nu().max(1)).max(1);
    let dv = g.m().div_ceil(g.nv().max(1)).max(1);
    du.saturating_mul(dv)
}

/// The intersect engine's UPDATE-E round: per-batch-edge dense-stamp
/// walks over the given live views, decrements accumulated into the
/// per-worker deltas of `pool` (the caller merges and applies them).
/// Batch edges must still be present in the views; `round_of`/`round`
/// drive the [`alive_for`] tie-break.  Shared with the two-phase
/// engine, whose coarse phase passes the full views and whose fine
/// phase passes per-range filtered views with a per-range round
/// array — each caller owns a distinct `pool`, which is what keeps
/// edge-id stamp tags from going stale across phases.
#[allow(clippy::too_many_arguments)]
pub(super) fn update_e_stamped(
    g: &BipartiteGraph,
    live_u: &LiveCsr,
    live_v: &LiveCsr,
    batch: &[u32],
    round_of: &[u32],
    round: u32,
    fp: usize,
    pool: &ScratchPool<EScratch>,
) {
    let m = g.m();
    parallel_for_dynamic_pooled(
        batch.len(),
        walk_grain(batch.len(), fp),
        pool,
        || {
            budget::probe_alloc(g.nv() * 8 + g.nv() / 8 + m * 8, "peel-e worker scratch");
            EScratch {
                stamp_eid: vec![0u32; g.nv()],
                stamp_tag: vec![ALIVE; g.nv()],
                stamped: Bitset::new(g.nv()),
                delta: DenseDelta::new(m),
            }
        },
        |s, range| {
            for bi in range {
                let e = batch[bi];
                let (u1, v1) = g.edge(e);
                // Stamp u1's live neighborhood; the (u1, v1)
                // slot is edge `e` itself, which alive_for
                // rejects, so v2 != v1 falls out for free.
                let vn = live_u.nbrs(u1 as usize);
                let ve = live_u.eids(u1 as usize);
                for j in 0..vn.len() {
                    if alive_for(round_of, round, ve[j], e) {
                        s.stamp_eid[vn[j] as usize] = ve[j];
                        s.stamp_tag[vn[j] as usize] = e;
                        s.stamped.set(vn[j] as usize);
                    }
                }
                // Co-edges (u2, v1), then u2's live
                // neighborhood against the stamps.  The bitset
                // rejects the common miss before the 4-byte
                // tag load; the tag still arbitrates, since
                // bits outlive their edge only until the
                // clearing sweep below.
                let un = live_v.nbrs(v1 as usize);
                let ue = live_v.eids(v1 as usize);
                for j in 0..un.len() {
                    let (u2, e2) = (un[j], ue[j]);
                    if !alive_for(round_of, round, e2, e) {
                        continue;
                    }
                    let wn = live_u.nbrs(u2 as usize);
                    let we = live_u.eids(u2 as usize);
                    for t in 0..wn.len() {
                        let (v2, eb) = (wn[t], we[t]);
                        if s.stamped.test(v2 as usize)
                            && s.stamp_tag[v2 as usize] == e
                            && alive_for(round_of, round, eb, e)
                        {
                            // Butterfly (u1, v1, u2, v2) dies:
                            // surviving edges lose one each.
                            s.delta.add(e2, 1);
                            s.delta.add(s.stamp_eid[v2 as usize], 1);
                            s.delta.add(eb, 1);
                        }
                    }
                }
                // Unstamp (clearing an unset bit is harmless).
                for &v2 in vn {
                    s.stamped.clear(v2 as usize);
                }
            }
        },
    );
}

/// UPDATE-E: for each destroyed butterfly, one decrement per surviving
/// edge, aggregated by the configured method into `out`.
///
/// Hash/Batch modes accumulate dense per-edge deltas (the natural
/// additive combine for edge-id keys; batching differs only in
/// scheduling grain).  Sort/Hist materialize the decrement list and
/// aggregate it with their respective primitives — their cost profile
/// is what Figure 13 compares.
fn update_e(
    g: &BipartiteGraph,
    batch: &[u32],
    round_of: &[u32],
    round: u32,
    agg: WedgeAgg,
    out: &mut DenseDelta,
) {
    let dense_mode = matches!(agg, WedgeAgg::Hash | WedgeAgg::BatchS | WedgeAgg::BatchWA);
    let sequential = num_threads() <= 1;
    let list = Mutex::new(Vec::<u64>::new());
    // Fast path: single-threaded dense accumulation, zero allocation.
    if dense_mode && sequential {
        for bi in 0..batch.len() {
            enumerate_batch_edge(g, batch, round_of, round, bi, &mut |eid| out.add(eid, 1));
        }
        return;
    }
    let merged = Mutex::new(HashMap::<u32, u64>::new());
    // BatchWA is *defined* by finest-grain work assignment (that is
    // the scheduling difference Figure 13 measures), so it pins grain
    // 1; every other strategy derives its claim grain from the
    // expected per-edge walk footprint against the tile budget.
    let grain = if agg == WedgeAgg::BatchWA {
        1
    } else {
        let du = g.m().div_ceil(g.nu().max(1)).max(1);
        let dv = g.m().div_ceil(g.nv().max(1)).max(1);
        walk_grain(batch.len(), du.saturating_mul(dv))
    };
    parallel_for_dynamic(batch.len(), grain, |r| {
        let mut local_list = Vec::new();
        let mut local_map = HashMap::<u32, u64>::new();
        for bi in r {
            if dense_mode {
                enumerate_batch_edge(g, batch, round_of, round, bi, &mut |eid| {
                    *local_map.entry(eid).or_insert(0) += 1;
                });
            } else {
                enumerate_batch_edge(g, batch, round_of, round, bi, &mut |eid| {
                    local_list.push(eid as u64);
                });
            }
        }
        if !local_list.is_empty() {
            list.lock().unwrap().extend(local_list);
        }
        if !local_map.is_empty() {
            let mut m = merged.lock().unwrap();
            for (e, d) in local_map {
                *m.entry(e).or_insert(0) += d;
            }
        }
    });
    if dense_mode {
        for (e, d) in merged.into_inner().unwrap() {
            out.add(e, d);
        }
    } else {
        let list = list.into_inner().unwrap();
        let pairs = match agg {
            WedgeAgg::Sort => aggregate_counts(list, true),
            _ => histogram(&list),
        };
        for (e, d) in pairs {
            out.add(e as u32, d);
        }
    }
}

/// Enumerate the destroyed-butterfly decrements of one peeled edge.
#[inline]
fn enumerate_batch_edge(
    g: &BipartiteGraph,
    batch: &[u32],
    round_of: &[u32],
    round: u32,
    bi: usize,
    emit: &mut impl FnMut(u32),
) {
    let e = batch[bi];
            let (u1, v1) = g.edge(e);
            let nb_v1 = g.nbrs_v(v1 as usize);
            let ed_v1 = g.eids_v(v1 as usize);
            for (j, &u2) in nb_v1.iter().enumerate() {
                if u2 == u1 {
                    continue;
                }
                let e2 = ed_v1[j];
                if !alive_for(round_of, round, e2, e) {
                    continue;
                }
                // Intersect N(u1) and N(u2) through the shared
                // adaptive kernel ([`intersect_pairs`]): scan-and-
                // binary-search when one list is much shorter —
                // O(min·log max), the paper's min(deg, deg') bound on
                // power-law hubs — else a two-pointer merge.
                let (a, b) = (g.nbrs_u(u1 as usize), g.nbrs_u(u2 as usize));
                intersect_pairs(a, b, |i1, i2| {
                    let v2 = a[i1];
                    if v2 != v1 {
                        let ea = g.eid_u(u1 as usize, i1);
                        let eb = g.eid_u(u2 as usize, i2);
                        if alive_for(round_of, round, ea, e)
                            && alive_for(round_of, round, eb, e)
                        {
                            // Butterfly (u1, v1, u2, v2) dies: surviving
                            // edges e2, ea, eb each lose one.
                            emit(e2);
                            emit(ea);
                            emit(eb);
                        }
                    }
                });
            }
}

/// Group edges by wing number — the k-wings (§3.2): the edge sets of
/// the maximal subgraphs where every edge is in >= k butterflies.
pub fn wings_histogram(wings: &[u64]) -> HashMap<u64, Vec<u32>> {
    let mut h: HashMap<u64, Vec<u32>> = HashMap::new();
    for (e, &w) in wings.iter().enumerate() {
        h.entry(w).or_default().push(e as u32);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::{count_per_edge, CountOpts};
    use crate::graph::gen;
    use crate::testutil::brute;

    fn wings_via(g: &BipartiteGraph, opts: &PeelEOpts) -> WingResult {
        let be = count_per_edge(g, &CountOpts::default()).unwrap();
        peel_edges(g, &be, opts).unwrap()
    }

    #[test]
    fn single_butterfly() {
        let g = gen::complete_bipartite(2, 2);
        let r = wings_via(&g, &PeelEOpts::default());
        assert_eq!(r.wings, vec![1, 1, 1, 1]);
    }

    #[test]
    fn complete_bipartite_uniform_wings() {
        let g = gen::complete_bipartite(3, 4);
        let expect = brute::wing_numbers(&g);
        let r = wings_via(&g, &PeelEOpts::default());
        assert_eq!(r.wings, expect);
    }

    #[test]
    fn matches_brute_force_over_all_configs() {
        for seed in [2, 7] {
            let g = gen::erdos_renyi(8, 9, 40, seed);
            let expect = brute::wing_numbers(&g);
            for engine in PeelEngine::ALL {
                for agg in WedgeAgg::ALL {
                    for buckets in BucketKind::ALL {
                        // Hub layout forces the relabeled path even on
                        // these tiny graphs.
                        for layout in [Layout::Flat, Layout::Hub] {
                            let r = wings_via(&g, &PeelEOpts { engine, agg, buckets, layout });
                            assert_eq!(
                                r.wings, expect,
                                "seed={seed} {engine:?} agg={agg:?} {buckets:?} {layout:?}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn intersect_engine_under_real_fork_join() {
        let g = gen::chung_lu(30, 40, 350, 2.1, 19);
        let be = count_per_edge(&g, &CountOpts::default()).unwrap();
        let base = peel_edges(&g, &be, &PeelEOpts { engine: PeelEngine::Agg, ..Default::default() })
            .unwrap();
        for t in [1usize, 3, 8] {
            let r = crate::prims::pool::with_threads(t, || {
                peel_edges(
                    &g,
                    &be,
                    &PeelEOpts { engine: PeelEngine::Intersect, ..Default::default() },
                )
                .unwrap()
            });
            assert_eq!(r.wings, base.wings, "threads={t}");
            assert_eq!(r.rounds, base.rounds, "threads={t}");
        }
    }

    #[test]
    fn planted_blocks_wings() {
        let g = gen::planted_blocks(8, 8, 2, 4, 4, 1.0, 0, 3);
        let expect = brute::wing_numbers(&g);
        let r = wings_via(&g, &PeelEOpts::default());
        assert_eq!(r.wings, expect);
        // All edges of a K_{4,4} block share the same wing number.
        assert!(r.wings.iter().all(|&w| w == r.wings[0]));
    }

    #[test]
    fn wings_histogram_partitions_edges() {
        let g = gen::erdos_renyi(10, 10, 50, 4);
        let r = wings_via(&g, &PeelEOpts::default());
        let h = wings_histogram(&r.wings);
        let total: usize = h.values().map(|v| v.len()).sum();
        assert_eq!(total, g.m());
    }
}
