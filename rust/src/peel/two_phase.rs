//! Two-phase coarse→fine range-parallel peeling
//! ([`PeelEngine::TwoPhase`](super::PeelEngine::TwoPhase)).
//!
//! The round-synchronous engines are span-bound by rho (the number of
//! peeling rounds).  Following RECEIPT (Lakhotia et al., arXiv
//! 2110.12511), this engine breaks the round barrier in two phases:
//!
//! 1. **Coarse**: [`range_thresholds`] picks ~`sqrt(n)` tip/wing-number
//!    boundaries balanced by butterfly mass (via the same
//!    [`MaxBuckets`] log-bucket machinery as `rank::co_degeneracy`).
//!    For each threshold `thr[j]` in ascending order, the coarse peel
//!    bulk-removes *every* live item whose current count is `<= thr[j]`
//!    (repeating until none remain) with one intersect-style update
//!    walk per sub-round.  By the threshold-core property — bulk
//!    removal at threshold t eliminates exactly `{x : peel(x) <= t}` —
//!    the items removed during stage `j` are exactly those whose final
//!    tip/wing number lies in `(thr[j-1], thr[j]]`, so the pass pins
//!    `stage[x]` without knowing exact numbers.
//! 2. **Fine**: the ranges peel **concurrently** (the span of the
//!    phase is the deepest single range, not the sum).  Each range
//!    runs ordinary min-bucket rounds over an independent sub-view,
//!    seeded with butterfly counts restricted to same-or-later ranges:
//!    the cross-range support is subtracted *once, up front*, never
//!    maintained.
//!
//! Exactness of the fine phase:
//!
//! * **Seeds** (PEEL-V): pair wedge multiplicities `d(x1, x2)` are
//!   static under vertex peeling (wedge centers are on the un-peeled
//!   side and never die), so `seed(x1) = Σ_{stage(x2) >= stage(x1)}
//!   C(d(x1, x2), 2)` — one parallel pass — is precisely `x1`'s
//!   butterfly count at the moment every earlier range has been fully
//!   peeled.  For PEEL-E the seed is the number of butterflies whose
//!   three other edges all have `stage >= stage(e)`, found by one
//!   stamped enumeration over the full adjacency.
//! * **Range isolation**: when range `j` starts, the true residual
//!   graph is exactly `stage >= j`.  Items of later ranges sit in the
//!   `thr[j]`-core, so their counts stay *above* `thr[j]` throughout
//!   range `j`'s peel — they can never enter a min-batch.  PEEL-V can
//!   therefore drop them from the sub-view entirely (their wedges with
//!   range-`j` members are pre-subtracted in the seeds); PEEL-E keeps
//!   them present-but-immortal (their edges still close butterflies
//!   with range-`j` edges) in the `stage >= j` filtered views, never
//!   decremented, never re-bucketed.
//! * **Running max**: the range-local `k` starts at 0, yet matches the
//!   global running max: every seed in range `j` exceeds `thr[j-1]`,
//!   which upper-bounds the global `k` entering the range, so the
//!   first local min already dominates it and `max(cur - removed, k)`
//!   clamps identically.
//!
//! Determinism: coarse sub-rounds collect batches by id scan, deltas
//! are additive sums, and each fine range — itself run serially — owns
//! disjoint output slots, so results are bit-identical at every thread
//! count.  The fine ranges are dealt to the pool workers by
//! `parallel_for_dynamic`; nested combinators inside a worker run
//! inline, so there is no thread oversubscription.

use crate::count::choose2;
use crate::count::intersect::TouchedCounter;
use crate::graph::ranked::walk_grain;
use crate::graph::BipartiteGraph;
use crate::prims::pool::{parallel_for_dynamic, parallel_for_dynamic_pooled, ScratchPool, SyncPtr};
use crate::rank::codeg_bucket_of;

use super::bucket::{make_buckets, MaxBuckets};
use super::delta::DenseDelta;
use super::edge::{
    alive_for, edge_walk_footprint, update_e_stamped, EScratch, PeelEOpts, WingResult, ALIVE,
};
use super::live::LiveCsr;
use super::vertex::{wedge_footprint, PeelVOpts, SideView, TipResult, VScratch};

/// Coarse range boundaries, balanced by butterfly mass: walk the
/// distinct initial-count values in ascending order and cut whenever
/// the accumulated mass crosses the next of `P ~= sqrt(n)` equal
/// targets.  The ascending walk reuses the co-degeneracy ranking's
/// bucket-parallel machinery: [`MaxBuckets`] over `log2` keys
/// ([`codeg_bucket_of`]) drained from the top, each claimed frontier
/// sorted by exact count — log buckets cover disjoint value ranges, so
/// the reversed concatenation is a full ascending sort.  Always ends
/// with a `u64::MAX` sentinel; zero total mass or `P == 1` degenerates
/// to a single range.  Mirrored by `range_thresholds` in
/// `scripts/peel_model.py`.
pub(crate) fn range_thresholds(counts: &[u64]) -> Vec<u64> {
    let n = counts.len();
    let total: u128 = counts.iter().map(|&c| c as u128).sum();
    let p = ((n as f64).sqrt() as u128).max(1);
    let mut thr = Vec::new();
    if total > 0 && p > 1 {
        let keys: Vec<u64> = counts.iter().map(|&c| codeg_bucket_of(c, true)).collect();
        let mut mb = MaxBuckets::new(&keys);
        let mut groups: Vec<Vec<u32>> = Vec::new();
        while let Some((_key, mut frontier)) = mb.pop_max() {
            frontier.sort_unstable_by_key(|&i| counts[i as usize]);
            groups.push(frontier);
        }
        let asc: Vec<u32> = groups.into_iter().rev().flatten().collect();
        let (mut acc, mut i, mut j) = (0u128, 0usize, 1u128);
        while i < n && j < p {
            let v = counts[asc[i] as usize];
            while i < n && counts[asc[i] as usize] == v {
                acc += v as u128;
                i += 1;
            }
            if acc * p >= j * total {
                thr.push(v);
                while j < p && acc * p >= j * total {
                    j += 1;
                }
            }
        }
    }
    thr.push(u64::MAX);
    thr
}

/// Two-phase PEEL-V (see the module docs for the phase structure and
/// exactness argument).
pub(super) fn peel_vertices_two_phase(
    view: &SideView<'_>,
    counts: &[u64],
    opts: &PeelVOpts,
) -> TipResult {
    let n = view.n_peel();
    let thr = range_thresholds(counts);
    let nranges = thr.len();
    let fp = wedge_footprint(view);

    // ---- Phase 1: coarse staged peel over the full center view. ----
    let mut live = view.live_centers();
    let mut cur: Vec<u64> = counts.to_vec();
    let mut alive = vec![true; n];
    let mut stage = vec![0u32; n];
    let mut coarse_rounds = 0usize;
    let mut delta = DenseDelta::new(n);
    let mut pool: ScratchPool<VScratch> = ScratchPool::new();
    for (j, &th) in thr.iter().enumerate() {
        loop {
            let batch: Vec<u32> = (0..n as u32)
                .filter(|&x| alive[x as usize] && cur[x as usize] <= th)
                .collect();
            if batch.is_empty() {
                break;
            }
            coarse_rounds += 1;
            for &x in &batch {
                alive[x as usize] = false;
                stage[x as usize] = j as u32;
            }
            for &x1 in &batch {
                for (i, &y) in view.nbrs_peel(x1 as usize).iter().enumerate() {
                    live.remove(y as usize, view.eid_peel(x1 as usize, i));
                }
            }
            // The intersect engine's round walk, verbatim: tally live
            // second endpoints per batch vertex, charge C(d, 2).
            {
                let (live, batch) = (&live, &batch[..]);
                parallel_for_dynamic_pooled(
                    batch.len(),
                    walk_grain(batch.len(), fp),
                    &pool,
                    || VScratch { ctr: TouchedCounter::new(n), delta: DenseDelta::new(n) },
                    |s, range| {
                        for bi in range {
                            let x1 = batch[bi];
                            for &y in view.nbrs_peel(x1 as usize) {
                                for &x2 in live.nbrs(y as usize) {
                                    s.ctr.bump(x2);
                                }
                            }
                            let delta = &mut s.delta;
                            s.ctr.drain(|x2, d| delta.add(x2, choose2(d as u64)));
                        }
                    },
                );
            }
            let mut parts: Vec<&mut DenseDelta> =
                pool.items_mut().iter_mut().map(|s| &mut s.delta).collect();
            delta.merge_parallel(&mut parts);
            // A butterfly holds exactly two peel-side vertices, so the
            // per-source sum is exact even for mixed-count bulk
            // batches; survivors' counts stay true without clamping.
            delta.drain(|x2, removed| {
                cur[x2 as usize] = cur[x2 as usize].saturating_sub(removed);
            });
        }
    }

    // ---- Seeds: one pass over the static pair multiplicities. ----
    let mut seed = vec![0u64; n];
    {
        let sp = SyncPtr(seed.as_mut_ptr());
        let stage = &stage[..];
        let spool: ScratchPool<TouchedCounter> = ScratchPool::new();
        parallel_for_dynamic_pooled(
            n,
            walk_grain(n, fp),
            &spool,
            || TouchedCounter::new(n),
            |ctr, range| {
                for x1 in range {
                    let s = stage[x1];
                    for &y in view.nbrs_peel(x1) {
                        for &x2 in view.nbrs_other(y as usize) {
                            if x2 as usize != x1 && stage[x2 as usize] >= s {
                                ctr.bump(x2);
                            }
                        }
                    }
                    let mut acc = 0u64;
                    ctr.drain(|_x2, d| acc += choose2(d as u64));
                    // Disjoint slots: each x1 is written exactly once.
                    unsafe { *sp.get().add(x1) = acc };
                }
            },
        );
    }

    // ---- Phase 2: ranges fine-peel concurrently. ----
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); nranges];
    for x in 0..n as u32 {
        members[stage[x as usize] as usize].push(x);
    }
    let mut local_of = vec![0u32; n];
    for ms in &members {
        for (i, &x) in ms.iter().enumerate() {
            local_of[x as usize] = i as u32;
        }
    }
    let mut tips = vec![0u64; n];
    let mut fine_rounds = vec![0usize; nranges];
    {
        let tp = SyncPtr(tips.as_mut_ptr());
        let rp = SyncPtr(fine_rounds.as_mut_ptr());
        let (stage, seed, members, local_of) =
            (&stage[..], &seed[..], &members[..], &local_of[..]);
        parallel_for_dynamic(nranges, 1, |range| {
            for j in range {
                let r = fine_peel_v_range(view, j as u32, &members[j], local_of, stage, seed, opts, &tp);
                unsafe { *rp.get().add(j) = r };
            }
        });
    }
    TipResult {
        peeled_u: view.peel_u,
        tips,
        rounds: coarse_rounds + fine_rounds.into_iter().max().unwrap_or(0),
    }
}

/// One range's fine PEEL-V: ordinary min-bucket rounds over a
/// members-only sub-view, seeded with the range-restricted counts.
/// Runs serially — the fine phase's parallelism is *across* ranges —
/// and writes each member's tip through `tips` (ranges own disjoint
/// slots).  Returns the range's round count.
#[allow(clippy::too_many_arguments)]
fn fine_peel_v_range(
    view: &SideView<'_>,
    j: u32,
    members: &[u32],
    local_of: &[u32],
    stage: &[u32],
    seed: &[u64],
    opts: &PeelVOpts,
    tips: &SyncPtr<u64>,
) -> usize {
    if members.is_empty() {
        return 0;
    }
    let mut live = view.live_centers_filtered(&|x, _e| stage[x as usize] == j);
    let seeds: Vec<u64> = members.iter().map(|&x| seed[x as usize]).collect();
    let mut buckets = make_buckets(opts.buckets, &seeds);
    let mut ctr = TouchedCounter::new(view.n_peel());
    let mut k = 0u64;
    let mut rounds = 0usize;
    while let Some((c, lbatch)) = buckets.pop_min() {
        rounds += 1;
        k = k.max(c);
        for &li in &lbatch {
            let x = members[li as usize] as usize;
            unsafe { *tips.get().add(x) = k };
        }
        for &li in &lbatch {
            let x1 = members[li as usize] as usize;
            for (i, &y) in view.nbrs_peel(x1).iter().enumerate() {
                live.remove(y as usize, view.eid_peel(x1, i));
            }
        }
        for &li in &lbatch {
            let x1 = members[li as usize] as usize;
            for &y in view.nbrs_peel(x1) {
                for &x2 in live.nbrs(y as usize) {
                    ctr.bump(x2);
                }
            }
            // Applying per-source is equivalent to batching the delta:
            // `max(·, k)` clamping commutes with splitting a decrement.
            let buckets = &mut buckets;
            ctr.drain(|x2, d| {
                let b = choose2(d as u64);
                if b > 0 {
                    let lx = local_of[x2 as usize];
                    let cur = buckets.current(lx);
                    buckets.update(lx, cur.saturating_sub(b).max(k));
                }
            });
        }
    }
    rounds
}

/// Two-phase PEEL-E (see the module docs).  Edge supports are not
/// static, so the coarse pass runs the exact stamp walk
/// ([`update_e_stamped`]) per bulk sub-round — the same-round
/// tie-break stays exact for mixed-count frontiers — and the fine
/// ranges peel `stage >= j` filtered views in which later-range edges
/// are permanently alive.
pub(super) fn peel_edges_two_phase(g: &BipartiteGraph, be: &[u64], opts: &PeelEOpts) -> WingResult {
    let m = g.m();
    assert_eq!(be.len(), m);
    let thr = range_thresholds(be);
    let nranges = thr.len();
    let fp = edge_walk_footprint(g);

    // ---- Phase 1: coarse staged bulk peel over the full views. ----
    let mut live_u = LiveCsr::u_view(g);
    let mut live_v = LiveCsr::v_view(g);
    let mut cur: Vec<u64> = be.to_vec();
    let mut round_of = vec![ALIVE; m];
    let mut stage = vec![0u32; m];
    let mut rnd = 0u32;
    let mut delta = DenseDelta::new(m);
    let mut pool: ScratchPool<EScratch> = ScratchPool::new();
    for (j, &th) in thr.iter().enumerate() {
        loop {
            let batch: Vec<u32> = (0..m as u32)
                .filter(|&e| round_of[e as usize] == ALIVE && cur[e as usize] <= th)
                .collect();
            if batch.is_empty() {
                break;
            }
            for &e in &batch {
                round_of[e as usize] = rnd;
                stage[e as usize] = j as u32;
            }
            update_e_stamped(g, &live_u, &live_v, &batch, &round_of, rnd, fp, &pool);
            for &e in &batch {
                let (u, v) = g.edge(e);
                live_u.remove(u as usize, e);
                live_v.remove(v as usize, e);
            }
            let mut parts: Vec<&mut DenseDelta> =
                pool.items_mut().iter_mut().map(|s| &mut s.delta).collect();
            delta.merge_parallel(&mut parts);
            delta.drain(|e, removed| {
                if round_of[e as usize] == ALIVE {
                    cur[e as usize] = cur[e as usize].saturating_sub(removed);
                }
            });
            rnd += 1;
        }
    }
    let coarse_rounds = rnd as usize;

    // ---- Seeds: butterflies whose other three edges are all
    // same-or-later range, via one stamped enumeration. ----
    let mut seed = vec![0u64; m];
    {
        let sp = SyncPtr(seed.as_mut_ptr());
        let stage = &stage[..];
        let spool: ScratchPool<Vec<u32>> = ScratchPool::new();
        parallel_for_dynamic_pooled(
            m,
            walk_grain(m, fp),
            &spool,
            || vec![ALIVE; g.nv()],
            |tag, range| {
                for ei in range {
                    let e = ei as u32;
                    let s = stage[ei];
                    let (u1, v1) = g.edge(e);
                    // Stamp v2 for every (u1, v2) slot of stage >= s.
                    // The (u1, v1) slot is edge `e` itself, whose
                    // `stage >= s` holds trivially — skip it
                    // explicitly so v1 is never stamped.
                    for (i, &v2) in g.nbrs_u(u1 as usize).iter().enumerate() {
                        let ea = g.eid_u(u1 as usize, i);
                        if ea != e && stage[ea as usize] >= s {
                            tag[v2 as usize] = e;
                        }
                    }
                    // Stale tags from other edges can never equal `e`:
                    // each edge id is enumerated exactly once.
                    let mut b = 0u64;
                    let nb = g.nbrs_v(v1 as usize);
                    let ed = g.eids_v(v1 as usize);
                    for (i, &u2) in nb.iter().enumerate() {
                        let e2 = ed[i];
                        if u2 == u1 || stage[e2 as usize] < s {
                            continue;
                        }
                        for (t, &v2) in g.nbrs_u(u2 as usize).iter().enumerate() {
                            let eb = g.eid_u(u2 as usize, t);
                            if tag[v2 as usize] == e && stage[eb as usize] >= s {
                                b += 1;
                            }
                        }
                    }
                    unsafe { *sp.get().add(ei) = b };
                }
            },
        );
    }

    // ---- Phase 2: ranges fine-peel concurrently. ----
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); nranges];
    for e in 0..m as u32 {
        members[stage[e as usize] as usize].push(e);
    }
    let mut local_of = vec![0u32; m];
    for ms in &members {
        for (i, &e) in ms.iter().enumerate() {
            local_of[e as usize] = i as u32;
        }
    }
    let mut wings = vec![0u64; m];
    let mut fine_rounds = vec![0usize; nranges];
    {
        let wp = SyncPtr(wings.as_mut_ptr());
        let rp = SyncPtr(fine_rounds.as_mut_ptr());
        let (stage, seed, members, local_of) =
            (&stage[..], &seed[..], &members[..], &local_of[..]);
        parallel_for_dynamic(nranges, 1, |range| {
            for j in range {
                let r = fine_peel_e_range(g, j as u32, &members[j], local_of, stage, seed, opts, &wp);
                unsafe { *rp.get().add(j) = r };
            }
        });
    }
    WingResult { wings, rounds: coarse_rounds + fine_rounds.into_iter().max().unwrap_or(0) }
}

/// One range's fine PEEL-E: min-bucket rounds with the stamp walk over
/// `stage >= j` filtered views.  Later-range edges are present in
/// every walk but permanently alive — the *per-range* `fr_round`
/// array is what makes the concurrent ranges safe: each range only
/// ever writes rounds for its own members, and a shared array would
/// race on the later-range reads.  Stamp scratch is fresh per range
/// (coarse-phase stamps carry the same edge-id tags and would
/// otherwise be stale); within the range each edge is walked exactly
/// once, so tags never collide.  Runs serially; returns the range's
/// round count.
#[allow(clippy::too_many_arguments)]
fn fine_peel_e_range(
    g: &BipartiteGraph,
    j: u32,
    members: &[u32],
    local_of: &[u32],
    stage: &[u32],
    seed: &[u64],
    opts: &PeelEOpts,
    wings: &SyncPtr<u64>,
) -> usize {
    if members.is_empty() {
        return 0;
    }
    let keep = |_x: u32, e: u32| stage[e as usize] >= j;
    let mut live_u = LiveCsr::u_view_filtered(g, &keep);
    let mut live_v = LiveCsr::v_view_filtered(g, &keep);
    let mut fr_round = vec![ALIVE; g.m()];
    let mut stamp_eid = vec![0u32; g.nv()];
    let mut stamp_tag = vec![ALIVE; g.nv()];
    let seeds: Vec<u64> = members.iter().map(|&e| seed[e as usize]).collect();
    let mut buckets = make_buckets(opts.buckets, &seeds);
    let mut delta = DenseDelta::new(g.m());
    let mut k = 0u64;
    let mut rnd = 0u32;
    while let Some((c, lbatch)) = buckets.pop_min() {
        k = k.max(c);
        for &li in &lbatch {
            let e = members[li as usize];
            unsafe { *wings.get().add(e as usize) = k };
            fr_round[e as usize] = rnd;
        }
        // The stamp walk of `update_e_stamped`, serially, against the
        // range-local round tags.
        for &li in &lbatch {
            let e = members[li as usize];
            let (u1, v1) = g.edge(e);
            let vn = live_u.nbrs(u1 as usize);
            let ve = live_u.eids(u1 as usize);
            for i in 0..vn.len() {
                if alive_for(&fr_round, rnd, ve[i], e) {
                    stamp_eid[vn[i] as usize] = ve[i];
                    stamp_tag[vn[i] as usize] = e;
                }
            }
            let un = live_v.nbrs(v1 as usize);
            let ue = live_v.eids(v1 as usize);
            for i in 0..un.len() {
                let (u2, e2) = (un[i], ue[i]);
                if !alive_for(&fr_round, rnd, e2, e) {
                    continue;
                }
                let wn = live_u.nbrs(u2 as usize);
                let we = live_u.eids(u2 as usize);
                for t in 0..wn.len() {
                    let (v2, eb) = (wn[t], we[t]);
                    if stamp_tag[v2 as usize] == e && alive_for(&fr_round, rnd, eb, e) {
                        delta.add(e2, 1);
                        delta.add(stamp_eid[v2 as usize], 1);
                        delta.add(eb, 1);
                    }
                }
            }
        }
        for &li in &lbatch {
            let e = members[li as usize];
            let (u, v) = g.edge(e);
            live_u.remove(u as usize, e);
            live_v.remove(v as usize, e);
        }
        delta.drain(|e2, removed| {
            // Later-range edges (stage > j) absorb decrements without
            // ever being re-bucketed; finalized range members are
            // dropped by the round tag.
            if stage[e2 as usize] == j && fr_round[e2 as usize] == ALIVE {
                let le = local_of[e2 as usize];
                let cur = buckets.current(le);
                buckets.update(le, cur.saturating_sub(removed).max(k));
            }
        });
        rnd += 1;
    }
    rnd as usize
}

#[cfg(test)]
mod tests {
    use super::super::{PeelEngine, PeelSide};
    use super::*;
    use crate::count::{count_per_edge, count_per_vertex, CountOpts};
    use crate::graph::{gen, Layout};
    use crate::prims::rng::Pcg32;

    /// Direct mirror of the Python model's sorted-walk definition.
    fn thresholds_reference(counts: &[u64]) -> Vec<u64> {
        let n = counts.len();
        let total: u128 = counts.iter().map(|&c| c as u128).sum();
        let p = ((n as f64).sqrt() as u128).max(1);
        let mut order = counts.to_vec();
        order.sort_unstable();
        let mut thr = Vec::new();
        if total > 0 && p > 1 {
            let (mut acc, mut i, mut j) = (0u128, 0usize, 1u128);
            while i < n && j < p {
                let v = order[i];
                while i < n && order[i] == v {
                    acc += v as u128;
                    i += 1;
                }
                if acc * p >= j * total {
                    thr.push(v);
                    while j < p && acc * p >= j * total {
                        j += 1;
                    }
                }
            }
        }
        thr.push(u64::MAX);
        thr
    }

    #[test]
    fn thresholds_match_the_sorted_walk_reference() {
        let mut rng = Pcg32::new(42);
        for trial in 0..200 {
            let n = (rng.next_below(60) + 1) as usize;
            let counts: Vec<u64> = (0..n)
                .map(|_| match rng.next_below(3) {
                    0 => 0,
                    1 => rng.next_below(8),
                    _ => rng.next_below(100_000),
                })
                .collect();
            assert_eq!(
                range_thresholds(&counts),
                thresholds_reference(&counts),
                "trial {trial}: {counts:?}"
            );
        }
    }

    #[test]
    fn thresholds_degenerate_cases() {
        assert_eq!(range_thresholds(&[]), vec![u64::MAX]);
        assert_eq!(range_thresholds(&[7]), vec![u64::MAX]);
        assert_eq!(range_thresholds(&[0, 0, 0, 0]), vec![u64::MAX]);
        // Thresholds are strictly increasing and sentinel-terminated.
        let thr = range_thresholds(&[1, 1, 2, 3, 3, 8, 9, 40, 40, 41, 90, 90, 90, 200, 1000, 1000]);
        assert!(thr.windows(2).all(|w| w[0] < w[1]), "{thr:?}");
        assert_eq!(*thr.last().unwrap(), u64::MAX);
        assert!(thr.len() > 1, "mass this spread must split: {thr:?}");
    }

    #[test]
    fn two_phase_tips_match_agg() {
        for seed in [3, 17, 29] {
            let g = gen::chung_lu(30, 36, 320, 2.0, seed);
            let vc = count_per_vertex(&g, &CountOpts::default()).unwrap();
            for side in [PeelSide::U, PeelSide::V] {
                let base = super::super::vertex::peel_vertices(
                    &g,
                    &vc.bu,
                    &vc.bv,
                    &PeelVOpts { engine: PeelEngine::Agg, side, ..Default::default() },
                )
                .unwrap();
                let two = super::super::vertex::peel_vertices(
                    &g,
                    &vc.bu,
                    &vc.bv,
                    &PeelVOpts {
                        engine: PeelEngine::TwoPhase,
                        side,
                        layout: Layout::Flat,
                        ..Default::default()
                    },
                )
                .unwrap();
                assert_eq!(two.tips, base.tips, "seed={seed} side={side:?}");
                assert_eq!(two.peeled_u, base.peeled_u);
            }
        }
    }

    #[test]
    fn two_phase_wings_match_agg() {
        for seed in [5, 23] {
            let g = gen::chung_lu(26, 30, 260, 2.1, seed);
            let be = count_per_edge(&g, &CountOpts::default()).unwrap();
            let base = super::super::edge::peel_edges(
                &g,
                &be,
                &PeelEOpts { engine: PeelEngine::Agg, ..Default::default() },
            )
            .unwrap();
            let two = super::super::edge::peel_edges(
                &g,
                &be,
                &PeelEOpts {
                    engine: PeelEngine::TwoPhase,
                    layout: Layout::Flat,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(two.wings, base.wings, "seed={seed}");
        }
    }

    #[test]
    fn two_phase_composes_with_hub_layout() {
        let g = gen::chung_lu(28, 34, 300, 2.0, 77);
        let vc = count_per_vertex(&g, &CountOpts::default()).unwrap();
        let be = count_per_edge(&g, &CountOpts::default()).unwrap();
        let flat = super::super::vertex::peel_vertices(
            &g,
            &vc.bu,
            &vc.bv,
            &PeelVOpts {
                engine: PeelEngine::TwoPhase,
                side: PeelSide::U,
                layout: Layout::Flat,
                ..Default::default()
            },
        )
        .unwrap();
        let hub = super::super::vertex::peel_vertices(
            &g,
            &vc.bu,
            &vc.bv,
            &PeelVOpts {
                engine: PeelEngine::TwoPhase,
                side: PeelSide::U,
                layout: Layout::Hub,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(hub.tips, flat.tips);
        let wf = super::super::edge::peel_edges(
            &g,
            &be,
            &PeelEOpts { engine: PeelEngine::TwoPhase, layout: Layout::Flat, ..Default::default() },
        )
        .unwrap();
        let wh = super::super::edge::peel_edges(
            &g,
            &be,
            &PeelEOpts { engine: PeelEngine::TwoPhase, layout: Layout::Hub, ..Default::default() },
        )
        .unwrap();
        assert_eq!(wh.wings, wf.wings);
    }
}
