//! Sariyüce–Pinar-style sequential peeling (the Table 4 baseline).
//!
//! Their implementation buckets by butterfly count in a **dense array
//! sized by the maximum count** and advances a cursor one bucket at a
//! time — including across *empty* buckets.  When counts are huge and
//! sparse (discogs_style: max-b_v ≈ 5.9e7 over 383 vertices), nearly
//! all time goes to scanning empties; that is exactly what the paper's
//! skip-ahead / Fibonacci-heap bucketing removes.  We reproduce the
//! behaviour faithfully (cursor walk, lazy entries, one min *bucket*
//! at a time, single-threaded updates).

use crate::graph::BipartiteGraph;

#[inline]
fn choose2(d: u64) -> u64 {
    d * d.saturating_sub(1) / 2
}

/// Dense-array bucketing cursor; also reports how many empty buckets
/// were scanned (the Table 4 diagnostic).
struct DenseBuckets {
    buckets: Vec<Vec<u32>>,
    cur: Vec<u64>,
    finalized: Vec<bool>,
    cursor: usize,
    remaining: usize,
    pub empty_scanned: u64,
}

impl DenseBuckets {
    fn new(counts: &[u64]) -> Self {
        let max = counts.iter().copied().max().unwrap_or(0) as usize;
        let mut buckets = vec![Vec::new(); max + 1];
        for (i, &c) in counts.iter().enumerate() {
            buckets[c as usize].push(i as u32);
        }
        Self {
            buckets,
            cur: counts.to_vec(),
            finalized: vec![false; counts.len()],
            cursor: 0,
            remaining: counts.len(),
            empty_scanned: 0,
        }
    }

    /// Next finalized item in count order (one at a time — sequential).
    fn pop(&mut self) -> Option<(u64, u32)> {
        if self.remaining == 0 {
            return None;
        }
        loop {
            if self.cursor >= self.buckets.len() {
                return None;
            }
            // Lazy validity filtering within the cursor bucket.
            while let Some(item) = self.buckets[self.cursor].pop() {
                let idx = item as usize;
                if !self.finalized[idx] && self.cur[idx] as usize == self.cursor {
                    self.finalized[idx] = true;
                    self.remaining -= 1;
                    return Some((self.cursor as u64, item));
                }
            }
            self.cursor += 1;
            self.empty_scanned += 1;
        }
    }

    fn update(&mut self, item: u32, new_count: u64) {
        let idx = item as usize;
        if self.finalized[idx] || new_count >= self.cur[idx] {
            return;
        }
        self.cur[idx] = new_count;
        self.buckets[new_count as usize].push(item);
    }
}

/// Sequential tip decomposition of the U side; returns
/// `(tip numbers, empty buckets scanned)`.
pub fn sp_tip_numbers_u(g: &BipartiteGraph, bu: &[u64]) -> (Vec<u64>, u64) {
    let nu = g.nu();
    let mut b = DenseBuckets::new(bu);
    let mut tips = vec![0u64; nu];
    let mut k = 0u64;
    let mut cnt = vec![0u32; nu];
    let mut touched: Vec<u32> = Vec::new();
    while let Some((c, u1)) = b.pop() {
        k = k.max(c);
        tips[u1 as usize] = k;
        // Update: recount wedges from u1 to live u2 (dense array).
        for &v in g.nbrs_u(u1 as usize) {
            for &u2 in g.nbrs_v(v as usize) {
                let u2 = u2 as usize;
                if u2 as u32 == u1 || b.finalized[u2] {
                    continue;
                }
                if cnt[u2] == 0 {
                    touched.push(u2 as u32);
                }
                cnt[u2] += 1;
            }
        }
        for &u2 in &touched {
            let removed = choose2(cnt[u2 as usize] as u64);
            cnt[u2 as usize] = 0;
            if removed > 0 {
                let cur = b.cur[u2 as usize];
                b.update(u2, cur.saturating_sub(removed).max(k));
            }
        }
        touched.clear();
    }
    (tips, b.empty_scanned)
}

/// Sequential wing decomposition; returns `(wing numbers, empty
/// buckets scanned)`.
pub fn sp_wing_numbers(g: &BipartiteGraph, be: &[u64]) -> (Vec<u64>, u64) {
    let m = g.m();
    let mut b = DenseBuckets::new(be);
    let mut wings = vec![0u64; m];
    let mut k = 0u64;
    while let Some((c, e)) = b.pop() {
        k = k.max(c);
        wings[e as usize] = k;
        let (u1, v1) = g.edge(e);
        let nb_v1 = g.nbrs_v(v1 as usize);
        let ed_v1 = g.eids_v(v1 as usize);
        for (j, &u2) in nb_v1.iter().enumerate() {
            if u2 == u1 {
                continue;
            }
            let e2 = ed_v1[j];
            if b.finalized[e2 as usize] {
                continue;
            }
            let (a, bb) = (g.nbrs_u(u1 as usize), g.nbrs_u(u2 as usize));
            let (mut i1, mut i2) = (0usize, 0usize);
            while i1 < a.len() && i2 < bb.len() {
                match a[i1].cmp(&bb[i2]) {
                    std::cmp::Ordering::Less => i1 += 1,
                    std::cmp::Ordering::Greater => i2 += 1,
                    std::cmp::Ordering::Equal => {
                        let v2 = a[i1];
                        if v2 != v1 {
                            let ea = g.eid_u(u1 as usize, i1);
                            let eb = g.eid_u(u2 as usize, i2);
                            if !b.finalized[ea as usize] && !b.finalized[eb as usize] {
                                for &x in &[e2, ea, eb] {
                                    let cur = b.cur[x as usize];
                                    b.update(x, cur.saturating_sub(1).max(k));
                                }
                            }
                        }
                        i1 += 1;
                        i2 += 1;
                    }
                }
            }
        }
    }
    (wings, b.empty_scanned)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::{count_per_edge, count_per_vertex, CountOpts};
    use crate::graph::gen;
    use crate::testutil::brute;

    #[test]
    fn sp_tips_match_brute_force() {
        for seed in [3, 9] {
            let g = gen::erdos_renyi(12, 14, 75, seed);
            let vc = count_per_vertex(&g, &CountOpts::default()).unwrap();
            let (tips, _) = sp_tip_numbers_u(&g, &vc.bu);
            assert_eq!(tips, brute::tip_numbers_u(&g), "seed={seed}");
        }
    }

    #[test]
    fn sp_wings_match_brute_force() {
        for seed in [2, 8] {
            let g = gen::erdos_renyi(8, 9, 40, seed);
            let be = count_per_edge(&g, &CountOpts::default()).unwrap();
            let (wings, _) = sp_wing_numbers(&g, &be);
            assert_eq!(wings, brute::wing_numbers(&g), "seed={seed}");
        }
    }

    #[test]
    fn empty_bucket_scanning_grows_with_count_range() {
        // Planted dense blocks: few distinct, large counts -> the dense
        // cursor wades through empty buckets (Table 4's discogs_style
        // pathology in miniature).
        let g = gen::planted_blocks(12, 12, 2, 6, 6, 1.0, 0, 1);
        let vc = count_per_vertex(&g, &CountOpts::default()).unwrap();
        let (tips, empties) = sp_tip_numbers_u(&g, &vc.bu);
        assert_eq!(tips, brute::tip_numbers_u(&g));
        // K_{6,6} per-vertex count = 5 * C(6,2) = 75 -> at least ~75
        // empty buckets scanned.
        assert!(empties >= 70, "empties={empties}");
    }
}
