//! Sequential comparators from prior work (the baselines of Tables 2,
//! 4 and the Table 2 "previous work" columns).
//!
//! * [`seq_count`] — Sanei-Mehri et al. side-order counting, the
//!   Wang et al. 2014 vanilla `O(Σ deg²)` algorithm, and a PGD-like
//!   unordered per-edge 4-cycle counter.
//! * [`seq_peel`] — Sariyüce–Pinar-style peeling with a *dense bucket
//!   array* that scans empty buckets sequentially — the behaviour that
//!   the paper's skip-ahead bucketing beats by up to 30696x (Table 4).

pub mod seq_count;
pub mod seq_peel;
