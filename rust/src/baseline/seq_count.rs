//! Sequential counting baselines.
//!
//! All single-threaded by construction (no `prims` parallelism), so
//! Table 2's parallel-vs-sequential comparisons measure algorithm and
//! scheduling differences, not implementation accidents: the data
//! structures mirror what the respective papers describe.

use std::collections::HashMap;

use crate::graph::BipartiteGraph;

#[inline]
fn choose2(d: u64) -> u64 {
    d * d.saturating_sub(1) / 2
}

/// Sanei-Mehri et al. (2018): pick the side whose wedges are cheaper,
/// enumerate its wedges sequentially, aggregate per endpoint pair with
/// a hash map.  `O(min-side Σ deg²)` work.
pub fn sanei_mehri_total(g: &BipartiteGraph) -> u64 {
    // Wedges with endpoints on U have centers on V and cost
    // Σ_v C(deg v, 2); endpoints-on-V costs Σ_u C(deg u, 2).
    let endpoints_u = g.wedges_centered_v() <= g.wedges_centered_u();
    let mut counts: HashMap<u64, u64> = HashMap::new();
    if endpoints_u {
        for v in 0..g.nv() {
            let nbrs = g.nbrs_v(v);
            for i in 0..nbrs.len() {
                for j in (i + 1)..nbrs.len() {
                    *counts
                        .entry(((nbrs[i] as u64) << 32) | nbrs[j] as u64)
                        .or_insert(0) += 1;
                }
            }
        }
    } else {
        for u in 0..g.nu() {
            let nbrs = g.nbrs_u(u);
            for i in 0..nbrs.len() {
                for j in (i + 1)..nbrs.len() {
                    *counts
                        .entry(((nbrs[i] as u64) << 32) | nbrs[j] as u64)
                        .or_insert(0) += 1;
                }
            }
        }
    }
    counts.values().map(|&d| choose2(d)).sum()
}

/// Wang et al. (2014) vanilla rectangle counting: for every U vertex,
/// walk its full 2-hop neighbourhood with a dense counter array —
/// `O(Σ_v deg(v)²)` with no ordering.  Returns per-vertex U counts and
/// the total.
pub fn wang_vanilla(g: &BipartiteGraph) -> (Vec<u64>, u64) {
    let nu = g.nu();
    let mut bu = vec![0u64; nu];
    let mut cnt = vec![0u32; nu];
    let mut touched: Vec<u32> = Vec::new();
    let mut total2 = 0u64;
    for u in 0..nu {
        for &v in g.nbrs_u(u) {
            for &u2 in g.nbrs_v(v as usize) {
                let u2 = u2 as usize;
                if u2 == u {
                    continue;
                }
                if cnt[u2] == 0 {
                    touched.push(u2 as u32);
                }
                cnt[u2] += 1;
            }
        }
        let mut b = 0u64;
        for &u2 in &touched {
            b += choose2(cnt[u2 as usize] as u64);
            cnt[u2 as usize] = 0;
        }
        touched.clear();
        bu[u] = b;
        total2 += b;
    }
    (bu, total2 / 2)
}

/// PGD-like edge-centric 4-cycle counting: for every edge `(u, v)` and
/// co-neighbor `u' ∈ N(v)`, intersect `N(u)` with `N(u')` — the
/// `O(Σ_{(u,v)∈E} Σ_{u'∈N(v)} min(deg u, deg u'))`-ish unordered work
/// bound the paper compares against (it exceeds the counting bound by
/// orders of magnitude on skewed graphs).
pub fn pgd_like_total(g: &BipartiteGraph) -> u64 {
    pgd_like_total_deadline(g, std::time::Duration::MAX).unwrap()
}

/// [`pgd_like_total`] with a time budget: returns `None` if the budget
/// is exhausted (mirrors the paper's "> 5.5 hrs" Table 2 entries —
/// PGD's unordered work bound genuinely does not finish on skewed
/// graphs).
pub fn pgd_like_total_deadline(
    g: &BipartiteGraph,
    budget: std::time::Duration,
) -> Option<u64> {
    let start = std::time::Instant::now();
    let mut quad = 0u64; // counts each butterfly 4 times (per U-side edge pairing)
    for u in 0..g.nu() {
        if u % 64 == 0 && start.elapsed() > budget {
            return None;
        }
        for &v in g.nbrs_u(u) {
            for &u2 in g.nbrs_v(v as usize) {
                if (u2 as usize) == u {
                    continue;
                }
                // |N(u) ∩ N(u2)| - 1 butterflies close this path.
                let (a, b) = (g.nbrs_u(u), g.nbrs_u(u2 as usize));
                let (mut i, mut j, mut c) = (0, 0, 0u64);
                while i < a.len() && j < b.len() {
                    match a[i].cmp(&b[j]) {
                        std::cmp::Ordering::Less => i += 1,
                        std::cmp::Ordering::Greater => j += 1,
                        std::cmp::Ordering::Equal => {
                            c += 1;
                            i += 1;
                            j += 1;
                        }
                    }
                }
                quad += c.saturating_sub(1);
            }
        }
    }
    Some(quad / 4)
}

/// Chiba–Nishizeki sequential counting with degree ordering — the
/// work-efficient `O(alpha m)` sequential algorithm our parallel
/// framework matches (used as the honest sequential-best in Table 2).
pub fn chiba_nishizeki_total(g: &BipartiteGraph) -> u64 {
    let rg = crate::rank::preprocess(g, crate::rank::Ranking::Degree);
    let mut total = 0u64;
    let mut cnt: Vec<u32> = vec![0; rg.n()];
    let mut touched: Vec<u32> = Vec::new();
    for x1 in 0..rg.n() {
        crate::count::wedges::wedges_of_source(&rg, false, x1, |w| {
            if cnt[w.hi as usize] == 0 {
                touched.push(w.hi);
            }
            cnt[w.hi as usize] += 1;
        });
        for &x2 in &touched {
            total += choose2(cnt[x2 as usize] as u64);
            cnt[x2 as usize] = 0;
        }
        touched.clear();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::testutil::brute;

    #[test]
    fn all_baselines_agree_with_brute_force() {
        for seed in [1, 6, 12] {
            let g = gen::erdos_renyi(20, 25, 180, seed);
            let expect = brute::total(&g);
            assert_eq!(sanei_mehri_total(&g), expect, "sanei seed={seed}");
            assert_eq!(wang_vanilla(&g).1, expect, "wang seed={seed}");
            assert_eq!(pgd_like_total(&g), expect, "pgd seed={seed}");
            assert_eq!(chiba_nishizeki_total(&g), expect, "cn seed={seed}");
        }
    }

    #[test]
    fn wang_per_vertex_matches() {
        let g = gen::chung_lu(30, 40, 300, 2.2, 5);
        let (bu, _) = wang_vanilla(&g);
        let (expect, _) = brute::per_vertex(&g);
        assert_eq!(bu, expect);
    }

    #[test]
    fn skewed_graph_consistency() {
        let g = gen::chung_lu(60, 90, 800, 2.1, 8);
        let a = sanei_mehri_total(&g);
        assert_eq!(a, wang_vanilla(&g).1);
        assert_eq!(a, chiba_nishizeki_total(&g));
        assert_eq!(a, pgd_like_total(&g));
    }
}
