//! Edge-list IO.
//!
//! Two formats:
//! * plain edge list — `u v` per line, 0-indexed, `#`/`%` comments;
//!   header line `# bip <nu> <nv>` optional (inferred from max ids
//!   otherwise).
//! * KONECT out.* files — `% bip` header, whitespace-separated
//!   1-indexed pairs (extra columns such as weights/timestamps are
//!   ignored), matching how the paper loads its datasets.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use super::bipartite::BipartiteGraph;

/// Load either supported format (sniffed from the header / indexing).
pub fn load_edge_list(path: &Path) -> anyhow::Result<BipartiteGraph> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut header: Option<(usize, usize)> = None;
    let mut konect = false;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        if t.starts_with('%') {
            // KONECT-style header.
            if lineno == 0 {
                konect = true;
            }
            continue;
        }
        if let Some(rest) = t.strip_prefix("# bip") {
            let mut it = rest.split_whitespace();
            let nu: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad bip header"))?.parse()?;
            let nv: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad bip header"))?.parse()?;
            header = Some((nu, nv));
            continue;
        }
        if t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u32 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing u", lineno + 1))?
            .parse()?;
        let v: u32 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing v", lineno + 1))?
            .parse()?;
        if konect {
            anyhow::ensure!(u >= 1 && v >= 1, "line {}: KONECT ids are 1-indexed", lineno + 1);
            edges.push((u - 1, v - 1));
        } else {
            edges.push((u, v));
        }
    }
    let (nu, nv) = header.unwrap_or_else(|| {
        let nu = edges.iter().map(|e| e.0 as usize + 1).max().unwrap_or(0);
        let nv = edges.iter().map(|e| e.1 as usize + 1).max().unwrap_or(0);
        (nu, nv)
    });
    Ok(BipartiteGraph::from_edges(nu, nv, &edges))
}

/// Write the plain edge-list format (with `# bip` header).
pub fn save_edge_list(g: &BipartiteGraph, path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# bip {} {}", g.nu(), g.nv())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn roundtrip_plain() {
        let g = gen::erdos_renyi(30, 40, 200, 5);
        let dir = std::env::temp_dir().join("pb_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        save_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list(&path).unwrap();
        assert_eq!(g2.nu(), g.nu());
        assert_eq!(g2.nv(), g.nv());
        assert_eq!(g2.edges(), g.edges());
    }

    #[test]
    fn konect_one_indexed_with_extra_columns() {
        let dir = std::env::temp_dir().join("pb_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.test");
        std::fs::write(&path, "% bip unweighted\n1 1 1 1280000\n2 1 1 1280001\n2 2\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.nu(), 2);
        assert_eq!(g.nv(), 2);
        assert_eq!(g.edges(), vec![(0, 0), (1, 0), (1, 1)]);
    }

    #[test]
    fn comments_and_blank_lines() {
        let dir = std::env::temp_dir().join("pb_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.txt");
        std::fs::write(&path, "# bip 3 3\n# a comment\n\n0 1\n2 2\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.nu(), 3);
        assert_eq!(g.m(), 2);
    }

    #[test]
    fn missing_file_errors() {
        assert!(load_edge_list(Path::new("/nonexistent/nope.txt")).is_err());
    }
}
